"""Unit + property tests for the paper's estimators (core/)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:          # offline container: deterministic shim
    from _hyp_fallback import given, settings, st

from repro.core import (exact_log_z, mimps_log_z, uniform_log_z, nmimps_log_z,
                        mince_log_z, head_tail_log_z, combine_head_tail_lse,
                        relative_error, build_ivf, mimps_ivf, probe,
                        gather_scores, exact_top_k, kmeans, make_feature_map,
                        build_fmbe, fmbe_z, apply_feature_map, solve_log_z,
                        solver_convergence_trace)
from repro.core.estimators import oracle_retrieve


def _q(vectors, i=123):
    return vectors[i]


class TestExact:
    def test_matches_numpy(self, vectors):
        q = _q(vectors)
        ours = exact_log_z(vectors, q)
        ref = np.log(np.sum(np.exp(np.asarray(vectors @ q, np.float64))))
        np.testing.assert_allclose(float(ours), ref, rtol=1e-5)

    def test_batched_vmap(self, vectors):
        qs = vectors[:8]
        out = jax.vmap(lambda q: exact_log_z(vectors, q))(qs)
        assert out.shape == (8,)
        assert bool(jnp.all(jnp.isfinite(out)))


class TestMIMPS:
    def test_full_head_is_exact(self, vectors, rng):
        """k = N, l = 0 degenerates to exact Z."""
        q = _q(vectors)
        lz = mimps_log_z(vectors, q, vectors.shape[0] - 1, 1, rng)
        np.testing.assert_allclose(float(lz), float(exact_log_z(vectors, q)),
                                   rtol=5e-4)

    def test_error_decreases_with_k(self, vectors, rng):
        """Paper Table 1 row pattern: error monotone down the k column."""
        q = _q(vectors)
        lzt = exact_log_z(vectors, q)
        errs = []
        for k in (1, 10, 100, 1000):
            samples = [relative_error(
                mimps_log_z(vectors, q, k, 100, jax.random.fold_in(rng, 17*k + s)),
                lzt) for s in range(5)]
            errs.append(float(np.mean(samples)))
        assert errs[-1] < errs[0]
        assert errs[-1] < 0.05

    def test_unbiased_tail(self, vectors, rng):
        """E[Z_hat] == Z over tail sampling (property of Eq. 5)."""
        q = _q(vectors)
        lzt = float(exact_log_z(vectors, q))
        keys = jax.random.split(rng, 1024)
        zs = jax.vmap(lambda k: jnp.exp(
            mimps_log_z(vectors, q, 100, 50, k)))(keys)
        rel = abs(float(jnp.mean(zs)) / np.exp(lzt) - 1.0)
        assert rel < 0.05, f"tail estimator biased: {rel}"

    def test_retrieval_error_rank1_worst(self, vectors, rng):
        """Paper Table 3: dropping rank-1 hurts much more than rank-2."""
        q = _q(vectors)
        lzt = exact_log_z(vectors, q)
        base = relative_error(mimps_log_z(vectors, q, 1000, 1000, rng), lzt)
        e1 = relative_error(
            mimps_log_z(vectors, q, 1000, 1000, rng, drop_ranks=(0,)), lzt)
        e2 = relative_error(
            mimps_log_z(vectors, q, 1000, 1000, rng, drop_ranks=(1,)), lzt)
        assert float(e1) > float(e2) >= 0.0
        assert float(e1) > float(base)

    def test_uniform_is_k0(self, vectors, rng):
        q = _q(vectors)
        lz = uniform_log_z(vectors, q, 500, rng)
        assert bool(jnp.isfinite(lz))

    def test_nmimps_underestimates(self, vectors):
        q = _q(vectors)
        lz = nmimps_log_z(vectors, q, 100)
        assert float(lz) < float(exact_log_z(vectors, q))


class TestHeadTail:
    @given(st.integers(1, 50), st.integers(1, 50), st.floats(-3, 3))
    @settings(max_examples=20, deadline=None)
    def test_headtail_property(self, nh, nt, shift):
        """head+tail == exact when tail sample == full tail (scale 1)."""
        rng = np.random.RandomState(nh * 100 + nt)
        head = jnp.array(rng.randn(nh) + shift, jnp.float32)
        tail = jnp.array(rng.randn(nt) - 1.0 + shift, jnp.float32)
        lz = head_tail_log_z(head, tail, jnp.float32(nt), jnp.float32(nt))
        ref = np.log(np.exp(np.asarray(head, np.float64)).sum()
                     + np.exp(np.asarray(tail, np.float64)).sum())
        np.testing.assert_allclose(float(lz), ref, rtol=1e-4)

    @given(st.integers(1, 64), st.integers(1, 64), st.floats(-3, 3),
           st.integers(1, 100000))
    @settings(max_examples=25, deadline=None)
    def test_fused_combine_matches_unfused(self, nh, nt, shift, n_total):
        """The fused-kernel interface (combine precomputed LSEs) must equal
        the unfused score-level head_tail_log_z within 1e-4 for any head/tail
        sizes, score shifts and tail populations (Eq. 5 equivalence)."""
        rng = np.random.RandomState(nh * 1000 + nt * 7 + n_total % 97)
        head = jnp.array(rng.randn(nh) + shift, jnp.float32)
        tail = jnp.array(rng.randn(nt) - 1.0 + shift, jnp.float32)
        fused = combine_head_tail_lse(
            jax.nn.logsumexp(head), jax.nn.logsumexp(tail),
            jnp.float32(n_total), jnp.float32(nt))
        unfused = head_tail_log_z(head, tail, jnp.float32(n_total),
                                  jnp.float32(nt))
        np.testing.assert_allclose(float(fused), float(unfused), atol=1e-4,
                                   rtol=1e-5)
        ref = np.log(np.exp(np.asarray(head, np.float64)).sum() +
                     (n_total / nt) *
                     np.exp(np.asarray(tail, np.float64)).sum())
        np.testing.assert_allclose(float(fused), ref, rtol=1e-4)


class TestMINCE:
    def test_solver_finds_root_on_synthetic(self):
        """With well-separated alpha/beta the NCE objective's optimum is
        recoverable; check f'(theta*) ~ 0."""
        rng = np.random.RandomState(0)
        alpha = jnp.array(rng.randn(100) + 8.0, jnp.float32)
        beta = jnp.array(rng.randn(100), jnp.float32)
        theta = solve_log_z(alpha, beta, jnp.float32(4.0), iters=40)
        trace = solver_convergence_trace(alpha, beta, jnp.float32(4.0), 40)
        assert float(trace[-1]) < 1e-2

    def test_halley_converges_at_least_as_fast(self):
        rng = np.random.RandomState(1)
        alpha = jnp.array(rng.randn(200) + 6.0, jnp.float32)
        beta = jnp.array(rng.randn(200), jnp.float32)
        th0 = jnp.float32(2.0)
        h = solver_convergence_trace(alpha, beta, th0, 15, solver="halley")
        n = solver_convergence_trace(alpha, beta, th0, 15, solver="newton")
        # compare first-iteration residual drop (paper: Halley speeds up opt)
        assert float(h[3]) <= float(n[3]) * 2.0  # not catastrophically worse
        assert float(h[-1]) < 1e-2

    def test_mince_runs_and_is_worse_than_mimps(self, vectors, rng):
        """Paper's empirical finding (Table 1): MINCE >> MIMPS error.

        Pinned to weighting='paper' — the literal Eq. 6/7 estimator Table 1
        reproduces. (The anchored serving weighting provably collapses onto
        the Eq. 5 estimate, so its error ties MIMPS by construction; the
        paper's gap is exactly the sampling noise the anchoring removes.)
        Averaged over several sampling draws — a single draw of either
        estimator is noisy enough to flip the comparison.
        """
        q = _q(vectors)
        lzt = exact_log_z(vectors, q)
        e_mince, e_mimps = [], []
        for s in range(8):
            k = jax.random.fold_in(rng, s)
            e_mince.append(float(relative_error(
                mince_log_z(vectors, q, 100, 100, k, weighting="paper"),
                lzt)))
            e_mimps.append(float(relative_error(
                mimps_log_z(vectors, q, 100, 100, k), lzt)))
        assert np.mean(e_mimps) < np.mean(e_mince)


class TestFMBE:
    def test_kernel_approx_unbiased(self, rng):
        """E[phi(x).phi(y)] ~= exp(x.y) for moderate dot products."""
        d = 16
        kx, kf = jax.random.split(rng)
        x = jax.random.normal(kx, (d,)) * 0.3
        y = -x * 0.5
        fm = make_feature_map(kf, d, 65536, max_degree=8)
        approx = float(jnp.sum(apply_feature_map(fm, x) * apply_feature_map(fm, y)))
        true = float(jnp.exp(jnp.dot(x, y)))
        assert abs(approx - true) / true < 0.15

    def test_fmbe_z_estimate(self, vectors, rng):
        v = vectors[:2048]
        q = v[7]
        fm = make_feature_map(rng, v.shape[1], 16384)
        st_ = build_fmbe(fm, v)
        z = float(fmbe_z(st_, q))
        zt = float(jnp.exp(exact_log_z(v, q)))
        # paper shows FMBE is a poor estimator at practical P — just require
        # the right order of magnitude.
        assert z > 0
        assert abs(np.log(max(z, 1e-9)) - np.log(zt)) < 2.0


class TestIVF:
    def test_kmeans_reduces_distortion(self, vectors, rng):
        v = vectors[:2048]
        c1, a1 = kmeans(rng, v, 16, iters=1)
        c2, a2 = kmeans(rng, v, 16, iters=10)
        d1 = float(jnp.sum((v - c1[a1]) ** 2))
        d2 = float(jnp.sum((v - c2[a2]) ** 2))
        assert d2 <= d1 * 1.001

    def test_index_covers_all_rows(self, vectors, rng):
        idx = build_ivf(rng, vectors, block_rows=128)
        ids = np.asarray(idx.row_id).ravel()
        real = np.sort(ids[ids >= 0])
        np.testing.assert_array_equal(real, np.arange(vectors.shape[0]))

    def test_probe_recall_top1(self, vectors, rng):
        """Rank-1 recall (the paper's critical retrieval property, Table 3)."""
        idx = build_ivf(rng, vectors, block_rows=128)
        hits = 0
        queries = vectors[:64]
        for i in range(64):
            q = queries[i]
            blocks = probe(idx, q, 8)
            s, valid = gather_scores(idx, q, blocks)
            s = jnp.where(valid, s, -1e30)
            _, ids = exact_top_k(vectors, q, 1)
            best_slot = int(jnp.argmax(s))
            rid = int(idx.row_id[blocks[best_slot // idx.block_rows],
                                 best_slot % idx.block_rows])
            hits += int(rid == int(ids[0]))
        assert hits >= 58, f"rank-1 recall too low: {hits}/64"

    def test_ivf_mimps_accuracy(self, vectors, rng):
        idx = build_ivf(rng, vectors, block_rows=128)
        q = _q(vectors)
        lzt = exact_log_z(vectors, q)
        r = mimps_ivf(idx, q, 8, 256, rng)
        assert float(relative_error(r.log_z, lzt)) < 0.25

    def test_ivf_cost_is_sublinear(self, vectors, rng):
        """FLOP accounting: probed rows + centroids << N."""
        idx = build_ivf(rng, vectors, block_rows=128)
        n_scored = idx.n_blocks + 8 * idx.block_rows + 256
        assert n_scored < vectors.shape[0] // 3


class TestOracle:
    def test_sorted_order(self, vectors):
        r = oracle_retrieve(vectors, _q(vectors))
        s = np.asarray(r.scores_sorted)
        assert (np.diff(s) <= 1e-6).all()
