"""Kernel autotuner (kernels/autotune.py): sweep, cache, failure handling,
and the backend/engine integration surface."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune as at


class TestAutotuneCore:
    def test_picks_fastest_and_caches(self, tmp_path):
        path = str(tmp_path / "cache.json")
        calls = []

        def build(cfg):
            def run():
                calls.append(cfg["x"])
                if cfg["x"] == 2:           # "fast" config: no busy work
                    return jnp.zeros(())
                sum(i * i for i in range(50_000))
                return jnp.zeros(())
            return run

        cands = [{"x": 1}, {"x": 2}, {"x": 3}]
        args = (jnp.zeros((4, 8)),)
        best = at.autotune("fake", cands, build, args, reps=2, path=path)
        assert best == {"x": 2}
        assert os.path.exists(path)
        # second call: cache hit, no sweeps run
        calls.clear()
        again = at.autotune("fake", cands, build, args, reps=2, path=path)
        assert again == {"x": 2}
        assert calls == []

    def test_cache_key_varies_with_shape_dtype_and_kernel(self):
        a32 = jnp.zeros((4, 8), jnp.float32)
        a16 = jnp.zeros((4, 8), jnp.bfloat16)
        b = jnp.zeros((8, 8), jnp.float32)
        k1 = at.cache_key("k", (a32,))
        assert k1 != at.cache_key("k", (a16,))
        assert k1 != at.cache_key("k", (b,))
        assert k1 != at.cache_key("other", (a32,))
        assert at.cache_key("k", (a32, 7)) != at.cache_key("k", (a32, 8))
        # deterministic
        assert k1 == at.cache_key("k", (jnp.zeros((4, 8), jnp.float32),))

    def test_failing_candidates_skipped(self, tmp_path):
        path = str(tmp_path / "cache.json")

        def build(cfg):
            def run():
                if cfg["x"] != 1:
                    raise RuntimeError("tile too large")
                return jnp.zeros(())
            return run

        best = at.autotune("flaky", [{"x": 0}, {"x": 1}, {"x": 2}],
                           build, (jnp.zeros((2,)),), reps=1, path=path)
        assert best == {"x": 1}
        rec = json.load(open(path))
        swept = next(iter(rec.values()))["swept"]
        assert sum("error" in r for r in swept) == 2

    def test_all_failing_returns_first_default(self, tmp_path):
        path = str(tmp_path / "cache.json")

        def build(cfg):
            def run():
                raise RuntimeError("no")
            return run

        best = at.autotune("dead", [{"x": 5}, {"x": 6}], build,
                           (jnp.zeros(()),), reps=1, path=path)
        assert best == {"x": 5}
        assert not os.path.exists(path)    # nothing worth caching


class TestKernelSweeps:
    def test_tune_ivf_decode_returns_runnable_config(self, tmp_path, rng):
        from repro.core import build_ivf
        from repro.core.decode import _tail_rows, make_plan, mimps_decode
        path = str(tmp_path / "cache.json")
        v = jax.random.normal(rng, (1024, 32)) * 0.3
        index = build_ivf(rng, v, block_rows=64)
        h = v[:8]
        plan = make_plan(index, h, rng, 2, 16)
        rows = _tail_rows(index, plan)
        row_logw = jnp.where(index.valid, 0.0, -1e30).astype(jnp.float32)
        cfg = at.tune_ivf_decode(index.v_blocks, h, plan.head_ids,
                                 plan.head_live, plan.head_member, row_logw,
                                 rows, plan.tail_accept, reps=1, path=path)
        assert set(cfg) == {"block_q", "tail_tile"}
        # the tuned config must run through the real decode path
        out = mimps_decode(index, h, rng, n_probe=2, l=16, k=1,
                           use_pallas=True, **cfg)
        ref = mimps_decode(index, h, rng, n_probe=2, l=16, k=1,
                           use_pallas=False)
        np.testing.assert_allclose(np.asarray(out.log_z),
                                   np.asarray(ref.log_z), atol=1e-4)

    def test_backend_tune_integration(self, tmp_path, rng):
        """Every registered backend's tune() returns decode-able kwargs."""
        import dataclasses

        from repro.configs.base import PartitionConfig
        from repro.core.backends import get_backend
        path = str(tmp_path / "cache.json")
        v = jax.random.normal(rng, (1024, 32)) * 0.3
        h = v[:8]
        cfg = PartitionConfig(method="mimps", block_rows=64, n_probe=2, l=16,
                              n_clusters=0, fmbe_features=256,
                              fmbe_max_degree=3)
        for method in ("mimps", "mince", "fmbe"):
            c = dataclasses.replace(cfg, method=method)
            bk = get_backend(method)
            state = bk.build(c, v, rng)
            kcfg = bk.tune(state, c, h, rng, path=path)
            assert isinstance(kcfg, dict)
            out = bk.decode(state, h, rng, c, k=1, use_pallas=True, **kcfg)
            ref = bk.decode(state, h, rng, c, k=1, use_pallas=False)
            np.testing.assert_allclose(np.asarray(out.log_z),
                                       np.asarray(ref.log_z), atol=1e-4,
                                       err_msg=method)
