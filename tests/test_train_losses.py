"""Estimator-backed training: sparse CE gradients, index lifecycle,
train->serve handoff (DESIGN.md SS13)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                      # pragma: no cover
    from _hyp_fallback import given, settings, st

from repro.configs import reduced_config
from repro.configs.base import TrainConfig
from repro.core import build_ivf_device, kmeans, kmeans_step, refresh_ivf
from repro.core.kmeans import _assign
from repro.models import Model
from repro.train import init_train_state, make_index_refresh, make_train_step
from repro.train.losses import ESTIMATOR_LOSSES, LOSSES, estimator_ce


def _full_ce(h, w, labels):
    logits = (h @ w.T).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, -1)
    s = jnp.take_along_axis(logits, labels[:, None], -1)[:, 0]
    return (lse - s).mean()


@pytest.fixture(scope="module")
def ce_setup(rng):
    v, d, t = 8192, 64, 32
    w = jax.random.normal(rng, (v, d)) * 0.3
    h = jax.random.normal(jax.random.fold_in(rng, 1), (t, d)) * 0.3
    labels = jax.random.randint(jax.random.fold_in(rng, 2), (t,), 0, v)
    index = build_ivf_device(rng, w, block_rows=64, n_clusters=32)
    return index, h, w, labels


class TestSparseCE:
    def test_logz_close_to_exact(self, ce_setup, rng):
        index, h, w, labels = ce_setup
        nll, lz, aux = estimator_ce(index, h, w, labels,
                                    jax.random.fold_in(rng, 3),
                                    n_probe=8, l=512)
        exact = jax.nn.logsumexp((h @ w.T).astype(jnp.float32), -1)
        err = np.abs(1 - np.exp(np.asarray(lz) - np.asarray(exact)))
        assert err.mean() < 0.1, err.mean()
        # nll >= 0: the label's mass is always inside the estimate
        assert bool(jnp.all(nll >= 0))

    def test_grad_cosine_vs_full_ce(self, ce_setup, rng):
        """Acceptance: cosine >= 0.99 vs the full-CE embedding gradient on
        the probed rows, and on dh."""
        index, h, w, labels = ce_setup
        key = jax.random.fold_in(rng, 3)

        def est(h, w):
            nll, _, _ = estimator_ce(index, h, w, labels, key,
                                     n_probe=8, l=512)
            return nll.mean()

        gh0, gw0 = jax.grad(_full_ce, argnums=(0, 1))(h, w, labels)
        gh1, gw1 = jax.grad(est, argnums=(0, 1))(h, w)
        touched = np.abs(np.asarray(gw1)).sum(-1) > 0
        # the backward writes a strict subset of rows — that IS the point
        assert touched.sum() < 0.6 * w.shape[0], touched.sum()

        def cos(a, b):
            a, b = np.asarray(a).ravel(), np.asarray(b).ravel()
            return a @ b / (np.linalg.norm(a) * np.linalg.norm(b))
        assert cos(gw0[touched], gw1[touched]) >= 0.99
        assert cos(gh0, gh1) >= 0.99

    def test_untouched_rows_have_zero_grad(self, ce_setup, rng):
        """The sparse contract: rows outside head ∪ tail ∪ labels get
        EXACTLY zero gradient (scatter-add, not a dense masked matmul)."""
        index, h, w, labels = ce_setup
        key = jax.random.fold_in(rng, 7)

        def est(w):
            nll, _, _ = estimator_ce(index, h, w, labels, key,
                                     n_probe=2, l=64)
            return nll.mean()

        gw = np.asarray(jax.grad(est)(w))
        zero_rows = np.abs(gw).sum(-1) == 0
        assert zero_rows.sum() > 0.5 * w.shape[0]

    def test_head_cap_trim_matches_full(self, ce_setup, rng):
        """head_cap trimming (cond fallback) never changes the math when the
        union fits, and overflows to the identical full-capacity trace."""
        index, h, w, labels = ce_setup
        key = jax.random.fold_in(rng, 11)
        n0, _, _ = estimator_ce(index, h, w, labels, key, n_probe=4, l=64)
        # generous cap: trimmed branch taken, same estimate
        n1, _, _ = estimator_ce(index, h, w, labels, key, n_probe=4, l=64,
                                head_cap=120)
        # cap of 1 block: always overflows -> full-capacity branch
        n2, _, _ = estimator_ce(index, h, w, labels, key, n_probe=4, l=64,
                                head_cap=1)
        np.testing.assert_allclose(np.asarray(n0), np.asarray(n1), atol=1e-5)
        np.testing.assert_allclose(np.asarray(n0), np.asarray(n2), atol=1e-5)


def _tiny_train(loss, steps=8, seed=0, refresh_every=0):
    cfg = reduced_config("qwen1.5-4b")
    cfg = dataclasses.replace(cfg, vocab=2048, partition=dataclasses.replace(
        cfg.partition, block_rows=64, n_probe=4, l=128, n_clusters=8))
    m = Model(cfg)
    tc = TrainConfig(lr=1e-3, loss=loss, total_steps=steps,
                     index_refresh_every=max(refresh_every, 1))
    state = init_train_state(m, tc, jax.random.PRNGKey(seed))
    step = jax.jit(make_train_step(m, tc))
    refresh = make_index_refresh(m, tc) if loss in ESTIMATOR_LOSSES else None
    key = jax.random.PRNGKey(seed + 1)
    batch = {"tokens": jax.random.randint(key, (2, 17), 0, cfg.vocab)[:, :-1],
             "labels": jax.random.randint(key, (2, 17), 0, cfg.vocab)[:, 1:]}
    losses = []
    for i in range(steps):
        if refresh is not None and refresh_every and i and \
                i % refresh_every == 0:
            state, _ = refresh(state)
        state, met = step(state, batch)
        losses.append(float(met["loss_total"]))
    return m, tc, state, losses


class TestEstimatorTraining:
    @pytest.mark.parametrize("loss", ["mimps_ce", "mince_ce"])
    def test_registered_and_trains(self, loss):
        assert loss in LOSSES
        _, _, state, losses = _tiny_train(loss, steps=8, refresh_every=3)
        assert state.index is not None
        assert all(np.isfinite(losses))
        assert losses[-1] < losses[0]

    def test_refresh_zero_recompiles(self):
        """Refresh-every-K reuses ONE executable (static pack shapes)."""
        cfg = reduced_config("qwen1.5-4b")
        cfg = dataclasses.replace(
            cfg, vocab=2048, partition=dataclasses.replace(
                cfg.partition, block_rows=64, n_probe=4, l=128,
                n_clusters=8))
        m = Model(cfg)
        tc = TrainConfig(lr=1e-3, loss="mimps_ce")
        state = init_train_state(m, tc, jax.random.PRNGKey(0))
        traces = [0]
        n_clusters = 8

        # same (index, params) -> (index, metrics) shape make_index_refresh
        # compiles (narrow on purpose: no full-state output copies)
        @jax.jit
        def refresh(index, params):
            traces[0] += 1
            return refresh_ivf(index, m.head_matrix(params),
                               n_clusters=n_clusters)

        for _ in range(4):
            new_index, metrics = refresh(state.index, state.params)
            state = state._replace(index=new_index)
        assert traces[0] == 1, f"refresh retraced {traces[0]} times"
        assert 0.0 <= float(metrics["churn"]) <= 1.0

    def test_index_rows_track_params(self):
        """After a refresh the index's embedded rows equal the CURRENT head
        matrix rows (the staleness the refresh exists to remove)."""
        m, tc, state, _ = _tiny_train("mimps_ce", steps=4)
        refresh = make_index_refresh(m, tc)
        state2, metrics = refresh(state)
        w = np.asarray(m.head_matrix(state2.params))
        idx = state2.index
        got = np.asarray(
            idx.v_blocks.reshape(-1, w.shape[1])[idx.slot_of_row])
        np.testing.assert_allclose(got, w, atol=1e-6)
        assert float(metrics["drift"]) > 0


class TestKmeansReseed:
    def test_empty_cluster_reseeds_to_farthest(self, rng):
        # two tight groups + one far outlier; third centroid starts dead
        x = jnp.concatenate([
            jnp.zeros((8, 2)) + jnp.array([0.0, 0.0]),
            jnp.zeros((8, 2)) + jnp.array([10.0, 0.0]),
            jnp.array([[50.0, 50.0]]),
        ])
        c0 = jnp.array([[0.0, 0.0], [10.0, 0.0], [-100.0, -100.0]])
        c1 = kmeans_step(x, c0)
        # the dead centroid must move to the farthest-assigned point (the
        # outlier, which sits 50+ from its centroid) — not stay stale
        assert float(jnp.linalg.norm(c1[2] - jnp.array([50.0, 50.0]))) < 1e-5
        counts = np.bincount(np.asarray(_assign(x, c1)), minlength=3)
        assert (counts > 0).all(), counts

    @given(st.integers(min_value=2, max_value=12),
           st.integers(min_value=0, max_value=10_000))
    @settings(max_examples=15, deadline=None)
    def test_reseed_repairs_empty_clusters_property(self, n_clusters, seed):
        """Property: every cluster that enters a Lloyd step empty leaves it
        reseeded onto a data point — and therefore nonempty in the very
        next assignment (distance 0 to its own point)."""
        key = jax.random.PRNGKey(seed)
        x = jax.random.normal(key, (64, 4))
        # adversarial init: every centroid at the same point -> all but one
        # cluster starts empty
        c0 = jnp.tile(x[:1], (n_clusters, 1))
        counts0 = np.bincount(np.asarray(_assign(x, c0)),
                              minlength=n_clusters)
        c1 = kmeans_step(x, c0)
        counts1 = np.bincount(np.asarray(_assign(x, c1)),
                              minlength=n_clusters)
        empty0 = counts0 == 0
        assert empty0.any()
        assert counts1[empty0].min() > 0, (counts0, counts1)

    def test_kmeans_end_to_end_no_empty(self, rng):
        x = jax.random.normal(rng, (256, 8))
        _, assign = kmeans(rng, x, n_clusters=16, iters=8)
        counts = np.bincount(np.asarray(assign), minlength=16)
        assert counts.min() > 0, counts
