"""Estimator-backend registry tests: batched MINCE/FMBE serving parity, the
FMBE kernel vs its XLA reference, temperature sampling, and the guarantee
that no serving path touches the oracle sort at decode time.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BACKENDS, build_ivf, exact_log_z, fmbe_decode,
                        get_backend, make_feature_map, build_fmbe,
                        apply_feature_map, fmbe_z_batch, mimps_decode,
                        mince_decode, mince_log_z, relative_error,
                        solve_log_z, uniform_log_z)
from repro.core.estimators import _complement_sample, oracle_retrieve
from repro.kernels.fmbe import fmbe_phi, fmbe_z


@pytest.fixture(scope="module")
def index(vectors, rng):
    return build_ivf(rng, vectors, block_rows=128)


# ---------------------------------------------------------------------------
# FMBE kernel parity (acceptance: 1e-4, f32 and bf16)
# ---------------------------------------------------------------------------

class TestFMBEKernel:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("q,p_feat,deg", [(13, 1000, 5), (32, 512, 8),
                                              (5, 300, 3)])
    def test_phi_matches_reference(self, vectors, rng, dtype, q, p_feat, deg):
        """Kernel phi == apply_feature_map within 1e-4 (incl. odd shapes:
        the feature axis is padded with coef == 0 features)."""
        d = vectors.shape[1]
        fm = make_feature_map(rng, d, p_feat, max_degree=deg)
        x = vectors[:q].astype(dtype)
        ref = np.asarray(apply_feature_map(fm, x), np.float32)
        ker = np.asarray(fmbe_phi(fm.omega, fm.degree, fm.coef, x))
        np.testing.assert_allclose(ker, ref, atol=1e-4, rtol=1e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_fused_z_matches_reference(self, vectors, rng, dtype):
        """Fused z (no (Q, P) materialization) == phi @ lambda at 1e-4 rel."""
        fm = make_feature_map(rng, vectors.shape[1], 1024, max_degree=6)
        st = build_fmbe(fm, vectors[:2048])
        x = vectors[:17].astype(dtype)
        z_ref = np.asarray(fmbe_z_batch(st, x))
        z_ker = np.asarray(fmbe_z(fm.omega, fm.degree, fm.coef,
                                  st.lambda_tilde, x))
        np.testing.assert_allclose(z_ker, z_ref, rtol=1e-4,
                                   atol=1e-4 * max(1.0, np.abs(z_ref).max()))

    def test_z_batch_pallas_toggle(self, vectors, rng):
        fm = make_feature_map(rng, vectors.shape[1], 512, max_degree=4)
        st = build_fmbe(fm, vectors[:1024])
        x = vectors[:9]
        a = np.asarray(fmbe_z_batch(st, x, use_pallas=False))
        b = np.asarray(fmbe_z_batch(st, x, use_pallas=True))
        np.testing.assert_allclose(b, a, rtol=1e-4,
                                   atol=1e-4 * max(1.0, np.abs(a).max()))


class TestFMBEStatistical:
    def test_batched_fmbe_unbiased_over_maps(self, rng):
        """E[Ẑ] == Z over feature-map draws (degree-capped kernel), checked
        batched against exact_log_z on a small vocab."""
        sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
        from conftest import make_clustered_vectors
        v = make_clustered_vectors(jax.random.fold_in(rng, 77), 1024, 16)
        qs = v[:4]
        z_true = np.exp(np.asarray(
            jax.vmap(lambda q: exact_log_z(v, q))(qs), np.float64))

        def one_map(k):
            fm = make_feature_map(k, 16, 2048, max_degree=8)
            return fmbe_z_batch(build_fmbe(fm, v), qs)

        zs = np.asarray(jnp.stack(
            [one_map(jax.random.fold_in(rng, s)) for s in range(48)]))
        ratio = zs.mean(axis=0) / z_true
        assert np.all(np.abs(ratio - 1.0) < 0.2), ratio


# ---------------------------------------------------------------------------
# Batched MINCE
# ---------------------------------------------------------------------------

class TestUnionScores:
    @pytest.mark.parametrize("q,p", [(16, 8), (5, 3)])
    def test_kernel_matches_gather(self, index, vectors, rng, q, p):
        """union_scores (per-tile union sweep, dead slots skipped) == the
        XLA gather on every live masked slot."""
        from repro.core.decode import make_plan, union_head_scores
        h = vectors[50:50 + q]
        kd = jax.random.fold_in(rng, q)
        plan = make_plan(index, h, kd, p, 8)
        s_k, m_k = union_head_scores(index, h, plan, True)
        s_x, m_x = union_head_scores(index, h, plan, False)
        np.testing.assert_array_equal(np.asarray(m_k), np.asarray(m_x))
        mk = np.asarray(m_k)
        np.testing.assert_allclose(np.asarray(s_k)[mk], np.asarray(s_x)[mk],
                                   atol=1e-4)


class TestBatchedMince:
    def test_batched_solver_matches_per_query_mince(self, vectors, rng):
        """The rank-polymorphic Halley solver on stacked oracle alpha/beta
        reproduces per-query mince_log_z(weighting='paper') exactly (same
        sample sets; the anchored default follows a different estimating
        equation — see core/mince.py)."""
        k, l = 100, 100
        qs = vectors[:6]
        n = vectors.shape[0]
        log_ratio = float(np.log(k) + np.log(n - k) - np.log(l))
        alphas, betas, theta0s, per_query = [], [], [], []
        for i in range(6):
            kq = jax.random.fold_in(rng, i)
            ret = oracle_retrieve(vectors, qs[i])
            head = ret.scores_sorted[:k]
            noise = _complement_sample(kq, ret, k, l)
            alphas.append(head + log_ratio)
            betas.append(noise + log_ratio)
            theta0s.append(jax.nn.logsumexp(head))
            per_query.append(float(mince_log_z(vectors, qs[i], k, l, kq,
                                               weighting="paper")))
        batched = solve_log_z(jnp.stack(alphas), jnp.stack(betas),
                              jnp.stack(theta0s))
        np.testing.assert_allclose(np.asarray(batched),
                                   np.asarray(per_query), atol=1e-4)

    def test_batched_rows_match_single_query_decode(self, index, vectors,
                                                    rng):
        """mince_decode of a batch == mince_decode of each query alone with
        the same key (the shared tail slots coincide; only the rejection
        mask is per-query)."""
        h = vectors[40:48]
        kd = jax.random.fold_in(rng, 3)
        batched = mince_decode(index, h, kd, n_probe=4, l=64,
                               use_pallas=False)
        for i in range(h.shape[0]):
            single = mince_decode(index, h[i:i + 1], kd, n_probe=4, l=64,
                                  use_pallas=False)
            np.testing.assert_allclose(float(batched.log_z[i]),
                                       float(single.log_z[0]), atol=1e-4)

    @pytest.mark.parametrize("q,p,l", [(16, 8, 64), (5, 4, 33)])
    def test_pallas_vs_xla_ref(self, index, vectors, rng, q, p, l):
        """union_scores kernel head (DMA-deduped, dead slots skipped) must
        match the XLA capacity-gather reference through the full solve."""
        h = vectors[100:100 + q]
        kd = jax.random.fold_in(rng, q + l)
        o_p = mince_decode(index, h, kd, n_probe=p, l=l, k=2,
                           use_pallas=True)
        o_r = mince_decode(index, h, kd, n_probe=p, l=l, k=2,
                           use_pallas=False)
        np.testing.assert_allclose(np.asarray(o_p.log_z),
                                   np.asarray(o_r.log_z), atol=1e-4)
        np.testing.assert_allclose(np.asarray(o_p.top_score),
                                   np.asarray(o_r.top_score), atol=1e-4)
        np.testing.assert_array_equal(np.asarray(o_p.top_id),
                                      np.asarray(o_r.top_id))

    def test_estimates_in_sane_band(self, index, vectors, rng):
        """MINCE is the paper's weak estimator — only require the batched
        serving path to land in the oracle MINCE quality band, not MIMPS's."""
        h = vectors[200:216]
        out = mince_decode(index, h, rng, n_probe=8, l=256, use_pallas=False)
        exact = jax.vmap(lambda q: exact_log_z(vectors, q))(h)
        d = np.asarray(out.log_z - exact)
        assert np.all(np.isfinite(d))
        assert np.max(np.abs(d)) < 6.0, d

    def test_candidates_match_mimps_head(self, index, vectors, rng):
        """Same probe plan => same top-1 candidate as the MIMPS pipeline."""
        h = vectors[:8]
        kd = jax.random.fold_in(rng, 11)
        o_mince = mince_decode(index, h, kd, n_probe=8, l=32,
                               use_pallas=False)
        o_mimps = mimps_decode(index, h, kd, n_probe=8, l=32,
                               use_pallas=False)
        np.testing.assert_array_equal(np.asarray(o_mince.top_id[:, 0]),
                                      np.asarray(o_mimps.top_id[:, 0]))
        np.testing.assert_allclose(np.asarray(o_mince.top_score[:, 0]),
                                   np.asarray(o_mimps.top_score[:, 0]),
                                   atol=1e-4)


class TestMinceDegenerate:
    def test_k0_regression_no_nan(self, vectors, rng):
        """k == 0 used to evaluate log(0) and poison the solver with NaNs;
        it must now fall back to the uniform-noise-only objective."""
        lz = mince_log_z(vectors, vectors[7], 0, 128, rng)
        assert bool(jnp.isfinite(lz)), lz
        np.testing.assert_allclose(
            float(lz), float(uniform_log_z(vectors, vectors[7], 128, rng)),
            atol=1e-5)

    def test_k_equals_n_is_exact(self, vectors, rng):
        n = vectors.shape[0]
        lz = mince_log_z(vectors, vectors[7], n, 16, rng)
        np.testing.assert_allclose(float(lz),
                                   float(exact_log_z(vectors, vectors[7])),
                                   rtol=1e-5)

    def test_complement_sample_k_equals_n(self, vectors, rng):
        """_complement_sample at k == N must not index out of range."""
        ret = oracle_retrieve(vectors, vectors[7])
        s = _complement_sample(rng, ret, vectors.shape[0], 8)
        assert s.shape == (8,)
        assert bool(jnp.all(jnp.isfinite(s)))

    def test_mimps_full_head_drops_tail(self, vectors, rng):
        """mimps_log_z(k=N) == exact (n_tail_total == 0 drops the tail)."""
        from repro.core import mimps_log_z
        n = vectors.shape[0]
        lz = mimps_log_z(vectors, vectors[7], n, 4, rng)
        np.testing.assert_allclose(float(lz),
                                   float(exact_log_z(vectors, vectors[7])),
                                   rtol=5e-4)


# ---------------------------------------------------------------------------
# Registry + engine dispatch
# ---------------------------------------------------------------------------

def _reduced_engine(rng, method, vocab=2048, use_pallas=False, **pc_kw):
    from repro.configs import reduced_config
    from repro.models import Model
    from repro.serve import Engine
    cfg = reduced_config("qwen1.5-4b")
    cfg = dataclasses.replace(
        cfg, vocab=vocab, partition=dataclasses.replace(
            cfg.partition, method=method, block_rows=128, n_probe=4, l=128,
            fmbe_features=2048, fmbe_max_degree=4, **pc_kw))
    m = Model(cfg)
    return Engine(m, m.init(rng), max_len=32, use_pallas=use_pallas), cfg


class TestRegistry:
    def test_unknown_method_raises(self):
        with pytest.raises(ValueError, match="no serving backend"):
            get_backend("nope")

    def test_serving_methods_registered(self):
        assert {"exact", "mimps", "mince", "fmbe", "selfnorm"} <= \
            set(BACKENDS)

    @pytest.mark.parametrize("method", ["mimps", "mince", "fmbe"])
    def test_no_oracle_retrieve_at_decode_time(self, rng, method,
                                               monkeypatch):
        """Acceptance: the batched registry path never runs the O(N log N)
        oracle sort. Engine build happens first (it may use anything); the
        decode step runs with oracle_retrieve booby-trapped."""
        eng, cfg = _reduced_engine(jax.random.fold_in(rng, 1), method)
        h = jax.random.normal(rng, (4, cfg.d_model)).astype(cfg.dtype) * 0.3

        def boom(*a, **k):
            raise AssertionError("oracle_retrieve called at decode time")

        import repro.core.estimators as est_mod
        monkeypatch.setattr(est_mod, "oracle_retrieve", boom)
        out = eng.next_token_distribution(h, rng)
        assert out["token"].shape == (4,)
        assert bool(jnp.all(jnp.isfinite(out["log_z"])))

    @pytest.mark.parametrize("method", ["mimps", "mince", "fmbe"])
    def test_engine_pallas_matches_ref(self, rng, method):
        eng_r, cfg = _reduced_engine(jax.random.fold_in(rng, 2), method)
        eng_p, _ = _reduced_engine(jax.random.fold_in(rng, 2), method,
                                   use_pallas=True)
        h = jax.random.normal(rng, (4, cfg.d_model)).astype(cfg.dtype) * 0.3
        o_r = eng_r.next_token_distribution(h, rng)
        o_p = eng_p.next_token_distribution(h, rng)
        np.testing.assert_allclose(np.asarray(o_p["log_z"]),
                                   np.asarray(o_r["log_z"]), atol=1e-4)
        np.testing.assert_array_equal(np.asarray(o_p["token"]),
                                      np.asarray(o_r["token"]))


class TestTemperature:
    @pytest.mark.parametrize("method", ["exact", "mimps", "mince", "fmbe",
                                        "selfnorm"])
    def test_zero_temperature_is_greedy(self, rng, method):
        """temperature == 0 must reproduce the argmax candidate exactly."""
        eng, cfg = _reduced_engine(jax.random.fold_in(rng, 3), method)
        h = jax.random.normal(rng, (4, cfg.d_model)).astype(cfg.dtype) * 0.3
        out = eng.next_token_distribution(h, rng, temperature=0.0)
        ref = eng.backend.decode(eng.state, h,
                                 jax.random.split(rng)[0], cfg.partition,
                                 k=1, use_pallas=False)
        np.testing.assert_array_equal(np.asarray(out["token"]),
                                      np.asarray(ref.top_id[:, 0]))

    def test_sampling_is_deterministic_per_key_and_varies(self, rng):
        eng, cfg = _reduced_engine(jax.random.fold_in(rng, 4), "mimps")
        h = jax.random.normal(rng, (32, cfg.d_model)).astype(cfg.dtype) * 0.3
        a = eng.next_token_distribution(h, rng, temperature=1.0)
        b = eng.next_token_distribution(h, rng, temperature=1.0)
        np.testing.assert_array_equal(np.asarray(a["token"]),
                                      np.asarray(b["token"]))
        c = eng.next_token_distribution(h, jax.random.fold_in(rng, 1),
                                        temperature=1.0)
        assert np.any(np.asarray(a["token"]) != np.asarray(c["token"]))

    def test_samples_come_from_retrieved_candidates(self, rng):
        eng, cfg = _reduced_engine(jax.random.fold_in(rng, 5), "mimps")
        h = jax.random.normal(rng, (8, cfg.d_model)).astype(cfg.dtype) * 0.3
        cand = eng.backend.decode(eng.state, h, jax.random.split(rng)[0],
                                  cfg.partition, k=cfg.partition.sample_k,
                                  use_pallas=False)
        toks = set()
        for s in range(8):
            out = eng.next_token_distribution(
                h, jax.random.fold_in(rng, 100 + s), temperature=2.0)
            for i in range(8):
                assert int(out["token"][i]) in \
                    set(int(t) for t in np.asarray(cand.top_id[i]))
                toks.add((i, int(out["token"][i])))
        # high temperature over near-flat logits must not be degenerate
        assert len(toks) > 8

    def test_low_temperature_approaches_greedy(self, rng):
        eng, cfg = _reduced_engine(jax.random.fold_in(rng, 6), "exact")
        h = jax.random.normal(rng, (8, cfg.d_model)).astype(cfg.dtype) * 0.3
        greedy = eng.next_token_distribution(h, rng, temperature=0.0)
        cold = eng.next_token_distribution(h, rng, temperature=1e-4)
        np.testing.assert_array_equal(np.asarray(greedy["token"]),
                                      np.asarray(cold["token"]))

    def test_generate_threads_temperature(self, rng):
        from repro.serve import generate
        eng, cfg = _reduced_engine(jax.random.fold_in(rng, 7), "mimps")
        prompt = jax.random.randint(rng, (2, 5), 0, cfg.vocab)
        t0 = generate(eng, prompt, 4, rng)
        t0b = generate(eng, prompt, 4, rng)
        np.testing.assert_array_equal(np.asarray(t0), np.asarray(t0b))
        t1 = generate(eng, prompt, 4, rng, temperature=1.0)
        assert t1.shape == (2, 4)
        t2 = generate(eng, prompt, 4, jax.random.fold_in(rng, 1),
                      temperature=1.0)
        assert np.any(np.asarray(t1) != np.asarray(t2))


# ---------------------------------------------------------------------------
# Sharded backends (8 placeholder devices, subprocess so the override
# never leaks into this process)
# ---------------------------------------------------------------------------

SHARDED_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.serve.output_layer import (IVFSpecs, sharded_decode)
from repro.core import build_fmbe, make_feature_map, fmbe_z_batch

mesh = jax.make_mesh((8,), ("model",))
nb, br, d, B = 32, 64, 32, 8
key = jax.random.PRNGKey(0)
v = jax.random.normal(key, (nb * br, d)) * 0.25
vb = v.reshape(nb, br, d)
cent = vb.mean(axis=1)
radius = jnp.max(jnp.linalg.norm(vb - cent[:, None, :], axis=-1), axis=1)
ivf = IVFSpecs(v_blocks=vb, centroids=cent, radius=radius,
               valid=jnp.ones((nb, br), bool))
h = v[:B] + 0.01 * jax.random.normal(jax.random.fold_in(key, 1), (B, d))
ref_lz = jax.nn.logsumexp((h @ v.T).astype(jnp.float32), -1)
ref_id = jnp.argmax(h @ v.T, -1)

# exhaustive probe (n_probe_local == local blocks): mimps head covers all
# rows -> tail dropped -> exact; mince k_eff == N -> head fallback -> exact
for method in ("mimps", "mince"):
    lz, tid, ts = jax.jit(lambda h, k: sharded_decode(
        mesh, method, ivf, h, k, n_probe_local=4, l_local=16,
        batch_spec=P()))(h, key)
    np.testing.assert_allclose(np.asarray(lz), np.asarray(ref_lz), atol=1e-3)
    np.testing.assert_array_equal(np.asarray(tid), np.asarray(ref_id))

# sublinear probe: estimates land near exact (mimps tight, mince loose)
lz, tid, ts = jax.jit(lambda h, k: sharded_decode(
    mesh, "mimps", ivf, h, k, n_probe_local=2, l_local=64,
    batch_spec=P()))(h, key)
err = np.abs(1 - np.exp(np.asarray(lz) - np.asarray(ref_lz)))
assert err.mean() < 0.25, err
lz_m, _, _ = jax.jit(lambda h, k: sharded_decode(
    mesh, "mince", ivf, h, k, n_probe_local=2, l_local=64,
    batch_spec=P()))(h, key)
assert np.all(np.isfinite(np.asarray(lz_m)))
assert np.max(np.abs(np.asarray(lz_m) - np.asarray(ref_lz))) < 6.0

# fmbe: replicated estimate == unsharded fmbe_z_batch; sharded candidates
fm = make_feature_map(jax.random.fold_in(key, 2), d, 2048, max_degree=6)
st = build_fmbe(fm, v)
lz_f, tid_f, ts_f = jax.jit(lambda h, k: sharded_decode(
    mesh, "fmbe", ivf, h, k, n_probe_local=4, l_local=0,
    fmbe_state=st, batch_spec=P()))(h, key)
z_ref = np.log(np.maximum(np.asarray(fmbe_z_batch(st, h)), 1e-30))
np.testing.assert_allclose(np.asarray(lz_f), z_ref, atol=1e-4)
np.testing.assert_array_equal(np.asarray(tid_f), np.asarray(ref_id))
print("SHARDED_OK")
"""


class TestShardedBackends:
    def test_sharded_mince_fmbe_8dev(self):
        env = dict(os.environ, PYTHONPATH="src")
        r = subprocess.run([sys.executable, "-c", SHARDED_SNIPPET],
                           capture_output=True, text=True, env=env,
                           cwd=os.path.dirname(os.path.dirname(
                               os.path.abspath(__file__))), timeout=300)
        assert "SHARDED_OK" in r.stdout, r.stdout + r.stderr

    def test_sharded_dispatch_unknown_method(self):
        from repro.serve.output_layer import sharded_decode
        with pytest.raises(ValueError, match="no sharded backend"):
            sharded_decode(None, "nope", None, None, None,
                           n_probe_local=1, l_local=1)
