"""Fault-tolerance + serving + distributed-estimation tests."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.configs.base import TrainConfig
from repro.models import Model
from repro.serve import Engine, generate
from repro.train import (CheckpointManager, init_train_state,
                         make_train_step, best_mesh_shape, StragglerWatchdog)


class TestCheckpoint:
    def test_roundtrip_and_resume(self, rng, tmp_path):
        cfg = reduced_config("qwen1.5-4b")
        m = Model(cfg)
        tc = TrainConfig(lr=1e-3, loss="ce")
        state = init_train_state(m, tc, rng)
        step = jax.jit(make_train_step(m, tc))
        batch = {"tokens": jax.random.randint(rng, (2, 17), 0, cfg.vocab)[:, :-1],
                 "labels": jax.random.randint(rng, (2, 17), 0, cfg.vocab)[:, 1:]}
        for _ in range(2):
            state, _ = step(state, batch)
        mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
        mgr.save(2, state, extra={"data_step": 2})
        restored, manifest = mgr.restore(None, like=state)
        assert manifest["step"] == 2
        assert manifest["extra"]["data_step"] == 2
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # training continues identically from the restore
        s1, m1 = step(state, batch)
        s2, m2 = step(restored, batch)
        np.testing.assert_allclose(float(m1["loss_total"]),
                                   float(m2["loss_total"]), rtol=1e-6)

    def test_index_state_roundtrip_bit_identical(self, rng, tmp_path):
        """Estimator-backed training: save -> restore -> one step is
        BIT-identical to the uninterrupted run, including the IVF index
        arrays carried in TrainState (resume determinism extends to the
        retrieval state, not just params/opt/rng)."""
        import dataclasses as dc
        cfg = reduced_config("qwen1.5-4b")
        cfg = dc.replace(cfg, vocab=2048, partition=dc.replace(
            cfg.partition, block_rows=64, n_probe=4, l=64, n_clusters=8))
        m = Model(cfg)
        tc = TrainConfig(lr=1e-3, loss="mimps_ce")
        state = init_train_state(m, tc, rng)
        assert state.index is not None
        step = jax.jit(make_train_step(m, tc))
        batch = {"tokens": jax.random.randint(rng, (2, 17), 0,
                                              cfg.vocab)[:, :-1],
                 "labels": jax.random.randint(rng, (2, 17), 0,
                                              cfg.vocab)[:, 1:]}
        for _ in range(2):
            state, _ = step(state, batch)
        # refresh so the saved index is NOT the init-time one
        from repro.train import make_index_refresh
        state, _ = make_index_refresh(m, tc)(state)
        mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
        mgr.save(2, state)
        restored, _ = mgr.restore(None, like=state)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # static pytree scalars come back as python ints (same treedef)
        assert jax.tree_util.tree_structure(state) == \
            jax.tree_util.tree_structure(restored)
        s1, m1 = step(state, batch)
        s2, m2 = step(restored, batch)
        for a, b in zip(jax.tree.leaves(s1), jax.tree.leaves(s2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(
            np.asarray(m1["loss_total"]), np.asarray(m2["loss_total"]))

    def test_atomicity_torn_write_ignored(self, rng, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_write=False)
        state = {"w": jnp.ones((3,))}
        mgr.save(1, state)
        # simulate a torn write: step dir without manifest
        os.makedirs(tmp_path / "step_0000000002")
        assert mgr.latest_step() == 1
        restored, man = mgr.restore(None, like=state)
        assert man["step"] == 1

    def test_keep_k_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
        for s in range(5):
            mgr.save(s, {"w": jnp.full((2,), s)})
        assert mgr.all_steps() == [3, 4]

    def test_async_write(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3, async_write=True)
        mgr.save(7, {"w": jnp.arange(4.0)})
        mgr.wait()
        assert mgr.latest_step() == 7


class TestElastic:
    def test_mesh_shrink(self):
        assert best_mesh_shape(256, 16) == (16, 16)
        assert best_mesh_shape(128, 16) == (8, 16)
        assert best_mesh_shape(96, 16) == (6, 16)
        # TP degree degrades gracefully when devices < requested
        assert best_mesh_shape(8, 16) == (1, 8)
        assert best_mesh_shape(6, 4) == (2, 3)

    def test_watchdog_flags_stragglers(self):
        wd = StragglerWatchdog(threshold=2.0, max_consecutive=100)
        import time
        for i in range(3):
            wd.start_step(); time.sleep(0.01); wd.end_step(i)
        wd.start_step(); time.sleep(0.08)
        assert wd.end_step(3) is True
        assert len(wd.events) == 1

    def test_watchdog_raises_on_persistent(self):
        wd = StragglerWatchdog(threshold=1.5, max_consecutive=2)
        import time
        wd.start_step(); time.sleep(0.01); wd.end_step(0)
        with pytest.raises(RuntimeError):
            for i in range(5):
                wd.start_step(); time.sleep(0.05); wd.end_step(i + 1)


class TestServe:
    @pytest.mark.parametrize("method", ["exact", "mimps", "mince",
                                        "selfnorm"])
    def test_decode_probabilities(self, rng, method):
        import dataclasses
        cfg = reduced_config("qwen1.5-4b")
        cfg = dataclasses.replace(
            cfg, vocab=2048, partition=dataclasses.replace(
                cfg.partition, method=method, block_rows=128, n_probe=4,
                l=128))
        m = Model(cfg)
        p = m.init(rng)
        eng = Engine(m, p, max_len=64)
        h = jax.random.normal(rng, (4, cfg.d_model)).astype(cfg.dtype) * 0.3
        out = eng.next_token_distribution(h, rng)
        assert out["token"].shape == (4,)
        assert bool(jnp.all(out["token"] >= 0))
        assert bool(jnp.all(out["token"] < cfg.vocab))
        if method != "selfnorm":
            # probabilities must be sane
            pr = jnp.exp(out["log_prob"])
            assert bool(jnp.all(pr <= 1.01)), pr
            assert bool(jnp.all(pr > 0))

    def test_mimps_logz_close_to_exact(self, rng):
        import dataclasses
        cfg = reduced_config("qwen1.5-4b")
        cfg = dataclasses.replace(
            cfg, vocab=4096, partition=dataclasses.replace(
                cfg.partition, method="mimps", block_rows=128, n_probe=8,
                l=512))
        m = Model(cfg)
        p = m.init(rng)
        eng = Engine(m, p, max_len=32)
        h = jax.random.normal(rng, (8, cfg.d_model)).astype(cfg.dtype) * 0.2
        out = eng.next_token_distribution(h, rng)
        w = m.head_matrix(p)
        exact = jax.nn.logsumexp((h @ w.T).astype(jnp.float32), -1)
        err = np.abs(1 - np.exp(np.asarray(out["log_z"]) - np.asarray(exact)))
        assert err.mean() < 0.15, err

    def test_swap_index_zero_recompile_parity(self, rng):
        """Train->serve handoff: swapping a new checkpoint into a live
        slot-table server (a) never recompiles the mixed step and (b) serves
        tokens bit-identical to a fresh engine built from the new params."""
        import dataclasses as dc
        from repro.serve.scheduler import Request, Scheduler
        cfg = reduced_config("qwen1.5-4b")
        cfg = dc.replace(cfg, vocab=2048, partition=dc.replace(
            cfg.partition, method="mimps", block_rows=64, n_probe=4, l=64,
            n_clusters=8))
        m = Model(cfg)
        p0 = m.init(rng)
        p1 = m.init(jax.random.fold_in(rng, 1))   # "freshly trained"
        eng = Engine(m, p0, max_len=32, key=rng, device_index=True)
        sch = Scheduler(eng, n_slots=2, key=rng)

        def serve_one():
            sch.admit(Request(prompt=[3, 5, 7], max_new_tokens=4,
                              key=jax.random.PRNGKey(9)))
            toks = []
            for _ in range(10):
                toks += [c.tokens for c in sch.step()["completions"]]
                if toks:
                    break
            return toks[0]

        before = serve_one()
        traces = sch.step_traces
        eng.swap_index(p1)
        after = serve_one()
        assert sch.step_traces == traces, "swap_index recompiled the step"
        assert after != before
        eng2 = Engine(m, p1, max_len=32, key=rng, device_index=True)
        solo = generate(eng2, jnp.asarray([[3, 5, 7]]), 4,
                        jax.random.PRNGKey(9))
        assert solo[0].tolist() == after

    def test_generate_loop(self, rng):
        cfg = reduced_config("musicgen-medium")
        m = Model(cfg)
        p = m.init(rng)
        eng = Engine(m, p, max_len=32)
        prompt = jax.random.randint(rng, (2, 4, cfg.n_codebooks), 0,
                                    cfg.vocab)
        toks = generate(eng, prompt, 4, rng)
        assert toks.shape == (2, 4, cfg.n_codebooks)


MULTIDEV_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.distributed import (sharded_exact_log_z, sharded_top_k,
                                    sharded_mimps_log_z, shard_map)

mesh = jax.make_mesh((8,), ("model",))
N, D = 4096, 32
key = jax.random.PRNGKey(0)
v = jax.random.normal(key, (N, D)) * 0.4
q = v[7]

@jax.jit
def dist_lse(v, q):
    return shard_map(
        lambda vl, q: sharded_exact_log_z(vl, q),
        mesh=mesh, in_specs=(P("model", None), P()), out_specs=P())(v, q)

lz = dist_lse(v, q)
ref = jax.nn.logsumexp(v @ q)
assert abs(float(lz - ref)) < 1e-3, (lz, ref)

@jax.jit
def dist_topk(v, q):
    return shard_map(
        lambda vl, q: sharded_top_k(vl, q, 8),
        mesh=mesh, in_specs=(P("model", None), P()), out_specs=P(),
        check_vma=False)(v, q)

tk = dist_topk(v, q)
ref_v, ref_i = jax.lax.top_k(v @ q, 8)
np.testing.assert_allclose(np.asarray(tk.scores), np.asarray(ref_v), rtol=1e-5)
np.testing.assert_array_equal(np.asarray(tk.ids), np.asarray(ref_i))

@jax.jit
def dist_mimps(v, q, key):
    return shard_map(
        lambda vl, q, k: sharded_mimps_log_z(vl, q, 64, 64, k)[0],
        mesh=mesh, in_specs=(P("model", None), P(), P()),
        out_specs=P(), check_vma=False)(v, q, key)

lzm = dist_mimps(v, q, key)
err = abs(1 - float(jnp.exp(lzm - ref)))
assert err < 0.1, err
print("MULTIDEV_OK")
"""


class TestDistributed:
    def test_sharded_estimators_8dev(self):
        """Run in a subprocess so the 8-device override never leaks."""
        env = dict(os.environ, PYTHONPATH="src")
        r = subprocess.run([sys.executable, "-c", MULTIDEV_SNIPPET],
                           capture_output=True, text=True, env=env,
                           cwd=os.path.dirname(os.path.dirname(
                               os.path.abspath(__file__))), timeout=300)
        assert "MULTIDEV_OK" in r.stdout, r.stdout + r.stderr
