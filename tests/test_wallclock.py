"""PR-3 wall-clock/accuracy regressions, pinned at the quick-bench config
(V=8192, d=128, br=128, p=8, l=256, Q=32 — the BENCH_*.json scale):

 * MINCE accuracy blow-up (rel_err ~ 3e5 in the PR-2 artifact) fixed by the
   anchored weighting + bracketed solve — rel_err < 1 asserted, and the
   collapse identity (anchored root == Eq. 5 anchor) asserted directly;
 * FMBE collapse (rel_err ~ 1.0: Ẑ ~ 2e-7 Z from the degree-capped Taylor)
   fixed by the exact-head/sketch-tail hybrid — rel_err < 0.5 asserted;
 * head_cap-trimmed XLA decode == full-capacity decode, on both the
   trim-taken and the overflow-fallback branches;
 * the benchmark regression gate's comparison logic.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.common import make_embeddings, shared_context_batch
from repro.core import build_ivf, mince_log_z
from repro.core.decode import fmbe_decode, mimps_decode, mince_decode
from repro.core import mince as _mince


@pytest.fixture(scope="module")
def bench():
    """The quick-bench world: embeddings, shared-context batch, index."""
    n, d, br, q = 8192, 128, 128, 32
    key = jax.random.PRNGKey(0)
    v = make_embeddings(key, n, d)
    h = shared_context_batch(key, v, q)
    index = build_ivf(key, v, block_rows=br)
    exact_lz = jax.nn.logsumexp((h @ v.T).astype(jnp.float32), -1)
    return v, h, index, exact_lz, jax.random.fold_in(key, 2)


class TestMinceBenchRegression:
    def test_decode_rel_err_under_one(self, bench):
        """PR-2 artifact: rel_err_vs_exact == 2.95e5. Must stay < 1."""
        v, h, index, exact_lz, kd = bench
        out = mince_decode(index, h, kd, n_probe=8, l=256, k=1,
                           use_pallas=False)
        rel = np.asarray(jnp.abs(1 - jnp.exp(out.log_z - exact_lz)))
        assert np.isfinite(rel).all()
        assert rel.mean() < 1.0, rel.mean()
        # in practice the anchored root is MIMPS-accurate; keep margin loose
        assert rel.mean() < 0.5, rel.mean()

    def test_oracle_mince_log_z_rel_err_under_one(self, bench):
        """The satellite's target: mince_log_z at the bench config."""
        v, h, index, exact_lz, kd = bench
        errs = [abs(1 - float(jnp.exp(
            mince_log_z(v, h[i], 1024, 256, jax.random.fold_in(kd, i))
            - exact_lz[i]))) for i in range(4)]
        assert max(errs) < 1.0, errs

    def test_collapse_identity(self, bench):
        """The anchored root IS the Eq. 5 anchor: MINCE and MIMPS on the
        same key (hence the same plan and tail draw) must agree on log Ẑ
        (mince.anchored_solve docstring), and the scalar solver must reach
        the anchor from a cold start under the bracket."""
        v, h, index, exact_lz, kd = bench
        out = mince_decode(index, h, kd, n_probe=8, l=256, k=1,
                           use_pallas=False)
        ref = mimps_decode(index, h, kd, n_probe=8, l=256, k=1,
                           use_pallas=False)
        np.testing.assert_allclose(np.asarray(out.log_z),
                                   np.asarray(ref.log_z), atol=2e-3)
        # scalar solver: far-off init converges to the anchor under bracket
        a = jnp.array([3.0, -5.0, 40.0])
        th = _mince.anchored_solve(a, a + jnp.array([10.0, -12.0, 0.5]),
                                   iters=30)
        np.testing.assert_allclose(np.asarray(th), np.asarray(a), atol=1e-4)
        thn = _mince.anchored_solve(a, a + 8.0, iters=30, solver="newton")
        np.testing.assert_allclose(np.asarray(thn), np.asarray(a), atol=1e-4)

    def test_stats_solver_matches_dense_solver(self, rng):
        """The sharded path's bucketed MinceStats solve must agree with the
        dense shared-atom solve on the same weighted atom sets (the
        histogram is the one-psum combine format; S=128 buckets keep the
        root within ~1e-2)."""
        k1, k2, k3 = jax.random.split(rng, 3)
        alpha = jax.random.normal(k1, (3, 400)) * 6.0
        wd = jax.random.uniform(k2, (3, 400)) * 2.0
        wn = jax.random.uniform(k3, (3, 400))
        theta0 = jnp.zeros((3,))
        dense = _mince.solve_shared_atoms(alpha, wd, wn, theta0, iters=40)
        stats = _mince.mince_stats(alpha, wd, wn, theta0)
        bucketed = _mince.solve_from_stats(stats, theta0, iters=40)
        np.testing.assert_allclose(np.asarray(bucketed), np.asarray(dense),
                                   atol=3e-2)

    def test_paper_weighting_still_diverges_less_catastrophically(self,
                                                                  bench):
        """weighting='paper' is kept for Table 1; the bracketed solver keeps
        it finite (the seed's trust-clamped walk reached +12 nats)."""
        v, h, index, exact_lz, kd = bench
        lz = mince_log_z(v, h[0], 1024, 256, kd, weighting="paper")
        assert bool(jnp.isfinite(lz))


class TestFmbeBenchRegression:
    def test_hybrid_rel_err(self, bench):
        """PR-2 artifact: rel_err_vs_exact ~ 1.0 (estimate collapsed toward
        Ẑ ~ 0: degree-capped Taylor at 28-nat scores). The exact-head /
        sketch-tail hybrid must stay < 0.5 at bench scale."""
        from repro.core.feature_maps import (FMBEState, build_fmbe,
                                             build_fmbe_blocks,
                                             make_feature_map)
        v, h, index, exact_lz, kd = bench
        fm = make_feature_map(jax.random.fold_in(kd, 7), 128, 1024,
                              max_degree=4)
        st = build_fmbe(fm, v)
        st = FMBEState(fm=st.fm, lambda_tilde=st.lambda_tilde,
                       lambda_blocks=build_fmbe_blocks(
                           fm, index.v_blocks, index.valid))
        out = fmbe_decode(st, index, h, kd, n_probe=8, k=1,
                          use_pallas=False)
        rel = np.asarray(jnp.abs(1 - jnp.exp(out.log_z - exact_lz)))
        assert rel.mean() < 0.5, rel.mean()
        # the hybrid can never be worse than dropping the tail entirely
        head_only = np.asarray(jnp.abs(1 - jnp.exp(out.head_lse - exact_lz)))
        assert rel.mean() <= head_only.mean() + 1e-6

    def test_lambda_blocks_sum_to_global(self, bench):
        from repro.core.feature_maps import (build_fmbe, build_fmbe_blocks,
                                             make_feature_map)
        v, h, index, exact_lz, kd = bench
        fm = make_feature_map(jax.random.fold_in(kd, 8), 128, 256,
                              max_degree=3)
        st = build_fmbe(fm, v)
        lam_b = build_fmbe_blocks(fm, index.v_blocks, index.valid)
        np.testing.assert_allclose(np.asarray(lam_b.sum(0)),
                                   np.asarray(st.lambda_tilde),
                                   rtol=2e-4, atol=2e-3)


class TestHeadCapTrim:
    def test_trim_equals_full_on_shared_context(self, bench):
        """U = 8 unique blocks -> the head_cap=12 trim branch runs; it must
        match the full-capacity decode exactly."""
        v, h, index, exact_lz, kd = bench
        small = mimps_decode(index, h, kd, n_probe=8, l=256, k=2,
                             use_pallas=False, head_cap=12)
        full = mimps_decode(index, h, kd, n_probe=8, l=256, k=2,
                            use_pallas=False, head_cap=10_000)
        np.testing.assert_allclose(np.asarray(small.log_z),
                                   np.asarray(full.log_z), atol=1e-5)
        np.testing.assert_array_equal(np.asarray(small.top_id),
                                      np.asarray(full.top_id))

    def test_overflow_falls_back_to_full(self, bench):
        """An uncorrelated batch overflows a tiny head_cap -> the cond's
        fallback branch must reproduce the full-capacity result."""
        v, h, index, exact_lz, kd = bench
        h_u = v[jax.random.choice(jax.random.fold_in(kd, 3), v.shape[0],
                                  (32,), replace=False)]
        tiny = mimps_decode(index, h_u, kd, n_probe=8, l=256, k=1,
                            use_pallas=False, head_cap=2)
        full = mimps_decode(index, h_u, kd, n_probe=8, l=256, k=1,
                            use_pallas=False, head_cap=10_000)
        np.testing.assert_allclose(np.asarray(tiny.log_z),
                                   np.asarray(full.log_z), atol=1e-5)

    def test_mince_trim_branches_agree(self, bench):
        v, h, index, exact_lz, kd = bench
        small = mince_decode(index, h, kd, n_probe=8, l=256, k=1,
                             use_pallas=False, head_cap=12)
        full = mince_decode(index, h, kd, n_probe=8, l=256, k=1,
                            use_pallas=False, head_cap=10_000)
        np.testing.assert_allclose(np.asarray(small.log_z),
                                   np.asarray(full.log_z), atol=1e-4)


class TestRegressionGate:
    def _write(self, tmp_path, dec_mimps_us=1000.0, est=None, srv=None,
               trn=None):
        est = est or {}
        dec = {"exact": {"us_per_step": 2000.0, "tokens_per_s": 16000.0},
               "mimps": {"us_per_step": dec_mimps_us,
                         "tokens_per_s": 32.0 / dec_mimps_us * 1e6}}
        methods = {}
        for m, us in {"exact": 2000.0, "mimps": 1200.0, "mince": 1400.0,
                      "fmbe": 1800.0, "lsh": 1600.0, **est}.items():
            methods[m] = {"us_per_step": us, "tokens_per_s": 32.0 / us * 1e6,
                          "rel_err_vs_exact":
                              {"exact": 0.0, "mimps": 0.12, "mince": 0.12,
                               "fmbe": 0.03, "lsh": 0.0002}[m]}
        (tmp_path / "BENCH_decode.json").write_text(json.dumps(
            {**dec, "speedup_xla": dec["exact"]["us_per_step"] /
             dec["mimps"]["us_per_step"]}))
        (tmp_path / "BENCH_estimators.json").write_text(json.dumps(
            {"methods": methods,
             "bound": {"ok_all": True, "byte_sublinear_all": True}}))
        overload = {"shed_rate": 0.4, "p95_under_overload": 20.0,
                    "degraded_token_frac": 0.5, "queue_depth_peak": 8,
                    "max_queue": 8, "recompiles_after_warmup": 0,
                    "tokens_by_tier": {"mimps": 50, "topk": 102},
                    "obs": {"trace_path": "artifacts/t.jsonl",
                            "trace_events": 252,
                            "snapshot_path": "artifacts/s.json",
                            "tokens_by_tier_harvested": {"mimps": 50,
                                                         "topk": 102},
                            "tokens_reconciled": True,
                            "shadow_rel_err_by_tier": {
                                "mimps": {"count": 24,
                                          "rel_err_mean": 0.015,
                                          "rel_err_max": 0.036}}}}
        scaling = {"lanes_per_replica": 4, "clock": "virtual-step",
                   "rows": [
                       {"data": d, "model": 1, "devices": d,
                        "n_slots": 4 * d, "n_req": 16 * d,
                        "tok_per_step": 1.9 * d, "steps": 68,
                        "goodput_tok_s": 900.0, "p95_token_ms": 20.0,
                        "occupancy_steady": 0.95, "token_parity": True,
                        "recompiles_after_warmup": 0}
                       for d in (1, 2, 4, 8)],
                   "goodput_monotone": True, "goodput_scaling_8v1": 8.0}

        def _spec_row(goodput, tps, acceptance=None):
            row = {"goodput_tok_s": goodput, "tok_per_step": tps,
                   "steps": 40, "token_parity": True,
                   "recompiles_after_warmup": 0}
            if acceptance is not None:
                row["acceptance"] = acceptance
                row["draft_flagged"] = 0
            return row

        spec = {"scenario": {"n_req": 32, "shared_prefix_len": 8,
                             "serving_tier": "exact", "vocab": 32768},
                "nonspec": _spec_row(300.0, 3.4),
                # topk is the winning draft on both clocks; fmbe pays for
                # its sketch features and loses both (as measured)
                "drafts": {"topk": _spec_row(360.0, 6.4, 0.45),
                           "fmbe": _spec_row(140.0, 3.0, 0.44)},
                "speedup_vs_nonspec": 1.2}
        prefix_cache = {"blocks": 64, "block_tokens": 4,
                        "off": _spec_row(300.0, 3.4) | {"steps": 38},
                        "on": _spec_row(330.0, 5.8) | {"steps": 22},
                        "hits": 24, "saved_replay_steps": 192,
                        "evictions": 0, "token_parity": True,
                        "recompiles_after_warmup": 0}
        latency = {"p50_token_ms": 5.0, "p95_token_ms": 30.0,
                   "p99_token_ms": 40.0,
                   "step_device_ms_mean": 1.7, "step_host_ms_mean": 0.3,
                   "edges_ms": [0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0,
                                100.0, 200.0, 500.0, 1000.0, 5000.0],
                   "per_tier_cumulative": {
                       "mimps": [0, 0, 33, 39, 39, 39, 39, 39, 39, 39,
                                 39, 39, 39]}}
        obs_overhead = {"goodput_on_tok_s": 590.0,
                        "goodput_off_tok_s": 600.0,
                        "goodput_ratio_on_vs_off": 590.0 / 600.0,
                        "token_parity_on_vs_off": True,
                        "recompiles_after_warmup": 0}
        serving = {"goodput_tok_s": 600.0,
                   "sequential_goodput_tok_s": 150.0,
                   "speedup_vs_sequential": 4.0,
                   "p50_token_ms": 5.0, "p95_token_ms": 30.0,
                   "occupancy_steady": 0.9, "peak_concurrency": 8,
                   "token_parity_vs_solo": True,
                   "recompiles_after_warmup": 0,
                   "dedup_by_fill": [[1, 1.0], [2, 0.94], [4, 0.55],
                                     [8, 0.26]],
                   "latency": latency, "obs_overhead": obs_overhead,
                   "spec": spec, "prefix_cache": prefix_cache,
                   "overload": overload, "scaling": scaling, **(srv or {})}
        if srv and "overload" in srv:
            serving["overload"] = {**overload, **srv["overload"]}
        if srv and "latency" in srv:
            serving["latency"] = {**latency, **srv["latency"]}
        if srv and "obs_overhead" in srv:
            serving["obs_overhead"] = {**obs_overhead,
                                       **srv["obs_overhead"]}
        if srv and "obs" in srv:
            serving["overload"] = {
                **serving["overload"],
                "obs": {**overload["obs"], **srv["obs"]}}
        (tmp_path / "BENCH_serving.json").write_text(json.dumps(serving))
        train = {"methods": {
            "fused_ce": {"tokens_per_s": 300.0, "us_per_step": 3000.0,
                         "final_loss": 8.0},
            "mimps_ce": {"tokens_per_s": 500.0, "us_per_step": 1800.0,
                         "final_loss": 8.1, "grad_cosine_vs_full": 0.997,
                         "grad_unique_ratio": 0.09,
                         "grad_scored_ratio": 0.27,
                         "refresh": {"churn": [0.2], "drift": [0.05],
                                     "count": 3, "step_retraces": 1,
                                     "refresh_retraces": 1}},
            "lsh_ce": {"tokens_per_s": 480.0, "us_per_step": 1900.0,
                       "final_loss": 8.2,
                       "refresh": {"churn": [0.1], "drift": [0.02],
                                   "count": 3, "step_retraces": 1,
                                   "refresh_retraces": 1}}},
            "loss_ratio_vs_fused": 1.01, "grad_float_ratio": 0.27,
            "zero_refresh_recompiles": True,
            "refresh_cost": {"ivf_refresh_us": 100000.0,
                             "lsh_update_us": 32000.0,
                             "rows_updated": 256, "ratio": 0.32},
            **(trn or {})}
        (tmp_path / "BENCH_train.json").write_text(json.dumps(train))

    def _check(self, tmp_path, monkeypatch):
        import benchmarks.run as run
        monkeypatch.chdir(tmp_path)
        return run.check()

    def test_green_within_tolerance(self, tmp_path, monkeypatch):
        import benchmarks.run as run
        self._write(tmp_path)
        monkeypatch.chdir(tmp_path)
        monkeypatch.setattr(run, "BASELINE_PATH",
                            str(tmp_path / "baseline.json"))
        run.update_baseline()
        assert self._check(tmp_path, monkeypatch) == 0
        # 20% slower mimps: inside the 25% budget
        self._write(tmp_path, dec_mimps_us=1200.0)
        assert self._check(tmp_path, monkeypatch) == 0

    def test_fails_on_regression_and_broken_invariant(self, tmp_path,
                                                      monkeypatch):
        import benchmarks.run as run
        self._write(tmp_path)
        monkeypatch.chdir(tmp_path)
        monkeypatch.setattr(run, "BASELINE_PATH",
                            str(tmp_path / "baseline.json"))
        run.update_baseline()
        # 30% slower decode mimps: regression AND (at 2600us > 2000us exact)
        # a broken speedup_xla invariant
        self._write(tmp_path, dec_mimps_us=2600.0)
        assert self._check(tmp_path, monkeypatch) >= 2
        # mince blowing past 1.5x mimps fails the acceptance invariant
        self._write(tmp_path, est={"mince": 2500.0})
        assert self._check(tmp_path, monkeypatch) >= 1

    def test_fails_on_broken_serving_invariants(self, tmp_path,
                                                monkeypatch):
        """The PR-4 gate: losing to sequential generate(), starving the
        slot table, breaking batched-vs-solo parity, or recompiling after
        warmup each fail --check on their own."""
        import benchmarks.run as run
        self._write(tmp_path)
        monkeypatch.chdir(tmp_path)
        monkeypatch.setattr(run, "BASELINE_PATH",
                            str(tmp_path / "baseline.json"))
        run.update_baseline()
        assert self._check(tmp_path, monkeypatch) == 0
        for bad in ({"speedup_vs_sequential": 0.8},
                    {"occupancy_steady": 0.3},
                    {"peak_concurrency": 4},
                    {"token_parity_vs_solo": False},
                    {"recompiles_after_warmup": 2}):
            self._write(tmp_path, srv=bad)
            assert self._check(tmp_path, monkeypatch) >= 1, bad

    def test_fails_on_broken_overload_invariants(self, tmp_path,
                                                 monkeypatch):
        """The PR-6 gate: no shedding at 2x demand, shedding everything, a
        starved tail, a ladder that never engages, a leaky queue bound, or
        a recompile under overload each fail --check on their own."""
        import benchmarks.run as run
        self._write(tmp_path)
        monkeypatch.chdir(tmp_path)
        monkeypatch.setattr(run, "BASELINE_PATH",
                            str(tmp_path / "baseline.json"))
        run.update_baseline()
        assert self._check(tmp_path, monkeypatch) == 0
        for bad in ({"shed_rate": 0.0},
                    {"shed_rate": 1.0},
                    {"p95_under_overload": float("inf")},
                    {"degraded_token_frac": 0.0},
                    {"queue_depth_peak": 9},
                    {"recompiles_after_warmup": 1}):
            self._write(tmp_path, srv={"overload": bad})
            assert self._check(tmp_path, monkeypatch) >= 1, bad
        # and a missing section entirely is itself a failure
        self._write(tmp_path)
        rep = json.loads((tmp_path / "BENCH_serving.json").read_text())
        del rep["overload"]
        (tmp_path / "BENCH_serving.json").write_text(json.dumps(rep))
        assert self._check(tmp_path, monkeypatch) >= 1

    def test_fails_on_broken_obs_invariants(self, tmp_path, monkeypatch):
        """The PR-9 gate: an observability tax over 5%, perturbed tokens,
        a recompile from toggling obs, device counters that disagree with
        host accounting, an empty trace, silent shadow telemetry, or a
        non-monotone cumulative histogram each fail --check on their
        own."""
        import benchmarks.run as run
        self._write(tmp_path)
        monkeypatch.chdir(tmp_path)
        monkeypatch.setattr(run, "BASELINE_PATH",
                            str(tmp_path / "baseline.json"))
        run.update_baseline()
        assert self._check(tmp_path, monkeypatch) == 0
        for bad in ({"obs_overhead": {"goodput_ratio_on_vs_off": 0.90}},
                    {"obs_overhead": {"token_parity_on_vs_off": False}},
                    {"obs_overhead": {"recompiles_after_warmup": 1}},
                    {"obs": {"tokens_reconciled": False}},
                    {"obs": {"trace_events": 0}},
                    {"obs": {"shadow_rel_err_by_tier": {}}},
                    {"latency": {"p99_token_ms": float("nan")}},
                    {"latency": {"p99_token_ms": 20.0}},   # p95 > p99
                    {"latency": {"per_tier_cumulative":
                                 {"mimps": [5, 3, 39, 39, 39, 39, 39, 39,
                                            39, 39, 39, 39, 39]}}}):
            self._write(tmp_path, srv=bad)
            assert self._check(tmp_path, monkeypatch) >= 1, bad
        # missing sections are themselves failures
        for section in ("latency", "obs_overhead"):
            self._write(tmp_path)
            rep = json.loads((tmp_path / "BENCH_serving.json").read_text())
            del rep[section]
            (tmp_path / "BENCH_serving.json").write_text(json.dumps(rep))
            assert self._check(tmp_path, monkeypatch) >= 1, section

    def test_fails_on_broken_scaling_invariants(self, tmp_path,
                                                monkeypatch):
        """The PR-7 gate: broken token parity, a recompile, starved
        occupancy, or a non-monotone tokens-per-step chain at any mesh
        shape each fail --check on their own, as does a missing curve."""
        import benchmarks.run as run
        self._write(tmp_path)
        monkeypatch.chdir(tmp_path)
        monkeypatch.setattr(run, "BASELINE_PATH",
                            str(tmp_path / "baseline.json"))
        run.update_baseline()
        assert self._check(tmp_path, monkeypatch) == 0

        def tweak(**kw):
            self._write(tmp_path)
            rep = json.loads((tmp_path / "BENCH_serving.json").read_text())
            rep["scaling"]["rows"][-1].update(kw)
            (tmp_path / "BENCH_serving.json").write_text(json.dumps(rep))

        for bad in ({"token_parity": False},
                    {"recompiles_after_warmup": 1},
                    {"occupancy_steady": 0.4},
                    {"tok_per_step": 1.0}):    # 8-dev row below 1-dev
            tweak(**bad)
            assert self._check(tmp_path, monkeypatch) >= 1, bad
        self._write(tmp_path)
        rep = json.loads((tmp_path / "BENCH_serving.json").read_text())
        del rep["scaling"]
        (tmp_path / "BENCH_serving.json").write_text(json.dumps(rep))
        assert self._check(tmp_path, monkeypatch) >= 1

    def test_fails_on_broken_raw_speed_invariants(self, tmp_path,
                                                  monkeypatch):
        """The PR-8 gate: a draft that breaks parity / recompiles / has
        degenerate acceptance, speculation losing to the plain scheduler
        on either clock, a warm cache that saves nothing, stringified or
        unsorted dedup_by_fill rows, and missing sections each fail
        --check on their own."""
        import benchmarks.run as run
        self._write(tmp_path)
        monkeypatch.chdir(tmp_path)
        monkeypatch.setattr(run, "BASELINE_PATH",
                            str(tmp_path / "baseline.json"))
        run.update_baseline()
        assert self._check(tmp_path, monkeypatch) == 0

        def tweak(section, **kw):
            self._write(tmp_path)
            rep = json.loads((tmp_path / "BENCH_serving.json").read_text())
            node = rep
            for part in section.split("."):
                node = node[part]
            node.update(kw)
            (tmp_path / "BENCH_serving.json").write_text(json.dumps(rep))

        for section, bad in (
                ("spec.drafts.topk", {"token_parity": False}),
                ("spec.drafts.topk", {"recompiles_after_warmup": 1}),
                ("spec.drafts.topk", {"acceptance": 0.0}),
                # every draft losing on wall clock fails even with the
                # tokens-per-step win intact, and vice versa
                ("spec.drafts.topk", {"goodput_tok_s": 120.0}),
                ("spec.drafts.topk", {"tok_per_step": 2.0}),
                ("prefix_cache", {"token_parity": False}),
                ("prefix_cache", {"recompiles_after_warmup": 1}),
                ("prefix_cache", {"saved_replay_steps": 0}),
                ("prefix_cache.on", {"steps": 38})):
            tweak(section, **bad)
            assert self._check(tmp_path, monkeypatch) >= 1, (section, bad)
        # dedup_by_fill: the old stringified-key object form, unsorted
        # rows, and out-of-range ratios are all format failures
        for bad_df in ({"1": 1.0, "8": 0.26},
                       [[8, 0.26], [1, 1.0]],
                       [[1, 1.0], [8, 1.7]]):
            self._write(tmp_path)
            rep = json.loads((tmp_path / "BENCH_serving.json").read_text())
            rep["dedup_by_fill"] = bad_df
            (tmp_path / "BENCH_serving.json").write_text(json.dumps(rep))
            assert self._check(tmp_path, monkeypatch) >= 1, bad_df
        for missing in ("spec", "prefix_cache"):
            self._write(tmp_path)
            rep = json.loads((tmp_path / "BENCH_serving.json").read_text())
            del rep[missing]
            (tmp_path / "BENCH_serving.json").write_text(json.dumps(rep))
            assert self._check(tmp_path, monkeypatch) >= 1, missing

    def test_fails_on_broken_train_invariants(self, tmp_path, monkeypatch):
        """The PR-5 gate: dense-ish embedding-grad floats, a gradient that
        diverges from full CE, a loss that drifts past 5%, or a recompiling
        refresh each fail --check on their own."""
        import json as _json
        import benchmarks.run as run
        self._write(tmp_path)
        monkeypatch.chdir(tmp_path)
        monkeypatch.setattr(run, "BASELINE_PATH",
                            str(tmp_path / "baseline.json"))
        run.update_baseline()
        assert self._check(tmp_path, monkeypatch) == 0
        for top, nested in (({"grad_float_ratio": 0.6}, {}),
                            (({}, {"grad_cosine_vs_full": 0.9})),
                            (({"loss_ratio_vs_fused": 1.2}, {})),
                            (({}, {"refresh": {"churn": [0.2],
                                               "drift": [0.05], "count": 3,
                                               "step_retraces": 1,
                                               "refresh_retraces": 3}}))):
            self._write(tmp_path, trn=top)
            if nested:
                rep = _json.loads(
                    (tmp_path / "BENCH_train.json").read_text())
                rep["methods"]["mimps_ce"].update(nested)
                (tmp_path / "BENCH_train.json").write_text(
                    _json.dumps(rep))
            assert self._check(tmp_path, monkeypatch) >= 1, (top, nested)

    def test_fails_on_broken_lsh_invariants(self, tmp_path, monkeypatch):
        """The PR-10 gate: lsh losing to exact in wall-clock, collision-head
        recall regressing past rel_err 0.1, an estimator breaking its
        floats_bound/byte-sublinear ceiling, update_rows losing to a full
        IVF refresh, or a recompiling lsh_ce refresh each fail --check on
        their own; so do missing lsh rows."""
        import json as _json
        import benchmarks.run as run
        self._write(tmp_path)
        monkeypatch.chdir(tmp_path)
        monkeypatch.setattr(run, "BASELINE_PATH",
                            str(tmp_path / "baseline.json"))
        run.update_baseline()
        assert self._check(tmp_path, monkeypatch) == 0
        # wall-clock: lsh must beat exact (2000us) on the same timing pass
        self._write(tmp_path, est={"lsh": 2600.0})
        assert self._check(tmp_path, monkeypatch) >= 1
        # accuracy + bound sections
        for mutate in (
                lambda r: r["methods"]["lsh"].update(
                    {"rel_err_vs_exact": 0.4}),
                lambda r: r["methods"].pop("lsh"),
                lambda r: r["bound"].update({"ok_all": False}),
                lambda r: r["bound"].update({"byte_sublinear_all": False})):
            self._write(tmp_path)
            rep = _json.loads(
                (tmp_path / "BENCH_estimators.json").read_text())
            mutate(rep)
            (tmp_path / "BENCH_estimators.json").write_text(
                _json.dumps(rep))
            assert self._check(tmp_path, monkeypatch) >= 1
        # train side: inverted refresh-cost advantage, recompiling refresh,
        # missing sections
        for trn in ({"refresh_cost": {"ivf_refresh_us": 30000.0,
                                      "lsh_update_us": 32000.0,
                                      "rows_updated": 256, "ratio": 1.07}},
                    {"refresh_cost": None}):
            self._write(tmp_path, trn=trn)
            if trn["refresh_cost"] is None:
                rep = _json.loads(
                    (tmp_path / "BENCH_train.json").read_text())
                del rep["refresh_cost"]
                (tmp_path / "BENCH_train.json").write_text(
                    _json.dumps(rep))
            assert self._check(tmp_path, monkeypatch) >= 1, trn
        self._write(tmp_path)
        rep = _json.loads((tmp_path / "BENCH_train.json").read_text())
        rep["methods"]["lsh_ce"]["refresh"]["refresh_retraces"] = 3
        (tmp_path / "BENCH_train.json").write_text(_json.dumps(rep))
        assert self._check(tmp_path, monkeypatch) >= 1
        self._write(tmp_path)
        rep = _json.loads((tmp_path / "BENCH_train.json").read_text())
        del rep["methods"]["lsh_ce"]
        (tmp_path / "BENCH_train.json").write_text(_json.dumps(rep))
        assert self._check(tmp_path, monkeypatch) >= 1
