"""LSH sampler backend (DESIGN.md SS18): packed SimHash index invariants,
O(1)-per-row update == fresh rebuild, fused Hamming-probe kernel parity,
importance-sampled tail correctness, unbiasedness of the collision
estimator over the hyperplane draw, zero-recompile maintenance, the lsh_ce
training loss, and the registry-derived serve CLI.

The 8-virtual-device sharded-decode parity case runs in a subprocess (the
tests/test_sharded_serving.py pattern) so the XLA device-count override
never leaks into this process.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import PartitionConfig
from repro.core import lsh as _lsh
from repro.core.backends import BACKENDS, get_backend

from conftest import make_clustered_vectors

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _index(key, w, **kw):
    kw.setdefault("n_bits", 5)
    kw.setdefault("n_tables", 6)
    kw.setdefault("bucket_cap", 2048)  # >= n: no-overflow regime
    return _lsh.build_lsh_device(key, w, **kw)


@pytest.fixture(scope="module")
def small_setup():
    key = jax.random.PRNGKey(3)
    w = make_clustered_vectors(key, 2048, 32, n_centers=16)
    h = jax.random.normal(jax.random.fold_in(key, 1), (8, 32)) * 0.4
    return key, w, h


class TestBuildInvariants:
    def test_packed_tables_route_back(self, small_setup):
        """Every routed row (slot >= 0) sits at exactly its recorded bucket
        slot; every live bucket entry points back at a row whose code is
        that bucket."""
        key, w, _ = small_setup
        idx = _index(key, w)
        codes = np.asarray(idx.codes)
        slots = np.asarray(idx.slot_of_row)
        buckets = np.asarray(idx.buckets)
        n, ltab = codes.shape
        assert codes.min() >= 0 and codes.max() < idx.n_buckets
        for t in range(ltab):
            routed = slots[:, t] >= 0
            r = np.nonzero(routed)[0]
            assert (buckets[t, codes[r, t], slots[r, t]] == r).all()
            live = buckets[t][buckets[t] >= 0]
            assert len(live) == len(set(live)) == routed.sum()

    def test_proj_carries_mips_coordinate(self, small_setup):
        key, w, _ = small_setup
        idx = _index(key, w)
        assert idx.proj.shape == (6, 5, w.shape[1] + 1)
        # default policy is angle-only: the augmented coordinate clamps to 0
        assert float(idx.aug_scale) == 0.0

    def test_tail_logits_track_norms(self, small_setup):
        key, w, _ = small_setup
        idx = _index(key, w, tail_beta=16.0)
        norms = jnp.linalg.norm(w, axis=-1)
        np.testing.assert_allclose(
            np.asarray(idx.tail_logits),
            np.asarray(idx.tail_scale * norms), rtol=1e-6)


class TestUpdateEqualsRebuild:
    """Satellite: O(1)-per-row ``update_rows`` must land in the SAME state a
    fresh pack of the updated embedding reaches — identical codes and
    bit-identical downstream candidate sets — in the low-overflow regime
    (generous caps; overflow changes which table drops a row, which is a
    documented divergence, not a bug)."""

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_update_rows_matches_fresh_pack(self, small_setup, seed):
        key, w, h = small_setup
        idx = _index(key, w)
        kr = jax.random.PRNGKey(100 + seed)
        rows = jax.random.choice(kr, w.shape[0], (64,), replace=False)
        w2 = w.at[rows].add(
            0.3 * jax.random.normal(jax.random.fold_in(kr, 1),
                                    (64, w.shape[1])))
        upd = _lsh.update_rows(idx, w2, rows)
        fresh = _lsh.pack_lsh(idx.proj, w2, idx.aug_scale, idx.tail_scale,
                              bucket_cap=idx.bucket_cap)
        assert bool(jnp.all(upd.codes == fresh.codes))
        np.testing.assert_allclose(np.asarray(upd.tail_logits),
                                   np.asarray(fresh.tail_logits), atol=1e-6)
        # routing sets per table agree (slot ORDER may differ — update
        # splices into the first free slot, pack fills in row order)
        for t in range(idx.n_tables):
            a = np.asarray(upd.buckets[t]); b = np.asarray(fresh.buckets[t])
            for bk in range(idx.n_buckets):
                assert set(a[bk][a[bk] >= 0]) == set(b[bk][b[bk] >= 0])
        kd = jax.random.fold_in(kr, 2)
        pa = _lsh.lsh_plan(upd, h, kd, 128)
        pb = _lsh.lsh_plan(fresh, h, kd, 128)
        assert int(pa.cand_live) > 0, "degenerate: no candidates routed"
        for f in ("occ_q", "cand_rows", "cand_live", "member", "k_eff",
                  "tail_ids", "tail_accept"):
            assert bool(jnp.all(getattr(pa, f) == getattr(pb, f))), f
        oa = _lsh.lsh_decode(upd, w2, h, kd, l=128)
        ob = _lsh.lsh_decode(fresh, w2, h, kd, l=128)
        np.testing.assert_allclose(np.asarray(oa.log_z),
                                   np.asarray(ob.log_z), atol=1e-6)
        assert bool(jnp.all(oa.top_id == ob.top_id))

    def test_rehash_metrics_contract(self, small_setup):
        key, w, _ = small_setup
        idx = _index(key, w)
        new, m = _lsh.rehash_lsh(idx, w * 1.5)
        assert set(m) == {"churn", "drift"}
        # pure rescale flips no sign bits: churn == 0, and the packed
        # tables must be reproduced exactly
        assert float(m["churn"]) == 0.0
        assert bool(jnp.all(new.buckets == idx.buckets))


class TestDecodeCorrectness:
    def test_close_to_exact(self, small_setup):
        key, w, h = small_setup
        idx = _index(key, w, n_bits=4, n_tables=8, tail_beta=16.0)
        out = _lsh.lsh_decode(idx, w, h, jax.random.fold_in(key, 7), l=256)
        exact = jax.nn.logsumexp((h @ w.T).astype(jnp.float32), -1)
        rel = jnp.abs(1.0 - jnp.exp(out.log_z - exact))
        assert float(rel.mean()) < 0.15, float(rel.mean())
        # top-1 over the collision head must be the true argmax whenever
        # the true argmax collides (it does here: clustered data, 8 tables)
        s = h @ w.T
        agree = (out.top_id[:, 0] == jnp.argmax(s, -1)).mean()
        assert float(agree) >= 0.75

    def test_overflow_dense_fallback_matches(self, small_setup):
        """cand_cap below the measured union flips consumers to the dense
        occ_q branch — identical math, so log Z must agree to float
        reduction order."""
        key, w, h = small_setup
        idx = _index(key, w, n_bits=4, n_tables=8)
        kd = jax.random.fold_in(key, 8)
        big = _lsh.lsh_decode(idx, w, h, kd, l=128, cand_cap=w.shape[0])
        plan = _lsh.lsh_plan(idx, h, kd, 128)
        tiny_cap = max(8, int(plan.cand_live) // 4)
        small = _lsh.lsh_decode(idx, w, h, kd, l=128, cand_cap=tiny_cap)
        np.testing.assert_allclose(np.asarray(big.log_z),
                                   np.asarray(small.log_z), atol=1e-5)
        assert bool(jnp.all(big.top_id == small.top_id))

    def test_active_mask_keeps_live_rows(self, small_setup):
        key, w, h = small_setup
        idx = _index(key, w)
        kd = jax.random.fold_in(key, 9)
        active = jnp.array([1, 1, 0, 1, 0, 1, 1, 1], bool)
        solo = _lsh.lsh_decode(idx, w, h, kd, l=64)
        masked = _lsh.lsh_decode(idx, w, h, kd, l=64, active=active)
        live = np.nonzero(np.asarray(active))[0]
        np.testing.assert_allclose(np.asarray(masked.log_z)[live],
                                   np.asarray(solo.log_z)[live], atol=1e-5)


class TestImportanceTail:
    def test_beta_zero_reduces_to_uniform(self, small_setup):
        """tail_beta = 0 makes the defensive mixture exactly uniform: zero
        per-sample bias and the Hajek denominator degrades to the plain
        accept count."""
        key, w, h = small_setup
        idx = _index(key, w, tail_beta=0.0)
        plan = _lsh.lsh_plan(idx, h, jax.random.fold_in(key, 11), 128)
        assert float(jnp.max(jnp.abs(plan.tail_bias))) < 1e-5
        np.testing.assert_allclose(
            np.asarray(plan.n_accept),
            np.asarray(plan.tail_accept.sum(-1)), rtol=1e-5)

    def test_mixture_floors_sample_weight(self, small_setup):
        """Defensive mixture: every row keeps p >= 1/(2n), so the count
        weight exp(tail_bias) = 1/(n p) never exceeds 2 (the property that
        keeps the Hajek denominator estimable under heavy tilt)."""
        key, w, h = small_setup
        idx = _index(key, w, tail_beta=48.0)
        plan = _lsh.lsh_plan(idx, h, jax.random.fold_in(key, 12), 256)
        assert float(jnp.max(jnp.exp(plan.tail_bias))) <= 2.0 + 1e-5

    def test_tail_estimator_unbiased_over_draws(self, small_setup):
        """E over tail draws of the Eq. 5 tail term ~= the exact tail mass
        at fixed head (Hajek ratio: consistent, O(1/l) bias)."""
        key, w, h = small_setup
        idx = _index(key, w, tail_beta=16.0)
        h1 = h[:1]
        exact = float(jax.nn.logsumexp(
            (h1 @ w.T).astype(jnp.float32), -1)[0])
        zs = []
        for s in range(48):
            out = _lsh.lsh_decode(idx, w, h1, jax.random.PRNGKey(500 + s),
                                  l=256)
            zs.append(float(out.log_z[0]))
        z_mean = np.log(np.mean(np.exp(np.array(zs) - exact)))
        assert abs(z_mean) < 0.1, z_mean


class TestUnbiasedness:
    def test_sns_over_hyperplane_draws(self):
        """Spring & Shrivastava's estimator is unbiased over the TABLE
        draw: averaging Ẑ across independent hyperplane sets converges on
        the exact partition function."""
        key = jax.random.PRNGKey(17)
        w = make_clustered_vectors(key, 512, 16, n_centers=8)
        h = jax.random.normal(jax.random.fold_in(key, 1), (2, 16)) * 0.4
        exact = jax.nn.logsumexp((h @ w.T).astype(jnp.float32), -1)
        ratios = []
        for s in range(64):
            idx = _index(jax.random.PRNGKey(700 + s), w, n_bits=4,
                         n_tables=4, bucket_cap=512)
            lz = _lsh.sns_log_z(idx, w, h)
            ratios.append(np.exp(np.asarray(lz - exact, np.float64)))
        mean = np.mean(ratios, axis=0)
        assert np.all(np.abs(mean - 1.0) < 0.25), mean


class TestKernelParity:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_fused_matches_reference(self, small_setup, dtype):
        key, w, h = small_setup
        idx = _index(key, w.astype(dtype), n_bits=4, n_tables=8)
        kd = jax.random.fold_in(key, 21)
        ref = _lsh.lsh_decode(idx, w.astype(dtype), h.astype(dtype), kd,
                              l=128, k=4, use_pallas=False)
        pal = _lsh.lsh_decode(idx, w.astype(dtype), h.astype(dtype), kd,
                              l=128, k=4, use_pallas=True)
        tol = 1e-5 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(np.asarray(pal.log_z),
                                   np.asarray(ref.log_z), atol=tol)
        np.testing.assert_allclose(np.asarray(pal.head_lse),
                                   np.asarray(ref.head_lse), atol=tol)
        if dtype == jnp.float32:
            assert bool(jnp.all(pal.top_id == ref.top_id))


class TestZeroRecompiles:
    def test_decode_across_update_and_rehash(self, small_setup):
        """Index maintenance is data, not shape: N decodes interleaved with
        update_rows and a full rehash reuse ONE decode executable."""
        key, w, h = small_setup
        idx = _index(key, w)
        traces = {"n": 0}

        def body(index, ww, hh, kk):
            traces["n"] += 1
            return _lsh.lsh_decode(index, ww, hh, kk, l=64).log_z

        dec = jax.jit(body)
        rows = jnp.arange(32, dtype=jnp.int32)
        for i in range(4):
            kk = jax.random.fold_in(key, 30 + i)
            jax.block_until_ready(dec(idx, w, h, kk))
            w = w.at[rows].add(0.01)
            idx = _lsh.update_rows(idx, w, rows)
        idx, _ = _lsh.rehash_lsh(idx, w)
        jax.block_until_ready(dec(idx, w, h, jax.random.fold_in(key, 40)))
        assert traces["n"] == 1, f"{traces['n'] - 1} decode recompiles"


class TestLshCeLoss:
    def test_registered_and_grads_touch_scored_rows(self):
        from repro.train.losses import ESTIMATOR_LOSSES, lsh_estimator_ce
        assert "lsh_ce" in ESTIMATOR_LOSSES
        key = jax.random.PRNGKey(5)
        w = make_clustered_vectors(key, 1024, 32, n_centers=8)
        idx = _index(key, w, n_bits=4, n_tables=6, bucket_cap=512)
        t = 16
        h = jax.random.normal(jax.random.fold_in(key, 1), (t, 32)) * 0.4
        labels = jax.random.randint(jax.random.fold_in(key, 2), (t,), 0,
                                    1024)
        kd = jax.random.fold_in(key, 3)

        def full(hh, ww):
            logits = (hh @ ww.T).astype(jnp.float32)
            lse = jax.nn.logsumexp(logits, -1)
            s = jnp.take_along_axis(logits, labels[:, None], -1)[:, 0]
            return (lse - s).mean()

        def est(hh, ww):
            nll, _, _ = lsh_estimator_ce(idx, hh, ww, labels, kd, l=256)
            return nll.mean()

        g_full = np.asarray(jax.grad(full, argnums=1)(h, w))
        g_est = np.asarray(jax.grad(est, argnums=1)(h, w))
        touched = np.abs(g_est).sum(-1) > 0
        assert 0 < touched.sum() < w.shape[0]
        plan = _lsh.lsh_plan(idx, h, kd, 256, cand_cap=idx.n)
        allowed = set(np.asarray(plan.cand_rows).tolist()) \
            | set(np.asarray(plan.tail_ids).tolist()) \
            | set(np.asarray(labels).tolist())
        assert set(np.nonzero(touched)[0].tolist()) <= allowed
        a, b = g_full[touched].ravel(), g_est[touched].ravel()
        cos = float(a @ b / (np.linalg.norm(a) * np.linalg.norm(b)))
        assert cos > 0.97, cos

    def test_train_state_lifecycle_zero_recompiles(self):
        """init -> lsh_ce steps -> rehash refresh -> more steps: ONE step
        executable, ONE refresh executable (the train_bench contract at
        test scale)."""
        import dataclasses
        from repro.configs import reduced_config
        from repro.configs.base import TrainConfig
        from repro.data import DataIterator, SyntheticCorpus
        from repro.models import Model
        from repro.train import (init_train_state, make_train_step)
        from repro.train.train_loop import make_index_refresh
        cfg = reduced_config("qwen1.5-4b")
        cfg = dataclasses.replace(
            cfg, vocab=2048,
            partition=dataclasses.replace(cfg.partition, l=128,
                                          lsh_bits=4, lsh_tables=6))
        model = Model(cfg)
        tc = TrainConfig(lr=1e-3, loss="lsh_ce", total_steps=6,
                         warmup_steps=1)
        state = init_train_state(model, tc, jax.random.PRNGKey(0))
        assert isinstance(state.index, _lsh.LSHIndex)
        traces = {"n": 0}
        raw = make_train_step(model, tc)

        def counted(s, b):
            traces["n"] += 1
            return raw(s, b)

        step = jax.jit(counted)
        refresh = make_index_refresh(model, tc)
        it = DataIterator(SyntheticCorpus(vocab=cfg.vocab, seed=0), 2, 8)
        for i in range(4):
            toks, labels = next(it)
            state, m = step(state, {"tokens": jnp.asarray(toks),
                                    "labels": jnp.asarray(labels)})
            if i == 1:
                state, rm = refresh(state)
                assert set(rm) == {"churn", "drift"}
        jax.block_until_ready(m["loss_total"])
        assert np.isfinite(float(m["loss_total"]))
        assert traces["n"] == 1, f"{traces['n'] - 1} step recompiles"


class TestServeRegistry:
    def test_backend_registered_and_servable(self):
        assert "lsh" in BACKENDS
        bk = get_backend("lsh")
        assert bk.sublinear

    def test_cli_choices_derive_from_registry(self):
        """Satellite: launch/serve.py --method/--spec-draft choices come
        from the BACKENDS registry, not a hand-written list."""
        from repro.launch import serve as serve_mod
        import argparse
        captured = {}
        real = argparse.ArgumentParser.add_argument

        def spy(self, *a, **kw):
            if a and a[0] in ("--method", "--spec-draft"):
                captured[a[0]] = kw.get("choices")
            return real(self, *a, **kw)

        argparse.ArgumentParser.add_argument = spy
        try:
            old_argv = sys.argv
            sys.argv = ["serve", "--help"]
            with pytest.raises(SystemExit):
                serve_mod.main()
        finally:
            argparse.ArgumentParser.add_argument = real
            sys.argv = old_argv
        for flag in ("--method", "--spec-draft"):
            assert captured.get(flag) == [None] + sorted(BACKENDS), flag

    def test_embedding_floats_sublinear(self, small_setup):
        key, w, _ = small_setup
        cfg = PartitionConfig(method="lsh", l=128, lsh_bits=4, lsh_tables=6,
                              lsh_bucket_cap=128, head_cap=512)
        bk = get_backend("lsh")
        st = bk.build(cfg, w, key)
        q = 8
        floats = bk.embedding_floats(st, cfg, q, u=400)
        assert floats < w.shape[0] * w.shape[1]
        assert floats <= bk.floats_bound(st, cfg, q)


SHARDED_PARITY_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.configs.base import PartitionConfig
from repro.core import backends as B
from repro.core.distributed import shard_map
from repro.launch.mesh import make_serving_mesh

cfg = PartitionConfig(method="lsh", l=64, head_cap=512, lsh_bits=4,
                      lsh_tables=6, lsh_bucket_cap=128, lsh_tail_beta=16.0)
key = jax.random.PRNGKey(0)
w = jax.random.normal(jax.random.PRNGKey(1), (1024, 32)) * 0.3
h = jax.random.normal(jax.random.PRNGKey(2), (8, 32))
active = jnp.array([1, 1, 0, 1, 1, 1, 0, 1], bool)
kd = jax.random.PRNGKey(7)
bk = B.get_backend("lsh")

for (dp, mp) in [(1, 4), (2, 4), (1, 8)]:
    mesh = make_serving_mesh(dp, mp)
    ref = bk.decode(bk.build(cfg, w, key), h, kd, cfg, k=4,
                    use_pallas=False, active=active)
    st = bk.build(cfg, w, key, block_multiple=mp)
    specs = B.state_partition_specs(st, mp)
    body = lambda s, hh: bk.shard_decode(s, hh, kd, cfg, k=4, active=active)
    out = jax.jit(shard_map(body, mesh, in_specs=(specs, P()),
                            out_specs=P(), check_vma=False))(st, h)
    for f in ("log_z", "top_score", "top_id", "head_lse", "tail_lse",
              "k_eff"):
        assert bool(jnp.all(getattr(ref, f) == getattr(out, f))), \
            (dp, mp, f)
print("ALL_OK")
"""


class TestShardedParity:
    def test_mesh_decode_bitwise_parity_8dev(self):
        """mesh_lsh_decode under (data, model) meshes is BITWISE identical
        to the single-device XLA decode — the plan replicates, only
        embedding rows shard."""
        r = subprocess.run([sys.executable, "-c", SHARDED_PARITY_SNIPPET],
                           capture_output=True, text=True,
                           env=dict(os.environ, PYTHONPATH="src"),
                           cwd=REPO, timeout=900)
        assert r.returncode == 0 and "ALL_OK" in r.stdout, \
            r.stdout + r.stderr
