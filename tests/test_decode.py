"""Fused batched MIMPS decode pipeline (core.decode + kernels.ivf_decode):
parity against the XLA gather fallback, estimator correctness, engine wiring.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (build_ivf, exact_log_z, head_count, make_plan,
                        mimps_decode, probe, probe_batch, gather_scores,
                        relative_error)
from repro.core.decode import plan_heads


@pytest.fixture(scope="module")
def index(vectors, rng):
    return build_ivf(rng, vectors, block_rows=128)


class TestProbeBatch:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_vmap_probe(self, index, vectors, dtype):
        qs = vectors[:32].astype(dtype)
        batched = probe_batch(index, qs, 8)
        looped = jax.vmap(lambda q: probe(index, q, 8))(qs)
        np.testing.assert_array_equal(np.asarray(batched), np.asarray(looped))

    def test_head_count_batched(self, index, vectors):
        qs = vectors[:8]
        bids = probe_batch(index, qs, 4)
        batched = head_count(index, bids)
        per_q = jnp.stack([head_count(index, bids[i]) for i in range(8)])
        np.testing.assert_array_equal(np.asarray(batched), np.asarray(per_q))


class TestPlanHeads:
    def test_union_covers_and_masks_pads(self, rng):
        bids = jax.random.randint(rng, (16, 4), 0, 10).astype(jnp.int32)
        head_ids, member, n_unique = plan_heads(bids, capacity=64)
        ids_np = np.asarray(head_ids)
        bids_np = np.asarray(bids)
        nu = int(n_unique)
        assert set(ids_np[:nu]) == set(bids_np.ravel())
        # membership == exact per-query set membership; pad slots all-false
        member_np = np.asarray(member)
        for qi in range(16):
            for u in range(64):
                expect = u < nu and ids_np[u] in bids_np[qi]
                assert member_np[qi, u] == expect
        # every query's probe count is preserved (no dup/dropped blocks)
        assert (member_np.sum(1) ==
                [len(set(r)) for r in bids_np]).all()


class TestFusedDecodeParity:
    """Acceptance: fused log-Ẑ matches the reference within 1e-4 (interpret)."""

    @pytest.mark.parametrize("q,p,l,k", [(16, 8, 64, 1), (5, 4, 33, 2),
                                         (32, 2, 128, 4)])
    def test_pallas_vs_xla_ref(self, index, vectors, rng, q, p, l, k):
        h = vectors[100:100 + q]
        kd = jax.random.fold_in(rng, q * 1000 + l)
        out_p = mimps_decode(index, h, kd, n_probe=p, l=l, k=k,
                             use_pallas=True)
        out_r = mimps_decode(index, h, kd, n_probe=p, l=l, k=k,
                             use_pallas=False)
        np.testing.assert_allclose(np.asarray(out_p.log_z),
                                   np.asarray(out_r.log_z), atol=1e-4)
        np.testing.assert_allclose(np.asarray(out_p.head_lse),
                                   np.asarray(out_r.head_lse), atol=1e-4)
        np.testing.assert_allclose(np.asarray(out_p.tail_lse),
                                   np.asarray(out_r.tail_lse), atol=1e-4)
        np.testing.assert_allclose(np.asarray(out_p.top_score),
                                   np.asarray(out_r.top_score), atol=1e-4)
        np.testing.assert_array_equal(np.asarray(out_p.top_id),
                                      np.asarray(out_r.top_id))

    def test_head_matches_gather_scores_fallback(self, index, vectors, rng):
        """The batched kernel's head LSE == per-query XLA gather_scores."""
        h = vectors[:16]
        kd = jax.random.fold_in(rng, 3)
        out = mimps_decode(index, h, kd, n_probe=8, l=16, use_pallas=True)
        plan = make_plan(index, h, kd, 8, 16)

        def one(qv, blocks):
            s, valid = gather_scores(index, qv, blocks)
            return jax.nn.logsumexp(jnp.where(valid, s, -1e30))

        ref = jax.vmap(one)(h, plan.block_ids)
        np.testing.assert_allclose(np.asarray(out.head_lse), np.asarray(ref),
                                   atol=1e-4)

    def test_bf16_parity(self, index, vectors, rng):
        h = vectors[7:20].astype(jnp.bfloat16)
        kd = jax.random.fold_in(rng, 5)
        out_p = mimps_decode(index, h, kd, n_probe=4, l=32, use_pallas=True)
        out_r = mimps_decode(index, h, kd, n_probe=4, l=32, use_pallas=False)
        np.testing.assert_allclose(np.asarray(out_p.log_z),
                                   np.asarray(out_r.log_z), atol=1e-4)

    def test_top1_is_exact_argmax_of_head(self, index, vectors):
        """Rank-1 id through the fused path == argmax over probed rows."""
        h = vectors[:8]
        kd = jax.random.PRNGKey(11)
        out = mimps_decode(index, h, kd, n_probe=8, l=16, use_pallas=True)
        bids = probe_batch(index, h, 8)
        for i in range(8):
            s, valid = gather_scores(index, h[i], bids[i])
            s = jnp.where(valid, s, -1e30)
            best = int(jnp.argmax(s))
            rid = int(index.row_id[bids[i][best // index.block_rows],
                                   best % index.block_rows])
            assert int(out.top_id[i, 0]) == rid


class TestDeviceBuild:
    """Acceptance: the jittable fixed-capacity build (mips.build_ivf_device)
    matches the host build's retrieval recall within 1% on these fixtures
    (same k-means key -> same clusters; the device index only adds empty
    capacity blocks, which the probe ranks at -inf)."""

    @pytest.fixture(scope="class")
    def dev_index(self, vectors, rng):
        from repro.core import build_ivf_device
        return build_ivf_device(rng, vectors, block_rows=128)

    @staticmethod
    def _recall_at_1(index, vectors, qs, n_probe=8):
        bids = probe_batch(index, qs, n_probe)
        br = index.v_blocks.shape[1]
        hits = 0
        for i in range(qs.shape[0]):
            s, valid = gather_scores(index, qs[i], bids[i])
            s = jnp.where(valid, s, -1e30)
            best = int(jnp.argmax(s))
            rid = int(index.row_id[bids[i][best // br], best % br])
            from repro.core import exact_top_k
            _, ids = exact_top_k(vectors, qs[i], 1)
            hits += int(rid == int(ids[0]))
        return hits / qs.shape[0]

    def test_recall_matches_host_build(self, index, dev_index, vectors, rng):
        qs = vectors[:64] + 0.1 * jax.random.normal(
            jax.random.fold_in(rng, 77), (64, vectors.shape[1]))
        r_host = self._recall_at_1(index, vectors, qs)
        r_dev = self._recall_at_1(dev_index, vectors, qs)
        assert abs(r_host - r_dev) <= 0.01, (r_host, r_dev)

    def test_every_row_packed_once(self, dev_index, vectors):
        rid = np.asarray(dev_index.row_id).ravel()
        assert sorted(rid[rid >= 0].tolist()) == \
            list(range(vectors.shape[0]))
        flat = np.asarray(dev_index.v_blocks).reshape(-1,
                                                      vectors.shape[1])
        np.testing.assert_allclose(
            flat[np.asarray(dev_index.slot_of_row)], np.asarray(vectors),
            atol=1e-6)

    def test_decode_parity_on_device_index(self, dev_index, vectors, rng):
        """The fused pipeline runs unchanged on a device-built index."""
        h = vectors[50:66]
        kd = jax.random.fold_in(rng, 13)
        out_p = mimps_decode(dev_index, h, kd, n_probe=8, l=64,
                             use_pallas=True)
        out_r = mimps_decode(dev_index, h, kd, n_probe=8, l=64,
                             use_pallas=False)
        np.testing.assert_allclose(np.asarray(out_p.log_z),
                                   np.asarray(out_r.log_z), atol=1e-4)
        exact = jax.nn.logsumexp(
            (h @ vectors.T).astype(jnp.float32), -1)
        err = np.abs(1 - np.exp(np.asarray(out_r.log_z) - np.asarray(exact)))
        assert err.mean() < 0.15, err.mean()

    def test_refresh_preserves_retrieval(self, dev_index, vectors, rng):
        """refresh_ivf on the SAME vectors is a no-op for retrieval quality
        and keeps every shape (the zero-recompile contract)."""
        from repro.core import refresh_ivf
        # invert the capacity formula nb = ceil(N/br) + C (device builds)
        br = dev_index.v_blocks.shape[1]
        n_clusters = dev_index.n_blocks - (-(-int(dev_index.n) // br))
        new_index, metrics = refresh_ivf(dev_index, vectors,
                                         n_clusters=n_clusters)
        assert new_index.v_blocks.shape == dev_index.v_blocks.shape
        qs = vectors[:32]
        r0 = self._recall_at_1(dev_index, vectors, qs)
        r1 = self._recall_at_1(new_index, vectors, qs)
        assert abs(r0 - r1) <= 0.05, (r0, r1)
        assert float(metrics["drift"]) < 1e-5  # nothing moved


class TestDecodeEstimator:
    def test_close_to_exact(self, index, vectors, rng):
        h = vectors[200:232]
        out = mimps_decode(index, h, rng, n_probe=8, l=256, use_pallas=True)
        exact = jax.vmap(lambda q: exact_log_z(vectors, q))(h)
        err = np.asarray(jax.vmap(relative_error)(out.log_z, exact))
        assert err.mean() < 0.1, err

    def test_tail_scale_unbiased(self, index, vectors, rng):
        """E[Ẑ] == Z under the (N - k_eff)/#accepted Eq. 5 scale (the
        Rao-Blackwellized, lower-variance form of the seed's N/l scale)."""
        q = vectors[123]
        lzt = float(exact_log_z(vectors, q))
        keys = jax.random.split(rng, 512)
        zs = jax.vmap(lambda k: jnp.exp(mimps_decode(
            index, q[None], k, n_probe=4, l=64,
            use_pallas=False).log_z[0]))(keys)
        rel = abs(float(jnp.mean(zs)) / np.exp(lzt) - 1.0)
        assert rel < 0.05, f"fused-path tail estimator biased: {rel}"


class TestEngineWiring:
    @pytest.mark.parametrize("use_pallas", [False, True])
    def test_engine_mimps_paths_agree(self, rng, use_pallas):
        from repro.configs import reduced_config
        from repro.models import Model
        from repro.serve import Engine
        cfg = reduced_config("qwen1.5-4b")
        cfg = dataclasses.replace(
            cfg, vocab=2048, partition=dataclasses.replace(
                cfg.partition, method="mimps", block_rows=128, n_probe=4,
                l=128))
        m = Model(cfg)
        p = m.init(rng)
        eng_ref = Engine(m, p, max_len=32, use_pallas=False)
        eng_pal = Engine(m, p, max_len=32, use_pallas=use_pallas)
        h = jax.random.normal(rng, (4, cfg.d_model)).astype(cfg.dtype) * 0.3
        o_ref = eng_ref.next_token_distribution(h, rng)
        o_pal = eng_pal.next_token_distribution(h, rng)
        np.testing.assert_allclose(np.asarray(o_pal["log_z"]),
                                   np.asarray(o_ref["log_z"]), atol=1e-4)
        np.testing.assert_array_equal(np.asarray(o_pal["token"]),
                                      np.asarray(o_ref["token"]))
