"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fused_ce import fused_ce_fwd, fused_ce_bwd
from repro.kernels.ops import (fused_cross_entropy, fused_topk_z,
                               ivf_block_scores)
from repro.kernels.ref import fused_ce_ref, topk_z_ref, ivf_score_ref


def _mk(key, t, d, v, dtype):
    kh, kw, kl = jax.random.split(key, 3)
    h = (jax.random.normal(kh, (t, d)) * 0.4).astype(dtype)
    w = (jax.random.normal(kw, (v, d)) * 0.4).astype(dtype)
    lab = jax.random.randint(kl, (t,), 0, v)
    return h, w, lab


SHAPES = [(16, 32, 128), (200, 96, 1000), (64, 128, 517), (8, 256, 2048)]
DTYPES = [jnp.float32, jnp.bfloat16]


class TestFusedCE:
    @pytest.mark.parametrize("t,d,v", SHAPES)
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_fwd_matches_ref(self, rng, t, d, v, dtype):
        h, w, lab = _mk(rng, t, d, v, dtype)
        nll, lse = fused_ce_fwd(h, w, lab, block_t=64, block_v=128)
        nll_r, lse_r = fused_ce_ref(h.astype(jnp.float32),
                                    w.astype(jnp.float32), lab)
        tol = 5e-5 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(np.asarray(nll), np.asarray(nll_r),
                                   rtol=tol, atol=tol)
        np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_r),
                                   rtol=tol, atol=tol)

    @pytest.mark.parametrize("t,d,v", [(48, 32, 300), (128, 64, 512)])
    def test_bwd_matches_autodiff(self, rng, t, d, v):
        h, w, lab = _mk(rng, t, d, v, jnp.float32)
        gn = jax.random.normal(jax.random.fold_in(rng, 5), (t,))
        gl = jax.random.normal(jax.random.fold_in(rng, 6), (t,))
        _, lse = fused_ce_ref(h, w, lab)
        dh, dw = fused_ce_bwd(h, w, lab, lse, gn, gl, block_t=32, block_v=128)

        def f(h, w):
            nll_r, lse_r = fused_ce_ref(h, w, lab)
            return jnp.sum(nll_r * gn) + jnp.sum(lse_r * gl)

        dh_r, dw_r = jax.grad(f, argnums=(0, 1))(h, w)
        np.testing.assert_allclose(np.asarray(dh), np.asarray(dh_r),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(dw), np.asarray(dw_r),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.skipif(jax.default_backend() != "tpu",
                        reason="exercises the compiled alias_dw=True dW "
                               "accumulation path, which interpret mode "
                               "cannot reach")
    def test_bwd_alias_path_on_tpu(self, rng):
        """Real-TPU guard for the accumulate-through-HBM dW branch: the
        io-aliased revisit pattern rests on a DMA-ordering assumption that
        only compiled execution can falsify."""
        h, w, lab = _mk(rng, 96, 128, 1024, jnp.float32)
        gn = jax.random.normal(jax.random.fold_in(rng, 5), (96,))
        gl = jax.random.normal(jax.random.fold_in(rng, 6), (96,))
        _, lse = fused_ce_ref(h, w, lab)
        dh, dw = fused_ce_bwd(h, w, lab, lse, gn, gl, block_t=32, block_v=256,
                              interpret=False)

        def f(h, w):
            nll_r, lse_r = fused_ce_ref(h, w, lab)
            return jnp.sum(nll_r * gn) + jnp.sum(lse_r * gl)

        dh_r, dw_r = jax.grad(f, argnums=(0, 1))(h, w)
        np.testing.assert_allclose(np.asarray(dh), np.asarray(dh_r),
                                   rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(np.asarray(dw), np.asarray(dw_r),
                                   rtol=1e-3, atol=1e-3)

    def test_custom_vjp_under_jit(self, rng):
        h, w, lab = _mk(rng, 40, 48, 257, jnp.float32)

        def loss(h, w):
            nll, lse = fused_cross_entropy(h, w, lab)
            return nll.mean() + 0.1 * (lse ** 2).mean()

        def loss_ref(h, w):
            logits = h @ w.T
            lse = jax.nn.logsumexp(logits, -1)
            nll = lse - jnp.take_along_axis(logits, lab[:, None], 1)[:, 0]
            return nll.mean() + 0.1 * (lse ** 2).mean()

        g = jax.jit(jax.grad(loss, argnums=(0, 1)))(h, w)
        gr = jax.grad(loss_ref, argnums=(0, 1))(h, w)
        np.testing.assert_allclose(np.asarray(g[0]), np.asarray(gr[0]),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(g[1]), np.asarray(gr[1]),
                                   rtol=1e-4, atol=1e-5)

    def test_grad_descent_reduces_loss(self, rng):
        """End-to-end sanity: SGD on the fused loss actually learns."""
        h, w, lab = _mk(rng, 64, 32, 128, jnp.float32)
        loss_fn = lambda w: fused_cross_entropy(h, w, lab)[0].mean()
        l0 = float(loss_fn(w))
        for _ in range(20):
            w = w - 0.5 * jax.grad(loss_fn)(w)
        assert float(loss_fn(w)) < l0 - 0.5


class TestTopkZ:
    @pytest.mark.parametrize("q,d,v", SHAPES)
    @pytest.mark.parametrize("k", [1, 8])
    def test_matches_ref(self, rng, q, d, v, k):
        h, w, _ = _mk(rng, q, d, v, jnp.float32)
        lse, tv, ti = fused_topk_z(h, w, k=k)
        lse_r, tv_r, ti_r = topk_z_ref(h, w, k)
        np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_r),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(tv), np.asarray(tv_r),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(ti), np.asarray(ti_r))

    def test_bf16(self, rng):
        h, w, _ = _mk(rng, 32, 64, 700, jnp.bfloat16)
        lse, tv, ti = fused_topk_z(h, w, k=4)
        lse_r, tv_r, ti_r = topk_z_ref(h.astype(jnp.float32),
                                       w.astype(jnp.float32), 4)
        np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_r),
                                   rtol=3e-2, atol=3e-2)


class TestIVFScore:
    @pytest.mark.parametrize("nb,br,d,q,p", [
        (8, 64, 32, 5, 2), (16, 128, 64, 37, 4), (32, 128, 128, 16, 8)])
    def test_matches_ref(self, rng, nb, br, d, q, p):
        kw, kh, ki = jax.random.split(rng, 3)
        wb = jax.random.normal(kw, (nb, br, d), jnp.float32)
        h = jax.random.normal(kh, (q, d), jnp.float32)
        ids = jax.random.randint(ki, (q, p), 0, nb)
        s = ivf_block_scores(wb, h, ids)
        s_r = ivf_score_ref(wb, h, ids)
        np.testing.assert_allclose(np.asarray(s), np.asarray(s_r),
                                   rtol=1e-5, atol=1e-5)

    def test_repeated_block_ids(self, rng):
        """Duplicate probes (degenerate routing) must still be correct."""
        wb = jax.random.normal(rng, (4, 32, 16), jnp.float32)
        h = jax.random.normal(jax.random.fold_in(rng, 1), (3, 16))
        ids = jnp.array([[0, 0], [3, 3], [1, 0]], jnp.int32)
        np.testing.assert_allclose(np.asarray(ivf_block_scores(wb, h, ids)),
                                   np.asarray(ivf_score_ref(wb, h, ids)),
                                   rtol=2e-5, atol=1e-6)
