"""Device-resident generation (serve.generate): the compiled lax.scan loop
must be indistinguishable from the host-driven debug loop — bit-identical
tokens, log_prob and log_z, greedy and sampled, text and audio heads — and
the empty-prompt crash of the seed must be a clean error."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import Model
from repro.serve import Engine, generate


def _text_engine(rng, method="mimps", temperature_vocab=2048):
    cfg = reduced_config("qwen1.5-4b")
    cfg = dataclasses.replace(
        cfg, vocab=temperature_vocab, partition=dataclasses.replace(
            cfg.partition, method=method, block_rows=128, n_probe=4, l=128))
    m = Model(cfg)
    return Engine(m, m.init(rng), max_len=32), cfg


def _audio_engine(rng):
    cfg = reduced_config("musicgen-medium")
    m = Model(cfg)
    return Engine(m, m.init(rng), max_len=32), cfg


def _both(eng, prompt, n, key, temperature=0.0):
    scan = generate(eng, prompt, n, key, temperature=temperature,
                    return_aux=True)
    host = generate(eng, prompt, n, key, temperature=temperature,
                    host_loop=True, return_aux=True)
    return scan, host


class TestScanHostParity:
    @pytest.mark.parametrize("temperature", [0.0, 0.8])
    def test_text_bit_identical(self, rng, temperature):
        eng, cfg = _text_engine(jax.random.fold_in(rng, 1))
        prompt = jax.random.randint(rng, (2, 3), 0, cfg.vocab)
        (t_s, aux_s), (t_h, aux_h) = _both(eng, prompt, 5, rng,
                                           temperature=temperature)
        assert t_s.shape == (2, 5)
        np.testing.assert_array_equal(np.asarray(t_s), np.asarray(t_h))
        np.testing.assert_array_equal(np.asarray(aux_s["log_prob"]),
                                      np.asarray(aux_h["log_prob"]))
        np.testing.assert_array_equal(np.asarray(aux_s["log_z"]),
                                      np.asarray(aux_h["log_z"]))

    @pytest.mark.parametrize("temperature", [0.0, 1.0])
    def test_audio_bit_identical(self, rng, temperature):
        eng, cfg = _audio_engine(jax.random.fold_in(rng, 2))
        prompt = jax.random.randint(rng, (2, 3, cfg.n_codebooks), 0,
                                    cfg.vocab)
        (t_s, aux_s), (t_h, aux_h) = _both(eng, prompt, 4, rng,
                                           temperature=temperature)
        assert t_s.shape == (2, 4, cfg.n_codebooks)
        np.testing.assert_array_equal(np.asarray(t_s), np.asarray(t_h))
        np.testing.assert_array_equal(np.asarray(aux_s["log_z"]),
                                      np.asarray(aux_h["log_z"]))

    def test_exact_backend_parity(self, rng):
        eng, cfg = _text_engine(jax.random.fold_in(rng, 3), method="exact",
                                temperature_vocab=512)
        prompt = jax.random.randint(rng, (1, 2), 0, cfg.vocab)
        (t_s, _), (t_h, _) = _both(eng, prompt, 6, rng)
        np.testing.assert_array_equal(np.asarray(t_s), np.asarray(t_h))

    def test_single_token_generation(self, rng):
        """n_tokens == 1: only the last replay step emits."""
        eng, cfg = _text_engine(jax.random.fold_in(rng, 4))
        prompt = jax.random.randint(rng, (2, 4), 0, cfg.vocab)
        t_s = generate(eng, prompt, 1, rng)
        t_h = generate(eng, prompt, 1, rng, host_loop=True)
        assert t_s.shape == (2, 1)
        np.testing.assert_array_equal(np.asarray(t_s), np.asarray(t_h))

    def test_default_path_returns_tokens_only(self, rng):
        eng, cfg = _text_engine(jax.random.fold_in(rng, 5))
        prompt = jax.random.randint(rng, (1, 2), 0, cfg.vocab)
        toks = generate(eng, prompt, 3, rng)
        assert isinstance(toks, jax.Array)
        assert toks.shape == (1, 3)

    def test_compiled_runner_is_cached_per_engine(self, rng):
        """Repeated generate() calls with the same shapes must reuse ONE
        compiled scan (a fresh inner jit per call would recompile the whole
        loop every request)."""
        eng, cfg = _text_engine(jax.random.fold_in(rng, 7))
        prompt = jax.random.randint(rng, (2, 3), 0, cfg.vocab)
        t0 = generate(eng, prompt, 4, rng)
        assert len(eng._scan_runners) == 1
        t1 = generate(eng, prompt, 4, jax.random.fold_in(rng, 1))
        assert len(eng._scan_runners) == 1
        np.testing.assert_array_equal(
            np.asarray(generate(eng, prompt, 4, rng)), np.asarray(t0))
        del t1


class TestReplayBucketing:
    """The scan runner buckets the replay length to the next power of two:
    heterogeneous prompt lengths share ONE compiled scan per bucket (the
    seed compiled per exact length), the padded trailing steps are
    discarded, and the emitted window is cut with a traced slice — so
    bucketing must be invisible in the outputs."""

    def test_lengths_in_one_bucket_share_one_runner(self, rng):
        eng, cfg = _text_engine(jax.random.fold_in(rng, 9))
        for t_replay in (3, 4):                      # both bucket to 4
            prompt = jax.random.randint(rng, (2, t_replay), 0, cfg.vocab)
            generate(eng, prompt, 4, rng)
        assert len(eng._scan_runners) == 1
        run = next(iter(eng._scan_runners.values()))
        if hasattr(run, "_cache_size"):              # one XLA executable
            assert run._cache_size() == 1

    @pytest.mark.parametrize("t_replay", [3, 5, 6])
    def test_non_pow2_lengths_bit_identical_to_host(self, rng, t_replay):
        """Pad replay steps + traced output slice == the unpadded host
        loop, for lengths below / between power-of-two buckets."""
        eng, cfg = _text_engine(jax.random.fold_in(rng, 10))
        prompt = jax.random.randint(rng, (2, t_replay), 0, cfg.vocab)
        (t_s, aux_s), (t_h, aux_h) = _both(eng, prompt, 4, rng,
                                           temperature=0.7)
        np.testing.assert_array_equal(np.asarray(t_s), np.asarray(t_h))
        np.testing.assert_array_equal(np.asarray(aux_s["log_z"]),
                                      np.asarray(aux_h["log_z"]))


class TestEmptyPromptGuard:
    @pytest.mark.parametrize("host_loop", [False, True])
    def test_empty_prompt_raises_value_error(self, rng, host_loop):
        """Seed regression: prompt.shape[1] == 0 crashed the host loop with
        UnboundLocalError (``out`` read before assignment)."""
        eng, cfg = _text_engine(jax.random.fold_in(rng, 6))
        empty = jnp.zeros((2, 0), jnp.int32)
        with pytest.raises(ValueError, match="non-empty prompt"):
            generate(eng, empty, 4, rng, host_loop=host_loop)

    @pytest.mark.parametrize("host_loop", [False, True])
    def test_zero_tokens_raises(self, rng, host_loop):
        """n_tokens == 0 would silently return one token (the last replay
        step's sample); both paths must refuse instead."""
        eng, cfg = _text_engine(jax.random.fold_in(rng, 8))
        prompt = jax.random.randint(rng, (1, 2), 0, cfg.vocab)
        with pytest.raises(ValueError, match="n_tokens"):
            generate(eng, prompt, 0, rng, host_loop=host_loop)
