"""Chaos suite: overload policy + fault-injection harness (DESIGN.md SS14).

The blast-radius contract under test: with any single injector active,
every NON-injected request completes with tokens bit-identical to the
fault-free run, nothing recompiles after warmup (fault masks are traced
data; tier steps compile once each), and no NaN/Inf ever reaches an
emitted log_prob / log_z. Overload policy: bounded queues shed instead of
stalling, deadlines evict instead of hogging, degradation walks the tier
ladder with hysteresis instead of flapping.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ServingConfig, reduced_config
from repro.core.decode import (HEALTH_NONFINITE_Z, apply_health_guard,
                               exact_topk_decode, mimps_decode)
from repro.models import Model
from repro.serve import (AdmissionFault, CorruptIndexFault, Engine,
                         InfLogitsFault, NanLogitsFault, Request, Scheduler,
                         Server, StepFault, generate, trace_arrivals)


@pytest.fixture(scope="module")
def served(rng):
    """One shared engine (mimps, IVF engaged) for the whole module."""
    cfg = reduced_config("qwen1.5-4b")
    cfg = dataclasses.replace(
        cfg, vocab=1024, partition=dataclasses.replace(
            cfg.partition, method="mimps", block_rows=64, n_probe=4, l=64))
    m = Model(cfg)
    eng = Engine(m, m.init(jax.random.fold_in(rng, 42)), max_len=24)
    return eng, cfg


def _requests(cfg, rng, n=3, budget=4):
    mk = lambda i, ln: np.asarray(
        jax.random.randint(jax.random.fold_in(rng, 300 + i), (ln,), 0,
                           cfg.vocab), np.int32)
    return [Request(prompt=mk(i, 2 + i % 3), max_new_tokens=budget,
                    key=jax.random.fold_in(rng, 400 + i),
                    temperature=0.0 if i % 2 else 0.7)
            for i in range(n)]


def _tokens_by_id(rep):
    return {c.request.req_id: c.tokens for c in rep.completions}


def _baseline(eng, rng, reqs, **run_kw):
    """Fault-free oracle: same requests, same scheduler key, no injector."""
    server = Server(Scheduler(eng, n_slots=3, key=rng))
    for r in reqs:
        server.submit(r)
    return server.run(**run_kw)


def _assert_all_finite(rep):
    for c in rep.completions:
        assert np.all(np.isfinite(c.log_probs)), c.request.req_id
        assert np.all(np.isfinite(c.log_zs)), c.request.req_id


class TestHealthGuardUnit:
    def test_identity_when_healthy_and_exact_when_flagged(self, served,
                                                          rng):
        """Healthy rows pass bit-unchanged; flagged rows get the exact
        decode's outputs (fallback equivalence vs the exact backend)."""
        eng, cfg = served
        pc = cfg.partition
        h = 0.1 * jax.random.normal(rng, (4, cfg.d_model)).astype(cfg.dtype)
        w = eng.state.w
        out = mimps_decode(eng.state.index, h, rng, n_probe=pc.n_probe,
                           l=pc.l, k=pc.sample_k, use_pallas=False)
        guarded, flags = apply_health_guard(out, w, h, pc.sample_k)
        assert np.all(np.asarray(flags) == 0)
        for a, b in zip(guarded, out):
            if b is not None:
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # poison two rows; the guard must splice in the exact path there
        bad = out._replace(
            log_z=out.log_z.at[1].set(jnp.nan).at[3].set(jnp.inf))
        guarded, flags = apply_health_guard(bad, w, h, pc.sample_k)
        flags = np.asarray(flags)
        assert flags[1] & HEALTH_NONFINITE_Z and flags[3] & HEALTH_NONFINITE_Z
        assert flags[0] == flags[2] == 0
        ex = exact_topk_decode(w, h, k=pc.sample_k, use_pallas=False)
        for row in (1, 3):
            assert np.isfinite(float(guarded.log_z[row]))
            np.testing.assert_array_equal(np.asarray(guarded.log_z[row]),
                                          np.asarray(ex.log_z[row]))
            np.testing.assert_array_equal(np.asarray(guarded.top_id[row]),
                                          np.asarray(ex.top_id[row]))
        for row in (0, 2):   # untouched rows keep estimator outputs
            np.testing.assert_array_equal(np.asarray(guarded.log_z[row]),
                                          np.asarray(out.log_z[row]))

    def test_active_mask_suppresses_padded_lanes(self, served, rng):
        eng, cfg = served
        pc = cfg.partition
        h = 0.1 * jax.random.normal(rng, (3, cfg.d_model)).astype(cfg.dtype)
        out = mimps_decode(eng.state.index, h, rng,
                           n_probe=pc.n_probe, l=pc.l, k=pc.sample_k,
                           use_pallas=False)
        bad = out._replace(log_z=out.log_z.at[2].set(jnp.nan))
        active = jnp.asarray([True, True, False])
        guarded, flags = apply_health_guard(bad, eng.state.w, h,
                                            pc.sample_k, active=active)
        assert np.all(np.asarray(flags) == 0)   # padded lane doesn't count
        # and the padded lane's garbage passes through untouched (identity)
        assert not np.isfinite(float(guarded.log_z[2]))

    def test_mince_solver_residual_diagnostic(self):
        # the non-convergence check for the iterative MINCE paths: ~0 at a
        # converged root, large away from it, non-finite on corrupted stats
        from repro.core.mince import (MinceStats, solve_from_stats,
                                      solver_residual)
        stats = MinceStats(a_data=jnp.zeros(2),
                           w_data=jnp.asarray([1.0, 0.0]),
                           a_noise=jnp.zeros(2),
                           w_noise=jnp.asarray([1.0, 0.0]),
                           lo=jnp.float32(-20.0), hi=jnp.float32(20.0))
        theta = solve_from_stats(stats, jnp.float32(5.0))
        assert float(solver_residual(theta, stats)) < 1e-5
        assert float(solver_residual(theta + 3.0, stats)) > 1e-2
        bad = stats._replace(w_data=jnp.asarray([jnp.nan, 0.0]))
        assert not bool(jnp.isfinite(solver_residual(theta, bad)))


class TestLaneFaultInjection:
    @pytest.mark.parametrize("fault_cls", [NanLogitsFault, InfLogitsFault])
    def test_injected_lane_contained_neighbors_bit_identical(
            self, served, rng, fault_cls):
        """Acceptance: injector active -> every non-injected request
        bit-identical to the fault-free run, zero recompiles, all emitted
        outputs finite (the guard caught the corruption in-step)."""
        eng, cfg = served
        reqs = _requests(cfg, rng)
        base = _tokens_by_id(_baseline(eng, rng, reqs))
        victim = reqs[1]
        inj = fault_cls([victim.req_id], steps=range(1, 20))
        sched = Scheduler(eng, n_slots=3, key=rng, injector=inj)
        server = Server(sched)
        for r in reqs:
            server.submit(r)
        rep = server.run()
        got = _tokens_by_id(rep)
        assert len(got) == len(reqs)
        for r in reqs:
            if r.req_id != victim.req_id:
                assert got[r.req_id] == base[r.req_id], \
                    "fault leaked into a non-injected lane"
        assert len(got[victim.req_id]) == victim.max_new_tokens
        _assert_all_finite(rep)                  # guard caught every NaN/Inf
        assert rep.health["flagged"] > 0
        assert rep.health["nonfinite_z"] > 0
        assert sched.step_traces == 1, "fault masks must be traced data"
        assert sched.admit_traces == 1


class TestIndexCorruption:
    @pytest.mark.parametrize("mode", ["zero", "permute", "drift"])
    def test_verify_restore_makes_all_requests_bit_identical(
            self, served, rng, mode):
        """A corrupted retrieval state (bad swap / bit-rot) is caught by the
        digest BEFORE any step consumes it; the deterministic rebuild makes
        EVERY request — not just neighbors — bit-identical to fault-free.
        'permute' is the case a position-blind checksum would miss."""
        eng, cfg = served
        reqs = _requests(cfg, rng, n=3, budget=5)
        base = _tokens_by_id(_baseline(eng, rng, reqs))
        inj = CorruptIndexFault(at_step=3, mode=mode, n_blocks=2, seed=7)
        sched = Scheduler(eng, n_slots=3, key=rng, injector=inj)
        server = Server(sched, ServingConfig(verify_index_every=1))
        for r in reqs:
            server.submit(r)
        rep = server.run()
        assert inj.fired
        assert rep.index_restores >= 1, "digest failed to catch corruption"
        got = _tokens_by_id(rep)
        for r in reqs:
            assert got[r.req_id] == base[r.req_id], \
                f"{mode}-corruption survived the restore"
        _assert_all_finite(rep)
        assert sched.step_traces == 1, "restore must reuse the executable"

    def test_digest_is_permutation_sensitive(self, served):
        """Unit pin for the checksum itself: swapping two IVF blocks must
        change the digest even though every value is preserved."""
        from repro.serve.engine import _digest
        eng, _ = served
        vb = np.array(eng.state.index.v_blocks)
        ref = _digest(jnp.asarray(vb))
        swapped = vb.copy()
        swapped[[0, 1]] = swapped[[1, 0]]
        assert _digest(jnp.asarray(swapped)) != ref
        assert _digest(jnp.asarray(vb)) == ref   # deterministic recompute


class TestHostFaults:
    def test_admission_fault_rejects_cleanly(self, served, rng):
        eng, cfg = served
        reqs = _requests(cfg, rng)
        base = _tokens_by_id(_baseline(eng, rng, reqs))
        victim = reqs[0]
        sched = Scheduler(eng, n_slots=3, key=rng,
                          injector=AdmissionFault([victim.req_id]))
        server = Server(sched)
        for r in reqs:
            server.submit(r)
        rep = server.run()
        by_id = {c.request.req_id: c for c in rep.completions}
        assert by_id[victim.req_id].reason == "fault_injected"
        assert by_id[victim.req_id].tokens == []
        assert rep.rejects_by_reason == {"fault_injected": 1}
        for r in reqs[1:]:
            assert by_id[r.req_id].tokens == base[r.req_id]

    def test_step_fault_retried_without_advancing_clock(self, served, rng):
        """A transient step-boundary exception is counted + retried; the
        table never advanced, so every request stays bit-identical."""
        eng, cfg = served
        reqs = _requests(cfg, rng)
        base = _tokens_by_id(_baseline(eng, rng, reqs))
        sched = Scheduler(eng, n_slots=3, key=rng,
                          injector=StepFault([1, 3, 4]))
        server = Server(sched)
        for r in reqs:
            server.submit(r)
        rep = server.run()
        assert rep.step_faults == 3
        got = _tokens_by_id(rep)
        for r in reqs:
            assert got[r.req_id] == base[r.req_id]


class TestDeadlines:
    def test_queue_expiry_sheds_with_reason(self, served, rng):
        """One slot, impatient requests: whoever can't be admitted before
        its deadline is shed at the admission boundary — accounting always
        balances (every submitted request resolves exactly once)."""
        eng, cfg = served
        reqs = _requests(cfg, rng, n=4, budget=6)
        sched = Scheduler(eng, n_slots=1, key=rng)
        server = Server(sched, ServingConfig(default_deadline=10))
        for r in reqs:
            server.submit(r)
        rep = server.run()
        assert len(rep.completions) == len(reqs)
        shed = [c for c in rep.completions if c.reason == "deadline_queue"]
        done = [c for c in rep.completions if c.error is None]
        assert shed and done
        assert all(c.tokens == [] for c in shed)
        assert rep.rejects_by_reason["deadline_queue"] == len(shed)
        # shed requests' queue wait is recorded too (satellite fix)
        assert rep.queue_wait_steps_mean > 0

    def test_mid_decode_eviction_leaves_neighbors_bit_identical(
            self, served, rng):
        """A lane evicted mid-decode recycles through the normal finished
        path; the surviving lane's tokens are unchanged bit-for-bit and the
        evicted lane keeps the partial prefix it already emitted."""
        eng, cfg = served
        keep = Request(prompt=[5, 9, 2], max_new_tokens=6,
                       key=jax.random.fold_in(rng, 77), temperature=0.6)
        evicted = Request(prompt=[8, 1], max_new_tokens=12, deadline=6,
                          key=jax.random.fold_in(rng, 78), temperature=0.3)
        solo_keep = [int(t) for t in np.asarray(generate(
            eng, jnp.asarray(keep.prompt)[None], keep.max_new_tokens,
            keep.key, temperature=keep.temperature))[0]]
        solo_evicted = [int(t) for t in np.asarray(generate(
            eng, jnp.asarray(evicted.prompt)[None],
            evicted.max_new_tokens, evicted.key,
            temperature=evicted.temperature))[0]]
        server = Server(Scheduler(eng, n_slots=2, key=rng))
        server.submit(keep)
        server.submit(evicted)
        rep = server.run()
        by_id = {c.request.req_id: c for c in rep.completions}
        assert by_id[keep.req_id].tokens == solo_keep
        assert by_id[keep.req_id].error is None
        ev = by_id[evicted.req_id]
        assert ev.reason == "deadline_evicted"
        assert 0 < len(ev.tokens) < evicted.max_new_tokens
        # partial output is a PREFIX of what the request would have said —
        # eviction truncates, it never rewrites
        assert ev.tokens == solo_evicted[:len(ev.tokens)]
        assert rep.rejects_by_reason == {"deadline_evicted": 1}


class TestBackpressure:
    def test_bounded_queue_sheds_at_the_door(self, served, rng):
        eng, cfg = served
        reqs = _requests(cfg, rng, n=6, budget=3)
        sched = Scheduler(eng, n_slots=1, key=rng)
        server = Server(sched, ServingConfig(max_queue=2))
        for r in reqs:
            server.submit(r)
        rep = server.run()
        assert len(rep.completions) == len(reqs)
        # 2 fit the queue at the door (the slot drains them later); the
        # other 4 shed immediately — bounded backlog, not unbounded wait
        assert rep.rejects_by_reason.get("queue_full", 0) == 4
        assert rep.queue_depth_peak <= 2
        assert 0 < rep.shed_rate < 1
        served_ok = [c for c in rep.completions if c.error is None]
        assert len(served_ok) == 2

    def test_max_steps_flushes_stranded_work(self, served, rng):
        """Satellite fix: hitting max_steps used to strand queued and
        in-flight requests silently; now everything resolves as an errored
        'server_stopped' completion and the table is left clean."""
        eng, cfg = served
        reqs = _requests(cfg, rng, n=4, budget=6)
        sched = Scheduler(eng, n_slots=2, key=rng)
        server = Server(sched)
        for r in reqs:
            server.submit(r)
        rep = server.run(max_steps=3)
        assert len(rep.completions) == len(reqs)
        stopped = [c for c in rep.completions
                   if c.reason == "server_stopped"]
        assert stopped, "stranded requests must be flushed, not dropped"
        assert sched.n_in_flight == 0
        assert sched.n_free == 2
        # the flushed lanes' partial work is kept
        assert rep.rejects_by_reason["server_stopped"] == len(stopped)


class TestDegradation:
    def test_ladder_walks_down_under_pressure_and_back_with_hysteresis(
            self, served, rng):
        """Sustained queue pressure steps the tier down (mimps -> topk);
        drained pressure steps back up only after the calm debounce. The
        monotone drain must produce a unimodal tier path — any down-move
        after an up-move is flapping, which the hysteresis band forbids.
        Each tier's step compiles exactly once."""
        eng, cfg = served
        long_req = Request(prompt=[3, 4], max_new_tokens=20,
                           key=jax.random.fold_in(rng, 501))
        shorts = _requests(cfg, rng, n=6, budget=2)
        sched = Scheduler(eng, n_slots=2, key=rng)
        server = Server(sched, ServingConfig(
            degrade_high=3, degrade_low=1, degrade_after=2,
            restore_after=4))
        assert server.ladder == ("mimps", "topk")
        server.submit(long_req)
        for r in shorts:
            server.submit(r)
        rep = server.run()
        assert len(rep.completions) == len(shorts) + 1
        assert rep.tier_transitions, "pressure never engaged the ladder"
        ladder_ix = [server.ladder.index(t) for _, t in rep.tier_transitions]
        went_up = False
        for prev, cur in zip([0] + ladder_ix, ladder_ix):
            if cur < prev:
                went_up = True
            elif went_up:
                pytest.fail(f"tier flapped: {rep.tier_transitions}")
        assert rep.tokens_by_tier.get("topk", 0) > 0
        assert rep.degraded_token_frac > 0
        # the audit trail: some completion recorded serving below the top
        # tier
        assert any("topk" in c.tiers for c in rep.completions
                   if c.error is None)
        _assert_all_finite(rep)
        # zero-recompile across the whole ladder: one compile per tier
        assert all(v == 1 for v in sched.traces_by_tier.values()), \
            sched.traces_by_tier
        assert rep.index_restores == 0

    def test_disabled_by_default(self, served, rng):
        eng, cfg = served
        reqs = _requests(cfg, rng, n=5, budget=2)
        sched = Scheduler(eng, n_slots=1, key=rng)
        server = Server(sched)   # default config: no watermarks
        for r in reqs:
            server.submit(r)
        rep = server.run()
        assert rep.tier_transitions == []
        assert rep.degraded_token_frac == 0.0
        assert set(rep.tokens_by_tier) == {"mimps"}
