"""Raw-speed serving: estimator-speculative decoding + shared-prefix KV
cache (DESIGN.md SS16).

The contract under test: both accelerations are INVISIBLE in the tokens —
a lane decoded speculatively (cheap registry draft proposes k tokens, the
lane's serving tier verifies them in one batched pass) or admitted on top
of cached prefix blocks emits bit-identical tokens to the same request
run alone through ``generate()`` — while the accepted-token count is
traced data (variable per-lane advance, zero recompiles after warmup),
the prefix pool ref-counts/evicts on the host with one compiled load and
one compiled save, a health-flagged draft collapses that lane to
non-speculative decode for the round, and admission lookahead never
starves a held request past its deadline.
"""
import dataclasses
import os
import subprocess
import sys
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hyp_fallback import given, settings, st

from repro.configs import ServingConfig, reduced_config
from repro.models import Model
from repro.serve import (Engine, NanLogitsFault, Request, Scheduler, Server,
                         generate, trace_arrivals)
from repro.serve.prefix_cache import PrefixPool, cache_is_kv_only
from repro.serve.scheduler import spec_accept

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def served(rng):
    """One shared engine (mimps, IVF engaged) for the whole module."""
    cfg = reduced_config("qwen1.5-4b")
    cfg = dataclasses.replace(
        cfg, vocab=1024, partition=dataclasses.replace(
            cfg.partition, method="mimps", block_rows=64, n_probe=4, l=64))
    m = Model(cfg)
    eng = Engine(m, m.init(jax.random.fold_in(rng, 42)), max_len=24)
    return eng, cfg


def _solo(eng, prompt, n, key, temperature=0.0):
    toks = generate(eng, jnp.asarray(prompt)[None], n, key,
                    temperature=temperature)
    return [int(t) for t in np.asarray(toks)[0]]


def _mixed_requests(cfg, rng, base=100):
    mk = lambda i, n: np.asarray(
        jax.random.randint(jax.random.fold_in(rng, base + i), (n,), 0,
                           cfg.vocab), np.int32)
    return [
        Request(prompt=mk(0, 3), max_new_tokens=5,
                key=jax.random.fold_in(rng, 7), temperature=0.0),
        Request(prompt=mk(1, 6), max_new_tokens=4,
                key=jax.random.fold_in(rng, 8), temperature=0.9),
        Request(prompt=mk(2, 4), max_new_tokens=6,
                key=jax.random.fold_in(rng, 9), temperature=0.5),
    ]


def _tokens_by_id(rep):
    return {c.request.req_id: c.tokens for c in rep.completions}


# ---------------------------------------------------------------------------
# speculative decoding: bit-exactness + compile stability
# ---------------------------------------------------------------------------

class TestSpecParity:
    @pytest.mark.parametrize("spec_k,draft", [(2, "topk"), (4, "topk"),
                                              (2, "fmbe")])
    def test_spec_bit_identical_to_solo(self, served, rng, spec_k, draft):
        """Acceptance: greedy AND temperature lanes emit the exact solo
        token stream at spec_k in {2, 4} — acceptance only decides how
        many verified positions land per round, never which token."""
        eng, cfg = served
        reqs = _mixed_requests(cfg, rng)
        solo = [_solo(eng, r.prompt, r.max_new_tokens, r.key,
                      r.temperature) for r in reqs]
        sched = Scheduler(eng, n_slots=4, key=rng, spec_draft=draft,
                          spec_k=spec_k)
        server = Server(sched)
        for r in reqs:
            server.submit(r)
        rep = server.run()
        got = _tokens_by_id(rep)
        for r, want in zip(reqs, solo):
            assert got[r.req_id] == want
        assert 0.0 < rep.spec_acceptance <= 1.0
        assert rep.spec_accepted <= rep.spec_proposed
        assert sched.step_traces == 1
        assert sched.admit_traces == 1

    def test_zero_recompiles_under_variable_acceptance(self, served, rng):
        """Pinned acceptance criterion: per-lane accepted counts vary
        round to round (temperature lanes reject at different depths) and
        across two traffic waves — all of it is traced data through ONE
        executable."""
        eng, cfg = served
        sched = Scheduler(eng, n_slots=3, key=rng, spec_draft="topk",
                          spec_k=4)
        server = Server(sched)
        server.submit(Request(prompt=[5, 7], max_new_tokens=2, key=1))
        server.run()
        assert sched.step_traces == 1 and sched.admit_traces == 1
        for base in (100, 300):
            reqs = _mixed_requests(cfg, rng, base=base) + [
                Request(prompt=[3], max_new_tokens=7, key=2,
                        temperature=2.0),
                Request(prompt=list(range(8)), max_new_tokens=1, key=3),
            ]
            rep = Server(sched).run(
                arrivals=trace_arrivals(reqs, [0, 0, 1, 2, 4]))
            assert len(rep.completions) == len(reqs)
            assert 0.0 < rep.spec_acceptance <= 1.0
        assert sched.step_traces == 1, "variable acceptance recompiled"
        assert sched.admit_traces == 1

    def test_spec_with_prefix_cache_warm_rerun_parity(self, served, rng):
        """Speculation + prefix cache composed: the warm second wave hits
        the pool (saving replay steps) and still matches solo bit-for-bit
        — cached KV rows are bit-identical to replayed rows."""
        eng, cfg = served
        reqs = _mixed_requests(cfg, rng)
        solo = [_solo(eng, r.prompt, r.max_new_tokens, r.key,
                      r.temperature) for r in reqs]
        sched = Scheduler(eng, n_slots=4, key=rng, spec_draft="topk",
                          spec_k=2, prefix_cache_blocks=8,
                          prefix_block_tokens=2)
        server = Server(sched)
        for r in reqs:
            server.submit(r)
        rep1 = server.run()
        for r, want in zip(reqs, solo):
            assert _tokens_by_id(rep1)[r.req_id] == want
        reqs2 = _mixed_requests(cfg, rng)      # same prompts, fresh ids
        for r in reqs2:
            server.submit(r)
        rep2 = server.run()
        got = _tokens_by_id(rep2)
        for r, want in zip(reqs2, solo):
            assert got[r.req_id] == want
        assert rep2.prefix["hits"] > 0
        assert rep2.prefix["saved_steps"] > 0
        assert rep2.steps < rep1.steps, "cache hits must shorten replay"
        assert sched.step_traces == 1
        assert sched.prefix.load_traces == 1
        assert sched.prefix.save_traces == 1

    def test_deadline_eviction_mid_speculation(self, served, rng):
        """Satellite 3 (integration half): a lane evicted mid-speculation
        leaves the surviving lane bit-identical, keeps a PREFIX of its own
        stream, and the slot table comes back clean (positions/budget/
        finished invariants intact — every lane recycled)."""
        eng, cfg = served
        keep = Request(prompt=[5, 9, 2], max_new_tokens=6,
                       key=jax.random.fold_in(rng, 77), temperature=0.6)
        evicted = Request(prompt=[8, 1], max_new_tokens=12, deadline=4,
                          key=jax.random.fold_in(rng, 78), temperature=0.3)
        solo_keep = _solo(eng, keep.prompt, keep.max_new_tokens, keep.key,
                          keep.temperature)
        solo_evicted = _solo(eng, evicted.prompt, evicted.max_new_tokens,
                             evicted.key, evicted.temperature)
        sched = Scheduler(eng, n_slots=2, key=rng, spec_draft="topk",
                          spec_k=4)
        server = Server(sched)
        server.submit(keep)
        server.submit(evicted)
        rep = server.run()
        by_id = {c.request.req_id: c for c in rep.completions}
        assert by_id[keep.req_id].tokens == solo_keep
        assert by_id[keep.req_id].error is None
        ev = by_id[evicted.req_id]
        assert ev.reason == "deadline_evicted"
        assert 0 < len(ev.tokens) < evicted.max_new_tokens
        assert ev.tokens == solo_evicted[:len(ev.tokens)]
        # table invariants: every lane recycled, positions inside capacity
        assert sched.n_free == 2
        assert np.all(np.asarray(sched.table.t_stream) <= eng.max_len)
        assert np.all(np.asarray(sched.table.budget) >= 0)

    def test_spec_composes_with_degradation_ladder(self, served, rng):
        """The tier walk swaps the VERIFIER, not the protocol: each tier's
        spec step compiles once, acceptance is tracked per tier, and no
        recompile happens across transitions."""
        eng, cfg = served
        long_req = Request(prompt=[3, 4], max_new_tokens=20,
                           key=jax.random.fold_in(rng, 501))
        shorts = _mixed_requests(cfg, rng) + _mixed_requests(cfg, rng, 200)
        sched = Scheduler(eng, n_slots=2, key=rng, spec_draft="topk",
                          spec_k=4)
        server = Server(sched, ServingConfig(
            degrade_high=3, degrade_low=1, degrade_after=2,
            restore_after=4))
        server.submit(long_req)
        for r in shorts:
            server.submit(r)
        rep = server.run()
        assert len(rep.completions) == len(shorts) + 1
        assert rep.tier_transitions, "pressure never engaged the ladder"
        assert all(v == 1 for v in sched.traces_by_tier.values()), \
            sched.traces_by_tier
        assert rep.spec_acceptance_by_tier
        for tier, acc in rep.spec_acceptance_by_tier.items():
            assert 0.0 < acc <= 1.0, (tier, acc)
        for c in rep.completions:
            assert np.all(np.isfinite(c.log_probs)), c.request.req_id


class TestSpecChaos:
    def test_nan_draft_falls_back_per_lane(self, served, rng):
        """Chaos acceptance: NaN logits in the DRAFT pass are caught by
        the health guard; the flagged lane collapses to a = 1 (literally
        non-speculative decode for that round) while every other lane
        stays bit-identical to the fault-free run. Nothing recompiles —
        the fault mask and the collapse are traced data."""
        eng, cfg = served
        reqs = _mixed_requests(cfg, rng)
        base_server = Server(Scheduler(eng, n_slots=3, key=rng,
                                       spec_draft="topk", spec_k=4))
        for r in reqs:
            base_server.submit(r)
        base = _tokens_by_id(base_server.run())
        victim = reqs[1]
        reqs2 = _mixed_requests(cfg, rng)
        inj = NanLogitsFault([reqs2[1].req_id], steps=range(1, 20))
        sched = Scheduler(eng, n_slots=3, key=rng, spec_draft="topk",
                          spec_k=4, injector=inj)
        server = Server(sched)
        for r in reqs2:
            server.submit(r)
        rep = server.run()
        got = _tokens_by_id(rep)
        for r, r0 in zip(reqs2, reqs):
            if r.req_id != reqs2[1].req_id:
                assert got[r.req_id] == base[r0.req_id], \
                    "draft fault leaked into a non-injected lane"
        assert len(got[reqs2[1].req_id]) == victim.max_new_tokens
        assert rep.draft_flagged > 0, \
            "the draft health guard never saw the NaN"
        for c in rep.completions:
            assert np.all(np.isfinite(c.log_probs)), c.request.req_id
            assert np.all(np.isfinite(c.log_zs)), c.request.req_id
        assert sched.step_traces == 1
        assert sched.admit_traces == 1


# ---------------------------------------------------------------------------
# the accepted-count algebra (satellite 3, property half)
# ---------------------------------------------------------------------------

class TestSpecAcceptProperty:
    MAX_LEN = 24
    K = 4

    @settings(max_examples=200)
    @given(st.integers(1, 4),        # n_ok (position 0 forced correct)
           st.integers(0, 24),       # t_stream
           st.integers(1, 24),       # t_replay
           st.integers(1, 8),        # budget (active lanes have budget >= 1)
           st.integers(0, 1),        # active
           st.integers(0, 1))        # draft_bad
    def test_accept_invariants(self, n_ok, t_stream, t_replay, budget,
                               active, draft_bad):
        """For ANY accepted-length pattern: inactive lanes advance 0;
        active lanes advance 1..k; emissions never exceed budget; the
        stream never runs past KV capacity (+1 overflow finish); a flagged
        draft collapses to exactly the non-speculative advance of 1."""
        k, max_len = self.K, self.MAX_LEN
        a = int(spec_accept(
            jnp.int32(n_ok), jnp.int32(t_stream), jnp.int32(t_replay),
            jnp.int32(budget), jnp.bool_(bool(active)),
            jnp.bool_(bool(draft_bad)), max_len, k))
        if not active:
            assert a == 0
            return
        assert 1 <= a <= k
        assert a <= n_ok or draft_bad or a == 1
        # emitted = accepted minus the replay positions covered this round
        r = min(max(t_replay - 1 - t_stream, 0), k)
        assert max(0, a - r) <= budget
        # never past capacity (equality at max_len -> the overflow finish;
        # a lane AT capacity still advances 1 and flags overflow)
        assert t_stream + a <= max_len or \
            (t_stream >= max_len and a == 1)
        if draft_bad:
            assert a == 1

    def test_vectorized_matches_scalar(self):
        """The traced call site is vectorized over lanes; it must agree
        with the per-lane scalar evaluation element-wise."""
        rng = np.random.default_rng(0)
        n = 64
        n_ok = rng.integers(1, 5, n)
        t_stream = rng.integers(0, 25, n)
        t_replay = rng.integers(1, 25, n)
        budget = rng.integers(1, 9, n)
        active = rng.integers(0, 2, n).astype(bool)
        bad = rng.integers(0, 2, n).astype(bool)
        vec = np.asarray(spec_accept(
            jnp.asarray(n_ok, jnp.int32), jnp.asarray(t_stream, jnp.int32),
            jnp.asarray(t_replay, jnp.int32), jnp.asarray(budget, jnp.int32),
            jnp.asarray(active), jnp.asarray(bad), self.MAX_LEN, self.K))
        for i in range(n):
            got = int(spec_accept(
                jnp.int32(n_ok[i]), jnp.int32(t_stream[i]),
                jnp.int32(t_replay[i]), jnp.int32(budget[i]),
                jnp.bool_(active[i]), jnp.bool_(bad[i]),
                self.MAX_LEN, self.K))
            assert got == int(vec[i]), i


# ---------------------------------------------------------------------------
# prefix pool host structure (trie / refcount / LRU)
# ---------------------------------------------------------------------------

def _kv(batch=2, t=16, n_kv=1, dh=4, fill=0.0):
    leaf = jnp.full((batch, t, n_kv, dh), fill, jnp.float32)
    return {"layers": [{"k": leaf, "v": leaf + 1.0}]}


class TestPrefixPoolUnit:
    def test_cache_is_kv_only(self):
        assert cache_is_kv_only(_kv())
        bad = {"layers": [{"k": jnp.zeros((2, 16, 1, 4)),
                           "conv": jnp.zeros((2, 16, 1, 4))}]}
        assert not cache_is_kv_only(bad)       # recurrent/conv state leaf
        low_rank = {"layers": [{"k": jnp.zeros((2, 16))}]}
        assert not cache_is_kv_only(low_rank)  # no (batch, pos) window

    def test_match_insert_roundtrip(self):
        pool = PrefixPool(_kv(), n_blocks=4, block_tokens=2,
                          max_match_blocks=4)
        cache = jax.tree.map(
            lambda l: l + jnp.arange(l.shape[-3],
                                     dtype=l.dtype)[None, :, None, None],
            _kv())
        toks = np.asarray([3, 1, 4, 1, 5], np.int32)
        # usable match capped at (p_len-1)//bt = 2 blocks even though the
        # prompt spans 2.5
        assert pool.insert(toks, 5, cache, lane=0) == 2
        m, ids, owner = pool.match(toks, 5)
        assert m == 2 and owner == 0
        # a different tail shares only the first block (trie split)
        toks2 = np.asarray([3, 1, 9, 9, 9], np.int32)
        m2, ids2, _ = pool.match(toks2, 5)
        assert m2 == 1 and ids2[0] == ids[0]
        # loading the match writes the SAME rows replay would produce
        dst = pool.load(_kv(), ids, lane=1)
        src_rows = np.asarray(cache["layers"][0]["k"][0, :4])
        np.testing.assert_array_equal(
            np.asarray(dst["layers"][0]["k"][1, :4]), src_rows)
        assert pool.hits == 1 and pool.saved_steps == 4

    def test_refcounted_eviction_never_orphans_children(self):
        """LRU eviction only takes LEAVES: a parent block with a live
        child is never evicted, so every surviving trie path stays walkable
        root-to-leaf (the refcount invariant)."""
        pool = PrefixPool(_kv(), n_blocks=4, block_tokens=2,
                          max_match_blocks=4)
        cache = _kv(fill=2.0)
        rng = np.random.default_rng(1)
        pool.insert(np.asarray([1, 2, 3, 4, 0], np.int32), 5, cache, 0)
        for i in range(6):    # force eviction churn past the 4-block pool
            toks = rng.integers(0, 100, size=(5,)).astype(np.int32)
            pool.insert(toks, 5, cache, 0)
        assert pool.evictions > 0
        assert pool.n_cached_blocks <= 4
        # invariant: every cached block's parent chain is intact
        for bid, (parent, _) in list(pool._key_of.items()):
            while parent >= 0:
                assert parent in pool._key_of, \
                    f"block {bid} orphaned (parent {parent} evicted)"
                parent = pool._key_of[parent][0]

    def test_insert_on_full_pool_of_protected_blocks_degrades(self):
        """When every block is an ancestor of the path being inserted
        (nothing evictable), insert saves what fits and stops — no raise,
        no corruption."""
        pool = PrefixPool(_kv(t=32), n_blocks=2, block_tokens=2,
                          max_match_blocks=8)
        cache = _kv(t=32, fill=1.0)
        toks = np.arange(10, dtype=np.int32)
        saved = pool.insert(toks, 10, cache, 0)
        assert saved == 2                      # pool capacity, not prompt
        assert pool.n_cached_blocks == 2
        m, _, _ = pool.match(toks, 10)
        assert m == 2

    def test_rejects_non_kv_cache(self):
        bad = {"layers": [{"k": jnp.zeros((2, 16, 1, 4)),
                           "s": jnp.zeros((2, 16, 1, 4))}]}
        with pytest.raises(NotImplementedError, match="KV"):
            PrefixPool(bad, n_blocks=4, block_tokens=2, max_match_blocks=2)


# ---------------------------------------------------------------------------
# admission lookahead (satellite 1)
# ---------------------------------------------------------------------------

class _FakeSched:
    """Just enough scheduler for Server._admit_ready: 2 replicas x 1 free
    lane each; requests carry .want_replica to drive prefix_preview."""
    tier = "mimps"
    verify_index_every = 0
    health_guard = True
    _step_fns = {"mimps": None}    # non-empty: Server must not touch guard

    def __init__(self, free_by_replica):
        self.free_by_replica = dict(free_by_replica)
        self.admitted = []

    @property
    def n_free(self):
        return sum(self.free_by_replica.values())

    def prefix_preview(self, req):
        want = getattr(req, "want_replica", None)
        return (4, want) if want is not None else (0, None)

    def free_in_replica(self, replica):
        return self.free_by_replica.get(replica, 0)

    def admit(self, req, deadline_steps=None):
        self.admitted.append(req.req_id)
        # consume a lane anywhere (preferred if free)
        want = getattr(req, "want_replica", None)
        if want is not None and self.free_by_replica.get(want, 0):
            self.free_by_replica[want] -= 1
            return
        for rep, n in self.free_by_replica.items():
            if n:
                self.free_by_replica[rep] -= 1
                return
        raise ValueError("no free lane")


class TestAdmissionLookahead:
    def _mk(self, want=None, **kw):
        r = Request(prompt=[1, 2, 3, 4], max_new_tokens=2, key=0, **kw)
        r.want_replica = want
        return r

    def test_window_admits_past_blocked_head(self):
        """Head-of-line fix: the queue head prefers full replica 0; with a
        window the next request (fits replica 1) admits THIS pass, the
        head is held in order, and the hold is counted."""
        sched = _FakeSched({0: 0, 1: 1})
        srv = Server(sched, ServingConfig(admit_window=2, admit_hold=8))
        blocked, free = self._mk(want=0), self._mk(want=1)
        srv.submit(blocked)
        srv.submit(free)
        srv._admit_ready()
        assert sched.admitted == [free.req_id]
        assert list(srv.queue) == [blocked]      # held, order preserved
        assert srv.admit_skipped == 1

    def test_strict_fifo_when_window_zero(self):
        """admit_window=0 is byte-identical PR-6 FIFO: the blocked head is
        admitted (anywhere) before anything behind it."""
        sched = _FakeSched({0: 0, 1: 1})
        srv = Server(sched)
        blocked, free = self._mk(want=0), self._mk(want=1)
        srv.submit(blocked)
        srv.submit(free)
        srv._admit_ready()
        assert sched.admitted == [blocked.req_id]
        assert srv.admit_skipped == 0

    def test_hold_count_bounds_starvation(self):
        """After admit_hold holds the request force-admits anywhere —
        forfeiting its cache hit, never starving."""
        srv = None
        sched = _FakeSched({0: 0, 1: 3})
        srv = Server(sched, ServingConfig(admit_window=1, admit_hold=3))
        blocked = self._mk(want=0)
        srv.submit(blocked)
        for i in range(2):
            srv._admit_ready()
            assert blocked.req_id not in sched.admitted
        srv._admit_ready()                       # 3rd pass: starving
        assert blocked.req_id in sched.admitted
        assert srv.admit_skipped == 2

    def test_deadline_near_forces_admission(self):
        """A held request whose deadline is within admit_hold steps
        force-admits immediately — no request starves past
        default_deadline."""
        sched = _FakeSched({0: 0, 1: 2})
        srv = Server(sched, ServingConfig(admit_window=1, admit_hold=8,
                                          default_deadline=5))
        blocked = self._mk(want=0)
        srv.submit(blocked)                      # deadline at step 5 <= 8
        srv._admit_ready()
        assert sched.admitted == [blocked.req_id]
        assert srv.admit_skipped == 0

    def test_lookahead_end_to_end_counts_skips(self, served, rng):
        """Real scheduler path: admit_window on with the pool off is a
        no-op (no owner preference -> pure FIFO), counts stay zero."""
        eng, cfg = served
        reqs = _mixed_requests(cfg, rng)
        sched = Scheduler(eng, n_slots=2, key=rng)
        server = Server(sched, ServingConfig(admit_window=2))
        for r in reqs:
            server.submit(r)
        rep = server.run()
        assert len(rep.completions) == len(reqs)
        assert rep.admit_skipped == 0


# ---------------------------------------------------------------------------
# mesh composition (subprocess: 8 placeholder host devices)
# ---------------------------------------------------------------------------

_MESH_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import reduced_config
from repro.models import Model
from repro.serve import Engine, Request, Scheduler, Server, generate
from repro.launch.mesh import make_serving_mesh

cfg = reduced_config("qwen1.5-4b")
cfg = dataclasses.replace(
    cfg, vocab=512, partition=dataclasses.replace(
        cfg.partition, method="mimps", block_rows=64, n_probe=2, l=32))
m = Model(cfg)
key = jax.random.PRNGKey(0)
params = m.init(jax.random.fold_in(key, 42))

solo_eng = Engine(m, params, max_len=20, key=key)
mk = lambda i, n: np.asarray(jax.random.randint(
    jax.random.fold_in(key, 100 + i), (n,), 0, cfg.vocab), np.int32)
def reqs():
    return [Request(prompt=mk(i, 3 + i % 4), max_new_tokens=3 + i % 3,
                    key=jax.random.fold_in(key, 200 + i),
                    temperature=0.0 if i % 2 == 0 else 0.8)
            for i in range(6)]
want = []
for r in reqs():
    t = generate(solo_eng, jnp.asarray(r.prompt)[None], r.max_new_tokens,
                 r.key, temperature=r.temperature)
    want.append([int(x) for x in np.asarray(t)[0]])

mesh = make_serving_mesh(data=2, model=2)
eng = Engine(m, params, max_len=20, key=key, mesh=mesh)
sched = Scheduler(eng, n_slots=4, key=key, spec_draft="topk", spec_k=4,
                  prefix_cache_blocks=8, prefix_block_tokens=2)
for wave in range(2):
    rs = reqs()
    srv = Server(sched)
    for r in rs:
        srv.submit(r)
    rep = srv.run()
    got = {c.request.req_id: c.tokens for c in rep.completions}
    for r, w in zip(rs, want):
        assert got[r.req_id] == w, (wave, r.req_id, got[r.req_id], w)
assert sched.step_traces == 1, sched.step_traces
assert sched.admit_traces == 1
assert rep.prefix["hits"] > 0, rep.prefix
print("ALL_OK")
"""


class TestMeshSpec:
    def test_mesh_spec_prefix_parity(self):
        """data=2,model=2 mesh + speculation + prefix pool: tokens match
        the single-device solo oracle on both waves, the warm wave hits
        the replica-local pool, zero retraces."""
        r = subprocess.run([sys.executable, "-c", _MESH_SNIPPET],
                           capture_output=True, text=True,
                           env=dict(os.environ, PYTHONPATH="src"),
                           cwd=REPO, timeout=900)
        assert r.returncode == 0 and "ALL_OK" in r.stdout, \
            r.stdout + r.stderr
