"""Deterministic stand-in for `hypothesis` on containers that lack it.

Provides just the surface the test-suite uses — ``given``, ``settings`` and
``strategies.integers`` / ``strategies.floats`` — drawing a fixed number of
pseudo-random examples from a seeded ``random.Random`` so runs are
reproducible. When the real hypothesis is installed the test modules import
it instead (see the try/except at their top).
"""
from __future__ import annotations

import random

_DEFAULT_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rnd: random.Random):
        return self._draw(rnd)


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    @staticmethod
    def floats(min_value, max_value, **_kw):
        return _Strategy(lambda r: r.uniform(min_value, max_value))


st = strategies


def settings(max_examples: int = _DEFAULT_EXAMPLES, **_kw):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(*strats):
    def deco(fn):
        n = getattr(fn, "_max_examples", _DEFAULT_EXAMPLES)

        def wrapper(self):
            rnd = random.Random(0xC0FFEE)
            for _ in range(n):
                fn(self, *(s.example(rnd) for s in strats))

        # NOT functools.wraps: pytest must see the zero-arg signature, or it
        # would try to resolve the hypothesis parameters as fixtures.
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
