"""Observability layer (obs/, DESIGN.md SS17).

The contract under test: the obs layer is a pure OBSERVER of the serving
stack — with observability fully enabled (device metric harvesting, shadow
exact-log-Z sampling, span tracing, exposition) every request's tokens are
bit-identical to the obs-off run, nothing retraces after warmup (the
metric state is always threaded; cadence flags are traced data, so the
executables cannot depend on whether obs is attached), and the telemetry
itself is truthful: the exact tier's shadow rel-err is identically zero,
harvested token counts reconcile with the host report, histogram rows are
cumulative-monotone, and the trace/registry artifacts are well-formed.
Coverage spans solo, ladder-degraded, speculative, and (2,2)-mesh serving
(the mesh case in an 8-virtual-device subprocess).
"""
import dataclasses
import json
import os
import subprocess
import sys
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ServingConfig, reduced_config
from repro.models import Model
from repro.obs import (LATENCY_EDGES_MS, TIERS, MetricsRegistry,
                       Observability, ObsConfig, TraceWriter, hist_quantile)
from repro.serve import Engine, Request, Scheduler, Server, trace_arrivals

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def served(rng):
    """One shared engine (mimps, IVF engaged) for the whole module."""
    cfg = reduced_config("qwen1.5-4b")
    cfg = dataclasses.replace(
        cfg, vocab=1024, partition=dataclasses.replace(
            cfg.partition, method="mimps", block_rows=64, n_probe=4, l=64))
    m = Model(cfg)
    eng = Engine(m, m.init(jax.random.fold_in(rng, 42)), max_len=24)
    return eng, cfg


def _requests(cfg, rng, n=4, budget=4):
    mk = lambda i, ln: np.asarray(
        jax.random.randint(jax.random.fold_in(rng, 800 + i), (ln,), 0,
                           cfg.vocab), np.int32)
    return [Request(prompt=mk(i, 2 + i % 3), max_new_tokens=budget,
                    key=jax.random.fold_in(rng, 900 + i),
                    temperature=0.0 if i % 2 else 0.7)
            for i in range(n)]


def _tokens(rep):
    return {c.request.req_id: c.tokens for c in rep.completions}


def _detach(sched):
    """Undo what Observability.attach set, so a follow-on obs-off run on
    the same scheduler really is obs-off."""
    sched.shadow_every = 0
    sched.engine.obs = None


def _obs(tmp_path, name, **kw):
    kw.setdefault("harvest_every", 2)
    kw.setdefault("shadow_every", 2)
    kw.setdefault("snapshot_every", 1)
    return Observability(ObsConfig(
        trace_path=str(tmp_path / f"{name}.jsonl"), **kw))


class TestBitParityObsOnVsOff:
    """Identical tokens with obs fully on vs off — instrumentation must
    not perturb sampling, in any serving mode."""

    def test_solo(self, served, rng, tmp_path):
        eng, cfg = served
        sched = Scheduler(eng, n_slots=3, key=rng)

        def run(obs):
            reqs = _requests(cfg, rng)
            rep = Server(sched, obs=obs).run(
                arrivals=trace_arrivals(reqs, [0.0] * len(reqs)))
            got = _tokens(rep)
            return [got[r.req_id] for r in reqs]   # positional: fresh ids

        off = run(None)
        obs = _obs(tmp_path, "solo")
        on = run(obs)
        obs.close()
        _detach(sched)
        assert on == off and off
        # and off-after-on: attaching never leaves residue in the scheduler
        assert run(None) == off

    def test_ladder_degraded(self, served, rng, tmp_path):
        eng, cfg = served

        def run(obs):
            sched = Scheduler(eng, n_slots=2, key=rng)
            server = Server(sched, ServingConfig(
                degrade_high=3, degrade_low=1, degrade_after=2,
                restore_after=4), obs=obs)
            reqs = [Request(prompt=[3, 4], max_new_tokens=20,
                            key=jax.random.fold_in(rng, 501))]
            reqs += _requests(cfg, rng, n=6, budget=2)
            for r in reqs:
                server.submit(r)
            rep = server.run()
            assert rep.tier_transitions, "pressure never engaged the ladder"
            got = _tokens(rep)
            return ([got[r.req_id] for r in reqs],
                    list(rep.tier_transitions))

        off, moves_off = run(None)
        obs = _obs(tmp_path, "ladder")
        on, moves_on = run(obs)
        obs.close()
        assert on == off and off
        assert moves_on == moves_off     # same deterministic ladder walk

    def test_speculative(self, served, rng, tmp_path):
        eng, cfg = served

        def run(obs):
            sched = Scheduler(eng, n_slots=3, key=rng, spec_draft="topk",
                              spec_k=3)
            reqs = _requests(cfg, rng, budget=6)
            rep = Server(sched, obs=obs).run(
                arrivals=trace_arrivals(reqs, [0.0] * len(reqs)))
            assert rep.spec_acceptance > 0
            got = _tokens(rep)
            return [got[r.req_id] for r in reqs]

        off = run(None)
        obs = _obs(tmp_path, "spec")
        on = run(obs)
        obs.close()
        assert on == off and off


class TestZeroRecompiles:
    def test_obs_toggling_never_retraces_and_metrics_are_not_keys(
            self, served, rng, tmp_path):
        """After warmup: obs on -> off -> on, plus a metric-state reset,
        all reuse the same executables — MetricState values (and the obs
        cadence) are data, not part of any jit cache key."""
        eng, cfg = served
        sched = Scheduler(eng, n_slots=3, key=rng)
        warm = Server(sched)
        warm.submit(Request(prompt=[5, 7], max_new_tokens=2, key=1))
        warm.run()
        t0, a0 = sched.step_traces, sched.admit_traces

        for mode in ("on", "off", "on"):
            obs = _obs(tmp_path, f"toggle_{mode}") if mode == "on" else None
            if obs is None:
                _detach(sched)
                sched.reset_metrics()   # fresh counters: still no retrace
            reqs = _requests(cfg, rng)
            Server(sched, obs=obs).run(
                arrivals=trace_arrivals(reqs, [0.0] * len(reqs)))
            if obs is not None:
                obs.close()
        _detach(sched)
        assert (sched.step_traces, sched.admit_traces) == (t0, a0)


class TestShadowTelemetry:
    def test_exact_tier_rel_err_identically_zero(self, rng, tmp_path):
        """The shadow oracle recomputes the same expression the exact tier
        serves with — so on the exact tier the live rel-err stream must be
        bitwise zero, with a nonzero sample count (the sanity anchor that
        licenses trusting the stream on estimator tiers)."""
        cfg = reduced_config("qwen1.5-4b")
        cfg = dataclasses.replace(
            cfg, vocab=1024, partition=dataclasses.replace(
                cfg.partition, method="exact", block_rows=64, n_probe=4,
                l=64))
        m = Model(cfg)
        eng = Engine(m, m.init(jax.random.fold_in(rng, 42)), max_len=24)
        sched = Scheduler(eng, n_slots=3, key=rng)
        obs = _obs(tmp_path, "exact", shadow_every=1)
        reqs = _requests(cfg, rng)
        Server(sched, obs=obs).run(
            arrivals=trace_arrivals(reqs, [0.0] * len(reqs)))
        shadow = obs.last_harvest["shadow_by_tier"]["exact"]
        obs.close()
        assert shadow["count"] > 0
        assert shadow["rel_err_mean"] == 0.0
        assert shadow["rel_err_max"] == 0.0

    def test_estimator_tier_rel_err_finite_and_tokens_reconcile(
            self, served, rng, tmp_path):
        eng, cfg = served
        sched = Scheduler(eng, n_slots=3, key=rng)
        sched.reset_metrics()
        obs = _obs(tmp_path, "mimps", shadow_every=1)
        reqs = _requests(cfg, rng)
        rep = Server(sched, obs=obs).run(
            arrivals=trace_arrivals(reqs, [0.0] * len(reqs)))
        h = obs.last_harvest
        obs.close()
        _detach(sched)
        s = h["shadow_by_tier"]["mimps"]
        assert s["count"] > 0
        assert np.isfinite(s["rel_err_mean"]) and s["rel_err_mean"] >= 0
        assert s["rel_err_max"] >= s["rel_err_mean"]
        # device counters == host accounting, the reconciliation criterion
        got = {t: v for t, v in h["tokens_by_tier"].items() if v}
        assert got == {t: v for t, v in dict(rep.tokens_by_tier).items()
                       if v}
        assert h["tokens_total"] == sum(got.values())

    def test_latency_histogram_rows_present_and_monotone(
            self, served, rng, tmp_path):
        eng, cfg = served
        sched = Scheduler(eng, n_slots=3, key=rng)
        reqs = _requests(cfg, rng)
        Server(sched).run(arrivals=trace_arrivals(reqs, [0.0] * len(reqs)))
        sched.reset_metrics()
        reqs = _requests(cfg, rng)
        Server(sched).run(arrivals=trace_arrivals(reqs, [0.0] * len(reqs)))
        h = sched.harvest_metrics()
        counts = h["latency_hist_by_tier"]["mimps"]
        assert len(counts) == len(LATENCY_EDGES_MS) + 1
        # the warm run records every step but the first (feed-forward: step
        # N's device time lands in step N+1's histogram)
        assert sum(counts) == h["steps"] - 1
        cum = np.cumsum(counts)
        assert all(b >= a for a, b in zip(cum, cum[1:]))
        q = hist_quantile(np.asarray(counts), LATENCY_EDGES_MS, 0.99)
        assert np.isfinite(q) and q > 0


class TestReportTiming:
    def test_p99_and_device_host_split(self, served, rng):
        eng, cfg = served
        sched = Scheduler(eng, n_slots=3, key=rng)
        reqs = _requests(cfg, rng)
        rep = Server(sched).run(
            arrivals=trace_arrivals(reqs, [0.0] * len(reqs)))
        assert rep.p50_token_ms <= rep.p95_token_ms <= rep.p99_token_ms
        assert np.isfinite(rep.p99_token_ms)
        assert rep.step_device_ms_mean > 0
        assert rep.step_host_ms_mean > 0
        assert "p99" in rep.summary() and "host" in rep.summary()


class TestTraceArtifacts:
    def test_trace_jsonl_wellformed_and_report_accepts(
            self, served, rng, tmp_path):
        eng, cfg = served
        sched = Scheduler(eng, n_slots=3, key=rng)
        obs = _obs(tmp_path, "trace",
                   snapshot_path=str(tmp_path / "snap.json"))
        reqs = _requests(cfg, rng)
        Server(sched, obs=obs).run(
            arrivals=trace_arrivals(reqs, [0.0] * len(reqs)))
        obs.close()
        _detach(sched)
        path = tmp_path / "trace.jsonl"
        events = [json.loads(l) for l in path.read_text().splitlines()]
        assert events and obs.tracer.events_written == len(events)
        names = {e["name"] for e in events}
        # lifecycle spans + step phases + instants all present
        for want in ("enqueue", "queued", "replay", "decode", "request",
                     "device_step:mimps", "host_step"):
            assert want in names, want
        for e in events:
            assert e["ph"] in ("X", "i", "C", "M")
            if e["ph"] == "X":
                assert e["dur"] >= 0 and e["ts"] >= 0
        snap = json.loads((tmp_path / "snap.json").read_text())
        assert snap["serving_steps"] > 0
        assert snap["harvest"]["tokens_total"] > 0

        from repro.launch import obs_report
        assert obs_report.main([str(path),
                                "--snapshot", str(tmp_path / "snap.json")
                                ]) == 0

    def test_obs_report_rejects_empty_and_malformed(self, tmp_path):
        from repro.launch import obs_report
        empty = tmp_path / "empty.jsonl"
        empty.write_text("")
        assert obs_report.main([str(empty)]) == 2
        bad = tmp_path / "bad.jsonl"
        bad.write_text('{"ph": "X", "name": "a"}\nnot json\n')
        assert obs_report.main([str(bad)]) == 2

    def test_obs_report_reconcile_mismatch_exits_3(self, tmp_path):
        from repro.launch import obs_report
        trace = tmp_path / "t.jsonl"
        trace.write_text(json.dumps(
            {"ph": "X", "name": "device_step:topk", "ts": 0, "dur": 1,
             "pid": 1, "tid": 0}) + "\n")
        snap = tmp_path / "s.json"
        snap.write_text(json.dumps(
            {"harvest": {"tokens_by_tier": {"mimps": 7},
                         "tokens_total": 7}}))
        assert obs_report.main([str(trace), "--snapshot", str(snap)]) == 3


class TestRegistry:
    def test_prometheus_text_format(self):
        r = MetricsRegistry()
        r.set("tokens_total", 42, mtype="counter", help="tokens")
        r.set("rel_err", 0.25, labels={"tier": "mimps"})
        text = r.prometheus_text()
        assert "# TYPE repro_tokens_total counter" in text
        assert "# HELP repro_tokens_total tokens" in text
        assert "repro_tokens_total 42" in text
        assert 'repro_rel_err{tier="mimps"} 0.25' in text
        r.close()

    def test_http_exposition(self):
        r = MetricsRegistry()
        r.set("up", 1, mtype="gauge")
        port = r.serve(0)   # ephemeral port
        try:
            with urllib.request.urlopen(
                    f"http://127.0.0.1:{port}/metrics", timeout=10) as resp:
                body = resp.read().decode()
            assert "repro_up 1" in body
            with urllib.request.urlopen(
                    f"http://127.0.0.1:{port}/snapshot", timeout=10) as resp:
                snap = json.loads(resp.read().decode())
            assert snap["up"] == 1.0
        finally:
            r.close()

    def test_tracewriter_counts_and_flushes(self, tmp_path):
        path = tmp_path / "w.jsonl"
        w = TraceWriter(str(path))
        w.name_thread(3, "req 3")
        w.span("s", 1.0, 2.0, tid=3)
        w.instant("i")
        w.counter("c", {"x": 1.0})
        w.close()
        lines = path.read_text().splitlines()
        # ctor names tid 0 ("scheduler") + the 4 events above
        assert len(lines) == w.events_written == 5
        assert all(json.loads(l)["pid"] == 1 for l in lines)


MESH_OBS_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
import dataclasses, tempfile, jax, jax.numpy as jnp, numpy as np
from repro.configs import reduced_config
from repro.models import Model
from repro.obs import Observability, ObsConfig
from repro.serve import Engine, Request, Scheduler, Server, trace_arrivals
from repro.launch.mesh import make_serving_mesh

rng = jax.random.PRNGKey(0)
cfg = reduced_config("qwen1.5-4b")
cfg = dataclasses.replace(
    cfg, vocab=1024, partition=dataclasses.replace(
        cfg.partition, method="mimps", block_rows=64, n_probe=4, l=64))
m = Model(cfg)
params = m.init(jax.random.fold_in(rng, 42))

mk = lambda i, n: np.asarray(
    jax.random.randint(jax.random.fold_in(rng, 100 + i), (n,), 0,
                       cfg.vocab), np.int32)
spec = [(mk(0, 3), 5, 7, 0.0), (mk(1, 6), 4, 8, 0.9),
        (mk(2, 4), 6, 9, 0.5), (mk(3, 5), 5, 10, 0.3)]
mkreqs = lambda: [Request(prompt=p, max_new_tokens=n,
                          key=jax.random.fold_in(rng, s), temperature=t)
                  for (p, n, s, t) in spec]

mesh = make_serving_mesh(2, 2)
eng = Engine(m, params, max_len=24, mesh=mesh)
sched = Scheduler(eng, n_slots=4, key=rng)

# obs-off wave (also warmup)
reqs1 = mkreqs()
rep_off = Server(sched).run(arrivals=trace_arrivals(
    reqs1, [0.0] * len(reqs1)))
off = {c.request.req_id: c.tokens for c in rep_off.completions}
t0, a0 = sched.step_traces, sched.admit_traces

# obs-on wave: harvest + shadow sampling + tracing, same warm scheduler
sched.reset_metrics()
tmp = tempfile.mkdtemp()
obs = Observability(ObsConfig(harvest_every=2, shadow_every=1,
                              trace_path=os.path.join(tmp, "t.jsonl")))
reqs = mkreqs()
rep_on = Server(sched, obs=obs).run(arrivals=trace_arrivals(
    reqs, [0.0] * len(reqs)))
on = {c.request.req_id: c.tokens for c in rep_on.completions}
h = obs.last_harvest
obs.close()

assert [on[r.req_id] for r in reqs] == \
    [off[r.req_id] for r in reqs1], "mesh obs parity"
assert sched.step_traces == t0 and sched.admit_traces == a0, \
    "obs attach retraced under mesh"
s = h["shadow_by_tier"]["mimps"]
assert s["count"] > 0 and np.isfinite(s["rel_err_mean"]), s
got = {t: v for t, v in h["tokens_by_tier"].items() if v}
want = {t: v for t, v in dict(rep_on.tokens_by_tier).items() if v}
assert got == want, (got, want)
print("ALL_OK")
"""


class TestMeshObs8Dev:
    def test_obs_parity_zero_retrace_and_reconcile_under_mesh(self):
        r = subprocess.run([sys.executable, "-c", MESH_OBS_SNIPPET],
                           capture_output=True, text=True,
                           env=dict(os.environ, PYTHONPATH="src"),
                           cwd=REPO, timeout=900)
        assert r.returncode == 0 and "ALL_OK" in r.stdout, \
            r.stdout + r.stderr


class TestTrainMetrics:
    def test_instrumented_step_accumulates_without_host_sync(self, rng):
        from repro.configs.base import TrainConfig
        from repro.train import (harvest_train_metrics,
                                 init_train_metric_state,
                                 init_train_state, make_instrumented_step,
                                 make_train_step)
        cfg = reduced_config("qwen1.5-4b")
        m = Model(cfg)
        tc = TrainConfig(lr=1e-3, total_steps=4, loss="fused_ce",
                         warmup_steps=1)
        state = init_train_state(m, tc, rng)
        step = jax.jit(make_instrumented_step(make_train_step(m, tc)))
        tm = init_train_metric_state()
        toks = np.zeros((2, 8), np.int32)
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
        for _ in range(4):
            state, tm, metrics = step(state, tm, batch)
        h = harvest_train_metrics(tm)
        assert h["steps"] == 4
        assert h["nonfinite_steps"] == 0
        assert np.isfinite(h["loss_mean"]) and h["loss_mean"] > 0
        assert h["loss_max"] >= h["loss_mean"]
        assert h["grad_norm_max"] >= h["grad_norm_mean"] > 0
        # the accumulator matches the per-step metrics it folded in
        assert h["loss_std"] >= 0
