"""Per-architecture smoke tests: reduced configs of the same family run a
forward + one train step + one decode step on CPU, asserting shapes + finite
outputs. (Full configs are exercised only via the dry run.)"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduced_config
from repro.configs.base import TrainConfig
from repro.data import SyntheticCorpus, DataIterator
from repro.models import Model
from repro.train import init_train_state, make_train_step


def _batch(cfg, key, b=2, s=32):
    if cfg.n_codebooks:
        toks = jax.random.randint(key, (b, s + 1, cfg.n_codebooks), 0,
                                  cfg.vocab)
    else:
        toks = jax.random.randint(key, (b, s + 1), 0, cfg.vocab)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.family == "vlm":
        batch["img"] = jax.random.normal(
            key, (b, cfg.n_image_tokens, cfg.d_model)).astype(cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch, rng):
        cfg = reduced_config(arch)
        m = Model(cfg)
        p = m.init(rng)
        batch = _batch(cfg, rng)
        h, aux = jax.jit(lambda p, b: m.forward(
            p, b["tokens"], img=b.get("img")))(p, batch)
        assert h.shape == (2, 32, cfg.d_model)
        assert bool(jnp.all(jnp.isfinite(h.astype(jnp.float32))))

    def test_train_step_reduces_loss_no_nan(self, arch, rng):
        cfg = reduced_config(arch)
        m = Model(cfg)
        tc = TrainConfig(lr=1e-3, warmup_steps=2, total_steps=50,
                         loss="fused_ce", microbatches=1)
        state = init_train_state(m, tc, rng)
        step = jax.jit(make_train_step(m, tc, backend="xla"))
        batch = _batch(cfg, rng)
        losses = []
        for i in range(5):
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss_total"]))
        assert all(np.isfinite(losses)), losses
        assert losses[-1] < losses[0], losses  # same batch -> must overfit

    def test_decode_step(self, arch, rng):
        cfg = reduced_config(arch)
        m = Model(cfg)
        p = m.init(rng)
        st = m.init_decode_state(2, 64)
        batch = _batch(cfg, rng)
        tok = batch["tokens"][:, 0]
        h, st2 = jax.jit(lambda p, s, t: m.decode_step(
            p, s, t, 3, img=batch.get("img")))(p, st, tok)
        assert h.shape == (2, cfg.d_model)
        assert bool(jnp.all(jnp.isfinite(h.astype(jnp.float32))))
        # state structure preserved
        assert jax.tree.structure(st) == jax.tree.structure(st2)


class TestFullConfigs:
    """Full configs: structural checks only (never allocate real weights)."""

    @pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
    def test_config_matches_assignment(self, arch):
        cfg = get_config(arch)
        spec = {
            "mistral-nemo-12b": (40, 5120, 32, 8, 14336, 131072),
            "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
            "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
            "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
            "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
            "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
            "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
            "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
            "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
            "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        }[arch]
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == spec

    def test_param_counts_in_range(self):
        """Sanity: analytic counts land near the advertised sizes."""
        expect = {"mistral-nemo-12b": (11e9, 14e9),
                  "llama-3.2-vision-90b": (80e9, 100e9),
                  "deepseek-moe-16b": (14e9, 20e9),
                  "rwkv6-7b": (6e9, 9e9),
                  "gemma3-4b": (3e9, 6e9)}
        for arch, (lo, hi) in expect.items():
            n = get_config(arch).param_count()
            assert lo < n < hi, f"{arch}: {n/1e9:.2f}B not in [{lo},{hi}]"

    def test_moe_active_params_smaller(self):
        cfg = get_config("moonshot-v1-16b-a3b")
        assert cfg.active_param_count() < 0.5 * cfg.param_count()


class TestTrainSubstrate:
    def test_microbatch_accumulation_matches(self, rng):
        """grad accumulation == single big batch (linearity of grads)."""
        cfg = reduced_config("qwen1.5-4b")
        m = Model(cfg)
        tc1 = TrainConfig(lr=1e-3, loss="ce", microbatches=1, seed=1)
        tc2 = dataclasses.replace(tc1, microbatches=2)
        s1 = init_train_state(m, tc1, rng)
        s2 = init_train_state(m, tc2, rng)
        batch = _batch(cfg, rng, b=4)
        s1b, m1 = jax.jit(make_train_step(m, tc1))(s1, batch)
        s2b, m2 = jax.jit(make_train_step(m, tc2))(s2, batch)
        p1 = jax.tree.leaves(s1b.params)
        p2 = jax.tree.leaves(s2b.params)
        for a, b in zip(p1, p2):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-2, atol=2e-4)

    @pytest.mark.parametrize("loss", ["ce", "fused_ce", "selfnorm", "nce",
                                      "sampled"])
    def test_all_losses_finite_and_trainable(self, rng, loss):
        cfg = reduced_config("qwen1.5-4b")
        m = Model(cfg)
        tc = TrainConfig(lr=1e-3, loss=loss)
        state = init_train_state(m, tc, rng)
        step = jax.jit(make_train_step(m, tc))
        batch = _batch(cfg, rng)
        for _ in range(3):
            state, metrics = step(state, batch)
        assert np.isfinite(float(metrics["loss_total"]))

    def test_selfnorm_drives_logz_to_zero(self, rng):
        cfg = reduced_config("qwen1.5-4b")
        m = Model(cfg)
        tc = TrainConfig(lr=3e-3, loss="selfnorm", selfnorm_alpha=0.5)
        state = init_train_state(m, tc, rng)
        step = jax.jit(make_train_step(m, tc))
        batch = _batch(cfg, rng)
        zs = []
        for _ in range(15):
            state, metrics = step(state, batch)
            zs.append(abs(float(metrics["mean_log_z"])))
        assert zs[-1] < zs[0], zs  # |log Z| shrinking (paper's SS2 baseline)

    def test_data_pipeline_deterministic_and_sharded(self):
        c = SyntheticCorpus(vocab=1000, seed=3)
        a = c.batch(5, 4, 16, shard=0, n_shards=2)
        b = c.batch(5, 4, 16, shard=0, n_shards=2)
        np.testing.assert_array_equal(a, b)
        other = c.batch(5, 4, 16, shard=1, n_shards=2)
        assert not np.array_equal(a, other)
        it = DataIterator(c, 4, 16)
        x0, y0 = next(it)
        assert x0.shape == (4, 16) and y0.shape == (4, 16)
        np.testing.assert_array_equal(x0[:, 1:], y0[:, :-1])
