"""Mesh-sharded traffic serving (DESIGN.md SS15).

In-process pieces: slot-lane routing across data replicas, IVF block
padding neutrality, engine mesh validations, tier-state partition-spec
rules. The device-count-dependent pieces run in subprocesses with 8
placeholder host devices (the tests/test_backends.py pattern, so the XLA
override never leaks into this process):

 * per-backend ``shard_decode`` body parity: one shard_map step over a
   (data, model) mesh must be BITWISE identical to the single-device
   decode on the unpadded index, for every servable estimator,
 * end-to-end scheduler parity: tokens from the mesh scheduler ==
   solo ``generate()`` per request, staggered admissions spread across
   replicas, and a second traffic wave retraces NOTHING,
 * sharded health guard: a NaN-injected lane falls back to the
   psum-combined exact splice; neighbors stay bit-identical to the
   fault-free mesh run with zero recompiles,
 * degradation ladder under the mesh: every tier compiles once during
   warmup; the overload walk traces nothing new.
"""
import os
import subprocess
import sys
import types

import dataclasses
import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run8(snippet: str, timeout: int = 900):
    r = subprocess.run([sys.executable, "-c", snippet],
                       capture_output=True, text=True,
                       env=dict(os.environ, PYTHONPATH="src"),
                       cwd=REPO, timeout=timeout)
    assert r.returncode == 0 and "ALL_OK" in r.stdout, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# in-process units
# ---------------------------------------------------------------------------

class TestSlotRouting:
    def test_least_loaded_replica_round_robin(self):
        """Admissions land on DISTINCT replicas first (least-loaded, ties
        to the lowest replica), lowest lane within a replica — staggered
        arrivals spread work across the data axis instead of piling onto
        replica 0."""
        from repro.serve.scheduler import Scheduler
        s = types.SimpleNamespace(n_replicas=4, lanes_per_replica=2,
                                  _free=list(range(8)))
        picks = [Scheduler._pick_slot(s) for _ in range(8)]
        assert picks == [0, 2, 4, 6, 1, 3, 5, 7]
        assert s._free == []

    def test_single_replica_keeps_fifo(self):
        from repro.serve.scheduler import Scheduler
        s = types.SimpleNamespace(n_replicas=1, lanes_per_replica=4,
                                  _free=[2, 0, 3])
        assert Scheduler._pick_slot(s) == 2

    def test_slots_must_divide_replicas(self):
        """The ctor rejects lane counts the data axis can't split evenly
        — validated before any device work, so a stub engine suffices
        (a real data=2 mesh would need 2 devices)."""
        from repro.serve.scheduler import Scheduler
        eng = types.SimpleNamespace(
            cfg=types.SimpleNamespace(n_codebooks=0),
            mesh=types.SimpleNamespace(shape={"data": 2, "model": 1}))
        with pytest.raises(ValueError, match="divide"):
            Scheduler(eng, n_slots=3)


class TestIndexPadding:
    def test_pad_is_decode_neutral(self, rng):
        """Dead pad blocks change nothing: probe ranks them -inf, scoring
        masks them, so decode over the padded index is bitwise identical —
        the property that lets the mesh shard a padded block dim while
        solo decode runs unpadded."""
        from repro.core.decode import mimps_decode
        from repro.core.mips import build_ivf, pad_ivf_blocks
        v = jax.random.normal(jax.random.fold_in(rng, 1), (1024, 32)) * 0.3
        h = jax.random.normal(jax.random.fold_in(rng, 2), (4, 32))
        idx = build_ivf(rng, v, block_rows=32, n_clusters=16)
        padded = pad_ivf_blocks(idx, 8)
        assert padded.v_blocks.shape[0] % 8 == 0
        assert padded.v_blocks.shape[0] >= idx.v_blocks.shape[0]
        a = mimps_decode(idx, h, rng, n_probe=4, l=64, k=4,
                         use_pallas=False)
        b = mimps_decode(padded, h, rng, n_probe=4, l=64, k=4,
                         use_pallas=False)
        for f in ("log_z", "top_score", "top_id", "head_lse", "tail_lse",
                  "k_eff"):
            np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                          np.asarray(getattr(b, f)), f)

    def test_pad_multiple_one_is_identity(self, rng):
        from repro.core.mips import build_ivf, pad_ivf_blocks
        v = jax.random.normal(rng, (256, 16))
        idx = build_ivf(rng, v, block_rows=32, n_clusters=4)
        assert pad_ivf_blocks(idx, 1) is idx


class TestEngineMeshValidation:
    @pytest.fixture(scope="class")
    def small(self, rng):
        from repro.configs import reduced_config
        from repro.models import Model
        cfg = reduced_config("qwen1.5-4b")
        cfg = dataclasses.replace(
            cfg, vocab=512, partition=dataclasses.replace(
                cfg.partition, method="mimps", block_rows=64, n_probe=2,
                l=32))
        m = Model(cfg)
        return m, m.init(jax.random.fold_in(rng, 3))

    def test_mesh_needs_both_axes(self, small, rng):
        from repro.serve import Engine
        m, params = small
        mesh = jax.make_mesh((1,), ("data",))
        with pytest.raises(ValueError, match="model"):
            Engine(m, params, max_len=16, mesh=mesh)

    def test_mesh_rejects_pallas(self, small, rng):
        from repro.launch.mesh import make_serving_mesh
        from repro.serve import Engine
        m, params = small
        with pytest.raises(ValueError, match="pallas"):
            Engine(m, params, max_len=16, mesh=make_serving_mesh(1, 1),
                   use_pallas=True)

    def test_mesh_pads_index_blocks(self, small, rng):
        """A (1,1) mesh engine works on the single real device and pads
        the IVF block dim to the model extent (trivially 1 here) while
        still matching solo generate() token-for-token."""
        from repro.launch.mesh import make_serving_mesh
        from repro.serve import Engine, Request, Scheduler, Server, generate
        m, params = small
        solo = Engine(m, params, max_len=16)
        eng = Engine(m, params, max_len=16, mesh=make_serving_mesh(1, 1))
        prompt = np.asarray(
            jax.random.randint(jax.random.fold_in(rng, 9), (3,), 0, 512),
            np.int32)
        want = [int(t) for t in np.asarray(generate(
            solo, jnp.asarray(prompt)[None], 4, rng))[0]]
        server = Server(Scheduler(eng, n_slots=2, key=rng))
        server.submit(Request(prompt=prompt, max_new_tokens=4, key=rng,
                              temperature=0.0))
        rep = server.run()
        assert rep.completions[0].tokens == want


class TestPartitionSpecs:
    def test_tier_state_specs_shard_only_output_layer(self, rng):
        from jax.sharding import PartitionSpec as P
        from repro.configs.base import PartitionConfig
        from repro.core import backends as B
        cfg = PartitionConfig(block_rows=32, n_probe=2, l=32, n_clusters=8,
                              method="mimps", fmbe_features=64)
        w = jax.random.normal(rng, (512, 16)) * 0.3
        st = B.get_backend("mimps").build(cfg, w, rng, block_multiple=4)
        specs = B.state_partition_specs(st, 4)
        assert specs.w == P("model", None)
        assert specs.index.v_blocks == P("model", None, None)
        # every other leaf — centroids, radius, valid, row ids — replicated
        assert specs.index.block_centroids == P()
        assert specs.index.valid == P()
        assert specs.index.slot_of_row == P()

    def test_indivisible_falls_back_to_replicated(self, rng):
        from jax.sharding import PartitionSpec as P
        from repro.configs.base import PartitionConfig
        from repro.core import backends as B
        cfg = PartitionConfig(block_rows=32, n_probe=2, l=32, n_clusters=8,
                              method="mimps", fmbe_features=64)
        w = jax.random.normal(rng, (510, 16)) * 0.3
        st = B.get_backend("mimps").build(cfg, w, rng)
        specs = B.state_partition_specs(st, 4)
        assert specs.w == P()


# ---------------------------------------------------------------------------
# 8-virtual-device subprocesses
# ---------------------------------------------------------------------------

BODY_PARITY_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.configs.base import PartitionConfig
from repro.core import backends as B
from repro.core.distributed import shard_map
from repro.launch.mesh import make_serving_mesh

cfg = PartitionConfig(block_rows=32, n_probe=4, l=64, n_clusters=16,
                      method="mimps", fmbe_features=128)
key = jax.random.PRNGKey(0)
w = jax.random.normal(jax.random.PRNGKey(1), (1024, 32)) * 0.3
h = jax.random.normal(jax.random.PRNGKey(2), (8, 32))
active = jnp.array([1, 1, 0, 1, 1, 1, 0, 1], bool)
kd = jax.random.PRNGKey(7)

for (dp, mp) in [(1, 4), (2, 4)]:
    mesh = make_serving_mesh(dp, mp)
    for method in ["mimps", "mince", "topk", "fmbe", "exact", "selfnorm"]:
        bk = B.get_backend(method)
        ref = bk.decode(bk.build(cfg, w, key, device=True), h, kd, cfg,
                        k=4, use_pallas=False, active=active)
        st = bk.build(cfg, w, key, device=True, block_multiple=mp)
        specs = B.state_partition_specs(st, mp)
        body = lambda s, hh: bk.shard_decode(s, hh, kd, cfg, k=4,
                                             active=active)
        out = jax.jit(shard_map(body, mesh, in_specs=(specs, P()),
                                out_specs=P(), check_vma=False))(st, h)
        if method in ("exact", "selfnorm"):
            # candidates exact; log_z only to psum reduction-order rounding
            assert bool(jnp.all(ref.top_score == out.top_score)), method
            assert bool(jnp.all(ref.top_id == out.top_id)), method
            assert bool(jnp.allclose(ref.log_z, out.log_z,
                                     atol=1e-5)), method
        else:
            for f in ("log_z", "top_score", "top_id", "head_lse",
                      "tail_lse", "k_eff"):
                assert bool(jnp.all(getattr(ref, f) == getattr(out, f))), \
                    (dp, mp, method, f)
print("ALL_OK")
"""


SCHED_PARITY_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs import reduced_config
from repro.models import Model
from repro.serve import (Engine, Request, Scheduler, Server, generate,
                         trace_arrivals)
from repro.launch.mesh import make_serving_mesh

rng = jax.random.PRNGKey(0)
cfg = reduced_config("qwen1.5-4b")
cfg = dataclasses.replace(
    cfg, vocab=1024, partition=dataclasses.replace(
        cfg.partition, method="mimps", block_rows=64, n_probe=4, l=64))
m = Model(cfg)
params = m.init(jax.random.fold_in(rng, 42))

mk = lambda i, n: np.asarray(
    jax.random.randint(jax.random.fold_in(rng, 100 + i), (n,), 0,
                       cfg.vocab), np.int32)
spec = [(mk(0, 3), 5, 7, 0.0), (mk(1, 6), 4, 8, 0.9),
        (mk(2, 4), 6, 9, 0.5), (mk(3, 5), 5, 10, 0.3),
        (mk(4, 2), 7, 11, 0.0), (mk(5, 7), 3, 12, 0.7)]
mkreqs = lambda: [Request(prompt=p, max_new_tokens=n,
                          key=jax.random.fold_in(rng, s), temperature=t)
                  for (p, n, s, t) in spec]

solo_eng = Engine(m, params, max_len=24)
solo = [[int(x) for x in np.asarray(generate(
            solo_eng, jnp.asarray(p)[None], n, jax.random.fold_in(rng, s),
            temperature=t))[0]] for (p, n, s, t) in spec]

for (dp, mp) in [(4, 1), (2, 2)]:
    mesh = make_serving_mesh(dp, mp)
    eng = Engine(m, params, max_len=24, mesh=mesh)
    sched = Scheduler(eng, n_slots=2 * dp, key=rng)
    server = Server(sched)
    # staggered arrivals: one request per virtual step, so admissions hit
    # the least-loaded-replica router one at a time
    reqs = mkreqs()
    rep = server.run(arrivals=trace_arrivals(
        reqs, [float(i) for i in range(len(reqs))]))
    got = {c.request.req_id: c.tokens for c in rep.completions}
    assert all(got[r.req_id] == solo[i] for i, r in enumerate(reqs)), \
        (dp, mp, "wave-1 parity")
    # second wave through the warm scheduler: parity again AND zero
    # retraces of either executable
    t0, a0 = sched.step_traces, sched.admit_traces
    reqs2 = mkreqs()
    server2 = Server(sched)
    rep2 = server2.run(arrivals=trace_arrivals(
        reqs2, [0.0] * len(reqs2)))
    got2 = {c.request.req_id: c.tokens for c in rep2.completions}
    assert all(got2[r.req_id] == solo[i] for i, r in enumerate(reqs2)), \
        (dp, mp, "wave-2 parity")
    assert sched.step_traces == t0 and sched.admit_traces == a0, \
        (dp, mp, "retraced after warmup")

# staggered admission spreads lanes across replicas: with 4 replicas and
# one-arrival-per-step, the first 4 admissions occupy 4 DISTINCT replicas
mesh = make_serving_mesh(4, 1)
eng = Engine(m, params, max_len=24, mesh=mesh)
sched = Scheduler(eng, n_slots=8, key=rng)
lanes = sched.lanes_per_replica
slots = [sched._pick_slot() for _ in range(4)]
assert sorted(s // lanes for s in slots) == [0, 1, 2, 3], slots
print("ALL_OK")
"""


FAULT_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs import ServingConfig, reduced_config
from repro.models import Model
from repro.serve import (Engine, NanLogitsFault, Request, Scheduler,
                         Server, default_ladder)
from repro.launch.mesh import make_serving_mesh

rng = jax.random.PRNGKey(0)
cfg = reduced_config("qwen1.5-4b")
cfg = dataclasses.replace(
    cfg, vocab=1024, partition=dataclasses.replace(
        cfg.partition, method="mimps", block_rows=64, n_probe=4, l=64))
m = Model(cfg)
params = m.init(jax.random.fold_in(rng, 42))
mesh = make_serving_mesh(2, 2)
eng = Engine(m, params, max_len=24, mesh=mesh)

mk = lambda i, n: np.asarray(
    jax.random.randint(jax.random.fold_in(rng, 300 + i), (n,), 0,
                       cfg.vocab), np.int32)
mkreqs = lambda: [Request(prompt=mk(i, 2 + i % 3), max_new_tokens=4,
                          key=jax.random.fold_in(rng, 400 + i),
                          temperature=0.0 if i % 2 else 0.7)
                  for i in range(4)]

# fault-free mesh oracle
base = Server(Scheduler(eng, n_slots=4, key=rng))
reqs0 = mkreqs()
for r in reqs0:
    base.submit(r)
rep0 = base.run()
toks0 = {c.request.req_id % 4: c.tokens for c in rep0.completions}

# NaN-injected lane under the mesh: guard must splice the psum-combined
# exact fallback into the victim only; neighbors bit-identical
reqs = mkreqs()
victim = reqs[1]
sched = Scheduler(eng, n_slots=4, key=rng,
                  injector=NanLogitsFault([victim.req_id],
                                          steps=range(1, 20)))
server = Server(sched)
for r in reqs:
    server.submit(r)
rep = server.run()
got = {c.request.req_id % 4: c.tokens for c in rep.completions}
for i in range(4):
    if i != 1:
        assert got[i] == toks0[i], ("fault leaked into lane", i)
for c in rep.completions:
    assert np.all(np.isfinite(np.asarray(c.log_probs))), c.request.req_id
    assert np.all(np.isfinite(np.asarray(c.log_zs))), c.request.req_id
assert rep.health["flagged"] > 0
assert rep.health["nonfinite_z"] > 0
assert sched.step_traces == 1, "fault masks must be traced data"

# degradation ladder under the mesh: warm every tier once, then sustained
# queue pressure (one long request hogging a lane + a backlog of shorts)
# walks the ladder without tracing anything new
sched2 = Scheduler(eng, n_slots=2, key=rng)
for tier in default_ladder(sched2.tier):
    sched2.set_tier(tier)
    warm = Server(sched2)
    for r in mkreqs()[:2]:
        warm.submit(r)
    warm.run()
sched2.set_tier("mimps")
t0, a0 = sched2.step_traces, sched2.admit_traces
srv = Server(sched2, ServingConfig(degrade_high=3, degrade_low=1,
                                   degrade_after=2, restore_after=4))
srv.submit(Request(prompt=mk(9, 2), max_new_tokens=20,
                   key=jax.random.fold_in(rng, 501)))
for i in range(6):
    srv.submit(Request(prompt=mk(10 + i, 2 + i % 3), max_new_tokens=2,
                       key=jax.random.fold_in(rng, 510 + i),
                       temperature=0.0 if i % 2 else 0.7))
rep2 = srv.run()
assert rep2.tier_transitions, "overload never walked the ladder"
assert rep2.degraded_token_frac > 0, rep2.tokens_by_tier
assert sched2.step_traces == t0 and sched2.admit_traces == a0, \
    "ladder walk retraced under mesh"
print("ALL_OK")
"""


class TestMeshServing8Dev:
    def test_shard_decode_body_parity_all_backends(self):
        _run8(BODY_PARITY_SNIPPET)

    def test_scheduler_token_parity_staggered_zero_retrace(self):
        _run8(SCHED_PARITY_SNIPPET)

    def test_health_guard_splice_and_ladder_under_mesh(self):
        _run8(FAULT_SNIPPET)
