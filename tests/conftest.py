"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the single real CPU device; only launch/dryrun.py
sets --xla_force_host_platform_device_count (in its own process)."""
import jax
import jax.numpy as jnp
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def make_clustered_vectors(key, n, d, n_centers=32, spread=0.5,
                           zipf_norms=True):
    """Synthetic word2vec-like class vectors: clustered + rank-scaled norms."""
    k1, k2, k3 = jax.random.split(key, 3)
    centers = jax.random.normal(k1, (n_centers, d))
    asg = jax.random.randint(k2, (n,), 0, n_centers)
    v = centers[asg] + spread * jax.random.normal(k3, (n, d))
    if zipf_norms:
        scale = 1.0 + 2.0 / jnp.sqrt(1.0 + jnp.arange(n))
        v = v * scale[:, None]
    # keep score scale moderate so exp() stays in float32 range
    v = v / jnp.linalg.norm(v, axis=1, keepdims=True) * jnp.sqrt(d) * 0.35
    return v


@pytest.fixture(scope="session")
def vectors(rng):
    return make_clustered_vectors(rng, 8192, 64)
