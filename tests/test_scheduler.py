"""Continuous-batching slot scheduler (serve.scheduler / serve.server).

The contract under test: the slot table is INVISIBLE to each request —
a request decoded in a busy, mixed-temperature, mixed-phase slot table
emits bit-identical tokens to the same request run alone through
``generate()`` — while slots recycle, admission never stalls in-flight
decodes, nothing recompiles after warmup, and cache-capacity overflows are
refused on host paths / clamped-with-flag in compiled steps."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import Model
from repro.serve import (Engine, Request, Scheduler, Server, ServeState,
                         generate, poisson_arrivals, trace_arrivals)


@pytest.fixture(scope="module")
def served(rng):
    """One shared engine (mimps, IVF engaged) for the whole module."""
    cfg = reduced_config("qwen1.5-4b")
    cfg = dataclasses.replace(
        cfg, vocab=1024, partition=dataclasses.replace(
            cfg.partition, method="mimps", block_rows=64, n_probe=4, l=64))
    m = Model(cfg)
    eng = Engine(m, m.init(jax.random.fold_in(rng, 42)), max_len=24)
    return eng, cfg


def _solo(eng, prompt, n, key, temperature=0.0):
    toks = generate(eng, jnp.asarray(prompt)[None], n, key,
                    temperature=temperature)
    return [int(t) for t in np.asarray(toks)[0]]


def _mixed_requests(cfg, rng):
    """Different lengths, temperatures, keys — the heterogeneous traffic a
    synchronous batch cannot serve without padding/recompiling."""
    mk = lambda i, n: np.asarray(
        jax.random.randint(jax.random.fold_in(rng, 100 + i), (n,), 0,
                           cfg.vocab), np.int32)
    return [
        Request(prompt=mk(0, 3), max_new_tokens=5,
                key=jax.random.fold_in(rng, 7), temperature=0.0),
        Request(prompt=mk(1, 6), max_new_tokens=4,
                key=jax.random.fold_in(rng, 8), temperature=0.9),
        Request(prompt=mk(2, 4), max_new_tokens=6,
                key=jax.random.fold_in(rng, 9), temperature=0.5),
    ]


class TestPerSlotSamplingParity:
    def test_mixed_temps_and_keys_bit_identical_to_solo(self, served, rng):
        """Satellite: two+ requests sharing the slot table with different
        temperatures/keys == running each alone through generate()."""
        eng, cfg = served
        reqs = _mixed_requests(cfg, rng)
        solo = [_solo(eng, r.prompt, r.max_new_tokens, r.key,
                      r.temperature) for r in reqs]
        server = Server(Scheduler(eng, n_slots=4, key=rng))
        for r in reqs:
            server.submit(r)
        rep = server.run()
        got = {c.request.req_id: c.tokens for c in rep.completions}
        assert len(got) == len(reqs)
        for r, want in zip(reqs, solo):
            assert got[r.req_id] == want

    def test_staggered_admission_does_not_perturb_in_flight(self, served,
                                                            rng):
        """Admitting mid-generation (chunked replay interleaved with live
        decodes) must not change any stream's tokens: membership masks keep
        each query's candidates its own, and sampling keys are per-slot."""
        eng, cfg = served
        reqs = _mixed_requests(cfg, rng)
        solo = [_solo(eng, r.prompt, r.max_new_tokens, r.key,
                      r.temperature) for r in reqs]
        server = Server(Scheduler(eng, n_slots=4, key=rng))
        rep = server.run(arrivals=trace_arrivals(reqs, [0, 2, 5]))
        got = {c.request.req_id: c.tokens for c in rep.completions}
        for r, want in zip(reqs, solo):
            assert got[r.req_id] == want

    def test_log_prob_finite_and_log_z_estimated(self, served, rng):
        eng, cfg = served
        reqs = _mixed_requests(cfg, rng)
        server = Server(Scheduler(eng, n_slots=4, key=rng))
        for r in reqs:
            server.submit(r)
        rep = server.run()
        for c in rep.completions:
            assert len(c.log_probs) == len(c.tokens) == len(c.log_zs)
            assert np.all(np.isfinite(c.log_probs))
            assert np.all(np.asarray(c.log_probs) <= 1e-4)  # log p <= 0


class TestCompileStability:
    def test_zero_recompiles_after_warmup(self, served, rng):
        """ONE compiled mixed step + ONE compiled admit serve every
        admission / replay / decode / recycle mix (acceptance criterion)."""
        eng, cfg = served
        sched = Scheduler(eng, n_slots=3, key=rng)
        server = Server(sched)
        # warmup: first step + first admission compile
        server.submit(Request(prompt=[5, 7], max_new_tokens=2, key=1))
        server.run()
        assert sched.step_traces == 1
        assert sched.admit_traces == 1
        # mixed follow-on traffic: different lengths, temps, budgets, slots
        reqs = _mixed_requests(cfg, rng) + [
            Request(prompt=[3], max_new_tokens=7, key=2, temperature=2.0),
            Request(prompt=list(range(8)), max_new_tokens=1, key=3),
        ]
        server2 = Server(sched)
        rep = server2.run(arrivals=poisson_arrivals(reqs, rate=1.5, seed=1))
        assert len(rep.completions) == len(reqs)
        assert sched.step_traces == 1, "mixed step recompiled"
        assert sched.admit_traces == 1, "admission recompiled"

    def test_temperature_change_does_not_recompile_generate(self, served,
                                                            rng):
        """Sampling params are traced data: T=0 and T>0 share one scan."""
        eng, cfg = served
        eng._scan_runners = {}
        prompt = jax.random.randint(rng, (1, 4), 0, cfg.vocab)
        generate(eng, prompt, 3, rng, temperature=0.0)
        generate(eng, prompt, 3, rng, temperature=0.8)
        assert len(eng._scan_runners) == 1


class TestSlotRecycling:
    def test_more_requests_than_slots_all_complete(self, served, rng):
        eng, cfg = served
        n_req, n_slots = 7, 2
        reqs = [Request(prompt=[(11 * i + 3) % cfg.vocab, i % cfg.vocab],
                        max_new_tokens=2 + i % 3, key=50 + i,
                        temperature=0.0 if i % 2 else 0.7)
                for i in range(n_req)]
        sched = Scheduler(eng, n_slots=n_slots, key=rng)
        server = Server(sched)
        for r in reqs:
            server.submit(r)
        rep = server.run()
        assert len(rep.completions) == n_req
        assert sched.n_free == n_slots          # every lane recycled
        assert rep.occupancy_steady > 0.5       # the CI gate's invariant
        assert rep.queue_wait_steps_mean > 0    # some requests queued

    def test_streaming_callbacks_fire_in_order(self, served, rng):
        eng, cfg = served
        seen = []
        done = []
        req = Request(prompt=[1, 2, 3], max_new_tokens=4, key=5,
                      on_token=lambda r, tok, t: seen.append(tok),
                      on_complete=lambda r, comp: done.append(comp))
        server = Server(Scheduler(eng, n_slots=2, key=rng))
        server.submit(req)
        server.run()
        assert len(done) == 1
        assert seen == done[0].tokens
        assert len(seen) == 4


class TestCapacityGuards:
    def test_admit_rejects_request_past_cache_capacity(self, served, rng):
        eng, cfg = served
        sched = Scheduler(eng, n_slots=2, key=rng)
        bad = Request(prompt=list(range(10)), max_new_tokens=eng.max_len,
                      key=0)
        with pytest.raises(ValueError, match="cache positions"):
            sched.admit(bad)

    def test_server_rejects_bad_request_without_killing_the_run(self,
                                                                served, rng):
        """One unadmittable request must not abandon the rest of the
        workload: it resolves as an errored, token-less completion and
        every other request still completes (with parity)."""
        eng, cfg = served
        good = Request(prompt=[4, 2], max_new_tokens=3, key=11)
        bad = Request(prompt=list(range(10)), max_new_tokens=eng.max_len,
                      key=12)
        solo = _solo(eng, good.prompt, 3, good.key)
        server = Server(Scheduler(eng, n_slots=2, key=rng))
        server.submit(good)
        server.submit(bad)
        rep = server.run()
        by_id = {c.request.req_id: c for c in rep.completions}
        assert by_id[good.req_id].tokens == solo
        assert by_id[good.req_id].error is None
        assert by_id[bad.req_id].tokens == []
        assert "cache positions" in by_id[bad.req_id].error

    def test_generate_rejects_request_past_cache_capacity(self, served,
                                                          rng):
        eng, cfg = served
        prompt = jnp.zeros((1, 10), jnp.int32)
        for host_loop in (False, True):
            with pytest.raises(ValueError, match="max_len"):
                generate(eng, prompt, eng.max_len, rng, host_loop=host_loop)

    def test_eager_decode_step_raises_past_max_len(self, served, rng):
        """Host-path guard: a concrete position past capacity raises
        instead of silently wrapping the KV ring."""
        eng, cfg = served
        state = ServeState(
            cache=eng.model.init_decode_state(1, eng.max_len),
            pos=jnp.asarray(eng.max_len, jnp.int32),
            last_token=jnp.zeros((1,), jnp.int32))
        with pytest.raises(ValueError, match="capacity"):
            eng.decode_step(state, rng)

    def test_compiled_decode_step_clamps_with_flag(self, served, rng):
        """Inside jit the same condition cannot raise: the write clamps to
        the last slot and the step reports ``overflow``."""
        eng, cfg = served
        step = jax.jit(lambda s, k: eng.decode_step(s, k)[0]["overflow"])
        mk = lambda p: ServeState(
            cache=eng.model.init_decode_state(1, eng.max_len),
            pos=jnp.asarray(p, jnp.int32),
            last_token=jnp.zeros((1,), jnp.int32))
        assert bool(step(mk(eng.max_len), rng))
        assert not bool(step(mk(eng.max_len - 1), rng))


class TestPerSlotPositions:
    def test_vector_pos_matches_per_lane_scalar_decode(self, served, rng):
        """models.decode_step with a (B,) position vector == slicing each
        lane out and decoding it alone at its scalar position."""
        eng, cfg = served
        model, params = eng.model, eng.params
        toks = jnp.asarray([3, 9], jnp.int32)
        pos = jnp.asarray([5, 0], jnp.int32)
        state = model.init_decode_state(2, eng.max_len)
        h_vec, _ = model.decode_step(params, state, toks, pos)
        for lane in range(2):
            lane_state = jax.tree.map(
                lambda t: jax.lax.dynamic_slice_in_dim(t, lane, 1, axis=1),
                state)
            h_solo, _ = model.decode_step(params, lane_state,
                                          toks[lane:lane + 1],
                                          jnp.asarray(pos[lane]))
            np.testing.assert_allclose(np.asarray(h_vec[lane]),
                                       np.asarray(h_solo[0]),
                                       rtol=2e-2, atol=2e-2)

    def test_audio_head_not_slot_servable(self, rng):
        cfg = reduced_config("musicgen-medium")
        m = Model(cfg)
        eng = Engine(m, m.init(rng), max_len=16)
        with pytest.raises(NotImplementedError, match="generate"):
            Scheduler(eng, n_slots=2)
