"""Serve-time output layer — the paper's Eq. 2/3 under production sharding.

Lowered paths (all used by launch/dryrun.py), dispatched per method through
``sharded_decode`` — the vocab-sharded face of the estimator-backend
registry (``core.backends``):

 * exact   : streaming chunked logits + online LSE + argmax over the
             vocab-sharded head. O(V d / T) compute per chip, O(B) comms.
 * mimps   : the paper's Eq. 5, vocab-sharded block-IVF inside shard_map:
             each model shard probes its local blocks, scores them,
             tail-samples its local complement; combine = one psum (log Z)
             + one O(T) all_gather (argmax candidates).
             O((nb + p.br + l) d / T) compute per chip — sublinear in V.
 * mince   : Eq. 6/7 with the same local probe/tail sets. The NCE root-find
             is nonlinear, so shards cannot combine log Z post hoc; instead
             each shard compresses its local anchored atoms into the
             fixed-size MinceStats histogram and ONE psum of the stacked
             (B, S, 4) sums recovers the global sufficient statistics —
             every shard then solves locally with zero per-iteration
             communication (the seed psum'd f'/f''/f''' every iteration).
 * fmbe    : Ẑ is O(P·M·d) replicated compute with no vocab-sized state, so
             the estimate needs no sharding at all; only the argmax
             candidates go through the sharded IVF probe.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..core import mince as _mince
from ..core.distributed import shard_map
from ..core.estimators import combine_head_tail_lse
from ..core.feature_maps import FMBEState, fmbe_z_batch

NEG = -1e30


# ---------------------------------------------------------------------------
# exact: streaming LSE + top-1 (XLA analogue of kernels/topk_z.py)
# ---------------------------------------------------------------------------

def streaming_logz_argmax(h: jax.Array, w: jax.Array, chunk: int = 8192
                          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """h (B, d), w (V, d) -> (log_z (B,), top_id (B,), top_score (B,)).

    Chunks are shard-INTERLEAVED (row r of chunk (j, b) is b*n_chunks + j):
    with the vocab contiguously sharded over 'model', every chunk spans all
    shards so each chunk's logits dot is local — contiguous chunks would be
    materialized with a full-logits all-reduce per chunk (see losses.py)."""
    v, d = w.shape
    pad = (-v) % chunk
    wp = jnp.pad(w, ((0, pad), (0, 0))) if pad else w
    n_chunks = wp.shape[0] // chunk
    wc = wp.reshape(chunk, n_chunks, d).swapaxes(0, 1)
    b = h.shape[0]

    def body(carry, xs):
        m, s, bi, bs = carry
        wi, ci = xs
        scores = (h @ wi.T).astype(jnp.float32)
        col = jnp.arange(chunk) * n_chunks + ci
        scores = jnp.where(col[None, :] < v, scores, NEG)
        m_new = jnp.maximum(m, jnp.max(scores, -1))
        s = s * jnp.exp(m - m_new) + jnp.sum(jnp.exp(scores - m_new[:, None]),
                                             -1)
        cmax = jnp.max(scores, -1)
        carg = col[jnp.argmax(scores, -1)]
        better = cmax > bs
        return (m_new, s, jnp.where(better, carg, bi),
                jnp.maximum(bs, cmax)), None

    init = (jnp.full((b,), NEG, jnp.float32), jnp.zeros((b,), jnp.float32),
            jnp.zeros((b,), jnp.int32), jnp.full((b,), NEG, jnp.float32))
    (m, s, bi, bs), _ = lax.scan(body, init, (wc, jnp.arange(n_chunks)))
    return m + jnp.log(s), bi, bs


# ---------------------------------------------------------------------------
# vocab-sharded block-IVF machinery shared by the mimps/mince/fmbe bodies
# ---------------------------------------------------------------------------

class IVFSpecs(NamedTuple):
    """Device-resident IVF arrays; leading (block) dim sharded over 'model'."""
    v_blocks: jax.Array      # (nb, br, d)
    centroids: jax.Array     # (nb, d)
    radius: jax.Array        # (nb,)
    valid: jax.Array         # (nb, br) bool


def ivf_specs_for(vocab: int, d: int, block_rows: int, dtype,
                  shard_multiple: int = 16) -> IVFSpecs:
    """ShapeDtypeStruct skeleton for the dry run (perfect packing assumed).
    Block count is rounded up to `shard_multiple` so the leading dim shards
    over 'model' (the real builder pads clusters the same way)."""
    nb = -(-vocab // block_rows)
    nb = -(-nb // shard_multiple) * shard_multiple
    sds = jax.ShapeDtypeStruct
    return IVFSpecs(v_blocks=sds((nb, block_rows, d), dtype),
                    centroids=sds((nb, d), dtype),
                    radius=sds((nb,), jnp.float32),
                    valid=sds((nb, block_rows), jnp.bool_))


def ivf_partition_specs() -> IVFSpecs:
    return IVFSpecs(v_blocks=P("model", None, None),
                    centroids=P("model", None),
                    radius=P("model"),
                    valid=P("model", None))


def _local_probe(ivf: IVFSpecs, h: jax.Array, n_probe_local: int):
    """Coarse-probe the local shard, batched (ball upper-bound ranking).

    Returns (bids (B, p), scores (B, p, br) pad-masked to NEG, bvalid,
    k_eff (B,))."""
    qn = jnp.linalg.norm(h.astype(jnp.float32), axis=-1, keepdims=True)
    cs = (h @ ivf.centroids.T).astype(jnp.float32) + ivf.radius[None] * qn
    _, bids = lax.top_k(cs, n_probe_local)                 # (B, p)
    blocks = ivf.v_blocks[bids]                            # (B, p, br, d)
    scores = jnp.einsum("bpRd,bd->bpR", blocks, h,
                        preferred_element_type=jnp.float32)
    bvalid = ivf.valid[bids]                               # (B, p, br)
    scores = jnp.where(bvalid, scores, NEG)
    return bids, scores, bvalid, bvalid.sum(axis=(-2, -1))


def _local_tail(ivf: IVFSpecs, key: jax.Array, bids: jax.Array, h: jax.Array,
                l_local: int, axis_name: str):
    """Shared uniform tail sample over local slots + per-query rejection.

    Returns (tail (B, l), ok (B, l), n_valid_local ())."""
    nb_l, br, d = ivf.v_blocks.shape
    n_slots = nb_l * br
    flat = ivf.v_blocks.reshape(n_slots, d)
    flat_valid = ivf.valid.reshape(n_slots)
    shard = lax.axis_index(axis_name)
    slots = jax.random.randint(jax.random.fold_in(key, shard),
                               (l_local,), 0, n_slots)
    sblk = slots // br
    unprobed = ~jnp.any(sblk[None, :, None] == bids[:, None, :], axis=-1)
    ok = unprobed & flat_valid[slots][None, :]             # (B, l)
    tail = jnp.einsum("bd,ld->bl", h, flat[slots],
                      preferred_element_type=jnp.float32)
    return tail, ok, flat_valid.sum()


def _merge_candidates(bids: jax.Array, scores: jax.Array, nb_l: int, br: int,
                      axis_name: str):
    """Local argmax candidate -> O(T) all_gather merge -> global slot id."""
    fs = scores.reshape(scores.shape[0], -1)               # (B, p*br)
    am = jnp.argmax(fs, axis=-1)
    cand_s = jnp.take_along_axis(fs, am[:, None], -1)[:, 0]
    cand_i = (jnp.take_along_axis(bids, (am // br)[:, None], -1)[:, 0] * br
              + am % br)
    all_s = lax.all_gather(cand_s, axis_name, axis=0)      # (T, B)
    all_i = lax.all_gather(cand_i, axis_name, axis=0)
    best = jnp.argmax(all_s, axis=0)                       # (B,)
    top_score = jnp.take_along_axis(all_s, best[None], 0)[0]
    top_slot = jnp.take_along_axis(all_i, best[None], 0)[0]
    top_global = best.astype(jnp.int32) * nb_l * br + top_slot
    return top_global, top_score


def _logspace_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """Distributed logsumexp of per-shard partial LSEs: O(1) floats."""
    m = lax.pmax(x, axis_name)
    return m + jnp.log(lax.psum(jnp.exp(x - m), axis_name))


# ---------------------------------------------------------------------------
# per-method shard_map bodies
# ---------------------------------------------------------------------------

def _local_ivf_logz(ivf: IVFSpecs, h: jax.Array, key: jax.Array,
                    n_probe_local: int, l_local: int,
                    axis_name: str = "model"):
    """MIMPS (Eq. 5) body: each shard = its own local IVF over its vocab rows.

    Batched like core.decode: one (B, d) x (d, nb_l) centroid matmul probes
    every query at once, and the l_local tail slots are drawn once and shared
    across the batch (one (B, d) x (d, l) matmul). Eq. 5 scale uses the
    per-query unprobed population and post-rejection sample count.
    """
    nb_l, br, d = ivf.v_blocks.shape
    bids, scores, bvalid, k_eff = _local_probe(ivf, h, n_probe_local)
    head_lse = jax.nn.logsumexp(scores.reshape(h.shape[0], -1), axis=-1)
    tail, ok, n_valid = _local_tail(ivf, key, bids, h, l_local, axis_name)
    tail_lse = jax.nn.logsumexp(jnp.where(ok, tail, NEG), axis=-1)
    n_tail_total = jnp.maximum(n_valid - k_eff, 0).astype(jnp.float32)
    n_acc = ok.sum(axis=-1).astype(jnp.float32)
    local_logz = combine_head_tail_lse(head_lse, tail_lse, n_tail_total,
                                       n_acc)
    log_z = _logspace_psum(local_logz, axis_name)
    top_global, top_score = _merge_candidates(bids, scores, nb_l, br,
                                              axis_name)
    return log_z, top_global, top_score


def _local_mince_logz(ivf: IVFSpecs, h: jax.Array, key: jax.Array,
                      n_probe_local: int, l_local: int, iters: int = 3,
                      solver: str = "halley", n_bins: int = 128,
                      axis_name: str = "model"):
    """MINCE (Eq. 6/7) body: the global NCE problem, stats-combined ONCE.

    Each shard holds its slice of the atom set (local probe head + local
    tail sample) and compresses it into the fixed-size ``mince.MinceStats``
    histogram around the globally-psum'd Eq. 5 anchor. Histograms are plain
    weighted sums over samples, so ONE psum of the stacked (B, S, 4) stats
    recovers the exact global sufficient statistics — every shard then runs
    the identical bracketed Halley solve locally on one shared theta. The
    seed psum'd (f', f'', f''') every iteration; the pre-solve combine
    removes the per-iteration collective entirely (iters x 3 scalars ->
    one (B, S, 4) array, and the solve no longer serializes on the wire).
    """
    nb_l, br, d = ivf.v_blocks.shape
    b = h.shape[0]
    bids, scores, bvalid, k_eff_l = _local_probe(ivf, h, n_probe_local)
    tail, ok, n_valid_l = _local_tail(ivf, key, bids, h, l_local, axis_name)

    k_eff = lax.psum(k_eff_l, axis_name).astype(jnp.float32)
    n_acc = lax.psum(ok.sum(axis=-1), axis_name).astype(jnp.float32)
    n_valid = lax.psum(n_valid_l, axis_name).astype(jnp.float32)
    n_tail = jnp.maximum(n_valid - k_eff, 0.0)
    c_t = n_tail / jnp.maximum(n_acc, 1.0)

    head_lse_l = jax.nn.logsumexp(scores.reshape(b, -1), axis=-1)
    theta0 = _logspace_psum(head_lse_l, axis_name)
    tail_lse = _logspace_psum(
        jax.nn.logsumexp(jnp.where(ok, tail, NEG), axis=-1), axis_name)
    anchor = combine_head_tail_lse(theta0, tail_lse, n_tail, n_acc)  # (B,)

    # local anchored atoms -> local histograms on the shared (global-anchor)
    # bins -> ONE psum of the stacked sums -> identical local solves
    s_all = jnp.concatenate([scores.reshape(b, -1), tail], axis=-1)
    m_all = jnp.concatenate(
        [bvalid.reshape(b, -1).astype(jnp.float32),
         ok.astype(jnp.float32) * c_t[:, None]], axis=-1)
    alpha, wd, wn = _mince.anchored_atoms(s_all, m_all, n_valid, k_eff,
                                          n_acc, anchor)
    st = _mince.mince_stats(alpha, wd, wn, anchor, n_bins=n_bins)
    stacked = jnp.stack([st.w_data, st.w_noise,
                         st.a_data * st.w_data,
                         st.a_noise * st.w_noise], axis=-1)   # (B, S, 4)
    g = lax.psum(stacked, axis_name)
    stats = _mince.MinceStats(
        a_data=g[..., 2] / jnp.maximum(g[..., 0], 1e-30),
        w_data=g[..., 0],
        a_noise=g[..., 3] / jnp.maximum(g[..., 1], 1e-30),
        w_noise=g[..., 1], lo=st.lo, hi=st.hi)
    theta = _mince.solve_from_stats(stats, anchor, iters=iters,
                                    solver=solver)

    uniform = tail_lse + jnp.log(jnp.maximum(n_valid, 1.0)) - \
        jnp.log(jnp.maximum(n_acc, 1.0))
    log_z = jnp.where(k_eff == 0, uniform, theta)
    log_z = jnp.where((n_acc == 0) | (n_tail == 0), theta0, log_z)
    top_global, top_score = _merge_candidates(bids, scores, nb_l, br,
                                              axis_name)
    return log_z, top_global, top_score


def _local_ivf_topk(ivf: IVFSpecs, h: jax.Array,
                    n_probe_local: int, axis_name: str = "model"):
    """Candidates-only body (FMBE): probe + argmax merge, no estimate."""
    nb_l, br, _ = ivf.v_blocks.shape
    bids, scores, _, _ = _local_probe(ivf, h, n_probe_local)
    return _merge_candidates(bids, scores, nb_l, br, axis_name)


# ---------------------------------------------------------------------------
# jit-composable wrappers + the sharded dispatch
# ---------------------------------------------------------------------------

def _shard_wrap(mesh, fn, ivf, h, key, batch_spec, n_out=3):
    h_spec = P(*batch_spec, None)
    in_specs = (ivf_partition_specs(), h_spec) + ((P(),) if key is not None
                                                  else ())
    out_specs = tuple(P(*batch_spec) for _ in range(n_out))
    args = (ivf, h) + ((key,) if key is not None else ())
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_vma=False)(*args)


def sharded_ivf_decode(mesh, ivf: IVFSpecs, h: jax.Array, key: jax.Array,
                       *, n_probe_local: int, l_local: int,
                       batch_spec=P("data")):
    """Sharded MIMPS decode. h (B, d) sharded over data."""
    fn = functools.partial(_local_ivf_logz, n_probe_local=n_probe_local,
                           l_local=l_local)
    return _shard_wrap(mesh, fn, ivf, h, key, batch_spec)


def sharded_mince_decode(mesh, ivf: IVFSpecs, h: jax.Array, key: jax.Array,
                         *, n_probe_local: int, l_local: int,
                         iters: int = 3, solver: str = "halley",
                         batch_spec=P("data")):
    """Sharded MINCE decode (one pre-solve stats psum, local Halley)."""
    fn = functools.partial(_local_mince_logz, n_probe_local=n_probe_local,
                           l_local=l_local, iters=iters, solver=solver)
    return _shard_wrap(mesh, fn, ivf, h, key, batch_spec)


def sharded_fmbe_decode(mesh, ivf: IVFSpecs, h: jax.Array, key: jax.Array,
                        *, n_probe_local: int, fmbe_state: FMBEState,
                        batch_spec=P("data"), l_local: int = 0):
    """Sharded FMBE decode: replicated O(P·M·d) Ẑ + sharded candidates."""
    del key, l_local
    z = fmbe_z_batch(fmbe_state, h)
    log_z = jnp.log(jnp.maximum(z, 1e-30))
    fn = functools.partial(_local_ivf_topk, n_probe_local=n_probe_local)
    top_id, top_s = _shard_wrap(mesh, fn, ivf, h, None, batch_spec, n_out=2)
    return log_z, top_id, top_s


SHARDED_BACKENDS = {
    "mimps": sharded_ivf_decode,
    "mince": sharded_mince_decode,
    "fmbe": sharded_fmbe_decode,
}


# ---------------------------------------------------------------------------
# Mesh-serving bodies (DESIGN.md SS15): full DecodeOut inside the scheduler's
# one shard_map step, bit-identical to the single-device core.decode paths
# ---------------------------------------------------------------------------
#
# The dry-run bodies above shard EVERYTHING per shard (local probe, local
# tail) and merge top-1 — right for throughput studies, but a serving lane
# must emit the SAME tokens it would emit solo, and tokens come from the full
# sorted top-k candidate list. The mesh bodies below get bitwise identity by
# splitting the index differently:
#
#  * ``v_blocks`` (the O(V d) payload) is sharded over 'model'; everything
#    else — centroids, radius, valid, row_id, slot_of_row — is per-block
#    METADATA, O(V/br (d + br)) floats, and stays replicated.
#  * probe / dedup / trim / tail plan / top-k therefore run the *verbatim*
#    ``core.decode`` code on replicated metadata: every shard derives the
#    same DecodePlan the single device would.
#  * only the embedding-row fetch is distributed: each shard contributes its
#    owned rows of the step's working set (union head + shared tail — the
#    paper's sublinear set) and ONE psum assembles the (U*br + l, d) staging
#    buffer; the scoring matmul then runs on identical operands, so every
#    output — log Ẑ included — is bit-equal to ``mimps_decode`` & friends.
#
# Comms per step: one psum of the sublinear working set (+ the health
# guard's log-domain psum on its exact-fallback branch) — the paper's
# sublinearity lifted to the collective level, with none of the
# "distributed estimator" numerics leaking into token identity.

from ..core import decode as _decode
from ..core import mips as _mips
from ..core.decode import DecodeOut
from ..core.distributed import logspace_psum, sharded_top_k
from ..core.estimators import NEG_INF, combine_head_tail_lse


def _gather_rows_psum(flat_local: jax.Array, slots: jax.Array,
                      axis_name: str) -> jax.Array:
    """Assemble global embedding rows from the model-sharded flat block
    table: each shard gathers the slots it owns (zeros elsewhere), one psum
    of (len(slots), d) makes every shard hold the exact rows — bitwise the
    single-device ``jnp.take`` (one real addend per element, rest zero)."""
    n_loc = flat_local.shape[0]
    me = lax.axis_index(axis_name)
    loc = slots - me * n_loc
    own = (loc >= 0) & (loc < n_loc)
    rows = jnp.where(own[:, None],
                     flat_local[jnp.clip(loc, 0, n_loc - 1)],
                     jnp.zeros((), flat_local.dtype))
    return lax.psum(rows, axis_name)


def _mesh_plan(index, h: jax.Array, key: jax.Array, n_probe: int, l: int,
               active) -> "_decode.DecodePlan":
    """``core.decode.make_plan`` against an index whose ``v_blocks`` leaf is
    the LOCAL shard: identical code except capacity comes from the
    replicated ``valid`` (global block count), since ``index.n_blocks``
    would report the local shard's."""
    block_ids = _mips.probe_batch(index, h, n_probe)
    if active is not None:
        donor = block_ids[jnp.argmax(active)]
        block_ids = jnp.where(active[:, None], block_ids, donor[None, :])
    capacity = min(h.shape[0] * n_probe, index.valid.shape[0])
    head_ids, member, n_unique = _decode.plan_heads(block_ids, capacity)
    tb, tr, accept = _decode.plan_tail(index, key, l, block_ids)
    k_eff = _mips.head_count(index, block_ids)
    return _decode.DecodePlan(block_ids=block_ids, head_ids=head_ids,
                              head_live=n_unique.astype(jnp.int32),
                              head_member=member, tail_blocks=tb,
                              tail_rows=tr, tail_accept=accept, k_eff=k_eff,
                              n_accept=accept.sum(axis=-1))


def _mesh_head_scores(index, h: jax.Array, head_ids, member, tail_slots,
                      axis_name: str):
    """``core.decode._head_scores_xla`` with the row gather distributed:
    same staging-buffer layout, same fused (Q,d)x(d, U*br [+ l]) dot on
    psum-assembled operands -> bitwise-identical scores."""
    _, br, d = index.v_blocks.shape
    flat = index.v_blocks.reshape(-1, d)
    slot = (head_ids[:, None] * br +
            jnp.arange(br, dtype=jnp.int32)[None, :]).reshape(-1)
    n_head = slot.shape[0]
    if tail_slots is not None:
        slot = jnp.concatenate([slot, tail_slots])
    w = _gather_rows_psum(flat, slot, axis_name)
    scores = jax.lax.dot_general(
        h, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    mask = (member[:, :, None] & index.valid[head_ids][None]
            ).reshape(h.shape[0], -1)
    if tail_slots is not None:
        return scores[:, :n_head], mask, scores[:, n_head:]
    return scores, mask


def mesh_mimps_decode(index, h: jax.Array, key: jax.Array, *, n_probe: int,
                      l: int, k: int = 1, head_cap: int = 0, active=None,
                      axis_name: str = "model") -> DecodeOut:
    """MIMPS (Eq. 5) under the serving mesh — bit-equal to
    ``mimps_decode(..., use_pallas=False)`` at every mesh size."""
    plan = _mesh_plan(index, h, key, n_probe, l, active)
    br = index.v_blocks.shape[1]
    tail_slots = plan.tail_blocks * br + plan.tail_rows
    cap = _decode._resolve_head_cap(head_cap, n_probe,
                                    plan.head_ids.shape[0])

    def branch(ids, member):
        scores, mask, ts = _mesh_head_scores(index, h, ids, member,
                                             tail_slots, axis_name)
        tl = _decode._masked_tail_lse(ts, plan.tail_accept)
        return _decode._head_topk(index, ids, scores, mask, k) + (tl,)

    head_lse, topv, topi, tail_lse = _decode._with_trimmed_head(plan, cap,
                                                                branch)
    log_z = combine_head_tail_lse(
        head_lse, tail_lse,
        (index.n - plan.k_eff).astype(jnp.float32),
        plan.n_accept.astype(jnp.float32))
    top_id = index.row_id.reshape(-1)[topi]
    return DecodeOut(log_z=log_z, top_score=topv, top_id=top_id,
                     head_lse=head_lse, tail_lse=tail_lse, k_eff=plan.k_eff,
                     head_live=plan.head_live)


def mesh_mince_decode(index, h: jax.Array, key: jax.Array, *, n_probe: int,
                      l: int, k: int = 1, iters: int = 2,
                      solver: str = "halley", head_cap: int = 0, active=None,
                      axis_name: str = "model") -> DecodeOut:
    """MINCE (Eq. 6/7) under the serving mesh: the anchored closed form of
    ``mince_decode`` on psum-assembled rows (``iters``/``solver`` kept for
    signature parity with the cold-start solvers)."""
    del iters, solver
    assert l >= 1, "MINCE needs at least one noise sample"
    plan = _mesh_plan(index, h, key, n_probe, l, active)
    br = index.v_blocks.shape[1]
    tail_slots = plan.tail_blocks * br + plan.tail_rows
    cap = _decode._resolve_head_cap(head_cap, n_probe,
                                    plan.head_ids.shape[0])
    n = index.n
    k_eff = plan.k_eff.astype(jnp.float32)
    n_acc = plan.n_accept.astype(jnp.float32)
    n_tail = jnp.maximum(n - k_eff, 0.0)

    def branch(ids, member):
        scores, mask, ts = _mesh_head_scores(index, h, ids, member,
                                             tail_slots, axis_name)
        hl = jax.nn.logsumexp(jnp.where(mask, scores, NEG_INF), axis=-1)
        tl = _decode._masked_tail_lse(ts, plan.tail_accept)
        theta = combine_head_tail_lse(hl, tl, n_tail, n_acc)
        _, topv, topi = _decode._head_topk(index, ids, scores, mask, k)
        return hl, tl, theta, topv, topi

    head_lse, tail_lse, theta, topv, topi = _decode._with_trimmed_head(
        plan, cap, branch)
    uniform = combine_head_tail_lse(
        jnp.full_like(head_lse, NEG_INF), tail_lse,
        jnp.zeros_like(n_acc) + jnp.asarray(n, jnp.float32), n_acc)
    log_z = jnp.where(k_eff == 0, uniform, theta)
    log_z = jnp.where((n_acc == 0) | (n_tail == 0), head_lse, log_z)
    top_id = index.row_id.reshape(-1)[topi]
    return DecodeOut(log_z=log_z, top_score=topv, top_id=top_id,
                     head_lse=head_lse, tail_lse=tail_lse, k_eff=plan.k_eff,
                     head_live=plan.head_live)


def mesh_topk_decode(index, h: jax.Array, key: jax.Array, *, n_probe: int,
                     k: int = 1, head_cap: int = 0, active=None,
                     axis_name: str = "model") -> DecodeOut:
    """Head-only ladder rung (``topk_head_decode``) under the serving mesh."""
    plan = _mesh_plan(index, h, key, n_probe, 0, active)
    cap = _decode._resolve_head_cap(head_cap, n_probe,
                                    plan.head_ids.shape[0])

    def branch(ids, member):
        scores, mask = _mesh_head_scores(index, h, ids, member, None,
                                         axis_name)
        return _decode._head_topk(index, ids, scores, mask, k)

    head_lse, topv, topi = _decode._with_trimmed_head(plan, cap, branch)
    top_id = index.row_id.reshape(-1)[topi]
    return DecodeOut(log_z=head_lse, top_score=topv, top_id=top_id,
                     head_lse=head_lse,
                     tail_lse=jnp.full_like(head_lse, -jnp.inf),
                     k_eff=plan.k_eff, head_live=plan.head_live)


def mesh_fmbe_decode(state: FMBEState, index, h: jax.Array, key: jax.Array,
                     *, n_probe: int, k: int = 1, head_cap: int = 0,
                     active=None, axis_name: str = "model") -> DecodeOut:
    """FMBE under the serving mesh: the sketch (and its per-block lambda
    table) is V-independent and replicated; only the candidate head rows are
    fetched through the sharded gather."""
    plan = _mesh_plan(index, h, key, n_probe, 0, active)
    cap = _decode._resolve_head_cap(head_cap, n_probe,
                                    plan.head_ids.shape[0])

    def branch(ids, member):
        scores, mask = _mesh_head_scores(index, h, ids, member, None,
                                         axis_name)
        return _decode._head_topk(index, ids, scores, mask, k)

    head_lse, topv, topi = _decode._with_trimmed_head(plan, cap, branch)
    if state.lambda_blocks is not None:
        from ..core.feature_maps import fmbe_tail_z
        z_tail = fmbe_tail_z(state, h, plan.block_ids, use_pallas=False)
        log_z = jnp.logaddexp(head_lse,
                              jnp.log(jnp.maximum(z_tail, 1e-30)))
    else:
        z = fmbe_z_batch(state, h)
        log_z = jnp.log(jnp.maximum(z, 1e-30))
    top_id = index.row_id.reshape(-1)[topi]
    return DecodeOut(log_z=log_z, top_score=topv, top_id=top_id,
                     head_lse=head_lse,
                     tail_lse=jnp.full_like(log_z, -jnp.inf),
                     k_eff=plan.k_eff, head_live=plan.head_live)


def mesh_exact_decode(w_local: jax.Array, h: jax.Array, *, k: int = 1,
                      active=None, axis_name: str = "model") -> DecodeOut:
    """Exact log Z + top-k with the embedding row-sharded over 'model':
    local logits + log-domain psum (log Z) and the O(kT) candidate merge.
    Candidate (score, id) pairs match the dense single-device pass (each is
    a selected local dot); log Z agrees to reduction-order rounding."""
    del active
    logits = (h @ w_local.T).astype(jnp.float32)
    log_z = logspace_psum(jax.nn.logsumexp(logits, -1), axis_name)
    tk = sharded_top_k(w_local, h, k, axis_name)
    q = h.shape[0]
    v = w_local.shape[0] * lax.psum(1, axis_name)
    return DecodeOut(log_z=log_z, top_score=tk.scores.astype(jnp.float32),
                     top_id=tk.ids.astype(jnp.int32), head_lse=log_z,
                     tail_lse=jnp.full((q,), -jnp.inf),
                     k_eff=jnp.full((q,), v, jnp.int32))


def mesh_selfnorm_decode(w_local: jax.Array, h: jax.Array, *, k: int = 1,
                         active=None, axis_name: str = "model") -> DecodeOut:
    out = mesh_exact_decode(w_local, h, k=k, active=active,
                            axis_name=axis_name)
    return out._replace(log_z=jnp.zeros_like(out.log_z))


def mesh_lsh_decode(lsh_index, w_local: jax.Array, h: jax.Array,
                    key: jax.Array, *, l: int, k: int = 1, cand_cap: int = 0,
                    active=None, axis_name: str = "model") -> DecodeOut:
    """LSH collision-head decode under the serving mesh — bit-equal to
    ``lsh.lsh_decode(..., use_pallas=False)`` at every mesh size.

    The whole LSH index (hyperplanes, codes, buckets, slots — metadata,
    no embedding payload) is replicated, so ``lsh.lsh_plan`` runs VERBATIM
    and every shard derives the identical plan; only the embedding rows are
    sharded, and the step's working set (trimmed candidate union + shared
    tail) is assembled with the one ``_gather_rows_psum`` — global row ids
    against the 'model'-row-sharded ``w``."""
    from ..core import lsh as _lshmod
    assert l >= 1, "lsh decode needs at least one tail sample"
    plan = _lshmod.lsh_plan(lsh_index, h, key, l, active=active,
                            cand_cap=cand_cap)

    def branch(rows, member, col_live):
        del col_live       # membership already encodes dead columns
        slots = jnp.concatenate([rows, plan.tail_ids])
        w = _gather_rows_psum(w_local, slots,
                              axis_name).astype(jnp.float32)
        scores = jax.lax.dot_general(
            h, w, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        c = rows.shape[0]
        eff = jnp.where(member, scores[:, :c], NEG_INF)
        head_lse = jax.nn.logsumexp(eff, axis=-1)
        topv, pos = jax.lax.top_k(eff, k)
        topi = rows[pos]
        tail_lse = _decode._masked_tail_lse(scores[:, c:]
                                            + plan.tail_bias[None, :],
                                            plan.tail_accept)
        return head_lse, tail_lse, topv, topi.astype(jnp.int32)

    head_lse, tail_lse, topv, topi = _lshmod._with_trimmed_cands(
        plan, branch)
    log_z = combine_head_tail_lse(
        head_lse, tail_lse,
        (lsh_index.n - plan.k_eff).astype(jnp.float32),
        plan.n_accept.astype(jnp.float32))
    return DecodeOut(log_z=log_z, top_score=topv, top_id=topi,
                     head_lse=head_lse, tail_lse=tail_lse,
                     k_eff=plan.k_eff, head_live=plan.cand_live)


def mesh_health_guard(out: DecodeOut, w_local: jax.Array, h: jax.Array,
                      k: int, active=None, axis_name: str = "model"):
    """``core.decode.apply_health_guard`` with the exact fallback sharded.

    Flags are computed on outputs that are replicated across the model axis
    (psum-assembled scores, replicated metadata), so every shard of a model
    group agrees on the ``lax.cond`` branch and the fallback's collectives
    (the log-domain psum + candidate all_gather of ``mesh_exact_decode``)
    line up; data replicas branch independently — their collective groups
    are disjoint. Healthy lanes take the bit-identity branch, exactly as on
    a single device."""
    flags = _decode.health_flags(out)
    if active is not None:
        flags = jnp.where(active, flags, 0)
    bad = flags > 0

    def fallback():
        ex = mesh_exact_decode(w_local, h, k=k, axis_name=axis_name)
        row = bad[:, None]
        return DecodeOut(
            log_z=jnp.where(bad, ex.log_z, out.log_z),
            top_score=jnp.where(row, ex.top_score, out.top_score),
            top_id=jnp.where(row, ex.top_id, out.top_id),
            head_lse=jnp.where(bad, ex.head_lse, out.head_lse),
            tail_lse=jnp.where(bad, ex.tail_lse, out.tail_lse),
            k_eff=out.k_eff, head_live=out.head_live)

    return jax.lax.cond(jnp.any(bad), fallback, lambda: out), flags


def sharded_decode(mesh, method: str, ivf: IVFSpecs, h: jax.Array,
                   key: jax.Array, *, n_probe_local: int, l_local: int,
                   batch_spec=P("data"), **method_kwargs):
    """Vocab-sharded face of the estimator-backend registry: dispatches to
    the method's shard_map body, returning (log_z, top_id, top_score) each
    (B,). 'exact' has no IVF state — call ``streaming_logz_argmax`` with the
    sharded embedding instead."""
    try:
        fn = SHARDED_BACKENDS[method]
    except KeyError:
        raise ValueError(
            f"no sharded backend for method {method!r}; have "
            f"{sorted(SHARDED_BACKENDS)} + 'exact' via streaming_logz_argmax"
        ) from None
    return fn(mesh, ivf, h, key, n_probe_local=n_probe_local,
              l_local=l_local, batch_spec=batch_spec, **method_kwargs)
