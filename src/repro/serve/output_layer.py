"""Serve-time output layer — the paper's Eq. 2/3 under production sharding.

Two lowered paths (both used by launch/dryrun.py):

 * exact   : streaming chunked logits + online LSE + argmax over the
             vocab-sharded head. O(V d / T) compute per chip, O(B) comms.
 * mimps   : the paper's estimator, vocab-sharded block-IVF inside
             shard_map: each model shard probes its local blocks, scores
             them, tail-samples its local complement; combine = one psum
             (log Z) + one O(k) all_gather (argmax candidates).
             O((nb + p.br + l) d / T) compute per chip — sublinear in V.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..core.distributed import shard_map
from ..core.estimators import combine_head_tail_lse

NEG = -1e30


# ---------------------------------------------------------------------------
# exact: streaming LSE + top-1 (XLA analogue of kernels/topk_z.py)
# ---------------------------------------------------------------------------

def streaming_logz_argmax(h: jax.Array, w: jax.Array, chunk: int = 8192
                          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """h (B, d), w (V, d) -> (log_z (B,), top_id (B,), top_score (B,)).

    Chunks are shard-INTERLEAVED (row r of chunk (j, b) is b*n_chunks + j):
    with the vocab contiguously sharded over 'model', every chunk spans all
    shards so each chunk's logits dot is local — contiguous chunks would be
    materialized with a full-logits all-reduce per chunk (see losses.py)."""
    v, d = w.shape
    pad = (-v) % chunk
    wp = jnp.pad(w, ((0, pad), (0, 0))) if pad else w
    n_chunks = wp.shape[0] // chunk
    wc = wp.reshape(chunk, n_chunks, d).swapaxes(0, 1)
    b = h.shape[0]

    def body(carry, xs):
        m, s, bi, bs = carry
        wi, ci = xs
        scores = (h @ wi.T).astype(jnp.float32)
        col = jnp.arange(chunk) * n_chunks + ci
        scores = jnp.where(col[None, :] < v, scores, NEG)
        m_new = jnp.maximum(m, jnp.max(scores, -1))
        s = s * jnp.exp(m - m_new) + jnp.sum(jnp.exp(scores - m_new[:, None]),
                                             -1)
        cmax = jnp.max(scores, -1)
        carg = col[jnp.argmax(scores, -1)]
        better = cmax > bs
        return (m_new, s, jnp.where(better, carg, bi),
                jnp.maximum(bs, cmax)), None

    init = (jnp.full((b,), NEG, jnp.float32), jnp.zeros((b,), jnp.float32),
            jnp.zeros((b,), jnp.int32), jnp.full((b,), NEG, jnp.float32))
    (m, s, bi, bs), _ = lax.scan(body, init, (wc, jnp.arange(n_chunks)))
    return m + jnp.log(s), bi, bs


# ---------------------------------------------------------------------------
# mimps: vocab-sharded block-IVF decode (the paper's technique, distributed)
# ---------------------------------------------------------------------------

class IVFSpecs(NamedTuple):
    """Device-resident IVF arrays; leading (block) dim sharded over 'model'."""
    v_blocks: jax.Array      # (nb, br, d)
    centroids: jax.Array     # (nb, d)
    radius: jax.Array        # (nb,)
    valid: jax.Array         # (nb, br) bool


def ivf_specs_for(vocab: int, d: int, block_rows: int, dtype,
                  shard_multiple: int = 16) -> IVFSpecs:
    """ShapeDtypeStruct skeleton for the dry run (perfect packing assumed).
    Block count is rounded up to `shard_multiple` so the leading dim shards
    over 'model' (the real builder pads clusters the same way)."""
    nb = -(-vocab // block_rows)
    nb = -(-nb // shard_multiple) * shard_multiple
    sds = jax.ShapeDtypeStruct
    return IVFSpecs(v_blocks=sds((nb, block_rows, d), dtype),
                    centroids=sds((nb, d), dtype),
                    radius=sds((nb,), jnp.float32),
                    valid=sds((nb, block_rows), jnp.bool_))


def ivf_partition_specs() -> IVFSpecs:
    return IVFSpecs(v_blocks=P("model", None, None),
                    centroids=P("model", None),
                    radius=P("model"),
                    valid=P("model", None))


def _local_ivf_logz(ivf: IVFSpecs, h: jax.Array, key: jax.Array,
                    n_probe_local: int, l_local: int,
                    axis_name: str = "model"):
    """shard_map body: each shard = its own local IVF over its vocab rows.

    Batched like core.decode: one (B, d) x (d, nb_l) centroid matmul probes
    every query at once, and the l_local tail slots are drawn once and shared
    across the batch (one (B, d) x (d, l) matmul). Eq. 5 scale uses the
    per-query unprobed population and post-rejection sample count.
    """
    nb_l, br, d = ivf.v_blocks.shape
    shard = lax.axis_index(axis_name)
    n_slots = nb_l * br
    flat = ivf.v_blocks.reshape(n_slots, d)
    flat_valid = ivf.valid.reshape(n_slots)

    # coarse probe, all queries at once (ball upper bound ranking)
    qn = jnp.linalg.norm(h.astype(jnp.float32), axis=-1, keepdims=True)
    cs = (h @ ivf.centroids.T).astype(jnp.float32) + ivf.radius[None] * qn
    _, bids = lax.top_k(cs, n_probe_local)                 # (B, p)
    blocks = ivf.v_blocks[bids]                            # (B, p, br, d)
    scores = jnp.einsum("bpRd,bd->bpR", blocks, h,
                        preferred_element_type=jnp.float32)
    bvalid = ivf.valid[bids]                               # (B, p, br)
    scores = jnp.where(bvalid, scores, NEG)
    k_eff = bvalid.sum(axis=(-2, -1))                      # (B,)
    head_lse = jax.nn.logsumexp(scores.reshape(h.shape[0], -1), axis=-1)

    # shared tail sample: uniform slots, reject pads + per-query probed blocks
    slots = jax.random.randint(jax.random.fold_in(key, shard),
                               (l_local,), 0, n_slots)
    sblk = slots // br
    unprobed = ~jnp.any(sblk[None, :, None] == bids[:, None, :], axis=-1)
    ok = unprobed & flat_valid[slots][None, :]             # (B, l)
    tail = jnp.einsum("bd,ld->bl", h, flat[slots],
                      preferred_element_type=jnp.float32)
    tail_lse = jax.nn.logsumexp(jnp.where(ok, tail, NEG), axis=-1)
    n_valid = flat_valid.sum()
    n_tail_total = jnp.maximum(n_valid - k_eff, 0).astype(jnp.float32)
    n_acc = ok.sum(axis=-1).astype(jnp.float32)
    local_logz = combine_head_tail_lse(head_lse, tail_lse, n_tail_total,
                                       n_acc)

    # local argmax candidate
    fs = scores.reshape(h.shape[0], -1)                    # (B, p*br)
    am = jnp.argmax(fs, axis=-1)
    cand_s = jnp.take_along_axis(fs, am[:, None], -1)[:, 0]
    cand_i = (jnp.take_along_axis(bids, (am // br)[:, None], -1)[:, 0] * br
              + am % br)
    # combine: distributed LSE (log Z) + O(T) candidate merge (argmax)
    m = lax.pmax(local_logz, axis_name)
    z = lax.psum(jnp.exp(local_logz - m), axis_name)
    log_z = m + jnp.log(z)
    all_s = lax.all_gather(cand_s, axis_name, axis=0)      # (T, B)
    all_i = lax.all_gather(cand_i, axis_name, axis=0)
    all_shard = jnp.arange(all_s.shape[0])
    best = jnp.argmax(all_s, axis=0)                       # (B,)
    top_score = jnp.take_along_axis(all_s, best[None], 0)[0]
    top_slot = jnp.take_along_axis(all_i, best[None], 0)[0]
    top_global = best.astype(jnp.int32) * nb_l * br + top_slot
    return log_z, top_global, top_score


def sharded_ivf_decode(mesh, ivf: IVFSpecs, h: jax.Array, key: jax.Array,
                       *, n_probe_local: int, l_local: int,
                       batch_spec=P("data")):
    """jit-composable shard_map wrapper. h (B, d) sharded over data."""
    fn = functools.partial(_local_ivf_logz, n_probe_local=n_probe_local,
                           l_local=l_local)
    h_spec = P(*batch_spec, None)
    return shard_map(
        fn, mesh=mesh,
        in_specs=(ivf_partition_specs(), h_spec, P()),
        out_specs=(P(*batch_spec), P(*batch_spec), P(*batch_spec)),
        check_vma=False)(ivf, h, key)
