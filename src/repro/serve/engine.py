"""Serving engine: prefill + cached decode with partition-estimated
probabilities — the paper's inference-time use case (Eq. 2/3).

Every non-audio method dispatches through the estimator-backend registry
(``core.backends``): one batched decode returns log Ẑ plus retrieved top-k
candidates, and sampling (greedy or Gumbel-max at temperature T) happens
once on top — no per-method branching here.

decode_step cost at the output layer (embedding floats per step, Q queries):
  exact     V·d + Q·d                    (fused one-pass: kernels.topk_z)
  mimps     nb·d + U·br·d + l·d + Q·d    — fused Eq. 5 pipeline (core.decode)
  mince     nb·d + U·br·d + l·d + Q·d    — same plan; batched Halley solve
  fmbe      P·M·d + P + nb·d + U·br·d + Q·d — V-independent Ẑ, IVF head
                                           for candidates only
  selfnorm  V·d + Q·d head only          (assumes Z == 1)
U = deduplicated probed blocks <= min(Q·n_probe, nb); full accounting in
DESIGN.md SS5/SS8 and BENCH_estimators.json.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core.backends import BACKENDS, BackendState, get_backend
from ..core.decode import DecodeOut, apply_health_guard
from ..models import Model


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ServeState:
    cache: Any
    pos: jax.Array           # scalar int32: next position to write
    last_token: jax.Array    # (B,) or (B, C)


@jax.jit
def _index_digest(v_blocks: jax.Array):
    """Two-scalar integrity checksum of an IVF block tensor. The
    position-weighted sum catches row/block *permutations* (a plain sum
    would not); the sum of squares catches zeroing and drift. Deterministic:
    the same jitted reduction over the same data yields bit-equal scalars,
    so digests compare with ==."""
    x = v_blocks.astype(jnp.float32)
    nb, br, _ = x.shape
    wts = (1.0 + jnp.arange(nb * br, dtype=jnp.float32)).reshape(nb, br, 1)
    return jnp.sum(x * wts), jnp.sum(x * x)


def _digest(v_blocks) -> tuple:
    a, b = _index_digest(v_blocks)
    return (float(a), float(b))


class Engine:
    """Batched serving for one model. Retrieval state (IVF index, FMBE
    sketch) is built once from the output embedding at engine construction
    ("index build time") by the method's registered backend."""

    def __init__(self, model: Model, params, max_len: int,
                 key: Optional[jax.Array] = None, use_pallas: bool = False,
                 autotune: bool = False, autotune_batch: int = 64,
                 device_index: bool = False, health_guard: bool = False,
                 mesh=None):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.max_len = max_len
        self.use_pallas = use_pallas
        self.device_index = device_index
        self.health_guard = health_guard
        # (data, model) serving mesh (launch.mesh.make_serving_mesh) — the
        # slot scheduler runs its one compiled step under shard_map on it.
        # The engine's own jitted paths (generate(), prefill) stay
        # single-device: they are the parity oracle the mesh step is
        # measured against.
        self.mesh = mesh
        if mesh is not None:
            for ax in ("data", "model"):
                if ax not in mesh.axis_names:
                    raise ValueError(
                        f"serving mesh must have ('data','model') axes, got "
                        f"{mesh.axis_names}")
            m = mesh.shape["model"]
            if use_pallas:
                raise ValueError(
                    "mesh serving runs the XLA estimator bodies under "
                    "shard_map; use_pallas is single-device only")
            if self.cfg.n_codebooks:
                raise ValueError("mesh serving does not support audio heads")
            if m > 1 and self.cfg.vocab % m:
                raise ValueError(
                    f"vocab {self.cfg.vocab} must divide the model-parallel "
                    f"degree {m} to shard the output embedding rows")
            self._block_multiple = m
        else:
            self._block_multiple = 1
        pc = self.cfg.partition
        key = key if key is not None else jax.random.PRNGKey(0)
        self._build_key = key
        # oracle-only study estimators have no batched serving path; they
        # serve exact Z rather than failing (documented fallthrough).
        method = pc.method if pc.method in BACKENDS else "exact"
        self.backend = get_backend(method)
        if self.cfg.n_codebooks:
            # audio: small per-codebook vocab, exact softmax per codebook
            self.state = None
        else:
            self.state = self.backend.build(
                pc, model.head_matrix(params), key, device=device_index,
                block_multiple=self._block_multiple)
        self.index = self.state.index if self.state is not None else None
        # degradation-tier states (serve.server tier ladder) + integrity
        # digests, recorded at every build/swap/restore
        self._tier_states: Dict[str, Any] = {}
        self._digests: Dict[str, tuple] = {}
        self.index_restores = 0
        # observability sink (obs.Observability.attach): index-lifecycle
        # events (swap / restore) land in the trace as instants. None = off.
        self.obs = None
        if self.index is not None:
            self._digests[method] = _digest(self.index.v_blocks)
        # measured Pallas tile sizes, swept once at engine build on a
        # representative decode batch and cached on disk (kernels.autotune);
        # the per-query tiles clamp to the live batch, so one sweep covers
        # the serving range
        self.kernel_cfg: dict = {}
        if autotune and use_pallas and self.state is not None:
            h_rep = 0.1 * jax.random.normal(
                jax.random.fold_in(key, 0xA07),
                (autotune_batch, self.cfg.d_model)).astype(self.cfg.dtype)
            self.kernel_cfg = self.backend.tune(self.state, pc, h_rep, key)

    # -- train -> serve handoff ----------------------------------------------

    def swap_index(self, params, key: Optional[jax.Array] = None) -> None:
        """Hot-swap a freshly trained checkpoint into this live engine:
        replace ``params`` and rebuild the retrieval state (IVF index /
        FMBE sketch) from the new output embedding.

        Zero-recompile contract: when the engine was constructed with
        ``device_index=True``, the rebuilt state has bit-identical pytree
        structure and shapes (``mips.build_ivf_device`` fixed capacity), so
        compiled steps that take (params, backend state) as ARGUMENTS — the
        slot-table scheduler's mixed step — keep serving from their existing
        executables; the swap is one host pointer update plus the jitted
        rebuild. ``generate()``'s cached scans bake params in as constants
        and are dropped instead (they recompile lazily on next use — the
        traffic path is the scheduler, not generate()).

        ``key`` defaults to the engine's build key, so two engines built
        and swapped with the same keys hold identical state (the parity
        tests' oracle).
        """
        key = key if key is not None else self._build_key
        if self.cfg.n_codebooks:
            self.params = params
            self._scan_runners = {}
            return
        w = self.model.head_matrix(params)
        new_state = self.backend.refresh(
            self.state, self.cfg.partition, w, key, device=self.device_index,
            block_multiple=self._block_multiple)
        if self.state is not None and self.device_index:
            old = jax.tree.map(lambda x: (x.shape, x.dtype)
                               if hasattr(x, "shape") else x, self.state)
            new = jax.tree.map(lambda x: (x.shape, x.dtype)
                               if hasattr(x, "shape") else x, new_state)
            if jax.tree_util.tree_structure(old) != \
                    jax.tree_util.tree_structure(new) or \
                    jax.tree.leaves(old) != jax.tree.leaves(new):
                raise ValueError(
                    "swap_index produced a retrieval state with different "
                    "shapes — the new checkpoint's head does not match the "
                    "engine's (vocab/d_model/partition config changed?)")
        self.params = params
        self.state = new_state
        self.index = new_state.index if new_state is not None else None
        self._scan_runners = {}
        # tier states / digests derive from the old embedding: drop and
        # re-record (tiers rebuild lazily on next use)
        self._tier_states = {}
        self._digests = {}
        if self.index is not None:
            self._digests[self.backend.method] = _digest(self.index.v_blocks)
        if self.obs is not None:
            self.obs.instant("index_swap",
                             args={"method": self.backend.method})

    # -- degradation tiers + retrieval-state integrity ------------------------

    def tier_state(self, method: str):
        """The retrieval state that serves ``method`` as a degradation tier.

        Index-routed tiers (mimps / mince / topk) REUSE the engine's IVF
        index — stepping down the ladder swaps which compiled step consumes
        the same device-resident state, no rebuild. Anything else the tier
        needs beyond that (the FMBE sketch; a fresh index when the base
        method built none) is built once on first use and cached."""
        if method == self.backend.method or self.state is None:
            return self.state
        st = self._tier_states.get(method)
        if st is None:
            st = self._build_tier_state(method)
            self._tier_states[method] = st
            if st is not None and st.index is not None \
                    and method not in self._digests:
                self._digests[method] = _digest(st.index.v_blocks)
        return st

    def _build_tier_state(self, method: str):
        backend = get_backend(method)
        if method in ("exact", "selfnorm"):
            return BackendState(w=self.state.w)
        if method in ("mimps", "mince", "topk") and self.state.index is not None:
            return BackendState(w=self.state.w, index=self.state.index)
        if method == "fmbe" and self.state.index is not None:
            # fmbe as a tier / speculative-draft backend shares the engine's
            # IVF index too — only the V-independent feature sketch and its
            # per-block lambda table are built fresh (one phi pass), so a
            # draft tier costs no second kmeans and hot-swaps with the index
            from ..core.feature_maps import (FMBEState, build_fmbe_blocks,
                                             make_feature_map)
            pc = self.cfg.partition
            kf, _ = jax.random.split(self._build_key)
            fm = make_feature_map(kf, self.state.w.shape[-1],
                                  pc.fmbe_features,
                                  max_degree=pc.fmbe_max_degree, p=pc.fmbe_p)
            idx = self.state.index
            lam_b = build_fmbe_blocks(fm, idx.v_blocks, idx.valid)
            fmbe = FMBEState(fm=fm, lambda_tilde=lam_b.sum(0),
                             lambda_blocks=lam_b)
            return BackendState(w=self.state.w, index=idx, fmbe=fmbe)
        return backend.build(self.cfg.partition,
                             self.model.head_matrix(self.params),
                             self._build_key, device=self.device_index,
                             block_multiple=self._block_multiple)

    def verify_and_restore(self, method: Optional[str] = None) -> bool:
        """Checksum ``method``'s retrieval state against the digest recorded
        when it was built/swapped; on mismatch (bit-rot, bad swap, stale
        drift) rebuild every retrieval state from params BEFORE any step
        consumes the corruption. Returns True iff a restore happened."""
        method = method or self.backend.method
        st = self.tier_state(method)
        if st is None or st.index is None:
            return False
        ref = self._digests.get(method)
        d = _digest(st.index.v_blocks)
        if ref is None:
            self._digests[method] = d
            return False
        if d == ref:
            return False
        self.restore_index()
        return True

    def restore_index(self, key: Optional[jax.Array] = None) -> None:
        """Rebuild the retrieval state from the CURRENT params with the
        engine's build key. ``backend.build`` is deterministic given (params,
        key, device), so the restored state is bit-identical to the original
        build — the chaos tests' token-parity guarantee rests on this."""
        if self.cfg.n_codebooks:
            return
        key = key if key is not None else self._build_key
        w = self.model.head_matrix(self.params)
        self.state = self.backend.build(
            self.cfg.partition, w, key, device=self.device_index,
            block_multiple=self._block_multiple)
        self.index = self.state.index
        self._tier_states = {}
        self._digests = {}
        self.index_restores += 1
        if self.index is not None:
            self._digests[self.backend.method] = _digest(self.index.v_blocks)
        if self.obs is not None:
            self.obs.instant("index_restore",
                             args={"method": self.backend.method,
                                   "restores": self.index_restores})

    def _install_state(self, state, method: Optional[str] = None) -> None:
        """Fault-injection hook: install a (possibly corrupted) retrieval
        state WITHOUT updating its recorded digest — simulates a bad
        ``swap_index`` / in-place bit-rot that ``verify_and_restore`` must
        catch. Not a public serving API."""
        method = method or self.backend.method
        if method == self.backend.method:
            self.state = state
            self.index = state.index if state is not None else None
        else:
            self._tier_states[method] = state

    # -- steps (jit-compiled by callers / launch scripts) ---------------------

    def prefill(self, tokens, img=None) -> Tuple[jax.Array, ServeState]:
        """Full-sequence prefill; returns hidden of last position + state
        primed for decode. (KV caches are rebuilt decode-side for simplicity
        of the scan layout; see launch/dryrun.py for the lowered prefill.)"""
        hidden, _ = self.model.forward(self.params, tokens, img=img)
        h_last = hidden[:, -1]
        batch = tokens.shape[0]
        state = ServeState(
            cache=self.model.init_decode_state(batch, self.max_len),
            pos=jnp.zeros((), jnp.int32),
            last_token=tokens[:, -1])
        return h_last, state

    def decode_step(self, state: ServeState, key: jax.Array, img=None,
                    temperature: float = 0.0
                    ) -> Tuple[Dict[str, jax.Array], ServeState]:
        """One token for every stream; returns sampling outputs + new state.
        ``temperature`` may be a python float or a traced scalar (0 =
        greedy) — it is sampling data, not a compile-time constant.

        Cache-capacity guard: a concrete (eager / host-loop) position past
        ``max_len`` raises — the KV write would silently clobber or wrap.
        Inside a compiled step the position is clamped to the last slot and
        the step is flagged in ``out["overflow"]`` instead (a traced value
        cannot raise); callers that loop (generate, the slot scheduler)
        bound their step counts so the flag never fires in normal service.
        """
        pos = state.pos
        if not isinstance(pos, jax.core.Tracer):
            if int(jnp.max(jnp.asarray(pos))) >= self.max_len:
                raise ValueError(
                    f"decode position {jnp.max(jnp.asarray(pos))} is past "
                    f"the KV-cache capacity max_len={self.max_len}; the "
                    f"write would wrap/clobber earlier positions")
        overflow = pos >= self.max_len
        pos_safe = jnp.minimum(pos, self.max_len - 1)
        h, new_cache = self.model.decode_step(
            self.params, state.cache, state.last_token, pos_safe, img=img)
        out = self.next_token_distribution(h, key, temperature)
        out["overflow"] = overflow
        new_state = ServeState(cache=new_cache, pos=state.pos + 1,
                               last_token=out["token"])
        return out, new_state

    # -- the paper's Eq. 2/3 at the output layer ------------------------------

    def next_token_distribution(self, h: jax.Array, key: jax.Array,
                                temperature: float = 0.0
                                ) -> Dict[str, jax.Array]:
        """Sample one token per stream. Greedy at temperature == 0;
        otherwise Gumbel-max over the retrieved head candidates with the
        reported probability normalized by the estimated log Ẑ.

        ``temperature`` is *traced data* (float or scalar array): changing
        it never recompiles, so the per-slot scheduler can thread one
        temperature per stream through the same executable. The backend
        always retrieves ``sample_k`` candidates — greedy decodes take the
        top-1 of the same (sorted) retrieval, so the candidate shape stays
        temperature-independent."""
        cfg = self.cfg
        k_est, k_samp = jax.random.split(key)
        if cfg.n_codebooks:
            # audio: exact per-codebook softmax; temperature over full logits
            t = jnp.asarray(temperature, jnp.float32)
            w = self.model.head_matrix(self.params)
            logits = jnp.einsum("bd,cvd->bcv", h, w)
            log_z = jax.nn.logsumexp(logits, -1)
            g = jax.random.gumbel(k_samp, logits.shape)
            safe_t = jnp.where(t > 0.0, t, 1.0)
            tok = jnp.where(t > 0.0,
                            jnp.argmax(logits / safe_t + g, -1),
                            jnp.argmax(logits, -1))
            tok = tok.astype(jnp.int32)
            top = jnp.take_along_axis(logits, tok[..., None], -1)[..., 0]
            return {"token": tok, "log_prob": top - log_z, "log_z": log_z}

        pc = cfg.partition
        out = self.backend.decode(self.state, h, k_est, pc, k=pc.sample_k,
                                  use_pallas=self.use_pallas,
                                  **self.kernel_cfg)
        if self.health_guard and self.state is not None:
            # identity when every lane is healthy (the lax.cond keep branch
            # returns the estimate bit-unchanged), exact fused fallback for
            # any lane whose estimate went non-finite/empty
            out, _ = apply_health_guard(out, self.state.w, h, pc.sample_k)
        return _sample_candidates(out, k_samp, temperature)


def _sample_candidates(out: DecodeOut, key: jax.Array,
                       temperature) -> Dict[str, jax.Array]:
    """Gumbel-max over retrieved candidates: token ~ softmax(s/T) restricted
    to the head. log_prob reports the model's T=1 probability of the chosen
    token, normalized with the estimated log Ẑ (selfnorm's Ẑ == 1).
    ``temperature`` is a traced scalar (0 = greedy: index 0 of the sorted
    candidates); the gumbel draw happens unconditionally so the executable
    is shared across temperatures — counter-based keys mean the unused draw
    perturbs nothing else."""
    t = jnp.asarray(temperature, jnp.float32)
    g = jax.random.gumbel(key, out.top_score.shape)
    safe_t = jnp.where(t > 0.0, t, 1.0)
    pick = jnp.where(t > 0.0,
                     jnp.argmax(out.top_score / safe_t + g, axis=-1),
                     jnp.zeros(out.top_score.shape[:1], jnp.int32)
                     ).astype(jnp.int32)
    tok = jnp.take_along_axis(out.top_id, pick[:, None], 1)[:, 0]
    score = jnp.take_along_axis(out.top_score, pick[:, None], 1)[:, 0]
    return {"token": tok.astype(jnp.int32), "log_prob": score - out.log_z,
            "log_z": out.log_z}


def generate(engine: Engine, prompt, n_tokens: int, key: jax.Array,
             img=None, temperature: float = 0.0, host_loop: bool = False,
             return_aux: bool = False):
    """Generation loop; greedy at temperature == 0.0, Gumbel-max candidate
    sampling otherwise. Returns (B, n_tokens) ids.

    Device-resident by default: prompt replay and generation run as ONE
    compiled ``jax.lax.scan`` over decode steps — per-step keys are
    pre-split, every replay step force-feeds its prompt token, and the whole
    loop is a single XLA dispatch (the seed dispatched one jitted step per
    token from Python, paying a host round-trip per generated token).
    ``host_loop=True`` keeps the step-by-step Python loop as a debug mode;
    both paths produce bit-identical tokens / log_prob / log_z
    (tests/test_generate.py pins this).

    The prompt is replayed through the decode cache; the last replay step
    already emits position 0's sample, so there is no separate prefill
    forward or full-output-layer pass (the seed engine ran both and
    discarded their results)."""
    if prompt.shape[1] == 0:
        raise ValueError(
            "generate() needs a non-empty prompt: the first sample is "
            "emitted by the last prompt-replay step, so there is nothing "
            "to condition on (the seed crashed here with UnboundLocalError)")
    if n_tokens < 1:
        raise ValueError(f"n_tokens must be >= 1, got {n_tokens}")
    t_replay = prompt.shape[1]
    if t_replay + n_tokens - 1 > engine.max_len:
        raise ValueError(
            f"prompt length {t_replay} + {n_tokens} generated tokens needs "
            f"{t_replay + n_tokens - 1} cache positions but the engine was "
            f"built with max_len={engine.max_len}; the KV write past "
            f"capacity would clobber earlier positions")
    if host_loop:
        return _generate_host(engine, prompt, n_tokens, key, img=img,
                              temperature=temperature, return_aux=return_aux)
    # Bucket the replay length to the next power of two so heterogeneous
    # prompt lengths share ONE compiled scan per bucket (the seed compiled a
    # fresh replay+decode scan for every distinct prompt length). The scan
    # runs `bucket + n_tokens - 1` steps; replay/generation switchover gates
    # on the TRUE length via the traced is_replay flags and fold schedule,
    # and the emitted window is cut out with a traced dynamic slice — pad
    # steps trail the real ones, burn a few decode steps, and are discarded.
    bucket = 1 << (t_replay - 1).bit_length()
    total = bucket + n_tokens - 1
    step_ix = jnp.arange(total, dtype=jnp.int32)
    fold_ids = jnp.where(step_ix < t_replay, step_ix,
                         10_000 + step_ix - t_replay)
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(fold_ids)
    # prompt tokens step-major, padded to the full scan length (the padding
    # is never read: is_replay gates on t < t_replay)
    prompt_sm = jnp.moveaxis(prompt, 1, 0)
    pad = total - t_replay
    prompt_sm = jnp.concatenate(
        [prompt_sm, jnp.zeros((pad,) + prompt_sm.shape[1:],
                              prompt_sm.dtype)]) if pad else prompt_sm
    is_replay = step_ix < t_replay
    batch_shape = prompt.shape[:1] + prompt.shape[2:]
    run = _scan_runner(engine, batch_shape, str(jnp.asarray(prompt).dtype),
                       bucket, n_tokens)
    toks, lp, lz = run(prompt_sm, keys, is_replay,
                       jnp.asarray(t_replay - 1, jnp.int32),
                       jnp.asarray(temperature, jnp.float32), img)
    if return_aux:
        return toks, {"log_prob": lp, "log_z": lz}
    return toks


def _scan_runner(engine: Engine, batch_shape, prompt_dtype, bucket: int,
                 n_tokens: int):
    """Build (or fetch) the compiled scan for one (engine, batch, replay
    bucket, n_tokens) cell.

    The executable is cached on the engine: jit keys its trace cache on the
    function object, so a fresh inner ``run`` per generate() call would
    recompile the whole replay+decode scan every request — exactly the
    dispatch overhead the device-resident loop exists to remove. ``img`` is
    a traced *argument* (not a closure constant) so cached executables serve
    changing images; the true replay length (as ``t_start``: the step index
    of the first emitted sample) and the temperature are traced arguments
    too, so neither prompt-length variation within a bucket nor a sampling-
    parameter change ever recompiles.
    """
    cache = getattr(engine, "_scan_runners", None)
    if cache is None:
        cache = engine._scan_runners = {}
    key = (batch_shape, prompt_dtype, bucket, n_tokens)
    run = cache.get(key)
    if run is not None:
        return run

    @jax.jit
    def run(prompt_sm, keys, is_replay, t_start, temperature, img):
        state = ServeState(
            cache=engine.model.init_decode_state(batch_shape[0],
                                                 engine.max_len),
            pos=jnp.zeros((), jnp.int32),
            last_token=prompt_sm[0])

        def step(state, xs):
            k_t, tok_t, replay_t = xs
            last = jnp.where(replay_t, tok_t, state.last_token)
            state = dataclasses.replace(state, last_token=last)
            out, state = engine.decode_step(state, k_t, img=img,
                                            temperature=temperature)
            return state, (out["token"], out["log_prob"], out["log_z"])

        _, (toks, lp, lz) = jax.lax.scan(step, state,
                                         (keys, prompt_sm, is_replay))
        # steps 0..t_start-1 replay the prompt; the emitted samples start at
        # the last replay step (position 0 of the generation) and any
        # bucket-padding steps trail behind the emitted window
        cut = lambda a: jax.lax.dynamic_slice_in_dim(a, t_start, n_tokens, 0)
        return (jnp.moveaxis(cut(toks), 0, 1),
                jnp.moveaxis(cut(lp), 0, 1), jnp.moveaxis(cut(lz), 0, 1))

    cache[key] = run
    return run


def _generate_host(engine: Engine, prompt, n_tokens: int, key: jax.Array,
                   img=None, temperature: float = 0.0,
                   return_aux: bool = False):
    """Debug path: one jitted decode_step dispatch per token (the seed
    loop). Key schedule matches the scan path exactly."""
    batch = prompt.shape[0]
    state = ServeState(
        cache=engine.model.init_decode_state(batch, engine.max_len),
        pos=jnp.zeros((), jnp.int32),
        last_token=prompt[:, 0])
    outs = []
    step_fn = jax.jit(lambda s, k: engine.decode_step(
        s, k, img=img, temperature=temperature))
    out = None
    for t in range(prompt.shape[1]):
        tok_t = prompt[:, t] if not engine.cfg.n_codebooks \
            else prompt[:, t, :]
        state = dataclasses.replace(state, last_token=tok_t)
        out, state = step_fn(state, jax.random.fold_in(key, t))
    outs.append(out)
    for t in range(n_tokens - 1):
        out, state = step_fn(state, jax.random.fold_in(key, 10_000 + t))
        outs.append(out)
    toks = jnp.stack([o["token"] for o in outs], axis=1)
    if return_aux:
        return toks, {
            "log_prob": jnp.stack([o["log_prob"] for o in outs], axis=1),
            "log_z": jnp.stack([o["log_z"] for o in outs], axis=1)}
    return toks
