"""Serving engine: prefill + cached decode with partition-estimated
probabilities — the paper's inference-time use case (Eq. 2/3).

Every non-audio method dispatches through the estimator-backend registry
(``core.backends``): one batched decode returns log Ẑ plus retrieved top-k
candidates, and sampling (greedy or Gumbel-max at temperature T) happens
once on top — no per-method branching here.

decode_step cost at the output layer (embedding floats per step, Q queries):
  exact     V·d + Q·d                    (fused one-pass: kernels.topk_z)
  mimps     nb·d + U·br·d + l·d + Q·d    — fused Eq. 5 pipeline (core.decode)
  mince     nb·d + U·br·d + l·d + Q·d    — same plan; batched Halley solve
  fmbe      P·M·d + P + nb·d + U·br·d + Q·d — V-independent Ẑ, IVF head
                                           for candidates only
  selfnorm  V·d + Q·d head only          (assumes Z == 1)
U = deduplicated probed blocks <= min(Q·n_probe, nb); full accounting in
DESIGN.md SS5/SS8 and BENCH_estimators.json.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core.backends import BACKENDS, get_backend
from ..core.decode import DecodeOut
from ..models import Model


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ServeState:
    cache: Any
    pos: jax.Array           # scalar int32: next position to write
    last_token: jax.Array    # (B,) or (B, C)


class Engine:
    """Batched serving for one model. Retrieval state (IVF index, FMBE
    sketch) is built once from the output embedding at engine construction
    ("index build time") by the method's registered backend."""

    def __init__(self, model: Model, params, max_len: int,
                 key: Optional[jax.Array] = None, use_pallas: bool = False):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.max_len = max_len
        self.use_pallas = use_pallas
        pc = self.cfg.partition
        key = key if key is not None else jax.random.PRNGKey(0)
        # oracle-only study estimators have no batched serving path; they
        # serve exact Z rather than failing (documented fallthrough).
        method = pc.method if pc.method in BACKENDS else "exact"
        self.backend = get_backend(method)
        if self.cfg.n_codebooks:
            # audio: small per-codebook vocab, exact softmax per codebook
            self.state = None
        else:
            self.state = self.backend.build(pc, model.head_matrix(params),
                                            key)
        self.index = self.state.index if self.state is not None else None

    # -- steps (jit-compiled by callers / launch scripts) ---------------------

    def prefill(self, tokens, img=None) -> Tuple[jax.Array, ServeState]:
        """Full-sequence prefill; returns hidden of last position + state
        primed for decode. (KV caches are rebuilt decode-side for simplicity
        of the scan layout; see launch/dryrun.py for the lowered prefill.)"""
        hidden, _ = self.model.forward(self.params, tokens, img=img)
        h_last = hidden[:, -1]
        batch = tokens.shape[0]
        state = ServeState(
            cache=self.model.init_decode_state(batch, self.max_len),
            pos=jnp.zeros((), jnp.int32),
            last_token=tokens[:, -1])
        return h_last, state

    def decode_step(self, state: ServeState, key: jax.Array, img=None,
                    temperature: float = 0.0
                    ) -> Tuple[Dict[str, jax.Array], ServeState]:
        """One token for every stream; returns sampling outputs + new state.
        ``temperature`` must be a static python float (0.0 = greedy)."""
        h, new_cache = self.model.decode_step(
            self.params, state.cache, state.last_token, state.pos, img=img)
        out = self.next_token_distribution(h, key, temperature)
        new_state = ServeState(cache=new_cache, pos=state.pos + 1,
                               last_token=out["token"])
        return out, new_state

    # -- the paper's Eq. 2/3 at the output layer ------------------------------

    def next_token_distribution(self, h: jax.Array, key: jax.Array,
                                temperature: float = 0.0
                                ) -> Dict[str, jax.Array]:
        """Sample one token per stream. Greedy at temperature == 0.0;
        otherwise Gumbel-max over the retrieved head candidates with the
        reported probability normalized by the estimated log Ẑ."""
        cfg = self.cfg
        k_est, k_samp = jax.random.split(key)
        if cfg.n_codebooks:
            # audio: exact per-codebook softmax; temperature over full logits
            w = self.model.head_matrix(self.params)
            logits = jnp.einsum("bd,cvd->bcv", h, w)
            log_z = jax.nn.logsumexp(logits, -1)
            if temperature > 0.0:
                g = jax.random.gumbel(k_samp, logits.shape)
                tok = jnp.argmax(logits / temperature + g, -1)
            else:
                tok = jnp.argmax(logits, -1)
            tok = tok.astype(jnp.int32)
            top = jnp.take_along_axis(logits, tok[..., None], -1)[..., 0]
            return {"token": tok, "log_prob": top - log_z, "log_z": log_z}

        pc = cfg.partition
        n_cand = pc.sample_k if temperature > 0.0 else 1
        out = self.backend.decode(self.state, h, k_est, pc, k=n_cand,
                                  use_pallas=self.use_pallas)
        return _sample_candidates(out, k_samp, temperature)


def _sample_candidates(out: DecodeOut, key: jax.Array,
                       temperature: float) -> Dict[str, jax.Array]:
    """Gumbel-max over retrieved candidates: token ~ softmax(s/T) restricted
    to the head. log_prob reports the model's T=1 probability of the chosen
    token, normalized with the estimated log Ẑ (selfnorm's Ẑ == 1)."""
    if temperature > 0.0:
        g = jax.random.gumbel(key, out.top_score.shape)
        pick = jnp.argmax(out.top_score / temperature + g, axis=-1)
    else:
        pick = jnp.zeros(out.top_score.shape[:1], jnp.int32)  # scores sorted
    tok = jnp.take_along_axis(out.top_id, pick[:, None], 1)[:, 0]
    score = jnp.take_along_axis(out.top_score, pick[:, None], 1)[:, 0]
    return {"token": tok.astype(jnp.int32), "log_prob": score - out.log_z,
            "log_z": out.log_z}


def generate(engine: Engine, prompt, n_tokens: int, key: jax.Array,
             img=None, temperature: float = 0.0):
    """Generation loop (host-driven); greedy at temperature == 0.0, Gumbel-max
    candidate sampling otherwise. Returns (B, n_tokens) ids.

    The prompt is replayed through the decode cache; the last replay step
    already emits position 0's sample, so there is no separate prefill
    forward or full-output-layer pass (the seed engine ran both and
    discarded their results)."""
    batch = prompt.shape[0]
    state = ServeState(
        cache=engine.model.init_decode_state(batch, engine.max_len),
        pos=jnp.zeros((), jnp.int32),
        last_token=prompt[:, 0])
    toks = []
    step_fn = jax.jit(lambda s, k: engine.decode_step(
        s, k, img=img, temperature=temperature))
    for t in range(prompt.shape[1]):
        tok_t = prompt[:, t] if not engine.cfg.n_codebooks \
            else prompt[:, t, :]
        state = dataclasses.replace(state, last_token=tok_t)
        out, state = step_fn(state, jax.random.fold_in(key, t))
    toks.append(out["token"])
    for t in range(n_tokens - 1):
        out, state = step_fn(state, jax.random.fold_in(key, 10_000 + t))
        toks.append(out["token"])
    return jnp.stack(toks, axis=1)
