"""Serving engine: prefill + cached decode with partition-estimated
probabilities — the paper's inference-time use case (Eq. 2/3).

decode_step cost at the output layer:
  exact     O(V d)         (fused one-pass: kernels.topk_z)
  mimps     O(nb d + U*br d + l d)  — sublinear fused pipeline (core.decode):
            batched coarse probe, deduplicated head blocks, shared tail
            sample; one Pallas kernel from probe table to log-Ẑ under
            use_pallas, the XLA gather reference otherwise.
  selfnorm  O(k d)         (head only; assumes Z == 1)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core import mips
from ..core.decode import mimps_decode
from ..models import Model


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ServeState:
    cache: Any
    pos: jax.Array           # scalar int32: next position to write
    last_token: jax.Array    # (B,) or (B, C)


class Engine:
    """Batched serving for one model. Retrieval state (IVF) is built once
    from the output embedding at engine construction ("index build time")."""

    def __init__(self, model: Model, params, max_len: int,
                 key: Optional[jax.Array] = None, use_pallas: bool = False):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.max_len = max_len
        self.use_pallas = use_pallas
        pc = self.cfg.partition
        self.index = None
        key = key if key is not None else jax.random.PRNGKey(0)
        w = model.head_matrix(params)
        if pc.method == "mimps" and not self.cfg.n_codebooks \
                and w.shape[0] >= 4 * pc.block_rows:
            self.index = mips.build_ivf(key, w, block_rows=pc.block_rows,
                                        n_clusters=pc.n_clusters)

    # -- steps (jit-compiled by callers / launch scripts) ---------------------

    def prefill(self, tokens, img=None) -> Tuple[jax.Array, ServeState]:
        """Full-sequence prefill; returns hidden of last position + state
        primed for decode. (KV caches are rebuilt decode-side for simplicity
        of the scan layout; see launch/dryrun.py for the lowered prefill.)"""
        hidden, _ = self.model.forward(self.params, tokens, img=img)
        h_last = hidden[:, -1]
        batch = tokens.shape[0]
        state = ServeState(
            cache=self.model.init_decode_state(batch, self.max_len),
            pos=jnp.zeros((), jnp.int32),
            last_token=tokens[:, -1])
        return h_last, state

    def decode_step(self, state: ServeState, key: jax.Array, img=None,
                    temperature: float = 0.0
                    ) -> Tuple[Dict[str, jax.Array], ServeState]:
        """One token for every stream; returns sampling outputs + new state."""
        h, new_cache = self.model.decode_step(
            self.params, state.cache, state.last_token, state.pos, img=img)
        out = self.next_token_distribution(h, key, temperature)
        new_state = ServeState(cache=new_cache, pos=state.pos + 1,
                               last_token=out["token"])
        return out, new_state

    # -- the paper's Eq. 2/3 at the output layer ------------------------------

    def next_token_distribution(self, h: jax.Array, key: jax.Array,
                                temperature: float = 0.0
                                ) -> Dict[str, jax.Array]:
        cfg = self.cfg
        pc = cfg.partition
        w = self.model.head_matrix(self.params)
        if cfg.n_codebooks:
            # audio: small per-codebook vocab -> exact softmax per codebook
            logits = jnp.einsum("bd,cvd->bcv", h, w)
            log_z = jax.nn.logsumexp(logits, -1)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            top = jnp.max(logits, -1)
            return {"token": tok, "log_prob": top - log_z, "log_z": log_z}

        if pc.method == "mimps" and self.index is not None:
            # fused batched pipeline: one coarse-probe matmul, deduplicated
            # head blocks, shared tail sample, Eq. 5 combine with
            # n_tail_total = N - k_eff and the post-rejection sample count.
            out = mimps_decode(self.index, h, key, n_probe=pc.n_probe,
                               l=pc.l, k=1, use_pallas=self.use_pallas)
            return {"token": out.top_id[:, 0].astype(jnp.int32),
                    "log_prob": out.top_score[:, 0] - out.log_z,
                    "log_z": out.log_z}

        if pc.method == "selfnorm":
            # head-only argmax; Z assumed 1 (trained with selfnorm loss)
            logits = h @ w.T
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            top = jnp.max(logits, -1)
            return {"token": tok, "log_prob": top,
                    "log_z": jnp.zeros_like(top)}

        # exact: fused single pass (Pallas on TPU, streaming XLA elsewhere)
        if self.use_pallas:
            from ..kernels.ops import fused_topk_z
            lse, topv, topi = fused_topk_z(h, w, k=1)
            return {"token": topi[:, 0], "log_prob": topv[:, 0] - lse,
                    "log_z": lse}
        logits = h @ w.T
        log_z = jax.nn.logsumexp(logits, -1)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        return {"token": tok, "log_prob": jnp.max(logits, -1) - log_z,
                "log_z": log_z}


def generate(engine: Engine, prompt, n_tokens: int, key: jax.Array,
             img=None):
    """Greedy generation loop (host-driven); returns (B, n_tokens) ids."""
    h, state = engine.prefill(prompt, img=img)
    out0 = engine.next_token_distribution(h, key)
    state = ServeState(cache=state.cache, pos=state.pos,
                       last_token=prompt[:, -1])
    toks = []
    step_fn = jax.jit(lambda s, k: engine.decode_step(s, k, img=img))
    # replay the prompt through the cache, then free-run
    for t in range(prompt.shape[1]):
        tok_t = prompt[:, t] if not engine.cfg.n_codebooks \
            else prompt[:, t, :]
        state = dataclasses.replace(state, last_token=tok_t)
        out, state = step_fn(state, jax.random.fold_in(key, t))
    toks.append(out["token"])
    for t in range(n_tokens - 1):
        out, state = step_fn(state, jax.random.fold_in(key, 10_000 + t))
        toks.append(out["token"])
    return jnp.stack(toks, axis=1)
