"""Continuous-batching slot scheduler: ONE compiled mixed step for a
fixed-capacity slot table (DESIGN.md SS12).

``Engine.generate`` serves one synchronous same-length batch per call; under
real traffic that leaves slots idle while the longest request drains and
recompiles whenever shapes drift. This module holds per-request decode state
in a padded device batch of ``n_slots`` lanes — KV-cache lane, position,
remaining-token budget, per-slot PRNG key, per-slot sampling params
(temperature / sample_k as *traced arrays*) — and advances every live lane
together with a single jitted step:

 * **Mixed prefill/decode.** Prompt replay is chunked into the decode path
   one token per step (the same replay-through-cache trick generate() uses),
   so a lane mid-replay and a lane mid-generation ride the SAME executable:
   admitting a request never stalls in-flight decodes and never recompiles.
 * **Shared estimator work.** The batched backend decode runs once over all
   lanes; the probe-union dedup that makes retrieval estimators pay off
   under load (U <= min(Q*n_probe, nb)) happens across *requests*. Inactive
   lanes are masked out of the union (``core.decode.make_plan(active=...)``)
   so a half-empty table never pays for garbage probes.
 * **Per-slot sampling on generate()'s key schedule.** Each lane folds its
   own request key with its own stream-step index (``fold_in(key, t)`` on
   replay, ``fold_in(key, 10000 + t)`` after), splits off the sampling key,
   and draws its own Gumbel noise — so a request decoded in a busy slot
   table emits bit-identical tokens to the same request run alone through
   ``generate()`` (tests/test_scheduler.py pins this).
 * **Slot recycling.** A finished lane is marked inactive on device and
   returned to the host free list; the next admission rewinds the lane to
   position 0 — stale KV above the new request's frontier is masked by the
   per-slot validity window, so no cache zeroing is needed.

Both jitted entry points (``_step``, ``_admit``) carry trace counters:
after one step and one admission, NOTHING recompiles — asserted by tests
and by ``benchmarks/serving_bench.py``.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from functools import partial
from typing import Any, Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

_REQ_IDS = itertools.count()


@dataclasses.dataclass
class Request:
    """One serving request. ``key`` may be a PRNG key array or an int seed;
    it drives this request's sampling exactly as the same key would in
    ``generate()``. ``sample_k=0`` means the engine's configured
    ``sample_k``; smaller values restrict Gumbel-max to the top candidates.
    """
    prompt: Any                       # (L,) ints (list / np / jax array)
    max_new_tokens: int
    key: Any = 0
    temperature: float = 0.0
    sample_k: int = 0
    on_token: Optional[Callable] = None     # fn(request, token, wall_time)
    on_complete: Optional[Callable] = None  # fn(request, completion)
    req_id: int = dataclasses.field(default_factory=lambda: next(_REQ_IDS))

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if np.ndim(self.key) == 0:
            self.key = jax.random.PRNGKey(int(self.key))


@dataclasses.dataclass
class Completion:
    """Streamed back through ``Request.on_complete`` and returned by
    ``Scheduler.step`` when a lane finishes."""
    request: Request
    tokens: List[int]
    log_probs: List[float]
    log_zs: List[float]
    admit_time: float
    first_token_time: Optional[float]
    done_time: float
    overflowed: bool = False
    error: Optional[str] = None    # set when admission rejected the request
                                   # (tokens stay empty)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SlotTable:
    """Device-resident per-lane decode state (everything the mixed step
    reads or writes; one pytree, one dispatch)."""
    cache: Any              # model decode state, batch = n_slots
    prompt: jax.Array       # (S, P_cap) padded prompt tokens
    last_token: jax.Array   # (S,)  lane's previous sampled token
    t_stream: jax.Array     # (S,)  step index within the lane's request ==
                            #       the lane's next KV position (one token
                            #       is consumed at position t per step)
    t_replay: jax.Array     # (S,)  lane's true prompt length
    budget: jax.Array       # (S,)  tokens still to emit
    req_key: jax.Array      # (S, 2) per-request PRNG key
    temperature: jax.Array  # (S,)  per-slot sampling temperature
    sample_k: jax.Array     # (S,)  per-slot candidate restriction
    active: jax.Array       # (S,)  lane holds a live request
    step_idx: jax.Array     # ()    global step counter (estimator PRNG)


def sample_slots(out, keys: jax.Array, temperature: jax.Array,
                 sample_k: Optional[jax.Array] = None):
    """Per-slot Gumbel-max over retrieved candidates: the traced-array
    generalization of ``engine._sample_candidates`` — one temperature, key
    and candidate budget PER ROW. Bit-compatible with the batch-shared
    sampler lane-for-lane when ``sample_k`` equals the retrieved width (the
    gumbel draw per lane matches the solo (1, k) draw exactly).

    keys (S, 2) are each lane's k_samp; temperature (S,) with 0 = greedy
    (index 0 of the sorted candidates); sample_k (S,) restricts lane s to
    its top ``sample_k[s]`` candidates.
    """
    kc = out.top_score.shape[1]
    g = jax.vmap(lambda k: jax.random.gumbel(k, (1, kc))[0])(keys)
    t = jnp.asarray(temperature, jnp.float32)
    safe_t = jnp.where(t > 0.0, t, 1.0)
    noisy = out.top_score / safe_t[:, None] + g
    if sample_k is not None:
        allowed = jnp.arange(kc)[None, :] < \
            jnp.maximum(sample_k, 1)[:, None]
        noisy = jnp.where(allowed, noisy, -jnp.inf)
    pick = jnp.where(t > 0.0, jnp.argmax(noisy, axis=-1),
                     jnp.zeros(t.shape, jnp.int32)).astype(jnp.int32)
    tok = jnp.take_along_axis(out.top_id, pick[:, None], 1)[:, 0]
    score = jnp.take_along_axis(out.top_score, pick[:, None], 1)[:, 0]
    return tok.astype(jnp.int32), score


class Scheduler:
    """Fixed-capacity continuous-batching scheduler over one ``Engine``.

    Host-side: a free-slot list, per-slot request bookkeeping, streaming
    callbacks. Device-side: the ``SlotTable`` plus two jitted functions —
    ``_admit`` (traced slot index: one compile serves every slot) and
    ``_step`` (the mixed replay/decode step). Audio (multi-codebook) heads
    have no slot-table path; use ``generate``.
    """

    def __init__(self, engine, n_slots: int, prompt_cap: Optional[int] = None,
                 key: Optional[jax.Array] = None):
        if engine.cfg.n_codebooks:
            raise NotImplementedError(
                "the slot scheduler serves single-stream text heads; "
                "audio codebook decoding goes through serve.generate")
        self.engine = engine
        self.n_slots = n_slots
        self.prompt_cap = int(prompt_cap or engine.max_len)
        self.key = key if key is not None else jax.random.PRNGKey(0)
        self.step_traces = 0
        self.admit_traces = 0
        self._free = list(range(n_slots))
        self._slot_req: List[Optional[Request]] = [None] * n_slots
        self._slot_acc: List[Optional[Completion]] = [None] * n_slots
        self.table = self._init_table()
        self._step_fn = self._build_step()
        self._admit_fn = self._build_admit()

    # -- device state --------------------------------------------------------

    def _init_table(self) -> SlotTable:
        s = self.n_slots
        eng = self.engine
        return SlotTable(
            cache=eng.model.init_decode_state(s, eng.max_len),
            prompt=jnp.zeros((s, self.prompt_cap), jnp.int32),
            last_token=jnp.zeros((s,), jnp.int32),
            t_stream=jnp.zeros((s,), jnp.int32),
            t_replay=jnp.ones((s,), jnp.int32),
            budget=jnp.zeros((s,), jnp.int32),
            req_key=jnp.zeros((s, 2), jnp.uint32),
            temperature=jnp.zeros((s,), jnp.float32),
            sample_k=jnp.ones((s,), jnp.int32),
            active=jnp.zeros((s,), bool),
            step_idx=jnp.zeros((), jnp.int32))

    def _build_step(self):
        eng = self.engine
        model = eng.model
        pc = eng.cfg.partition
        backend = eng.backend
        kernel_cfg = dict(eng.kernel_cfg)
        use_pallas = eng.use_pallas
        max_len = eng.max_len
        est_key = jax.random.fold_in(self.key, 0xE57)
        # donate the table: the step updates the KV cache in place instead
        # of allocating + copying n_slots x max_len of it per token (CPU has
        # no donation support and would warn on every compile, so gate it)
        donate = (0,) if jax.default_backend() != "cpu" else ()

        # params and the retrieval state are traced ARGUMENTS, not closure
        # constants: Engine.swap_index can hand a freshly trained checkpoint
        # to a live server and the very next step serves it from the same
        # executable (shapes are identical under device_index=True)
        @partial(jax.jit, donate_argnums=donate)
        def step(table: SlotTable, params, bstate):
            self.step_traces += 1   # python side effect: counts (re)traces
            # -- input token: next prompt token while replaying, else the
            #    lane's own previous sample
            is_replay = table.t_stream < table.t_replay
            t_clamp = jnp.minimum(table.t_stream, self.prompt_cap - 1)
            ptok = jnp.take_along_axis(table.prompt, t_clamp[:, None],
                                       1)[:, 0]
            tok_in = jnp.where(is_replay, ptok, table.last_token)
            # -- cache-capacity guard: traced positions clamp-with-flag
            #    (Engine.decode_step's compiled-path contract)
            overflow = table.active & (table.t_stream >= max_len)
            pos_safe = jnp.minimum(table.t_stream, max_len - 1)
            h, new_cache = model.decode_step(params, table.cache, tok_in,
                                             pos_safe)
            # -- per-slot sampling keys on generate()'s fold schedule
            fold = jnp.where(is_replay, table.t_stream,
                             10_000 + table.t_stream - table.t_replay)
            step_keys = jax.vmap(jax.random.fold_in)(table.req_key, fold)
            k_samp = jax.vmap(lambda k: jax.random.split(k)[1])(step_keys)
            # -- ONE shared estimator decode across every lane; masked lanes
            #    stay out of the probe union
            k_est = jax.random.fold_in(est_key, table.step_idx)
            out = backend.decode(bstate, h, k_est, pc, k=pc.sample_k,
                                 use_pallas=use_pallas, active=table.active,
                                 **kernel_cfg)
            tok, score = sample_slots(out, k_samp, table.temperature,
                                      table.sample_k)
            # -- lifecycle: the lane's first kept sample is emitted by its
            #    LAST replay step (t_stream == t_replay - 1), same as
            #    generate(); budget counts emitted tokens
            emitted = table.active & (table.t_stream >= table.t_replay - 1) \
                & ~overflow
            new_budget = table.budget - emitted.astype(jnp.int32)
            finished = (emitted & (new_budget <= 0)) | overflow
            act = table.active
            new_table = dataclasses.replace(
                table,
                cache=new_cache,
                last_token=jnp.where(act, tok, table.last_token),
                t_stream=table.t_stream + act.astype(jnp.int32),
                budget=new_budget,
                active=act & ~finished,
                step_idx=table.step_idx + 1)
            head_live = out.head_live if out.head_live is not None \
                else jnp.zeros((), jnp.int32)
            outs = {"token": tok, "log_prob": score - out.log_z,
                    "log_z": out.log_z, "emitted": emitted,
                    "finished": finished, "overflow": overflow,
                    "n_active": act.astype(jnp.int32).sum(),
                    "head_live": head_live}
            return new_table, outs

        return step

    def _build_admit(self):
        donate = (0,) if jax.default_backend() != "cpu" else ()

        @partial(jax.jit, donate_argnums=donate)
        def admit(table: SlotTable, slot, prompt_row, p_len, budget, key,
                  temp, sample_k):
            self.admit_traces += 1
            upd = lambda arr, val: arr.at[slot].set(val)
            return dataclasses.replace(
                table,
                prompt=jax.lax.dynamic_update_slice(
                    table.prompt, prompt_row[None, :], (slot, 0)),
                last_token=upd(table.last_token, prompt_row[0]),
                t_stream=upd(table.t_stream, 0),
                t_replay=upd(table.t_replay, p_len),
                budget=upd(table.budget, budget),
                req_key=table.req_key.at[slot].set(key),
                temperature=upd(table.temperature, temp),
                sample_k=upd(table.sample_k, sample_k),
                active=upd(table.active, True))

        return admit

    # -- host API -------------------------------------------------------------

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_in_flight(self) -> int:
        return self.n_slots - len(self._free)

    def admit(self, request: Request) -> int:
        """Place a request in a free lane; returns the slot index. Raises
        when the table is full (callers queue — see serve.server) or when
        the request cannot fit the engine's caches (host-path guard:
        admission is the last point where a python error is possible)."""
        p_len = int(request.prompt.shape[0])
        if p_len < 1:
            raise ValueError("request needs a non-empty prompt")
        if p_len > self.prompt_cap:
            raise ValueError(
                f"prompt length {p_len} > scheduler prompt_cap "
                f"{self.prompt_cap}")
        if request.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        need = p_len + request.max_new_tokens - 1
        if need > self.engine.max_len:
            raise ValueError(
                f"request needs {need} cache positions (prompt {p_len} + "
                f"{request.max_new_tokens} tokens) but engine max_len is "
                f"{self.engine.max_len}")
        if not self._free:
            raise RuntimeError("no free slot; queue the request instead")
        slot = self._free.pop(0)
        prompt_row = np.zeros((self.prompt_cap,), np.int32)
        prompt_row[:p_len] = request.prompt
        sk = request.sample_k or self.engine.cfg.partition.sample_k
        sk = max(1, min(sk, self.engine.cfg.partition.sample_k))
        self.table = self._admit_fn(
            self.table, jnp.int32(slot), jnp.asarray(prompt_row),
            jnp.int32(p_len), jnp.int32(request.max_new_tokens),
            jnp.asarray(request.key, jnp.uint32), jnp.float32(
                request.temperature), jnp.int32(sk))
        self._slot_req[slot] = request
        self._slot_acc[slot] = Completion(
            request=request, tokens=[], log_probs=[], log_zs=[],
            admit_time=time.perf_counter(), first_token_time=None,
            done_time=0.0)
        return slot

    def step(self) -> dict:
        """Advance every live lane one token. Returns a host-side record:
        emitted tokens (streamed through ``on_token``), finished requests
        (``on_complete`` + listed under ``"completions"``), occupancy and
        probe-dedup metrics for this step."""
        t0 = time.perf_counter()
        self.table, out = self._step_fn(self.table, self.engine.params,
                                        self.engine.state)
        out = jax.device_get(out)
        now = time.perf_counter()
        completions = []
        for s in range(self.n_slots):
            req = self._slot_req[s]
            if req is None:
                continue
            acc = self._slot_acc[s]
            if out["emitted"][s]:
                if acc.first_token_time is None:
                    acc.first_token_time = now
                acc.tokens.append(int(out["token"][s]))
                acc.log_probs.append(float(out["log_prob"][s]))
                acc.log_zs.append(float(out["log_z"][s]))
                if req.on_token is not None:
                    req.on_token(req, int(out["token"][s]), now)
            if out["finished"][s]:
                acc.done_time = now
                acc.overflowed = bool(out["overflow"][s])
                self._slot_req[s] = None
                self._slot_acc[s] = None
                self._free.append(s)
                self._free.sort()
                completions.append(acc)
                if req.on_complete is not None:
                    req.on_complete(req, acc)
        return {"wall_s": now - t0,
                "n_active": int(out["n_active"]),
                "head_live": int(out["head_live"]),
                "occupancy": int(out["n_active"]) / self.n_slots,
                "completions": completions}
