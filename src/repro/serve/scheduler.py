"""Continuous-batching slot scheduler: ONE compiled mixed step for a
fixed-capacity slot table (DESIGN.md SS12).

``Engine.generate`` serves one synchronous same-length batch per call; under
real traffic that leaves slots idle while the longest request drains and
recompiles whenever shapes drift. This module holds per-request decode state
in a padded device batch of ``n_slots`` lanes — KV-cache lane, position,
remaining-token budget, per-slot PRNG key, per-slot sampling params
(temperature / sample_k as *traced arrays*) — and advances every live lane
together with a single jitted step:

 * **Mixed prefill/decode.** Prompt replay is chunked into the decode path
   one token per step (the same replay-through-cache trick generate() uses),
   so a lane mid-replay and a lane mid-generation ride the SAME executable:
   admitting a request never stalls in-flight decodes and never recompiles.
 * **Shared estimator work.** The batched backend decode runs once over all
   lanes; the probe-union dedup that makes retrieval estimators pay off
   under load (U <= min(Q*n_probe, nb)) happens across *requests*. Inactive
   lanes are masked out of the union (``core.decode.make_plan(active=...)``)
   so a half-empty table never pays for garbage probes.
 * **Per-slot sampling on generate()'s key schedule.** Each lane folds its
   own request key with its own stream-step index (``fold_in(key, t)`` on
   replay, ``fold_in(key, 10000 + t)`` after), splits off the sampling key,
   and draws its own Gumbel noise — so a request decoded in a busy slot
   table emits bit-identical tokens to the same request run alone through
   ``generate()`` (tests/test_scheduler.py pins this).
 * **Slot recycling.** A finished lane is marked inactive on device and
   returned to the host free list; the next admission rewinds the lane to
   position 0 — stale KV above the new request's frontier is masked by the
   per-slot validity window, so no cache zeroing is needed.

Both jitted entry points (``_step``, ``_admit``) carry trace counters:
after one step and one admission, NOTHING recompiles — asserted by tests
and by ``benchmarks/serving_bench.py``.

Robustness layer (DESIGN.md SS14)
---------------------------------
 * **Deadlines.** Each lane carries a traced countdown next to its budget;
   a lane whose deadline lapses mid-decode is *evicted* — folded into the
   same ``finished`` path as normal completion, so the slot recycles next
   step with no extra dispatch and no recompile. Neighbors are unaffected
   bit-for-bit (per-lane keys; masked rows never contribute probes).
 * **Estimator tiers.** ``set_tier`` switches which backend the NEXT step
   decodes with (the server's degradation ladder). Each tier's step is
   compiled once, lazily, against the same SlotTable — stepping down under
   overload is a host pointer update.
 * **Health guard.** The compiled step routes any lane whose estimate went
   non-finite / empty through the exact dense fallback under ``lax.cond``
   (``core.decode.apply_health_guard``): no NaN ever reaches sampling, and
   healthy steps take a bit-identical identity branch.
 * **Fault injection.** An attached ``serve.faults`` injector can raise
   before the compiled step runs, corrupt engine retrieval state (caught by
   the digest verify/restore cadence), or flip per-lane fault masks — the
   masks are traced arguments (all-False in normal service), so injection
   never recompiles and an injected lane's blast radius is itself.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from functools import partial
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..core.backends import (get_backend, shadow_exact_log_z,
                             state_partition_specs, verify_decode)
from ..core.decode import (HEALTH_EMPTY_HEAD, HEALTH_NONFINITE_SCORE,
                           HEALTH_NONFINITE_Z, apply_health_guard,
                           health_flags)
from ..core.distributed import shard_map
from ..obs.metrics import (TIER_IX, init_metric_state, observe_step,
                           shadow_rel_err)
from ..obs.metrics import harvest as harvest_metric_state
from .prefix_cache import PrefixPool, cache_is_kv_only

_REQ_IDS = itertools.count()

# deadline sentinel: far above any real step count, small enough that the
# int32 countdown never wraps
NO_DEADLINE = 1 << 30


@dataclasses.dataclass
class Request:
    """One serving request. ``key`` may be a PRNG key array or an int seed;
    it drives this request's sampling exactly as the same key would in
    ``generate()``. ``sample_k=0`` means the engine's configured
    ``sample_k``; smaller values restrict Gumbel-max to the top candidates.
    """
    prompt: Any                       # (L,) ints (list / np / jax array)
    max_new_tokens: int
    key: Any = 0
    temperature: float = 0.0
    sample_k: int = 0
    deadline: int = 0                 # virtual steps from submission before
                                      # the request is shed/evicted (0 = none;
                                      # the server may stamp its default)
    on_token: Optional[Callable] = None     # fn(request, token, wall_time)
    on_complete: Optional[Callable] = None  # fn(request, completion)
    req_id: int = dataclasses.field(default_factory=lambda: next(_REQ_IDS))

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if np.ndim(self.key) == 0:
            self.key = jax.random.PRNGKey(int(self.key))


@dataclasses.dataclass
class Completion:
    """Streamed back through ``Request.on_complete`` and returned by
    ``Scheduler.step`` when a lane finishes."""
    request: Request
    tokens: List[int]
    log_probs: List[float]
    log_zs: List[float]
    admit_time: float
    first_token_time: Optional[float]
    done_time: float
    overflowed: bool = False
    error: Optional[str] = None    # set when the request did not complete
                                   # normally (admission rejected: tokens
                                   # empty; evicted mid-decode: tokens
                                   # partial)
    reason: Optional[str] = None   # machine-readable code for error
                                   # completions: 'queue_full',
                                   # 'deadline_queue', 'deadline_evicted',
                                   # 'admit_rejected', 'fault_injected',
                                   # 'server_stopped'
    tiers: List[str] = dataclasses.field(default_factory=list)
                                   # estimator tier(s) this request's tokens
                                   # were served at, in order (degradation
                                   # audit trail; normally one entry)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SlotTable:
    """Device-resident per-lane decode state (everything the mixed step
    reads or writes; one pytree, one dispatch)."""
    cache: Any              # model decode state, batch = n_slots
    prompt: jax.Array       # (S, P_cap) padded prompt tokens
    last_token: jax.Array   # (S,)  lane's previous sampled token
    t_stream: jax.Array     # (S,)  step index within the lane's request ==
                            #       the lane's next KV position (one token
                            #       is consumed at position t per step)
    t_replay: jax.Array     # (S,)  lane's true prompt length
    budget: jax.Array       # (S,)  tokens still to emit
    req_key: jax.Array      # (S, 2) per-request PRNG key
    temperature: jax.Array  # (S,)  per-slot sampling temperature
    sample_k: jax.Array     # (S,)  per-slot candidate restriction
    deadline: jax.Array     # (S,)  remaining virtual steps before eviction
                            #       (NO_DEADLINE = none)
    active: jax.Array       # (S,)  lane holds a live request
    step_idx: jax.Array     # ()    global step counter (estimator PRNG)


def sample_slots(out, keys: jax.Array, temperature: jax.Array,
                 sample_k: Optional[jax.Array] = None):
    """Per-slot Gumbel-max over retrieved candidates: the traced-array
    generalization of ``engine._sample_candidates`` — one temperature, key
    and candidate budget PER ROW. Bit-compatible with the batch-shared
    sampler lane-for-lane when ``sample_k`` equals the retrieved width (the
    gumbel draw per lane matches the solo (1, k) draw exactly).

    keys (S, 2) are each lane's k_samp; temperature (S,) with 0 = greedy
    (index 0 of the sorted candidates); sample_k (S,) restricts lane s to
    its top ``sample_k[s]`` candidates.
    """
    kc = out.top_score.shape[1]
    g = jax.vmap(lambda k: jax.random.gumbel(k, (1, kc))[0])(keys)
    t = jnp.asarray(temperature, jnp.float32)
    safe_t = jnp.where(t > 0.0, t, 1.0)
    noisy = out.top_score / safe_t[:, None] + g
    if sample_k is not None:
        allowed = jnp.arange(kc)[None, :] < \
            jnp.maximum(sample_k, 1)[:, None]
        noisy = jnp.where(allowed, noisy, -jnp.inf)
    pick = jnp.where(t > 0.0, jnp.argmax(noisy, axis=-1),
                     jnp.zeros(t.shape, jnp.int32)).astype(jnp.int32)
    tok = jnp.take_along_axis(out.top_id, pick[:, None], 1)[:, 0]
    score = jnp.take_along_axis(out.top_score, pick[:, None], 1)[:, 0]
    return tok.astype(jnp.int32), score


def spec_accept(n_ok: jax.Array, t_stream: jax.Array, t_replay: jax.Array,
                budget: jax.Array, active: jax.Array, draft_bad: jax.Array,
                max_len: int, spec_k: int) -> jax.Array:
    """Accepted-position count per lane for one speculative round — the
    variable-advance algebra, factored out so the property tests can hammer
    it directly (DESIGN.md SS16b).

    ``n_ok`` is the leading-correct-input count over the round's spec_k
    positions (position 0's input is forced correct, so n_ok >= 1). The
    accepted count ``a`` is n_ok capped three ways: a lane may not emit
    past its budget (replay positions don't emit — the first
    r = clip(t_replay-1-t_stream, 0, k) accepted positions are free), may
    not advance past the KV capacity, and a lane whose DRAFT pass was
    health-flagged collapses to a = 1 — literally the non-speculative step
    for that lane this round (the chaos-fault fallback). Inactive lanes
    advance 0. Invariants (property-tested): active lanes get 1 <= a <=
    spec_k; emitted count max(0, a - r) never exceeds budget; t_stream + a
    never exceeds max_len + 1 with equality only at the overflow finish.
    """
    r = jnp.clip(t_replay - 1 - t_stream, 0, spec_k)
    a = jnp.minimum(n_ok, r + jnp.maximum(budget, 0))
    a = jnp.where(draft_bad, 1, a)
    a = jnp.clip(a, 1, spec_k)
    a = jnp.minimum(a, jnp.maximum(max_len - t_stream, 1))
    return jnp.where(active, a, 0).astype(jnp.int32)


class Scheduler:
    """Fixed-capacity continuous-batching scheduler over one ``Engine``.

    Host-side: a free-slot list, per-slot request bookkeeping, streaming
    callbacks. Device-side: the ``SlotTable`` plus two jitted functions —
    ``_admit`` (traced slot index: one compile serves every slot) and
    ``_step`` (the mixed replay/decode step). Audio (multi-codebook) heads
    have no slot-table path; use ``generate``.
    """

    def __init__(self, engine, n_slots: int, prompt_cap: Optional[int] = None,
                 key: Optional[jax.Array] = None, injector=None,
                 health_guard: bool = True,
                 spec_draft: Optional[str] = None, spec_k: int = 1,
                 spec_draft_probes: int = 0, prefix_cache_blocks: int = 0,
                 prefix_block_tokens: int = 8):
        if engine.cfg.n_codebooks:
            raise NotImplementedError(
                "the slot scheduler serves single-stream text heads; "
                "audio codebook decoding goes through serve.generate")
        self.engine = engine
        self.n_slots = n_slots
        # (data, model) serving mesh (Engine(mesh=...)): slot lanes are laid
        # out replica-major over the FLAT (S,) table — lane s lives on data
        # replica s // lanes_per_replica — and the one compiled step runs
        # under shard_map (DESIGN.md SS15). mesh=None is the single-device
        # path, byte-for-byte the PR-6 scheduler.
        self.mesh = getattr(engine, "mesh", None)
        if self.mesh is not None:
            self.n_replicas = int(self.mesh.shape["data"])
            if n_slots % self.n_replicas:
                raise ValueError(
                    f"n_slots {n_slots} must divide the mesh's data degree "
                    f"{self.n_replicas} (each replica owns an equal set of "
                    f"KV lanes)")
        else:
            self.n_replicas = 1
        self.lanes_per_replica = n_slots // self.n_replicas
        self.prompt_cap = int(prompt_cap or engine.max_len)
        self.key = key if key is not None else jax.random.PRNGKey(0)
        self.health_guard = health_guard
        self.injector = injector           # serve.faults.FaultInjector | None
        self.verify_index_every = 0        # digest-check cadence (0 = off);
                                           # set by the server from its
                                           # ServingConfig
        self.tier = engine.backend.method  # estimator tier the next step
                                           # decodes with
        self.step_traces = 0
        self.admit_traces = 0
        self.traces_by_tier: Dict[str, int] = {}
        self.steps_done = 0
        self._free = list(range(n_slots))
        self._slot_req: List[Optional[Request]] = [None] * n_slots
        self._slot_acc: List[Optional[Completion]] = [None] * n_slots
        self._no_fault = jnp.zeros((n_slots,), bool)
        # -- observability (obs/, DESIGN.md SS17): the metric pytree is
        # ALWAYS threaded through the compiled step — enabling harvesting
        # or shadow sampling later changes only traced data, never the
        # executable, so tokens stay bit-exact and trace counters pinned
        self.shadow_every = 0              # shadow-oracle cadence in steps
                                           # (0 = off); obs.Observability
                                           # sets it from ObsConfig
        self.metrics_state = init_metric_state()
        self._last_step_ms = -1.0          # previous step's device phase,
                                           # fed forward into the device
                                           # latency histogram (< 0: none)
        self._last_step_tier = engine.backend.method
        self.table = self._init_table()
        if self.mesh is not None:
            # canonical shardings: jit keys its compile cache on INPUT
            # shardings, so every table/params/state argument is pinned to
            # these exact NamedShardings (init + drain via device_put; admit
            # via out_shardings; step via out_specs) — that is what makes
            # "zero recompiles after warmup" survive the mesh
            self._table_sh = self._shardings_of(self._table_specs())
            self._lane_sh = NamedSharding(self.mesh, P("data"))
            self._repl_sh = NamedSharding(self.mesh, P())
            self._placements: Dict[Any, tuple] = {}
            self.table = jax.device_put(self.table, self._table_sh)
            self._no_fault = jax.device_put(self._no_fault, self._lane_sh)
            # metric counters are replicated (each replica accumulates the
            # same psum-reduced globals); pin them so the step executable's
            # input-sharding cache key never drifts
            self.metrics_state = jax.device_put(self.metrics_state,
                                                self._repl_sh)
        # -- estimator-speculative decoding (DESIGN.md SS16b): a cheap
        # registry backend drafts spec_k tokens per lane inside the step;
        # ONE batched pass of the lane's serving tier verifies them. The
        # draft runs a REDUCED probe budget — with the verifier's own
        # probes the candidates (and hence the deterministic Gumbel-max
        # sample) would match trivially and speculation would buy nothing.
        self.spec_draft = spec_draft
        self.spec_k = max(1, int(spec_k)) if spec_draft else 1
        pc = engine.cfg.partition
        self.spec_draft_probes = int(spec_draft_probes) or \
            max(1, pc.n_probe // 2)
        self.prefix: Optional[PrefixPool] = None
        if self.spec_k > 1 or prefix_cache_blocks:
            if engine.cfg.sliding_window or \
                    not cache_is_kv_only(self.table.cache):
                raise NotImplementedError(
                    "speculative decoding and the prefix cache rely on "
                    "rewindable full-attention KV lanes (a rejected or "
                    "stale position is overwritten before it is attended); "
                    "sliding-window ring buffers and recurrent decode "
                    "states break that argument")
        if self.spec_k > 1:
            get_backend(spec_draft)      # unknown drafts fail at init
        if prefix_cache_blocks:
            self.prefix = PrefixPool(
                self.table.cache, prefix_cache_blocks, prefix_block_tokens,
                max_match_blocks=max(
                    1, (self.prompt_cap - 1) // prefix_block_tokens),
                mesh=self.mesh,
                cache_shardings=None if self.mesh is None
                else self._table_sh.cache,
                n_replicas=self.n_replicas)
        self._step_fns: Dict[str, Callable] = {}
        self._bstate_sh: Dict[str, Any] = {}
        self._dstate_sh: Dict[str, Any] = {}
        self._admit_fn = self._build_admit()

    # -- device state --------------------------------------------------------

    def _init_table(self) -> SlotTable:
        s = self.n_slots
        eng = self.engine
        return SlotTable(
            cache=eng.model.init_decode_state(s, eng.max_len),
            prompt=jnp.zeros((s, self.prompt_cap), jnp.int32),
            last_token=jnp.zeros((s,), jnp.int32),
            t_stream=jnp.zeros((s,), jnp.int32),
            t_replay=jnp.ones((s,), jnp.int32),
            budget=jnp.zeros((s,), jnp.int32),
            req_key=jnp.zeros((s, 2), jnp.uint32),
            temperature=jnp.zeros((s,), jnp.float32),
            sample_k=jnp.ones((s,), jnp.int32),
            deadline=jnp.full((s,), NO_DEADLINE, jnp.int32),
            active=jnp.zeros((s,), bool),
            step_idx=jnp.zeros((), jnp.int32))

    # -- mesh plumbing -------------------------------------------------------

    def _table_specs(self) -> SlotTable:
        """PartitionSpec tree of the SlotTable under the serving mesh: every
        per-lane (S, ...) leaf — including each KV-cache lane batch — shards
        dim 0 over 'data'; the step counter is replicated. The table stays
        FLAT (S,), replica-major: host bookkeeping (``_slot_req[s]``,
        ``out["emitted"][s]``) is layout-blind."""
        from ..launch.mesh import serve_cache_spec
        cache = jax.tree_util.tree_map_with_path(serve_cache_spec,
                                                 self.table.cache)
        lane = P("data")
        return SlotTable(cache=cache, prompt=P("data", None),
                         last_token=lane, t_stream=lane, t_replay=lane,
                         budget=lane, req_key=P("data", None),
                         temperature=lane, sample_k=lane, deadline=lane,
                         active=lane, step_idx=P())

    def _shardings_of(self, specs):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), specs,
                            is_leaf=lambda x: isinstance(x, P))

    def _placed(self, cache_key, obj, shardings):
        """device_put ``obj`` to its canonical shardings, memoized by object
        identity: params/tier states are long-lived engine objects, so
        steady-state steps re-place nothing; a ``swap_index`` swaps in new
        objects and misses the cache exactly once."""
        ent = self._placements.get(cache_key)
        if ent is not None and ent[0] is obj:
            return ent[1]
        placed = jax.device_put(obj, shardings)
        self._placements[cache_key] = (obj, placed)
        return placed

    def _build_step(self, method: str):
        if self.spec_k > 1:
            return self._build_spec_step(method)
        eng = self.engine
        model = eng.model
        pc = eng.cfg.partition
        backend = get_backend(method)
        # measured kernel tiles were swept for the engine's own backend;
        # degradation tiers run library defaults (correctness never depends
        # on the tile choice)
        kernel_cfg = dict(eng.kernel_cfg) \
            if method == eng.backend.method else {}
        use_pallas = eng.use_pallas
        health_guard = self.health_guard
        max_len = eng.max_len
        est_key = jax.random.fold_in(self.key, 0xE57)
        # donate the table: the step updates the KV cache in place instead
        # of allocating + copying n_slots x max_len of it per token (CPU has
        # no donation support and would warn on every compile, so gate it)
        donate = (0,) if jax.default_backend() != "cpu" else ()

        mesh = self.mesh
        tier_ix = TIER_IX[method]
        n_slots = self.n_slots

        # the step body, shared verbatim by both compilation paths: plain
        # jit on a single device, or shard_map over the (data, model) mesh —
        # where ``table`` is each replica's local lanes, ``bstate``'s
        # payloads are the local model shard, and the only mesh-specific
        # lines are the estimator dispatch (backend.shard_decode — the
        # psum-row-gather bodies in serve.output_layer, bit-identical to
        # decode), the mesh health guard, and the data-psum of the two
        # step scalars
        def body(table: SlotTable, params, bstate, fault_nan, fault_inf,
                 metrics, extras):
            # -- input token: next prompt token while replaying, else the
            #    lane's own previous sample
            is_replay = table.t_stream < table.t_replay
            t_clamp = jnp.minimum(table.t_stream, self.prompt_cap - 1)
            ptok = jnp.take_along_axis(table.prompt, t_clamp[:, None],
                                       1)[:, 0]
            tok_in = jnp.where(is_replay, ptok, table.last_token)
            # -- cache-capacity guard: traced positions clamp-with-flag
            #    (Engine.decode_step's compiled-path contract)
            overflow = table.active & (table.t_stream >= max_len)
            pos_safe = jnp.minimum(table.t_stream, max_len - 1)
            h, new_cache = model.decode_step(params, table.cache, tok_in,
                                             pos_safe)
            # -- per-slot sampling keys on generate()'s fold schedule
            fold = jnp.where(is_replay, table.t_stream,
                             10_000 + table.t_stream - table.t_replay)
            step_keys = jax.vmap(jax.random.fold_in)(table.req_key, fold)
            k_samp = jax.vmap(lambda k: jax.random.split(k)[1])(step_keys)
            # -- ONE shared estimator decode across every lane; masked lanes
            #    stay out of the probe union
            k_est = jax.random.fold_in(est_key, table.step_idx)
            if mesh is None:
                out = backend.decode(bstate, h, k_est, pc, k=pc.sample_k,
                                     use_pallas=use_pallas,
                                     active=table.active, **kernel_cfg)
            else:
                out = backend.shard_decode(bstate, h, k_est, pc,
                                           k=pc.sample_k,
                                           active=table.active,
                                           axis_name="model")
            # -- lane-scoped fault injection: the masks are traced arguments
            #    (all-False arrays in normal service — same executable), and
            #    every downstream consumer is per-lane, so a corrupted lane's
            #    blast radius is exactly itself
            corrupt = fault_nan | fault_inf
            bad_val = jnp.where(fault_inf, jnp.inf, jnp.nan)
            out = out._replace(
                log_z=jnp.where(corrupt, bad_val, out.log_z),
                top_score=jnp.where(corrupt[:, None], bad_val[:, None],
                                    out.top_score))
            # -- health guard: unhealthy lanes (non-finite log Ẑ / empty
            #    probe union / non-finite scores — whether injected or
            #    organic) fall back to the exact dense path; healthy steps
            #    take the bit-identical identity branch
            if health_guard and mesh is None:
                out, flags = apply_health_guard(out, bstate.w, h,
                                                pc.sample_k,
                                                active=table.active)
            elif health_guard:
                from .output_layer import mesh_health_guard
                out, flags = mesh_health_guard(out, bstate.w, h,
                                               pc.sample_k,
                                               active=table.active,
                                               axis_name="model")
            else:
                flags = jnp.zeros(table.active.shape, jnp.int32)
            tok, score = sample_slots(out, k_samp, table.temperature,
                                      table.sample_k)
            # -- lifecycle: the lane's first kept sample is emitted by its
            #    LAST replay step (t_stream == t_replay - 1), same as
            #    generate(); budget counts emitted tokens
            emitted = table.active & (table.t_stream >= table.t_replay - 1) \
                & ~overflow
            new_budget = table.budget - emitted.astype(jnp.int32)
            done = (emitted & (new_budget <= 0)) | overflow
            act = table.active
            # -- deadline countdown: one virtual step of service per step; a
            #    lane that lapses without finishing is evicted through the
            #    SAME finished path (slot recycles next step, no recompile).
            #    It still emits this step's token — eviction returns partial
            #    output, it does not discard work already done.
            new_ddl = table.deadline - act.astype(jnp.int32)
            expired = act & ~done & (new_ddl <= 0)
            finished = done | expired
            new_table = dataclasses.replace(
                table,
                cache=new_cache,
                last_token=jnp.where(act, tok, table.last_token),
                t_stream=table.t_stream + act.astype(jnp.int32),
                budget=new_budget,
                deadline=new_ddl,
                active=act & ~finished,
                step_idx=table.step_idx + 1)
            head_live = out.head_live if out.head_live is not None \
                else jnp.zeros((), jnp.int32)
            n_active = act.astype(jnp.int32).sum()
            if mesh is not None:
                # per-replica scalars -> global (head_live sums each
                # replica's probe-union size; replicated over 'model'
                # already — the plan runs on replicated metadata)
                n_active = jax.lax.psum(n_active, "data")
                head_live = jax.lax.psum(head_live, "data")
            # -- observability (obs/): shadow-sampled exact log Z on the
            # traced cadence flag (both cond branches ride the same
            # executable — the mesh_health_guard replicated-predicate
            # pattern licenses the collectives inside) + the metric-state
            # accumulation. Reads only values the step already computed;
            # nothing feeds back into sampling.
            shadow = jax.lax.cond(
                extras["do_shadow"],
                lambda: shadow_rel_err(
                    out.log_z,
                    shadow_exact_log_z(
                        bstate, h, None if mesh is None else "model"),
                    act),
                lambda: (jnp.float32(0.0), jnp.float32(0.0), jnp.int32(0)))
            new_metrics = observe_step(
                metrics, tier_ix, n_slots,
                n_active=n_active, head_live=head_live,
                n_emitted=emitted.astype(jnp.int32).sum(),
                health_flags=flags, queue_depth=extras["queue_depth"],
                last_ms=extras["last_ms"], last_tier=extras["last_tier"],
                shadow=shadow,
                axis_name=None if mesh is None else "data")
            outs = {"token": tok, "log_prob": score - out.log_z,
                    "log_z": out.log_z, "emitted": emitted,
                    "finished": finished, "overflow": overflow,
                    "expired": expired, "health": flags,
                    "n_active": n_active, "head_live": head_live}
            return new_table, new_metrics, outs

        if mesh is None:
            # params and the retrieval state are traced ARGUMENTS, not
            # closure constants: Engine.swap_index can hand a freshly
            # trained checkpoint to a live server and the very next step
            # serves it from the same executable (shapes are identical
            # under device_index=True)
            @partial(jax.jit, donate_argnums=donate)
            def step(table: SlotTable, params, bstate, fault_nan, fault_inf,
                     metrics, extras):
                self.step_traces += 1   # python side effect: counts traces
                self.traces_by_tier[method] = \
                    self.traces_by_tier.get(method, 0) + 1
                return body(table, params, bstate, fault_nan, fault_inf,
                            metrics, extras)

            return step

        # mesh path: the SAME body under shard_map. Per-lane leaves split
        # over 'data' (each replica advances its own lanes + KV), the
        # retrieval payloads over 'model' (state_partition_specs), params
        # replicated. The trace counters live OUT here — shard_map may
        # re-trace the body while specializing, which is not a recompile.
        table_specs = self._table_specs()
        bstate = self.engine.tier_state(method)
        bspecs = state_partition_specs(bstate, self.mesh.shape["model"])
        self._bstate_sh[method] = self._shardings_of(bspecs)
        lane = P("data")
        # metric state + host scalars ride replicated (P() prefix covers the
        # whole pytree): every replica accumulates identical psum-reduced
        # counters, so the host may harvest any one shard
        out_specs = (table_specs, P(),
                     {"token": lane, "log_prob": lane, "log_z": lane,
                      "emitted": lane, "finished": lane, "overflow": lane,
                      "expired": lane, "health": lane,
                      "n_active": P(), "head_live": P()})
        sharded = shard_map(body, mesh,
                            in_specs=(table_specs, P(), bspecs, lane, lane,
                                      P(), P()),
                            out_specs=out_specs, check_vma=False)

        @partial(jax.jit, donate_argnums=donate)
        def step(table: SlotTable, params, bstate, fault_nan, fault_inf,
                 metrics, extras):
            self.step_traces += 1
            self.traces_by_tier[method] = \
                self.traces_by_tier.get(method, 0) + 1
            return sharded(table, params, bstate, fault_nan, fault_inf,
                           metrics, extras)

        return step

    def _build_spec_step(self, method: str):
        """Draft/verify twin of ``_build_step`` (DESIGN.md SS16b): the ONE
        compiled step drafts ``spec_k`` tokens per lane with the cheap
        ``spec_draft`` backend at a reduced probe budget, then verifies all
        positions with ONE batched pass of the lane's serving tier
        (``core.backends.verify_decode``) and advances each lane by its
        accepted count — traced data, so variable per-lane acceptance never
        recompiles.

        Exactness is deterministic, not stochastic: sampling is Gumbel-max
        under the per-position fold key, so the verifier's sample at
        position j is bit-identical to what the non-speculative step would
        emit there — PROVIDED position j's input token was correct. The
        accepted prefix is precisely the positions whose inputs were
        correct (replay positions are forced correct; a generation
        position's input is the previous draft token, correct iff it
        matched the previous verifier token), so emitted tokens are
        bit-identical to the non-speculative scheduler for greedy AND
        temperature lanes, with no rejection-resampling residual. Rejected
        positions leave garbage KV above the accepted frontier; every such
        position is rewritten by a later sequential step before it is ever
        attended, and the per-lane validity mask hides the rest — the same
        argument that gates this path to full-attention KV states.

        A tier walk (serve.server's degradation ladder) swaps ``method`` —
        the VERIFIER — while the draft stays fixed: the protocol is
        unchanged, only who checks the drafts."""
        eng = self.engine
        model = eng.model
        pc = eng.cfg.partition
        backend = get_backend(method)
        draft = get_backend(self.spec_draft)
        draft_pc = dataclasses.replace(pc, method=self.spec_draft,
                                       n_probe=self.spec_draft_probes)
        kernel_cfg = dict(eng.kernel_cfg) \
            if method == eng.backend.method else {}
        use_pallas = eng.use_pallas
        health_guard = self.health_guard
        max_len = eng.max_len
        kk = self.spec_k
        prompt_cap = self.prompt_cap
        est_key = jax.random.fold_in(self.key, 0xE57)
        draft_key = jax.random.fold_in(self.key, 0xD4AF)
        donate = (0,) if jax.default_backend() != "cpu" else ()
        mesh = self.mesh
        tier_ix = TIER_IX[method]
        n_slots = self.n_slots

        def body(table: SlotTable, params, bstate, dstate, fault_nan,
                 fault_inf, metrics, extras):
            act = table.active
            corrupt = fault_nan | fault_inf
            bad_val = jnp.where(fault_inf, jnp.inf, jnp.nan)
            cache = table.cache
            hs, ksamps, reps, ovfls, dtoks = [], [], [], [], []
            draft_bad = jnp.zeros_like(act)
            d_prev = table.last_token
            # -- draft phase: kk sequential model steps threading the KV
            #    cache exactly as kk non-spec steps would; the j-th input is
            #    the prompt token while replaying, else the (j-1)-th draft
            for j in range(kk):
                pos = table.t_stream + j
                is_rep = pos < table.t_replay
                t_clamp = jnp.minimum(pos, prompt_cap - 1)
                ptok = jnp.take_along_axis(table.prompt, t_clamp[:, None],
                                           1)[:, 0]
                tok_in = jnp.where(is_rep, ptok, d_prev)
                ovfls.append(act & (pos >= max_len))
                pos_safe = jnp.minimum(pos, max_len - 1)
                h, cache = model.decode_step(params, cache, tok_in,
                                             pos_safe)
                fold = jnp.where(is_rep, pos, 10_000 + pos - table.t_replay)
                step_keys = jax.vmap(jax.random.fold_in)(table.req_key,
                                                         fold)
                k_samp = jax.vmap(lambda k: jax.random.split(k)[1])(
                    step_keys)
                hs.append(h)
                ksamps.append(k_samp)
                reps.append(is_rep)
                if j < kk - 1:
                    dk = jax.random.fold_in(
                        jax.random.fold_in(draft_key, table.step_idx), j)
                    if mesh is None:
                        dout = draft.decode(dstate, h, dk, draft_pc,
                                            k=pc.sample_k,
                                            use_pallas=use_pallas,
                                            active=act)
                    else:
                        dout = draft.shard_decode(dstate, h, dk, draft_pc,
                                                  k=pc.sample_k, active=act,
                                                  axis_name="model")
                    # lane-fault masks corrupt the DRAFT pass too: a flagged
                    # draft forces that lane to a = 1 below — per-lane
                    # fallback to plain non-speculative decode
                    dout = dout._replace(
                        log_z=jnp.where(corrupt, bad_val, dout.log_z),
                        top_score=jnp.where(corrupt[:, None],
                                            bad_val[:, None],
                                            dout.top_score))
                    draft_bad = draft_bad | (health_flags(dout) > 0)
                    d_tok, _ = sample_slots(dout, k_samp, table.temperature,
                                            table.sample_k)
                    dtoks.append(d_tok)
                    d_prev = d_tok
            # -- verify phase: ONE accurate-backend pass over all S*kk
            #    drafted positions, on the SAME estimator key schedule as
            #    the non-spec step (candidates per row are key-independent;
            #    the key only drives tail sampling, i.e. log Ẑ)
            hseq = jnp.stack(hs, 1)
            k_est = jax.random.fold_in(est_key, table.step_idx)
            out = verify_decode(backend, bstate, hseq, k_est, pc,
                                k=pc.sample_k, active=act,
                                use_pallas=use_pallas,
                                axis_name=None if mesh is None else "model",
                                **kernel_cfg)
            corrupt_r = jnp.repeat(corrupt, kk)
            bad_r = jnp.repeat(bad_val, kk)
            out = out._replace(
                log_z=jnp.where(corrupt_r, bad_r, out.log_z),
                top_score=jnp.where(corrupt_r[:, None], bad_r[:, None],
                                    out.top_score))
            act_r = jnp.repeat(act, kk)
            hflat = hseq.reshape(-1, hseq.shape[-1])
            if health_guard and mesh is None:
                out, vflags = apply_health_guard(out, bstate.w, hflat,
                                                 pc.sample_k, active=act_r)
            elif health_guard:
                from .output_layer import mesh_health_guard
                out, vflags = mesh_health_guard(out, bstate.w, hflat,
                                                pc.sample_k, active=act_r,
                                                axis_name="model")
            else:
                vflags = jnp.zeros(act_r.shape, jnp.int32)
            ks_flat = jnp.stack(ksamps, 1).reshape(-1, 2)
            v_tok, v_score = sample_slots(
                out, ks_flat, jnp.repeat(table.temperature, kk),
                jnp.repeat(table.sample_k, kk))
            S = act.shape[0]
            v_tok = v_tok.reshape(S, kk)
            v_score = v_score.reshape(S, kk)
            log_z = out.log_z.reshape(S, kk)
            vflags = vflags.reshape(S, kk)
            # -- acceptance: leading-correct-input prefix, capped by budget
            #    / capacity / draft health (spec_accept)
            ok = jnp.ones_like(act)
            oks = [ok]
            for j in range(1, kk):
                ok = ok & (reps[j] | (dtoks[j - 1] == v_tok[:, j - 1]))
                oks.append(ok)
            n_ok = jnp.stack(oks, 1).astype(jnp.int32).sum(1)
            a = spec_accept(n_ok, table.t_stream, table.t_replay,
                            table.budget, act, draft_bad, max_len, kk)
            jpos = jnp.arange(kk)[None, :]
            accepted_m = jpos < a[:, None]
            ovfl_m = jnp.stack(ovfls, 1)
            emit = accepted_m & act[:, None] \
                & ((table.t_stream[:, None] + jpos)
                   >= (table.t_replay[:, None] - 1)) & ~ovfl_m
            e = emit.astype(jnp.int32).sum(1)
            new_budget = table.budget - e
            overflow = ovfl_m[:, 0]
            done = (act & (e > 0) & (new_budget <= 0)) | overflow
            # one speculative round = one virtual step of deadline service
            new_ddl = table.deadline - act.astype(jnp.int32)
            expired = act & ~done & (new_ddl <= 0)
            finished = done | expired
            idx = jnp.clip(a - 1, 0, kk - 1)
            lt = jnp.take_along_axis(v_tok, idx[:, None], 1)[:, 0]
            new_table = dataclasses.replace(
                table,
                cache=cache,
                last_token=jnp.where(act, lt, table.last_token),
                t_stream=table.t_stream + a,
                budget=new_budget,
                deadline=new_ddl,
                active=act & ~finished,
                step_idx=table.step_idx + 1)
            flags_l = jnp.zeros_like(n_ok)
            for j in range(kk):
                flags_l = flags_l | jnp.where(accepted_m[:, j],
                                              vflags[:, j], 0)
            head_live = out.head_live if out.head_live is not None \
                else jnp.zeros((), jnp.int32)
            n_active = act.astype(jnp.int32).sum()
            if mesh is not None:
                n_active = jax.lax.psum(n_active, "data")
                head_live = jax.lax.psum(head_live, "data")
            # -- observability: the shadow oracle scores the SAME flattened
            # (S*kk) verify rows the serving tier just estimated, so one
            # cadenced pass samples every drafted position's rel-err
            shadow = jax.lax.cond(
                extras["do_shadow"],
                lambda: shadow_rel_err(
                    out.log_z,
                    shadow_exact_log_z(
                        bstate, hflat, None if mesh is None else "model"),
                    act_r),
                lambda: (jnp.float32(0.0), jnp.float32(0.0), jnp.int32(0)))
            new_metrics = observe_step(
                metrics, tier_ix, n_slots,
                n_active=n_active, head_live=head_live,
                n_emitted=e.sum(),
                health_flags=flags_l, queue_depth=extras["queue_depth"],
                last_ms=extras["last_ms"], last_tier=extras["last_tier"],
                shadow=shadow,
                spec_proposed=act.astype(jnp.int32).sum() * kk,
                spec_accepted=a.sum(),
                draft_flagged=(draft_bad & act).astype(jnp.int32).sum(),
                axis_name=None if mesh is None else "data")
            outs = {"token": v_tok, "log_prob": v_score - log_z,
                    "log_z": log_z, "emitted": emit,
                    "finished": finished, "overflow": overflow,
                    "expired": expired, "health": flags_l,
                    "accepted": a, "draft_flagged": draft_bad & act,
                    "n_active": n_active, "head_live": head_live}
            return new_table, new_metrics, outs

        if mesh is None:
            @partial(jax.jit, donate_argnums=donate)
            def step(table: SlotTable, params, bstate, dstate, fault_nan,
                     fault_inf, metrics, extras):
                self.step_traces += 1
                self.traces_by_tier[method] = \
                    self.traces_by_tier.get(method, 0) + 1
                return body(table, params, bstate, dstate, fault_nan,
                            fault_inf, metrics, extras)

            return step

        table_specs = self._table_specs()
        bstate = self.engine.tier_state(method)
        bspecs = state_partition_specs(bstate, self.mesh.shape["model"])
        self._bstate_sh[method] = self._shardings_of(bspecs)
        dstate = self.engine.tier_state(self.spec_draft)
        dspecs = state_partition_specs(dstate, self.mesh.shape["model"])
        self._dstate_sh[self.spec_draft] = self._shardings_of(dspecs)
        lane = P("data")
        lane_k = P("data", None)
        out_specs = (table_specs, P(),
                     {"token": lane_k, "log_prob": lane_k, "log_z": lane_k,
                      "emitted": lane_k, "finished": lane, "overflow": lane,
                      "expired": lane, "health": lane, "accepted": lane,
                      "draft_flagged": lane,
                      "n_active": P(), "head_live": P()})
        sharded = shard_map(body, mesh,
                            in_specs=(table_specs, P(), bspecs, dspecs,
                                      lane, lane, P(), P()),
                            out_specs=out_specs, check_vma=False)

        @partial(jax.jit, donate_argnums=donate)
        def step(table: SlotTable, params, bstate, dstate, fault_nan,
                 fault_inf, metrics, extras):
            self.step_traces += 1
            self.traces_by_tier[method] = \
                self.traces_by_tier.get(method, 0) + 1
            return sharded(table, params, bstate, dstate, fault_nan,
                           fault_inf, metrics, extras)

        return step

    def _get_step(self, method: str):
        fn = self._step_fns.get(method)
        if fn is None:
            fn = self._step_fns[method] = self._build_step(method)
        return fn

    def set_tier(self, method: str) -> None:
        """Switch which estimator tier the NEXT step decodes with (the
        server walks its degradation ladder through this). Each tier's step
        compiles once, lazily, and tier states reuse the engine's index
        (``Engine.tier_state``) — after warmup a tier switch is two host
        pointer updates, zero device work, zero recompiles."""
        if method == self.tier:
            return
        get_backend(method)   # unknown tiers fail loudly, not at trace time
        self.tier = method

    def _build_admit(self):
        donate = (0,) if jax.default_backend() != "cpu" else ()
        # under a mesh, pin the admitted table to the canonical shardings:
        # .at[slot].set on a 'data'-sharded lane would otherwise leave XLA
        # free to emit a differently-sharded (or replicated) result, and the
        # step executable — keyed on input shardings — would recompile
        jit_kw = {} if self.mesh is None else \
            {"out_shardings": self._table_sh}

        @partial(jax.jit, donate_argnums=donate, **jit_kw)
        def admit(table: SlotTable, slot, prompt_row, p_len, budget, key,
                  temp, sample_k, deadline, t0):
            self.admit_traces += 1
            upd = lambda arr, val: arr.at[slot].set(val)
            # t0 > 0 = prefix-cache hit: the pool already landed the first
            # t0 positions of KV (Scheduler.admit), so replay resumes there
            return dataclasses.replace(
                table,
                prompt=jax.lax.dynamic_update_slice(
                    table.prompt, prompt_row[None, :], (slot, 0)),
                last_token=upd(table.last_token, prompt_row[0]),
                t_stream=upd(table.t_stream, t0),
                t_replay=upd(table.t_replay, p_len),
                budget=upd(table.budget, budget),
                req_key=table.req_key.at[slot].set(key),
                temperature=upd(table.temperature, temp),
                sample_k=upd(table.sample_k, sample_k),
                deadline=upd(table.deadline, deadline),
                active=upd(table.active, True))

        return admit

    # -- host API -------------------------------------------------------------

    @property
    def n_free(self) -> int:
        return len(self._free)

    def _pick_slot(self, preferred_replica: Optional[int] = None) -> int:
        """Claim a free lane. Single device: lowest index (FIFO order over
        a sorted free list — the PR-6 behavior, unchanged). Under a mesh,
        route to the LEAST-LOADED data replica (most free lanes; ties to
        the lowest replica) and take its lowest lane — staggered admissions
        spread across replicas instead of piling onto replica 0, which is
        what makes goodput scale with the data degree under partial load.
        ``preferred_replica`` (prefix-cache affinity: the replica owning a
        matched block chain) is tried first; when it has no free lane the
        admission falls through to least-loaded and forfeits the hit."""
        if self.n_replicas == 1:
            return self._free.pop(0)
        if preferred_replica is not None:
            cand = [s for s in self._free
                    if s // self.lanes_per_replica == preferred_replica]
            if cand:
                slot = min(cand)
                self._free.remove(slot)
                return slot
        free_per = [0] * self.n_replicas
        for s in self._free:
            free_per[s // self.lanes_per_replica] += 1
        rep = max(range(self.n_replicas), key=lambda r: (free_per[r], -r))
        slot = min(s for s in self._free
                   if s // self.lanes_per_replica == rep)
        self._free.remove(slot)
        return slot

    def free_in_replica(self, replica: int) -> int:
        """Free lanes owned by one data replica (1 replica == the whole
        table on a single device). The server's bounded-lookahead admission
        uses this to decide whether holding a request for its preferred
        replica is worth a skip."""
        if self.n_replicas == 1:
            return len(self._free)
        return sum(1 for s in self._free
                   if s // self.lanes_per_replica == replica)

    def prefix_preview(self, request: "Request"):
        """(cached_prefix_tokens, owner_replica) the prefix pool would give
        this request at admission — None owner when the pool is off or the
        prompt misses. Host-only dict walk; used by the server's admission
        lookahead to route requests toward their cached blocks."""
        if self.prefix is None:
            return 0, None
        p_len = int(request.prompt.shape[0])
        if p_len < 1:
            return 0, None
        m, _, owner = self.prefix.match(request.prompt, p_len)
        return m * self.prefix.block_tokens, owner

    @property
    def n_in_flight(self) -> int:
        return self.n_slots - len(self._free)

    def admit(self, request: Request,
              deadline_steps: Optional[int] = None) -> int:
        """Place a request in a free lane; returns the slot index. Raises
        when the table is full (callers queue — see serve.server) or when
        the request cannot fit the engine's caches (host-path guard:
        admission is the last point where a python error is possible).
        ``deadline_steps`` is the lane's eviction countdown in scheduler
        steps (None = no deadline); the server passes the request's
        *remaining* deadline so queue wait counts against it."""
        if self.injector is not None:
            # fault hook BEFORE any state mutates: a rejected admission
            # leaves the scheduler exactly as it was
            self.injector.on_admit(request, self)
        if deadline_steps is not None and deadline_steps < 1:
            raise ValueError("deadline already expired at admission")
        p_len = int(request.prompt.shape[0])
        if p_len < 1:
            raise ValueError("request needs a non-empty prompt")
        if p_len > self.prompt_cap:
            raise ValueError(
                f"prompt length {p_len} > scheduler prompt_cap "
                f"{self.prompt_cap}")
        if request.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        need = p_len + request.max_new_tokens - 1
        if need > self.engine.max_len:
            raise ValueError(
                f"request needs {need} cache positions (prompt {p_len} + "
                f"{request.max_new_tokens} tokens) but engine max_len is "
                f"{self.engine.max_len}")
        if not self._free:
            raise RuntimeError("no free slot; queue the request instead")
        # -- prefix cache: host trie match, then ONE traced block-gather
        #    lands the cached KV in the lane and replay resumes at t0
        pref_ids: List[int] = []
        owner = None
        if self.prefix is not None:
            _, pref_ids, owner = self.prefix.match(request.prompt, p_len)
        slot = self._pick_slot(owner)
        t0 = 0
        if pref_ids and (self.n_replicas == 1
                         or slot // self.lanes_per_replica == owner):
            new_cache = self.prefix.load(self.table.cache, pref_ids, slot)
            self.table = dataclasses.replace(self.table, cache=new_cache)
            t0 = len(pref_ids) * self.prefix.block_tokens
        prompt_row = np.zeros((self.prompt_cap,), np.int32)
        prompt_row[:p_len] = request.prompt
        sk = request.sample_k or self.engine.cfg.partition.sample_k
        sk = max(1, min(sk, self.engine.cfg.partition.sample_k))
        ddl = NO_DEADLINE if deadline_steps is None else int(deadline_steps)
        self.table = self._admit_fn(
            self.table, jnp.int32(slot), jnp.asarray(prompt_row),
            jnp.int32(p_len), jnp.int32(request.max_new_tokens),
            jnp.asarray(request.key, jnp.uint32), jnp.float32(
                request.temperature), jnp.int32(sk), jnp.int32(ddl),
            jnp.int32(t0))
        self._slot_req[slot] = request
        self._slot_acc[slot] = Completion(
            request=request, tokens=[], log_probs=[], log_zs=[],
            admit_time=time.perf_counter(), first_token_time=None,
            done_time=0.0)
        return slot

    def step(self, queue_depth: int = 0) -> dict:
        """Advance every live lane one token. Returns a host-side record:
        emitted tokens (streamed through ``on_token``), finished requests
        (``on_complete`` + listed under ``"completions"``), occupancy,
        probe-dedup, tier and estimator-health metrics for this step.
        ``queue_depth`` is the server's admission backlog, recorded into the
        device-resident queue gauge (traced data — never a recompile).

        Fault-injection order matters: the injector fires FIRST (a raised
        ``FaultError`` leaves the table unadvanced — the server retries the
        step), then the digest verify/restore cadence runs so a corrupted
        retrieval state is repaired BEFORE the compiled step consumes it.

        Timing: ``wall_device_s`` covers dispatch + compiled step + the
        outs readback; ``wall_host_s`` is everything else (injector, state
        lookups, completion bookkeeping, ``on_token``/``on_complete``
        callbacks); ``wall_s`` is their sum. The raw ``t_*`` perf_counter
        stamps ride along for the span tracer."""
        t0 = time.perf_counter()
        if self.injector is not None:
            self.injector.on_step_begin(self)
        restored = False
        if self.verify_index_every and \
                self.steps_done % self.verify_index_every == 0:
            restored = self.engine.verify_and_restore(self.tier)
        fault_nan = fault_inf = self._no_fault
        if self.injector is not None:
            lanes = self.injector.lane_faults(self)
            if lanes is not None:
                fault_nan = jnp.asarray(np.asarray(lanes[0], bool))
                fault_inf = jnp.asarray(np.asarray(lanes[1], bool))
        step_fn = self._get_step(self.tier)
        bstate = self.engine.tier_state(self.tier)
        params = self.engine.params
        spec = self.spec_k > 1
        dstate = self.engine.tier_state(self.spec_draft) if spec else None
        if self.mesh is not None:
            # canonical placements (identity-memoized: free in steady state)
            params = self._placed("params", params, self._repl_sh)
            bstate = self._placed(("bstate", self.tier), bstate,
                                  self._bstate_sh[self.tier])
            if spec:
                dstate = self._placed(("dstate", self.spec_draft), dstate,
                                      self._dstate_sh[self.spec_draft])
            if fault_nan is not self._no_fault:
                fault_nan = jax.device_put(fault_nan, self._lane_sh)
                fault_inf = jax.device_put(fault_inf, self._lane_sh)
        # observability scalars: traced data with a fixed pytree structure,
        # so toggling the shadow cadence or a moving queue depth hits the
        # same executable
        do_shadow = bool(self.shadow_every
                         and self.steps_done % self.shadow_every == 0)
        extras = {"queue_depth": jnp.int32(max(queue_depth, 0)),
                  "last_ms": jnp.float32(self._last_step_ms),
                  "last_tier": jnp.int32(TIER_IX[self._last_step_tier]),
                  "do_shadow": jnp.bool_(do_shadow)}
        t_dispatch = time.perf_counter()
        if spec:
            self.table, self.metrics_state, out = step_fn(
                self.table, params, bstate, dstate, fault_nan, fault_inf,
                self.metrics_state, extras)
        else:
            self.table, self.metrics_state, out = step_fn(
                self.table, params, bstate, fault_nan, fault_inf,
                self.metrics_state, extras)
        self.steps_done += 1
        out = jax.device_get(out)
        now = time.perf_counter()
        self._last_step_ms = (now - t_dispatch) * 1e3
        self._last_step_tier = self.tier
        # normalize to (S, k) position-major token matrices: the non-spec
        # step is the k = 1 column
        if np.asarray(out["token"]).ndim == 1:
            tok = np.asarray(out["token"])[:, None]
            em = np.asarray(out["emitted"])[:, None]
            lp = np.asarray(out["log_prob"])[:, None]
            lz = np.asarray(out["log_z"])[:, None]
        else:
            tok = np.asarray(out["token"])
            em = np.asarray(out["emitted"])
            lp = np.asarray(out["log_prob"])
            lz = np.asarray(out["log_z"])
        completions = []
        for s in range(self.n_slots):
            req = self._slot_req[s]
            if req is None:
                continue
            acc = self._slot_acc[s]
            for j in range(tok.shape[1]):
                if not em[s, j]:
                    continue
                if acc.first_token_time is None:
                    acc.first_token_time = now
                acc.tokens.append(int(tok[s, j]))
                acc.log_probs.append(float(lp[s, j]))
                acc.log_zs.append(float(lz[s, j]))
                if not acc.tiers or acc.tiers[-1] != self.tier:
                    acc.tiers.append(self.tier)
                if req.on_token is not None:
                    req.on_token(req, int(tok[s, j]), now)
            if out["finished"][s]:
                acc.done_time = now
                acc.overflowed = bool(out["overflow"][s])
                if out["expired"][s]:
                    acc.error = "deadline exceeded (evicted mid-decode)"
                    acc.reason = "deadline_evicted"
                if self.prefix is not None and acc.error is None \
                        and not acc.overflowed:
                    # cleanly-finished lane: its prompt KV is fully valid —
                    # register the block-aligned prefix in the pool BEFORE
                    # the slot recycles
                    self.prefix.insert(
                        req.prompt, int(req.prompt.shape[0]),
                        self.table.cache, s,
                        s // self.lanes_per_replica)
                self._slot_req[s] = None
                self._slot_acc[s] = None
                self._free.append(s)
                self._free.sort()
                completions.append(acc)
                if req.on_complete is not None:
                    req.on_complete(req, acc)
        flags = np.asarray(out["health"])
        t_done = time.perf_counter()
        rec = {"wall_s": t_done - t0,
               "wall_device_s": now - t_dispatch,
               "wall_host_s": (t_dispatch - t0) + (t_done - now),
               "t_start": t0, "t_dispatch": t_dispatch,
               "t_device_done": now, "t_done": t_done,
               "n_active": int(out["n_active"]),
               "head_live": int(out["head_live"]),
               "occupancy": int(out["n_active"]) / self.n_slots,
               "completions": completions,
               "tier": self.tier,
               "n_emitted": int(em.sum()),
               "index_restored": restored,
               "health_flagged": int((flags > 0).sum()),
               "health_nonfinite_z":
                   int((flags & HEALTH_NONFINITE_Z > 0).sum()),
               "health_empty_head":
                   int((flags & HEALTH_EMPTY_HEAD > 0).sum()),
               "health_nonfinite_score":
                   int((flags & HEALTH_NONFINITE_SCORE > 0).sum())}
        if spec:
            rec["spec_proposed"] = int(out["n_active"]) * self.spec_k
            rec["spec_accepted"] = int(np.asarray(out["accepted"]).sum())
            rec["draft_flagged"] = \
                int(np.asarray(out["draft_flagged"]).sum())
        return rec

    def harvest_metrics(self) -> dict:
        """ONE device->host read of the cumulative metric pytree (the obs
        layer calls this on its harvest cadence; see obs.metrics.harvest).
        Counters are monotone — harvesting never resets them."""
        return harvest_metric_state(self.metrics_state, self.n_slots)

    def reset_metrics(self) -> None:
        """Zero the device metric state (between benchmark phases). The
        fresh pytree has identical shapes/shardings, so the next step hits
        its existing executable — pinned under the mesh exactly like the
        init-time state."""
        self.metrics_state = init_metric_state()
        if self.mesh is not None:
            self.metrics_state = jax.device_put(self.metrics_state,
                                                self._repl_sh)
        self._last_step_ms = -1.0

    def drain(self, reason: str = "server_stopped") -> List[Completion]:
        """Forcibly close out every in-flight lane host-side: each open
        request becomes an errored completion carrying whatever tokens it
        already emitted, its lane returns to the free list, and the device
        table is deactivated in one update. The server flushes through this
        at shutdown / ``max_steps`` instead of silently stranding work."""
        now = time.perf_counter()
        completions = []
        for s in range(self.n_slots):
            req = self._slot_req[s]
            if req is None:
                continue
            acc = self._slot_acc[s]
            acc.done_time = now
            acc.error = f"evicted: {reason}"
            acc.reason = reason
            self._slot_req[s] = None
            self._slot_acc[s] = None
            self._free.append(s)
            completions.append(acc)
            if req.on_complete is not None:
                req.on_complete(req, acc)
        if completions:
            self._free.sort()
            n = self.n_slots
            self.table = dataclasses.replace(
                self.table,
                active=jnp.zeros((n,), bool),
                budget=jnp.zeros((n,), jnp.int32),
                deadline=jnp.full((n,), NO_DEADLINE, jnp.int32))
            if self.mesh is not None:
                # the freshly-built host arrays above are uncommitted; pin
                # the table back to canonical shardings so the next step
                # hits its existing executable
                self.table = jax.device_put(self.table, self._table_sh)
        return completions
