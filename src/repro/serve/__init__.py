from .engine import Engine, ServeState, generate
from .scheduler import Completion, Request, Scheduler, SlotTable
from .server import (Arrival, Server, ServerReport, poisson_arrivals,
                     trace_arrivals)

__all__ = ["Engine", "ServeState", "generate", "Scheduler", "SlotTable",
           "Request", "Completion", "Server", "ServerReport", "Arrival",
           "poisson_arrivals", "trace_arrivals"]
