from .engine import Engine, ServeState, generate
