from .engine import Engine, ServeState, generate
from .faults import (AdmissionFault, CompositeFault, CorruptIndexFault,
                     FaultError, FaultInjector, InfLogitsFault,
                     NanLogitsFault, StepFault)
from .scheduler import (NO_DEADLINE, Completion, Request, Scheduler,
                        SlotTable)
from .server import (Arrival, Server, ServerReport, default_ladder,
                     poisson_arrivals, trace_arrivals)

__all__ = ["Engine", "ServeState", "generate", "Scheduler", "SlotTable",
           "Request", "Completion", "NO_DEADLINE", "Server", "ServerReport",
           "Arrival", "poisson_arrivals", "trace_arrivals", "default_ladder",
           "FaultError", "FaultInjector", "CompositeFault", "NanLogitsFault",
           "InfLogitsFault", "CorruptIndexFault", "AdmissionFault",
           "StepFault"]
