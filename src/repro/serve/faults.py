"""Deterministic fault injection for the serving stack (DESIGN.md SS14).

Chaos harness for the scheduler/server: every injector is seeded and fires
on the scheduler's deterministic step counter, so a faulted run is exactly
reproducible. The contract the chaos tests pin (tests/test_faults.py): with
any single injector active, every NON-injected request completes with
tokens bit-identical to the fault-free run, nothing recompiles after
warmup, and no NaN/Inf ever reaches an emitted log_prob / log_z.

Injection surfaces, matched to real failure modes:

 * ``NanLogitsFault`` / ``InfLogitsFault`` — corrupted activations or
   embedding rows for specific requests: flips the compiled step's traced
   per-lane fault masks (no recompile; blast radius = the lane), which the
   in-step health guard must catch and route through the exact fallback.
 * ``CorruptIndexFault`` — a bad ``swap_index`` / device bit-rot: installs
   a zeroed-block, permuted-block, or drifted copy of the engine's IVF
   state WITHOUT updating its digest. The scheduler's verify/restore
   cadence must repair it before any step consumes it.
 * ``AdmissionFault`` — dependency failure at admission time for specific
   requests: raises before the scheduler mutates anything; the server
   rejects with reason 'fault_injected'.
 * ``StepFault`` — a transient host-side exception at a step boundary
   (watchdog trip, preempted RPC): raises before the compiled step runs;
   the server counts it and retries without advancing the virtual clock.

``CompositeFault`` chains several injectors. All hooks receive the live
``Scheduler`` — injectors may read its request map / step counter but must
only mutate state through the documented surfaces above.
"""
from __future__ import annotations

from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np


class FaultError(RuntimeError):
    """Raised by injectors to simulate a host-side failure. The scheduler
    guarantees it propagates BEFORE any device state mutates, so catching
    it and retrying is always safe."""


class FaultInjector:
    """Base injector: every hook is a no-op. Subclasses override the
    surface(s) they corrupt; the scheduler calls these at fixed points:

    - ``on_admit(request, sched)``   before admission mutates anything
    - ``on_step_begin(sched)``       before digest verify + compiled step
    - ``lane_faults(sched)``         -> None, or (nan_mask, inf_mask) bool
                                        arrays of shape (n_slots,)
    """

    def on_admit(self, request, sched) -> None:
        pass

    def on_step_begin(self, sched) -> None:
        pass

    def lane_faults(self, sched
                    ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        return None


class CompositeFault(FaultInjector):
    """Run several injectors in sequence (lane masks OR together)."""

    def __init__(self, injectors: Sequence[FaultInjector]):
        self.injectors = list(injectors)

    def on_admit(self, request, sched) -> None:
        for inj in self.injectors:
            inj.on_admit(request, sched)

    def on_step_begin(self, sched) -> None:
        for inj in self.injectors:
            inj.on_step_begin(sched)

    def lane_faults(self, sched):
        nan = inf = None
        for inj in self.injectors:
            lanes = inj.lane_faults(sched)
            if lanes is None:
                continue
            n, i = (np.asarray(lanes[0], bool), np.asarray(lanes[1], bool))
            nan = n if nan is None else nan | n
            inf = i if inf is None else inf | i
        if nan is None:
            return None
        return nan, inf


class _LogitsFault(FaultInjector):
    """Shared machinery: flip the fault mask for targeted requests' lanes
    on the given scheduler steps."""

    _inf = False

    def __init__(self, req_ids: Iterable[int], steps: Iterable[int]):
        self.req_ids = set(int(r) for r in req_ids)
        self.steps = set(int(s) for s in steps)

    def lane_faults(self, sched):
        if sched.steps_done not in self.steps:
            return None
        mask = np.zeros((sched.n_slots,), bool)
        for s, req in enumerate(sched._slot_req):
            if req is not None and req.req_id in self.req_ids:
                mask[s] = True
        if not mask.any():
            return None
        zero = np.zeros_like(mask)
        return (zero, mask) if self._inf else (mask, zero)


class NanLogitsFault(_LogitsFault):
    """NaN log Ẑ + candidate scores for the targeted requests' lanes on the
    targeted steps (``steps`` index the scheduler's ``steps_done``)."""
    _inf = False


class InfLogitsFault(_LogitsFault):
    """Same as ``NanLogitsFault`` but +Inf — exercises the guard's Inf arm
    (an Inf that survives to sampling corrupts argmax silently rather than
    poisoning downstream sums, which is why both arms are pinned)."""
    _inf = True


class CorruptIndexFault(FaultInjector):
    """Install a corrupted copy of the current tier's retrieval state at
    step ``at_step`` (simulating a bad swap / bit-rot; fires once).

    mode:
      'zero'    - zero out ``n_blocks`` IVF blocks (dead rows: the lanes
                  probing them lose mass silently)
      'permute' - swap the first ``2 * n_blocks`` blocks pairwise (routing
                  betrayal: centroids point at the wrong rows — the failure
                  a plain checksum-of-sums would MISS, which is why the
                  digest is position-weighted)
      'drift'   - add seeded Gaussian noise, scale ``drift_scale`` (stale /
                  half-updated index after an interrupted swap)
    """

    def __init__(self, at_step: int, mode: str = "zero", n_blocks: int = 2,
                 seed: int = 0, drift_scale: float = 0.05):
        assert mode in ("zero", "permute", "drift")
        self.at_step = int(at_step)
        self.mode = mode
        self.n_blocks = int(n_blocks)
        self.seed = int(seed)
        self.drift_scale = float(drift_scale)
        self.fired = False

    def on_step_begin(self, sched) -> None:
        if self.fired or sched.steps_done != self.at_step:
            return
        self.fired = True
        import dataclasses

        import jax.numpy as jnp
        eng = sched.engine
        state = eng.tier_state(sched.tier)
        if state is None or state.index is None:
            raise FaultError("CorruptIndexFault needs an index-backed tier")
        vb = np.array(state.index.v_blocks)
        nb = vb.shape[0]
        if self.mode == "zero":
            vb[: min(self.n_blocks, nb)] = 0
        elif self.mode == "permute":
            for i in range(0, min(2 * self.n_blocks, nb - 1), 2):
                vb[[i, i + 1]] = vb[[i + 1, i]]
        else:
            rng = np.random.default_rng(self.seed)
            vb = vb + self.drift_scale * rng.standard_normal(
                vb.shape).astype(vb.dtype)
        index = dataclasses.replace(state.index, v_blocks=jnp.asarray(vb)) \
            if dataclasses.is_dataclass(state.index) \
            else state.index._replace(v_blocks=jnp.asarray(vb))
        eng._install_state(dataclasses.replace(state, index=index)
                           if dataclasses.is_dataclass(state)
                           else state._replace(index=index),
                           method=sched.tier)


class AdmissionFault(FaultInjector):
    """Fail admission for the targeted request ids (dependency outage at
    the door). Raises before the scheduler mutates anything."""

    def __init__(self, req_ids: Iterable[int]):
        self.req_ids = set(int(r) for r in req_ids)

    def on_admit(self, request, sched) -> None:
        if request.req_id in self.req_ids:
            raise FaultError(
                f"injected admission failure for request {request.req_id}")


class StepFault(FaultInjector):
    """Raise at the given step boundaries, once each (transient host-side
    failure: the server must retry without advancing the virtual clock)."""

    def __init__(self, steps: Iterable[int]):
        self.steps = set(int(s) for s in steps)
        self._fired: set = set()

    def on_step_begin(self, sched) -> None:
        t = sched.steps_done
        if t in self.steps and t not in self._fired:
            self._fired.add(t)
            raise FaultError(f"injected step fault at step {t}")


__all__ = ["FaultError", "FaultInjector", "CompositeFault",
           "NanLogitsFault", "InfLogitsFault", "CorruptIndexFault",
           "AdmissionFault", "StepFault"]
