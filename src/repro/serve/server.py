"""Traffic-facing serving loop over the slot scheduler (DESIGN.md SS12/SS14).

The ``Scheduler`` is mechanism (slot table + one compiled mixed step); the
``Server`` is policy: an admission queue, arrival processes (Poisson or a
replayed trace), slot recycling back into admission, streaming per-token /
per-request callbacks, and the latency accounting the serving benchmark
reports.

Time model: arrivals are scheduled on a **virtual step clock** (a request
"arrives" at step t), which keeps traffic generation deterministic and
backend-speed-independent — the same trace replays bit-identically on any
machine. Latency metrics are real wall-clock, measured around the compiled
step. When the table drains and the queue is empty but arrivals remain in
the future, the clock fast-forwards to the next arrival (idle steps are not
simulated).

Overload policy (``configs.ServingConfig``, all knobs in virtual steps):

 * **Backpressure.** A bounded admission queue sheds over-watermark
   arrivals at submit time ('queue_full') and expired entries at the next
   admission boundary ('deadline_queue') — every shed is an errored,
   token-less completion with a machine-readable reason, never a silent
   drop. Queue wait is recorded for shed requests too (they waited; the
   report should say so).
 * **Deadlines.** A request's deadline (its own or the config default)
   counts down from submission; the *remaining* budget at admission becomes
   the lane's traced eviction countdown, so queue wait spends the same
   budget service does.
 * **Graceful degradation.** Under sustained queue pressure the server
   walks the scheduler DOWN an estimator-tier ladder (e.g. mimps -> topk:
   cheaper steps drain the backlog) and back UP with hysteresis — separate
   high/low watermarks plus consecutive-step debounce, so an oscillating
   queue cannot flap the tier. Tier switches never recompile (each tier's
   step compiles once; see ``Scheduler.set_tier``).
 * **Fault containment.** A ``FaultError`` raised at a step boundary (the
   injection harness, serve.faults) is counted and retried without
   advancing the virtual clock — the device table was never touched, so
   non-injected requests stay bit-identical to a fault-free run.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..configs.base import ServingConfig
from ..core.backends import BACKENDS
from .faults import FaultError
from .scheduler import Completion, Request, Scheduler


@dataclasses.dataclass
class Arrival:
    at_step: float
    request: Request


def poisson_arrivals(requests: Sequence[Request], rate: float,
                     seed: int = 0) -> List[Arrival]:
    """Poisson process on the virtual step clock: inter-arrival gaps are
    Exp(rate) steps (``rate`` = expected requests per scheduler step)."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for req in requests:
        t += float(rng.exponential(1.0 / max(rate, 1e-9)))
        out.append(Arrival(at_step=t, request=req))
    return out


def trace_arrivals(requests: Sequence[Request],
                   at_steps: Sequence[float]) -> List[Arrival]:
    """Replay a recorded trace: request i arrives at virtual step
    ``at_steps[i]``."""
    assert len(requests) == len(at_steps)
    return sorted((Arrival(float(t), r)
                   for t, r in zip(at_steps, requests)),
                  key=lambda a: a.at_step)


_DEFAULT_LADDERS: Dict[str, Tuple[str, ...]] = {
    # ordered most-accurate -> cheapest; every rung shares the engine's IVF
    # index (Engine.tier_state), so walking down is free of rebuilds
    "mimps": ("mimps", "topk"),
    "mince": ("mince", "mimps", "topk"),
    "fmbe": ("fmbe", "topk"),
}
# every other registered backend degrades within itself: the REGISTRY is
# the source of truth (a new backend is never silently unladderable), and a
# singleton ladder is the right default for backends that share no IVF
# index with the topk rung (lsh: stepping "down" to topk would force a
# k-means build the engine never made, and exact is costlier, not cheaper)
for _m in sorted(BACKENDS):
    _DEFAULT_LADDERS.setdefault(_m, (_m,))


def default_ladder(method: str) -> Tuple[str, ...]:
    """The degradation ladder used when ``ServingConfig.degrade_ladder`` is
    empty: start at the engine's own method, step down through cheaper
    index-sharing tiers, end at head-only top-k (Eq. 4) — the rung that
    keeps lanes moving when everything else is too slow."""
    return _DEFAULT_LADDERS.get(method, (method,))


@dataclasses.dataclass
class ServerReport:
    completions: List[Completion]
    wall_s: float                  # first admission -> last completion
    steps: int
    goodput_tok_s: float           # emitted tokens / wall_s
    p50_token_ms: float            # per-token latency percentiles over all
    p95_token_ms: float            #   emitted tokens (gap to previous token
                                   #   of the same request; first token:
                                   #   admission -> emit)
    p99_token_ms: float            # tail percentile of the same series
    peak_concurrency: int          # max live lanes reached during the run
    occupancy_mean: float          # mean live-lane fraction over live steps
    occupancy_steady: float        # same, but only while demand exceeded
                                   #   capacity (queue non-empty at step
                                   #   start) — the saturation figure
    dedup_ratio_mean: Optional[float]  # mean U / (n_active * n_probe)
    dedup_by_fill: dict            # n_active -> mean dedup ratio
    queue_wait_steps_mean: float   # admission queueing delay (virtual
                                   #   steps) — includes shed requests
    # -- overload / robustness accounting (DESIGN.md SS14) -------------------
    rejects_by_reason: Dict[str, int] = dataclasses.field(
        default_factory=dict)      # reason code -> count over every errored
                                   # completion (sheds, evictions, flushes)
    shed_rate: float = 0.0         # errored completions / all completions
    queue_depth_peak: int = 0      # max queue depth reached
    tokens_by_tier: Dict[str, int] = dataclasses.field(default_factory=dict)
    degraded_token_frac: float = 0.0   # tokens emitted below the top tier
    tier_transitions: List[Tuple[int, str]] = dataclasses.field(
        default_factory=list)      # (virtual step, new tier)
    health: Dict[str, int] = dataclasses.field(default_factory=dict)
                                   # estimator health-guard counters summed
                                   # over the run (lane-steps flagged)
    index_restores: int = 0        # digest-verify mismatches repaired
    step_faults: int = 0           # FaultErrors caught + retried at step
                                   # boundaries
    # -- raw-speed accounting (DESIGN.md SS16) -------------------------------
    admit_skipped: int = 0         # bounded-lookahead admission holds
    spec_proposed: int = 0         # lane-positions offered by speculative
                                   # rounds (n_active * spec_k per step)
    spec_accepted: int = 0         # lane-positions actually advanced
    spec_acceptance: float = 0.0   # accepted / proposed (0 when spec off)
    spec_acceptance_by_tier: Dict[str, float] = dataclasses.field(
        default_factory=dict)      # per VERIFIER tier (the ladder walks the
                                   # verifier; the draft stays fixed)
    draft_flagged: int = 0         # lane-rounds where the draft pass was
                                   # health-flagged -> non-spec fallback
    prefix: Dict[str, int] = dataclasses.field(default_factory=dict)
                                   # this run's prefix-pool deltas: hits,
                                   # saved_steps, inserted, evictions
    # -- step-time attribution (obs satellite: device vs host split) ---------
    step_device_ms_mean: float = 0.0   # mean compiled-step + readback time
    step_host_ms_mean: float = 0.0     # mean host bookkeeping/callback time
                                       # per step (previously swallowed into
                                       # the latency figure)

    def summary(self) -> str:
        ded = f"{self.dedup_ratio_mean:.2f}" \
            if self.dedup_ratio_mean is not None else "n/a"
        base = (f"{len(self.completions)} requests, {self.steps} steps, "
                f"{self.goodput_tok_s:.1f} tok/s goodput, per-token p50 "
                f"{self.p50_token_ms:.2f}ms p95 {self.p95_token_ms:.2f}ms "
                f"p99 {self.p99_token_ms:.2f}ms, step device "
                f"{self.step_device_ms_mean:.2f}ms + host "
                f"{self.step_host_ms_mean:.2f}ms, "
                f"occupancy {self.occupancy_mean:.2f} "
                f"(steady {self.occupancy_steady:.2f}), probe dedup {ded}")
        if self.rejects_by_reason or self.tier_transitions or \
                self.index_restores or self.step_faults:
            base += (f"; shed {self.shed_rate:.2f} {self.rejects_by_reason}"
                     f", degraded frac {self.degraded_token_frac:.2f} "
                     f"({len(self.tier_transitions)} tier moves), "
                     f"{self.index_restores} index restores, "
                     f"{self.step_faults} step faults")
        if self.spec_proposed:
            base += (f"; spec acceptance {self.spec_acceptance:.2f} "
                     f"({self.spec_accepted}/{self.spec_proposed}, "
                     f"{self.draft_flagged} draft-flagged)")
        if self.prefix.get("hits") or self.prefix.get("inserted"):
            base += (f"; prefix hits {self.prefix.get('hits', 0)} saving "
                     f"{self.prefix.get('saved_steps', 0)} replay steps")
        if self.admit_skipped:
            base += f"; {self.admit_skipped} admission holds"
        return base


class Server:
    """Admission queue + run loop around one ``Scheduler``.

    Requests enter via ``submit`` (immediate) or a pre-built arrival list
    (``run(arrivals=...)``); free slots are filled FIFO from the queue at
    every step boundary, so a completion recycles its lane into the next
    queued request on the very next step. ``cfg`` (``ServingConfig``)
    activates the overload policy; the default config keeps every mechanism
    off and reproduces the plain unbounded loop.
    """

    def __init__(self, scheduler: Scheduler,
                 cfg: Optional[ServingConfig] = None, obs=None):
        self.scheduler = scheduler
        self.cfg = cfg or ServingConfig()
        self.cfg.validate()
        # optional observability layer (obs.Observability): harvest cadence,
        # span tracing, shadow sampling. The scheduler's instrumented step
        # is identical with or without it — obs only reads.
        self.obs = obs
        if obs is not None:
            obs.attach(self)
        scheduler.verify_index_every = self.cfg.verify_index_every
        if not scheduler._step_fns:
            # policy reaches mechanism only before the first compile: the
            # guard is baked into each tier's executable
            scheduler.health_guard = self.cfg.health_guard
        self.ladder: Tuple[str, ...] = tuple(
            self.cfg.degrade_ladder or default_ladder(scheduler.tier))
        for tier in self.ladder:
            if tier not in BACKENDS:
                raise ValueError(
                    f"unknown degradation tier {tier!r}; registered "
                    f"backends: {sorted(BACKENDS)}")
        self.queue: deque = deque()
        self._queued_at: dict = {}      # req_id -> virtual step queued
        self._deadline_at: dict = {}    # req_id -> absolute deadline step
        # per-run accumulators, reset by run() (entries are dropped from
        # _queued_at at admission so bookkeeping stays bounded)
        self._run_waits: List[float] = []
        self._rejected: List[Completion] = []
        self._admit_skips: dict = {}    # req_id -> lookahead holds so far
        self.admit_skipped = 0
        self._step_faults = 0
        self._tier_ix = 0
        self._pressure = 0
        self._calm = 0
        self.tier_transitions: List[Tuple[int, str]] = []
        self.step_i = 0

    def submit(self, request: Request) -> None:
        cfg = self.cfg
        if cfg.max_queue and len(self.queue) >= cfg.max_queue:
            # backpressure: shed at the door instead of growing an unbounded
            # backlog every queued request then times out in
            self._reject(request, "queue_full",
                         f"admission queue full ({cfg.max_queue})")
            return
        ddl = request.deadline or cfg.default_deadline
        if ddl:
            self._deadline_at[request.req_id] = self.step_i + int(ddl)
        self._queued_at[request.req_id] = float(self.step_i)
        self.queue.append(request)
        if self.obs is not None:
            self.obs.on_submit(self, request)

    def _reject(self, req: Request, reason: str, error: str,
                queued_at: Optional[float] = None) -> None:
        """Close a request out as an errored, token-less completion. The
        queue wait (if it queued at all) is recorded — shed requests waited
        too, and hiding them would flatter the wait metric."""
        now = time.perf_counter()
        if queued_at is not None:
            self._run_waits.append(self.step_i - queued_at)
        self._deadline_at.pop(req.req_id, None)
        comp = Completion(request=req, tokens=[], log_probs=[], log_zs=[],
                          admit_time=now, first_token_time=None,
                          done_time=now, error=error, reason=reason)
        self._rejected.append(comp)
        if self.obs is not None:
            self.obs.on_reject(self, req, reason)
        if req.on_complete is not None:
            req.on_complete(req, comp)

    def _admit_ready(self) -> None:
        """Fill free lanes from the queue. Default: strict FIFO (the PR-6
        behavior, byte-identical when ``admit_window == 0``). With
        ``admit_window > 0`` the pass does bounded-lookahead first-fit: a
        request whose preferred (prefix-block-owning) data replica has no
        free lane is HELD — put back at the queue head in order — so later
        requests that fit elsewhere admit instead of blocking behind it.
        Each hold is counted (``admit_skipped``); a request held
        ``admit_hold`` times, or whose deadline is within ``admit_hold``
        steps, force-admits anywhere (forfeiting its cache hit), so no
        request starves past its deadline."""
        cfg = self.cfg
        held: List[Request] = []
        while self.queue and self.scheduler.n_free:
            req = self.queue.popleft()
            queued = self._queued_at.get(req.req_id, self.step_i)
            ddl_at = self._deadline_at.get(req.req_id)
            if ddl_at is not None and ddl_at - self.step_i < 1:
                # expired while queued: shed before paying for prefill
                self._queued_at.pop(req.req_id, None)
                self._admit_skips.pop(req.req_id, None)
                self._reject(req, "deadline_queue",
                             f"deadline lapsed after {self.step_i - queued:g}"
                             " steps in queue", queued_at=queued)
                continue
            if cfg.admit_window and len(held) < cfg.admit_window:
                _, owner = self.scheduler.prefix_preview(req)
                if owner is not None and \
                        self.scheduler.free_in_replica(owner) == 0:
                    skips = self._admit_skips.get(req.req_id, 0)
                    starving = skips + 1 >= cfg.admit_hold or (
                        ddl_at is not None
                        and ddl_at - self.step_i <= cfg.admit_hold)
                    if not starving:
                        self._admit_skips[req.req_id] = skips + 1
                        self.admit_skipped += 1
                        held.append(req)
                        continue
            self._queued_at.pop(req.req_id, None)
            self._admit_skips.pop(req.req_id, None)
            remaining = None if ddl_at is None else int(ddl_at - self.step_i)
            try:
                self.scheduler.admit(req, deadline_steps=remaining)
            except FaultError as e:
                # injected admission failure: reject cleanly, nothing else
                # in the batch is touched (admit raises before any mutation)
                self._reject(req, "fault_injected", str(e), queued_at=queued)
                continue
            except ValueError as e:
                # one unadmittable request (over cache capacity, empty
                # prompt) must not kill the loop for every other request:
                # reject it with an errored, token-less completion
                self._reject(req, "admit_rejected", str(e), queued_at=queued)
                continue
            self._deadline_at.pop(req.req_id, None)
            self._run_waits.append(self.step_i - queued)
        for req in reversed(held):
            self.queue.appendleft(req)

    def _update_tier(self) -> None:
        """Hysteresis ladder walk on queue depth. Pressure (depth >= high)
        must persist ``degrade_after`` consecutive steps to step down; calm
        (depth <= low) must persist ``restore_after`` steps to step up; the
        dead band between the watermarks holds the current tier and resets
        neither direction into flapping."""
        cfg = self.cfg
        if not cfg.degrade_high or len(self.ladder) < 2:
            return
        depth = len(self.queue)
        if depth >= cfg.degrade_high:
            self._pressure += 1
            self._calm = 0
        elif depth <= cfg.degrade_low:
            self._calm += 1
            self._pressure = 0
        else:
            self._pressure = 0
            self._calm = 0
        if self._pressure >= cfg.degrade_after and \
                self._tier_ix < len(self.ladder) - 1:
            self._tier_ix += 1
            self._pressure = 0
            self.scheduler.set_tier(self.ladder[self._tier_ix])
            self.tier_transitions.append(
                (self.step_i, self.ladder[self._tier_ix]))
        elif self._calm >= cfg.restore_after and self._tier_ix > 0:
            self._tier_ix -= 1
            self._calm = 0
            self.scheduler.set_tier(self.ladder[self._tier_ix])
            self.tier_transitions.append(
                (self.step_i, self.ladder[self._tier_ix]))

    def run(self, arrivals: Optional[Sequence[Arrival]] = None,
            max_steps: int = 100_000,
            on_step: Optional[Callable] = None) -> ServerReport:
        """Drive the loop until every submitted/arriving request completes
        (or ``max_steps``). Returns the traffic report. Hitting
        ``max_steps`` FLUSHES all queued and in-flight work as errored
        completions ('server_stopped') — accounting always balances, nothing
        is silently stranded."""
        pending = deque(sorted(arrivals or [], key=lambda a: a.at_step))
        completions: List[Completion] = []
        token_lat: List[float] = []
        steady_occ: List[float] = []
        run_records: List[dict] = []    # THIS run's step records only — a
                                        # reused/warmed scheduler must not
                                        # leak its history into the report
        t_start = None
        t_end = None
        steps = 0
        queue_depth_peak = 0
        # _run_waits/_rejected are NOT reset here: sheds recorded by
        # submit() calls made before run() (queue_full backpressure) belong
        # to this run's report; both reset after the report is assembled
        self._step_faults = 0
        self.admit_skipped = 0
        self._admit_skips = {}
        self.tier_transitions = []
        self._tier_ix = 0
        self._pressure = 0
        self._calm = 0
        pf0 = self.scheduler.prefix.stats() \
            if self.scheduler.prefix is not None else None
        self.scheduler.set_tier(self.ladder[0])
        while steps < max_steps:
            while pending and pending[0].at_step <= self.step_i:
                self.submit(pending.popleft().request)
            queue_depth_peak = max(queue_depth_peak, len(self.queue))
            if not self.queue and self.scheduler.n_in_flight == 0:
                if not pending:
                    break
                # fast-forward the idle gap to the next arrival
                self.step_i = max(self.step_i, int(np.ceil(
                    pending[0].at_step)))
                continue
            demand_backed_up = bool(self.queue)
            self._admit_ready()
            self._update_tier()
            if self.scheduler.n_in_flight == 0:
                # everything queued was rejected at admission: nothing to
                # step (and no occupancy sample to take)
                continue
            if t_start is None:
                t_start = time.perf_counter()
            try:
                rec = self.scheduler.step(queue_depth=len(self.queue))
            except FaultError:
                # injected step-boundary fault: the compiled step never ran,
                # the table is unadvanced — count it, burn one loop
                # iteration against max_steps (bounding retry storms) and
                # retry WITHOUT advancing the virtual clock, so arrival
                # timing and every request's tokens are unchanged
                self._step_faults += 1
                steps += 1
                continue
            run_records.append(rec)
            now = time.perf_counter()
            if demand_backed_up:
                steady_occ.append(rec["occupancy"])
            for comp in rec["completions"]:
                completions.append(comp)
                t_end = now
            self.step_i += 1
            steps += 1
            if self.obs is not None:
                self.obs.on_step(self, rec)
            if on_step is not None:
                on_step(self, rec)
        # flush: anything still queued or in-flight at exit (max_steps hit)
        # becomes an errored completion instead of being silently stranded
        while self.queue:
            req = self.queue.popleft()
            queued = self._queued_at.pop(req.req_id, self.step_i)
            self._reject(req, "server_stopped",
                         "server stopped before admission", queued_at=queued)
        drained = self.scheduler.drain("server_stopped")
        if drained:
            completions.extend(drained)
            t_end = time.perf_counter()
        # latency accounting from completion records: token i's latency is
        # the gap between consecutive emissions; completions record only the
        # first/last stamps, so spread the post-first-token budget evenly —
        # the steady-state decode cadence (every live lane emits once per
        # step) makes this exact up to scheduler jitter.
        for comp in completions:
            n = len(comp.tokens)
            if n == 0:
                continue
            first = (comp.first_token_time or comp.done_time) \
                - comp.admit_time
            token_lat.append(first)
            if n > 1 and comp.first_token_time is not None:
                per = (comp.done_time - comp.first_token_time) / (n - 1)
                token_lat.extend([per] * (n - 1))
        total_tokens = sum(len(c.tokens) for c in completions)
        wall = (t_end - t_start) \
            if (t_start is not None and t_end is not None) else float("nan")
        n_probe = self.scheduler.engine.cfg.partition.n_probe
        live = [r for r in run_records if r["n_active"] > 0]
        occ = [r["occupancy"] for r in live]
        waits = self._run_waits
        completions.extend(self._rejected)
        self._run_waits = []
        self._rejected = []
        fills: dict = {}
        for r in live:
            if r["head_live"] > 0:
                fills.setdefault(r["n_active"], []).append(
                    r["head_live"] / (r["n_active"] * n_probe))
        dedup = [x for v in fills.values() for x in v]
        rejects: Dict[str, int] = {}
        for c in completions:
            if c.error is not None:
                reason = c.reason or "error"
                rejects[reason] = rejects.get(reason, 0) + 1
        tokens_by_tier: Dict[str, int] = {}
        health = {"flagged": 0, "nonfinite_z": 0, "empty_head": 0,
                  "nonfinite_score": 0}
        index_restores = 0
        for r in run_records:
            tier = r.get("tier", self.ladder[0])
            tokens_by_tier[tier] = tokens_by_tier.get(tier, 0) \
                + r.get("n_emitted", 0)
            health["flagged"] += r.get("health_flagged", 0)
            health["nonfinite_z"] += r.get("health_nonfinite_z", 0)
            health["empty_head"] += r.get("health_empty_head", 0)
            health["nonfinite_score"] += r.get("health_nonfinite_score", 0)
            index_restores += int(r.get("index_restored", False))
        degraded = sum(v for k, v in tokens_by_tier.items()
                       if k != self.ladder[0])
        n_errored = sum(1 for c in completions if c.error is not None)
        # speculative-decoding accounting: acceptance overall and per
        # VERIFIER tier (rounds the ladder served at a lower rung verify
        # with that rung's backend; the draft never moves)
        spec_proposed = sum(r.get("spec_proposed", 0) for r in run_records)
        spec_accepted = sum(r.get("spec_accepted", 0) for r in run_records)
        draft_flagged = sum(r.get("draft_flagged", 0) for r in run_records)
        spec_by_tier: Dict[str, List[int]] = {}
        for r in run_records:
            if r.get("spec_proposed"):
                ent = spec_by_tier.setdefault(r["tier"], [0, 0])
                ent[0] += r.get("spec_accepted", 0)
                ent[1] += r["spec_proposed"]
        prefix_stats: Dict[str, int] = {}
        if pf0 is not None:
            pf1 = self.scheduler.prefix.stats()
            prefix_stats = {k: pf1[k] - pf0[k] for k in pf0
                            if k != "cached_blocks"}
            prefix_stats["cached_blocks"] = pf1["cached_blocks"]
        dev_ms = [r["wall_device_s"] * 1e3 for r in run_records
                  if "wall_device_s" in r]
        host_ms = [r["wall_host_s"] * 1e3 for r in run_records
                   if "wall_host_s" in r]
        report = ServerReport(
            completions=completions,
            wall_s=wall,
            steps=steps,
            goodput_tok_s=total_tokens / wall if wall and wall > 0
            else float("nan"),
            p50_token_ms=float(np.percentile(token_lat, 50) * 1e3)
            if token_lat else float("nan"),
            p95_token_ms=float(np.percentile(token_lat, 95) * 1e3)
            if token_lat else float("nan"),
            p99_token_ms=float(np.percentile(token_lat, 99) * 1e3)
            if token_lat else float("nan"),
            step_device_ms_mean=float(np.mean(dev_ms)) if dev_ms else 0.0,
            step_host_ms_mean=float(np.mean(host_ms)) if host_ms else 0.0,
            peak_concurrency=max((r["n_active"] for r in live), default=0),
            occupancy_mean=float(np.mean(occ)) if occ else 0.0,
            occupancy_steady=float(np.mean(steady_occ)) if steady_occ
            else (float(np.mean(occ)) if occ else 0.0),
            dedup_ratio_mean=float(np.mean(dedup)) if dedup else None,
            dedup_by_fill={k: float(np.mean(v))
                           for k, v in sorted(fills.items())},
            queue_wait_steps_mean=float(np.mean(waits)) if waits else 0.0,
            rejects_by_reason=rejects,
            shed_rate=n_errored / len(completions) if completions else 0.0,
            queue_depth_peak=queue_depth_peak,
            tokens_by_tier=tokens_by_tier,
            degraded_token_frac=degraded / max(1, total_tokens),
            tier_transitions=list(self.tier_transitions),
            health=health,
            index_restores=index_restores,
            step_faults=self._step_faults,
            admit_skipped=self.admit_skipped,
            spec_proposed=spec_proposed,
            spec_accepted=spec_accepted,
            spec_acceptance=spec_accepted / spec_proposed
            if spec_proposed else 0.0,
            spec_acceptance_by_tier={t: a / p for t, (a, p)
                                     in sorted(spec_by_tier.items())},
            draft_flagged=draft_flagged,
            prefix=prefix_stats)
        if self.obs is not None:
            self.obs.on_done(self, report)
        return report
