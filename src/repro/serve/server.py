"""Traffic-facing serving loop over the slot scheduler (DESIGN.md SS12).

The ``Scheduler`` is mechanism (slot table + one compiled mixed step); the
``Server`` is policy: an admission queue, arrival processes (Poisson or a
replayed trace), slot recycling back into admission, streaming per-token /
per-request callbacks, and the latency accounting the serving benchmark
reports.

Time model: arrivals are scheduled on a **virtual step clock** (a request
"arrives" at step t), which keeps traffic generation deterministic and
backend-speed-independent — the same trace replays bit-identically on any
machine. Latency metrics are real wall-clock, measured around the compiled
step. When the table drains and the queue is empty but arrivals remain in
the future, the clock fast-forwards to the next arrival (idle steps are not
simulated).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, List, Optional, Sequence

import numpy as np

from .scheduler import Completion, Request, Scheduler


@dataclasses.dataclass
class Arrival:
    at_step: float
    request: Request


def poisson_arrivals(requests: Sequence[Request], rate: float,
                     seed: int = 0) -> List[Arrival]:
    """Poisson process on the virtual step clock: inter-arrival gaps are
    Exp(rate) steps (``rate`` = expected requests per scheduler step)."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for req in requests:
        t += float(rng.exponential(1.0 / max(rate, 1e-9)))
        out.append(Arrival(at_step=t, request=req))
    return out


def trace_arrivals(requests: Sequence[Request],
                   at_steps: Sequence[float]) -> List[Arrival]:
    """Replay a recorded trace: request i arrives at virtual step
    ``at_steps[i]``."""
    assert len(requests) == len(at_steps)
    return sorted((Arrival(float(t), r)
                   for t, r in zip(at_steps, requests)),
                  key=lambda a: a.at_step)


@dataclasses.dataclass
class ServerReport:
    completions: List[Completion]
    wall_s: float                  # first admission -> last completion
    steps: int
    goodput_tok_s: float           # emitted tokens / wall_s
    p50_token_ms: float            # per-token latency percentiles over all
    p95_token_ms: float            #   emitted tokens (gap to previous token
                                   #   of the same request; first token:
                                   #   admission -> emit)
    peak_concurrency: int          # max live lanes reached during the run
    occupancy_mean: float          # mean live-lane fraction over live steps
    occupancy_steady: float        # same, but only while demand exceeded
                                   #   capacity (queue non-empty at step
                                   #   start) — the saturation figure
    dedup_ratio_mean: Optional[float]  # mean U / (n_active * n_probe)
    dedup_by_fill: dict            # n_active -> mean dedup ratio
    queue_wait_steps_mean: float   # admission queueing delay (virtual steps)

    def summary(self) -> str:
        ded = f"{self.dedup_ratio_mean:.2f}" \
            if self.dedup_ratio_mean is not None else "n/a"
        return (f"{len(self.completions)} requests, {self.steps} steps, "
                f"{self.goodput_tok_s:.1f} tok/s goodput, per-token p50 "
                f"{self.p50_token_ms:.2f}ms p95 {self.p95_token_ms:.2f}ms, "
                f"occupancy {self.occupancy_mean:.2f} "
                f"(steady {self.occupancy_steady:.2f}), probe dedup {ded}")


class Server:
    """Admission queue + run loop around one ``Scheduler``.

    Requests enter via ``submit`` (immediate) or a pre-built arrival list
    (``run(arrivals=...)``); free slots are filled FIFO from the queue at
    every step boundary, so a completion recycles its lane into the next
    queued request on the very next step.
    """

    def __init__(self, scheduler: Scheduler):
        self.scheduler = scheduler
        self.queue: deque = deque()
        self._queued_at: dict = {}      # req_id -> virtual step queued
        # per-run accumulators, reset by run() (entries are dropped from
        # _queued_at at admission so bookkeeping stays bounded)
        self._run_waits: List[float] = []
        self._rejected: List[Completion] = []
        self.step_i = 0

    def submit(self, request: Request) -> None:
        self._queued_at[request.req_id] = float(self.step_i)
        self.queue.append(request)

    def _admit_ready(self) -> None:
        while self.queue and self.scheduler.n_free:
            req = self.queue.popleft()
            queued = self._queued_at.pop(req.req_id, self.step_i)
            try:
                self.scheduler.admit(req)
            except ValueError as e:
                # one unadmittable request (over cache capacity, empty
                # prompt) must not kill the loop for every other request:
                # reject it with an errored, token-less completion
                now = time.perf_counter()
                comp = Completion(request=req, tokens=[], log_probs=[],
                                  log_zs=[], admit_time=now,
                                  first_token_time=None, done_time=now,
                                  error=str(e))
                self._rejected.append(comp)
                if req.on_complete is not None:
                    req.on_complete(req, comp)
                continue
            self._run_waits.append(self.step_i - queued)

    def run(self, arrivals: Optional[Sequence[Arrival]] = None,
            max_steps: int = 100_000,
            on_step: Optional[Callable] = None) -> ServerReport:
        """Drive the loop until every submitted/arriving request completes
        (or ``max_steps``). Returns the traffic report."""
        pending = deque(sorted(arrivals or [], key=lambda a: a.at_step))
        completions: List[Completion] = []
        token_lat: List[float] = []
        steady_occ: List[float] = []
        run_records: List[dict] = []    # THIS run's step records only — a
                                        # reused/warmed scheduler must not
                                        # leak its history into the report
        t_start = None
        t_end = None
        steps = 0
        self._run_waits = []
        self._rejected = []
        while steps < max_steps:
            while pending and pending[0].at_step <= self.step_i:
                self.submit(pending.popleft().request)
            if not self.queue and self.scheduler.n_in_flight == 0:
                if not pending:
                    break
                # fast-forward the idle gap to the next arrival
                self.step_i = max(self.step_i, int(np.ceil(
                    pending[0].at_step)))
                continue
            demand_backed_up = bool(self.queue)
            self._admit_ready()
            if self.scheduler.n_in_flight == 0:
                # everything queued was rejected at admission: nothing to
                # step (and no occupancy sample to take)
                continue
            if t_start is None:
                t_start = time.perf_counter()
            rec = self.scheduler.step()
            run_records.append(rec)
            now = time.perf_counter()
            if demand_backed_up:
                steady_occ.append(rec["occupancy"])
            for comp in rec["completions"]:
                completions.append(comp)
                t_end = now
            self.step_i += 1
            steps += 1
            if on_step is not None:
                on_step(self, rec)
        # latency accounting from completion records: token i's latency is
        # the gap between consecutive emissions; completions record only the
        # first/last stamps, so spread the post-first-token budget evenly —
        # the steady-state decode cadence (every live lane emits once per
        # step) makes this exact up to scheduler jitter.
        for comp in completions:
            n = len(comp.tokens)
            if n == 0:
                continue
            first = (comp.first_token_time or comp.done_time) \
                - comp.admit_time
            token_lat.append(first)
            if n > 1 and comp.first_token_time is not None:
                per = (comp.done_time - comp.first_token_time) / (n - 1)
                token_lat.extend([per] * (n - 1))
        total_tokens = sum(len(c.tokens) for c in completions)
        wall = (t_end - t_start) if (t_start and t_end) else float("nan")
        n_probe = self.scheduler.engine.cfg.partition.n_probe
        live = [r for r in run_records if r["n_active"] > 0]
        occ = [r["occupancy"] for r in live]
        waits = self._run_waits
        completions.extend(self._rejected)
        fills: dict = {}
        for r in live:
            if r["head_live"] > 0:
                fills.setdefault(r["n_active"], []).append(
                    r["head_live"] / (r["n_active"] * n_probe))
        dedup = [x for v in fills.values() for x in v]
        return ServerReport(
            completions=completions,
            wall_s=wall,
            steps=steps,
            goodput_tok_s=total_tokens / wall if wall and wall > 0
            else float("nan"),
            p50_token_ms=float(np.percentile(token_lat, 50) * 1e3)
            if token_lat else float("nan"),
            p95_token_ms=float(np.percentile(token_lat, 95) * 1e3)
            if token_lat else float("nan"),
            peak_concurrency=max((r["n_active"] for r in live), default=0),
            occupancy_mean=float(np.mean(occ)) if occ else 0.0,
            occupancy_steady=float(np.mean(steady_occ)) if steady_occ
            else (float(np.mean(occ)) if occ else 0.0),
            dedup_ratio_mean=float(np.mean(dedup)) if dedup else None,
            dedup_by_fill={k: float(np.mean(v))
                           for k, v in sorted(fills.items())},
            queue_wait_steps_mean=float(np.mean(waits)) if waits else 0.0)
