"""Shared-prefix KV cache for the slot scheduler (DESIGN.md SS16a).

Under shared-context traffic every admitted request replays its full prompt
through its own KV lane one token per step — even when hundreds of prompts
open with the same system preamble. This module keeps a fixed-capacity
device-resident **prefix pool**: KV rows for previously-served prompt
prefixes at token-block granularity, matched host-side on admission and
copied into the new lane with ONE traced gather + window write, so a
request whose first L prompt tokens are cached starts replay at position L
instead of 0.

Design split (mirrors the scheduler's own host/device split):

 * **Host: a radix-trie-lite.** Nodes are keyed ``(parent_block_id,
   token_bytes)`` — one node per ``block_tokens``-token chunk, chained
   through parent ids, so matching a prompt is a dict walk and two prompts
   share exactly their common block-aligned prefix. Eviction is ref-counted
   LRU over *leaf* nodes (a node with children is pinned: evicting it would
   orphan longer cached prefixes). All of this is plain python — it runs
   once per admission/completion, never per token.
 * **Device: a block pool per KV leaf.** For every cache leaf
   (*stack, S, L, n_kv, Dh) the pool holds (*stack, n_blocks,
   block_tokens, n_kv, Dh). ``load`` gathers a traced id vector of blocks
   and lands them in the lane with one ``write_lane_window``; ``save``
   copies one block out of a finished lane. Both are jitted once — traced
   lane/offset/ids, static shapes — so the pool adds exactly two
   executables to the scheduler's zero-recompile budget.

Correctness leans on two facts. (1) KV rows are a pure function of the
token prefix, absolute positions, and the (frozen) params, so pool rows
are bit-identical to the rows replay would have produced — tokens after a
prefix hit are bit-identical to a cold lane. (2) ``load`` writes the full
static match window (padded ids gather garbage); positions >= the matched
length L are garbage, but the lane resumes at t_stream = L and the decode
step overwrites each position before it is ever attended (the same
sequential-overwrite argument the speculative rollback relies on), while
the per-lane validity mask hides everything beyond the frontier. Neither
argument survives sliding-window ring buffers or recurrent decode states,
so the scheduler gates the pool on full-attention KV states.

Copy-vs-share: lanes COPY pool blocks instead of page-sharing them, so a
loaded lane never references the pool again — eviction needs no lane
refcounts and can never corrupt an in-flight request.

Under the (data, model) serving mesh the pool's block axis is sharded over
``data`` exactly like the slot table's lane axis; blocks are allocated
replica-local so a chain lives with its owner replica, admission prefers
that replica (see ``Scheduler.admit`` / server lookahead), and a forced
cross-replica admission just forfeits the hit (t0 = 0) rather than paying
a cross-replica gather.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models.attention import slice_lane_window, write_lane_window


def cache_is_kv_only(cache) -> bool:
    """True when every decode-state leaf is a full-attention KV buffer
    ((*stack, S, L, n_kv, Dh) named 'k'/'v') — the only states whose rows
    can be block-copied and position-offset. Recurrent leaves (wkv/ssm/
    conv) fold history into O(1) state and cannot be rewound or spliced."""
    ok = [True]
    def check(path, leaf):
        name = None
        for p in reversed(path):
            if isinstance(p, jax.tree_util.DictKey):
                name = p.key
                break
        if name not in ("k", "v") or np.ndim(leaf) < 4:
            ok[0] = False
    jax.tree_util.tree_map_with_path(check, cache)
    return ok[0]


class PrefixPool:
    """Fixed-capacity shared-prefix KV pool. Built by the scheduler against
    its own decode-state template and (optional) mesh shardings."""

    def __init__(self, cache_template, n_blocks: int, block_tokens: int,
                 max_match_blocks: int, mesh=None, cache_shardings=None,
                 n_replicas: int = 1):
        if n_blocks < 1 or block_tokens < 1:
            raise ValueError("prefix pool needs n_blocks/block_tokens >= 1")
        if n_blocks % n_replicas:
            raise ValueError(
                f"prefix_cache_blocks {n_blocks} must divide the data "
                f"degree {n_replicas} (blocks are replica-local)")
        if not cache_is_kv_only(cache_template):
            raise NotImplementedError(
                "the prefix cache block-copies full-attention KV rows; "
                "this model's decode state has recurrent/windowed leaves")
        self.n_blocks = n_blocks
        self.block_tokens = block_tokens
        self.max_match_blocks = max_match_blocks
        self.n_replicas = n_replicas
        self.blocks_per_replica = n_blocks // n_replicas

        def make(leaf):
            shape = list(leaf.shape)
            shape[-4] = n_blocks
            shape[-3] = block_tokens
            return jnp.zeros(shape, leaf.dtype)

        self.pool = jax.tree.map(make, cache_template)
        pool_sh = None
        if mesh is not None:
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as P
            from ..launch.mesh import serve_cache_spec
            specs = jax.tree_util.tree_map_with_path(serve_cache_spec,
                                                     self.pool)
            pool_sh = jax.tree.map(
                lambda s: NamedSharding(mesh, s), specs,
                is_leaf=lambda x: isinstance(x, P))
            self.pool = jax.device_put(self.pool, pool_sh)

        # -- trie-lite: (parent_block_id, chunk_bytes) -> block_id
        self._node: Dict[Tuple[int, bytes], int] = {}
        self._key_of: Dict[int, Tuple[int, bytes]] = {}
        self._children: Dict[int, int] = {}
        self._lru: Dict[int, int] = {}
        self._tick = 0
        self._free: List[List[int]] = [
            list(range(r * self.blocks_per_replica,
                       (r + 1) * self.blocks_per_replica))
            for r in range(n_replicas)]
        # -- counters (surfaced through scheduler.step records / reports)
        self.hits = 0               # admissions that loaded >= 1 block
        self.saved_steps = 0        # replay steps skipped (sum of t0)
        self.inserted = 0           # blocks written into the pool
        self.evictions = 0
        self.load_traces = 0
        self.save_traces = 0

        donate = (0,) if jax.default_backend() != "cpu" else ()
        load_kw = {} if cache_shardings is None else \
            {"out_shardings": cache_shardings}
        save_kw = {} if pool_sh is None else {"out_shardings": pool_sh}
        bt = block_tokens
        mcap = max_match_blocks

        @partial(jax.jit, donate_argnums=donate, **load_kw)
        def load(cache, pool, ids, lane):
            self.load_traces += 1

            def leaf_load(cleaf, pleaf):
                got = jnp.take(pleaf, ids, axis=-4)     # (..., Mcap, Bt, ...)
                lead = got.shape[:-4]
                rows = got.reshape(*lead, 1, mcap * bt, *got.shape[-2:])
                return write_lane_window(cleaf, rows, lane, 0)

            return jax.tree.map(leaf_load, cache, pool)

        @partial(jax.jit, donate_argnums=donate, **save_kw)
        def save(pool, cache, lane, start, block_id):
            self.save_traces += 1

            def leaf_save(pleaf, cleaf):
                rows = slice_lane_window(cleaf, lane, start, bt)
                return write_lane_window(pleaf, rows, block_id, 0)

            return jax.tree.map(leaf_save, pool, cache)

        self._load_fn = load
        self._save_fn = save

    # -- host trie ----------------------------------------------------------

    def _chunks(self, tokens: np.ndarray, n: int):
        bt = self.block_tokens
        for i in range(n):
            yield np.asarray(tokens[i * bt:(i + 1) * bt],
                             np.int32).tobytes()

    def match(self, tokens, p_len: int) -> Tuple[int, List[int],
                                                 Optional[int]]:
        """Longest cached block-aligned prefix of ``tokens``. Returns
        (matched_blocks, block_ids, owner_replica). The usable match is
        capped at (p_len - 1) // block_tokens: the lane's LAST replay step
        must still execute to emit the first token."""
        limit = min((p_len - 1) // self.block_tokens, self.max_match_blocks)
        ids: List[int] = []
        parent = -1
        for chunk in self._chunks(np.asarray(tokens), limit):
            bid = self._node.get((parent, chunk))
            if bid is None:
                break
            ids.append(bid)
            parent = bid
        self._tick += 1
        for bid in ids:
            self._lru[bid] = self._tick
        owner = ids[0] // self.blocks_per_replica if ids else None
        return len(ids), ids, owner

    def _alloc(self, replica: int, protect) -> Optional[int]:
        free = self._free[replica]
        if free:
            return free.pop(0)
        lo, hi = (replica * self.blocks_per_replica,
                  (replica + 1) * self.blocks_per_replica)
        leaves = [b for b in range(lo, hi)
                  if self._children.get(b, 1) == 0 and b not in protect]
        if not leaves:
            return None
        victim = min(leaves, key=lambda b: self._lru.get(b, 0))
        key = self._key_of.pop(victim)
        del self._node[key]
        del self._children[victim]
        self._lru.pop(victim, None)
        if key[0] >= 0:
            self._children[key[0]] -= 1
        self.evictions += 1
        return victim

    # -- device ops (called by the scheduler) -------------------------------

    def load(self, cache, ids: List[int], lane: int):
        """Copy matched pool blocks into lane ``lane`` of ``cache``; padded
        id slots gather block 0 — garbage beyond the matched length is
        overwritten by replay before it is ever attended."""
        padded = np.zeros((self.max_match_blocks,), np.int32)
        padded[:len(ids)] = ids
        self.hits += 1
        self.saved_steps += len(ids) * self.block_tokens
        return self._load_fn(cache, self.pool, jnp.asarray(padded),
                             jnp.int32(lane))

    def insert(self, tokens, p_len: int, cache, lane: int,
               replica: int = 0) -> int:
        """Register a cleanly-finished lane's prompt blocks: walk the trie,
        save each missing fully-shadowed block out of the lane's KV (one
        jitted copy per new block). Returns the number of blocks saved."""
        limit = min((p_len - 1) // self.block_tokens, self.max_match_blocks)
        parent = -1
        path: set = set()
        saved = 0
        for i, chunk in enumerate(self._chunks(np.asarray(tokens), limit)):
            bid = self._node.get((parent, chunk))
            if bid is None:
                bid = self._alloc(replica, path)
                if bid is None:
                    break
                self._node[(parent, chunk)] = bid
                self._key_of[bid] = (parent, chunk)
                self._children[bid] = 0
                if parent >= 0:
                    self._children[parent] += 1
                self.pool = self._save_fn(
                    self.pool, cache, jnp.int32(lane),
                    jnp.int32(i * self.block_tokens), jnp.int32(bid))
                self.inserted += 1
                saved += 1
            self._tick += 1
            self._lru[bid] = self._tick
            path.add(bid)
            parent = bid
        return saved

    @property
    def n_cached_blocks(self) -> int:
        return len(self._key_of)

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "saved_steps": self.saved_steps,
                "inserted": self.inserted, "evictions": self.evictions,
                "cached_blocks": self.n_cached_blocks}
