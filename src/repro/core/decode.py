"""Fused batched decode paths: one pipeline from coarse probe to log-Ẑ.

This is the serving-side realization of Eq. 5 (DESIGN.md SS4), plus the
batched MINCE (Eq. 6/7) and FMBE (Eq. 9/10) decodes that share its probe
plan — every sublinear estimator consumes the same ``DecodePlan``; none of
them touches ``oracle_retrieve`` (the O(N log N) sort is an accuracy-study
tool, not a serving path). Per decode step for a query batch h (Q, d):

    probe_batch ──► (Q, p) block ids          one (Q,d)x(d,nb) matmul
         │
    plan_heads  ──► union table (U,) + membership mask (Q, U)
    plan_tail   ──► l shared tail samples + rejection mask (Q, l)
         │
    ivf_decode  ──► head_lse, tail_lse, top-k     one Pallas kernel:
         │          (block_q,d) tiles x scalar-prefetched blocks,
         │          online LSE + running top-k, no (Q,p,br) HBM tensor
         ▼
    combine_head_tail_lse ──► log Ẑ          Eq. 5 with n_tail = N - k_eff

Tail samples are drawn **once per step and shared across the batch** (each
query still gets an unbiased tail: the slots are uniform and independent of
q), which turns the tail gather into l row fetches + one (Q,d)x(d,l) matmul
instead of Q*l scattered gathers. Rejection happens per query at block
granularity; the Eq. 5 scale uses n_tail_total = N - k_eff with the
*post-rejection* sample count — the Rao–Blackwellized form of the seed
engine's N/l scale (both are unbiased; conditioning on the survivor count
removes the rejection-noise component of the variance, at the cost of
dropping the tail on the measure-zero-ish event that no sample survives).

Wall-clock (the PR-3 fix): the XLA reference used to gather and score the
full *static capacity* min(Q*p, nb) — at bench scale that is every block,
i.e. an exact pass with gather overhead on top, which is why
BENCH_decode.json recorded speedup_xla 0.56. The XLA paths now trim the
union to a small static ``head_cap`` (auto: n_probe + overlap headroom,
``_resolve_head_cap``) whenever the
*measured* unique count fits — the common case for production decode
batches, whose streams share context — via a ``lax.cond`` whose fallback
branch is the old full-capacity trace, so overflow costs speed, never
correctness.  Head rows and tail rows are then scored by ONE fused
(Q,d)x(d, U*br + l) matmul over a single row gather.

``mimps_decode(..., use_pallas=False)`` runs the same plan through this XLA
path — the interpret/CPU reference the parity tests pin the kernel to.
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..kernels.ivf_score import ivf_decode, union_scores
from . import mince as _mince
from . import mips as _mips
from .estimators import NEG_INF, combine_head_tail_lse
from .feature_maps import FMBEState, fmbe_tail_z, fmbe_z_batch


class DecodePlan(NamedTuple):
    block_ids: jax.Array    # (Q, p)  per-query probed blocks
    head_ids: jax.Array     # (U,)    deduplicated union (pad = repeat last)
    head_live: jax.Array    # ()      number of real (non-pad) union slots
    head_member: jax.Array  # (Q, U)  bool membership mask
    tail_blocks: jax.Array  # (l,)    block of each shared tail sample
    tail_rows: jax.Array    # (l,)    row-in-block of each shared tail sample
    tail_accept: jax.Array  # (Q, l)  bool rejection mask
    k_eff: jax.Array        # (Q,)    real rows covered by probed blocks
    n_accept: jax.Array     # (Q,)    post-rejection tail sample count


class DecodeOut(NamedTuple):
    log_z: jax.Array        # (Q,)
    top_score: jax.Array    # (Q, k)
    top_id: jax.Array       # (Q, k) original row ids
    head_lse: jax.Array     # (Q,)
    tail_lse: jax.Array     # (Q,)  -inf where no tail sample survived
    k_eff: jax.Array        # (Q,)
    head_live: Any = None   # ()   measured deduplicated union size U (probe
                            #      paths only; None for dense decodes) — the
                            #      serving scheduler's dedup-vs-fill metric


def plan_heads(block_ids: jax.Array, capacity: int):
    """Deduplicate a (Q, p) probe table into (head_ids (capacity,),
    member (Q, capacity)).

    The union is sorted and compacted to the front; pad slots repeat the last
    unique id (consecutive identical BlockSpec indices cost no extra DMA) and
    are masked out of every query's membership row, so duplicates are never
    double-counted. ``capacity`` must be >= the unique count; capacity =
    min(Q*p, n_blocks) always is.
    """
    q, p = block_ids.shape
    flat = jnp.sort(block_ids.reshape(-1))
    is_new = jnp.concatenate(
        [jnp.ones((1,), bool), flat[1:] != flat[:-1]])
    tgt = jnp.cumsum(is_new) - 1                       # slot for each element
    n_unique = tgt[-1] + 1
    head_ids = jnp.full((capacity,), flat[-1], jnp.int32)
    head_ids = head_ids.at[tgt].set(flat.astype(jnp.int32))
    slot_live = jnp.arange(capacity) < n_unique
    member = jnp.any(head_ids[None, :, None] == block_ids[:, None, :],
                     axis=-1) & slot_live[None, :]
    return head_ids, member, n_unique


def plan_tail(index: _mips.IVFIndex, key: jax.Array, l: int,
              block_ids: jax.Array):
    """l uniform tail samples over *original* rows, shared across the batch.

    Returns (tail_blocks (l,), tail_rows (l,), accept (Q, l)); sample j is
    rejected for query q iff its block is in q's probed set (those rows are
    already counted exactly in the head). l == 0 yields empty (but
    well-shaped) tail arrays — the head-only plan FMBE consumes.
    """
    idx = jax.random.randint(key, (l,), 0, index.n)
    slots = index.slot_of_row[idx]
    tb = (slots // index.block_rows).astype(jnp.int32)
    tr = (slots % index.block_rows).astype(jnp.int32)
    accept = ~jnp.any(tb[None, None, :] == block_ids[:, :, None], axis=1)
    return tb, tr, accept


def make_plan(index: _mips.IVFIndex, h: jax.Array, key: jax.Array,
              n_probe: int, l: int,
              active: Optional[jax.Array] = None) -> DecodePlan:
    """Probe + dedup + tail-sample: everything the fused kernel consumes.

    ``active`` (Q,) bool marks the real queries of a padded slot-table batch
    (continuous-batching serving): masked-out rows adopt the first live
    row's probe set, so a half-full slot table never inflates the dedup'd
    union U with garbage blocks — U (and the decode's wall-clock) tracks the
    *live* batch, and the dedup-vs-fill metric stays meaningful. Per-query
    outputs of masked rows are well-formed but meaningless (the scheduler
    discards them); active rows are untouched — their membership mask, and
    therefore their candidates and head/tail LSEs, never depend on what the
    other rows probe.
    """
    block_ids = _mips.probe_batch(index, h, n_probe)
    if active is not None:
        donor = block_ids[jnp.argmax(active)]          # (p,) first live row
        block_ids = jnp.where(active[:, None], block_ids, donor[None, :])
    capacity = min(h.shape[0] * n_probe, index.n_blocks)
    head_ids, member, n_unique = plan_heads(block_ids, capacity)
    tb, tr, accept = plan_tail(index, key, l, block_ids)
    k_eff = _mips.head_count(index, block_ids)
    return DecodePlan(block_ids=block_ids, head_ids=head_ids,
                      head_live=n_unique.astype(jnp.int32),
                      head_member=member, tail_blocks=tb, tail_rows=tr,
                      tail_accept=accept, k_eff=k_eff,
                      n_accept=accept.sum(axis=-1))


def head_row_table(index: _mips.IVFIndex, head_ids: jax.Array,
                   member: jax.Array):
    """Original-row view of a (possibly head_cap-trimmed) union slice:
    (head_rows (U*br,) pad-clamped row ids, head_mask (Q, U*br) =
    membership AND slot validity). The one place the pad-handling
    invariant (clamp + rid>=0 masking) lives.

    With ``tail_row_ids`` below, this is how the training losses score a
    plan against a LIVE weight matrix: the (possibly stale) index supplies
    routing only — probe centroids, block layout, tail map — and
    ``w[head_rows]`` / ``w[tail_ids]`` replace its embedded copies, so the
    gradient is exact at the current parameters; everything else (k_eff,
    rejection masks) is layout-only and stays valid as ``w`` drifts
    between refreshes."""
    rid = index.row_id[head_ids]                           # (U, br), -1 pad
    head_rows = jnp.maximum(rid, 0).reshape(-1)
    head_mask = (member[:, :, None] & (rid >= 0)[None]
                 ).reshape(member.shape[0], -1)
    return head_rows, head_mask


def tail_row_ids(index: _mips.IVFIndex, plan: DecodePlan) -> jax.Array:
    """Original row id of every shared tail sample, (l,)."""
    br = index.v_blocks.shape[1]
    return index.row_id.reshape(-1)[plan.tail_blocks * br + plan.tail_rows]


def _resolve_head_cap(head_cap: int, n_probe: int, capacity: int) -> int:
    """0 = auto: the probe width plus headroom for partial overlap (dedup on
    a shared-context batch drives U -> n_probe; the fallback trace covers
    genuinely uncorrelated batches)."""
    if head_cap <= 0:
        head_cap = max(n_probe + max(4, n_probe // 2), 8)
    return min(head_cap, capacity)


def _tail_rows(index: _mips.IVFIndex, plan: DecodePlan):
    """Shared tail rows gathered once into a dense (l, d) staging buffer —
    what both the Pallas kernel's tiled tail phase and the XLA path's fused
    matmul consume (l*d HBM floats either way)."""
    flat = index.v_blocks.reshape(-1, index.v_blocks.shape[-1])
    slots = plan.tail_blocks * index.block_rows + plan.tail_rows
    return flat[slots]


def _tail_row_scores(index: _mips.IVFIndex, h: jax.Array, plan: DecodePlan):
    """Tail staging rows + their (Q, l) f32 scores (one small matmul)."""
    rows = _tail_rows(index, plan)
    ts = jnp.einsum("qd,ld->ql", h, rows,
                    preferred_element_type=jnp.float32)
    return rows, ts


def _masked_tail_lse(ts: jax.Array, accept: jax.Array) -> jax.Array:
    """Per-query tail LSE; genuine -inf where no sample survived (the
    fused-kernel contract)."""
    tail_lse = jax.nn.logsumexp(jnp.where(accept, ts, NEG_INF), axis=-1)
    return jnp.where(jnp.any(accept, axis=-1), tail_lse, -jnp.inf)


def _head_scores_xla(index: _mips.IVFIndex, h: jax.Array, head_ids, member,
                     tail_rows=None):
    """Gather the union's rows once, score with one dense matmul.

    head_ids (U,) / member (Q, U) may be the trimmed or the full-capacity
    slice. When ``tail_rows`` (l, d) is given, the tail rides the SAME
    matmul (one (Q,d)x(d, U*br+l) dot instead of two dispatches) and the
    (Q, l) tail scores are returned alongside.

    Returns (scores (Q, U*br) f32, mask (Q, U*br) bool[, tail (Q, l) f32])
    where mask combines per-query membership with cluster-pad validity.
    """
    nb, br, d = index.v_blocks.shape
    flat = index.v_blocks.reshape(-1, d)
    slot = (head_ids[:, None] * br +
            jnp.arange(br, dtype=jnp.int32)[None, :]).reshape(-1)
    w = jnp.take(flat, slot, axis=0)                       # (U*br, d)
    if tail_rows is not None:
        w = jnp.concatenate([w, tail_rows.astype(w.dtype)], axis=0)
    scores = jax.lax.dot_general(
        h, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                # (Q, U*br [+ l])
    mask = (member[:, :, None] & index.valid[head_ids][None]
            ).reshape(h.shape[0], -1)
    if tail_rows is not None:
        n_head = slot.shape[0]
        return scores[:, :n_head], mask, scores[:, n_head:]
    return scores, mask


def _head_topk(index: _mips.IVFIndex, head_ids, scores, mask, k: int):
    """(head_lse, topv, top slot ids) over masked union scores."""
    br = index.v_blocks.shape[1]
    eff = jnp.where(mask, scores, NEG_INF)
    head_lse = jax.nn.logsumexp(eff, axis=-1)
    topv, pos = jax.lax.top_k(eff, k)
    topi = head_ids[pos // br] * br + pos % br             # global slot ids
    return head_lse, topv, topi.astype(jnp.int32)


def _with_trimmed_head(plan: DecodePlan, head_cap: int, branch_fn):
    """Run ``branch_fn(head_ids, member)`` on the head_cap-trimmed union when
    the measured unique count fits, else on the full capacity (identical
    math, fixed output shapes — overflow costs wall-clock, not correctness).
    """
    capacity = plan.head_ids.shape[0]
    if head_cap >= capacity:
        return branch_fn(plan.head_ids, plan.head_member)
    return jax.lax.cond(
        plan.head_live <= head_cap,
        lambda: branch_fn(plan.head_ids[:head_cap],
                          plan.head_member[:, :head_cap]),
        lambda: branch_fn(plan.head_ids, plan.head_member))


@partial(jax.jit, static_argnames=("n_probe", "l", "k", "use_pallas",
                                   "block_q", "tail_tile", "head_cap",
                                   "interpret"))
def mimps_decode(index: _mips.IVFIndex, h: jax.Array, key: jax.Array,
                 *, n_probe: int, l: int, k: int = 1,
                 use_pallas: bool = True, block_q: int = 128,
                 tail_tile: int = 32, head_cap: int = 0,
                 active: Optional[jax.Array] = None,
                 interpret=None) -> DecodeOut:
    """Batched sublinear decode: h (Q, d) -> log Ẑ, top-k rows, per Eq. 5.

    Embedding bytes touched per step:
      n_blocks*d (centroids) + U*br*d (deduplicated head) + l*d (tail rows)
    vs V*d for the exact path. U <= min(Q*n_probe, n_blocks), and decode
    batches serving overlapping contexts dedup toward U ~ n_probe.

    ``block_q`` / ``tail_tile`` are the Pallas pipeline's autotunable tile
    sizes (kernels.autotune); ``head_cap`` bounds the XLA path's static
    union capacity (0 = auto, see ``_resolve_head_cap``); ``active`` masks
    the live rows of a padded slot-table batch (see ``make_plan``).
    """
    plan = make_plan(index, h, key, n_probe, l, active=active)
    tail_rows_g = _tail_rows(index, plan)
    if use_pallas:
        row_logw = jnp.where(index.valid, 0.0, NEG_INF).astype(jnp.float32)
        head_lse, tail_lse, topv, topi = ivf_decode(
            index.v_blocks, h, plan.head_ids, plan.head_live,
            plan.head_member, row_logw, tail_rows_g, plan.tail_accept,
            k=k, block_q=block_q, tail_tile=tail_tile, interpret=interpret)
    else:
        cap = _resolve_head_cap(head_cap, n_probe, plan.head_ids.shape[0])

        def branch(ids, member):
            scores, mask, ts = _head_scores_xla(index, h, ids, member,
                                                tail_rows=tail_rows_g)
            tl = _masked_tail_lse(ts, plan.tail_accept)
            return _head_topk(index, ids, scores, mask, k) + (tl,)

        head_lse, topv, topi, tail_lse = _with_trimmed_head(plan, cap,
                                                            branch)
    n = index.n
    log_z = combine_head_tail_lse(
        head_lse, tail_lse,
        (n - plan.k_eff).astype(jnp.float32),
        plan.n_accept.astype(jnp.float32))
    top_id = index.row_id.reshape(-1)[topi]
    return DecodeOut(log_z=log_z, top_score=topv, top_id=top_id,
                     head_lse=head_lse, tail_lse=tail_lse, k_eff=plan.k_eff,
                     head_live=plan.head_live)


# ---------------------------------------------------------------------------
# Shared head machinery for the MINCE / FMBE batched backends
# ---------------------------------------------------------------------------

def union_head_scores(index: _mips.IVFIndex, h: jax.Array, plan: DecodePlan,
                      use_pallas: bool, interpret=None, block_q: int = 128):
    """Score the deduplicated probe union for every query.

    Returns (scores (Q, U_cap, br) f32, mask (Q, U_cap, br) bool).

    Traffic: the Pallas path (``kernels.ivf_score.union_scores``) fetches
    each of the U *unique* blocks once per query tile (pad slots elide both
    DMA and compute), i.e. U·br·d embedding floats — the figure the SS5/SS8
    accounting reports. The XLA reference gathers all U_cap =
    min(Q·n_probe, nb) static slots (capacity·br·d, the ``floats_bound``
    ceiling); it is the parity oracle, not the deployment path (which trims
    to ``head_cap`` — see ``mince_decode`` / ``fmbe_decode``).
    """
    if use_pallas:
        scores = union_scores(index.v_blocks, h, plan.head_ids,
                              plan.head_live, block_q=block_q,
                              interpret=interpret)
    else:
        blocks = index.v_blocks[plan.head_ids]              # (U_cap, br, d)
        scores = jnp.einsum("ubd,qd->qub", blocks, h,
                            preferred_element_type=jnp.float32)
    mask = plan.head_member[:, :, None] & index.valid[plan.head_ids][None]
    return scores, mask


@partial(jax.jit, static_argnames=("n_probe", "l", "k", "iters", "solver",
                                   "use_pallas", "head_cap", "block_q",
                                   "interpret"))
def mince_decode(index: _mips.IVFIndex, h: jax.Array, key: jax.Array,
                 *, n_probe: int, l: int, k: int = 1, iters: int = 2,
                 solver: str = "halley", use_pallas: bool = True,
                 head_cap: int = 0, block_q: int = 128,
                 active: Optional[jax.Array] = None,
                 interpret=None) -> DecodeOut:
    """Batched sublinear MINCE (Eq. 6/7): S_k(q) is the IVF probe head, the
    noise set is the plan's shared uniform tail — no oracle sort anywhere.

    Score-once: every embedding row is scored exactly once (the same trimmed
    gather+matmul as MIMPS), and the solver never revisits it. The anchored
    NCE estimating equation's root provably coincides with the Eq. 5 anchor
    (the collapse identity — see ``mince.anchored_solve``), so the serving
    estimate is evaluated in closed form at the anchor; ``iters``/``solver``
    parameterize the general bracketed solvers used by the oracle
    (weighting='paper') and sharded paths (the seed ran 25 cold-start
    iterations per step over the full atom set and still diverged to
    rel_err ~ 3e5 at bench scale).

    Degenerate heads are guarded per query: k_eff == 0 falls back to the
    uniform-noise-only objective (importance sampling over the tail), and an
    empty complement (k_eff == N or zero surviving samples) falls back to
    the exactly-scored head.
    """
    assert l >= 1, "MINCE needs at least one noise sample"
    plan = make_plan(index, h, key, n_probe, l, active=active)
    tail_rows_g = _tail_rows(index, plan)

    n = index.n
    k_eff = plan.k_eff.astype(jnp.float32)
    n_acc = plan.n_accept.astype(jnp.float32)
    n_tail = jnp.maximum(n - k_eff, 0.0)

    def solve(scores, mask, ts):
        """anchored-NCE estimate for one head slice — closed form."""
        hl = jax.nn.logsumexp(jnp.where(mask, scores, NEG_INF), axis=-1)
        tl = _masked_tail_lse(ts, plan.tail_accept)
        # the collapse identity (mince.anchored_solve) proves the anchored
        # estimating equation's unique root IS the Eq. 5 anchor, so the
        # estimate is taken in closed form; the bracketed Halley machinery
        # lives in anchored_solve (cold starts), solve_shared_atoms (oracle
        # weighting='paper') and solve_from_stats (sharded one-psum combine)
        theta = combine_head_tail_lse(hl, tl, n_tail, n_acc)
        return hl, tl, theta

    if use_pallas:
        scores3, mask3 = union_head_scores(index, h, plan, True, interpret,
                                           block_q=block_q)
        q = h.shape[0]
        scores, mask = scores3.reshape(q, -1), mask3.reshape(q, -1)
        ts = jnp.einsum("qd,ld->ql", h, tail_rows_g,
                        preferred_element_type=jnp.float32)
        head_lse, tail_lse, theta = solve(scores, mask, ts)
        _, topv, topi = _head_topk(index, plan.head_ids, scores, mask, k)
    else:
        cap = _resolve_head_cap(head_cap, n_probe, plan.head_ids.shape[0])

        def branch(ids, member):
            scores, mask, ts = _head_scores_xla(index, h, ids, member,
                                                tail_rows=tail_rows_g)
            hl, tl, theta = solve(scores, mask, ts)
            _, topv, topi = _head_topk(index, ids, scores, mask, k)
            return hl, tl, theta, topv, topi

        head_lse, tail_lse, theta, topv, topi = _with_trimmed_head(
            plan, cap, branch)

    # per-query degenerate guards (cannot happen at sane configs, must not NaN)
    uniform = combine_head_tail_lse(
        jnp.full_like(head_lse, NEG_INF), tail_lse,
        jnp.zeros_like(n_acc) + jnp.asarray(n, jnp.float32), n_acc)
    log_z = jnp.where(k_eff == 0, uniform, theta)
    log_z = jnp.where((n_acc == 0) | (n_tail == 0), head_lse, log_z)

    top_id = index.row_id.reshape(-1)[topi]
    return DecodeOut(log_z=log_z, top_score=topv, top_id=top_id,
                     head_lse=head_lse, tail_lse=tail_lse, k_eff=plan.k_eff,
                     head_live=plan.head_live)


@partial(jax.jit, static_argnames=("n_probe", "k", "use_pallas", "head_cap",
                                   "block_q", "block_p", "interpret"))
def fmbe_decode(state: FMBEState, index: _mips.IVFIndex, h: jax.Array,
                key: jax.Array, *, n_probe: int, k: int = 1,
                use_pallas: bool = True, head_cap: int = 0,
                block_q: int = 128, block_p: int = 128,
                active: Optional[jax.Array] = None,
                interpret=None) -> DecodeOut:
    """Batched FMBE decode: exact head + sketch-estimated complement.

    The probed head (the same l=0 plan the candidates come from) is scored
    exactly; the random-feature sketch estimates only the *complement* mass
    via the block-partitioned lambda table (``feature_maps.fmbe_tail_z``):

        log Ẑ = logaddexp(head_lse, log max(phi(h)·lambda_rest, 0+))

    The seed fed the whole vocabulary through the sketch, whose degree-capped
    Taylor expansion collapses once scores exceed ~the cap (rel_err -> 1 at
    bench scale); partitioning confines the sketch's bias/variance to the
    tail fraction of Z, so the hybrid error is bounded by the head-recall
    error regardless of score scale. Falls back to the seed's global-sketch
    estimate when the state has no per-block table. O(P M d) per query plus
    p·P lambda floats, still independent of V. The estimate is deterministic
    given the feature map; ``key`` only feeds the empty tail plan.
    """
    plan = make_plan(index, h, key, n_probe, l=0,   # head-only plan
                     active=active)
    cap = _resolve_head_cap(head_cap, n_probe, plan.head_ids.shape[0])

    if use_pallas:
        scores3, mask3 = union_head_scores(index, h, plan, True, interpret,
                                           block_q=block_q)
        q = h.shape[0]
        head_lse, topv, topi = _head_topk(
            index, plan.head_ids, scores3.reshape(q, -1),
            mask3.reshape(q, -1), k)
    else:
        def branch(ids, member):
            scores, mask = _head_scores_xla(index, h, ids, member)
            return _head_topk(index, ids, scores, mask, k)

        head_lse, topv, topi = _with_trimmed_head(plan, cap, branch)

    if state.lambda_blocks is not None:
        z_tail = fmbe_tail_z(state, h, plan.block_ids,
                             use_pallas=use_pallas, interpret=interpret,
                             block_q=block_q, block_p=block_p)
        log_z = jnp.logaddexp(head_lse,
                              jnp.log(jnp.maximum(z_tail, 1e-30)))
    else:
        z = fmbe_z_batch(state, h, use_pallas=use_pallas, interpret=interpret)
        log_z = jnp.log(jnp.maximum(z, 1e-30))
    top_id = index.row_id.reshape(-1)[topi]
    return DecodeOut(log_z=log_z, top_score=topv, top_id=top_id,
                     head_lse=head_lse,
                     tail_lse=jnp.full_like(log_z, -jnp.inf),
                     k_eff=plan.k_eff, head_live=plan.head_live)


@partial(jax.jit, static_argnames=("n_probe", "k", "use_pallas", "head_cap",
                                   "block_q", "interpret"))
def topk_head_decode(index: _mips.IVFIndex, h: jax.Array, key: jax.Array,
                     *, n_probe: int, k: int = 1, use_pallas: bool = True,
                     head_cap: int = 0, block_q: int = 128,
                     active: Optional[jax.Array] = None,
                     interpret=None) -> DecodeOut:
    """Head-only decode (Eq. 4 / nmimps at the output layer): the cheapest
    retrieval tier of the serving degradation ladder.

    Same probe plan and candidate retrieval as MIMPS, but no tail sampling
    and no complement estimate at all — log Ẑ is the probed head's LSE, a
    deterministic underestimate of log Z (the paper's SS3 shows how far Eq. 4
    falls short as an *estimator*). Serving keeps it anyway: under overload
    the sampling distribution over retrieved candidates is unchanged
    (Gumbel-max renormalizes over the head), only the reported log-prob
    calibration degrades, and the step drops the l·d tail traffic plus the
    tail plan entirely. ``key`` feeds only the (empty) tail plan.
    """
    plan = make_plan(index, h, key, n_probe, l=0, active=active)
    cap = _resolve_head_cap(head_cap, n_probe, plan.head_ids.shape[0])

    if use_pallas:
        scores3, mask3 = union_head_scores(index, h, plan, True, interpret,
                                           block_q=block_q)
        q = h.shape[0]
        head_lse, topv, topi = _head_topk(
            index, plan.head_ids, scores3.reshape(q, -1),
            mask3.reshape(q, -1), k)
    else:
        def branch(ids, member):
            scores, mask = _head_scores_xla(index, h, ids, member)
            return _head_topk(index, ids, scores, mask, k)

        head_lse, topv, topi = _with_trimmed_head(plan, cap, branch)

    top_id = index.row_id.reshape(-1)[topi]
    return DecodeOut(log_z=head_lse, top_score=topv, top_id=top_id,
                     head_lse=head_lse,
                     tail_lse=jnp.full_like(head_lse, -jnp.inf),
                     k_eff=plan.k_eff, head_live=plan.head_live)


# ---------------------------------------------------------------------------
# Estimator health guard (DESIGN.md SS14): no NaN ever reaches sampling
# ---------------------------------------------------------------------------

HEALTH_NONFINITE_Z = 1      # log Ẑ is NaN/Inf (solver blow-up, corrupt data)
HEALTH_EMPTY_HEAD = 2       # probe union covered zero real rows
HEALTH_NONFINITE_SCORE = 4  # a retrieved candidate score is NaN/Inf


def health_flags(out: DecodeOut) -> jax.Array:
    """Per-query health bitmask (Q,) int32 over a ``DecodeOut``.

    Flags the conditions that must never reach the sampler: a non-finite
    log Ẑ (MINCE solver non-convergence, corrupted embeddings, fault
    injection), an empty probe union (every probed block dead — k_eff == 0),
    or non-finite candidate scores. ``tail_lse == -inf`` is NOT flagged —
    that is the documented no-survivor value and the Eq. 5 combine already
    guards it."""
    bad_z = ~jnp.isfinite(out.log_z)
    empty = out.k_eff == 0
    bad_s = jnp.any(~jnp.isfinite(out.top_score), axis=-1)
    return (bad_z.astype(jnp.int32) * HEALTH_NONFINITE_Z
            + empty.astype(jnp.int32) * HEALTH_EMPTY_HEAD
            + bad_s.astype(jnp.int32) * HEALTH_NONFINITE_SCORE)


def apply_health_guard(out: DecodeOut, w: jax.Array, h: jax.Array,
                       k: int, active: Optional[jax.Array] = None):
    """Route unhealthy queries through the exact dense path (Eq. 2 fallback).

    Returns ``(guarded DecodeOut, flags (Q,) int32)``. Healthy batches pay
    one ``jnp.any`` reduction and take the identity branch of a ``lax.cond``
    — outputs are BIT-IDENTICAL to the unguarded decode (an all-false
    ``where`` preserves its operand), so the guard can sit unconditionally
    inside the compiled serving step. When any query is flagged, the cond's
    fallback branch scores the full embedding once (V·d — the price of
    correctness on a degenerate step) and splices exact log Z / candidates
    into the flagged rows only; unflagged rows keep their estimator outputs
    untouched. ``active`` masks rows out of the check entirely (a padded
    scheduler lane carries garbage by design and must not trigger — or pay
    for — the fallback).
    """
    flags = health_flags(out)
    if active is not None:
        flags = jnp.where(active, flags, 0)
    bad = flags > 0

    def fallback():
        ex = exact_topk_decode(w, h, k=k, use_pallas=False)
        row = bad[:, None]
        return DecodeOut(
            log_z=jnp.where(bad, ex.log_z, out.log_z),
            top_score=jnp.where(row, ex.top_score, out.top_score),
            top_id=jnp.where(row, ex.top_id, out.top_id),
            head_lse=jnp.where(bad, ex.head_lse, out.head_lse),
            tail_lse=jnp.where(bad, ex.tail_lse, out.tail_lse),
            k_eff=out.k_eff, head_live=out.head_live)

    def keep():
        return out

    return jax.lax.cond(jnp.any(bad), fallback, keep), flags


# ---------------------------------------------------------------------------
# Dense-output decodes (exact / selfnorm) behind the same DecodeOut contract
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("k", "use_pallas", "block_q", "block_v",
                                   "interpret"))
def exact_topk_decode(w: jax.Array, h: jax.Array, *, k: int = 1,
                      use_pallas: bool = False, block_q: int = 128,
                      block_v: int = 512,
                      active: Optional[jax.Array] = None,
                      interpret=None) -> DecodeOut:
    """Exact log Z + top-k in one pass (Pallas ``topk_z`` or streaming XLA).
    ``active`` is accepted for backend-signature uniformity and ignored —
    the dense pass scores every row regardless of slot occupancy."""
    del active
    if use_pallas:
        from ..kernels.topk_z import topk_z
        lse, topv, topi = topk_z(h, w, k, block_q=block_q, block_v=block_v,
                                 interpret=interpret)
    else:
        logits = (h @ w.T).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, -1)
        topv, topi = jax.lax.top_k(logits, k)
    q, v = h.shape[0], w.shape[0]
    return DecodeOut(log_z=lse, top_score=topv,
                     top_id=topi.astype(jnp.int32), head_lse=lse,
                     tail_lse=jnp.full((q,), -jnp.inf),
                     k_eff=jnp.full((q,), v, jnp.int32))


@partial(jax.jit, static_argnames=("k", "use_pallas", "interpret"))
def selfnorm_decode(w: jax.Array, h: jax.Array, *, k: int = 1,
                    use_pallas: bool = False,
                    active: Optional[jax.Array] = None,
                    interpret=None) -> DecodeOut:
    """Self-normalized head: candidates as exact, but Z assumed == 1
    (log Ẑ == 0; the model was trained with the selfnorm penalty)."""
    del active
    out = exact_topk_decode(w, h, k=k, use_pallas=use_pallas,
                            interpret=interpret)
    return out._replace(log_z=jnp.zeros_like(out.log_z))
