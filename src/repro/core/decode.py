"""Fused batched decode paths: one pipeline from coarse probe to log-Ẑ.

This is the serving-side realization of Eq. 5 (DESIGN.md SS4), plus the
batched MINCE (Eq. 6/7) and FMBE (Eq. 9/10) decodes that share its probe
plan — every sublinear estimator consumes the same ``DecodePlan``; none of
them touches ``oracle_retrieve`` (the O(N log N) sort is an accuracy-study
tool, not a serving path). Per decode step for a query batch h (Q, d):

    probe_batch ──► (Q, p) block ids          one (Q,d)x(d,nb) matmul
         │
    plan_heads  ──► union table (U,) + membership mask (Q, U)
    plan_tail   ──► l shared tail samples + rejection mask (Q, l)
         │
    ivf_decode  ──► head_lse, tail_lse, top-k     one Pallas kernel:
         │          (block_q,d) tiles x scalar-prefetched blocks,
         │          online LSE + running top-k, no (Q,p,br) HBM tensor
         ▼
    combine_head_tail_lse ──► log Ẑ          Eq. 5 with n_tail = N - k_eff

Tail samples are drawn **once per step and shared across the batch** (each
query still gets an unbiased tail: the slots are uniform and independent of
q), which turns the tail gather into l row fetches + one (Q,d)x(d,l) matmul
instead of Q*l scattered gathers. Rejection happens per query at block
granularity; the Eq. 5 scale uses n_tail_total = N - k_eff with the
*post-rejection* sample count — the Rao–Blackwellized form of the seed
engine's N/l scale (both are unbiased; conditioning on the survivor count
removes the rejection-noise component of the variance, at the cost of
dropping the tail on the measure-zero-ish event that no sample survives).

``mimps_decode(..., use_pallas=False)`` runs the same plan through an XLA
gather path — the interpret/CPU reference the parity tests pin the kernel to.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..kernels.ivf_score import ivf_decode, union_scores
from . import mince as _mince
from . import mips as _mips
from .estimators import NEG_INF, combine_head_tail_lse
from .feature_maps import FMBEState, fmbe_z_batch


class DecodePlan(NamedTuple):
    block_ids: jax.Array    # (Q, p)  per-query probed blocks
    head_ids: jax.Array     # (U,)    deduplicated union (pad = repeat last)
    head_live: jax.Array    # ()      number of real (non-pad) union slots
    head_member: jax.Array  # (Q, U)  bool membership mask
    tail_blocks: jax.Array  # (l,)    block of each shared tail sample
    tail_rows: jax.Array    # (l,)    row-in-block of each shared tail sample
    tail_accept: jax.Array  # (Q, l)  bool rejection mask
    k_eff: jax.Array        # (Q,)    real rows covered by probed blocks
    n_accept: jax.Array     # (Q,)    post-rejection tail sample count


class DecodeOut(NamedTuple):
    log_z: jax.Array        # (Q,)
    top_score: jax.Array    # (Q, k)
    top_id: jax.Array       # (Q, k) original row ids
    head_lse: jax.Array     # (Q,)
    tail_lse: jax.Array     # (Q,)  -inf where no tail sample survived
    k_eff: jax.Array        # (Q,)


def plan_heads(block_ids: jax.Array, capacity: int):
    """Deduplicate a (Q, p) probe table into (head_ids (capacity,),
    member (Q, capacity)).

    The union is sorted and compacted to the front; pad slots repeat the last
    unique id (consecutive identical BlockSpec indices cost no extra DMA) and
    are masked out of every query's membership row, so duplicates are never
    double-counted. ``capacity`` must be >= the unique count; capacity =
    min(Q*p, n_blocks) always is.
    """
    q, p = block_ids.shape
    flat = jnp.sort(block_ids.reshape(-1))
    is_new = jnp.concatenate(
        [jnp.ones((1,), bool), flat[1:] != flat[:-1]])
    tgt = jnp.cumsum(is_new) - 1                       # slot for each element
    n_unique = tgt[-1] + 1
    head_ids = jnp.full((capacity,), flat[-1], jnp.int32)
    head_ids = head_ids.at[tgt].set(flat.astype(jnp.int32))
    slot_live = jnp.arange(capacity) < n_unique
    member = jnp.any(head_ids[None, :, None] == block_ids[:, None, :],
                     axis=-1) & slot_live[None, :]
    return head_ids, member, n_unique


def plan_tail(index: _mips.IVFIndex, key: jax.Array, l: int,
              block_ids: jax.Array):
    """l uniform tail samples over *original* rows, shared across the batch.

    Returns (tail_blocks (l,), tail_rows (l,), accept (Q, l)); sample j is
    rejected for query q iff its block is in q's probed set (those rows are
    already counted exactly in the head). l == 0 yields empty (but
    well-shaped) tail arrays — the head-only plan FMBE consumes.
    """
    idx = jax.random.randint(key, (l,), 0, index.n)
    slots = index.slot_of_row[idx]
    tb = (slots // index.block_rows).astype(jnp.int32)
    tr = (slots % index.block_rows).astype(jnp.int32)
    accept = ~jnp.any(tb[None, None, :] == block_ids[:, :, None], axis=1)
    return tb, tr, accept


def make_plan(index: _mips.IVFIndex, h: jax.Array, key: jax.Array,
              n_probe: int, l: int) -> DecodePlan:
    """Probe + dedup + tail-sample: everything the fused kernel consumes."""
    block_ids = _mips.probe_batch(index, h, n_probe)
    capacity = min(h.shape[0] * n_probe, index.n_blocks)
    head_ids, member, n_unique = plan_heads(block_ids, capacity)
    tb, tr, accept = plan_tail(index, key, l, block_ids)
    k_eff = _mips.head_count(index, block_ids)
    return DecodePlan(block_ids=block_ids, head_ids=head_ids,
                      head_live=n_unique.astype(jnp.int32),
                      head_member=member, tail_blocks=tb, tail_rows=tr,
                      tail_accept=accept, k_eff=k_eff,
                      n_accept=accept.sum(axis=-1))


def _decode_ref(index: _mips.IVFIndex, h: jax.Array, plan: DecodePlan,
                k: int):
    """XLA reference for the fused kernel: same plan, gather-based compute.

    Materializes the (Q, U, br) score tensor the Pallas path exists to avoid;
    numerics must match ivf_decode to float32 round-off.
    """
    br = index.block_rows
    blocks = index.v_blocks[plan.head_ids]               # (U, br, d)
    scores = jnp.einsum("ubd,qd->qub", blocks, h,
                        preferred_element_type=jnp.float32)
    logw = jnp.where(index.valid, 0.0, NEG_INF)[plan.head_ids]   # (U, br)
    eff = scores + logw[None]
    eff = jnp.where(plan.head_member[:, :, None], eff, NEG_INF)
    q = h.shape[0]
    flat = eff.reshape(q, -1)
    head_lse = jax.nn.logsumexp(flat, axis=-1)
    topv, pos = jax.lax.top_k(flat, k)
    topi = plan.head_ids[pos // br] * br + pos % br       # global slot ids
    rows = index.v_blocks[plan.tail_blocks, plan.tail_rows]      # (l, d)
    ts = jnp.einsum("qd,ld->ql", h, rows,
                    preferred_element_type=jnp.float32)   # (Q, l)
    tail_lse = jax.nn.logsumexp(
        jnp.where(plan.tail_accept, ts, NEG_INF), axis=-1)
    # match the kernel's contract: queries with zero surviving samples get a
    # genuine -inf, not NEG_INF + log(l)
    tail_lse = jnp.where(jnp.any(plan.tail_accept, axis=-1), tail_lse,
                         -jnp.inf)
    return head_lse, tail_lse, topv, topi.astype(jnp.int32)


@partial(jax.jit, static_argnames=("n_probe", "l", "k", "use_pallas",
                                   "block_q", "interpret"))
def mimps_decode(index: _mips.IVFIndex, h: jax.Array, key: jax.Array,
                 *, n_probe: int, l: int, k: int = 1,
                 use_pallas: bool = True, block_q: int = 128,
                 interpret=None) -> DecodeOut:
    """Batched sublinear decode: h (Q, d) -> log Ẑ, top-k rows, per Eq. 5.

    Embedding bytes touched per step:
      n_blocks*d (centroids) + U*br*d (deduplicated head) + l*d (tail rows)
    vs V*d for the exact path. U <= min(Q*n_probe, n_blocks), and decode
    batches serving overlapping contexts dedup toward U ~ n_probe.
    """
    plan = make_plan(index, h, key, n_probe, l)
    if use_pallas:
        row_logw = jnp.where(index.valid, 0.0, NEG_INF).astype(jnp.float32)
        head_lse, tail_lse, topv, topi = ivf_decode(
            index.v_blocks, h, plan.head_ids, plan.head_live,
            plan.head_member, row_logw,
            plan.tail_blocks, plan.tail_rows, plan.tail_accept,
            k=k, block_q=block_q, interpret=interpret)
    else:
        head_lse, tail_lse, topv, topi = _decode_ref(index, h, plan, k)
    n = index.n
    log_z = combine_head_tail_lse(
        head_lse, tail_lse,
        (n - plan.k_eff).astype(jnp.float32),
        plan.n_accept.astype(jnp.float32))
    top_id = index.row_id.reshape(-1)[topi]
    return DecodeOut(log_z=log_z, top_score=topv, top_id=top_id,
                     head_lse=head_lse, tail_lse=tail_lse, k_eff=plan.k_eff)


# ---------------------------------------------------------------------------
# Shared head machinery for the MINCE / FMBE batched backends
# ---------------------------------------------------------------------------

def union_head_scores(index: _mips.IVFIndex, h: jax.Array, plan: DecodePlan,
                      use_pallas: bool, interpret=None):
    """Score the deduplicated probe union for every query.

    Returns (scores (Q, U_cap, br) f32, mask (Q, U_cap, br) bool). Unlike
    the fused MIMPS kernel this *does* materialize per-row scores — MINCE's
    Halley iteration revisits every sample 'iters' times, so the alpha set
    is inherent, not an implementation artifact.

    Traffic: the Pallas path (``kernels.ivf_score.union_scores``) fetches
    each of the U *unique* blocks once per query tile (pad slots elide both
    DMA and compute), i.e. U·br·d embedding floats — the figure the SS5/SS8
    accounting reports. The XLA reference gathers all U_cap =
    min(Q·n_probe, nb) static slots (capacity·br·d, the ``floats_bound``
    ceiling); it is the parity oracle, not the deployment path.
    """
    if use_pallas:
        scores = union_scores(index.v_blocks, h, plan.head_ids,
                              plan.head_live, interpret=interpret)
    else:
        blocks = index.v_blocks[plan.head_ids]              # (U_cap, br, d)
        scores = jnp.einsum("ubd,qd->qub", blocks, h,
                            preferred_element_type=jnp.float32)
    mask = plan.head_member[:, :, None] & index.valid[plan.head_ids][None]
    return scores, mask


def _union_topk(index: _mips.IVFIndex, plan: DecodePlan, scores, mask,
                k: int):
    """Top-k (score, vocab id) over the masked union scores."""
    q = scores.shape[0]
    br = index.block_rows
    flat = jnp.where(mask, scores, NEG_INF).reshape(q, -1)
    topv, pos = jax.lax.top_k(flat, k)
    topi = plan.head_ids[pos // br] * br + pos % br          # global slot ids
    return topv, index.row_id.reshape(-1)[topi]


@partial(jax.jit, static_argnames=("n_probe", "l", "k", "iters", "solver",
                                   "use_pallas", "interpret"))
def mince_decode(index: _mips.IVFIndex, h: jax.Array, key: jax.Array,
                 *, n_probe: int, l: int, k: int = 1, iters: int = 25,
                 solver: str = "halley", use_pallas: bool = True,
                 interpret=None) -> DecodeOut:
    """Batched sublinear MINCE (Eq. 6/7): S_k(q) is the IVF probe head, the
    noise set is the plan's shared uniform tail — no oracle sort anywhere.

    alpha_i = s_i + log(k_eff (N - k_eff) / n_accept) over probed head rows,
    beta_j likewise over surviving tail samples; one batched trust-clamped
    Halley sweep solves every query's theta = log Ẑ simultaneously.

    Degenerate heads are guarded per query: k_eff == 0 falls back to the
    uniform-noise-only objective (importance sampling over the tail), and an
    empty complement (k_eff == N or zero surviving samples) falls back to
    the exactly-scored head.
    """
    assert l >= 1, "MINCE needs at least one noise sample"
    plan = make_plan(index, h, key, n_probe, l)
    scores, mask = union_head_scores(index, h, plan, use_pallas, interpret)
    q = h.shape[0]
    head = scores.reshape(q, -1)
    head_mask = mask.reshape(q, -1)
    flat = index.v_blocks.reshape(-1, index.v_blocks.shape[-1])
    slots = plan.tail_blocks * index.block_rows + plan.tail_rows
    tail = jnp.einsum("qd,ld->ql", h, flat[slots],
                      preferred_element_type=jnp.float32)    # (Q, l)
    tail_mask = plan.tail_accept

    n = index.n
    k_eff = plan.k_eff.astype(jnp.float32)
    n_acc = plan.n_accept.astype(jnp.float32)
    n_tail = jnp.maximum(n - k_eff, 0.0)
    log_ratio = (jnp.log(jnp.maximum(k_eff, 1.0)) +
                 jnp.log(jnp.maximum(n_tail, 1.0)) -
                 jnp.log(jnp.maximum(n_acc, 1.0)))           # (Q,)
    head_lse = jax.nn.logsumexp(
        jnp.where(head_mask, head, NEG_INF), axis=-1)
    tail_lse = jax.nn.logsumexp(
        jnp.where(tail_mask, tail, NEG_INF), axis=-1)
    tail_lse = jnp.where(jnp.any(tail_mask, axis=-1), tail_lse, -jnp.inf)

    theta = _mince.solve_log_z(
        head + log_ratio[:, None], tail + log_ratio[:, None], head_lse,
        iters=iters, solver=solver,
        alpha_mask=head_mask.astype(jnp.float32),
        beta_mask=tail_mask.astype(jnp.float32))
    # per-query degenerate guards (cannot happen at sane configs, must not NaN)
    uniform = combine_head_tail_lse(
        jnp.full_like(head_lse, NEG_INF), tail_lse,
        jnp.zeros_like(n_acc) + jnp.asarray(n, jnp.float32), n_acc)
    log_z = jnp.where(k_eff == 0, uniform, theta)
    log_z = jnp.where((n_acc == 0) | (n_tail == 0), head_lse, log_z)

    topv, top_id = _union_topk(index, plan, scores, mask, k)
    return DecodeOut(log_z=log_z, top_score=topv, top_id=top_id,
                     head_lse=head_lse, tail_lse=tail_lse, k_eff=plan.k_eff)


@partial(jax.jit, static_argnames=("n_probe", "k", "use_pallas", "interpret"))
def fmbe_decode(state: FMBEState, index: _mips.IVFIndex, h: jax.Array,
                key: jax.Array, *, n_probe: int, k: int = 1,
                use_pallas: bool = True, interpret=None) -> DecodeOut:
    """Batched FMBE decode: log Ẑ from the random-feature sketch (O(P M d)
    per query, independent of V), argmax/sampling candidates from the IVF
    probe head via an l=0 head-only plan. The estimate is deterministic
    given the feature map; ``key`` only feeds the empty tail plan.
    """
    plan = make_plan(index, h, key, n_probe, l=0)   # head-only plan
    scores, mask = union_head_scores(index, h, plan, use_pallas, interpret)
    head_lse = jax.nn.logsumexp(
        jnp.where(mask, scores, NEG_INF).reshape(h.shape[0], -1), axis=-1)
    z = fmbe_z_batch(state, h, use_pallas=use_pallas, interpret=interpret)
    log_z = jnp.log(jnp.maximum(z, 1e-30))
    topv, top_id = _union_topk(index, plan, scores, mask, k)
    return DecodeOut(log_z=log_z, top_score=topv, top_id=top_id,
                     head_lse=head_lse,
                     tail_lse=jnp.full_like(log_z, -jnp.inf),
                     k_eff=plan.k_eff)


# ---------------------------------------------------------------------------
# Dense-output decodes (exact / selfnorm) behind the same DecodeOut contract
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("k", "use_pallas", "interpret"))
def exact_topk_decode(w: jax.Array, h: jax.Array, *, k: int = 1,
                      use_pallas: bool = False, interpret=None) -> DecodeOut:
    """Exact log Z + top-k in one pass (Pallas ``topk_z`` or streaming XLA)."""
    if use_pallas:
        from ..kernels.topk_z import topk_z
        lse, topv, topi = topk_z(h, w, k, interpret=interpret)
    else:
        logits = (h @ w.T).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, -1)
        topv, topi = jax.lax.top_k(logits, k)
    q, v = h.shape[0], w.shape[0]
    return DecodeOut(log_z=lse, top_score=topv,
                     top_id=topi.astype(jnp.int32), head_lse=lse,
                     tail_lse=jnp.full((q,), -jnp.inf),
                     k_eff=jnp.full((q,), v, jnp.int32))


@partial(jax.jit, static_argnames=("k", "use_pallas", "interpret"))
def selfnorm_decode(w: jax.Array, h: jax.Array, *, k: int = 1,
                    use_pallas: bool = False, interpret=None) -> DecodeOut:
    """Self-normalized head: candidates as exact, but Z assumed == 1
    (log Ẑ == 0; the model was trained with the selfnorm penalty)."""
    out = exact_topk_decode(w, h, k=k, use_pallas=use_pallas,
                            interpret=interpret)
    return out._replace(log_z=jnp.zeros_like(out.log_z))
