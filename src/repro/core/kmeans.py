"""Jittable Lloyd's k-means — substrate for the TPU-native IVF MIPS index.

Euclidean k-means over the (unnormalized) class-vector matrix, exactly the
coarse quantizer geometry ScaNN-style retrieval uses. Empty clusters retain
their previous centroid.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp


def _assign(x: jax.Array, c: jax.Array) -> jax.Array:
    """Nearest-centroid assignment by squared Euclidean distance."""
    # ||x - c||^2 = ||x||^2 - 2 x.c + ||c||^2 ; ||x||^2 constant per row.
    d2 = -2.0 * (x @ c.T) + jnp.sum(c * c, axis=-1)[None, :]
    return jnp.argmin(d2, axis=-1).astype(jnp.int32)


@partial(jax.jit, static_argnames=("n_clusters", "iters"))
def kmeans(key: jax.Array, x: jax.Array, n_clusters: int,
           iters: int = 15) -> Tuple[jax.Array, jax.Array]:
    """Returns (centroids (C, d), assignments (N,))."""
    n = x.shape[0]
    init_idx = jax.random.choice(key, n, (n_clusters,), replace=False)
    c0 = x[init_idx].astype(jnp.float32)

    def step(c, _):
        assign = _assign(x, c)
        sums = jax.ops.segment_sum(x.astype(jnp.float32), assign,
                                   num_segments=n_clusters)
        counts = jax.ops.segment_sum(jnp.ones((n,), jnp.float32), assign,
                                     num_segments=n_clusters)
        c_new = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts, 1.0)[:, None], c)
        return c_new, None

    c, _ = jax.lax.scan(step, c0, None, length=iters)
    return c, _assign(x, c)
