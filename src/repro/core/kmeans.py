"""Jittable Lloyd's k-means — substrate for the TPU-native IVF MIPS index.

Euclidean k-means over the (unnormalized) class-vector matrix, exactly the
coarse quantizer geometry ScaNN-style retrieval uses. The single Lloyd
iteration is exposed as ``kmeans_step`` so the index-refresh path
(``mips.refresh_ivf``) can reuse the exact same jitted update under
embedding drift, and ``centroids_from_assign`` recovers cluster centroids
from a stored assignment (the refresh warm start).

Empty clusters are reseeded to the farthest-assigned points (the standard
k-means repair move): under drift a centroid can lose every member, and
silently retaining the stale centroid would leave a dead probe target that
never wins a coarse-probe again while its old rows crowd other blocks.
Reseeding keeps every cluster live with static shapes (top-k of the
per-point distance to its own centroid).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp


def _assign(x: jax.Array, c: jax.Array) -> jax.Array:
    """Nearest-centroid assignment by squared Euclidean distance."""
    # ||x - c||^2 = ||x||^2 - 2 x.c + ||c||^2 ; ||x||^2 constant per row.
    d2 = -2.0 * (x @ c.T) + jnp.sum(c * c, axis=-1)[None, :]
    return jnp.argmin(d2, axis=-1).astype(jnp.int32)


def centroids_from_assign(x: jax.Array, assign: jax.Array,
                          n_clusters: int) -> Tuple[jax.Array, jax.Array]:
    """(centroids (C, d) f32, counts (C,) f32) of an existing assignment.
    Empty clusters get a zero centroid — callers that iterate go through
    ``kmeans_step`` which repairs them."""
    sums = jax.ops.segment_sum(x.astype(jnp.float32), assign,
                               num_segments=n_clusters)
    counts = jax.ops.segment_sum(jnp.ones(x.shape[:1], jnp.float32), assign,
                                 num_segments=n_clusters)
    return sums / jnp.maximum(counts, 1.0)[:, None], counts


def kmeans_step(x: jax.Array, c: jax.Array) -> jax.Array:
    """One Lloyd iteration with empty-cluster repair; c (C, d) -> (C, d).

    Clusters that end the assignment empty are reseeded to the points
    farthest from their currently-assigned centroid (distinct point per
    empty cluster, taken from the global farthest-point ranking), instead of
    silently retaining the stale centroid. A reseeded centroid sits exactly
    on a data point, so the next assignment is guaranteed to repopulate it.
    """
    n_clusters = c.shape[0]
    assign = _assign(x, c)
    mean_c, counts = centroids_from_assign(x, assign, n_clusters)
    # distance of every point to its own centroid — the repair candidates
    xf = x.astype(jnp.float32)
    d2 = jnp.sum(jnp.square(xf - c.astype(jnp.float32)[assign]), axis=-1)
    _, far_idx = jax.lax.top_k(d2, n_clusters)        # C farthest points
    empty = counts == 0
    # empty cluster #j (in cluster order) takes the j-th farthest point
    rank = jnp.clip(jnp.cumsum(empty.astype(jnp.int32)) - 1, 0,
                    n_clusters - 1)
    reseed = xf[far_idx[rank]]
    return jnp.where(empty[:, None], reseed, mean_c)


@partial(jax.jit, static_argnames=("n_clusters", "iters"))
def kmeans(key: jax.Array, x: jax.Array, n_clusters: int,
           iters: int = 15) -> Tuple[jax.Array, jax.Array]:
    """Returns (centroids (C, d), assignments (N,))."""
    n = x.shape[0]
    init_idx = jax.random.choice(key, n, (n_clusters,), replace=False)
    c0 = x[init_idx].astype(jnp.float32)

    def step(c, _):
        return kmeans_step(x, c), None

    c, _ = jax.lax.scan(step, c0, None, length=iters)
    return c, _assign(x, c)
