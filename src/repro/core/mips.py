"""TPU-native MIPS: block-IVF index (the hardware adaptation of the paper's
LSH / k-d-tree retrieval — see DESIGN.md SS3).

Layout: class vectors are k-means clustered, permuted cluster-contiguously and
padded to a multiple of ``block_rows``. Per-block centroids form the coarse
quantizer. A query scores all block centroids (one dense matmul), takes the
top-``n_probe`` blocks, and scores only those blocks' rows — either via the
XLA gather fallback here or the scalar-prefetch Pallas kernel in
``repro.kernels.ivf_score``.

Retrieval cost per query: O(n_blocks * d + n_probe * block_rows * d)
vs brute force O(N * d) — sublinear once n_blocks << N.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .kmeans import _assign, centroids_from_assign, kmeans, kmeans_step


class IVFIndex(NamedTuple):
    v_blocks: jax.Array         # (n_blocks, block_rows, d) permuted+padded rows
    valid: jax.Array            # (n_blocks, block_rows) bool — pad rows False
    row_id: jax.Array           # (n_blocks, block_rows) original row id (-1 pad)
    slot_of_row: jax.Array      # (N,) padded slot index of each original row
    block_centroids: jax.Array  # (n_blocks, d)
    block_radius: jax.Array     # (n_blocks,) max ||v - centroid|| over block
    n: int                      # true N
    block_rows: int
    assign: Optional[jax.Array] = None  # (N,) k-means cluster of each row —
                                        # the refresh warm start (refresh_ivf)

    @property
    def n_blocks(self) -> int:
        return self.v_blocks.shape[0]


def build_ivf(key: jax.Array, v: jax.Array, block_rows: int = 512,
              n_clusters: int = 0, kmeans_iters: int = 20) -> IVFIndex:
    """Build the block-IVF index. Host-side, called once (index build time).

    Blocks are *cluster-pure*: each k-means cluster is padded up to a multiple
    of ``block_rows``, so a block's rows all share one cluster and the block
    centroid is meaningful. Large clusters span several blocks and therefore
    naturally receive proportionally many probe slots. Padding overhead is
    <= 0.5 block per cluster (~12% at the default cluster size of 4 blocks).
    """
    import numpy as np

    n, d = v.shape
    if n_clusters <= 0:
        n_clusters = max(1, n // (4 * block_rows))
    _, assign_j = kmeans(key, v, n_clusters=n_clusters, iters=kmeans_iters)
    assign = np.asarray(assign_j)
    v_np = np.asarray(v)

    # pack cluster-by-cluster, padding each to a block multiple
    sizes = np.bincount(assign, minlength=n_clusters)
    padded = np.maximum(block_rows,
                        ((sizes + block_rows - 1) // block_rows) * block_rows)
    offsets = np.concatenate([[0], np.cumsum(padded)])
    n_total = int(offsets[-1])
    row_id_flat = np.full((n_total,), -1, np.int32)
    order = np.argsort(assign, kind="stable")
    cluster_starts = np.concatenate([[0], np.cumsum(sizes)])
    for c in range(n_clusters):
        rows = order[cluster_starts[c]:cluster_starts[c + 1]]
        row_id_flat[offsets[c]:offsets[c] + len(rows)] = rows
    valid_flat = row_id_flat >= 0
    v_flat = np.zeros((n_total, d), v_np.dtype)
    v_flat[valid_flat] = v_np[row_id_flat[valid_flat]]
    slot_of_row = np.zeros((n,), np.int32)
    slot_of_row[row_id_flat[valid_flat]] = np.nonzero(valid_flat)[0]

    n_blocks = n_total // block_rows
    v_blocks = v_flat.reshape(n_blocks, block_rows, d)
    valid = valid_flat.reshape(n_blocks, block_rows)
    row_id = row_id_flat.reshape(n_blocks, block_rows)
    counts = np.maximum(valid.sum(axis=1, keepdims=True), 1)
    block_centroids = (v_blocks * valid[..., None]).sum(axis=1) / counts
    dist = np.linalg.norm(v_blocks - block_centroids[:, None, :], axis=-1)
    block_radius = np.max(np.where(valid, dist, 0.0), axis=1)
    return IVFIndex(v_blocks=jnp.asarray(v_blocks),
                    valid=jnp.asarray(valid),
                    row_id=jnp.asarray(row_id),
                    slot_of_row=jnp.asarray(slot_of_row),
                    block_centroids=jnp.asarray(block_centroids, v.dtype),
                    block_radius=jnp.asarray(block_radius, jnp.float32),
                    n=n, block_rows=block_rows, assign=assign_j)


# ---------------------------------------------------------------------------
# Device-resident index lifecycle (train-time: the index lives INSIDE the
# compiled train state and is refreshed as the embedding drifts)
# ---------------------------------------------------------------------------

def ivf_capacity_blocks(n: int, block_rows: int, n_clusters: int) -> int:
    """Static block capacity that fits ANY assignment of n rows into
    n_clusters cluster-pure padded blocks: each cluster wastes < 1 block of
    padding (empty clusters cost exactly one), so
    ceil(n / block_rows) + n_clusters blocks always suffice. Fixing the
    capacity to this bound is what makes repacking shape-static — refresh
    after refresh reuses ONE compiled executable."""
    return -(-n // block_rows) + n_clusters


@partial(jax.jit, static_argnames=("n_clusters", "block_rows"))
def pack_ivf(v: jax.Array, assign: jax.Array, n_clusters: int,
             block_rows: int) -> IVFIndex:
    """Jittable segment-sort packing: (v, assignment) -> block-IVF index.

    The device-side replacement for the host build's numpy packing loop.
    Rows are stably sorted by cluster, each cluster's segment is placed at a
    block-aligned offset (cumsum of per-cluster padded sizes), and the pad
    slots are masked — one argsort + two scatters, no host round-trip. The
    output always has ``ivf_capacity_blocks`` blocks regardless of the
    assignment, so every repack of the same (N, block_rows, n_clusters)
    triple has identical shapes. Blocks past the packed frontier (and the
    one block an empty cluster reserves) are all-pad; ``probe``/
    ``probe_batch`` rank dead blocks at -inf so they never spend a probe.
    """
    n, d = v.shape
    br = block_rows
    nb = ivf_capacity_blocks(n, br, n_clusters)
    n_total = nb * br
    ones = jnp.ones((n,), jnp.int32)
    sizes = jax.ops.segment_sum(ones, assign, num_segments=n_clusters)
    padded = jnp.maximum(br, -(-sizes // br) * br)
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(padded)[:-1]])
    cluster_start = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(sizes)[:-1]])
    order = jnp.argsort(assign, stable=True).astype(jnp.int32)
    sorted_assign = assign[order]
    rank = jnp.arange(n, dtype=jnp.int32) - cluster_start[sorted_assign]
    slots = offsets[sorted_assign] + rank                    # (n,) unique
    row_id_flat = jnp.full((n_total,), -1, jnp.int32).at[slots].set(order)
    v_flat = jnp.zeros((n_total, d), v.dtype).at[slots].set(v[order])
    slot_of_row = jnp.zeros((n,), jnp.int32).at[order].set(slots)

    v_blocks = v_flat.reshape(nb, br, d)
    valid = (row_id_flat >= 0).reshape(nb, br)
    row_id = row_id_flat.reshape(nb, br)
    counts = jnp.maximum(valid.sum(axis=1, keepdims=True), 1)
    centroids = (v_blocks.astype(jnp.float32) * valid[..., None]
                 ).sum(axis=1) / counts
    dist = jnp.linalg.norm(v_blocks.astype(jnp.float32) -
                           centroids[:, None, :], axis=-1)
    radius = jnp.max(jnp.where(valid, dist, 0.0), axis=1)
    return IVFIndex(v_blocks=v_blocks, valid=valid, row_id=row_id,
                    slot_of_row=slot_of_row,
                    block_centroids=centroids.astype(v.dtype),
                    block_radius=radius.astype(jnp.float32),
                    n=n, block_rows=br, assign=assign.astype(jnp.int32))


def build_ivf_device(key: jax.Array, v: jax.Array, block_rows: int = 512,
                     n_clusters: int = 0,
                     kmeans_iters: int = 20) -> IVFIndex:
    """Device-resident build: jitted k-means + ``pack_ivf``, no numpy.

    Same coarse-quantizer geometry as ``build_ivf`` (identical k-means, so
    identical cluster contents and packing order); the only difference is
    the static block capacity — ``ivf_capacity_blocks`` headroom instead of
    the host build's data-dependent total — which is what lets the index be
    rebuilt/refreshed inside a compiled training loop with zero recompiles,
    and hot-swapped into a serving engine whose executables were traced on
    the same shapes (``serve.engine.Engine.swap_index``).
    """
    n = v.shape[0]
    if n_clusters <= 0:
        n_clusters = max(1, n // (4 * block_rows))
    _, assign = kmeans(key, v, n_clusters=n_clusters, iters=kmeans_iters)
    return pack_ivf(v, assign, n_clusters, block_rows)


@partial(jax.jit, static_argnames=("n_clusters", "kmeans_iters"))
def refresh_ivf(index: IVFIndex, w: jax.Array, *, n_clusters: int,
                kmeans_iters: int = 1):
    """Incremental index maintenance under embedding drift: recompute the
    cluster geometry from the CURRENT ``w``, reassign drifted rows, repack.

    Warm-starts from the stored assignment (``index.assign``), runs
    ``kmeans_iters`` of the jitted Lloyd step (``kmeans.kmeans_step`` — the
    same update the build uses, including empty-cluster reseeding, which is
    what keeps clusters live as rows migrate), reassigns every row to its
    nearest refreshed centroid, and repacks with ``pack_ivf``. All shapes
    are functions of (N, block_rows, n_clusters) only, so refresh-every-K-
    steps reuses one executable — zero recompiles across refreshes.

    Returns ``(new_index, metrics)`` with the maintenance observables:
      churn  — fraction of rows whose cluster changed this refresh
      drift  — mean ||w_row - stored_row|| / mean ||w_row|| staleness of the
               index's embedded copies at call time (what the refresh fixed)
    """
    n, d = w.shape
    assign_old = index.assign
    c, _ = centroids_from_assign(w, assign_old, n_clusters)
    for _ in range(kmeans_iters):
        c = kmeans_step(w, c)
    assign_new = _assign(w, c)
    churn = jnp.mean((assign_new != assign_old).astype(jnp.float32))
    stale = index.v_blocks.reshape(-1, d)[index.slot_of_row]
    wf = w.astype(jnp.float32)
    drift = jnp.mean(jnp.linalg.norm(wf - stale.astype(jnp.float32), axis=-1)
                     ) / jnp.maximum(
        jnp.mean(jnp.linalg.norm(wf, axis=-1)), 1e-9)
    new_index = pack_ivf(w, assign_new, n_clusters, index.v_blocks.shape[1])
    return new_index, {"churn": churn, "drift": drift}


def probe(index: IVFIndex, q: jax.Array, n_probe: int,
          bound: bool = True) -> jax.Array:
    """Top-n_probe block ids. q: (d,) -> (p,).

    bound=True ranks blocks by the ball upper bound
      max_{v in block} v.q <= c.q + r ||q||           (Cauchy-Schwarz)
    which guarantees the block containing the true argmax is ranked above any
    block whose *bound* is below the argmax's score — much higher rank-1
    recall than mean-centroid ranking on norm-skewed (word2vec-like) data.
    """
    c_scores = (index.block_centroids @ q).astype(jnp.float32)
    if bound:
        c_scores = c_scores + index.block_radius * \
            jnp.linalg.norm(q.astype(jnp.float32))
    c_scores = jnp.where(index.valid.any(-1), c_scores, -jnp.inf)
    _, ids = jax.lax.top_k(c_scores, n_probe)
    return ids.astype(jnp.int32)


def probe_batch(index: IVFIndex, q: jax.Array, n_probe: int,
                bound: bool = True) -> jax.Array:
    """Batched coarse probe: q (Q, d) -> (Q, p) block ids.

    One dense (Q, d) x (d, n_blocks) matmul scores every query against every
    block centroid — the MXU-saturating replacement for vmap(probe), and the
    first stage of the fused decode pipeline (DESIGN.md SS4). Same ball-bound
    ranking as `probe`; `jax.vmap(probe)` and `probe_batch` agree exactly.
    """
    c_scores = (q @ index.block_centroids.T).astype(jnp.float32)  # (Q, nb)
    if bound:
        qn = jnp.linalg.norm(q.astype(jnp.float32), axis=-1, keepdims=True)
        c_scores = c_scores + index.block_radius[None, :] * qn
    c_scores = jnp.where(index.valid.any(-1)[None, :], c_scores, -jnp.inf)
    _, ids = jax.lax.top_k(c_scores, n_probe)
    return ids.astype(jnp.int32)


def gather_scores(index: IVFIndex, q: jax.Array,
                  block_ids: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Score rows of the probed blocks (XLA gather fallback).

    Returns (scores (p*block_rows,), valid (p*block_rows,)).
    The Pallas path (kernels.ivf_score) computes the same contraction with
    scalar-prefetched block indices and VMEM-resident tiles.
    """
    blocks = index.v_blocks[block_ids]          # (p, B, d)
    scores = jnp.einsum("pbd,d->pb", blocks, q)
    valid = index.valid[block_ids]
    return scores.reshape(-1), valid.reshape(-1)


def head_count(index: IVFIndex, block_ids: jax.Array) -> jax.Array:
    """Number of real (non-pad) rows covered by the probed blocks (k_eff).

    block_ids (p,) -> scalar, or batched (Q, p) -> (Q,). This is the
    per-query head size Eq. 5 subtracts from N for the tail scale.
    """
    return index.valid[block_ids].sum(axis=(-2, -1))


@partial(jax.jit, static_argnames=("k",))
def exact_top_k(v: jax.Array, q: jax.Array, k: int):
    """Oracle S_k(q): exact top-k by inner product. O(N d) — accuracy studies."""
    s = v @ q
    vals, ids = jax.lax.top_k(s, k)
    return vals, ids


def pad_ivf_blocks(index: IVFIndex, multiple: int) -> IVFIndex:
    """Pad the block axis with dead (all-pad) blocks so n_blocks % multiple
    == 0 — required before sharding the block dim over a model axis of that
    extent. Dead blocks are invisible to every consumer: ``probe`` ranks
    them -inf (valid.any() is False), scoring masks them, and the engine's
    position-weighted digest is unchanged (zero rows x zero valid). Row
    slots don't move, so ``slot_of_row`` and the packed rows stay bitwise
    identical — scores over real rows are unaffected.
    """
    nb, br, d = index.v_blocks.shape
    pad = (-nb) % multiple
    if pad == 0:
        return index
    return index._replace(
        v_blocks=jnp.concatenate(
            [index.v_blocks,
             jnp.zeros((pad, br, d), index.v_blocks.dtype)]),
        valid=jnp.concatenate(
            [index.valid, jnp.zeros((pad, br), bool)]),
        row_id=jnp.concatenate(
            [index.row_id, jnp.full((pad, br), -1, index.row_id.dtype)]),
        block_centroids=jnp.concatenate(
            [index.block_centroids,
             jnp.zeros((pad, d), index.block_centroids.dtype)]),
        block_radius=jnp.concatenate(
            [index.block_radius,
             jnp.zeros((pad,), index.block_radius.dtype)]))
