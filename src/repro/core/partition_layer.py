"""Output-layer integration: the paper's technique as a first-class feature.

``PartitionLayer`` owns the estimator choice plus any prebuilt retrieval state
(IVF index, FMBE feature map) derived from the output embedding matrix. The
serving engine calls ``log_z`` / ``top_candidates``; the training losses in
``repro.train.losses`` use the same configs for NCE/self-norm/sampled-softmax.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import PartitionConfig
from . import backends as _backends
from . import estimators as est
from . import mips
from .feature_maps import FMBEState


@dataclasses.dataclass
class PartitionLayer:
    cfg: PartitionConfig
    index: Optional[mips.IVFIndex] = None
    fmbe_state: Optional[FMBEState] = None

    @staticmethod
    def build(cfg: PartitionConfig, w_out: jax.Array,
              key: jax.Array) -> "PartitionLayer":
        """Build retrieval state from the output embedding (index-build time)
        via the method's registered backend (core.backends).

        w_out: (vocab, d_model) — rows are the class vectors v_i.
        """
        cfg.validate()
        index = None
        fmbe_state = None
        if cfg.method in _backends.BACKENDS:
            # only the state the *per-query* estimators consume: the serving
            # backends also index mince/fmbe for sampling candidates, but
            # estimate_log_z ignores it there and top_candidates must stay
            # exact for the accuracy studies.
            state = _backends.get_backend(cfg.method).build(
                cfg, w_out, key, with_index=(cfg.method == "mimps"))
            index, fmbe_state = state.index, state.fmbe
        return PartitionLayer(cfg=cfg, index=index, fmbe_state=fmbe_state)

    def log_z(self, w_out: jax.Array, h: jax.Array,
              key: jax.Array) -> jax.Array:
        """Batched log Z estimate. h: (B, d) -> (B,)."""
        cfg = self.cfg
        keys = jax.random.split(key, h.shape[0])
        fn = lambda q, k: est.estimate_log_z(
            cfg.method, w_out, q, k, k=cfg.k, l=cfg.l, index=self.index,
            n_probe=cfg.n_probe, fmbe_state=self.fmbe_state,
            mince_iters=cfg.mince_iters, mince_solver=cfg.mince_solver)
        return jax.vmap(fn)(h, keys)

    def top_candidates(self, w_out: jax.Array, h: jax.Array, k: int,
                       key: jax.Array):
        """(scores, ids) of the retrieved head, batched."""
        if self.index is not None:
            def one(q):
                blocks = mips.probe(self.index, q, self.cfg.n_probe)
                s, valid = mips.gather_scores(self.index, q, blocks)
                s = jnp.where(valid, s, est.NEG_INF)
                vals, pos = jax.lax.top_k(s, k)
                rid = self.index.row_id[
                    blocks[pos // self.index.block_rows],
                    pos % self.index.block_rows]
                return vals, rid
            return jax.vmap(one)(h)
        scores = h @ w_out.T
        return jax.lax.top_k(scores, k)

    def normalized_top_prob(self, w_out: jax.Array, h: jax.Array,
                            key: jax.Array):
        """The paper's Eq. 2/3: (argmax id, p(i_hat)) with estimated Z."""
        vals, ids = self.top_candidates(w_out, h, 1, key)
        log_z = self.log_z(w_out, h, key)
        return ids[:, 0], jnp.exp(vals[:, 0] - log_z)
