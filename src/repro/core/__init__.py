"""Core: the paper's sublinear partition estimators + TPU-native MIPS."""
from .backends import (BACKENDS, BackendState, EstimatorBackend, get_backend,
                       register_backend)
from .decode import (DecodeOut, DecodePlan, exact_topk_decode, fmbe_decode,
                     head_row_table, make_plan, mimps_decode, mince_decode,
                     plan_heads, plan_tail, selfnorm_decode, tail_row_ids,
                     union_head_scores)
from .estimators import (exact_log_z, mimps_log_z, uniform_log_z,
                         nmimps_log_z, mince_log_z, fmbe_log_z, fmbe_z,
                         mimps_ivf, estimate_log_z, relative_error,
                         head_tail_log_z, combine_head_tail_lse)
from .feature_maps import (FeatureMap, FMBEState, make_feature_map,
                           apply_feature_map, build_fmbe, build_fmbe_blocks,
                           fmbe_estimate_z, fmbe_tail_z, fmbe_z_batch)
from .kmeans import centroids_from_assign, kmeans, kmeans_step
from .mince import (MinceStats, anchored_atoms, derivative_sums,
                    halley_step, mince_stats, nce_objective,
                    solve_from_stats, solve_log_z, solve_shared_atoms,
                    solver_convergence_trace, stats_derivative_sums)
from .mips import (IVFIndex, build_ivf, build_ivf_device, ivf_capacity_blocks,
                   pack_ivf, probe, probe_batch, gather_scores, head_count,
                   exact_top_k, refresh_ivf)
from .partition_layer import PartitionLayer

__all__ = [
    "exact_log_z", "mimps_log_z", "uniform_log_z", "nmimps_log_z",
    "mince_log_z", "fmbe_log_z", "fmbe_z", "mimps_ivf", "estimate_log_z",
    "relative_error", "head_tail_log_z", "combine_head_tail_lse",
    "DecodeOut", "DecodePlan", "make_plan", "mimps_decode", "mince_decode",
    "fmbe_decode", "exact_topk_decode", "selfnorm_decode",
    "union_head_scores", "plan_heads", "plan_tail",
    "BACKENDS", "BackendState", "EstimatorBackend", "get_backend",
    "register_backend", "FeatureMap", "FMBEState",
    "make_feature_map", "apply_feature_map", "build_fmbe", "fmbe_estimate_z",
    "fmbe_z_batch", "build_fmbe_blocks", "fmbe_tail_z", "kmeans",
    "solve_log_z", "derivative_sums", "halley_step",
    "nce_objective", "solver_convergence_trace", "MinceStats",
    "anchored_atoms", "mince_stats", "solve_from_stats",
    "solve_shared_atoms", "stats_derivative_sums",
    "IVFIndex", "build_ivf", "build_ivf_device", "ivf_capacity_blocks",
    "pack_ivf", "refresh_ivf", "probe", "probe_batch", "gather_scores",
    "head_count", "exact_top_k", "PartitionLayer", "head_row_table",
    "tail_row_ids", "kmeans_step", "centroids_from_assign",
]
