"""Vocab-sharded partition estimation (DESIGN.md SS6).

The output embedding table V (N, d) is sharded over the ``model`` mesh axis
(rows). These helpers run *inside* shard_map/pjit: each shard computes its
local head/tail contributions and the combine is

  * log Z        : pmax/psum log-domain reduction            (O(1) comms)
  * global top-k : all_gather of k local candidates           (O(k T) comms)

i.e. communication is sublinear in N — the paper's property lifted to the
collective level.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-portable ``shard_map``.

    jax >= 0.5 exposes ``jax.shard_map`` (replication checking flag named
    ``check_vma``); 0.4.x only has ``jax.experimental.shard_map.shard_map``
    with the flag named ``check_rep``. All in-repo callers go through here.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def logspace_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """psum of exp(x) carried in log domain, -inf-safe.

    The one cross-shard combine every sharded estimator body uses for
    partial log-Z terms (head LSEs, tail LSEs, anchored sums)."""
    m = lax.pmax(x, axis_name)
    safe = jnp.where(jnp.isfinite(m), m, 0.0)
    s = lax.psum(jnp.exp(x - safe), axis_name)
    return jnp.where(jnp.isfinite(m), safe + jnp.log(s), m)


def _dist_lse(local_lse: jax.Array, axis_name: str) -> jax.Array:
    """logsumexp across shards from per-shard logsumexps."""
    return logspace_psum(local_lse, axis_name)


def sharded_exact_log_z(v_local: jax.Array, q: jax.Array,
                        axis_name: str = "model") -> jax.Array:
    """Exact log Z with V row-sharded. q replicated: (d,) or (B, d)."""
    scores = q @ v_local.T if q.ndim == 2 else v_local @ q
    local = jax.nn.logsumexp(scores, axis=-1)
    return _dist_lse(local, axis_name)


class ShardedTopK(NamedTuple):
    scores: jax.Array   # (..., k) global top-k scores (descending)
    ids: jax.Array      # (..., k) global row ids


def sharded_top_k(v_local: jax.Array, q: jax.Array, k: int,
                  axis_name: str = "model") -> ShardedTopK:
    """Global top-k via local top-k + O(kT) all_gather merge."""
    n_local = v_local.shape[0]
    shard = lax.axis_index(axis_name)
    scores = q @ v_local.T if q.ndim == 2 else v_local @ q
    lv, li = lax.top_k(scores, min(k, n_local))
    gi = li + shard * n_local
    av = lax.all_gather(lv, axis_name, axis=-1, tiled=True)
    ai = lax.all_gather(gi, axis_name, axis=-1, tiled=True)
    mv, mi = lax.top_k(av, k)
    return ShardedTopK(scores=mv, ids=jnp.take_along_axis(ai, mi, axis=-1))


def sharded_mimps_log_z(v_local: jax.Array, q: jax.Array,
                        k_local: int, l_local: int, key: jax.Array,
                        axis_name: str = "model"
                        ) -> Tuple[jax.Array, ShardedTopK]:
    """MIMPS with V row-sharded (k_local/l_local are *per-shard*, static).

    Per-shard head of k_local rows + per-shard tail of l_local uniform
    samples; combined in log domain. The shard-wise head union always covers
    at least the global top-k_local, so this dominates single-host MIMPS with
    (k_local*T, l_local*T) in head coverage. Returns (log_z, merged top-k
    candidates) — the candidate merge is what serving needs for p(i_hat).
    """
    shard = lax.axis_index(axis_name)
    n_local = v_local.shape[0]
    key = jax.random.fold_in(key, shard)
    scores = v_local @ q                              # (n_local,)
    hv, hi = lax.top_k(scores, k_local)
    # local tail: uniform over local rows, reject head members by rank trick:
    # sample positions in the local sorted order beyond k_local.
    order = jnp.argsort(-scores)
    pos = k_local + jax.random.randint(key, (l_local,), 0, n_local - k_local)
    tail = scores[order[pos]]
    log_head = jax.nn.logsumexp(hv)
    log_tail = (jnp.log(jnp.float32(n_local - k_local)) -
                jnp.log(jnp.float32(l_local)) + jax.nn.logsumexp(tail))
    local_lse = jnp.logaddexp(log_head, log_tail)
    log_z = _dist_lse(local_lse, axis_name)
    gi = hi + shard * n_local
    av = lax.all_gather(hv, axis_name, axis=0, tiled=True)
    ai = lax.all_gather(gi, axis_name, axis=0, tiled=True)
    mv, mi = lax.top_k(av, k_local)
    return log_z, ShardedTopK(scores=mv, ids=ai[mi])
