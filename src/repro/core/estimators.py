"""The paper's partition-function estimators (SS4), pure JAX.

All estimators operate on a single query ``q: (d,)`` and are vmap-friendly;
log-domain throughout for stability (errors are reported as
``|1 - exp(logZ_hat - logZ)|`` which is exact for relative error).

Oracle variants score all N rows (O(Nd)) — they exist to reproduce the paper's
SS5.1 controlled-accuracy experiments, where retrieval is assumed perfect and
errors are injected deterministically. Sublinear variants go through the
block-IVF index (mips.py / kernels.ivf_score).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import mince as _mince
from . import mips as _mips
from .feature_maps import FMBEState, fmbe_estimate_z

NEG_INF = -1e30


def _lse(x: jax.Array, mask: Optional[jax.Array] = None) -> jax.Array:
    if mask is not None:
        x = jnp.where(mask, x, NEG_INF)
    return jax.nn.logsumexp(x, axis=-1)


# ---------------------------------------------------------------------------
# Exact (brute force) baseline
# ---------------------------------------------------------------------------

def exact_log_z(v: jax.Array, q: jax.Array) -> jax.Array:
    """log Z = logsumexp_i (v_i . q). O(N d)."""
    return _lse(v @ q)


# ---------------------------------------------------------------------------
# Head/tail core (Eq. 5) in log domain
# ---------------------------------------------------------------------------

def combine_head_tail_lse(log_head: jax.Array, log_tail: jax.Array,
                          n_tail_total: jax.Array,
                          n_tail_samples: jax.Array) -> jax.Array:
    """Eq. 5 combine from precomputed logsumexps (the fused-kernel interface):

        log( exp(log_head) + (n_tail_total / n_tail_samples) * exp(log_tail) )

    Guards the degenerate cases (empty tail population or zero surviving
    samples) by dropping the tail term. log_tail == -inf (all samples masked)
    is mapped through the same guard so no NaNs leak out of -inf + finite.
    """
    log_scale = jnp.log(jnp.maximum(n_tail_total, 1e-9)) - \
        jnp.log(jnp.maximum(n_tail_samples, 1e-9))
    ok = (n_tail_total > 0) & (n_tail_samples > 0)
    log_tail = jnp.where(ok, jnp.maximum(log_tail, NEG_INF) + log_scale,
                         NEG_INF)
    return jnp.logaddexp(log_head, log_tail)


def head_tail_log_z(head_scores: jax.Array,
                    tail_scores: jax.Array,
                    n_tail_total: jax.Array,
                    n_tail_samples: jax.Array,
                    head_mask: Optional[jax.Array] = None,
                    tail_mask: Optional[jax.Array] = None) -> jax.Array:
    """log( sum_head exp + (n_tail_total / n_tail_samples) * sum_tail exp )."""
    log_head = _lse(head_scores, head_mask) if head_scores.shape[-1] else NEG_INF
    log_tail = _lse(tail_scores, tail_mask) if tail_scores.shape[-1] else NEG_INF
    return combine_head_tail_lse(log_head, log_tail, n_tail_total,
                                 n_tail_samples)


# ---------------------------------------------------------------------------
# Oracle retrieval (paper SS5.1): full sort, deterministic error injection
# ---------------------------------------------------------------------------

class OracleRetrieval(NamedTuple):
    scores_sorted: jax.Array   # (N,) descending
    order: jax.Array           # (N,) ids


def oracle_retrieve(v: jax.Array, q: jax.Array) -> OracleRetrieval:
    s = v @ q
    order = jnp.argsort(-s)
    return OracleRetrieval(scores_sorted=s[order], order=order)


def _complement_sample(key: jax.Array, ret: OracleRetrieval, k: int, l: int):
    """l uniform samples from ranks [k, N) — exact complement sampling.

    k == N is guarded (randint over an empty range is undefined): positions
    clamp to the last rank and callers must drop the tail term — which Eq. 5
    does automatically via n_tail_total == 0 in ``combine_head_tail_lse``.
    """
    n = ret.scores_sorted.shape[0]
    pos = k + jax.random.randint(key, (l,), 0, max(n - k, 1))
    return ret.scores_sorted[jnp.minimum(pos, n - 1)]


@partial(jax.jit, static_argnames=("k", "l"))
def mimps_log_z(v: jax.Array, q: jax.Array, k: int, l: int,
                key: jax.Array,
                drop_ranks: Optional[Tuple[int, ...]] = None) -> jax.Array:
    """MIMPS (Eq. 5) with oracle retrieval.

    drop_ranks: simulate retrieval errors (Table 3) — the listed head ranks
    (0-based) are removed from S_k, as if the ANN failed to return them.
    """
    ret = oracle_retrieve(v, q)
    if k > 0:
        head = ret.scores_sorted[:k]
        head_mask = jnp.ones((k,), bool)
        if drop_ranks:
            for r in drop_ranks:
                head_mask = head_mask.at[r].set(False)
    else:
        head = jnp.zeros((0,))
        head_mask = None
    if l > 0:
        tail = _complement_sample(key, ret, k, l)
    else:
        tail = jnp.zeros((0,))
    n = v.shape[0]
    return head_tail_log_z(head, tail, jnp.float32(n - k), jnp.float32(l),
                           head_mask=head_mask)


@partial(jax.jit, static_argnames=("l",))
def uniform_log_z(v: jax.Array, q: jax.Array, l: int, key: jax.Array):
    """Uniform importance sampling (k=0 special case of MIMPS)."""
    n = v.shape[0]
    idx = jax.random.randint(key, (l,), 0, n)
    tail = v[idx] @ q
    return head_tail_log_z(jnp.zeros((0,)), tail, jnp.float32(n), jnp.float32(l))


@partial(jax.jit, static_argnames=("k",))
def nmimps_log_z(v: jax.Array, q: jax.Array, k: int) -> jax.Array:
    """Naive MIMPS (Eq. 4): head only — shown inadequate in the paper."""
    vals, _ = _mips.exact_top_k(v, q, k)
    return _lse(vals)


@partial(jax.jit, static_argnames=("k", "l", "iters", "solver", "weighting"))
def mince_log_z(v: jax.Array, q: jax.Array, k: int, l: int, key: jax.Array,
                iters: int = 25, solver: str = "halley",
                weighting: str = "anchored") -> jax.Array:
    """MINCE (Eq. 6/7): solve for Z via NCE with S_k as data, uniform noise.

    weighting='paper' is the literal Eq. 6/7 setup (alpha_i = s_i +
    log(k (N-k)/l) over the enumerated head, beta_j likewise over noise) —
    what Table 1 reproduces, and what diverges at concentrated score scales
    because the enumerated top-k is *not* a k-sample from p = exp(s)/Z
    (BENCH_estimators.json recorded rel_err ~ 3e5 before this fix; see
    ``core.mince`` for the analysis).

    weighting='anchored' (default) keeps the NCE estimating equation and the
    Halley solve but enters each enumerated/sampled atom with its importance
    weight (``mince.anchored_atoms``), anchored at the Eq. 5 plug-in. The
    equation then factorizes and its root coincides with the anchor (the
    collapse identity — ``mince.anchored_solve``), so the estimate is
    MIMPS-accurate in both flat and concentrated regimes and the bracketed
    solve cannot diverge.

    Degenerate heads are guarded: k == 0 has no data samples, so the NCE
    objective cannot identify Z (log k would poison alpha with -inf and the
    Halley solver with NaNs) — fall back to the uniform-noise-only objective,
    which *is* identifiable and equals uniform importance sampling. k >= N
    means the head is the whole vocabulary: return the exact logsumexp.
    """
    n = v.shape[0]
    if k <= 0:
        return uniform_log_z(v, q, l, key)
    if k >= n:
        return exact_log_z(v, q)
    ret = oracle_retrieve(v, q)
    head = ret.scores_sorted[:k]
    noise = _complement_sample(key, ret, k, l)
    theta0 = _lse(head)   # head mass is a sane starting point
    if weighting == "paper":
        log_ratio = jnp.log(jnp.float32(k)) + jnp.log(jnp.float32(n - k)) - \
            jnp.log(jnp.float32(l))
        alpha = head + log_ratio
        beta = noise + log_ratio
        return _mince.solve_log_z(alpha, beta, theta0, iters=iters,
                                  solver=solver)
    assert weighting == "anchored", weighting
    c_t = jnp.float32(n - k) / jnp.float32(l)
    scores = jnp.concatenate([head, noise])
    mult = jnp.concatenate([jnp.ones((k,), jnp.float32),
                            jnp.full((l,), c_t, jnp.float32)])
    anchor = head_tail_log_z(head, noise, jnp.float32(n - k), jnp.float32(l))
    alpha, wd, wn = _mince.anchored_atoms(
        scores, mult, n, jnp.float32(k), jnp.float32(l), anchor)
    return _mince.solve_shared_atoms(alpha, wd, wn, anchor, iters=iters,
                                     solver=solver)


def fmbe_log_z(state: FMBEState, q: jax.Array) -> jax.Array:
    """FMBE returns a *signed* Z estimate; log of clipped value for API parity."""
    z = fmbe_estimate_z(state, q)
    return jnp.log(jnp.maximum(z, 1e-30))


def fmbe_z(state: FMBEState, q: jax.Array) -> jax.Array:
    return fmbe_estimate_z(state, q)


# ---------------------------------------------------------------------------
# Sublinear MIMPS via block-IVF (the TPU-native deployment path)
# ---------------------------------------------------------------------------

class IVFEstimate(NamedTuple):
    log_z: jax.Array
    k_eff: jax.Array           # real rows covered by probed blocks
    top_score: jax.Array       # best inner product found (for p(i_hat))
    top_id: jax.Array          # original row id of the argmax


@partial(jax.jit, static_argnames=("n_probe", "l"))
def mimps_ivf(index: _mips.IVFIndex, q: jax.Array, n_probe: int, l: int,
              key: jax.Array) -> IVFEstimate:
    """Sublinear MIMPS: head = rows of top-n_probe IVF blocks (scored exactly),
    tail = uniform rejection sample over unprobed rows, scaled by
    (N - k_eff) / #survivors (Eq. 5's (N-k)/|U_l| with rejection).

    Cost: O(n_blocks d + n_probe block_rows d + l d)  <<  O(N d).
    """
    blocks = _mips.probe(index, q, n_probe)
    head_scores, head_valid = _mips.gather_scores(index, q, blocks)
    k_eff = head_valid.sum()
    n = index.n
    # tail: sample original rows uniformly; reject those in probed blocks.
    idx = jax.random.randint(key, (l,), 0, n)
    slots = index.slot_of_row[idx]
    row_block = slots // index.block_rows
    in_head = jnp.any(row_block[:, None] == blocks[None, :], axis=1)
    flat = index.v_blocks.reshape(-1, index.v_blocks.shape[-1])
    tail_scores = flat[slots] @ q
    # Eq. 5 with rejection: the surviving samples are uniform over the
    # N - k_eff unprobed rows, so scale by (N - k_eff) / #survivors — the
    # Rao-Blackwellization (over the survivor count) of the equally unbiased
    # N / l scale; conditioning removes the rejection-noise variance term.
    log_z = head_tail_log_z(head_scores, tail_scores,
                            (n - k_eff).astype(jnp.float32),
                            jnp.sum(~in_head).astype(jnp.float32),
                            head_mask=head_valid, tail_mask=~in_head)
    masked = jnp.where(head_valid, head_scores, NEG_INF)
    best = jnp.argmax(masked)
    top_id = index.row_id[blocks[best // index.block_rows],
                          best % index.block_rows]
    return IVFEstimate(log_z=log_z, k_eff=k_eff,
                       top_score=masked[best], top_id=top_id)


# ---------------------------------------------------------------------------
# Per-query dispatcher (oracle/study path)
# ---------------------------------------------------------------------------
# The registry below is the single-query analogue of the batched serving
# registry in ``core.backends`` — same method names, same semantics. Serving
# code (engine / sharded output layer / benches) must go through
# ``backends.get_backend``; this table exists for the paper's per-query
# accuracy studies (Tables 1-3) and the training losses.

_PER_QUERY = {
    "exact": lambda v, q, key, opt: exact_log_z(v, q),
    "mimps": lambda v, q, key, opt: (
        mimps_ivf(opt["index"], q, opt["n_probe"], opt["l"], key).log_z
        if opt["index"] is not None
        else mimps_log_z(v, q, opt["k"], opt["l"], key)),
    "nmimps": lambda v, q, key, opt: nmimps_log_z(v, q, opt["k"]),
    "uniform": lambda v, q, key, opt: uniform_log_z(v, q, opt["l"], key),
    "mince": lambda v, q, key, opt: mince_log_z(
        v, q, opt["k"], opt["l"], key, iters=opt["mince_iters"],
        solver=opt["mince_solver"]),
    "fmbe": lambda v, q, key, opt: fmbe_log_z(opt["fmbe_state"], q),
    "selfnorm": lambda v, q, key, opt: jnp.zeros(()),   # assume Z == 1
}


def estimate_log_z(method: str, v: jax.Array, q: jax.Array, key: jax.Array,
                   *, k: int = 100, l: int = 100,
                   index: Optional[_mips.IVFIndex] = None,
                   n_probe: int = 8,
                   fmbe_state: Optional[FMBEState] = None,
                   mince_iters: int = 25,
                   mince_solver: str = "halley") -> jax.Array:
    try:
        fn = _PER_QUERY[method]
    except KeyError:
        raise ValueError(f"unknown partition method {method!r}; "
                         f"have {sorted(_PER_QUERY)}") from None
    if method == "fmbe":
        assert fmbe_state is not None, "fmbe requires a prebuilt FMBEState"
    return fn(v, q, key, dict(k=k, l=l, index=index, n_probe=n_probe,
                              fmbe_state=fmbe_state, mince_iters=mince_iters,
                              mince_solver=mince_solver))


def relative_error(log_z_hat: jax.Array, log_z_true: jax.Array) -> jax.Array:
    """|Z_hat - Z| / Z computed stably in log domain (paper's mu, /100)."""
    return jnp.abs(1.0 - jnp.exp(log_z_hat - log_z_true))
