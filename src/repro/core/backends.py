"""Estimator-backend registry: the single serving dispatch path (DESIGN SS8).

Every partition method the engine can serve is a registered backend with two
obligations:

 * ``build(cfg, w, key)``   — index-build-time state derived from the output
   embedding ``w (V, d)``: the block-IVF index, the FMBE feature sketch, or
   nothing (exact / selfnorm).
 * ``decode(state, h, key, cfg, k, use_pallas, active)`` — one batched
   decode step for queries ``h (Q, d)``, returning the uniform ``DecodeOut``
   contract:
   ``log Ẑ (Q,)`` plus retrieved top-k ``(score, vocab id)`` candidates the
   sampler draws from. No backend touches ``oracle_retrieve`` here — the
   O(N log N) sort exists only for the paper's per-query accuracy studies
   (``estimators.estimate_log_z``).

``serve.engine.Engine``, the vocab-sharded output layer, the estimator
benchmark, and the examples all go through ``get_backend(method)`` — adding
an estimator means registering a backend, not growing if-chains at four call
sites. Backends also own their SS5/SS8 byte accounting
(``embedding_floats`` / ``floats_bound``) so the benchmark asserts each
method against its *own* ceiling instead of a hardcoded formula.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import PartitionConfig
from . import lsh as _lsh
from . import mips as _mips
from .decode import (DecodeOut, exact_topk_decode, fmbe_decode, mimps_decode,
                     mince_decode, selfnorm_decode, topk_head_decode)
from .feature_maps import (FMBEState, build_fmbe, build_fmbe_blocks,
                           make_feature_map)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BackendState:
    """Retrieval state built once per engine ("index build time").

    Registered as a pytree so it can be a traced ARGUMENT of compiled
    serving steps (the slot scheduler) instead of a baked-in constant —
    that is what lets ``Engine.swap_index`` hot-swap a freshly trained
    checkpoint into a live server without invalidating any executable."""
    w: jax.Array
    index: Optional[_mips.IVFIndex] = None
    fmbe: Optional[FMBEState] = None
    lsh: Optional[_lsh.LSHIndex] = None


def _build_index(cfg: PartitionConfig, w: jax.Array, key: jax.Array,
                 device: bool = False,
                 block_multiple: int = 1) -> Optional[_mips.IVFIndex]:
    """Block-IVF over the output embedding; skipped for tiny vocabularies
    (the exact pass is already cheaper than a probe there). ``device=True``
    uses the jittable fixed-capacity build (``mips.build_ivf_device``) whose
    shapes depend only on (V, block_rows, n_clusters) — the prerequisite
    for rebuilding the index under a live server without recompiling.

    ``block_multiple`` pads the block axis with dead blocks so it divides
    the serving mesh's model-parallel degree (``mips.pad_ivf_blocks``) —
    applied HERE, before any state derived from the blocks (FMBE's
    per-block lambdas index by block id), so every downstream shape is
    consistently padded."""
    if w.shape[0] >= 4 * cfg.block_rows:
        build = _mips.build_ivf_device if device else _mips.build_ivf
        index = build(key, w, block_rows=cfg.block_rows,
                      n_clusters=cfg.n_clusters)
        if block_multiple > 1:
            index = _mips.pad_ivf_blocks(index, block_multiple)
        return index
    return None


class EstimatorBackend:
    method: str = ""
    sublinear: bool = False       # True -> decode cost independent of V*d

    def build(self, cfg: PartitionConfig, w: jax.Array, key: jax.Array,
              *, with_index: bool = True, device: bool = False,
              block_multiple: int = 1) -> BackendState:
        """with_index=False skips the kmeans IVF build for callers that only
        need the estimate (the per-query accuracy studies); serving always
        builds it — it supplies the sampling candidates. ``device=True``
        selects the fixed-capacity jittable index build (shape-stable
        across rebuilds — required for ``Engine.swap_index``).
        ``block_multiple`` pads the index block axis to a multiple (mesh
        serving: the model-parallel degree, so v_blocks shards evenly)."""
        return BackendState(w=w)

    def refresh(self, state: BackendState, cfg: PartitionConfig,
                w: jax.Array, key: jax.Array, *, device: bool = True,
                block_multiple: int = 1) -> BackendState:
        """Rebuild the retrieval state from a NEW embedding — the
        ``Engine.swap_index`` entry point. With ``device=True`` (the
        fixed-capacity index build) the result has an IDENTICAL pytree
        structure/shapes to a same-config ``build``, so compiled steps
        that take the state as an argument keep their executables; that is
        the hot-swap contract. ``device``/``block_multiple`` mirror how the
        engine was built."""
        del state
        return self.build(cfg, w, key, device=device,
                          block_multiple=block_multiple)

    def decode(self, state: BackendState, h: jax.Array, key: jax.Array,
               cfg: PartitionConfig, *, k: int = 1,
               use_pallas: bool = False,
               active: Optional[jax.Array] = None,
               **kernel_cfg) -> DecodeOut:
        """``kernel_cfg`` carries the method's autotuned Pallas tile sizes
        (``tune``'s return value); empty = kernel defaults. ``active`` (Q,)
        bool marks the live rows of a padded slot-table batch (continuous
        batching): probe paths keep masked rows out of the dedup'd union
        (core.decode.make_plan), dense paths ignore it."""
        raise NotImplementedError

    def shard_decode(self, state: BackendState, h: jax.Array,
                     key: jax.Array, cfg: PartitionConfig, *, k: int = 1,
                     active: Optional[jax.Array] = None,
                     axis_name: str = "model") -> DecodeOut:
        """Mesh-serving twin of ``decode``: runs INSIDE the scheduler's
        shard_map step, with ``state`` partitioned per
        ``state_partition_specs`` (w rows / index v_blocks local to the
        ``axis_name`` shard, all metadata replicated). Same DecodeOut
        contract; the IVF paths are bit-identical to their single-device
        ``decode`` (serve.output_layer mesh bodies). XLA-only — the mesh
        step never takes the Pallas kernels, so no ``kernel_cfg``."""
        raise NotImplementedError(
            f"backend {self.method!r} has no mesh serving path")

    def tune(self, state: BackendState, cfg: PartitionConfig, h: jax.Array,
             key: jax.Array, *, path=None) -> dict:
        """Measure-and-cache the method's Pallas tile sizes for a decode
        batch shaped like ``h`` (kernels.autotune; on-disk cache keyed by
        shape/dtype/backend). Returns kwargs for ``decode``; {} = nothing
        to tune."""
        return {}

    # -- SS5/SS8 byte accounting (embedding floats per decode step) ----------

    def embedding_floats(self, state: BackendState, cfg: PartitionConfig,
                         q: int, u: Optional[int] = None) -> int:
        """Measured embedding floats for a Q-query step (u = measured number
        of deduplicated probed blocks, where applicable)."""
        v, d = state.w.shape
        return v * d + q * d

    def floats_bound(self, state: BackendState, cfg: PartitionConfig,
                     q: int) -> int:
        """Per-method ceiling the benchmark asserts ``embedding_floats``
        against (worst-case u = min(Q*n_probe, n_blocks))."""
        return self.embedding_floats(state, cfg, q)


BACKENDS: Dict[str, EstimatorBackend] = {}


def register_backend(cls):
    inst = cls()
    assert inst.method, "backend must set a method name"
    BACKENDS[inst.method] = inst
    return cls


def get_backend(method: str) -> EstimatorBackend:
    try:
        return BACKENDS[method]
    except KeyError:
        raise ValueError(
            f"no serving backend registered for method {method!r}; serving "
            f"methods: {sorted(BACKENDS)} (oracle-only estimators such as "
            f"'uniform'/'nmimps' live in core.estimators.estimate_log_z)"
        ) from None


def state_partition_specs(state: BackendState, n_model: int):
    """PartitionSpec tree for a BackendState entering the mesh serving step.

    Only the O(V d) payloads shard over 'model': the embedding rows ``w``
    and the IVF ``v_blocks`` block axis. Every per-block metadata leaf
    (centroids, radius, valid, row_id, slot_of_row) and the FMBE sketch is
    replicated — that is what lets the shard_map bodies run the verbatim
    single-device plan (probe/dedup/trim/tail) and fetch rows with one
    psum (``serve.output_layer``). Falls back to full replication when a
    payload doesn't divide ``n_model`` (the engine enforces divisibility
    up front for real meshes)."""
    from jax.sharding import PartitionSpec as P
    specs = jax.tree.map(lambda _: P(), state)
    repl = {}
    if state.w.shape[0] % n_model == 0:
        repl["w"] = P("model", None)
    if (state.index is not None
            and state.index.v_blocks.shape[0] % n_model == 0):
        repl["index"] = specs.index._replace(v_blocks=P("model", None, None))
    return dataclasses.replace(specs, **repl)


def verify_decode(backend: EstimatorBackend, state: BackendState,
                  h: jax.Array, key: jax.Array, cfg: PartitionConfig, *,
                  k: int = 1, active: Optional[jax.Array] = None,
                  use_pallas: bool = False, axis_name: Optional[str] = None,
                  **kernel_cfg) -> DecodeOut:
    """k-position batched verification: ONE accurate-backend decode over a
    (S, k_pos, d) stack of drafted hidden states, the core of
    estimator-speculative decoding (DESIGN.md SS16b).

    The stack is flattened lane-major to (S*k_pos, d) and dispatched through
    the backend's ordinary ``decode`` (or ``shard_decode`` when
    ``axis_name`` is set — inside the scheduler's shard_map step). Because
    every probe path computes candidates PER QUERY on replicated metadata
    and masks inactive rows out of the dedup union only (never out of a
    row's own candidate list), each flattened row's DecodeOut is identical
    to what a separate single-position step would produce for that hidden
    state — so verifying k drafted positions in one batch is exact, and the
    batch amortizes the probe-union gather across all S*k_pos queries.
    ``active`` is the per-LANE (S,) mask; it is expanded to rows here.
    Leaves come back flat — callers reshape to (S, k_pos, ...)."""
    S, kpos, d = h.shape
    hf = h.reshape(S * kpos, d)
    act = None if active is None else jnp.repeat(active, kpos)
    if axis_name is not None:
        return backend.shard_decode(state, hf, key, cfg, k=k, active=act,
                                    axis_name=axis_name)
    return backend.decode(state, hf, key, cfg, k=k, use_pallas=use_pallas,
                          active=act, **kernel_cfg)


def shadow_exact_log_z(state: BackendState, h: jax.Array,
                       axis_name: Optional[str] = None) -> jax.Array:
    """Ground-truth log Z for the observability shadow sampler (obs/): the
    EXACT backend's log-partition expression, reproduced term-for-term so
    that shadow-sampling the exact tier yields rel-err identically zero
    (bitwise: same dtype cast, same reduction — ``exact_topk_decode``'s XLA
    branch single-device, ``mesh_exact_decode``'s logspace-psum under the
    model axis). Every ``BackendState`` carries the dense embedding ``w``
    (the health guard's fallback already relies on it), so the oracle costs
    one dense matmul on the shadow cadence and nothing on other steps."""
    lse = jax.nn.logsumexp((h @ state.w.T).astype(jnp.float32), -1)
    if axis_name is None:
        return lse
    from .distributed import logspace_psum
    return logspace_psum(lse, axis_name)


def _head_floats(state: BackendState, cfg: PartitionConfig, q: int,
                 u: Optional[int]) -> int:
    """Centroid scan + deduplicated head blocks + query rows."""
    idx = state.index
    d = state.w.shape[1]
    if idx is None:
        return state.w.shape[0] * d + q * d
    if u is None:
        u = min(q * cfg.n_probe, idx.n_blocks)
    return idx.n_blocks * d + u * idx.block_rows * d + q * d


@register_backend
class ExactBackend(EstimatorBackend):
    method = "exact"

    def decode(self, state, h, key, cfg, *, k=1, use_pallas=False,
               active=None, **kernel_cfg):
        return exact_topk_decode(state.w, h, k=k, use_pallas=use_pallas,
                                 active=active, **kernel_cfg)

    def shard_decode(self, state, h, key, cfg, *, k=1, active=None,
                     axis_name="model"):
        # serve.output_layer imported lazily at trace time: serve is already
        # loaded whenever a mesh step exists, and core must not import serve
        # at module scope
        from ..serve.output_layer import mesh_exact_decode
        return mesh_exact_decode(state.w, h, k=k, active=active,
                                 axis_name=axis_name)

    def tune(self, state, cfg, h, key, *, path=None):
        from ..kernels.autotune import tune_topk_z
        return tune_topk_z(h, state.w, 1, path=path)


@register_backend
class SelfnormBackend(EstimatorBackend):
    method = "selfnorm"

    def decode(self, state, h, key, cfg, *, k=1, use_pallas=False,
               active=None, **kernel_cfg):
        return selfnorm_decode(state.w, h, k=k, use_pallas=use_pallas,
                               active=active, **kernel_cfg)

    def shard_decode(self, state, h, key, cfg, *, k=1, active=None,
                     axis_name="model"):
        from ..serve.output_layer import mesh_selfnorm_decode
        return mesh_selfnorm_decode(state.w, h, k=k, active=active,
                                    axis_name=axis_name)

    tune = ExactBackend.tune


@register_backend
class MimpsBackend(EstimatorBackend):
    method = "mimps"
    sublinear = True

    def build(self, cfg, w, key, *, with_index=True, device=False,
              block_multiple=1):
        return BackendState(
            w=w, index=_build_index(cfg, w, key, device=device,
                                    block_multiple=block_multiple)
            if with_index else None)

    def decode(self, state, h, key, cfg, *, k=1, use_pallas=False,
               active=None, **kernel_cfg):
        if state.index is None:
            return exact_topk_decode(state.w, h, k=k, use_pallas=use_pallas)
        return mimps_decode(state.index, h, key, n_probe=cfg.n_probe,
                            l=cfg.l, k=k, head_cap=cfg.head_cap,
                            use_pallas=use_pallas, active=active,
                            **kernel_cfg)

    def shard_decode(self, state, h, key, cfg, *, k=1, active=None,
                     axis_name="model"):
        from ..serve.output_layer import (mesh_exact_decode,
                                          mesh_mimps_decode)
        if state.index is None:
            return mesh_exact_decode(state.w, h, k=k, axis_name=axis_name)
        return mesh_mimps_decode(state.index, h, key, n_probe=cfg.n_probe,
                                 l=cfg.l, k=k, head_cap=cfg.head_cap,
                                 active=active, axis_name=axis_name)

    def tune(self, state, cfg, h, key, *, path=None):
        if state.index is None:
            return {}
        from ..kernels.autotune import tune_ivf_decode
        from .decode import _tail_rows, make_plan
        index = state.index
        plan = make_plan(index, h, key, cfg.n_probe, max(cfg.l, 1))
        rows = _tail_rows(index, plan)
        row_logw = jnp.where(index.valid, 0.0, -1e30).astype(jnp.float32)
        return tune_ivf_decode(index.v_blocks, h, plan.head_ids,
                               plan.head_live, plan.head_member, row_logw,
                               rows, plan.tail_accept, path=path)

    def embedding_floats(self, state, cfg, q, u=None):
        base = _head_floats(state, cfg, q, u)
        d = state.w.shape[1]
        return base + (cfg.l * d if state.index is not None else 0)


@register_backend
class MinceBackend(EstimatorBackend):
    method = "mince"
    sublinear = True

    def build(self, cfg, w, key, *, with_index=True, device=False,
              block_multiple=1):
        return BackendState(
            w=w, index=_build_index(cfg, w, key, device=device,
                                    block_multiple=block_multiple)
            if with_index else None)

    def decode(self, state, h, key, cfg, *, k=1, use_pallas=False,
               active=None, **kernel_cfg):
        if state.index is None:
            return exact_topk_decode(state.w, h, k=k, use_pallas=use_pallas)
        return mince_decode(state.index, h, key, n_probe=cfg.n_probe,
                            l=cfg.l, k=k, iters=cfg.mince_iters,
                            solver=cfg.mince_solver, head_cap=cfg.head_cap,
                            use_pallas=use_pallas, active=active,
                            **kernel_cfg)

    def shard_decode(self, state, h, key, cfg, *, k=1, active=None,
                     axis_name="model"):
        from ..serve.output_layer import (mesh_exact_decode,
                                          mesh_mince_decode)
        if state.index is None:
            return mesh_exact_decode(state.w, h, k=k, axis_name=axis_name)
        return mesh_mince_decode(state.index, h, key, n_probe=cfg.n_probe,
                                 l=cfg.l, k=k, iters=cfg.mince_iters,
                                 solver=cfg.mince_solver,
                                 head_cap=cfg.head_cap, active=active,
                                 axis_name=axis_name)

    def tune(self, state, cfg, h, key, *, path=None):
        if state.index is None:
            return {}
        from ..kernels.autotune import tune_union_scores
        from .decode import make_plan
        plan = make_plan(state.index, h, key, cfg.n_probe, max(cfg.l, 1))
        return tune_union_scores(state.index.v_blocks, h, plan.head_ids,
                                 plan.head_live, path=path)

    # same traffic shape as MIMPS: union head blocks + shared tail rows
    embedding_floats = MimpsBackend.embedding_floats


@register_backend
class TopkBackend(EstimatorBackend):
    """Head-only retrieval (Eq. 4 at the output layer): the bottom rung of
    the serving degradation ladder. Candidates and sampling are identical to
    MIMPS; log Ẑ is the probed head's LSE (deterministic underestimate — no
    tail traffic, no tail plan). Not an accuracy-study estimator: it exists
    so an overloaded server can keep emitting tokens at the lowest possible
    per-step cost instead of stalling."""
    method = "topk"
    sublinear = True

    build = MimpsBackend.build

    def decode(self, state, h, key, cfg, *, k=1, use_pallas=False,
               active=None, **kernel_cfg):
        if state.index is None:
            return exact_topk_decode(state.w, h, k=k, use_pallas=use_pallas)
        kernel_cfg.pop("tail_tile", None)    # tuned-for-mimps cfgs carry it
        return topk_head_decode(state.index, h, key, n_probe=cfg.n_probe,
                                k=k, head_cap=cfg.head_cap,
                                use_pallas=use_pallas, active=active,
                                **kernel_cfg)

    def shard_decode(self, state, h, key, cfg, *, k=1, active=None,
                     axis_name="model"):
        from ..serve.output_layer import (mesh_exact_decode,
                                          mesh_topk_decode)
        if state.index is None:
            return mesh_exact_decode(state.w, h, k=k, axis_name=axis_name)
        return mesh_topk_decode(state.index, h, key, n_probe=cfg.n_probe,
                                k=k, head_cap=cfg.head_cap, active=active,
                                axis_name=axis_name)

    tune = MinceBackend.tune                 # same union-score kernel

    def embedding_floats(self, state, cfg, q, u=None):
        return _head_floats(state, cfg, q, u)


@register_backend
class FmbeBackend(EstimatorBackend):
    method = "fmbe"
    sublinear = True

    def build(self, cfg, w, key, *, with_index=True, device=False,
              block_multiple=1):
        kf, ki = jax.random.split(key)
        fm = make_feature_map(kf, w.shape[-1], cfg.fmbe_features,
                              max_degree=cfg.fmbe_max_degree, p=cfg.fmbe_p)
        # index already padded to block_multiple here, so the per-block
        # lambda table below lines up with padded block ids (pad blocks are
        # all-invalid -> zero lambda rows, lambda_tilde unchanged)
        index = _build_index(cfg, w, ki, device=device,
                             block_multiple=block_multiple) \
            if with_index else None
        if index is not None:
            # block-partitioned lambdas (the exact-head/sketch-tail hybrid);
            # lambda_tilde is their sum — one O(V P M d) phi pass, not two
            lam_b = build_fmbe_blocks(fm, index.v_blocks, index.valid)
            fmbe = FMBEState(fm=fm, lambda_tilde=lam_b.sum(0),
                             lambda_blocks=lam_b)
        else:
            fmbe = build_fmbe(fm, w)
        return BackendState(w=w, index=index, fmbe=fmbe)

    def decode(self, state, h, key, cfg, *, k=1, use_pallas=False,
               active=None, **kernel_cfg):
        from .feature_maps import fmbe_z_batch
        if state.index is None:
            out = exact_topk_decode(state.w, h, k=k, use_pallas=use_pallas)
            z = fmbe_z_batch(state.fmbe, h, use_pallas=use_pallas)
            return out._replace(log_z=jnp.log(jnp.maximum(z, 1e-30)))
        return fmbe_decode(state.fmbe, state.index, h, key,
                           n_probe=cfg.n_probe, k=k, head_cap=cfg.head_cap,
                           use_pallas=use_pallas, active=active,
                           **kernel_cfg)

    def shard_decode(self, state, h, key, cfg, *, k=1, active=None,
                     axis_name="model"):
        from ..serve.output_layer import (mesh_exact_decode,
                                          mesh_fmbe_decode)
        if state.index is None:
            from .feature_maps import fmbe_z_batch
            out = mesh_exact_decode(state.w, h, k=k, axis_name=axis_name)
            z = fmbe_z_batch(state.fmbe, h)       # sketch is replicated
            return out._replace(log_z=jnp.log(jnp.maximum(z, 1e-30)))
        return mesh_fmbe_decode(state.fmbe, state.index, h, key,
                                n_probe=cfg.n_probe, k=k,
                                head_cap=cfg.head_cap, active=active,
                                axis_name=axis_name)

    def tune(self, state, cfg, h, key, *, path=None):
        from ..kernels.autotune import tune_fmbe_z
        fm = state.fmbe.fm
        return tune_fmbe_z(fm.omega, fm.degree, fm.coef,
                           state.fmbe.lambda_tilde, h, path=path)

    def embedding_floats(self, state, cfg, q, u=None):
        # feature sketch (omega + lambda) + the candidate head + the
        # per-query probed-block lambda gather of the tail hybrid
        fm = state.fmbe.fm
        p_feat, max_deg, d = fm.omega.shape
        lam_gather = (q * cfg.n_probe * p_feat
                      if state.fmbe.lambda_blocks is not None else 0)
        return (p_feat * max_deg * d + p_feat + lam_gather +
                _head_floats(state, cfg, q, u))


@register_backend
class LshBackend(EstimatorBackend):
    """SimHash collision head + Eq. 5 tail combine (core.lsh): the second
    retrieval structure. The index supplies ROUTING ONLY — candidates and
    tail rows are always gathered from ``state.w`` — so there is no embedded
    row copy to drift stale, swap_index is a cheap re-hash (no Lloyd steps),
    and the engine's index digests (IVF-only) are simply inapplicable.
    ``cfg.head_cap`` is reinterpreted as the candidate-ROW cap of the
    trimmed scoring matmul (0 = auto, ``lsh.resolve_cand_cap``)."""
    method = "lsh"
    sublinear = True

    def build(self, cfg, w, key, *, with_index=True, device=False,
              block_multiple=1):
        # tiny vocabularies: the exact pass beats any probe — same skip
        # criterion shape as _build_index (4x the expected bucket load)
        del device, block_multiple                  # build is always jittable
        lsh = None
        if with_index and w.shape[0] >= 4 * (1 << cfg.lsh_bits):
            lsh = _lsh.build_lsh_device(
                key, w, n_bits=cfg.lsh_bits, n_tables=cfg.lsh_tables,
                bucket_cap=cfg.lsh_bucket_cap,
                mips_scale=cfg.lsh_mips_scale,
                tail_beta=cfg.lsh_tail_beta)
        return BackendState(w=w, lsh=lsh)

    def decode(self, state, h, key, cfg, *, k=1, use_pallas=False,
               active=None, **kernel_cfg):
        if state.lsh is None:
            return exact_topk_decode(state.w, h, k=k, use_pallas=use_pallas)
        return _lsh.lsh_decode(state.lsh, state.w, h, key, l=cfg.l, k=k,
                               cand_cap=cfg.head_cap, use_pallas=use_pallas,
                               active=active, **kernel_cfg)

    def shard_decode(self, state, h, key, cfg, *, k=1, active=None,
                     axis_name="model"):
        from ..serve.output_layer import (mesh_exact_decode,
                                          mesh_lsh_decode)
        if state.lsh is None:
            return mesh_exact_decode(state.w, h, k=k, axis_name=axis_name)
        return mesh_lsh_decode(state.lsh, state.w, h, key, l=cfg.l, k=k,
                               cand_cap=cfg.head_cap, active=active,
                               axis_name=axis_name)

    def tune(self, state, cfg, h, key, *, path=None):
        if state.lsh is None:
            return {}
        from ..kernels.autotune import tune_lsh_probe
        return tune_lsh_probe(state.lsh, state.w, h, key,
                              l=max(cfg.l, 1), cand_cap=cfg.head_cap,
                              path=path)

    def embedding_floats(self, state, cfg, q, u=None):
        # hyperplanes + dedup'd candidate rows + shared tail rows + queries
        v, d = state.w.shape
        lsh = state.lsh
        if lsh is None:
            return v * d + q * d
        if u is None:        # worst case: every probed bucket slot unique
            u = min(q * lsh.n_tables * lsh.bucket_cap, v)
        return (lsh.n_tables * lsh.n_bits * d + u * d + cfg.l * d + q * d)
