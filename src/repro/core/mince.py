"""MINCE: estimating Z as the parameter of an NCE objective (paper SS4.2).

Paper Eq. 7 (negated objective to *minimize*):

    -J(Z) = sum_i log(Z / a_i + 1) + sum_j log(b_j / Z + 1)

with a_i = exp(s_i . q) * k (N - k) / l over head samples s_i in S_k(q) and
b_j defined analogously over the l uniform noise samples.

We optimize in theta = log Z (the objective is strictly convex in theta):

    f(theta)  = sum_i softplus(theta - alpha_i) + sum_j softplus(beta_j - theta)
    f'(theta) = sum_i sigma(theta - alpha_i) - sum_j sigma(beta_j - theta)

f', f'', f''' are all elementwise sigmoids/products — the paper's observation
that "even the third derivatives can be found efficiently", enabling Halley's
method (cubic convergence) over Newton's (quadratic).

All entry points are **rank-polymorphic over leading batch axes** (the
serving path solves a whole decode batch of independent NCE problems in one
trust-clamped Halley iteration): ``alpha (..., A)``, ``beta (..., B)``,
``theta (...,)`` — sample sums are always over the trailing axis. The
scalar per-query form used by ``estimators.mince_log_z`` is the ``... = ()``
special case; ``jax.vmap(solve_log_z)`` and the batched call agree exactly.
``derivative_sums`` / ``halley_step`` are split out so the vocab-sharded
output layer can ``psum`` the partial sums between them (each shard holds a
slice of the sample sets; every shard then walks one shared theta).

Score-once serving path
-----------------------
The serving decode touches every embedding row exactly once (the scores are
resident from the probe plan); the per-query cached atoms
``(alpha, w_data, w_noise)`` are the sufficient statistics of the NCE
objective and ``solve_shared_atoms`` iterates on them with ONE fused
sigmoid pass per Halley step — data and noise evaluate on the same atom
set, so sigma(alpha - theta) = 1 - sigma(theta - alpha) collapses all
three derivative sums into a single pass, and no embedding is ever
re-gathered inside the iteration.

For the vocab-sharded output layer the atoms live on different shards, so
they are further compressed into ``MinceStats`` — a fixed-size weighted
histogram of the sigmoid-argument multiset, bucketed around the Eq. 5
anchor.  Because sigmoids saturate, atoms clamped into the edge buckets
(|alpha - anchor| > span) contribute their exact saturated value; interior
buckets use the weighted-mean representative (second-order accurate,
validated < 1e-3 theta error at bench scale).  Histograms are plain
weighted sums over samples, so shards combine with ONE psum of the
(B, S, 4) stats before the solve instead of one psum per iteration
(``serve.output_layer._local_mince_logz``).

Anchored weights (the bench-scale divergence fix)
-------------------------------------------------
The seed treated the *enumerated* top-k head as if it were a k-sample from
the model distribution p = exp(s)/Z.  For the paper's flat word2vec regime
that is tolerable; at concentrated scales it overcounts rare head items by
(N-k)/l and the NCE root lands at a score quantile instead of log Z
(BENCH_estimators.json recorded rel_err ~ 3e5).  ``anchored_atoms`` fixes
the weighting: each enumerated atom i enters the data side with weight
k' * m_i * exp(s_i - anchor) — its expected multiplicity in a k'-sample of
p, with the Eq. 5 estimate as the plug-in anchor — and the noise side with
weight (l'/N) * m_i (m_i = 1 for enumerated head rows, (N-k)/n_accept for
tail survivors).  With these weights the population estimating equation
sum_i w_d,i sigma(theta - alpha_i) = sum_i w_n,i sigma(alpha_i - theta)
is the Gutmann–Hyvärinen identity evaluated exactly; in fact it factorizes
in closed form and its unique root IS the Eq. 5 anchor (the collapse
identity, proved in ``anchored_solve``) — averaging out the multinomial
sampling noise of NCE's data multiplicities collapses MINCE onto MIMPS,
which is precisely why the paper finds MINCE dominated by MIMPS: the
difference between them is pure sampling noise.  The anchored serving path
therefore inherits MIMPS-level accuracy in *both* regimes by construction.
The paper's original weighting stays available as ``weighting='paper'`` in
``estimators.mince_log_z`` — it is what Table 1 reproduces.

``solve_from_stats`` also fixes the solver dynamics: f' is monotone
non-decreasing in theta, so the Halley/Newton step is safeguarded by a
maintained bracket (bisect whenever the proposed step leaves it) — the seed's
unbracketed trust clamp let the iterate wander +-10/step across the f'
plateau, which is where the remaining ~9 nats of the bench blow-up came from.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp


def nce_objective(theta: jax.Array, alpha: jax.Array, beta: jax.Array,
                  alpha_mask=None, beta_mask=None) -> jax.Array:
    """-J(logZ = theta); alpha = log a_i (..., A), beta = log b_j (..., B),
    theta (...,) -> (...,). Masks (same shapes as alpha/beta) drop samples."""
    ta = jax.nn.softplus(theta[..., None] - alpha)
    tb = jax.nn.softplus(beta - theta[..., None])
    if alpha_mask is not None:
        ta = ta * alpha_mask
    if beta_mask is not None:
        tb = tb * beta_mask
    return jnp.sum(ta, axis=-1) + jnp.sum(tb, axis=-1)


def derivative_sums(theta, alpha, beta, alpha_mask=None, beta_mask=None):
    """(f', f'', f''') of the NCE objective, summed over the sample axis.

    theta (...,), alpha (..., A), beta (..., B) -> three (...,) arrays.
    These are plain sums over samples, so shards holding disjoint slices of
    the alpha/beta sets can ``lax.psum`` the three outputs before
    ``halley_step`` — the distributed-MINCE combine (O(1) floats per iter).
    """
    sa = jax.nn.sigmoid(theta[..., None] - alpha)
    sb = jax.nn.sigmoid(beta - theta[..., None])
    if alpha_mask is not None:
        sa = sa * alpha_mask
    if beta_mask is not None:
        sb = sb * beta_mask
    da = sa * (1.0 - sa)
    db = sb * (1.0 - sb)
    f1 = jnp.sum(sa, axis=-1) - jnp.sum(sb, axis=-1)
    f2 = jnp.sum(da, axis=-1) + jnp.sum(db, axis=-1)
    f3 = jnp.sum(da * (1.0 - 2.0 * sa), axis=-1) - \
        jnp.sum(db * (1.0 - 2.0 * sb), axis=-1)
    return f1, f2, f3


def halley_step(f1, f2, f3, solver: str = "halley",
                max_step: float = 10.0, eps: float = 1e-12):
    """One trust-clamped root-finding step from the derivative sums.

    solver: 'halley' (uses f''' — the paper's speedup) or 'newton'. Falls
    back to Newton where the Halley denominator degenerates.
    """
    newton = f1 / (f2 + eps)
    if solver == "halley":
        denom = 2.0 * f2 * f2 - f1 * f3
        halley = 2.0 * f1 * f2 / jnp.where(jnp.abs(denom) < eps, eps, denom)
        step = jnp.where(jnp.abs(denom) < eps, newton, halley)
    else:
        step = newton
    return jnp.clip(step, -max_step, max_step)


@partial(jax.jit, static_argnames=("iters", "solver", "max_step"))
def solve_log_z(alpha: jax.Array, beta: jax.Array, theta0: jax.Array,
                iters: int = 25, solver: str = "halley",
                alpha_mask=None, beta_mask=None,
                max_step: float = 10.0) -> jax.Array:
    """Minimize -J over theta = log Z. Returns theta*, shape = theta0.

    Batched: alpha (..., A), beta (..., B), theta0 (...,) solve every
    leading-axis problem simultaneously (one fused Halley sweep per decode
    batch). Steps are trust-clamped to +-max_step for robustness far from
    the root.
    """
    def body(theta, _):
        f1, f2, f3 = derivative_sums(theta, alpha, beta, alpha_mask,
                                     beta_mask)
        step = halley_step(f1, f2, f3, solver=solver, max_step=max_step)
        return theta - step, jnp.abs(step)

    theta, steps = jax.lax.scan(body, theta0, None, length=iters)
    return theta


# ---------------------------------------------------------------------------
# Score-once sufficient statistics + bracketed solve (serving path)
# ---------------------------------------------------------------------------

class MinceStats(NamedTuple):
    """Fixed-size sufficient statistics of one (batched) NCE problem.

    All arrays share leading batch axes; S is the static bucket count.
    ``a_*`` are bucket representatives (weighted mean alpha), ``w_*`` the
    bucket weight sums. ``lo``/``hi`` bracket the root (f' is monotone and
    saturates outside [lo, hi] by construction of the clamped binning).
    """
    a_data: jax.Array    # (..., S)
    w_data: jax.Array    # (..., S)
    a_noise: jax.Array   # (..., S)
    w_noise: jax.Array   # (..., S)
    lo: jax.Array        # (...,)
    hi: jax.Array        # (...,)


def anchored_atoms(scores, mult, n, k_virt, l_virt, log_anchor):
    """Sigmoid-argument atoms + consistent NCE weights from resident scores.

    scores (..., A): every enumerated/sampled score (head rows ++ surviving
    tail samples); mult (..., A): the IS multiplicity of each atom in the
    full population sum (1 for enumerated head rows, (N-k_eff)/n_accept for
    tail survivors, 0 for masked slots); n: population size; k_virt/l_virt
    (...,): virtual data/noise sample counts (the natural choice is
    k_eff/n_accept); log_anchor (...,): plug-in log Ẑ (Eq. 5 combine).

    Returns (alpha, w_data, w_noise), each (..., A).
    """
    k_virt = jnp.asarray(k_virt, jnp.float32)
    l_virt = jnp.asarray(l_virt, jnp.float32)
    log_anchor = jnp.asarray(log_anchor, jnp.float32)
    log_r = (jnp.log(jnp.maximum(k_virt, 1.0)) +
             jnp.log(jnp.asarray(n, jnp.float32)) -
             jnp.log(jnp.maximum(l_virt, 1.0)))
    alpha = scores + log_r[..., None]
    w_data = (k_virt[..., None] * mult *
              jnp.exp(jnp.minimum(scores - log_anchor[..., None], 40.0)))
    w_noise = (l_virt / n)[..., None] * mult
    return alpha, w_data, w_noise


def mince_stats(alpha, w_data, w_noise, log_anchor, *, n_bins: int = 128,
                span: float = 20.0) -> MinceStats:
    """Compress weighted atoms into S-bucket histograms around the anchor.

    Atoms land in uniform bins over [anchor - span, anchor + span]; atoms
    outside are clamped into the edge bins, where sigma has saturated (to
    < 2e-9 at span = 20) so the clamped representative is exact.  Stats from
    disjoint atom slices ADD — shards psum the four arrays once pre-solve.
    """
    batch = alpha.shape[:-1]
    lo = jnp.asarray(log_anchor, jnp.float32) - span
    width = (2.0 * span) / n_bins
    b = jnp.clip(((alpha - lo[..., None]) / width).astype(jnp.int32),
                 0, n_bins - 1)
    flat_b = b.reshape(-1, b.shape[-1])
    nrow = flat_b.shape[0]
    rows = jnp.broadcast_to(jnp.arange(nrow)[:, None], flat_b.shape)

    def seg(w):
        z = jnp.zeros((nrow, n_bins), jnp.float32)
        return z.at[rows, flat_b].add(w.reshape(-1, w.shape[-1]))

    wd, wn = seg(w_data), seg(w_noise)
    ad = seg(w_data * alpha) / jnp.maximum(wd, 1e-30)
    an = seg(w_noise * alpha) / jnp.maximum(wn, 1e-30)
    shape = batch + (n_bins,)
    return MinceStats(a_data=ad.reshape(shape), w_data=wd.reshape(shape),
                      a_noise=an.reshape(shape), w_noise=wn.reshape(shape),
                      lo=lo - 1.0, hi=log_anchor + span + 1.0)


def stats_derivative_sums(theta, stats: MinceStats):
    """(f', f'', f''') from bucketed stats — O(S) per query per iteration."""
    sa = jax.nn.sigmoid(theta[..., None] - stats.a_data)
    sb = jax.nn.sigmoid(stats.a_noise - theta[..., None])
    da = stats.w_data * sa * (1.0 - sa)
    db = stats.w_noise * sb * (1.0 - sb)
    f1 = jnp.sum(stats.w_data * sa, -1) - jnp.sum(stats.w_noise * sb, -1)
    f2 = jnp.sum(da, -1) + jnp.sum(db, -1)
    f3 = jnp.sum(da * (1.0 - 2.0 * sa), -1) - \
        jnp.sum(db * (1.0 - 2.0 * sb), -1)
    return f1, f2, f3


def solver_residual(theta, stats: MinceStats) -> jax.Array:
    """|f'(theta)| — the non-convergence diagnostic for a finished solve.

    A solve that converged sits at |f'| ~ round-off; a residual that stayed
    large marks a non-converged (or corrupted-input) problem. Serving's
    health layer does not need this for the anchored closed form (whose
    failure mode is a non-finite anchor, caught by
    ``decode.health_flags``) — it exists for the iterative paths
    (cold-start / sharded stats), where theta can be finite yet wrong.
    Non-finite stats propagate to a non-finite residual, so
    ``~isfinite(residual) | (residual > tol)`` is the complete check."""
    f1, _, _ = stats_derivative_sums(theta, stats)
    return jnp.abs(f1)


@partial(jax.jit, static_argnames=("iters", "solver"))
def solve_from_stats(stats: MinceStats, theta0, iters: int = 25,
                     solver: str = "halley"):
    """Bracket-safeguarded Halley/Newton root-find on bucketed stats.

    f' is monotone non-decreasing, so every evaluation tightens a bracket
    [lo, hi]; a proposed step that leaves the bracket is replaced by its
    midpoint (bisection), making divergence impossible while keeping the
    cubic local rate near the root.
    """
    theta0 = jnp.clip(theta0, stats.lo, stats.hi)

    def body(carry, _):
        theta, lo, hi = carry
        f1, f2, f3 = stats_derivative_sums(theta, stats)
        lo = jnp.where(f1 < 0, theta, lo)
        hi = jnp.where(f1 < 0, hi, theta)
        step = halley_step(f1, f2, f3, solver=solver,
                           max_step=float("inf"))
        cand = theta - step
        # inclusive bounds: a converged iterate (step == 0) sits exactly on
        # its own bracket edge and must stay there, not bisect away
        inside = (cand >= lo) & (cand <= hi)
        theta = jnp.where(inside, cand, 0.5 * (lo + hi))
        return (theta, lo, hi), None

    (theta, _, _), _ = jax.lax.scan(body, (theta0, stats.lo, stats.hi),
                                    None, length=iters)
    return theta


@partial(jax.jit, static_argnames=("iters", "solver"))
def anchored_solve(anchor, theta0, iters: int = 2, solver: str = "halley"):
    """Bracketed Halley solve of the anchored NCE equation (serving path).

    THE COLLAPSE IDENTITY. With the Rao-Blackwellized data multiplicities
    w_d,i = k' m_i exp(s_i - anchor) (the *expected* count of atom i in a
    k'-sample of the plug-in model) the estimating equation factorizes in
    closed form: with r = l'/N and G(theta) = sum_i m_i sigma(alpha_i -
    theta),

        f'(theta) =  sum_i w_d,i sigma(theta - alpha_i)
                   - sum_i w_n,i sigma(alpha_i - theta)
                  =  r (e^{theta - anchor} - 1) G(theta),

    because sigma(-x) = e^{-x} sigma(x) turns every data term into
    e^{theta-anchor-R} times its noise twin. Since G > 0 everywhere, the
    unique root is **exactly the anchor** — i.e. averaging out the
    multinomial sampling noise of NCE's data set collapses MINCE onto the
    Eq. 5 (MIMPS) estimate. The residual value MINCE adds over Eq. 5 in the
    paper's Table 1 is therefore *pure sampling noise*; the serving decode
    (``core.decode.mince_decode``) consequently evaluates the estimate in
    closed form at the anchor and inherits MIMPS-level accuracy by
    construction — that is the fix for the seed's rel_err ~ 3e5, which came
    from reading the enumerated head AS the sample (see module docstring).
    This function IS the solver for callers that want to run the iteration
    (cold starts, tests); note the damped step is bounded by 2 per
    iteration, so a start |delta| nats off needs ~|delta|/2 iterations —
    exactly the trap the seed's cold-start solver fell into.

    Better still, the positive factors r G(theta) CANCEL out of the damped
    Newton/Halley step (f'/f'' ratios are scale-free and G varies slowly
    against e^delta), leaving the exact scalar iterations

        newton:  theta <- theta - (1 - e^{-(theta - anchor)})
        halley:  theta <- theta - 2 tanh((theta - anchor) / 2)

    so after the one embedding pass that produced the anchor, each solver
    iteration costs a few scalar FLOPs per query — no per-atom work at all.
    The per-query sufficient statistic of the whole solve is the anchor
    itself. (The general weighted-atom solvers remain as
    ``solve_shared_atoms`` — the oracle study path — and
    ``solve_from_stats`` — the sharded one-psum combine; both find the same
    root through genuine per-sample sigmoid sums.) f' has
    sign(theta - anchor), so the bracket argument applies unchanged.
    """
    anchor = jnp.asarray(anchor, jnp.float32)
    span = 40.0
    theta0 = jnp.clip(theta0, anchor - span + 1.0, anchor + span - 1.0)

    def body(carry, _):
        theta, lo, hi = carry
        delta = jnp.clip(theta - anchor, -span, span)
        lo = jnp.where(delta < 0, theta, lo)
        hi = jnp.where(delta < 0, hi, theta)
        if solver == "halley":
            step = 2.0 * jnp.tanh(0.5 * delta)
        else:
            step = 1.0 - jnp.exp(-delta)
        cand = theta - step
        inside = (cand >= lo) & (cand <= hi)
        theta = jnp.where(inside, cand, 0.5 * (lo + hi))
        return (theta, lo, hi), None

    (theta, _, _), _ = jax.lax.scan(
        body, (theta0, anchor - span, anchor + span), None, length=iters)
    return theta


@partial(jax.jit, static_argnames=("iters", "solver"))
def solve_shared_atoms(alpha, w_data, w_noise, theta0, iters: int = 8,
                       solver: str = "halley", span: float = 40.0):
    """Bracketed Halley solve when data and noise share one atom set.

    The anchored serving objective evaluates both sides on the SAME alphas
    (enumerated head rows ++ tail survivors), so sigma(alpha - theta) =
    1 - sigma(theta - alpha) collapses the three derivative sums to ONE
    sigmoid pass over (..., A) per iteration:

        u = w_data + w_noise,  c = sa (1 - sa)
        f1 = sum u*sa - sum w_noise,  f2 = sum u*c,  f3 = sum u*c*(1 - 2 sa)

    theta0 should be the anchor (Eq. 5 plug-in) — the anchored root lies
    within ~1e-3 of it, so a handful of iterations reach float32 round-off;
    the [theta0 - span, theta0 + span] bracket (where f1 has provably
    saturated to its constant-sign limits) makes divergence impossible.
    """
    u = w_data + w_noise
    k_noise = jnp.sum(w_noise, axis=-1)
    return _bracketed_shared_solve(alpha, u, k_noise, theta0, iters, solver,
                                   span=span)


def _bracketed_shared_solve(alpha, u, k_noise, theta0, iters, solver,
                            span: float = 40.0):
    lo0 = theta0 - span
    hi0 = theta0 + span

    def body(carry, _):
        theta, lo, hi = carry
        sa = jax.nn.sigmoid(theta[..., None] - alpha)
        c = u * sa * (1.0 - sa)
        f1 = jnp.sum(u * sa, axis=-1) - k_noise
        f2 = jnp.sum(c, axis=-1)
        f3 = jnp.sum(c * (1.0 - 2.0 * sa), axis=-1)
        lo = jnp.where(f1 < 0, theta, lo)
        hi = jnp.where(f1 < 0, hi, theta)
        step = halley_step(f1, f2, f3, solver=solver, max_step=float("inf"))
        cand = theta - step
        # inclusive bounds: a converged iterate (step == 0) sits exactly on
        # its own bracket edge and must stay there, not bisect away
        inside = (cand >= lo) & (cand <= hi)
        theta = jnp.where(inside, cand, 0.5 * (lo + hi))
        return (theta, lo, hi), None

    (theta, _, _), _ = jax.lax.scan(body, (theta0, lo0, hi0), None,
                                    length=iters)
    return theta


def solver_convergence_trace(alpha, beta, theta0, iters=25, solver="halley"):
    """Per-iteration |f'(theta)| trace — used to benchmark Halley vs Newton."""
    def body(theta, _):
        f1, f2, f3 = derivative_sums(theta, alpha, beta, None, None)
        step = halley_step(f1, f2, f3, solver=solver)
        return theta - step, jnp.abs(f1)
    _, trace = jax.lax.scan(body, theta0, None, length=iters)
    return trace
