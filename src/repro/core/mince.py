"""MINCE: estimating Z as the parameter of an NCE objective (paper SS4.2).

Paper Eq. 7 (negated objective to *minimize*):

    -J(Z) = sum_i log(Z / a_i + 1) + sum_j log(b_j / Z + 1)

with a_i = exp(s_i . q) * k (N - k) / l over head samples s_i in S_k(q) and
b_j defined analogously over the l uniform noise samples.

We optimize in theta = log Z (the objective is strictly convex in theta):

    f(theta)  = sum_i softplus(theta - alpha_i) + sum_j softplus(beta_j - theta)
    f'(theta) = sum_i sigma(theta - alpha_i) - sum_j sigma(beta_j - theta)

f', f'', f''' are all elementwise sigmoids/products — the paper's observation
that "even the third derivatives can be found efficiently", enabling Halley's
method (cubic convergence) over Newton's (quadratic).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def nce_objective(theta: jax.Array, alpha: jax.Array, beta: jax.Array,
                  alpha_mask=None, beta_mask=None) -> jax.Array:
    """-J(logZ = theta); alpha = log a_i, beta = log b_j."""
    ta = jax.nn.softplus(theta - alpha)
    tb = jax.nn.softplus(beta - theta)
    if alpha_mask is not None:
        ta = ta * alpha_mask
    if beta_mask is not None:
        tb = tb * beta_mask
    return jnp.sum(ta) + jnp.sum(tb)


def _derivatives(theta, alpha, beta, alpha_mask, beta_mask):
    sa = jax.nn.sigmoid(theta - alpha)
    sb = jax.nn.sigmoid(beta - theta)
    if alpha_mask is not None:
        sa = sa * alpha_mask
    if beta_mask is not None:
        sb = sb * beta_mask
    da = sa * (1.0 - sa)
    db = sb * (1.0 - sb)
    f1 = jnp.sum(sa) - jnp.sum(sb)
    f2 = jnp.sum(da) + jnp.sum(db)
    f3 = jnp.sum(da * (1.0 - 2.0 * sa)) - jnp.sum(db * (1.0 - 2.0 * sb))
    return f1, f2, f3


@partial(jax.jit, static_argnames=("iters", "solver", "max_step"))
def solve_log_z(alpha: jax.Array, beta: jax.Array, theta0: jax.Array,
                iters: int = 25, solver: str = "halley",
                alpha_mask=None, beta_mask=None,
                max_step: float = 10.0) -> jax.Array:
    """Minimize -J over theta = log Z. Returns theta*.

    solver: 'halley' (uses f''' — the paper's speedup) or 'newton'.
    Steps are trust-clamped to +-max_step for robustness far from the root.
    """
    eps = 1e-12

    def body(theta, _):
        f1, f2, f3 = _derivatives(theta, alpha, beta, alpha_mask, beta_mask)
        newton = f1 / (f2 + eps)
        if solver == "halley":
            denom = 2.0 * f2 * f2 - f1 * f3
            halley = 2.0 * f1 * f2 / jnp.where(jnp.abs(denom) < eps, eps, denom)
            # fall back to newton when halley denominator degenerates
            step = jnp.where(jnp.abs(denom) < eps, newton, halley)
        else:
            step = newton
        step = jnp.clip(step, -max_step, max_step)
        return theta - step, jnp.abs(step)

    theta, steps = jax.lax.scan(body, theta0, None, length=iters)
    return theta


def solver_convergence_trace(alpha, beta, theta0, iters=25, solver="halley"):
    """Per-iteration |f'(theta)| trace — used to benchmark Halley vs Newton."""
    def body(theta, _):
        f1, f2, f3 = _derivatives(theta, alpha, beta, None, None)
        newton = f1 / (f2 + 1e-12)
        if solver == "halley":
            denom = 2.0 * f2 * f2 - f1 * f3
            step = jnp.where(jnp.abs(denom) < 1e-12, newton,
                             2.0 * f1 * f2 / denom)
        else:
            step = newton
        step = jnp.clip(step, -10.0, 10.0)
        return theta - step, jnp.abs(f1)
    _, trace = jax.lax.scan(body, theta0, None, length=iters)
    return trace
