"""MINCE: estimating Z as the parameter of an NCE objective (paper SS4.2).

Paper Eq. 7 (negated objective to *minimize*):

    -J(Z) = sum_i log(Z / a_i + 1) + sum_j log(b_j / Z + 1)

with a_i = exp(s_i . q) * k (N - k) / l over head samples s_i in S_k(q) and
b_j defined analogously over the l uniform noise samples.

We optimize in theta = log Z (the objective is strictly convex in theta):

    f(theta)  = sum_i softplus(theta - alpha_i) + sum_j softplus(beta_j - theta)
    f'(theta) = sum_i sigma(theta - alpha_i) - sum_j sigma(beta_j - theta)

f', f'', f''' are all elementwise sigmoids/products — the paper's observation
that "even the third derivatives can be found efficiently", enabling Halley's
method (cubic convergence) over Newton's (quadratic).

All entry points are **rank-polymorphic over leading batch axes** (the
serving path solves a whole decode batch of independent NCE problems in one
trust-clamped Halley iteration): ``alpha (..., A)``, ``beta (..., B)``,
``theta (...,)`` — sample sums are always over the trailing axis. The
scalar per-query form used by ``estimators.mince_log_z`` is the ``... = ()``
special case; ``jax.vmap(solve_log_z)`` and the batched call agree exactly.
``derivative_sums`` / ``halley_step`` are split out so the vocab-sharded
output layer can ``psum`` the partial sums between them (each shard holds a
slice of the sample sets; every shard then walks one shared theta).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def nce_objective(theta: jax.Array, alpha: jax.Array, beta: jax.Array,
                  alpha_mask=None, beta_mask=None) -> jax.Array:
    """-J(logZ = theta); alpha = log a_i (..., A), beta = log b_j (..., B),
    theta (...,) -> (...,). Masks (same shapes as alpha/beta) drop samples."""
    ta = jax.nn.softplus(theta[..., None] - alpha)
    tb = jax.nn.softplus(beta - theta[..., None])
    if alpha_mask is not None:
        ta = ta * alpha_mask
    if beta_mask is not None:
        tb = tb * beta_mask
    return jnp.sum(ta, axis=-1) + jnp.sum(tb, axis=-1)


def derivative_sums(theta, alpha, beta, alpha_mask=None, beta_mask=None):
    """(f', f'', f''') of the NCE objective, summed over the sample axis.

    theta (...,), alpha (..., A), beta (..., B) -> three (...,) arrays.
    These are plain sums over samples, so shards holding disjoint slices of
    the alpha/beta sets can ``lax.psum`` the three outputs before
    ``halley_step`` — the distributed-MINCE combine (O(1) floats per iter).
    """
    sa = jax.nn.sigmoid(theta[..., None] - alpha)
    sb = jax.nn.sigmoid(beta - theta[..., None])
    if alpha_mask is not None:
        sa = sa * alpha_mask
    if beta_mask is not None:
        sb = sb * beta_mask
    da = sa * (1.0 - sa)
    db = sb * (1.0 - sb)
    f1 = jnp.sum(sa, axis=-1) - jnp.sum(sb, axis=-1)
    f2 = jnp.sum(da, axis=-1) + jnp.sum(db, axis=-1)
    f3 = jnp.sum(da * (1.0 - 2.0 * sa), axis=-1) - \
        jnp.sum(db * (1.0 - 2.0 * sb), axis=-1)
    return f1, f2, f3


def halley_step(f1, f2, f3, solver: str = "halley",
                max_step: float = 10.0, eps: float = 1e-12):
    """One trust-clamped root-finding step from the derivative sums.

    solver: 'halley' (uses f''' — the paper's speedup) or 'newton'. Falls
    back to Newton where the Halley denominator degenerates.
    """
    newton = f1 / (f2 + eps)
    if solver == "halley":
        denom = 2.0 * f2 * f2 - f1 * f3
        halley = 2.0 * f1 * f2 / jnp.where(jnp.abs(denom) < eps, eps, denom)
        step = jnp.where(jnp.abs(denom) < eps, newton, halley)
    else:
        step = newton
    return jnp.clip(step, -max_step, max_step)


@partial(jax.jit, static_argnames=("iters", "solver", "max_step"))
def solve_log_z(alpha: jax.Array, beta: jax.Array, theta0: jax.Array,
                iters: int = 25, solver: str = "halley",
                alpha_mask=None, beta_mask=None,
                max_step: float = 10.0) -> jax.Array:
    """Minimize -J over theta = log Z. Returns theta*, shape = theta0.

    Batched: alpha (..., A), beta (..., B), theta0 (...,) solve every
    leading-axis problem simultaneously (one fused Halley sweep per decode
    batch). Steps are trust-clamped to +-max_step for robustness far from
    the root.
    """
    def body(theta, _):
        f1, f2, f3 = derivative_sums(theta, alpha, beta, alpha_mask,
                                     beta_mask)
        step = halley_step(f1, f2, f3, solver=solver, max_step=max_step)
        return theta - step, jnp.abs(step)

    theta, steps = jax.lax.scan(body, theta0, None, length=iters)
    return theta


def solver_convergence_trace(alpha, beta, theta0, iters=25, solver="halley"):
    """Per-iteration |f'(theta)| trace — used to benchmark Halley vs Newton."""
    def body(theta, _):
        f1, f2, f3 = derivative_sums(theta, alpha, beta, None, None)
        step = halley_step(f1, f2, f3, solver=solver)
        return theta - step, jnp.abs(f1)
    _, trace = jax.lax.scan(body, theta0, None, length=iters)
    return trace
