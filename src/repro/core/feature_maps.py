"""FMBE substrate: Kar-Karnick random feature maps for the exp dot-product kernel.

Paper Eq. 9/10:  phi_j(x) = sqrt(a_M p^{M+1}) prod_{r=1..M} (omega_r . x),
with M ~ Geometric (P[M=m] = p^-(m+1)), omega Rademacher, a_m = 1/m!.

exp(x.y) ~= sum_j phi_j(x) phi_j(y).

We cap M at ``max_degree`` and renormalize the truncated geometric so the
estimator is unbiased for the degree-capped Taylor expansion of exp (the
residual past degree 8 is < 1e-4 for |x.y| <~ 4; documented in DESIGN.md).

Block-partitioned sketch (the bench-scale accuracy fix): besides the global
``lambda_tilde = sum_i phi(v_i)``, the serving build also keeps the per-IVF-
block partial sums ``lambda_blocks[b] = sum_{i in block b} phi(v_i)``
(nb x P floats). The decode hybrid then scores the probed head *exactly* and
asks the sketch only for the complement mass,

    Z_tail_hat(q) = phi(q) . (lambda_tilde - sum_{b probed} lambda_blocks[b]),

so the truncated-Taylor bias and random-feature variance — catastrophic once
scores exceed ~max_degree nats, which is exactly the concentrated regime
where the head matters — are confined to the tail fraction of Z. See
``core.decode.fmbe_decode``.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class FeatureMap(NamedTuple):
    """Random feature map state. All arrays are device-resident."""
    omega: jax.Array      # (P, max_degree, d) Rademacher +-1
    degree: jax.Array     # (P,) int32, sampled M_j in [0, max_degree]
    coef: jax.Array       # (P,) sqrt(a_M / P_hat[M]) / sqrt(P)
    p: float


class FMBEState(NamedTuple):
    fm: FeatureMap
    lambda_tilde: jax.Array   # (P,) = sum_i phi(v_i)
    lambda_blocks: Optional[jax.Array] = None  # (nb, P) per-IVF-block sums


def make_feature_map(key: jax.Array, d: int, n_features: int,
                     max_degree: int = 8, p: float = 2.0,
                     dtype=jnp.float32) -> FeatureMap:
    k_m, k_o = jax.random.split(key)
    # truncated geometric P[M=m] proportional to p^-(m+1), m in [0, max_degree]
    logits = jnp.array([-(m + 1) * math.log(p) for m in range(max_degree + 1)])
    probs = jax.nn.softmax(logits)
    degree = jax.random.categorical(k_m, jnp.log(probs), shape=(n_features,))
    a = jnp.array([1.0 / math.gamma(m + 1) for m in range(max_degree + 1)])
    coef_table = jnp.sqrt(a / probs) / math.sqrt(n_features)
    coef = coef_table[degree].astype(dtype)
    omega = jax.random.rademacher(
        k_o, (n_features, max_degree, d), dtype=dtype)
    return FeatureMap(omega=omega, degree=degree.astype(jnp.int32),
                      coef=coef, p=p)


def apply_feature_map(fm: FeatureMap, x: jax.Array) -> jax.Array:
    """phi(x): x (..., d) -> (..., P)."""
    # proj[..., j, m] = omega[j, m] . x
    proj = jnp.einsum("pmd,...d->...pm", fm.omega, x)
    m_idx = jnp.arange(fm.omega.shape[1])
    mask = m_idx[None, :] < fm.degree[:, None]          # (P, max_degree)
    factors = jnp.where(mask, proj, 1.0)
    prod = jnp.prod(factors, axis=-1)                   # (..., P)
    return prod * fm.coef


def build_fmbe(fm: FeatureMap, v: jax.Array, chunk: int = 2048) -> FMBEState:
    """Precompute lambda_tilde = sum_i phi(v_i) in row chunks (bounded memory)."""
    n, d = v.shape
    pad = (-n) % chunk
    v_pad = jnp.pad(v, ((0, pad), (0, 0)))
    valid = jnp.arange(n + pad) < n
    v_chunks = v_pad.reshape(-1, chunk, d)
    m_chunks = valid.reshape(-1, chunk)

    def body(acc, xs):
        vc, mc = xs
        phi = apply_feature_map(fm, vc)                 # (chunk, P)
        return acc + jnp.sum(phi * mc[:, None], axis=0), None

    init = jnp.zeros((fm.omega.shape[0],), fm.omega.dtype)
    lam, _ = jax.lax.scan(body, init, (v_chunks, m_chunks))
    return FMBEState(fm=fm, lambda_tilde=lam)


def build_fmbe_blocks(fm: FeatureMap, v_blocks: jax.Array,
                      valid: jax.Array) -> jax.Array:
    """Per-IVF-block partial lambdas: (nb, br, d) -> (nb, P).

    One scan over blocks (bounded memory, like ``build_fmbe``); cluster-pad
    rows are masked out. ``lambda_blocks.sum(0) == lambda_tilde`` up to
    float addition order.
    """
    def body(_, xs):
        vb, mb = xs                                   # (br, d), (br,)
        phi = apply_feature_map(fm, vb)               # (br, P)
        return None, jnp.sum(phi * mb[:, None], axis=0)

    _, lam = jax.lax.scan(body, None, (v_blocks, valid.astype(fm.omega.dtype)))
    return lam


def fmbe_tail_z(state: FMBEState, x: jax.Array, probed_blocks: jax.Array,
                use_pallas: bool = False, interpret=None,
                block_q: int = 128, block_p: int = 128) -> jax.Array:
    """Signed sketch estimate of the *complement* mass per query.

    x (Q, d), probed_blocks (Q, p) int32 -> (Q,):
    phi(x_q) . (lambda_tilde - sum_{b in probed_q} lambda_blocks[b]).
    Touches p·P lambda floats per query — independent of V and br.
    """
    assert state.lambda_blocks is not None, \
        "fmbe_tail_z needs a block-partitioned build (build_fmbe_blocks)"
    lam_rest = (state.lambda_tilde[None, :] -
                state.lambda_blocks[probed_blocks].sum(axis=1))   # (Q, P)
    if use_pallas:
        from ..kernels.fmbe import fmbe_z as _fmbe_z
        return _fmbe_z(state.fm.omega, state.fm.degree, state.fm.coef,
                       lam_rest, x, block_q=block_q, block_p=block_p,
                       interpret=interpret)
    phi = apply_feature_map(state.fm, x)               # (Q, P)
    return jnp.sum(phi * lam_rest.astype(phi.dtype), axis=-1)


def fmbe_estimate_z(state: FMBEState, q: jax.Array) -> jax.Array:
    """Z_hat(q) = phi(q) . lambda_tilde.  O(P * max_degree * d).

    NOTE: random-feature estimates can be negative; callers clip when a
    log-domain value is required (the paper reports signed relative error).
    """
    phi_q = apply_feature_map(state.fm, q)
    return jnp.einsum("...p,p->...", phi_q, state.lambda_tilde)


def fmbe_z_batch(state: FMBEState, x: jax.Array,
                 use_pallas: bool = False, interpret=None) -> jax.Array:
    """Batched signed Ẑ for a decode batch: x (Q, d) -> (Q,).

    ``use_pallas`` routes through ``kernels.fmbe.fmbe_z``, which computes the
    degree products tile-by-tile in VMEM — neither the ``(Q, P, max_degree)``
    projection intermediate of ``apply_feature_map`` nor the ``(Q, P)``
    feature matrix ever reaches HBM. The XLA path is the parity reference.
    """
    if use_pallas:
        from ..kernels.fmbe import fmbe_z as _fmbe_z
        return _fmbe_z(state.fm.omega, state.fm.degree, state.fm.coef,
                       state.lambda_tilde, x, interpret=interpret)
    phi = apply_feature_map(state.fm, x)                # (Q, P)
    return phi @ state.lambda_tilde.astype(phi.dtype)
