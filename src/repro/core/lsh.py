"""SimHash/ALSH-MIPS index: the second retrieval structure under the
backend registry (ROADMAP open item 2, Spring & Shrivastava 2017).

Where the block-IVF index routes through learned centroids that k-means must
keep in sync with the drifting embedding, the LSH index routes through K*L
FIXED random hyperplanes: a row's address is its K-bit sign pattern under
each of L tables, and maintenance under churn is an O(1) per-row re-hash +
bucket scatter (``update_rows``) — no Lloyd steps, ever. The price is a
randomized candidate set; the payoff is that the collision event has a
KNOWN analytic probability, which Spring & Shrivastava turn into an
*unbiased* partition-function sampler (``sns_log_z``). Serving instead
reuses the paper's Eq. 5 head/tail combine over the collision head (the
same Rao–Blackwellized form the IVF decodes use — lower variance than
inverse-propensity weighting, and it shares ``combine_head_tail_lse``).

Static-shape doctrine (same zero-recompile discipline as ``pack_ivf``):
bucket tables are fixed-capacity ``(L, 2**K, cap)`` row-id arrays, overflow
rows are *dropped from routing* and recorded in ``slot_of_row`` so the
estimator can exclude them from both the head AND tail-rejection — a
dropped row is simply a tail-population member, so no mass is ever lost
and the estimator stays unbiased under overflow.

The one consistency invariant everything hangs off:

    collide(q, r)  :=  exists table t with codes[r, t] == qcodes[q, t]
                       AND slot_of_row[r, t] >= 0

Head membership, tail rejection, and the training loss's label_in_head all
evaluate exactly this predicate, so every row is counted exactly once.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .decode import DecodeOut, _masked_tail_lse
from .estimators import NEG_INF, combine_head_tail_lse

# Q*V*L ceiling under which lsh_plan computes collisions by broadcast code
# compare instead of bucket scatter (see the strategy note in lsh_plan).
_BCAST_COLLIDE_LIMIT = 1 << 25


class LSHIndex(NamedTuple):
    """Device-resident SimHash MIPS index. All static facts live in shapes
    — no int fields, so the tuple jits/shards/checkpoints like any pytree.

    MIPS augmentation (Shrivastava & Li / Neyshabur & Srebro): rows hash as
    ``[w_r, sqrt(M^2 - |w_r|^2)]`` and queries as ``[h, 0]``, so the cosine
    the sign bits see is ``h.w_r / (|h| M)`` — collision probability is
    monotone in the INNER PRODUCT, and the collision head catches the
    high-score rows regardless of the vocab's norm spread (angle-only
    SimHash misses heavy near-miss rows, which blows up tail variance)."""
    proj: jax.Array         # (L, K, d+1) f32 — fixed random hyperplanes
                            # (last column hits the norm-augmented coord)
    aug_scale: jax.Array    # () f32 — the norm cap M of the augmentation
    tail_scale: jax.Array   # () f32 — tail-proposal temperature tau
    tail_logits: jax.Array  # (V,) f32 — tau * |w_r|, the unnormalized
                            # log-weights of the norm-tempered tail proposal
    codes: jax.Array        # (V, L) int32 — packed K-bit code per table
    buckets: jax.Array      # (L, 2**K, cap) int32 row ids, -1 = empty
    slot_of_row: jax.Array  # (V, L) int32 slot in own bucket, -1 = dropped

    @property
    def n(self) -> int:
        return self.codes.shape[0]

    @property
    def n_tables(self) -> int:
        return self.proj.shape[0]

    @property
    def n_bits(self) -> int:
        return self.proj.shape[1]

    @property
    def n_buckets(self) -> int:
        return self.buckets.shape[1]

    @property
    def bucket_cap(self) -> int:
        return self.buckets.shape[2]


def lsh_bucket_cap(n: int, n_bits: int) -> int:
    """Auto bucket capacity: 4x the uniform-hash expectation, floored at 8
    and rounded up to a multiple of 8 (lane-friendly)."""
    mean = max(1, -(-n // (1 << n_bits)))      # ceil(n / 2**K)
    return max(8, -(-4 * mean // 8) * 8)


def _row_aug(w: jax.Array, aug_scale: jax.Array) -> jax.Array:
    """(V,) augmented coordinate sqrt(max(M^2 - |w_r|^2, 0)) — rows whose
    norm outgrew M between refreshes clamp to 0 (mild distortion until the
    next ``rehash_lsh`` re-fits M)."""
    sq = jnp.sum(w.astype(jnp.float32) ** 2, axis=-1)
    return jnp.sqrt(jnp.clip(aug_scale.astype(jnp.float32) ** 2 - sq, 0.0))


def hash_codes(proj: jax.Array, x: jax.Array,
               aug: Optional[jax.Array] = None) -> jax.Array:
    """Packed SimHash codes for x (N, d) -> (N, L) int32 in [0, 2**K).

    ``proj`` is (L, K, d+1): the last column belongs to the MIPS-augmented
    coordinate — pass its value per row via ``aug`` (index rows), or omit
    it for queries (whose augmented coordinate is identically 0).

    One (N,d)x(d,L*K) matmul, then the K sign bits of each table pack into
    an int via a power-of-two dot — K <= 24 keeps the packed value exact in
    f32, which is what lets the Pallas kernel do the same packing as a
    matmul against a constant (L*K, L) weight."""
    ltab, k, dp = proj.shape
    pm = proj.reshape(ltab * k, dp)
    s = x.astype(jnp.float32) @ pm[:, :x.shape[-1]].T          # (N, L*K)
    if aug is not None:
        s = s + aug.astype(jnp.float32)[:, None] * pm[:, -1][None, :]
    bits = (s > 0).astype(jnp.int32).reshape(-1, ltab, k)
    weights = (1 << jnp.arange(k, dtype=jnp.int32))[None, None, :]
    return (bits * weights).sum(-1).astype(jnp.int32)          # (N, L)


def _pack_one_table(col: jax.Array, n_buckets: int, cap: int):
    """Scatter one table's (V,) codes into a (n_buckets, cap) bucket array
    (-1 = empty) + (V,) slot assignment (-1 = overflow-dropped). Same
    sort/rank scatter idiom as ``mips.pack_ivf``; rows past ``cap`` in a
    bucket are dropped from routing (recorded, not lost — see module doc)."""
    n = col.shape[0]
    sizes = jax.ops.segment_sum(jnp.ones((n,), jnp.int32), col,
                                num_segments=n_buckets)
    start = jnp.cumsum(sizes) - sizes                          # exclusive
    order = jnp.argsort(col, stable=True)
    rank = jnp.arange(n, dtype=jnp.int32) - start[col[order]]
    keep = rank < cap
    tgt = jnp.where(keep, col[order] * cap + rank, n_buckets * cap)
    flat = jnp.full((n_buckets * cap,), -1, jnp.int32)
    flat = flat.at[tgt].set(order.astype(jnp.int32), mode="drop")
    slots = jnp.full((n,), -1, jnp.int32)
    slots = slots.at[order].set(jnp.where(keep, rank, -1))
    return flat.reshape(n_buckets, cap), slots


def _fit_aug_scale(w: jax.Array, mips_scale: float) -> jax.Array:
    """() f32 norm cap M = mips_scale * max row norm.

    M is a *policy*, not just a bound: rows with |w| >= M clamp their
    augmented coordinate to 0 and hash by pure angle (sign bits ignore
    scale), while rows with |w| << M sink toward the augmented pole —
    random codes, usually overflow-dropped, i.e. routed to the tail.
    mips_scale = 0 is exact angle-only SimHash everywhere (classic
    Simple-LSH with M >= max|w| flattens the dominant moderate-norm rows'
    collision odds by |w|/M and wrecks the head — measured, not
    theoretical); small positive values deliberately spend routing
    capacity on the heavy rows only."""
    return mips_scale * jnp.sqrt(jnp.max(jnp.sum(
        w.astype(jnp.float32) ** 2, axis=-1)))


def _fit_tail_scale(w: jax.Array, tail_beta: float) -> jax.Array:
    """() f32 tail-proposal temperature tau = tail_beta / max|w_r|.

    The tail importance-samples rows with p_r ∝ exp(tau * |w_r|): the
    heaviest row is exp(tail_beta * (1 - |w_r|/max)) times likelier than a
    row of norm |w_r|, so a heavy row that escapes the collision head is
    all but guaranteed to be caught by the tail draw — the worst-case
    variance of the head/tail combine collapses from "one uniform sample
    in l must hit it" to "it is sampled every step". tail_beta = 0 is the
    exact uniform tail."""
    mx = jnp.sqrt(jnp.max(jnp.sum(w.astype(jnp.float32) ** 2, axis=-1)))
    return tail_beta / jnp.maximum(mx, 1e-12)


@partial(jax.jit, static_argnames=("bucket_cap",))
def pack_lsh(proj: jax.Array, w: jax.Array, aug_scale: jax.Array,
             tail_scale: jax.Array, *, bucket_cap: int) -> LSHIndex:
    """Hash every row of w (MIPS-augmented), fit the tail-proposal logits,
    and pack the L bucket tables. Jittable, static output shapes — rebuilds
    never retrace downstream consumers."""
    codes = hash_codes(proj, w, aug=_row_aug(w, aug_scale))    # (V, L)
    n_buckets = 1 << proj.shape[1]
    buckets, slots = jax.vmap(
        _pack_one_table, in_axes=(1, None, None), out_axes=(0, 1)
    )(codes, n_buckets, bucket_cap)
    tail_scale = jnp.asarray(tail_scale, jnp.float32)
    norms = jnp.sqrt(jnp.sum(w.astype(jnp.float32) ** 2, axis=-1))
    return LSHIndex(proj=proj, aug_scale=jnp.asarray(aug_scale, jnp.float32),
                    tail_scale=tail_scale, tail_logits=tail_scale * norms,
                    codes=codes, buckets=buckets, slot_of_row=slots)


def build_lsh_device(key: jax.Array, w: jax.Array, *, n_bits: int = 8,
                     n_tables: int = 8, bucket_cap: int = 0,
                     mips_scale: float = 0.0,
                     tail_beta: float = 8.0) -> LSHIndex:
    """Fresh index from an embedding table: draw the L*K hyperplanes once
    (they are NEVER re-drawn — ``rehash_lsh``/``update_rows`` keep them, so
    codes stay comparable across refreshes), fit the MIPS norm cap and the
    tail-proposal temperature, and pack."""
    assert 1 <= n_bits <= 24, "packed codes must stay f32-exact (K <= 24)"
    n, d = w.shape
    if bucket_cap <= 0:
        bucket_cap = lsh_bucket_cap(n, n_bits)
    proj = jax.random.normal(key, (n_tables, n_bits, d + 1), jnp.float32)
    return pack_lsh(proj, w, _fit_aug_scale(w, mips_scale),
                    _fit_tail_scale(w, tail_beta), bucket_cap=bucket_cap)


@jax.jit
def update_rows(index: LSHIndex, w: jax.Array,
                rows: jax.Array) -> LSHIndex:
    """O(1)-per-row index maintenance: re-hash the given rows against the
    CURRENT w and splice them into the bucket tables — remove from the old
    bucket slot, insert at the first free slot of the new bucket. No
    clustering, no repack, O(R * L * cap) total work; shapes are static so
    calling it every step never recompiles. A row that finds its new bucket
    full is dropped from that table's routing (slot -1) — the same
    overflow semantics as a fresh ``pack_lsh``."""
    ltab = index.n_tables
    cap = index.bucket_cap
    t_idx = jnp.arange(ltab, dtype=jnp.int32)

    def step(carry, r):
        codes, buckets, slots, tlog = carry
        wr = w[r][None, :]
        new_c = hash_codes(index.proj, wr,
                           aug=_row_aug(wr, index.aug_scale))[0]   # (L,)
        old_c, old_s = codes[r], slots[r]
        safe_old = jnp.where(old_s >= 0, old_s, cap)
        buckets = buckets.at[t_idx, old_c, safe_old].set(-1, mode="drop")
        rowsets = buckets[t_idx, new_c]                        # (L, cap)
        free = rowsets == -1
        has = free.any(-1)
        slot = jnp.where(has, jnp.argmax(free, axis=-1), -1).astype(jnp.int32)
        buckets = buckets.at[t_idx, new_c,
                             jnp.where(has, slot, cap)].set(r, mode="drop")
        codes = codes.at[r].set(new_c)
        slots = slots.at[r].set(slot)
        tlog = tlog.at[r].set(index.tail_scale
                              * jnp.sqrt(jnp.sum(wr[0] ** 2)))
        return (codes, buckets, slots, tlog), None

    (codes, buckets, slots, tlog), _ = jax.lax.scan(
        step, (index.codes, index.buckets, index.slot_of_row,
               index.tail_logits),
        rows.astype(jnp.int32))
    return index._replace(codes=codes, buckets=buckets, slot_of_row=slots,
                          tail_logits=tlog)


@partial(jax.jit, static_argnames=("mips_scale", "tail_beta"))
def rehash_lsh(index: LSHIndex, w: jax.Array,
               mips_scale: Optional[float] = None,
               tail_beta: Optional[float] = None):
    """Full re-hash against the current w, keeping the hyperplanes — the
    LSH analogue of ``mips.refresh_ivf`` with the same
    ``(index, {"churn", "drift"})`` contract (and no Lloyd steps: this is
    one matmul + L scatter packs). Pass ``mips_scale`` to re-fit the MIPS
    norm cap M to the current w; by default the stored M is kept, matching
    ``update_rows`` (codes stay comparable across refreshes either way).
    churn = fraction of rows whose code changed in any table; drift = mean
    fraction of flipped code bits."""
    aug = (index.aug_scale if mips_scale is None
           else _fit_aug_scale(w, mips_scale))
    tscale = (index.tail_scale if tail_beta is None
              else _fit_tail_scale(w, tail_beta))
    new = pack_lsh(index.proj, w, aug, tscale, bucket_cap=index.bucket_cap)
    diff = index.codes ^ new.codes                             # (V, L)
    churn = jnp.mean(jnp.any(diff != 0, axis=-1).astype(jnp.float32))
    k = index.n_bits
    pop = jnp.zeros(diff.shape, jnp.int32)
    x = diff
    for _ in range(k):
        pop = pop + (x & 1)
        x = x >> 1
    drift = jnp.mean(pop.astype(jnp.float32)) / k
    return new, {"churn": churn, "drift": drift}


# ---------------------------------------------------------------------------
# Collision predicate + probe plan
# ---------------------------------------------------------------------------

def _collide(index: LSHIndex, qcodes: jax.Array,
             rows: jax.Array) -> jax.Array:
    """(Q, R) bool: does row r collide with query q in ANY table where r is
    actually routed (slot >= 0)? The single predicate head membership, tail
    rejection, and label_in_head all share."""
    cc = index.codes[rows]                                     # (R, L)
    ok = index.slot_of_row[rows] >= 0                          # (R, L)
    hit = (qcodes[:, None, :] == cc[None, :, :]) & ok[None, :, :]
    return jnp.any(hit, axis=-1)


class LshPlan(NamedTuple):
    qcodes: jax.Array       # (Q, L)  query codes (post active-donor masking)
    occ_q: jax.Array        # (Q, V)  full collision mask (overflow scoring)
    cand_rows: jax.Array    # (C,)    dedup'd candidate union (pad = 0, dead)
    cand_live: jax.Array    # ()      measured unique candidate count
    member: jax.Array       # (Q, C)  collision membership (live slots only)
    k_eff: jax.Array        # (Q,)    exact |C(q)| — rows colliding with q
    tail_ids: jax.Array     # (l,)    shared tail row ids ~ p (norm-tempered)
    tail_bias: jax.Array    # (l,)    -log(n * p_j): per-sample importance
                            #         bias, added to the sample's score
    tail_accept: jax.Array  # (Q, l)  True where the sample does NOT collide
    n_accept: jax.Array     # (Q,) f32 effective accepted mass
                            #         sum_j accept * exp(tail_bias_j) —
                            #         the Hajek denominator; the plain
                            #         accept COUNT when the proposal is
                            #         uniform (tail_beta = 0)


def lsh_plan(index: LSHIndex, h: jax.Array, key: jax.Array, l: int,
             active: Optional[jax.Array] = None,
             cand_cap: int = 0) -> LshPlan:
    """Hash the batch, union the probed buckets, build the collision head +
    shared rejected tail — the LSH analogue of ``decode.make_plan``.

    The compact union ``cand_rows`` is sized ``resolve_cand_cap(cand_cap)``
    — the static footprint every downstream consumer scores. When the
    measured union overflows it (``cand_live > C``), consumers switch to
    dense scoring over ``occ_q`` via ``_with_trimmed_cands`` (identical
    math; overflow costs wall-clock, never correctness).

    ``active`` masks padded scheduler lanes at the QCODE level (masked rows
    adopt the first live row's codes), so a half-full slot table never
    inflates the candidate union; live rows' plans are untouched."""
    n = index.n
    qcodes = hash_codes(index.proj, h)                         # (Q, L)
    if active is not None:
        donor = qcodes[jnp.argmax(active)]
        qcodes = jnp.where(active[:, None], qcodes, donor[None, :])

    q = h.shape[0]
    ltab = index.n_tables
    capacity = resolve_cand_cap(cand_cap, index, n)
    # PER-QUERY occupancy mask over the vocab: occ_q[i, r] <=> row r sits in
    # one of query i's probed buckets <=> the collision predicate
    # ``_collide`` (buckets only hold validly-routed rows; overflow-dropped
    # rows have slot_of_row == -1 on that table). Everything downstream is
    # O(V)/gather work on this mask. Two bit-identical strategies, chosen
    # by STATIC shapes (no retracing):
    #   * per-table code compare: O(Q*V*L) elementwise SIMD work against the
    #     packed codes — no scatter, runtime independent of K;
    #   * bucket-gather + scatter: O(Q*L*cap) updates — asymptotically
    #     sublinear in V, but scatter serializes on CPU backends (measured
    #     ~60ns/update: it dominated the whole plan at bench scale).
    if q * n * ltab <= _BCAST_COLLIDE_LIMIT:
        # -2 sentinel can never equal a code in [0, 2**K)
        eff_codes = jnp.where(index.slot_of_row >= 0, index.codes, -2)
        occ_q = jnp.zeros((q, n), bool)
        for t in range(ltab):      # 2D compares fuse well; a single 3D
            occ_q = occ_q | (qcodes[:, t:t + 1] == eff_codes[None, :, t])
    else:
        cap = index.bucket_cap
        cand = index.buckets[jnp.arange(ltab)[None, :], qcodes]
        flat = cand.reshape(q, -1)                             # (Q, L*cap)
        safe = jnp.where(flat < 0, n, flat)             # empty slots -> OOB
        qi = jnp.broadcast_to(jnp.arange(q)[:, None], safe.shape)
        occ_q = jnp.zeros((q, n), bool).at[qi, safe].set(True, mode="drop")
    # materialize ONCE: occ_q feeds four reductions/gathers below, and
    # without the barrier XLA re-fuses (recomputes) the producer into every
    # consumer — measured 3x plan wall-clock at bench scale
    occ_q = jax.lax.optimization_barrier(occ_q)
    occ = occ_q.any(0)
    # prefix-sum compaction: ascending unique row ids, zero-padded; rows
    # past ``capacity`` are NOT lost — overflow flips consumers to occ_q.
    # Compaction is a cumsum + SEARCHSORTED gather (the j-th candidate is
    # the first row whose running count reaches j), not jnp.nonzero: the
    # nonzero lowering scatters all V updates serially on CPU — measured
    # 403us vs 69us at V=8k for bit-identical output.
    occ_cs = jnp.cumsum(occ.astype(jnp.int32))
    live = occ_cs[-1]
    j = jnp.arange(1, capacity + 1, dtype=jnp.int32)
    cand_rows = jnp.searchsorted(occ_cs, j, side="left").astype(jnp.int32)
    cand_rows = jnp.where(j <= live, cand_rows, 0)
    slot_live = jnp.arange(capacity) < live
    member = jnp.take(occ_q, cand_rows, axis=1) & slot_live[None, :]
    # occ_q counts exactly q's own collision set, so this is exact |C(q)|
    k_eff = occ_q.sum(-1).astype(jnp.int32)

    # norm-tempered tail: i.i.d. draws from the DEFENSIVE MIXTURE
    # p = 1/2 uniform + 1/2 softmax(tail_logits). The tilted half catches
    # heavy rows that escaped the collision head (the dominant worst-case
    # error); the uniform half keeps every count weight 1/(n p) <= 2, so
    # the Hajek denominator below — which must estimate the SIZE of the
    # tail population, a job a heavy-tilted proposal is terrible at —
    # stays tight. The combine is the Hajek (self-normalized) estimator:
    # per-sample score bias -log(n p_j) plus the matching effective count;
    # at tail_beta = 0 the mixture IS uniform and this reduces exactly to
    # the uniform Rao-Blackwellized ratio.
    logp_all = jnp.logaddexp(jax.nn.log_softmax(index.tail_logits),
                             -jnp.log(float(n))) - jnp.log(2.0)  # (V,)
    # inverse-CDF sampling, NOT jax.random.categorical: categorical draws an
    # (l, V) Gumbel matrix through threefry — measured 142ms vs 209us for
    # the cumsum+searchsorted path at bench scale (V=8k, l=512) on CPU
    cdf = jnp.cumsum(jnp.exp(logp_all))
    u = jax.random.uniform(key, (max(l, 1),)) * cdf[-1]
    tail_ids = jnp.clip(jnp.searchsorted(cdf, u), 0,
                        n - 1)[:l].astype(jnp.int32)
    tail_bias = -(logp_all[tail_ids] + jnp.log(float(n)))      # (l,)
    if l:
        tail_accept = ~jnp.take(occ_q, tail_ids, axis=1)
    else:
        tail_accept = jnp.zeros((q, 0), bool)
    n_accept = jnp.sum(tail_accept * jnp.exp(tail_bias)[None, :], axis=-1)
    return LshPlan(qcodes=qcodes, occ_q=occ_q, cand_rows=cand_rows,
                   cand_live=live, member=member, k_eff=k_eff,
                   tail_ids=tail_ids, tail_bias=tail_bias,
                   tail_accept=tail_accept,
                   n_accept=n_accept.astype(jnp.float32))


def resolve_cand_cap(cand_cap: int, index: LSHIndex, n: int) -> int:
    """0 = auto: twice one query's worst-case bucket pull (L*cap) — decode
    batches share context, so the union dedups toward a single query's
    candidate set. This cap IS the plan's static candidate footprint: it
    keeps the common-case scoring matmul sublinear in V, with the rare
    union overflow handled densely (``_with_trimmed_cands``)."""
    if cand_cap <= 0:
        cand_cap = 2 * index.n_tables * index.bucket_cap
    return min(cand_cap, n)


def _with_trimmed_cands(plan: LshPlan, branch_fn):
    """Run ``branch_fn(cand_rows, member, col_live)`` on the compact union
    when the measured unique count fits its static capacity, else densely on
    every vocab row with ``occ_q`` as the membership mask (identical math,
    static shapes — overflow costs wall-clock, never correctness).
    ``col_live`` counts the valid leading columns of ``cand_rows`` (= the
    full width in the dense branch, where columns are not compacted)."""
    capacity = plan.cand_rows.shape[0]
    n = plan.occ_q.shape[1]
    if capacity >= n:
        return branch_fn(plan.cand_rows, plan.member, plan.cand_live)
    return jax.lax.cond(
        plan.cand_live <= capacity,
        lambda: branch_fn(plan.cand_rows, plan.member, plan.cand_live),
        lambda: branch_fn(jnp.arange(n, dtype=jnp.int32), plan.occ_q,
                          jnp.int32(n)))


# ---------------------------------------------------------------------------
# Batched decode (Eq. 5 combine over the collision head)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("l", "k", "cand_cap", "use_pallas",
                                   "block_q", "cand_tile", "tail_tile",
                                   "interpret"))
def lsh_decode(index: LSHIndex, w: jax.Array, h: jax.Array, key: jax.Array,
               *, l: int, k: int = 1, cand_cap: int = 0,
               use_pallas: bool = False, block_q: int = 128,
               cand_tile: int = 128, tail_tile: int = 32,
               active: Optional[jax.Array] = None,
               interpret=None) -> DecodeOut:
    """Batched sublinear decode through the LSH index: h (Q, d) -> log Ẑ,
    top-k rows, per Eq. 5 with the collision head as S(q).

    The index supplies ROUTING ONLY — candidate/tail rows are always
    gathered from the live ``w``, so serving a drifted embedding between
    refreshes (or training's exact-gradient requirement) needs no embedded
    copy. Embedding bytes touched: U*d (dedup'd candidates) + l*d (tail)
    + L*K*d (hyperplanes), vs V*d exact.
    """
    assert l >= 1, "lsh_decode needs at least one tail sample"
    plan = lsh_plan(index, h, key, l, active=active, cand_cap=cand_cap)
    tail_rows = w[plan.tail_ids].astype(jnp.float32)
    n = index.n

    if use_pallas:
        from ..kernels.lsh_probe import lsh_probe

        def branch(rows, member, col_live):
            del member  # the kernel recomputes membership from codes
            w_cand = w[rows].astype(jnp.float32)
            cand_codes = index.codes[rows]
            cand_ok = (index.slot_of_row[rows] >= 0)
            # counts (Q, C) is dropped here: its width differs between the
            # trimmed and dense cond branches (tests consume it directly)
            return lsh_probe(
                w_cand, h, index.proj, rows, cand_codes, cand_ok,
                col_live, tail_rows, plan.tail_accept, plan.tail_bias,
                k=k, block_q=block_q, cand_tile=cand_tile,
                tail_tile=tail_tile, interpret=interpret)[:4]

        head_lse, tail_lse, topv, topi = _with_trimmed_cands(plan, branch)
    else:
        def branch(rows, member, col_live):
            del col_live       # membership already encodes dead columns
            w_cand = w[rows].astype(jnp.float32)
            stacked = jnp.concatenate([w_cand, tail_rows], axis=0)
            scores = jax.lax.dot_general(
                h, stacked, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
            c = rows.shape[0]
            eff = jnp.where(member, scores[:, :c], NEG_INF)
            head_lse = jax.nn.logsumexp(eff, axis=-1)
            topv, pos = jax.lax.top_k(eff, k)
            topi = rows[pos]                                   # original ids
            tail_lse = _masked_tail_lse(scores[:, c:]
                                        + plan.tail_bias[None, :],
                                        plan.tail_accept)
            return head_lse, tail_lse, topv, topi.astype(jnp.int32)

        head_lse, tail_lse, topv, topi = _with_trimmed_cands(plan, branch)

    log_z = combine_head_tail_lse(
        head_lse, tail_lse,
        (n - plan.k_eff).astype(jnp.float32),
        plan.n_accept.astype(jnp.float32))
    return DecodeOut(log_z=log_z, top_score=topv, top_id=topi,
                     head_lse=head_lse, tail_lse=tail_lse,
                     k_eff=plan.k_eff, head_live=plan.cand_live)


# ---------------------------------------------------------------------------
# Unbiasedness: analytic collision probability (Spring & Shrivastava 2017)
# ---------------------------------------------------------------------------

def collision_log_prob(index: LSHIndex, h: jax.Array,
                       w: jax.Array) -> jax.Array:
    """(Q, V) log P[collide(q, r)] under SimHash: per-bit agreement
    p = 1 - theta/pi, per-table p**K, across L independent tables
    P = 1 - (1 - p**K)**L. Analytic — does not consult the realized
    tables (valid routing estimate only when nothing overflowed).

    theta is the angle in the MIPS-AUGMENTED space: rows hash as
    ``[w_r, sqrt(M^2 - |w_r|^2)]`` (norm M, or |w_r| when it outgrew M and
    the augmented coord clamped to 0) and queries as ``[h, 0]``, so
    cos = h.w_r / (|h| * max(M, |w_r|))."""
    hnorm = jnp.maximum(jnp.linalg.norm(h.astype(jnp.float32), axis=-1,
                                        keepdims=True), 1e-12)
    wnorm = jnp.linalg.norm(w.astype(jnp.float32), axis=-1)    # (V,)
    denom = jnp.maximum(jnp.maximum(index.aug_scale, wnorm), 1e-12)
    ip = h.astype(jnp.float32) @ w.astype(jnp.float32).T       # (Q, V)
    cos = jnp.clip(ip / (hnorm * denom[None, :]), -1.0, 1.0)
    p_bit = jnp.clip(1.0 - jnp.arccos(cos) / jnp.pi, 1e-9, 1.0 - 1e-9)
    p_tab = index.n_bits * jnp.log(p_bit)                      # log p**K
    return jnp.log1p(-jnp.exp(
        index.n_tables * jnp.log1p(-jnp.exp(p_tab))))


def sns_log_z(index: LSHIndex, w: jax.Array, h: jax.Array) -> jax.Array:
    """Spring & Shrivastava's unbiased sampled-softmax partition estimate:
    Ẑ(q) = sum_{r in C(q)} e^{s_r} / P[collide(q, r)], where C(q) is the
    realized collision set. Unbiased over the hyperplane draw because
    E[1{collide}] = P. O(V*L) compare + O(V*d) scores — an accuracy-study
    tool (tests/docs), not a serving path; serving uses the lower-variance
    Eq. 5 combine in ``lsh_decode``."""
    qcodes = hash_codes(index.proj, h)
    member = _collide(index, qcodes, jnp.arange(index.n))      # (Q, V)
    s = (h.astype(jnp.float32) @ w.T.astype(jnp.float32))
    logp = collision_log_prob(index, h, w)
    return jax.nn.logsumexp(jnp.where(member, s - logp, NEG_INF), axis=-1)
