"""Mamba2 (SSD) block — the Zamba2 hybrid's backbone.

Structure (arXiv:2405.21060 as used by Zamba2): separate z/x/BC/dt
projections (separate — not packed — so tensor-parallel sharding of d_inner
never splits across semantic boundaries), depthwise causal conv over time on
(x, B, C), scalar-per-head decay a_t = exp(-dt_t * exp(A_log)), state update

    h_t = a_t h_{t-1} + dt_t * (x_t outer B_t)      h: (B, H, P, N)
    y_t = C_t . h_t + D x_t

Sequential lax.scan over time for train/prefill (the chunked SSD form is a
perf optimization tracked in EXPERIMENTS.md); O(1)-state decode step.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from .layers import _dense_init, init_rmsnorm, rmsnorm

Params = Dict[str, Any]


def _dims(cfg):
    ssm = cfg.ssm
    d_inner = ssm.expand * cfg.d_model
    head_p = 64
    n_heads = d_inner // head_p
    return d_inner, n_heads, head_p, ssm.state_dim, ssm.conv_dim


def init_mamba_block(key, cfg) -> Params:
    d = cfg.d_model
    d_inner, n_h, p_dim, n_state, conv = _dims(cfg)
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    return {
        "wz": _dense_init(ks[0], (d, d_inner), dt),
        "wx": _dense_init(ks[1], (d, d_inner), dt),
        "wbc": _dense_init(ks[2], (d, 2 * n_state), dt),
        "wdt": _dense_init(ks[3], (d, n_h), dt),
        "conv_x_w": (jax.random.normal(ks[4], (conv, d_inner))
                     * 0.1).astype(dt),
        "conv_x_b": jnp.zeros((d_inner,), dt),
        "conv_bc_w": (jax.random.normal(ks[5], (conv, 2 * n_state))
                      * 0.1).astype(dt),
        "conv_bc_b": jnp.zeros((2 * n_state,), dt),
        "a_log": jnp.zeros((n_h,), jnp.float32),
        "d_skip": jnp.ones((n_h,), dt),
        "dt_bias": jnp.zeros((n_h,), dt),
        "norm": init_rmsnorm(d_inner, dt),
        "out_proj": _dense_init(ks[6], (d_inner, d), dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array, state=None):
    """Depthwise causal conv over time. x (B, S, C), w (K, C).

    state (B, K-1, C) carries the last K-1 inputs for decode continuity.
    Returns (y (B, S, C), new_state)."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else state
    return jax.nn.silu(out + b), new_state


def mamba_block(p: Params, x: jax.Array, cfg, state=None):
    """x (B, S, d) -> (out, new_state).

    state: {'conv_x', 'conv_bc', 'ssm'} or None (train/prefill from zeros).
    """
    b, s, d = x.shape
    d_inner, n_h, p_dim, n_state, conv = _dims(cfg)
    z = x @ p["wz"]
    xin = x @ p["wx"]
    bc = x @ p["wbc"]
    dt_raw = x @ p["wdt"]
    cx = state["conv_x"] if state is not None else None
    cb = state["conv_bc"] if state is not None else None
    xconv, new_cx = _causal_conv(xin, p["conv_x_w"], p["conv_x_b"], cx)
    bcconv, new_cb = _causal_conv(bc, p["conv_bc_w"], p["conv_bc_b"], cb)
    xc = xconv.reshape(b, s, n_h, p_dim)
    b_in = bcconv[..., :n_state]                             # (B,S,N)
    c_in = bcconv[..., n_state:]                             # (B,S,N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))   # (B,S,H)
    a = jnp.exp(-dt * jnp.exp(p["a_log"]))                   # (B,S,H)

    ssm0 = (state["ssm"] if state is not None else
            jnp.zeros((b, n_h, p_dim, n_state), jnp.float32))

    def step(h, xs):
        xt, bt, ct, at, dtt = xs
        upd = jnp.einsum("bhp,bn->bhpn", (dtt[..., None] * xt), bt)
        h_new = h * at[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", h_new, ct)
        return h_new, y

    xs = (jnp.moveaxis(xc.astype(jnp.float32), 1, 0),
          jnp.moveaxis(b_in.astype(jnp.float32), 1, 0),
          jnp.moveaxis(c_in.astype(jnp.float32), 1, 0),
          jnp.moveaxis(a, 1, 0),
          jnp.moveaxis(dt, 1, 0))
    h_final, ys = jax.lax.scan(jax.checkpoint(step), ssm0, xs)
    y = jnp.moveaxis(ys, 0, 1)                               # (B,S,H,P)
    y = y + xc.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[:, None]
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = rmsnorm(p["norm"], y) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    return out, {"conv_x": new_cx, "conv_bc": new_cb, "ssm": h_final}


def init_mamba_state(batch: int, cfg, dtype):
    d_inner, n_h, p_dim, n_state, conv = _dims(cfg)
    return {
        "conv_x": jnp.zeros((batch, conv - 1, d_inner), dtype),
        "conv_bc": jnp.zeros((batch, conv - 1, 2 * n_state), dtype),
        "ssm": jnp.zeros((batch, n_h, p_dim, n_state), jnp.float32),
    }
