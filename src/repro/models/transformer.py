"""Model assembly for all assigned architectures.

Layer stacks are ``lax.scan`` over parameter pytrees stacked on a leading
axis (HLO size O(1) in depth — see DESIGN.md SS7). Heterogeneous patterns use
*grouped* scans whose body unrolls the group members:

  gemma3-4b   : scan over 5 groups of [5 local + 1 global] + tail of 4 local
  llama-vision: scan over 20 groups of [4 self + 1 cross]
  zamba2-7b   : scan over 13 groups of [6 mamba] + shared attn block (single
                weight copy, closure) + tail of 3 mamba
  others      : one homogeneous scanned stack

Decode states are pytrees stacked the same way as their stacks, so the same
scan drives the cached path.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .attention import (KVCache, cross_attention, decode_self_attention,
                        init_attention, self_attention)
from .layers import (embed, init_embedding, init_mlp, init_rmsnorm, mlp,
                     rmsnorm, _dense_init)
from .mamba import init_mamba_block, init_mamba_state, mamba_block
from .moe import init_moe, moe_block
from .rwkv import RWKVState, init_rwkv_block, rwkv_block

Params = Dict[str, Any]


def _split_init(fn, key, n):
    """Stack n inits on a leading axis (vmap keeps this eval_shape-able)."""
    return jax.vmap(fn)(jax.random.split(key, n))


def _add_aux(a: Dict, b: Dict) -> Dict:
    out = dict(a)
    for k, v in b.items():
        out[k] = out.get(k, 0.0) + v
    return out


ZERO_AUX = {"moe_balance": 0.0, "moe_zloss": 0.0, "moe_drop_frac": 0.0}


# ---------------------------------------------------------------------------
# Transformer block (attention + FFN/MoE)
# ---------------------------------------------------------------------------

def init_tblock(key, cfg: ModelConfig, kind: str = "dense",
                cross: bool = False) -> Params:
    k1, k2 = jax.random.split(key)
    dt = jnp.dtype(cfg.dtype)
    p = {
        "ln1": init_rmsnorm(cfg.d_model, dt),
        "attn": init_attention(k1, cfg, cross=cross),
        "ln2": init_rmsnorm(cfg.d_model, dt),
    }
    if kind == "moe":
        p["ffn"] = init_moe(k2, cfg)
    else:
        p["ffn"] = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.act, dt)
    return p


def tblock_fwd(p: Params, x, cfg, *, kind="dense", window=0):
    h = self_attention(p["attn"], rmsnorm(p["ln1"], x, cfg.norm_eps), cfg,
                       window=window)
    x = x + h
    y = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if kind == "moe":
        f, aux = moe_block(p["ffn"], y, cfg)
    else:
        f, aux = mlp(p["ffn"], y, cfg.act), ZERO_AUX
    return x + f, aux


def tblock_decode(p: Params, x, cache: KVCache, pos, cfg, *, kind="dense",
                  window=0):
    h, cache = decode_self_attention(
        p["attn"], rmsnorm(p["ln1"], x, cfg.norm_eps), cache, pos, cfg,
        window=window)
    x = x + h
    y = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if kind == "moe":
        f, _ = moe_block(p["ffn"], y, cfg)
    else:
        f = mlp(p["ffn"], y, cfg.act)
    return x + f, cache


def cross_block_fwd(p: Params, x, img, cfg):
    x = x + cross_attention(p["attn"], rmsnorm(p["ln1"], x, cfg.norm_eps),
                            img, cfg)
    return x + mlp(p["ffn"], rmsnorm(p["ln2"], x, cfg.norm_eps), cfg.act)


# ---------------------------------------------------------------------------
# Family plans
# ---------------------------------------------------------------------------

def _gemma_plan(cfg):
    """(n_groups, locals_per_group, tail_locals)."""
    r = cfg.local_global_ratio                       # 5 locals : 1 global
    group = r + 1
    n_groups = cfg.n_layers // group
    tail = cfg.n_layers - n_groups * group
    return n_groups, r, tail


def _vlm_plan(cfg):
    group = cfg.cross_attn_every                     # 4 self + 1 cross
    n_groups = cfg.n_layers // group
    assert n_groups * group == cfg.n_layers, "vlm layers must divide evenly"
    return n_groups, group - 1


def _hybrid_plan(cfg):
    group = cfg.shared_attn_every
    n_groups = cfg.n_layers // group
    tail = cfg.n_layers - n_groups * group
    return n_groups, group, tail


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

class Model:
    """Functional model wrapper for one ModelConfig."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- init ---------------------------------------------------------------

    def init(self, key) -> Params:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        ks = jax.random.split(key, 8)
        p: Params = {}
        if cfg.n_codebooks:
            p["embed"] = {"table": _dense_init(
                ks[0], (cfg.n_codebooks, cfg.vocab, cfg.d_model), dt,
                scale=1.0)}
        else:
            p["embed"] = init_embedding(ks[0], cfg.vocab, cfg.d_model, dt)
        p["final_norm"] = init_rmsnorm(cfg.d_model, dt)
        if not cfg.tie_embeddings:
            if cfg.n_codebooks:
                p["lm_head"] = _dense_init(
                    ks[1], (cfg.n_codebooks, cfg.vocab, cfg.d_model), dt)
            else:
                p["lm_head"] = _dense_init(
                    ks[1], (cfg.vocab, cfg.d_model), dt)

        fam = cfg.family
        if fam in ("dense", "audio") and not cfg.local_global_ratio:
            p["blocks"] = _split_init(
                lambda k: init_tblock(k, cfg), ks[2], cfg.n_layers)
        elif cfg.local_global_ratio:                  # gemma3
            g, r, tail = _gemma_plan(cfg)
            p["local_groups"] = _split_init(
                lambda k: _split_init(lambda k2: init_tblock(k2, cfg), k, r),
                ks[2], g)
            p["global_groups"] = _split_init(
                lambda k: init_tblock(k, cfg), ks[3], g)
            if tail:
                p["local_tail"] = _split_init(
                    lambda k: init_tblock(k, cfg), ks[4], tail)
        elif fam == "vlm":
            g, n_self = _vlm_plan(cfg)
            p["self_groups"] = _split_init(
                lambda k: _split_init(lambda k2: init_tblock(k2, cfg), k,
                                      n_self), ks[2], g)
            p["cross_groups"] = _split_init(
                lambda k: init_tblock(k, cfg, cross=True), ks[3], g)
        elif fam == "moe":
            p["blocks"] = _split_init(
                lambda k: init_tblock(k, cfg, kind="moe"), ks[2],
                cfg.n_layers)
        elif fam == "ssm":
            p["blocks"] = _split_init(
                lambda k: init_rwkv_block(k, cfg), ks[2], cfg.n_layers)
        elif fam == "hybrid":
            g, per, tail = _hybrid_plan(cfg)
            p["mamba_groups"] = _split_init(
                lambda k: _split_init(lambda k2: init_mamba_block(k2, cfg),
                                      k, per), ks[2], g)
            p["shared_attn"] = init_tblock(ks[3], cfg)   # ONE weight copy
            if tail:
                p["mamba_tail"] = _split_init(
                    lambda k: init_mamba_block(k, cfg), ks[4], tail)
        else:
            raise ValueError(f"unknown family {fam}")
        return p

    # -- embedding / head ----------------------------------------------------

    def embed_tokens(self, p: Params, tokens):
        cfg = self.cfg
        if cfg.n_codebooks:
            # tokens (B, S, n_codebooks) -> sum of per-codebook embeddings
            tabs = p["embed"]["table"]                    # (C, V, d)
            outs = [jnp.take(tabs[c], tokens[..., c], axis=0)
                    for c in range(cfg.n_codebooks)]
            return sum(outs)
        return embed(p["embed"], tokens)

    def head_matrix(self, p: Params):
        cfg = self.cfg
        if cfg.tie_embeddings:
            return p["embed"]["table"]
        return p["lm_head"]

    def logits(self, p: Params, hidden):
        """Full logits — small-vocab path / tests only (O(T V) memory)."""
        w = self.head_matrix(p)
        if self.cfg.n_codebooks:
            return jnp.einsum("...d,cvd->...cv", hidden, w)
        return hidden @ w.T

    # -- full-sequence forward (train / prefill) -----------------------------

    def forward(self, p: Params, tokens, *, img=None) -> Tuple[Any, Dict]:
        """tokens (B, S[, C]) -> (hidden (B, S, d), aux)."""
        cfg = self.cfg
        x = self.embed_tokens(p, tokens)
        remat = cfg.remat != "none"

        def ck(f):
            return jax.checkpoint(f) if remat else f

        aux = ZERO_AUX
        fam = cfg.family
        if fam in ("dense", "audio") and not cfg.local_global_ratio:
            body = ck(lambda px, x_: tblock_fwd(
                px, x_, cfg, window=cfg.sliding_window))

            def f(carry, px):
                x_, a_ = carry
                x2, a2 = body(px, x_)
                return (x2, _add_aux(a_, a2)), None
            (x, aux), _ = jax.lax.scan(f, (x, aux), p["blocks"])
        elif cfg.local_global_ratio:
            g, r, tail = _gemma_plan(cfg)

            def group_fn(pg):
                loc, glob = pg

                def f(x_):
                    for i in range(r):
                        x_, _ = tblock_fwd(
                            jax.tree.map(lambda t: t[i], loc), x_, cfg,
                            window=cfg.sliding_window)
                    x_, _ = tblock_fwd(glob, x_, cfg, window=0)
                    return x_
                return f

            def f(x_, pg):
                return ck(group_fn(pg))(x_), None
            x, _ = jax.lax.scan(
                f, x, (p["local_groups"], p["global_groups"]))
            if tail:
                def ft(x_, px):
                    return ck(lambda x2: tblock_fwd(
                        px, x2, cfg, window=cfg.sliding_window)[0])(x_), None
                x, _ = jax.lax.scan(ft, x, p["local_tail"])
        elif fam == "vlm":
            g, n_self = _vlm_plan(cfg)

            def f(x_, pg):
                selfs, crossp = pg

                def body_(x2):
                    for i in range(n_self):
                        x2, _ = tblock_fwd(
                            jax.tree.map(lambda t: t[i], selfs), x2, cfg)
                    return cross_block_fwd(crossp, x2, img, cfg)
                return ck(body_)(x_), None
            x, _ = jax.lax.scan(f, x, (p["self_groups"], p["cross_groups"]))
        elif fam == "moe":
            body = ck(lambda px, x_: tblock_fwd(px, x_, cfg, kind="moe"))

            def f(carry, px):
                x_, a_ = carry
                x2, a2 = body(px, x_)
                return (x2, _add_aux(a_, a2)), None
            (x, aux), _ = jax.lax.scan(f, (x, aux), p["blocks"])
        elif fam == "ssm":
            body = ck(lambda px, x_: rwkv_block(px, x_, cfg)[0])

            def f(x_, px):
                return body(px, x_), None
            x, _ = jax.lax.scan(f, x, p["blocks"])
        elif fam == "hybrid":
            g, per, tail = _hybrid_plan(cfg)
            shared = p["shared_attn"]

            def f(x_, pg):
                def body_(x2):
                    for i in range(per):
                        x2, _ = mamba_block(
                            jax.tree.map(lambda t: t[i], pg), x2, cfg)
                    x2, _ = tblock_fwd(shared, x2, cfg)
                    return x2
                return ck(body_)(x_), None
            x, _ = jax.lax.scan(f, x, p["mamba_groups"])
            if tail:
                def ft(x_, px):
                    return ck(lambda x2: mamba_block(px, x2, cfg)[0])(x_), None
                x, _ = jax.lax.scan(ft, x, p["mamba_tail"])
        else:
            raise ValueError(fam)

        return rmsnorm(p["final_norm"], x, cfg.norm_eps), aux

    # -- decode --------------------------------------------------------------

    def init_decode_state(self, batch: int, max_len: int) -> Any:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        hd, nkv = cfg.resolved_head_dim, cfg.n_kv_heads

        def kv(n, length):
            shape = (n, batch, length, nkv, hd)
            return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}

        fam = cfg.family
        if fam in ("dense", "audio") and not cfg.local_global_ratio:
            length = min(max_len, cfg.sliding_window) if cfg.sliding_window \
                else max_len
            return {"kv": kv(cfg.n_layers, length)}
        if cfg.local_global_ratio:
            g, r, tail = _gemma_plan(cfg)
            w = min(max_len, cfg.sliding_window)
            st = {"local": {"k": jnp.zeros((g, r, batch, w, nkv, hd), dt),
                            "v": jnp.zeros((g, r, batch, w, nkv, hd), dt)},
                  "global": kv(g, max_len)}
            if tail:
                st["tail"] = kv(tail, w)
            return st
        if fam == "vlm":
            g, n_self = _vlm_plan(cfg)
            return {"self": {"k": jnp.zeros((g, n_self, batch, max_len, nkv,
                                             hd), dt),
                             "v": jnp.zeros((g, n_self, batch, max_len, nkv,
                                             hd), dt)}}
        if fam == "moe":
            return {"kv": kv(cfg.n_layers, max_len)}
        if fam == "ssm":
            s0 = RWKVState.init(batch, cfg, dt)
            return {"rwkv": jax.tree.map(
                lambda t: jnp.broadcast_to(
                    t[None], (cfg.n_layers,) + t.shape), s0)}
        if fam == "hybrid":
            g, per, tail = _hybrid_plan(cfg)
            m0 = init_mamba_state(batch, cfg, dt)
            st = {"mamba": jax.tree.map(
                lambda t: jnp.broadcast_to(t[None, None],
                                           (g, per) + t.shape), m0),
                  "shared_kv": kv(g, max_len)}
            if tail:
                st["mamba_tail"] = jax.tree.map(
                    lambda t: jnp.broadcast_to(t[None],
                                               (tail,) + t.shape), m0)
            return st
        raise ValueError(fam)

    def decode_step(self, p: Params, state, token, pos, *, img=None):
        """token (B,) or (B, C) -> (hidden_last (B, d), new_state)."""
        cfg = self.cfg
        tok = token[:, None] if not cfg.n_codebooks else token[:, None, :]
        x = self.embed_tokens(p, tok)                      # (B, 1, d)
        fam = cfg.family

        def as_cache(st):
            return KVCache(k=st["k"], v=st["v"])

        if fam in ("dense", "audio", "moe") and not cfg.local_global_ratio:
            kind = "moe" if fam == "moe" else "dense"
            win = cfg.sliding_window

            def f(x_, xs):
                px, st = xs
                x2, c = tblock_decode(px, x_, as_cache(st), pos, cfg,
                                      kind=kind, window=win)
                return x2, {"k": c.k, "v": c.v}
            x, new_kv = jax.lax.scan(f, x, (p["blocks"], state["kv"]))
            new_state = {"kv": new_kv}
        elif cfg.local_global_ratio:
            g, r, tail = _gemma_plan(cfg)
            w = cfg.sliding_window

            def f(x_, xs):
                loc, glob, lst, gst = xs
                new_l = []
                for i in range(r):
                    x_, c = tblock_decode(
                        jax.tree.map(lambda t: t[i], loc), x_,
                        as_cache(jax.tree.map(lambda t: t[i], lst)), pos,
                        cfg, window=w)
                    new_l.append({"k": c.k, "v": c.v})
                x_, cg = tblock_decode(glob, x_, as_cache(gst), pos, cfg,
                                       window=0)
                stack = jax.tree.map(lambda *ts: jnp.stack(ts), *new_l)
                return x_, (stack, {"k": cg.k, "v": cg.v})
            x, (new_local, new_global) = jax.lax.scan(
                f, x, (p["local_groups"], p["global_groups"],
                       state["local"], state["global"]))
            new_state = {"local": new_local, "global": new_global}
            if tail:
                def ft(x_, xs):
                    px, st = xs
                    x2, c = tblock_decode(px, x_, as_cache(st), pos, cfg,
                                          window=w)
                    return x2, {"k": c.k, "v": c.v}
                x, new_tail = jax.lax.scan(
                    ft, x, (p["local_tail"], state["tail"]))
                new_state["tail"] = new_tail
        elif fam == "vlm":
            g, n_self = _vlm_plan(cfg)

            def f(x_, xs):
                selfs, crossp, st = xs
                new_s = []
                for i in range(n_self):
                    x_, c = tblock_decode(
                        jax.tree.map(lambda t: t[i], selfs), x_,
                        as_cache(jax.tree.map(lambda t: t[i], st)), pos, cfg)
                    new_s.append({"k": c.k, "v": c.v})
                x_ = cross_block_fwd(crossp, x_, img, cfg)
                return x_, jax.tree.map(lambda *ts: jnp.stack(ts), *new_s)
            x, new_self = jax.lax.scan(
                f, x, (p["self_groups"], p["cross_groups"], state["self"]))
            new_state = {"self": new_self}
        elif fam == "ssm":
            def f(x_, xs):
                px, st = xs
                x2, st2 = rwkv_block(px, x_, cfg, st)
                return x2, st2
            x, new_rwkv = jax.lax.scan(f, x, (p["blocks"], state["rwkv"]))
            new_state = {"rwkv": new_rwkv}
        elif fam == "hybrid":
            g, per, tail = _hybrid_plan(cfg)
            shared = p["shared_attn"]

            def f(x_, xs):
                pg, mst, kst = xs
                new_m = []
                for i in range(per):
                    x_, s2 = mamba_block(
                        jax.tree.map(lambda t: t[i], pg), x_, cfg,
                        jax.tree.map(lambda t: t[i], mst))
                    new_m.append(s2)
                x_, c = tblock_decode(shared, x_, as_cache(kst), pos, cfg)
                return x_, (jax.tree.map(lambda *ts: jnp.stack(ts), *new_m),
                            {"k": c.k, "v": c.v})
            x, (new_mamba, new_shared) = jax.lax.scan(
                f, x, (p["mamba_groups"], state["mamba"],
                       state["shared_kv"]))
            new_state = {"mamba": new_mamba, "shared_kv": new_shared}
            if tail:
                def ft(x_, xs):
                    px, st = xs
                    x2, s2 = mamba_block(px, x_, cfg, st)
                    return x2, s2
                x, new_tail = jax.lax.scan(
                    ft, x, (p["mamba_tail"], state["mamba_tail"]))
                new_state["mamba_tail"] = new_tail
        else:
            raise ValueError(fam)

        h = rmsnorm(p["final_norm"], x, cfg.norm_eps)
        return h[:, 0], new_state
