"""Model zoo: scan-stacked transformer families + the paper's LBL."""
from .transformer import Model
from . import lbl

__all__ = ["Model", "lbl"]
