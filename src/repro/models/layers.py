"""Shared neural-net layers: norms, MLPs, embeddings, RoPE.

Pure-functional style: ``init_*`` returns a params pytree (nested dicts of
arrays); ``apply`` functions are free of global state so everything is
scannable / pjit-friendly and ``jax.eval_shape``-able (the dry run never
allocates real weights).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def _dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs: gated (silu/gelu) and squared-ReLU (nemotron)
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, d_ff: int, act: str, dtype) -> Params:
    ks = jax.random.split(key, 3)
    p = {"down": _dense_init(ks[2], (d_ff, d), dtype)}
    if act == "sqrelu":
        p["up"] = _dense_init(ks[0], (d, d_ff), dtype)
    else:
        p["gate"] = _dense_init(ks[0], (d, d_ff), dtype)
        p["up"] = _dense_init(ks[1], (d, d_ff), dtype)
    return p


def mlp(p: Params, x: jax.Array, act: str) -> jax.Array:
    if act == "sqrelu":
        h = jnp.square(jax.nn.relu(x @ p["up"]))
    else:
        a = x @ p["gate"]
        a = jax.nn.silu(a) if act == "silu" else jax.nn.gelu(a)
        h = a * (x @ p["up"])
    return h @ p["down"]


# ---------------------------------------------------------------------------
# Embedding + output head
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int, dtype) -> Params:
    return {"table": _dense_init(key, (vocab, d), dtype, scale=1.0)}


def embed(p: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                     / head_dim)


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float) -> jax.Array:
    """x: (..., S, H, Dh), positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, Dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
