"""RWKV6 "Finch" block: token-shift time mix with data-dependent decay +
squared-ReLU channel mix. Attention-free; per-head (head_size x head_size)
state makes decode O(1) in context — this arch runs the long_500k shape.

Faithful structure (arXiv:2404.05892): r/k/v/g/w projections of
token-shift-interpolated inputs, LoRA-parameterized data-dependent decay
w_t = exp(-exp(w0 + lora(x_t))), bonus `u` for the current token, recurrence

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

Train/prefill run the recurrence with lax.scan over time; decode is one step.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import _dense_init, rmsnorm, init_rmsnorm

Params = Dict[str, Any]


def _heads(cfg):
    hs = (cfg.ssm.wkv_head_size if cfg.ssm else 64)
    return cfg.d_model // hs, hs


def init_rwkv_block(key, cfg) -> Params:
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    lora = 64
    ks = jax.random.split(key, 12)
    n_h, hs = _heads(cfg)
    return {
        "mix": {  # token-shift interpolation weights per stream
            "mu": (jax.random.uniform(ks[0], (5, d)) * 0.5 + 0.25).astype(dt),
            "wr": _dense_init(ks[1], (d, d), dt),
            "wk": _dense_init(ks[2], (d, d), dt),
            "wv": _dense_init(ks[3], (d, d), dt),
            "wg": _dense_init(ks[4], (d, d), dt),
            "wo": _dense_init(ks[5], (d, d), dt),
            "decay_w0": jnp.full((d,), -6.0, dt),
            "decay_a": _dense_init(ks[6], (d, lora), dt),
            "decay_b": _dense_init(ks[7], (lora, d), dt),
            "bonus_u": (jax.random.normal(ks[8], (n_h, hs)) * 0.1).astype(dt),
            "ln_x": init_rmsnorm(d, dt),
        },
        "cmix": {  # channel mix
            "mu": (jax.random.uniform(ks[9], (2, d)) * 0.5 + 0.25).astype(dt),
            "wk": _dense_init(ks[10], (d, cfg.d_ff), dt),
            "wv": _dense_init(ks[11], (cfg.d_ff, d), dt),
            "wr": _dense_init(jax.random.fold_in(key, 99), (d, d), dt),
        },
    }


def _token_shift(x: jax.Array, x_last: jax.Array) -> jax.Array:
    """shift right by one along time; x_last fills position 0."""
    return jnp.concatenate([x_last[:, None], x[:, :-1]], axis=1)


def _constrain_heads(x, spec):
    """Pin the head dim to the 'model' axis if a mesh is ambient — without
    this the wkv scan's sharding fixpoint resolves replicated and GSPMD
    all-gathers r/k/v/w before the loop (measured 240 GB/step at (16,16))."""
    try:
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:                                      # no mesh (tests)
        return x


def wkv_scan(r, k, v, w, u, state0):
    """Recurrence over time. r/k/v (B, S, H, hs), w (B, S, H, hs) decay in
    (0,1), u (H, hs); state (B, H, hs, hs). Returns (out (B,S,H,hs), state).

    NOTE(perf log): forcing head-sharding on r/k/v/w + state with
    _constrain_heads was tried and REFUTED — collectives went 912 -> 1325
    GB/step at (16,16) because the backward then reshards every stream per
    microbatch. GSPMD's replicated fixpoint for the tiny (B,H,hs,hs) state
    is the cheaper global solution; see EXPERIMENTS.md SSPerf."""
    def step(s, xs):
        rt, kt, vt, wt = xs                       # (B, H, hs)
        # r/k/v arrive bf16 (transport + their cotangent collectives run at
        # half width); state math is f32 for stability over long horizons.
        rt = rt.astype(jnp.float32)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt,
                        preferred_element_type=jnp.float32)
        out = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s_new = s * wt[..., None] + kv
        return s_new, out

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    state, out = jax.lax.scan(jax.checkpoint(step), state0, xs)
    return jnp.moveaxis(out, 0, 1), state


def rwkv_time_mix(p: Params, x: jax.Array, cfg, x_last, state0
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x (B, S, d). Returns (out, new_x_last, new_state)."""
    n_h, hs = _heads(cfg)
    b, s, d = x.shape
    xs = _token_shift(x, x_last)
    mu = p["mu"]
    xr, xk, xv, xg, xw = (x + (xs - x) * mu[i] for i in range(5))
    r = (xr @ p["wr"]).reshape(b, s, n_h, hs)
    k = (xk @ p["wk"]).reshape(b, s, n_h, hs)
    v = (xv @ p["wv"]).reshape(b, s, n_h, hs)
    g = jax.nn.silu(xg @ p["wg"])
    # data-dependent decay (the Finch hallmark)
    decay = p["decay_w0"] + (xw @ p["decay_a"]) @ p["decay_b"]
    w = jnp.exp(-jnp.exp(decay.astype(jnp.float32))).reshape(b, s, n_h, hs)
    out, state = wkv_scan(r, k, v, w,               # bf16 transport
                          p["bonus_u"].astype(jnp.float32),
                          state0)
    # per-head group normalization (RWKV6's GroupNorm(n_heads)) — head-local,
    # so the whole time-mix shards on heads with a single all-reduce at wo
    # (a full-d rmsnorm here forced cross-head stats + activation gathers).
    var = jnp.mean(jnp.square(out), axis=-1, keepdims=True)
    out = out * jax.lax.rsqrt(var + 1e-5)
    out = out * p["ln_x"]["scale"].astype(jnp.float32).reshape(n_h, hs)
    out = out.reshape(b, s, d).astype(x.dtype) * g
    return out @ p["wo"], x[:, -1], state


def rwkv_channel_mix(p: Params, x: jax.Array, x_last):
    xs = _token_shift(x, x_last)
    mu = p["mu"]
    xk = x + (xs - x) * mu[0]
    xr = x + (xs - x) * mu[1]
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return (k @ p["wv"]) * jax.nn.sigmoid(xr @ p["wr"]), x[:, -1]


class RWKVState:
    """Decode-time state per layer: (x_last_tm, x_last_cm, wkv_state)."""

    @staticmethod
    def init(batch: int, cfg, dtype):
        n_h, hs = _heads(cfg)
        return {
            "tm_last": jnp.zeros((batch, cfg.d_model), dtype),
            "cm_last": jnp.zeros((batch, cfg.d_model), dtype),
            "wkv": jnp.zeros((batch, n_h, hs, hs), jnp.float32),
        }


def rwkv_block(p: Params, x: jax.Array, cfg, state=None):
    """Full block (pre-norm residual). x (B, S, d).

    state=None -> zeros (training); else decode-style carry-through.
    """
    b = x.shape[0]
    if state is None:
        state = RWKVState.init(b, cfg, x.dtype)
    tm_out, tm_last, wkv = rwkv_time_mix(
        p["mix"], x, cfg, state["tm_last"], state["wkv"])
    x = x + tm_out
    cm_out, cm_last = rwkv_channel_mix(p["cmix"], x, state["cm_last"])
    x = x + cm_out
    return x, {"tm_last": tm_last, "cm_last": cm_last, "wkv": wkv}
