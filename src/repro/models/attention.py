"""Attention: GQA self-attention (full / sliding-window / causal), cross
attention (VLM), and cached decode.

 * The prefill/train path is flash-style chunked attention (lax.scan +
   online softmax): [S, S] score matrices are never materialized — required
   for the 32k-prefill / 500k shapes and gives XLA a fusable HLO.
 * GQA is computed with grouped einsums — KV heads are NEVER repeated into
   full head count (a naive repeat at llama-90B decode_32k would materialize
   a 68 TB tensor).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .layers import _dense_init, apply_rope

Params = Dict[str, Any]
NEG = -1e30


def init_attention(key, cfg, cross: bool = False) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    p = {
        "wq": _dense_init(ks[0], (d, nh * hd), dt),
        "wk": _dense_init(ks[1], (d, nkv * hd), dt),
        "wv": _dense_init(ks[2], (d, nkv * hd), dt),
        "wo": _dense_init(ks[3], (nh * hd, d), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nh * hd,), dt)
        p["bk"] = jnp.zeros((nkv * hd,), dt)
        p["bv"] = jnp.zeros((nkv * hd,), dt)
    return p


def _project_qkv(p: Params, x: jax.Array, kv_x: jax.Array, cfg):
    hd, nh, nkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    q = x @ p["wq"]
    k = kv_x @ p["wk"]
    v = kv_x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    g = nh // nkv
    q = q.reshape(*x.shape[:-1], nkv, g, hd)       # grouped query heads
    k = k.reshape(*kv_x.shape[:-1], nkv, hd)
    v = v.reshape(*kv_x.shape[:-1], nkv, hd)
    return q, k, v


def flash_attention(q, k, v, *, causal: bool, window: int = 0,
                    q_offset: int = 0, block_kv: int = 1024) -> jax.Array:
    """Chunked attention with online softmax, GQA-grouped.

    q (B, Sq, Kv, G, Dh); k, v (B, Skv, Kv, Dh).
    window > 0 limits attention to the last `window` positions.
    Returns (B, Sq, Kv, G, Dh).
    """
    b, sq, kv_h, g, hd = q.shape
    skv = k.shape[1]
    block_kv = min(block_kv, skv)
    pad = (-skv) % block_kv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_blocks = k.shape[1] // block_kv
    kb = jnp.moveaxis(k.reshape(b, n_blocks, block_kv, kv_h, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, n_blocks, block_kv, kv_h, hd), 1, 0)
    scale = hd ** -0.5
    q_pos = q_offset + jnp.arange(sq)

    def chunk(carry, xs):
        m_prev, s_prev, o_prev = carry
        kc, vc, blk = xs
        kv_pos = blk * block_kv + jnp.arange(block_kv)
        s = jnp.einsum("bqkgd,bckd->bkgqc", q, kc,
                       preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((sq, block_kv), bool)
        if causal:
            mask &= q_pos[:, None] >= kv_pos[None, :]
        if window:
            mask &= q_pos[:, None] - kv_pos[None, :] < window
        mask &= (kv_pos < skv)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])
        s_new = s_prev * alpha + jnp.sum(p, axis=-1)
        o_new = o_prev * alpha[..., None] + jnp.einsum(
            "bkgqc,bckd->bkgqd", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32)
        return (m_new, s_new, o_new), None

    init = (jnp.full((b, kv_h, g, sq), NEG, jnp.float32),
            jnp.zeros((b, kv_h, g, sq), jnp.float32),
            jnp.zeros((b, kv_h, g, sq, hd), jnp.float32))
    chunk_fn = jax.checkpoint(chunk)  # recompute scores in bwd (flash-style)
    (m, s, o), _ = jax.lax.scan(chunk_fn, init,
                                (kb, vb, jnp.arange(n_blocks)))
    out = o / jnp.maximum(s, 1e-30)[..., None]       # (B, Kv, G, Sq, Dh)
    return jnp.moveaxis(out, 3, 1).astype(q.dtype)   # (B, Sq, Kv, G, Dh)


def self_attention(p: Params, x: jax.Array, cfg, *, window: int = 0,
                   positions: Optional[jax.Array] = None) -> jax.Array:
    """Training / prefill self-attention (causal)."""
    q, k, v = _project_qkv(p, x, x, cfg)
    if positions is None:
        positions = jnp.arange(x.shape[1])
    b, s = x.shape[:2]
    qf = q.reshape(b, s, -1, q.shape[-1])            # (B,S,H,Dh) for rope
    qf = apply_rope(qf, positions, cfg.rope_theta)
    q = qf.reshape(q.shape)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = flash_attention(q, k, v, causal=True, window=window)
    return o.reshape(*x.shape[:-1], -1) @ p["wo"]


def cross_attention(p: Params, x: jax.Array, kv_feats: jax.Array,
                    cfg) -> jax.Array:
    """VLM cross-attn: queries from text stream, kv from image embeddings."""
    q, k, v = _project_qkv(p, x, kv_feats, cfg)
    o = flash_attention(q, k, v, causal=False)
    return o.reshape(*x.shape[:-1], -1) @ p["wo"]


class KVCache(NamedTuple):
    k: jax.Array       # (B, S_max, n_kv, Dh)
    v: jax.Array


def decode_self_attention(p: Params, x: jax.Array, cache: KVCache, pos,
                          cfg, *, window: int = 0):
    """Single-token decode. x (B, 1, d); pos: absolute position — a scalar
    shared by the batch (generate()'s lock-step loop) or an (B,) vector of
    per-stream positions (the continuous-batching slot table, where each
    lane is at a different depth of its own request).

    Returns (out (B, 1, d), updated cache). For sliding-window layers the
    cache is a ring buffer of length `window`.
    """
    q, k, v = _project_qkv(p, x, x, cfg)             # q (B,1,Kv,G,Dh)
    pos = jnp.asarray(pos)
    per_slot = pos.ndim == 1
    posv = pos[:, None] if per_slot else pos[None]   # (B,1) | (1,)
    b = x.shape[0]
    qf = apply_rope(q.reshape(b, 1, -1, q.shape[-1]), posv, cfg.rope_theta)
    q = qf.reshape(q.shape)
    k = apply_rope(k, posv, cfg.rope_theta)
    s_max = cache.k.shape[1]
    slot = (pos % window) if window else pos
    new_k = _dyn_update(cache.k, k, slot)
    new_v = _dyn_update(cache.v, v, slot)
    valid = jnp.minimum(pos + 1, s_max)
    scale = cfg.resolved_head_dim ** -0.5
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, new_k,
                   preferred_element_type=jnp.float32) * scale
    kv_idx = jnp.arange(s_max)
    if per_slot:
        mask = kv_idx[None, :] < valid[:, None]      # (B, s_max)
        s = jnp.where(mask[:, None, None, None, :], s, NEG)
    else:
        mask = kv_idx < valid
        s = jnp.where(mask[None, None, None, None], s, NEG)
    a = jax.nn.softmax(s, axis=-1).astype(new_v.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", a, new_v)
    out = o.reshape(*x.shape[:-1], -1) @ p["wo"]
    return out, KVCache(k=new_k, v=new_v)


def _dyn_update(buf: jax.Array, row: jax.Array, slot) -> jax.Array:
    """Write one token's KV at `slot` — scalar (whole batch at the same
    position) or (B,) (each lane at its own position, vmapped)."""
    slot = jnp.asarray(slot, jnp.int32)
    row = row.astype(buf.dtype)
    if slot.ndim == 0:
        return jax.lax.dynamic_update_slice(buf, row, (0, slot, 0, 0))
    return jax.vmap(
        lambda b, r, s: jax.lax.dynamic_update_slice(b, r, (s, 0, 0))
    )(buf, row, slot)


# -- lane-window KV block ops (serve.prefix_cache) ---------------------------
#
# The serving decode state keeps the lane batch at axis -4 and token
# positions at axis -3 of every k/v leaf (launch.mesh.serve_cache_spec's
# convention; the model's scanned layers stack extra leading axes). These
# two primitives are the whole traced surface of the shared prefix cache:
# a contiguous multi-token read out of one lane, and a contiguous
# multi-token write back into one lane — both with TRACED lane index and
# start position, so one compilation serves every (slot, offset) pair.


def slice_lane_window(leaf: jax.Array, lane, start, length: int) -> jax.Array:
    """Read `length` consecutive KV rows of one lane: leaf (*stack, S, L,
    n_kv, Dh) -> (*stack, 1, length, n_kv, Dh). `lane`/`start` may be
    traced; `length` is static."""
    nd = leaf.ndim
    starts = [jnp.int32(0)] * nd
    starts[-4] = jnp.asarray(lane, jnp.int32)
    starts[-3] = jnp.asarray(start, jnp.int32)
    sizes = list(leaf.shape)
    sizes[-4] = 1
    sizes[-3] = length
    return jax.lax.dynamic_slice(leaf, starts, sizes)


def write_lane_window(leaf: jax.Array, rows: jax.Array, lane,
                      start) -> jax.Array:
    """Multi-token append: write `rows` (*stack, 1, length, n_kv, Dh) into
    one lane of `leaf` at positions [start, start+length). The per-token
    `_dyn_update` generalized to a contiguous window — the prefix-cache
    copy lands L tokens of KV in one dynamic_update_slice instead of L
    replay steps."""
    nd = leaf.ndim
    starts = [jnp.int32(0)] * nd
    starts[-4] = jnp.asarray(lane, jnp.int32)
    starts[-3] = jnp.asarray(start, jnp.int32)
    return jax.lax.dynamic_update_slice(leaf, rows.astype(leaf.dtype),
                                        starts)
