"""Fine-grained MoE (DeepSeek-MoE / Moonlight family): shared experts +
top-k routed experts with capacity-based, jittable dispatch.

Dispatch is scatter-based (no [T, E, C] one-hot combine tensor): tokens are
placed into a (E, C, d) buffer via cumsum-derived slots, expert matmuls run
dense per expert, and results are gathered back weighted by router probs.
Under pjit the buffer is sharded E -> 'model' (expert parallelism); the
scatter/gather becomes XLA's all-to-all on the EP axis.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from .layers import _dense_init, init_mlp, mlp

Params = Dict[str, Any]


def init_moe(key, cfg) -> Params:
    m = cfg.moe
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    exp_keys = jax.random.split(ks[0], m.n_experts)
    shared_keys = jax.random.split(ks[1], max(m.n_shared, 1))
    experts = jax.vmap(
        lambda k: init_mlp(k, d, m.expert_d_ff, cfg.act, dt))(exp_keys)
    p: Params = {
        "router": _dense_init(ks[2], (d, m.n_experts), jnp.float32),
        "experts": experts,            # leaves stacked (E, ...)
    }
    if m.n_shared:
        p["shared"] = jax.vmap(
            lambda k: init_mlp(k, d, m.expert_d_ff, cfg.act, dt))(shared_keys)
    return p


def moe_block(p: Params, x: jax.Array, cfg):
    """x (B, S, d) -> (out (B, S, d), aux_losses dict)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)

    logits = (xf.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)             # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    capacity = int(m.capacity_factor * t * m.top_k / m.n_experts)
    capacity = max(capacity, 4)

    # slot assignment: position of each (token, choice) within its expert
    flat_e = top_e.reshape(-1)                               # (T*k,)
    onehot = jax.nn.one_hot(flat_e, m.n_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) * onehot                # (T*k, E)
    slot = pos.sum(-1) - 1                                   # (T*k,)
    keep = slot < capacity

    # scatter tokens into (E, C, d) dispatch buffer
    buf = jnp.zeros((m.n_experts, capacity, d), x.dtype)
    tok_idx = jnp.repeat(jnp.arange(t), m.top_k)
    src = jnp.where(keep[:, None], xf[tok_idx], 0)
    buf = buf.at[flat_e, jnp.clip(slot, 0, capacity - 1)].add(
        jnp.where(keep[:, None], src, 0))

    # dense per-expert MLPs: vmap over stacked expert params
    out_buf = jax.vmap(lambda pe, xe: mlp(pe, xe, cfg.act))(
        p["experts"], buf)                                   # (E, C, d)

    # gather back, weighted by router prob
    gathered = out_buf[flat_e, jnp.clip(slot, 0, capacity - 1)]
    gathered = jnp.where(keep[:, None], gathered, 0)
    flat_w = top_p.reshape(-1)[:, None].astype(x.dtype)
    contrib = gathered * flat_w
    out = jnp.zeros((t, d), x.dtype).at[tok_idx].add(contrib)

    if m.n_shared:
        shared = jnp.sum(jax.vmap(lambda ps: mlp(ps, xf, cfg.act))(
            p["shared"]), axis=0)
        out = out + shared

    # aux losses: load balance (Switch-style) + router z-loss
    me = probs.mean(0)                                       # (E,)
    ce = jax.nn.one_hot(top_e[:, 0], m.n_experts).mean(0)
    aux = {
        "moe_balance": m.n_experts * jnp.sum(me * ce) * m.aux_loss,
        "moe_zloss": jnp.mean(jax.nn.logsumexp(logits, -1) ** 2)
                     * m.router_z_loss,
        "moe_drop_frac": 1.0 - keep.mean(),
    }
    return out.reshape(b, s, d), aux
