"""Log-bilinear language model (Mnih & Hinton 2008) — the paper's SS5.2 model.

q(context) = sum_i C_i . r_{w_i}  over a fixed context window; the score of
next word w is q . r_w + b_w. Trained with NCE while clamping Z := 1 (the
heuristic the paper evaluates MIMPS against in Table 4).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from .layers import _dense_init

Params = Dict[str, Any]


def init_lbl(key, vocab: int, d: int, context: int, dtype=jnp.float32
             ) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "r": _dense_init(k1, (vocab, d), dtype, scale=0.1),   # word vectors
        "c": _dense_init(k2, (context, d, d), dtype,
                         scale=d ** -0.5),                     # position mats
        "b": jnp.zeros((vocab,), dtype),
    }


def context_vector(p: Params, ctx_tokens: jax.Array) -> jax.Array:
    """ctx_tokens (B, n_ctx) -> q (B, d)."""
    r_ctx = jnp.take(p["r"], ctx_tokens, axis=0)     # (B, n, d)
    return jnp.einsum("bnd,nde->be", r_ctx, p["c"])


def scores(p: Params, q: jax.Array, words: jax.Array = None) -> jax.Array:
    """q (B, d) -> scores over `words` (or the full vocab)."""
    if words is None:
        return q @ p["r"].T + p["b"]
    r = jnp.take(p["r"], words, axis=0)              # (..., d)
    b = jnp.take(p["b"], words, axis=0)
    return jnp.einsum("bd,b...d->b...", q, r) + b


def class_vectors(p: Params) -> jax.Array:
    """The v_i of the paper: output side = r (+ bias folded via append).

    Bias is absorbed by appending 1 to q and b to r, so MIPS operates on
    (d+1)-dim vectors exactly as [3]'s reduction suggests."""
    return jnp.concatenate([p["r"], p["b"][:, None]], axis=1)


def query_vector(p: Params, ctx_tokens: jax.Array) -> jax.Array:
    q = context_vector(p, ctx_tokens)
    ones = jnp.ones((*q.shape[:-1], 1), q.dtype)
    return jnp.concatenate([q, ones], axis=-1)


def nce_loss(p: Params, ctx: jax.Array, target: jax.Array,
             noise: jax.Array, log_noise_prob: jax.Array,
             n_noise: int) -> jax.Array:
    """NCE with Z clamped to 1 (paper SS5.2 training setup).

    ctx (B, n); target (B,); noise (B, k); log_noise_prob: log q(w) for
    target and noise words, shapes (B,) and (B, k).
    """
    q = context_vector(p, ctx)
    s_t = scores(p, q, target)                        # (B,)  log p_model
    s_n = scores(p, q, noise)                         # (B, k)
    log_k = jnp.log(jnp.float32(n_noise))
    # P(data | w) = sigma(s - log k q(w))
    pos = jax.nn.log_sigmoid(s_t - log_k - log_noise_prob[0])
    neg = jax.nn.log_sigmoid(-(s_n - log_k - log_noise_prob[1]))
    return -(pos.mean() + neg.sum(axis=1).mean())
