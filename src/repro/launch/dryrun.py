"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on a
512-placeholder-device CPU host and extract the roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-4b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all   # full matrix

Artifacts land in artifacts/dryrun/<mesh>/<arch>__<shape>[__mode].json and
are consumed by benchmarks/roofline.py (EXPERIMENTS.md SS Dry-run/Roofline).
"""
# The FIRST two lines must run before any other import (jax locks the device
# count on first init):
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

import argparse            # noqa: E402
import dataclasses         # noqa: E402
import json                # noqa: E402
import re                  # noqa: E402
import time                # noqa: E402
import traceback           # noqa: E402

import jax                 # noqa: E402
import jax.numpy as jnp    # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import get_config, ASSIGNED_ARCHS           # noqa: E402
from ..configs.base import SHAPES, TrainConfig, get_shape  # noqa: E402
from ..models import Model                                  # noqa: E402
from ..serve.output_layer import (ivf_specs_for, ivf_partition_specs,
                                  sharded_decode,
                                  streaming_logz_argmax)    # noqa: E402
from ..train import init_train_state, make_train_step      # noqa: E402
from . import mesh as mesh_lib                              # noqa: E402
from .hlo_analysis import analyze as analyze_hlo            # noqa: E402

SDS = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
# input specs per (arch, shape)
# ---------------------------------------------------------------------------

def token_struct(cfg, batch, seq):
    if cfg.n_codebooks:
        return SDS((batch, seq, cfg.n_codebooks), jnp.int32)
    return SDS((batch, seq), jnp.int32)


def train_batch_struct(cfg, batch, seq):
    out = {"tokens": token_struct(cfg, batch, seq),
           "labels": token_struct(cfg, batch, seq)}
    if cfg.family == "vlm":
        out["img"] = SDS((batch, cfg.n_image_tokens, cfg.d_model),
                         jnp.dtype(cfg.dtype))
    return out


def input_specs(arch: str, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    cfg = get_config(arch)
    sc = get_shape(shape_name)
    if sc.kind == "train":
        return {"batch": train_batch_struct(cfg, sc.global_batch, sc.seq_len)}
    if sc.kind == "prefill":
        out = {"tokens": token_struct(cfg, sc.global_batch, sc.seq_len)}
        if cfg.family == "vlm":
            out["img"] = SDS((sc.global_batch, cfg.n_image_tokens,
                              cfg.d_model), jnp.dtype(cfg.dtype))
        return out
    # decode: one new token against a seq_len KV cache
    model = Model(cfg)
    cache = jax.eval_shape(
        lambda: model.init_decode_state(sc.global_batch, sc.seq_len))
    tok = SDS((sc.global_batch,), jnp.int32) if not cfg.n_codebooks else \
        SDS((sc.global_batch, cfg.n_codebooks), jnp.int32)
    out = {"state": cache, "token": tok, "pos": SDS((), jnp.int32),
           "key": SDS((2,), jnp.uint32)}
    if cfg.family == "vlm":
        out["img"] = SDS((sc.global_batch, cfg.n_image_tokens, cfg.d_model),
                         jnp.dtype(cfg.dtype))
    return out


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def build_lowering(arch: str, shape_name: str, mesh, output_mode="exact"):
    cfg = get_config(arch)
    sc = get_shape(shape_name)
    model = Model(cfg)
    dsize = mesh_lib.data_size(mesh)

    params_struct = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0)))
    p_shard = mesh_lib.params_shardings(mesh, params_struct)

    if sc.kind == "train":
        mb = max(1, sc.global_batch // dsize)  # 1 seq/device/microbatch
        tc = TrainConfig(loss="fused_ce", microbatches=mb)
        state_struct = jax.eval_shape(
            lambda: init_train_state(model, tc, jax.random.PRNGKey(0)))
        s_shard = type(state_struct)(
            params=p_shard,
            opt=type(state_struct.opt)(
                step=NamedSharding(mesh, P()),
                m=p_shard, v=p_shard),
            rng=NamedSharding(mesh, P()))
        batch_struct = train_batch_struct(cfg, sc.global_batch, sc.seq_len)
        b_shard = mesh_lib.batch_shardings(mesh, batch_struct,
                                           sc.global_batch)
        step = make_train_step(model, tc, backend="xla", mesh=mesh)
        jitted = jax.jit(step, in_shardings=(s_shard, b_shard),
                         out_shardings=(s_shard, None), donate_argnums=(0,))
        return jitted.lower(state_struct, batch_struct), {
            "step": "train_step", "microbatches": mb}

    if sc.kind == "prefill":
        specs = input_specs(arch, shape_name)
        b_shard = mesh_lib.batch_shardings(mesh, specs, sc.global_batch)

        def prefill_step(params, tokens, img=None):
            hidden, _ = model.forward(params, tokens, img=img)
            h_last = hidden[:, -1]
            w = model.head_matrix(params)
            if cfg.n_codebooks:
                logits = jnp.einsum("bd,cvd->bcv", h_last, w)
                lse = jax.nn.logsumexp(logits, -1)
                return {"log_z": lse,
                        "token": jnp.argmax(logits, -1),
                        "top": jnp.max(logits, -1)}
            log_z, top_id, top_s = streaming_logz_argmax(h_last, w)
            return {"log_z": log_z, "token": top_id, "top": top_s}

        args = (specs["tokens"],) + ((specs["img"],)
                                     if cfg.family == "vlm" else ())
        shards = (b_shard["tokens"],) + ((b_shard["img"],)
                                         if cfg.family == "vlm" else ())
        jitted = jax.jit(prefill_step,
                         in_shardings=(p_shard,) + shards)
        return jitted.lower(params_struct, *args), {"step": "prefill_step"}

    # decode
    specs = input_specs(arch, shape_name)
    st_shard = mesh_lib.decode_state_shardings(mesh, specs["state"],
                                               sc.global_batch)
    tok_shard = mesh_lib.batch_shardings(mesh, specs["token"],
                                         sc.global_batch)
    dp = mesh_lib.batch_axis_for(mesh, sc.global_batch)
    pc = cfg.partition
    use_ivf = output_mode == "mimps" and pc.method in ("mimps", "mince")
    ivf = None
    if use_ivf:
        ivf = ivf_specs_for(cfg.vocab, cfg.d_model, pc.block_rows,
                            jnp.dtype(cfg.dtype))

    def serve_step(params, state, token, pos, key, img=None, ivf_arrays=None):
        h, new_state = model.decode_step(params, state, token, pos, img=img)
        w = model.head_matrix(params)
        if cfg.n_codebooks:
            logits = jnp.einsum("bd,cvd->bcv", h, w)
            lse = jax.nn.logsumexp(logits, -1)
            out = {"log_z": lse, "token": jnp.argmax(logits, -1)}
        elif ivf_arrays is not None:
            p_local = max(1, pc.n_probe // mesh.shape["model"])
            l_local = max(8, pc.l // mesh.shape["model"])
            mince_kw = ({"iters": pc.mince_iters, "solver": pc.mince_solver}
                        if pc.method == "mince" else {})
            log_z, top_id, top_s = sharded_decode(
                mesh, pc.method, ivf_arrays, h, key, n_probe_local=p_local,
                l_local=l_local,
                batch_spec=P(dp) if dp else P(), **mince_kw)
            out = {"log_z": log_z, "token": top_id,
                   "log_prob": top_s - log_z}
        else:
            log_z, top_id, top_s = streaming_logz_argmax(h, w)
            out = {"log_z": log_z, "token": top_id,
                   "log_prob": top_s - log_z}
        return out, new_state

    args = [params_struct, specs["state"], specs["token"], specs["pos"],
            specs["key"]]
    shards = [p_shard, st_shard, tok_shard, NamedSharding(mesh, P()),
              NamedSharding(mesh, P())]
    kwargs_struct = {}
    if cfg.family == "vlm":
        kwargs_struct["img"] = specs["img"]
    if use_ivf:
        kwargs_struct["ivf_arrays"] = ivf

    def wrapped(params, state, token, pos, key, extra):
        return serve_step(params, state, token, pos, key,
                          img=extra.get("img"),
                          ivf_arrays=extra.get("ivf_arrays"))

    extra_shard = {}
    if "img" in kwargs_struct:
        extra_shard["img"] = mesh_lib.batch_shardings(
            mesh, kwargs_struct["img"], sc.global_batch)
    if "ivf_arrays" in kwargs_struct:
        extra_shard["ivf_arrays"] = jax.tree.map(
            lambda s: NamedSharding(mesh, s), ivf_partition_specs())

    jitted = jax.jit(wrapped, in_shardings=tuple(shards) + (extra_shard,),
                     out_shardings=(None, st_shard), donate_argnums=(1,))
    return jitted.lower(*args, kwargs_struct), {
        "step": f"serve_step[{output_mode}]"}


# ---------------------------------------------------------------------------
# per-cell runner
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, mesh_kind: str,
             output_mode: str = "exact", out_dir: str = "artifacts/dryrun"):
    cfg = get_config(arch)
    sc = get_shape(shape_name)
    if shape_name == "long_500k" and not cfg.subquadratic:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "skipped": "pure full-attention arch (DESIGN.md SS5)"}
    mesh = mesh_lib.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    with mesh:
        lowered, meta = build_lowering(arch, shape_name, mesh, output_mode)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for f in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "alias_size_in_bytes",
                  "temp_size_in_bytes"):
            if hasattr(ma, f):
                mem[f] = int(getattr(ma, f))
    except Exception as e:                                   # noqa: BLE001
        mem["error"] = str(e)
    cost = {}
    try:
        ca = compiled.cost_analysis()
        cost = {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and (
                    "flops" in k or "bytes" in k or "utilization" not in k)}
        cost = {k: v for k, v in cost.items()
                if k in ("flops", "transcendentals", "bytes accessed")
                or k.startswith("bytes accessed")}
    except Exception as e:                                   # noqa: BLE001
        cost["error"] = str(e)
    t0 = time.time()
    hlo = analyze_hlo(compiled.as_text())
    t_analyze = time.time() - t0
    n_chips = 512 if mesh_kind == "multi" else 256
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "output_mode": output_mode, **meta,
        "n_chips": n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "analyze_s": round(t_analyze, 1),
        "memory_analysis": mem,
        "cost_analysis_xla": cost,        # loop-blind (XLA HloCostAnalysis)
        # trip-count-aware per-device numbers (launch/hlo_analysis.py):
        "flops_per_device": hlo["flops"],
        "bytes_per_device": hlo["bytes"],
        "transcendentals_per_device": hlo["transcendentals"],
        "collective_bytes": hlo["collectives"],
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
        "global_tokens": sc.global_batch * (sc.seq_len if sc.kind == "train"
                                            else 1),
    }
    os.makedirs(f"{out_dir}/{mesh_kind}", exist_ok=True)
    suffix = "" if output_mode == "exact" else f"__{output_mode}"
    with open(f"{out_dir}/{mesh_kind}/{arch}__{shape_name}{suffix}.json",
              "w") as f:
        json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--output-mode", default="exact",
                    choices=["exact", "mimps"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ASSIGNED_ARCHS:
            for s in SHAPES:
                for mk in ("single", "multi"):
                    cells.append((a, s.name, mk, "exact"))
    else:
        cells.append((args.arch, args.shape, args.mesh, args.output_mode))

    failures = 0
    for a, s, mk, om in cells:
        try:
            jax.clear_caches()
            r = run_cell(a, s, mk, om, args.out)
            if "skipped" in r:
                print(f"[SKIP] {a} x {s} x {mk}: {r['skipped']}", flush=True)
            else:
                fl = r["flops_per_device"]
                cb = sum(r["collective_bytes"].values())
                print(f"[OK]   {a} x {s} x {mk} ({r['step']}): "
                      f"compile {r['compile_s']}s flops/dev {fl:.3e} "
                      f"bytes/dev {r['bytes_per_device']:.3e} "
                      f"coll/dev {cb/1e9:.3f} GB", flush=True)
        except Exception:                                    # noqa: BLE001
            failures += 1
            print(f"[FAIL] {a} x {s} x {mk}", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
