"""Production mesh + sharding rules (DESIGN.md SS6).

``make_production_mesh`` is a FUNCTION so importing this module never touches
jax device state (required by the dry-run bootstrap ordering).

Sharding policy (single-pod (data=16, model=16); multi-pod adds leading
pure-DP 'pod'):
  batch dims                  -> ('pod','data')  [replicated if indivisible]
  vocab / embedding rows      -> 'model'
  attention/projection fan-out (heads*hd, d_ff, d_inner) -> 'model'
  projection fan-in of the return matmuls (wo/down/out_proj) -> 'model'
  experts (MoE)               -> 'model'  (expert parallelism)
  KV-cache sequence dim       -> 'model'  (decode: flash-decoding style)
  norms, routers, small LoRA  -> replicated
"""
from __future__ import annotations

import math
import re
from typing import Any, List, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def best_mesh_shape(n_devices: int, model_parallel: int) -> Tuple[int, int]:
    """(data, model) factorization for the devices we actually have.

    THE shared topology rule: train-time elastic rebuilds
    (``train.elastic.make_elastic_mesh``) and the serving mesh
    (``make_mesh_2d`` / ``make_serving_mesh``) both factor through here, so
    both sides agree on axis names and shapes for any device count. Shrinks
    the model axis only when the device count drops below the requested TP
    degree."""
    mp = min(model_parallel, n_devices)
    while n_devices % mp:
        mp -= 1
    return n_devices // mp, mp


def make_mesh_2d(shape: Tuple[int, int],
                 devices: Optional[List] = None) -> Mesh:
    """The one (data, model) mesh constructor. ``devices=None`` lets
    ``jax.make_mesh`` pick a performant device order over the whole slice;
    an explicit list (elastic rebuilds from survivors, serving's
    ``--mesh data=K,model=M`` on a subset) is reshaped as given."""
    dp, mp = shape
    if devices is None:
        return jax.make_mesh((dp, mp), ("data", "model"))
    import numpy as np
    dev_array = np.asarray(devices[:dp * mp]).reshape(dp, mp)
    return Mesh(dev_array, ("data", "model"))


def make_serving_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Serving mesh over the first data*model local devices (launch/serve.py
    ``--mesh data=K,model=M``)."""
    need = data * model
    devs = jax.devices()
    if need > len(devs):
        raise ValueError(
            f"mesh data={data},model={model} needs {need} devices but only "
            f"{len(devs)} are visible")
    return make_mesh_2d((data, model), devs[:need])


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    if multi_pod:
        return jax.make_mesh((2, 16, 16), ("pod", "data", "model"))
    return make_mesh_2d((16, 16))


def data_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def data_size(mesh: Mesh) -> int:
    # math.prod, not jnp.prod: this runs on host-side python ints and the
    # module promises import-time (and call-time) device purity
    return math.prod(mesh.shape[a] for a in data_axes(mesh))


def batch_axis_for(mesh: Mesh, batch: int):
    """'data'(+'pod') if the batch divides the data extent, else replicate."""
    if batch % data_size(mesh) == 0:
        ax = data_axes(mesh)
        return ax if len(ax) > 1 else ax[0]
    return None


# ---------------------------------------------------------------------------
# parameter specs by tree path
# ---------------------------------------------------------------------------

_COL = {"wq", "wk", "wv", "gate", "up", "wg", "wz", "wx", "decay_b"}
_ROW = {"wo", "down", "out_proj"}
_SHARD_BIAS = {"bq", "bk", "bv", "conv_x_b"}
_REPL = {"scale", "router", "mu", "bonus_u", "decay_w0", "decay_a", "wbc",
         "wdt", "conv_bc_w", "conv_bc_b", "a_log", "d_skip", "dt_bias", "b",
         "c"}


def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "name"):
            parts.append(str(e.name))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
    return "/".join(parts)


def _pad(nd: int, tail) -> P:
    return P(*([None] * (nd - len(tail)) + list(tail)))


def param_spec(path, leaf, model_axis_size: int = 16) -> P:
    """PartitionSpec for one parameter leaf (stack dims lead; rules apply to
    the trailing semantic dims). Falls back to replication whenever the
    preferred axis doesn't divide."""
    s = _path_str(path)
    name = s.split("/")[-1]
    nd = leaf.ndim
    shape = leaf.shape

    def ok(dim_from_end: int) -> bool:
        return shape[nd - dim_from_end] % model_axis_size == 0

    if "experts" in s and "shared" not in s:
        # (L, E, d, ff)-style: shard the expert dim (-3)
        if nd >= 3 and ok(3):
            return _pad(nd, ["model", None, None])
        return _pad(nd, [None] * min(nd, 3))
    if "shared" in s:
        if name in ("gate", "up") and ok(1):
            return _pad(nd, [None, "model"])
        if name == "down" and ok(2):
            return _pad(nd, ["model", None])
        return _pad(nd, [])
    if name == "table" or name == "lm_head":
        # (V, d) or (C, V, d): vocab at -2
        return _pad(nd, ["model", None]) if ok(2) else _pad(nd, [])
    # rwkv channel-mix rules must precede the generic _COL/_ROW names:
    # cmix/wv is the ROW (down) projection even though "wv" is a _COL name
    # elsewhere (mis-ordering cost a measured 240 GB/step of ff all-gathers).
    if "cmix" in s:
        if name in ("wk", "wr"):
            return _pad(nd, [None, "model"]) if ok(1) else _pad(nd, [])
        if name == "wv":
            return _pad(nd, ["model", None]) if ok(2) else _pad(nd, [])
    if name in _COL or (name == "wr" and nd >= 2):
        return _pad(nd, [None, "model"]) if ok(1) else _pad(nd, [])
    if name in _ROW:
        return _pad(nd, ["model", None]) if ok(2) else _pad(nd, [])
    if name == "conv_x_w":
        return _pad(nd, [None, "model"]) if ok(1) else _pad(nd, [])
    if name in _SHARD_BIAS:
        return _pad(nd, ["model"]) if ok(1) else _pad(nd, [])
    return _pad(nd, [])        # norms, routers, mu, ... replicated


def params_shardings(mesh: Mesh, params_struct: Any) -> Any:
    m = mesh.shape["model"]
    return jax.tree_util.tree_map_with_path(
        lambda p, x: NamedSharding(mesh, param_spec(p, x, m)), params_struct)


# ---------------------------------------------------------------------------
# decode-state specs by tree path
# ---------------------------------------------------------------------------

def decode_state_spec(path, leaf, mesh: Mesh, batch: int) -> P:
    s = _path_str(path)
    name = s.split("/")[-1]
    nd = leaf.ndim
    dp = batch_axis_for(mesh, batch)
    model = mesh.shape["model"]

    if name in ("k", "v"):
        # (..., B, S, nkv, hd): seq -> model (flash-decoding style)
        seq = leaf.shape[nd - 3]
        sm = "model" if seq % model == 0 else None
        return _pad(nd, [dp, sm, None, None])
    if name in ("tm_last", "cm_last"):
        return _pad(nd, [dp, None])
    if name == "wkv":
        heads = leaf.shape[nd - 3]
        hm = "model" if heads % model == 0 else None
        return _pad(nd, [dp, hm, None, None])
    if name == "conv_x":
        ch = leaf.shape[nd - 1]
        cm = "model" if ch % model == 0 else None
        return _pad(nd, [dp, None, cm])
    if name == "conv_bc":
        return _pad(nd, [dp, None, None])
    if name == "ssm":
        heads = leaf.shape[nd - 3]
        hm = "model" if heads % model == 0 else None
        return _pad(nd, [dp, hm, None, None])
    return _pad(nd, [])


def decode_state_shardings(mesh: Mesh, struct: Any, batch: int) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda p, x: NamedSharding(mesh, decode_state_spec(p, x, mesh,
                                                           batch)), struct)


# ---------------------------------------------------------------------------
# serving (slot-scheduler) cache specs
# ---------------------------------------------------------------------------

def serve_cache_spec(path, leaf) -> P:
    """PartitionSpec for one slot-table KV/state-cache leaf under the
    serving mesh: the batch (slot-lane) dim shards over 'data', everything
    else stays replicated. Unlike ``decode_state_spec`` there is no
    model-axis sharding inside the transformer state — the serving mesh's
    'model' axis shards only the output layer (embedding rows / IVF
    blocks), so each model shard holds its data-replica's full cache and
    the decode_step body needs no collectives. Layouts mirror
    ``decode_state_spec`` (batch at -4 for k/v/wkv/ssm, -2 for the token
    shifts, -3 for conv states)."""
    s = _path_str(path)
    name = s.split("/")[-1]
    nd = leaf.ndim
    if name in ("k", "v", "wkv", "ssm"):
        return _pad(nd, ["data", None, None, None])
    if name in ("tm_last", "cm_last"):
        return _pad(nd, ["data", None])
    if name in ("conv_x", "conv_bc"):
        return _pad(nd, ["data", None, None])
    return _pad(nd, [])


# ---------------------------------------------------------------------------
# batch specs
# ---------------------------------------------------------------------------

def batch_shardings(mesh: Mesh, batch_struct: Any, batch: int) -> Any:
    dp = batch_axis_for(mesh, batch)

    def one(x):
        return NamedSharding(mesh, _pad(x.ndim, []) if dp is None
                             else P(dp, *([None] * (x.ndim - 1))))
    return jax.tree.map(one, batch_struct)


def replicated(mesh: Mesh, struct: Any) -> Any:
    return jax.tree.map(lambda x: NamedSharding(mesh, P()), struct)
