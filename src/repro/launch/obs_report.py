"""Summarize an observability trace (and optionally cross-check a snapshot).

  PYTHONPATH=src python -m repro.launch.obs_report /tmp/trace.jsonl \
      [--snapshot /tmp/snap.json] [--to-json /tmp/trace.chrome.json]

Reads the Chrome-trace JSONL written by ``--trace-out`` (one event object
per line, Trace Event Format phases X/i/C/M) and prints a human summary:
event counts, per-phase wall-time by span name, request outcomes, tier
transitions, and the last shadow rel-err counter samples.

Exit codes (CI smoke-gates on these):
  0  trace parsed and non-trivial
  2  empty trace, no parseable events, or malformed lines
  3  ``--snapshot`` reconciliation failed (tiers in the snapshot's
     tokens_by_tier disagree with tiers seen in the trace spans)

``--to-json`` re-emits the events as a single Chrome JSON array file that
``chrome://tracing`` / Perfetto load directly.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import Counter, defaultdict


def load_events(path: str):
    """Parse JSONL trace events. Returns (events, n_bad_lines)."""
    events, bad = [], 0
    try:
        fh = open(path, "r", encoding="utf-8")
    except OSError as e:
        print(f"obs_report: cannot open {path}: {e}", file=sys.stderr)
        return [], 1
    with fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
            except json.JSONDecodeError:
                bad += 1
                continue
            if not isinstance(ev, dict) or "ph" not in ev \
                    or "name" not in ev:
                bad += 1
                continue
            events.append(ev)
    return events, bad


def summarize(events) -> dict:
    """Aggregate the parsed events into the printed/reconciled summary."""
    by_phase = Counter(e["ph"] for e in events)
    span_ms = defaultdict(float)
    span_n = Counter()
    step_tiers = Counter()      # device_step:<tier> -> count
    outcomes = Counter()
    transitions = []
    shed = 0
    shadow_last = {}
    for e in events:
        ph, name = e["ph"], e["name"]
        if ph == "X":
            span_n[name] += 1
            span_ms[name] += e.get("dur", 0) / 1e3
            if name.startswith("device_step:"):
                step_tiers[name.split(":", 1)[1]] += 1
            elif name == "request":
                outcomes[e.get("args", {}).get("outcome", "ok")] += 1
        elif ph == "i":
            if name == "tier_transition":
                a = e.get("args", {})
                transitions.append((a.get("step"), a.get("tier")))
            elif name == "shed":
                shed += 1
        elif ph == "C" and name == "shadow_rel_err":
            shadow_last = e.get("args", {})
    return {"by_phase": dict(by_phase), "span_ms": dict(span_ms),
            "span_n": dict(span_n), "step_tiers": dict(step_tiers),
            "outcomes": dict(outcomes), "transitions": transitions,
            "shed": shed, "shadow_last": shadow_last}


def reconcile(summary: dict, snapshot: dict):
    """Check the snapshot's tokens_by_tier against tiers seen in the trace.

    Every tier that emitted tokens per the harvested device counters must
    have at least one ``device_step:<tier>`` span in the trace (and vice
    versa for tiers that stepped enough to harvest). Returns a list of
    mismatch strings (empty = reconciled).
    """
    problems = []
    harvest = snapshot.get("harvest", {})
    tok_by_tier = {t: v for t, v in
                   harvest.get("tokens_by_tier", {}).items() if v}
    traced = summary["step_tiers"]
    for t in tok_by_tier:
        if t not in traced:
            problems.append(
                f"tier {t!r} emitted {tok_by_tier[t]} tokens per snapshot "
                f"but has no device_step span in the trace")
    snap_total = harvest.get("tokens_total")
    if snap_total is not None and tok_by_tier:
        s = sum(tok_by_tier.values())
        if s != snap_total:
            problems.append(
                f"tokens_by_tier sums to {s} but tokens_total is "
                f"{snap_total}")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.launch.obs_report",
        description="summarize a --trace-out observability trace")
    ap.add_argument("trace", help="JSONL trace written by --trace-out")
    ap.add_argument("--snapshot", default=None,
                    help="metrics snapshot JSON to reconcile against")
    ap.add_argument("--to-json", default=None, metavar="PATH",
                    help="also write a Chrome JSON array trace to PATH")
    args = ap.parse_args(argv)

    events, bad = load_events(args.trace)
    if bad:
        print(f"obs_report: {bad} malformed line(s) in {args.trace}",
              file=sys.stderr)
        return 2
    if not events:
        print(f"obs_report: no events in {args.trace}", file=sys.stderr)
        return 2

    s = summarize(events)
    print(f"trace {args.trace}: {len(events)} events "
          f"(phases {s['by_phase']})")
    if s["step_tiers"]:
        steps = ", ".join(f"{t}:{n}" for t, n in
                          sorted(s["step_tiers"].items()))
        print(f"  device steps by tier: {steps}")
    for name in sorted(s["span_ms"], key=s["span_ms"].get, reverse=True):
        print(f"  span {name:<24s} n={s['span_n'][name]:<5d} "
              f"total {s['span_ms'][name]:9.2f} ms")
    if s["outcomes"]:
        print(f"  request outcomes: {s['outcomes']}  (shed events: "
              f"{s['shed']})")
    if s["transitions"]:
        path = " -> ".join(f"{t}@{step}" for step, t in s["transitions"])
        print(f"  tier transitions: {path}")
    if s["shadow_last"]:
        live = ", ".join(f"{t}:{v:.3e}" for t, v in
                         sorted(s["shadow_last"].items()))
        print(f"  last shadow rel-err by tier: {live}")

    if args.to_json:
        with open(args.to_json, "w", encoding="utf-8") as fh:
            json.dump({"traceEvents": events}, fh)
        print(f"  wrote chrome trace: {args.to_json}")

    if args.snapshot:
        try:
            with open(args.snapshot, "r", encoding="utf-8") as fh:
                snap = json.load(fh)
        except (OSError, json.JSONDecodeError) as e:
            print(f"obs_report: cannot read snapshot {args.snapshot}: {e}",
                  file=sys.stderr)
            return 3
        problems = reconcile(s, snap)
        if problems:
            for p in problems:
                print(f"obs_report: RECONCILE FAIL: {p}", file=sys.stderr)
            return 3
        print(f"  snapshot {args.snapshot}: reconciled "
              f"(tokens_by_tier consistent with traced tiers)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
