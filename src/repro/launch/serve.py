"""Traffic-driven serving: continuous batching over the slot scheduler.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-4b --reduced \
      --slots 8 --requests 16 --rate 1.0 --gen 12 --method mimps

Generates a Poisson arrival stream of mixed-length, mixed-temperature
requests, serves it through ``serve.Server`` (admission queue, one compiled
mixed prefill/decode step, slot recycling, streaming callbacks), and prints
the traffic report. ``--sequential`` adds a one-request-at-a-time
``generate()`` pass over the same workload for comparison.

``--method`` choices come from the estimator-backend registry, so every
servable method (including the PR-2 additions ``mince`` and ``fmbe``) is
accepted; oracle-only study estimators are not servable and not listed.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, reduced_config
from ..core.backends import BACKENDS
from ..models import Model
from ..serve import (Engine, Request, Scheduler, Server, generate,
                     poisson_arrivals)


def build_workload(n: int, vocab: int, gen: int, pmin: int, pmax: int,
                   temperature: float, seed: int):
    """Mixed prompt lengths cycling [pmin..pmax], alternating greedy /
    sampled — the heterogeneous traffic one synchronous batch can't serve
    without padding every request to the longest."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        p_len = pmin + (i * 3) % max(pmax - pmin + 1, 1)
        prompt = rng.integers(0, vocab, size=(p_len,), dtype=np.int32)
        reqs.append(Request(
            prompt=prompt, max_new_tokens=gen,
            key=jax.random.PRNGKey(seed + 1000 + i),
            temperature=0.0 if i % 2 == 0 else temperature))
    return reqs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--method", default=None,
                    choices=[None] + sorted(BACKENDS))
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=1.0,
                    help="expected arrivals per scheduler step")
    ap.add_argument("--prompt-len-min", type=int, default=4)
    ap.add_argument("--prompt-len-max", type=int, default=16)
    ap.add_argument("--gen", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.8,
                    help="sampled requests' temperature (every other "
                         "request decodes greedily)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--stream", action="store_true",
                    help="print every completion as it finishes")
    ap.add_argument("--sequential", action="store_true",
                    help="also run the one-request-at-a-time generate() "
                         "baseline over the same workload")
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if args.method:
        cfg = dataclasses.replace(
            cfg, partition=dataclasses.replace(cfg.partition,
                                               method=args.method))
    model = Model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    max_len = args.prompt_len_max + args.gen + 1
    eng = Engine(model, params, max_len=max_len, key=key,
                 use_pallas=args.use_pallas)
    print(f"arch {cfg.name}  Z-method {cfg.partition.method}  "
          f"vocab {cfg.vocab}  slots {args.slots}")

    if cfg.n_codebooks:
        # audio codebook heads have no slot-table path (multi-stream
        # tokens); keep the pre-scheduler synchronous batch demo working
        print("audio arch: serving one synchronous generate() batch "
              "(no continuous batching for codebook heads)")
        shape = (args.slots, args.prompt_len_min, cfg.n_codebooks)
        prompt = jax.random.randint(key, shape, 0, cfg.vocab)
        t0 = time.perf_counter()
        toks = generate(eng, prompt, args.gen, key,
                        temperature=args.temperature)
        jax.block_until_ready(toks)
        dt = time.perf_counter() - t0
        n_tok = args.slots * args.gen
        print(f"generated {args.slots}x{args.gen} codebook tokens in "
              f"{dt:.2f}s ({n_tok / dt:.1f} tok/s)")
        return

    reqs = build_workload(args.requests, cfg.vocab, args.gen,
                          args.prompt_len_min, args.prompt_len_max,
                          args.temperature, args.seed)
    if args.stream:
        for r in reqs:
            r.on_complete = lambda req, comp: print(
                f"  req {req.req_id:3d} T={req.temperature:.1f} "
                f"len {len(req.prompt):2d} -> {comp.tokens[:8]}"
                f"{'...' if len(comp.tokens) > 8 else ''}")

    sched = Scheduler(eng, n_slots=args.slots, key=key)
    server = Server(sched)
    arrivals = poisson_arrivals(reqs, rate=args.rate, seed=args.seed)
    rep = server.run(arrivals=arrivals)
    print("continuous:", rep.summary())
    print(f"  recompiles after warmup would be: step={sched.step_traces - 1} "
          f"admit={sched.admit_traces - 1} (0 expected)")
    if rep.dedup_by_fill:
        fills = ", ".join(f"{k}:{v:.2f}" for k, v in
                          rep.dedup_by_fill.items())
        print(f"  probe-union dedup by batch fill: {fills}")

    if args.sequential:
        # warm each compile bucket first so the comparison is steady-state
        seen = set()
        for r in reqs:
            b = 1 << (len(r.prompt) - 1).bit_length()
            if b not in seen:
                seen.add(b)
                jax.block_until_ready(generate(
                    eng, jnp.asarray(r.prompt)[None], r.max_new_tokens,
                    r.key, temperature=r.temperature))
        t0 = time.perf_counter()
        tot = 0
        for r in reqs:
            toks = generate(eng, jnp.asarray(r.prompt)[None],
                            r.max_new_tokens, r.key,
                            temperature=r.temperature)
            tot += int(jnp.asarray(toks).shape[1])
        jax.block_until_ready(toks)
        dt = time.perf_counter() - t0
        print(f"sequential: {tot} tokens in {dt:.2f}s "
              f"({tot / dt:.1f} tok/s); continuous speedup "
              f"{rep.goodput_tok_s / (tot / dt):.2f}x")


if __name__ == "__main__":
    main()
