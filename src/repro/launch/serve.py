"""Traffic-driven serving: continuous batching over the slot scheduler.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-4b --reduced \
      --slots 8 --requests 16 --rate 1.0 --gen 12 --method mimps

Generates a Poisson arrival stream of mixed-length, mixed-temperature
requests, serves it through ``serve.Server`` (admission queue, one compiled
mixed prefill/decode step, slot recycling, streaming callbacks), and prints
the traffic report. ``--sequential`` adds a one-request-at-a-time
``generate()`` pass over the same workload for comparison.

``--method`` choices come from the estimator-backend registry, so every
servable method (including the PR-2 additions ``mince`` and ``fmbe``) is
accepted; oracle-only study estimators are not servable and not listed.

Overload policy (DESIGN.md SS14) is driven by the ``ServingConfig`` flags:
``--max-queue`` bounds the admission queue (arrivals over the bound are
shed), ``--deadline`` stamps every request with a default deadline in
virtual steps (expired queue entries are shed, in-flight lanes evicted),
and ``--degrade-high/--degrade-low/--degrade-after/--restore-after`` (plus
an optional explicit ``--ladder``) walk the estimator-tier degradation
ladder under sustained queue pressure. All default off.

Raw speed (DESIGN.md SS16), still bit-identical per token:
``--spec-draft topk --spec-k 4`` turns on estimator-speculative decoding
(a cheap registry tier drafts k tokens per lane inside the compiled step,
the lane's serving tier verifies them in one batched pass);
``--prefix-cache-blocks N`` enables the shared-prefix KV pool (admissions
whose prompt prefix is cached skip those replay steps). ``--admit-window``
adds bounded admission lookahead so a full preferred replica doesn't
head-of-line block the queue.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ServingConfig, get_config, reduced_config
from ..core.backends import BACKENDS
from ..models import Model
from ..obs import Observability, ObsConfig
from ..serve import (Engine, Request, Scheduler, Server, generate,
                     poisson_arrivals)


def build_workload(n: int, vocab: int, gen: int, pmin: int, pmax: int,
                   temperature: float, seed: int):
    """Mixed prompt lengths cycling [pmin..pmax], alternating greedy /
    sampled — the heterogeneous traffic one synchronous batch can't serve
    without padding every request to the longest."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        p_len = pmin + (i * 3) % max(pmax - pmin + 1, 1)
        prompt = rng.integers(0, vocab, size=(p_len,), dtype=np.int32)
        reqs.append(Request(
            prompt=prompt, max_new_tokens=gen,
            key=jax.random.PRNGKey(seed + 1000 + i),
            temperature=0.0 if i % 2 == 0 else temperature))
    return reqs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--method", default=None,
                    choices=[None] + sorted(BACKENDS))
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=1.0,
                    help="expected arrivals per scheduler step")
    ap.add_argument("--prompt-len-min", type=int, default=4)
    ap.add_argument("--prompt-len-max", type=int, default=16)
    ap.add_argument("--gen", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.8,
                    help="sampled requests' temperature (every other "
                         "request decodes greedily)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--mesh", default=None, metavar="data=K,model=M",
                    help="scale out over a (data, model) device mesh: slot "
                         "lanes split across K replicas, the output "
                         "embedding + IVF index across M shards, one "
                         "shard_map step (requires K*M visible devices; "
                         "tokens stay bit-identical to single-device)")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bound the admission queue; arrivals over the "
                         "bound are shed with reason 'queue_full' "
                         "(0 = unbounded)")
    ap.add_argument("--deadline", type=int, default=0,
                    help="default per-request deadline in virtual steps: "
                         "expired queue entries are shed, in-flight lanes "
                         "evicted mid-decode (0 = no deadlines)")
    ap.add_argument("--degrade-high", type=int, default=0,
                    help="queue depth at/above which sustained pressure "
                         "steps the estimator tier DOWN the ladder "
                         "(0 = degradation off)")
    ap.add_argument("--degrade-low", type=int, default=0,
                    help="queue depth at/below which sustained calm "
                         "restores the tier back UP")
    ap.add_argument("--degrade-after", type=int, default=3,
                    help="consecutive over-watermark steps before "
                         "degrading")
    ap.add_argument("--restore-after", type=int, default=8,
                    help="consecutive under-watermark steps before "
                         "restoring")
    ap.add_argument("--ladder", default=None,
                    help="comma list of tiers, most-accurate first (default:"
                         " the method's built-in ladder, e.g. mimps,topk)")
    ap.add_argument("--spec-draft", default=None,
                    choices=[None] + sorted(BACKENDS),
                    help="estimator-speculative decoding: draft tier that "
                         "proposes --spec-k tokens per lane inside the one "
                         "compiled step; the lane's serving tier verifies "
                         "all of them in a single batched pass (tokens stay "
                         "bit-identical; typically 'topk' or 'fmbe')")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="drafted tokens per lane per speculative round "
                         "(ignored without --spec-draft)")
    ap.add_argument("--spec-draft-probes", type=int, default=0,
                    help="IVF probes for the draft pass (0 = half the "
                         "serving tier's n_probe; the draft must be cheaper "
                         "than the verifier for speculation to pay)")
    ap.add_argument("--prefix-cache-blocks", type=int, default=0,
                    help="device-resident shared-prefix KV pool capacity in "
                         "token blocks; admissions with a cached prefix of "
                         "L tokens skip L replay steps (0 = off)")
    ap.add_argument("--prefix-block-tokens", type=int, default=8,
                    help="tokens per prefix-pool block (match granularity)")
    ap.add_argument("--admit-window", type=int, default=0,
                    help="admission lookahead: hold up to N queue-head "
                         "requests whose prefix-cache-preferred replica is "
                         "full, admitting the first fit instead "
                         "(0 = strict FIFO)")
    ap.add_argument("--admit-hold", type=int, default=8,
                    help="force-admit a held request anywhere after this "
                         "many holds (bounds unfairness)")
    ap.add_argument("--verify-index-every", type=int, default=0,
                    help="digest-verify (and restore) the serving tier's "
                         "IVF index every N steps (0 = off)")
    ap.add_argument("--no-health-guard", action="store_true",
                    help="disable the in-step estimator health guard "
                         "(non-finite log-Z / empty probe union -> exact "
                         "fallback)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write per-request lifecycle spans + step phases "
                         "as Chrome-trace/Perfetto JSONL to PATH "
                         "(summarize with repro.launch.obs_report)")
    ap.add_argument("--metrics-port", type=int, default=0,
                    help="serve Prometheus text metrics on "
                         "127.0.0.1:PORT/metrics (0 = off)")
    ap.add_argument("--metrics-snapshot", default=None, metavar="PATH",
                    help="write periodic JSON metric snapshots to PATH")
    ap.add_argument("--harvest-every", type=int, default=16,
                    help="steps between device->host metric harvests")
    ap.add_argument("--shadow-every", type=int, default=16,
                    help="steps between shadow-sampled exact log-Z passes "
                         "feeding the live per-tier rel-err stream "
                         "(0 = off)")
    ap.add_argument("--stream", action="store_true",
                    help="print every completion as it finishes")
    ap.add_argument("--sequential", action="store_true",
                    help="also run the one-request-at-a-time generate() "
                         "baseline over the same workload")
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if args.method:
        cfg = dataclasses.replace(
            cfg, partition=dataclasses.replace(cfg.partition,
                                               method=args.method))
    mesh = None
    if args.mesh:
        from .mesh import make_serving_mesh
        kv = dict(part.split("=", 1) for part in args.mesh.split(","))
        unknown = set(kv) - {"data", "model"}
        if unknown:
            raise SystemExit(f"--mesh keys must be data/model, got "
                             f"{sorted(unknown)}")
        mesh = make_serving_mesh(data=int(kv.get("data", 1)),
                                 model=int(kv.get("model", 1)))

    model = Model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    max_len = args.prompt_len_max + args.gen + 1
    eng = Engine(model, params, max_len=max_len, key=key,
                 use_pallas=args.use_pallas, mesh=mesh)
    mesh_note = "" if mesh is None else \
        f"  mesh data={mesh.shape['data']},model={mesh.shape['model']}"
    print(f"arch {cfg.name}  Z-method {cfg.partition.method}  "
          f"vocab {cfg.vocab}  slots {args.slots}{mesh_note}")

    if cfg.n_codebooks:
        # audio codebook heads have no slot-table path (multi-stream
        # tokens); keep the pre-scheduler synchronous batch demo working
        print("audio arch: serving one synchronous generate() batch "
              "(no continuous batching for codebook heads)")
        shape = (args.slots, args.prompt_len_min, cfg.n_codebooks)
        prompt = jax.random.randint(key, shape, 0, cfg.vocab)
        t0 = time.perf_counter()
        toks = generate(eng, prompt, args.gen, key,
                        temperature=args.temperature)
        jax.block_until_ready(toks)
        dt = time.perf_counter() - t0
        n_tok = args.slots * args.gen
        print(f"generated {args.slots}x{args.gen} codebook tokens in "
              f"{dt:.2f}s ({n_tok / dt:.1f} tok/s)")
        return

    reqs = build_workload(args.requests, cfg.vocab, args.gen,
                          args.prompt_len_min, args.prompt_len_max,
                          args.temperature, args.seed)
    if args.stream:
        for r in reqs:
            r.on_complete = lambda req, comp: print(
                f"  req {req.req_id:3d} T={req.temperature:.1f} "
                f"len {len(req.prompt):2d} -> {comp.tokens[:8]}"
                f"{'...' if len(comp.tokens) > 8 else ''}")

    sched = Scheduler(eng, n_slots=args.slots, key=key,
                      spec_draft=args.spec_draft, spec_k=args.spec_k,
                      spec_draft_probes=args.spec_draft_probes,
                      prefix_cache_blocks=args.prefix_cache_blocks,
                      prefix_block_tokens=args.prefix_block_tokens)
    srv_cfg = ServingConfig(
        max_queue=args.max_queue, default_deadline=args.deadline,
        degrade_ladder=tuple(args.ladder.split(",")) if args.ladder else (),
        degrade_high=args.degrade_high, degrade_low=args.degrade_low,
        degrade_after=args.degrade_after, restore_after=args.restore_after,
        health_guard=not args.no_health_guard,
        verify_index_every=args.verify_index_every,
        admit_window=args.admit_window, admit_hold=args.admit_hold)
    obs = None
    if args.trace_out or args.metrics_port or args.metrics_snapshot:
        obs = Observability(ObsConfig(
            harvest_every=args.harvest_every,
            shadow_every=args.shadow_every,
            trace_path=args.trace_out or "",
            metrics_port=args.metrics_port,
            snapshot_path=args.metrics_snapshot or ""))
        if obs.port:
            print(f"  metrics: http://127.0.0.1:{obs.port}/metrics")
    server = Server(sched, srv_cfg, obs=obs)
    arrivals = poisson_arrivals(reqs, rate=args.rate, seed=args.seed)
    rep = server.run(arrivals=arrivals)
    print("continuous:", rep.summary())
    if obs is not None:
        h = obs.last_harvest or {}
        shadow = h.get("shadow_by_tier", {})
        live = {t: f"{v['rel_err_mean']:.2e}/{v['rel_err_max']:.2e}"
                for t, v in shadow.items() if v["count"]}
        if live:
            print(f"  shadow rel-err mean/max by tier: {live}")
        if args.trace_out:
            print(f"  trace: {args.trace_out} "
                  f"({obs.tracer.events_written} events)")
        if args.metrics_snapshot:
            print(f"  snapshot: {args.metrics_snapshot}")
        obs.close()
    step_extra = sched.step_traces - max(len(sched.traces_by_tier), 1)
    print(f"  recompiles after warmup would be: step={step_extra} "
          f"admit={sched.admit_traces - 1} (0 expected; one trace per "
          f"served tier: {dict(sched.traces_by_tier)})")
    if rep.dedup_by_fill:
        fills = ", ".join(f"{k}:{v:.2f}" for k, v in
                          rep.dedup_by_fill.items())
        print(f"  probe-union dedup by batch fill: {fills}")
    if rep.rejects_by_reason or rep.tier_transitions or \
            rep.index_restores or any(rep.health.values()):
        print(f"  robustness: shed_rate {rep.shed_rate:.2f} "
              f"(by reason: {dict(rep.rejects_by_reason)}), "
              f"queue peak {rep.queue_depth_peak}")
        if rep.tier_transitions:
            path = " -> ".join(f"{t}@{s}" for s, t in rep.tier_transitions)
            print(f"  tier transitions: {path}; tokens by tier "
                  f"{dict(rep.tokens_by_tier)} "
                  f"(degraded frac {rep.degraded_token_frac:.2f})")
        if rep.index_restores or any(rep.health.values()):
            print(f"  guards: health {dict(rep.health)}, index restores "
                  f"{rep.index_restores}, step faults {rep.step_faults}")

    if args.sequential:
        # warm each compile bucket first so the comparison is steady-state
        seen = set()
        for r in reqs:
            b = 1 << (len(r.prompt) - 1).bit_length()
            if b not in seen:
                seen.add(b)
                jax.block_until_ready(generate(
                    eng, jnp.asarray(r.prompt)[None], r.max_new_tokens,
                    r.key, temperature=r.temperature))
        t0 = time.perf_counter()
        tot = 0
        for r in reqs:
            toks = generate(eng, jnp.asarray(r.prompt)[None],
                            r.max_new_tokens, r.key,
                            temperature=r.temperature)
            tot += int(jnp.asarray(toks).shape[1])
        jax.block_until_ready(toks)
        dt = time.perf_counter() - t0
        print(f"sequential: {tot} tokens in {dt:.2f}s "
              f"({tot / dt:.1f} tok/s); continuous speedup "
              f"{rep.goodput_tok_s / (tot / dt):.2f}x")


if __name__ == "__main__":
    main()
