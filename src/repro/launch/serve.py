"""Serving driver: batched decode with configurable partition estimation.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-4b --reduced \
      --batch 8 --prompt-len 16 --gen 16 --method mimps
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, reduced_config
from ..models import Model
from ..serve import Engine, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--method", default=None,
                    choices=[None, "exact", "mimps", "selfnorm", "uniform"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if args.method:
        cfg = dataclasses.replace(
            cfg, partition=dataclasses.replace(cfg.partition,
                                               method=args.method))
    model = Model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    eng = Engine(model, params, max_len=args.prompt_len + args.gen + 1,
                 key=key)
    print(f"arch {cfg.name}  Z-method {cfg.partition.method}  "
          f"vocab {cfg.vocab}")

    shape = (args.batch, args.prompt_len) if not cfg.n_codebooks else \
        (args.batch, args.prompt_len, cfg.n_codebooks)
    prompt = jax.random.randint(key, shape, 0, cfg.vocab)
    t0 = time.perf_counter()
    toks = generate(eng, prompt, args.gen, key)
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    print(f"generated {args.batch}x{args.gen} tokens in {dt:.2f}s "
          f"({args.batch*args.gen/dt:.1f} tok/s)")
    print("sample stream 0:", [int(t) for t in
                               jnp.asarray(toks)[0].reshape(-1)[:16]])


if __name__ == "__main__":
    main()
