"""Production train driver: elastic mesh, checkpoint/auto-resume, straggler
watchdog, deterministic resumable data.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-4b --reduced \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On a real cluster each host runs this same script; jax.distributed handles
process groups. On this single-host container it drives the 1-device mesh —
the code path (mesh build -> restore -> step loop -> checkpoint) is the one
the dry run lowers at (16, 16).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, reduced_config
from ..configs.base import TrainConfig
from ..data import DataIterator, SyntheticCorpus
from ..models import Model
from ..train import (CheckpointManager, StragglerWatchdog,
                     harvest_train_metrics, init_train_metric_state,
                     init_train_state, make_elastic_mesh,
                     make_index_refresh, make_instrumented_step,
                     make_train_step)
from ..train.losses import ESTIMATOR_LOSSES, LOSSES


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--reduced", action="store_true",
                    help="laptop-scale config of the same family")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    # choices from the registry so a typo (or a loss added without wiring)
    # fails at parse time — the same stale-list bug class launch/serve.py
    # --method had before it read the backend registry
    ap.add_argument("--loss", default="fused_ce", choices=sorted(LOSSES))
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--index-refresh-every", type=int, default=100,
                    help="steps between IVF index refreshes (estimator-"
                         "backed losses only; shapes are static so the "
                         "refresh never recompiles; 0 disables refreshes)")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--harvest-every", type=int, default=10,
                    help="steps between device->host metric syncs; the "
                         "loop only block_until_ready's on this cadence "
                         "(device counters accumulate loss/grad stats "
                         "in between — obs layer, DESIGN.md SS17)")
    ap.add_argument("--metrics-snapshot", default="", metavar="PATH",
                    help="write harvested train metrics as JSON to PATH "
                         "at the end of the run")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    model = Model(cfg)
    tc = TrainConfig(lr=args.lr, total_steps=args.steps, loss=args.loss,
                     microbatches=args.microbatches, seed=args.seed,
                     warmup_steps=max(1, args.steps // 10),
                     index_refresh_every=args.index_refresh_every)
    mesh = make_elastic_mesh(model_parallel=args.model_parallel)
    print(f"mesh: {dict(mesh.shape)}  arch: {cfg.name}  "
          f"params: {cfg.param_count()/1e6:.1f}M")

    corpus = SyntheticCorpus(vocab=cfg.vocab, seed=args.seed)
    it = DataIterator(corpus, args.batch, args.seq,
                      n_codebooks=cfg.n_codebooks)
    state = init_train_state(model, tc, jax.random.PRNGKey(args.seed))

    mgr = None
    start_step = 0
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=3)
        latest = mgr.latest_step()
        if latest is not None:
            state, manifest = mgr.restore(latest, like=state)
            start_step = manifest["step"]
            it.state.step = manifest["extra"].get("data_step", start_step)
            print(f"resumed from step {start_step}")

    step_fn = jax.jit(make_instrumented_step(make_train_step(model, tc)))
    refresh_fn = make_index_refresh(model, tc) \
        if tc.loss in ESTIMATOR_LOSSES and tc.index_refresh_every > 0 \
        else None
    wd = StragglerWatchdog()
    tm = init_train_metric_state()
    sync_every = max(args.harvest_every, 1)
    with mesh:
        for step in range(start_step, args.steps):
            toks, labels = next(it)
            batch = {"tokens": jnp.asarray(toks),
                     "labels": jnp.asarray(labels)}
            if cfg.family == "vlm":
                batch["img"] = jnp.zeros(
                    (args.batch, cfg.n_image_tokens, cfg.d_model),
                    jnp.dtype(cfg.dtype))
            wd.start_step()
            # cadence keyed on the GLOBAL step (not the resume offset) so a
            # resumed run refreshes at exactly the same steps as an
            # uninterrupted one — resume determinism includes the index
            refreshed = ""
            if refresh_fn is not None and step > 0 and \
                    step % tc.index_refresh_every == 0:
                state, rm = refresh_fn(state)
                refreshed = (f" [refresh churn {float(rm['churn']):.3f}"
                             f" drift {float(rm['drift']):.3f}]")
            state, tm, metrics = step_fn(state, tm, batch)
            # only synchronize with the device on the harvest/log cadence —
            # between syncs the dispatch queue runs ahead and the device
            # counters (TrainMetricState) carry the per-step stats
            log_now = (step % 10 == 0 or step == args.steps - 1
                       or bool(refreshed))
            if log_now or (step + 1) % sync_every == 0:
                jax.block_until_ready(metrics["loss_total"])
            slow = wd.end_step(step)
            if log_now:
                print(f"step {step:5d} loss {float(metrics['loss_total']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e}"
                      + (" [straggler]" if slow else "") + refreshed)
            if mgr and (step + 1) % args.ckpt_every == 0:
                mgr.save(step + 1, state,
                         extra={"data_step": it.state.step})
    th = harvest_train_metrics(tm)
    print(f"train metrics: loss mean {th['loss_mean']:.4f} "
          f"std {th['loss_std']:.4f} max {th['loss_max']:.4f}  "
          f"gnorm mean {th['grad_norm_mean']:.3f} "
          f"max {th['grad_norm_max']:.3f}  "
          f"nonfinite steps {th['nonfinite_steps']}/{th['steps']}")
    if args.metrics_snapshot:
        import json
        with open(args.metrics_snapshot, "w", encoding="utf-8") as fh:
            json.dump(th, fh, indent=1)
        print(f"train metrics snapshot: {args.metrics_snapshot}")
    if mgr:
        mgr.save(args.steps, state, extra={"data_step": it.state.step})
        mgr.wait()
        print(f"final checkpoint at {args.ckpt_dir}")


if __name__ == "__main__":
    main()
