"""Hillclimb profiler: attribute trip-aware bytes/flops/collective bytes to
JAX-level op names (from HLO metadata) for one dry-run cell.

  PYTHONPATH=src python -m repro.launch.breakdown --arch rwkv6-7b \
      --shape train_4k [--top 20] [--kind collective|bytes|flops]
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

import argparse      # noqa: E402
import collections   # noqa: E402
import re            # noqa: E402

import jax           # noqa: E402

from . import mesh as mesh_lib                       # noqa: E402
from .dryrun import build_lowering                   # noqa: E402
from .hlo_analysis import (COLLECTIVES, _called, _dot_flops,  # noqa: E402
                           _fusion_operand_traffic,
                           _root_dus_update_bytes, parse_module,
                           ELEMENTWISE, TRANSCENDENTAL)


def meta_tag(line: str) -> str:
    m = re.search(r'op_name="([^"]+)"', line)
    if not m:
        return "(no-metadata)"
    tag = m.group(1)
    tag = re.sub(r"\[.*?\]", "", tag)
    parts = tag.split("/")
    return "/".join(parts[-3:])[:70]


def run(arch, shape, mesh_kind="single", output_mode="exact", top=20,
        kind="collective"):
    mesh = mesh_lib.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    with mesh:
        lowered, _ = build_lowering(arch, shape, mesh, output_mode)
        compiled = lowered.compile()
    comps = parse_module(compiled.as_text())

    def trip(c):
        if c is None or c not in comps:
            return 1
        cs = comps[c].consts
        return max(cs) if cs else 1

    agg = collections.Counter()
    skip = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
            "broadcast", "iota", "reshape", "after-all", "convert", "copy",
            "transpose", "while"}

    def walk(name, mult):
        comp = comps.get(name)
        if comp is None:
            return
        for nm, rtype, op, line in comp.instrs:
            elems, rb = comp.shapes[nm]
            args = line.split("(", 1)[1] if "(" in line else ""
            onames = [o for o in re.findall(r"%([\w.\-]+)", args)
                      if o in comp.shapes]
            ob = [comp.shapes[o][1] for o in onames]
            val = 0
            base = op[:-6] if op.endswith("-start") else op
            if kind == "collective":
                if base in COLLECTIVES and not op.endswith("-done"):
                    val = rb
            elif kind == "bytes":
                if op == "fusion":
                    fm = re.search(r"calls=%?([\w.\-]+)", line)
                    fused = comps.get(fm.group(1)) if fm else None
                    dus = _root_dus_update_bytes(fused)
                    if dus is not None:
                        val = 2 * dus + _fusion_operand_traffic(
                            fused, ob, sliced_only=True)
                    else:
                        val = rb + _fusion_operand_traffic(fused, ob)
                elif op in ("dynamic-slice", "gather"):
                    val = 2 * rb
                elif op in ("dynamic-update-slice", "scatter"):
                    val = 2 * (ob[1] if len(ob) > 1 else rb)
                elif op not in skip:
                    val = rb + sum(ob)
            elif kind == "flops":
                if op == "dot":
                    val = _dot_flops(line, elems, comp)
                elif op in ELEMENTWISE or op in TRANSCENDENTAL:
                    val = elems
            if val:
                agg[(meta_tag(line), op)] += mult * val
            for kd, cond, callee in _called(line):
                walk(callee, mult * (trip(cond) if kd == "while" else 1))

    entry = next(n for n, c in comps.items() if c.entry)
    walk(entry, 1)
    unit = 1e9
    print(f"\n== {kind} breakdown: {arch} x {shape} x {mesh_kind} "
          f"[{output_mode}] (GB or GFLOP per device per step) ==")
    for (tag, op), v in agg.most_common(top):
        print(f"{v/unit:12.3f}  {op:22s} {tag}")
    print(f"{sum(agg.values())/unit:12.3f}  TOTAL")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--output-mode", default="exact")
    ap.add_argument("--top", type=int, default=20)
    ap.add_argument("--kind", default="collective",
                    choices=["collective", "bytes", "flops"])
    a = ap.parse_args()
    run(a.arch, a.shape, a.mesh, a.output_mode, a.top, a.kind)
