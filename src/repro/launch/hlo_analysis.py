"""Trip-count-aware analysis of post-partitioning HLO text.

XLA's HloCostAnalysis (compiled.cost_analysis()) visits every computation
ONCE — lax.scan bodies (layers, microbatches, flash KV blocks) are counted a
single time, which silently under-reports FLOPs/bytes by the loop trip count.
This module re-walks the HLO text and multiplies while-body contributions by
the loop bound (scan loops carry it as a constant in their condition).

Extracted per entry module (per-device numbers, since the module is the SPMD
per-device program):
  * flops          : 2*M*N*K for dot ops (descending into fusions) +
                     1/elem for elementwise arith + transcendentals
  * bytes          : operand+result bytes at top-level instruction boundaries
                     (fusion internals excluded — values stay in registers)
  * collectives    : result bytes by type (all-reduce / all-gather /
                     reduce-scatter / all-to-all / collective-permute)
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2,
               "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
               "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*"
                    r"([\w\-]+)\(")
COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
ELEMENTWISE = {"add", "subtract", "multiply", "divide", "maximum", "minimum",
               "compare", "select", "and", "or", "xor", "negate", "abs",
               "clamp"}
TRANSCENDENTAL = {"exponential", "log", "rsqrt", "sqrt", "tanh", "logistic",
                  "power", "sine", "cosine", "erf", "exponential-minus-one"}


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    elems, total = 0, 0
    for dt, dims in SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for x in dims.split(","):
            if x:
                n *= int(x)
        elems += n
        total += n * DTYPE_BYTES[dt]
    return elems, total


class Computation:
    def __init__(self, name, entry=False):
        self.name = name
        self.entry = entry
        self.instrs = []        # (name, result_type, op, rest_of_line)
        self.consts = []
        self.shapes: Dict[str, Tuple[int, int]] = {}
        self.root = None        # name of the ROOT instruction


def parse_module(hlo_text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur = None
    for line in hlo_text.splitlines():
        line = re.sub(r"/\*.*?\*/", "", line)   # strip /*index=N*/ comments
        cm = COMP_RE.match(line)
        if cm and (line.startswith("%") or line.startswith("ENTRY")):
            cur = Computation(cm.group(2), entry=bool(cm.group(1)))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        dm = DEF_RE.match(line)
        if dm:
            name, rtype, op = dm.group(1), dm.group(2), dm.group(3)
            cur.instrs.append((name, rtype, op, line))
            cur.shapes[name] = _shape_elems_bytes(rtype)
            if re.match(r"^\s*ROOT\b", line):
                cur.root = name
        for c in re.findall(r"constant\((\d+)\)", line):
            cur.consts.append(int(c))
    return comps


def _called(line: str):
    """(kind, [computations]) referenced by this instruction line."""
    out = []
    m = re.search(r"condition=%?([\w.\-]+)", line)
    b = re.search(r"body=%?([\w.\-]+)", line)
    if b:
        out.append(("while", m.group(1) if m else None, b.group(1)))
    cm = re.search(r"calls=%?([\w.\-]+)", line)
    if cm:
        out.append(("fusion", None, cm.group(1)))
    tm = re.search(r"to_apply=%?([\w.\-]+)", line)
    if tm:
        out.append(("call", None, tm.group(1)))
    for br in re.findall(r"(?:true_computation|false_computation|"
                         r"branch_computations)=\{?%?([\w.\-]+)", line):
        out.append(("call", None, br))
    return out


def _dot_flops(line: str, result_elems: int, comp: Computation) -> int:
    """2 * prod(result) * K. K = product of lhs contracting dims."""
    ops = re.findall(r"%([\w.\-]+)", line.split("(", 1)[1])
    lhs_shape = None
    # first operand with a known shape = lhs
    for o in ops:
        if o in comp.shapes:
            m = re.search(rf"%{re.escape(o)}\b", line)
            break
    # contracting dims from the attribute
    cd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    lhs_t = re.search(r"dot\(\s*(\w+\[[0-9,]*\])?", line)
    k = 1
    if cd:
        dims = [int(x) for x in cd.group(1).split(",") if x]
        # find the lhs operand's dims from its definition
        if ops:
            lhs_name = ops[0]
            for nm, rtype, op, dl in comp.instrs:
                if nm == lhs_name:
                    sm = SHAPE_RE.search(rtype)
                    if sm:
                        ds = [int(x) for x in sm.group(2).split(",") if x]
                        for d in dims:
                            if d < len(ds):
                                k *= ds[d]
                    break
            else:
                k = 0
    if k <= 1 and "lhs_contracting_dims" in line:
        k = max(k, 1)
    return 2 * result_elems * max(k, 1)


def _root_dus_update_bytes(fused: "Computation"):
    """If the fusion is an in-place stacked write — it contains a
    dynamic-update-slice covering the whole output (possibly wrapped in
    dtype converts, a CPU bf16-emulation artifact) — return the update
    operand's byte size (the only data that actually moves). Else None."""
    if fused is None or fused.root is None:
        return None
    root_elems = fused.shapes.get(fused.root, (0, 0))[0]
    for nm, rtype, op, line in fused.instrs:
        if op == "dynamic-update-slice" and \
                fused.shapes[nm][0] == root_elems:
            args = line.split("(", 1)[1] if "(" in line else ""
            ops_in = [o for o in re.findall(r"%([\w.\-]+)", args)
                      if o in fused.shapes]
            if len(ops_in) > 1:
                return fused.shapes[ops_in[1]][1]
    return None


def _fusion_operand_traffic(fused: "Computation", operand_bytes,
                            sliced_only: bool = False) -> int:
    """HBM reads of a fusion: parameters consumed only through
    (dynamic-)slice/gather ops contribute their slice bytes; parameters
    consumed whole contribute full bytes (or nothing if sliced_only)."""
    if fused is None:
        return sum(operand_bytes)
    param_of = {}
    for nm, rtype, op, line in fused.instrs:
        if op == "parameter":
            pm = re.search(r"parameter\((\d+)\)", line)
            if pm:
                param_of[nm] = int(pm.group(1))
    total = 0
    for pname, pidx in param_of.items():
        if pidx >= len(operand_bytes):
            continue
        slice_bytes = 0
        whole = False
        used = False
        for nm, rtype, op, line in fused.instrs:
            if op == "parameter":
                continue
            args = line.split("(", 1)[1] if "(" in line else ""
            ops_in = re.findall(r"%([\w.\-]+)", args)
            if pname in ops_in:
                used = True
                if op in ("dynamic-slice", "slice", "gather") and \
                        ops_in and ops_in[0] == pname:
                    slice_bytes += fused.shapes[nm][1]
                else:
                    whole = True
        if not used:
            continue
        if whole:
            total += 0 if sliced_only else operand_bytes[pidx]
        else:
            total += slice_bytes
    return total


def analyze(hlo_text: str) -> Dict[str, float]:
    comps = parse_module(hlo_text)
    # global shape table for cross-computation operand lookup (dot lhs)
    for c in comps.values():
        pass

    def trip(cond_name):
        if cond_name is None or cond_name not in comps:
            return 1
        cs = comps[cond_name].consts
        return max(cs) if cs else 1

    memo_f, memo_b, memo_c = {}, {}, {}

    def walk(name: str, for_bytes: bool):
        comp = comps.get(name)
        if comp is None:
            return (0, 0.0, {}) if not for_bytes else 0
        key = name
        memo = memo_b if for_bytes else memo_f
        if key in memo:
            return memo[key]
        if for_bytes:
            # CPU-backend artifacts excluded from the TPU-target byte model:
            #  convert  - CPU has no native bf16 compute; converts fuse on TPU
            #  copy     - loop double-buffering artifacts; in-place on TPU
            #  transpose- layout normalization; fused on TPU
            skip = {"parameter", "constant", "get-tuple-element", "tuple",
                    "bitcast", "broadcast", "iota", "reshape", "after-all",
                    "convert", "copy", "transpose", "while"}
            total = 0
            for nm, rtype, op, line in comp.instrs:
                elems, rbytes = comp.shapes[nm]
                args = line.split("(", 1)[1] if "(" in line else ""
                opnames = [o for o in re.findall(r"%([\w.\-]+)", args)
                           if o in comp.shapes]
                operand_bytes = [comp.shapes[o][1] for o in opnames]
                if op == "fusion":
                    fm = re.search(r"calls=%?([\w.\-]+)", line)
                    fused = comps.get(fm.group(1)) if fm else None
                    root_dus_upd = _root_dus_update_bytes(fused)
                    if root_dus_upd is not None:
                        # in-place stacked write: only the slice moves
                        total += 2 * root_dus_upd + _fusion_operand_traffic(
                            fused, operand_bytes, sliced_only=True)
                    else:
                        total += rbytes + _fusion_operand_traffic(
                            fused, operand_bytes)
                elif op in ("dynamic-slice", "gather"):
                    total += 2 * rbytes          # slice read + write only
                elif op in ("dynamic-update-slice", "scatter"):
                    upd = operand_bytes[1] if len(operand_bytes) > 1 else \
                        rbytes
                    total += 2 * upd             # in-place update traffic
                elif op not in skip:
                    total += rbytes + sum(operand_bytes)
                for kind, cond, callee in _called(line):
                    if kind == "while":
                        total += trip(cond) * walk(callee, True)
                    elif kind == "call":
                        total += walk(callee, True)
                    # fusion internals handled above
            memo[key] = total
            return total
        flops = 0.0
        trans = 0.0
        for nm, rtype, op, line in comp.instrs:
            elems, rbytes = comp.shapes[nm]
            if op == "dot":
                flops += _dot_flops(line, elems, comp)
            elif op == "convolution":
                # window size from the kernel operand is hard to recover
                # from text reliably; count 2*result*K with K from
                # window={size=...}
                wm = re.search(r"window=\{size=([0-9x]+)", line)
                k = 1
                if wm:
                    for x in wm.group(1).split("x"):
                        k *= int(x)
                flops += 2 * elems * k
            elif op in ELEMENTWISE:
                flops += elems
            elif op in TRANSCENDENTAL:
                trans += elems
                flops += elems
            elif op == "reduce":
                flops += elems  # approximation: one op per output elem lost
            for kind, cond, callee in _called(line):
                mult = trip(cond) if kind == "while" else 1
                f2, t2 = walk(callee, False)
                flops += mult * f2
                trans += mult * t2
        memo[key] = (flops, trans)
        return memo[key]

    def walk_coll(name: str):
        comp = comps.get(name)
        if comp is None:
            return {}
        if name in memo_c:
            return memo_c[name]
        memo_c[name] = {}
        out: Dict[str, int] = {}
        for nm, rtype, op, line in comp.instrs:
            base = op[:-6] if op.endswith("-start") else op
            if base in COLLECTIVES and not op.endswith("-done"):
                _, b = comp.shapes[nm]
                out[base] = out.get(base, 0) + b
            for kind, cond, callee in _called(line):
                mult = trip(cond) if kind == "while" else 1
                for k2, v2 in walk_coll(callee).items():
                    out[k2] = out.get(k2, 0) + mult * v2
        memo_c[name] = out
        return out

    entry = next((n for n, c in comps.items() if c.entry), None)
    if entry is None:
        return {"flops": 0, "bytes": 0, "collectives": {}}
    flops, trans = walk(entry, False)
    nbytes = walk(entry, True)
    colls = walk_coll(entry)
    return {"flops": float(flops), "transcendentals": float(trans),
            "bytes": float(nbytes), "collectives": colls}
