"""Device-resident serving metrics (DESIGN.md SS17).

The scheduler threads ONE registered pytree of counters/histograms
(``MetricState``) through its compiled step, unconditionally: observability
"on" vs "off" differ only in host-side harvest cadence and the traced
shadow-sampling flag, never in which executable runs — that is what keeps
tokens bit-identical and the trace counters pinned. Updates read only from
values the step already computed (emitted counts, health flags, the
probe-union size); nothing here feeds back into the token path.

Under the (data, model) serving mesh the state is replicated (``P()`` in and
out of ``shard_map``): each replica's local contributions are psum-reduced
over ``'data'`` inside ``observe_step`` before accumulation, so every
replica holds the same global counters and the host can harvest any one
shard.

Harvesting is a cadence-controlled ``jax.device_get`` of the whole pytree —
the only device->host traffic observability adds (the per-step ``outs``
readback already exists for token streaming and stays untouched).

The step-latency histogram is fed forward: the host measures step N's
device phase and passes it into step N+1 as traced data (``last_ms`` /
``last_tier``), so the buckets live on device with everything else and no
extra sync point appears. ``last_ms < 0`` (the first step) records nothing.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..core.backends import BACKENDS
from ..core.decode import (HEALTH_EMPTY_HEAD, HEALTH_NONFINITE_SCORE,
                           HEALTH_NONFINITE_Z)

# canonical tier order: every per-tier row in the metric state is indexed by
# position in this tuple (static per compiled tier step, so the .at[] adds
# constant-fold their row index)
TIERS: tuple = tuple(sorted(BACKENDS))
TIER_IX: dict = {t: i for i, t in enumerate(TIERS)}

# bucket UPPER edges, shared by device accumulation, harvest, the serving
# benchmark rows and obs_report: value v lands in the first bucket whose
# edge exceeds it; the trailing bucket is the +inf overflow
LATENCY_EDGES_MS: tuple = (0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0,
                           200.0, 500.0, 1000.0, 5000.0)
QUEUE_EDGES: tuple = (0, 1, 2, 4, 8, 16, 32, 64, 128)
OCC_EDGES: tuple = (0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9)

_NT = len(TIERS)
_NL = len(LATENCY_EDGES_MS) + 1
_NQ = len(QUEUE_EDGES) + 1
_NO = len(OCC_EDGES) + 1


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MetricState:
    """One pytree of monotone counters (Prometheus semantics: harvest reads
    cumulative values and never resets them mid-run)."""
    steps: jax.Array            # ()   steps observed
    tokens_total: jax.Array     # ()   emitted tokens
    tokens_by_tier: jax.Array   # (T,) emitted tokens per estimator tier
    active_sum: jax.Array       # ()   sum of live lanes per step (gauge avg)
    fill_sum: jax.Array         # ()   sum of probe-union live blocks
    queue_sum: jax.Array        # ()   sum of admission-queue depth per step
    queue_hist: jax.Array       # (NQ,) queue-depth histogram
    occ_hist: jax.Array         # (NO,) occupancy-fraction histogram
    latency_hist: jax.Array     # (T, NL) device-step-ms histogram per tier
    health_flagged: jax.Array   # ()   lane-steps health-guard flagged
    health_by_cause: jax.Array  # (3,) [nonfinite_z, empty_head,
                                #       nonfinite_score] lane-steps
    spec_proposed: jax.Array    # ()   speculative positions offered
    spec_accepted: jax.Array    # ()   speculative positions advanced
    draft_flagged: jax.Array    # ()   draft-health fallbacks to k=1
    shadow_count: jax.Array     # (T,) lane-steps shadow-sampled per tier
    shadow_err_sum: jax.Array   # (T,) f32 sum of |Ẑ/Z - 1| over samples
    shadow_err_max: jax.Array   # (T,) f32 max |Ẑ/Z - 1| seen


def init_metric_state() -> MetricState:
    z = lambda *s: jnp.zeros(s, jnp.int32)
    zf = lambda *s: jnp.zeros(s, jnp.float32)
    return MetricState(
        steps=z(), tokens_total=z(), tokens_by_tier=z(_NT),
        active_sum=z(), fill_sum=z(), queue_sum=z(),
        queue_hist=z(_NQ), occ_hist=z(_NO), latency_hist=z(_NT, _NL),
        health_flagged=z(), health_by_cause=z(3),
        spec_proposed=z(), spec_accepted=z(), draft_flagged=z(),
        shadow_count=z(_NT), shadow_err_sum=zf(_NT), shadow_err_max=zf(_NT))


def _bucket(edges: tuple, v) -> jax.Array:
    return jnp.searchsorted(jnp.asarray(edges, jnp.float32),
                            jnp.asarray(v, jnp.float32), side="left")


def observe_step(m: MetricState, tier_ix: int, n_slots: int, *,
                 n_active, head_live, n_emitted, health_flags,
                 queue_depth, last_ms, last_tier, shadow=None,
                 spec_proposed=None, spec_accepted=None, draft_flagged=None,
                 axis_name=None) -> MetricState:
    """Accumulate one step into the metric state (traced; runs inside the
    compiled scheduler step).

    ``n_active`` / ``head_live`` are already GLOBAL (the step body psums
    them for its own outs); ``n_emitted``, ``health_flags`` (per local
    lane), the spec scalars and the ``shadow`` triple are this replica's
    local contributions and get psum-reduced here when ``axis_name`` is
    set. ``queue_depth`` / ``last_ms`` / ``last_tier`` are replicated host
    scalars.
    """
    i32 = jnp.int32
    hf = jnp.asarray(health_flags)
    flagged = (hf > 0).sum().astype(i32)
    causes = jnp.stack([
        ((hf & HEALTH_NONFINITE_Z) > 0).sum(),
        ((hf & HEALTH_EMPTY_HEAD) > 0).sum(),
        ((hf & HEALTH_NONFINITE_SCORE) > 0).sum()]).astype(i32)
    n_emitted = jnp.asarray(n_emitted, i32)
    sp = i32(0) if spec_proposed is None else jnp.asarray(spec_proposed, i32)
    sa = i32(0) if spec_accepted is None else jnp.asarray(spec_accepted, i32)
    df = i32(0) if draft_flagged is None else jnp.asarray(draft_flagged, i32)
    if shadow is None:
        sh_sum, sh_max, sh_n = (jnp.float32(0.0), jnp.float32(0.0), i32(0))
    else:
        sh_sum, sh_max, sh_n = shadow
    if axis_name is not None:
        n_emitted = jax.lax.psum(n_emitted, axis_name)
        flagged = jax.lax.psum(flagged, axis_name)
        causes = jax.lax.psum(causes, axis_name)
        sp = jax.lax.psum(sp, axis_name)
        sa = jax.lax.psum(sa, axis_name)
        df = jax.lax.psum(df, axis_name)
        sh_sum = jax.lax.psum(sh_sum, axis_name)
        sh_n = jax.lax.psum(sh_n, axis_name)
        sh_max = jax.lax.pmax(sh_max, axis_name)
    n_active = jnp.asarray(n_active, i32)
    lat_ok = (jnp.asarray(last_ms, jnp.float32) >= 0.0).astype(i32)
    lat_b = _bucket(LATENCY_EDGES_MS, last_ms)
    occ_b = _bucket(OCC_EDGES, n_active.astype(jnp.float32) / n_slots)
    q_b = _bucket(QUEUE_EDGES, queue_depth)
    return dataclasses.replace(
        m,
        steps=m.steps + 1,
        tokens_total=m.tokens_total + n_emitted,
        tokens_by_tier=m.tokens_by_tier.at[tier_ix].add(n_emitted),
        active_sum=m.active_sum + n_active,
        fill_sum=m.fill_sum + jnp.asarray(head_live, i32),
        queue_sum=m.queue_sum + jnp.asarray(queue_depth, i32),
        queue_hist=m.queue_hist.at[q_b].add(1),
        occ_hist=m.occ_hist.at[occ_b].add(1),
        latency_hist=m.latency_hist.at[jnp.asarray(last_tier, i32),
                                       lat_b].add(lat_ok),
        health_flagged=m.health_flagged + flagged,
        health_by_cause=m.health_by_cause + causes,
        spec_proposed=m.spec_proposed + sp,
        spec_accepted=m.spec_accepted + sa,
        draft_flagged=m.draft_flagged + df,
        shadow_count=m.shadow_count.at[tier_ix].add(sh_n),
        shadow_err_sum=m.shadow_err_sum.at[tier_ix].add(sh_sum),
        shadow_err_max=m.shadow_err_max.at[tier_ix].max(sh_max))


def shadow_rel_err(log_z, ref_log_z, active) -> tuple:
    """Masked relative error of the serving estimate against the exact
    shadow oracle: rel = |exp(log Ẑ - log Z) - 1| = |Ẑ/Z - 1|, the paper's
    multiplicative-guarantee error. Inactive lanes and non-finite values
    (injected faults; lanes the guard already replaced) are excluded.
    Returns the (sum, max, count) triple ``observe_step`` accumulates.

    Unbiasedness: the sampling cadence is a host counter, independent of
    the data each step decodes, so the sampled steps are a deterministic
    systematic sample of the step stream — E[err_sum/count] is the mean
    per-lane rel-err over sampled steps with no selection on the value.
    """
    rel = jnp.abs(jnp.expm1(jnp.asarray(log_z, jnp.float32)
                            - jnp.asarray(ref_log_z, jnp.float32)))
    ok = jnp.asarray(active, bool) & jnp.isfinite(rel)
    relm = jnp.where(ok, rel, 0.0)
    return (relm.sum(), relm.max(initial=0.0),
            ok.sum().astype(jnp.int32))


def harvest(m: MetricState, n_slots: int) -> dict:
    """ONE device->host read of the whole metric pytree, flattened into a
    plain dict (python scalars + per-tier sub-dicts) for the registry,
    snapshots and the serving benchmark. Non-destructive: counters stay
    cumulative on device."""
    g = jax.device_get(m)
    steps = int(g.steps)
    tiers_tok = {t: int(g.tokens_by_tier[i]) for t, i in TIER_IX.items()
                 if int(g.tokens_by_tier[i])}
    shadow = {}
    for t, i in TIER_IX.items():
        n = int(g.shadow_count[i])
        if n:
            shadow[t] = {"count": n,
                         "rel_err_mean": float(g.shadow_err_sum[i]) / n,
                         "rel_err_max": float(g.shadow_err_max[i])}
    lat = {t: [int(c) for c in g.latency_hist[i]]
           for t, i in TIER_IX.items() if int(g.latency_hist[i].sum())}
    return {
        "steps": steps,
        "tokens_total": int(g.tokens_total),
        "tokens_by_tier": tiers_tok,
        "occupancy_mean": float(g.active_sum) / (max(steps, 1) * n_slots),
        "fill_mean": float(g.fill_sum) / max(steps, 1),
        "queue_depth_mean": float(g.queue_sum) / max(steps, 1),
        "queue_hist": [int(c) for c in g.queue_hist],
        "queue_edges": list(QUEUE_EDGES),
        "occ_hist": [int(c) for c in g.occ_hist],
        "occ_edges": list(OCC_EDGES),
        "latency_hist_by_tier": lat,
        "latency_edges_ms": list(LATENCY_EDGES_MS),
        "health_flagged": int(g.health_flagged),
        "health_by_cause": {
            "nonfinite_z": int(g.health_by_cause[0]),
            "empty_head": int(g.health_by_cause[1]),
            "nonfinite_score": int(g.health_by_cause[2])},
        "spec_proposed": int(g.spec_proposed),
        "spec_accepted": int(g.spec_accepted),
        "draft_flagged": int(g.draft_flagged),
        "shadow_by_tier": shadow,
    }


def hist_quantile(counts, edges, q: float) -> float:
    """Quantile from a bucketed histogram: the upper edge of the bucket
    where the cumulative count crosses q (clamped to the last finite edge
    for the overflow bucket — histogram quantiles are bucket-resolution
    upper bounds, never interpolated guesses)."""
    counts = np.asarray(counts, np.int64)
    total = int(counts.sum())
    if total == 0:
        return float("nan")
    cum = np.cumsum(counts)
    b = int(np.searchsorted(cum, q * total))
    return float(edges[min(b, len(edges) - 1)])
