"""Per-request span tracing: Chrome-trace / Perfetto-compatible JSONL.

One JSON event object per line (the streaming flavor of the Trace Event
Format — ``chrome://tracing`` and Perfetto both ingest it after wrapping in
a ``[...]`` array, which ``launch/obs_report.py --to-json`` does). Events
use wall-clock microseconds relative to the writer's creation:

 * ``X`` complete spans — request lifecycle phases (queued / replay /
   decode / request) on tid = request id, and per-step engine phases
   (device vs host time) on the scheduler's tid 0;
 * ``i`` instants — enqueue, admit, shed/evict, tier transitions, index
   swap/restore;
 * ``C`` counters — harvested gauges (queue depth, occupancy, per-tier
   shadow rel-err), drawn as tracks;
 * ``M`` metadata — thread names.

Everything is host-side and append-only. Events buffer as plain dicts in
the serving loop and serialize in batches at ``flush()`` / ``close()`` —
JSON encoding stays off the goodput-critical path, and a crashed run
leaves a readable prefix through the last flush (the buffer also
self-flushes past ``MAX_BUFFERED`` events to bound memory). No external
deps.
"""
from __future__ import annotations

import json
import time
from typing import List, Optional


class TraceWriter:
    PID = 1
    MAX_BUFFERED = 16384

    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "w")
        self._t0 = time.perf_counter()
        self._named_tids: set = set()
        self._buf: List[dict] = []
        self.events_written = 0
        self.name_thread(0, "scheduler")

    def _ts(self, t: Optional[float]) -> float:
        """Wall stamp (time.perf_counter seconds) -> trace µs."""
        return ((time.perf_counter() if t is None else t) - self._t0) * 1e6

    def _emit(self, ev: dict) -> None:
        self._buf.append(ev)
        self.events_written += 1
        if len(self._buf) >= self.MAX_BUFFERED:
            self.flush()

    def name_thread(self, tid: int, name: str) -> None:
        if tid in self._named_tids:
            return
        self._named_tids.add(tid)
        self._emit({"ph": "M", "name": "thread_name", "pid": self.PID,
                    "tid": tid, "args": {"name": name}})

    def span(self, name: str, t_start: float, t_end: float, tid: int = 0,
             cat: str = "serve", args: Optional[dict] = None) -> None:
        ts = self._ts(t_start)
        self._emit({"ph": "X", "name": name, "cat": cat, "pid": self.PID,
                    "tid": tid, "ts": ts,
                    "dur": max(self._ts(t_end) - ts, 0.0),
                    "args": args or {}})

    def instant(self, name: str, t: Optional[float] = None, tid: int = 0,
                cat: str = "serve", args: Optional[dict] = None) -> None:
        self._emit({"ph": "i", "name": name, "cat": cat, "pid": self.PID,
                    "tid": tid, "ts": self._ts(t), "s": "t",
                    "args": args or {}})

    def counter(self, name: str, values: dict,
                t: Optional[float] = None) -> None:
        self._emit({"ph": "C", "name": name, "pid": self.PID, "tid": 0,
                    "ts": self._ts(t),
                    "args": {k: float(v) for k, v in values.items()}})

    def flush(self) -> None:
        if self._buf:
            self._f.write("".join(
                json.dumps(ev, separators=(",", ":")) + "\n"
                for ev in self._buf))
            self._buf.clear()
        self._f.flush()

    def close(self) -> None:
        if not self._f.closed:
            self.flush()
            self._f.close()
