"""Metrics registry: one host-side map of named metrics with Prometheus
text exposition and JSON snapshots. Stdlib only.

The registry is a sink — ``obs.Observability`` pushes harvested device
counters and server gauges into it; consumers pull either the Prometheus
text format (``GET /metrics`` on the optional HTTP server) or a JSON
snapshot (``GET /snapshot``, or periodic file writes). Values are plain
floats; labeled series are dicts keyed by a single label value (the
estimator tier everywhere in this repo).
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, Optional, Tuple

_VALID_TYPES = ("counter", "gauge", "histogram")


class MetricsRegistry:
    def __init__(self, prefix: str = "repro"):
        self.prefix = prefix
        self._lock = threading.Lock()
        # name -> (type, help, {labels_tuple: value})
        self._metrics: Dict[str, Tuple[str, str, dict]] = {}
        self._server: Optional[ThreadingHTTPServer] = None

    def set(self, name: str, value, labels: Optional[dict] = None,
            mtype: str = "gauge", help: str = "") -> None:
        assert mtype in _VALID_TYPES, mtype
        key = tuple(sorted((labels or {}).items()))
        with self._lock:
            ent = self._metrics.get(name)
            if ent is None:
                ent = (mtype, help, {})
                self._metrics[name] = ent
            ent[2][key] = float(value)

    def set_many(self, values: dict, labels: Optional[dict] = None,
                 mtype: str = "gauge") -> None:
        for name, v in values.items():
            self.set(name, v, labels=labels, mtype=mtype)

    def get(self, name: str, labels: Optional[dict] = None):
        key = tuple(sorted((labels or {}).items()))
        with self._lock:
            ent = self._metrics.get(name)
            return None if ent is None else ent[2].get(key)

    def snapshot(self) -> dict:
        """JSON-ready view: {name: value} for unlabeled series,
        {name: {label_value: value}} for labeled ones."""
        out: dict = {}
        with self._lock:
            for name, (_, _, series) in sorted(self._metrics.items()):
                if list(series) == [()]:
                    out[name] = series[()]
                else:
                    out[name] = {"/".join(v for _, v in key): val
                                 for key, val in sorted(series.items())}
        return out

    def write_snapshot(self, path: str, extra: Optional[dict] = None) -> None:
        snap = self.snapshot()
        if extra:
            snap.update(extra)
        with open(path, "w") as f:
            json.dump(snap, f, indent=1, sort_keys=True)
            f.write("\n")

    def prometheus_text(self) -> str:
        """Prometheus text exposition format 0.0.4."""
        lines = []
        with self._lock:
            for name, (mtype, help_, series) in sorted(self._metrics.items()):
                full = f"{self.prefix}_{name}"
                if help_:
                    lines.append(f"# HELP {full} {help_}")
                lines.append(f"# TYPE {full} {mtype}")
                for key, val in sorted(series.items()):
                    if key:
                        lbl = ",".join(f'{k}="{v}"' for k, v in key)
                        lines.append(f"{full}{{{lbl}}} {val:g}")
                    else:
                        lines.append(f"{full} {val:g}")
        return "\n".join(lines) + "\n"

    # -- optional HTTP exposition -------------------------------------------

    def serve(self, port: int, host: str = "127.0.0.1") -> int:
        """Start a daemon-threaded exposition server; returns the bound
        port (pass port=0 for an ephemeral one)."""
        registry = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):
                if self.path.rstrip("/") in ("", "/metrics"):
                    body = registry.prometheus_text().encode()
                    ctype = "text/plain; version=0.0.4"
                elif self.path.rstrip("/") == "/snapshot":
                    body = (json.dumps(registry.snapshot(), sort_keys=True)
                            + "\n").encode()
                    ctype = "application/json"
                else:
                    self.send_error(404)
                    return
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):   # no stderr chatter per scrape
                pass

        self._server = ThreadingHTTPServer((host, port), Handler)
        t = threading.Thread(target=self._server.serve_forever, daemon=True)
        t.start()
        return self._server.server_address[1]

    def close(self) -> None:
        if self._server is not None:
            self._server.shutdown()
            self._server.server_close()
            self._server = None
