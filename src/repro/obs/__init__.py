"""Live observability for the serve+train stack (DESIGN.md SS17).

Three layers, composable and individually optional:

 * **Device-resident metrics** (``obs.metrics``): the scheduler threads a
   ``MetricState`` pytree through its one compiled step; the host harvests
   it on ``ObsConfig.harvest_every`` cadence into the registry.
 * **Per-request tracing** (``obs.tracing``): lifecycle spans (enqueue ->
   admit -> replay -> decode -> complete/shed/evict), per-step device/host
   phases and harvested counter tracks as Chrome-trace JSONL.
 * **Estimator-quality telemetry**: shadow-sampled exact log-Z inside the
   compiled step (``core.backends.shadow_exact_log_z`` under ``lax.cond``)
   surfaces a live per-tier rel-err stream; exposition via the Prometheus
   registry (``ObsConfig.metrics_port``) and JSON snapshots.

``Observability`` wires all of it to a ``serve.Server`` — pass it as
``Server(sched, cfg, obs=Observability(ObsConfig(...)))``. The instrumented
executables are IDENTICAL with observability on or off (the metric state is
always threaded; cadence flags are traced data), so tokens stay bit-exact
and warmup trace counts stay pinned.
"""
from __future__ import annotations

import math
import time
from typing import Optional

from ..configs.base import ObsConfig
from .metrics import (LATENCY_EDGES_MS, OCC_EDGES, QUEUE_EDGES, TIER_IX,
                      TIERS, MetricState, harvest, hist_quantile,
                      init_metric_state, observe_step, shadow_rel_err)
from .registry import MetricsRegistry
from .tracing import TraceWriter

__all__ = ["Observability", "ObsConfig", "MetricsRegistry", "TraceWriter",
           "MetricState", "TIERS", "TIER_IX", "LATENCY_EDGES_MS",
           "QUEUE_EDGES", "OCC_EDGES", "init_metric_state", "observe_step",
           "harvest", "hist_quantile", "shadow_rel_err"]


class Observability:
    """Host-side orchestrator: harvest cadence, span emission, exposition.

    All hooks are no-throw by construction (pure bookkeeping + buffered
    writes); the serving loop never blocks on a scrape — the HTTP server
    runs in a daemon thread against the registry's lock-protected map.
    """

    def __init__(self, cfg: Optional[ObsConfig] = None):
        self.cfg = cfg or ObsConfig()
        self.cfg.validate()
        self.registry = MetricsRegistry()
        self.tracer: Optional[TraceWriter] = (
            TraceWriter(self.cfg.trace_path) if self.cfg.trace_path
            else None)
        self.port: Optional[int] = (
            self.registry.serve(self.cfg.metrics_port)
            if self.cfg.metrics_port else None)
        self.last_harvest: dict = {}
        self._steps = 0
        self._harvests = 0
        self._tiers_seen = 0
        self._submit_at: dict = {}     # req_id -> wall stamp at enqueue

    # -- wiring ---------------------------------------------------------------

    def attach(self, server) -> None:
        """Bind to a ``serve.Server`` (called by its constructor). Sets the
        scheduler's shadow cadence and hooks the engine's index lifecycle
        events; everything else flows through the server's obs calls."""
        server.scheduler.shadow_every = self.cfg.shadow_every
        server.scheduler.engine.obs = self
        if self.tracer:
            self.tracer.instant("observability_attached", args={
                "tiers": list(TIERS),
                "shadow_every": self.cfg.shadow_every,
                "harvest_every": self.cfg.harvest_every})

    def instant(self, name: str, args: Optional[dict] = None) -> None:
        """Engine-facing hook (index swap / restore / build events)."""
        if self.tracer:
            self.tracer.instant(name, args=args)

    # -- server lifecycle hooks ----------------------------------------------

    def on_submit(self, server, request) -> None:
        self._submit_at[request.req_id] = time.perf_counter()
        if self.tracer:
            self.tracer.instant("enqueue", tid=request.req_id, args={
                "req_id": request.req_id, "queue_depth": len(server.queue),
                "prompt_len": int(request.prompt.shape[0]),
                "max_new_tokens": request.max_new_tokens})

    def on_reject(self, server, request, reason: str) -> None:
        t0 = self._submit_at.pop(request.req_id, None)
        if self.tracer:
            now = time.perf_counter()
            self.tracer.name_thread(request.req_id,
                                    f"req {request.req_id}")
            if t0 is not None:
                self.tracer.span("queued", t0, now, tid=request.req_id,
                                 args={"outcome": reason})
            self.tracer.instant("shed", t=now, tid=request.req_id,
                                args={"reason": reason})

    def on_step(self, server, rec: dict) -> None:
        self._steps += 1
        if self.tracer:
            t0, td, te, tn = (rec.get("t_start"), rec.get("t_dispatch"),
                              rec.get("t_device_done"), rec.get("t_done"))
            if td is not None and te is not None:
                self.tracer.span(f"device_step:{rec['tier']}", td, te,
                                 args={"n_active": rec["n_active"],
                                       "n_emitted": rec["n_emitted"],
                                       "spec_accepted":
                                           rec.get("spec_accepted", 0)})
            if t0 is not None and tn is not None:
                self.tracer.span("host_step", te or t0, tn,
                                 args={"completions":
                                       len(rec["completions"])})
            for comp in rec["completions"]:
                self._trace_completion(comp)
            # tier transitions appended by the server since last look
            for step_i, tier in server.tier_transitions[self._tiers_seen:]:
                self.tracer.instant("tier_transition",
                                    args={"tier": tier, "step": step_i})
            self._tiers_seen = len(server.tier_transitions)
        else:
            for comp in rec["completions"]:
                self._submit_at.pop(comp.request.req_id, None)
            self._tiers_seen = len(server.tier_transitions)
        if self.cfg.metrics and self._steps % self.cfg.harvest_every == 0:
            self._harvest(server)

    def on_done(self, server, report) -> None:
        """End of a ``Server.run``: final harvest, report-level gauges, a
        last snapshot, flush. The trace stays open for back-to-back runs;
        call ``close()`` when finished."""
        if self.cfg.metrics:
            self._harvest(server, force_snapshot=bool(
                self.cfg.snapshot_path))
        r = self.registry
        for name, v in (("goodput_tok_s", report.goodput_tok_s),
                        ("p50_token_ms", report.p50_token_ms),
                        ("p95_token_ms", report.p95_token_ms),
                        ("p99_token_ms", report.p99_token_ms),
                        ("shed_rate", report.shed_rate)):
            if isinstance(v, float) and math.isnan(v):
                continue
            r.set(name, v, help="ServerReport." + name)
        if self.tracer:
            self.tracer.flush()

    def close(self) -> None:
        if self.tracer:
            self.tracer.close()
        self.registry.close()

    # -- internals ------------------------------------------------------------

    def _trace_completion(self, comp) -> None:
        req = comp.request
        tid = req.req_id
        t_sub = self._submit_at.pop(tid, None)
        self.tracer.name_thread(tid, f"req {tid}")
        if t_sub is not None and comp.admit_time >= t_sub:
            self.tracer.span("queued", t_sub, comp.admit_time, tid=tid)
        first = comp.first_token_time
        if first is not None:
            self.tracer.span("replay", comp.admit_time, first, tid=tid)
            self.tracer.span("decode", first, comp.done_time, tid=tid,
                             args={"tokens": len(comp.tokens)})
        outcome = comp.reason or ("overflow" if comp.overflowed else "ok")
        self.tracer.span("request", comp.admit_time, comp.done_time,
                         tid=tid, cat="request",
                         args={"req_id": tid, "tokens": len(comp.tokens),
                               "tiers": list(comp.tiers),
                               "outcome": outcome,
                               "error": comp.error or ""})
        if comp.error is not None:
            self.tracer.instant("evict", t=comp.done_time, tid=tid,
                                args={"reason": outcome})

    def _harvest(self, server, force_snapshot: bool = False) -> None:
        sched = server.scheduler
        h = harvest(sched.metrics_state, sched.n_slots)
        self.last_harvest = h
        self._harvests += 1
        self._push_registry(h, server)
        if self.tracer:
            self.tracer.counter("queue_depth",
                                {"depth": len(server.queue)})
            self.tracer.counter("occupancy",
                                {"live_frac": h["occupancy_mean"]})
            if h["shadow_by_tier"]:
                self.tracer.counter(
                    "shadow_rel_err",
                    {t: s["rel_err_mean"]
                     for t, s in h["shadow_by_tier"].items()})
        if self.cfg.snapshot_path and (
                force_snapshot
                or self._harvests % self.cfg.snapshot_every == 0):
            self.registry.write_snapshot(
                self.cfg.snapshot_path,
                extra={"harvest": h, "harvests": self._harvests})

    def _push_registry(self, h: dict, server) -> None:
        r = self.registry
        r.set("serving_steps", h["steps"], mtype="counter",
              help="scheduler steps observed")
        r.set("serving_tokens_total", h["tokens_total"], mtype="counter",
              help="tokens emitted")
        for t, v in h["tokens_by_tier"].items():
            r.set("serving_tokens", v, labels={"tier": t}, mtype="counter")
        r.set("occupancy_mean", h["occupancy_mean"])
        r.set("queue_depth", len(server.queue))
        r.set("queue_depth_mean", h["queue_depth_mean"])
        r.set("probe_union_fill_mean", h["fill_mean"])
        r.set("health_flagged_total", h["health_flagged"], mtype="counter")
        for cause, v in h["health_by_cause"].items():
            r.set("health_cause_total", v, labels={"cause": cause},
                  mtype="counter")
        r.set("spec_proposed_total", h["spec_proposed"], mtype="counter")
        r.set("spec_accepted_total", h["spec_accepted"], mtype="counter")
        r.set("draft_flagged_total", h["draft_flagged"], mtype="counter")
        for t, s in h["shadow_by_tier"].items():
            r.set("shadow_samples_total", s["count"], labels={"tier": t},
                  mtype="counter",
                  help="lane-steps shadow-sampled against exact log Z")
            r.set("shadow_rel_err_mean", s["rel_err_mean"],
                  labels={"tier": t},
                  help="mean |Zhat/Z - 1| over shadow samples")
            r.set("shadow_rel_err_max", s["rel_err_max"],
                  labels={"tier": t})
        for t, counts in h["latency_hist_by_tier"].items():
            cum = 0
            edges = list(h["latency_edges_ms"]) + ["+Inf"]
            for edge, c in zip(edges, counts):
                cum += c
                r.set("step_latency_ms_bucket", cum,
                      labels={"tier": t, "le": str(edge)},
                      mtype="histogram")
