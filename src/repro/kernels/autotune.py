"""Kernel autotuner: measured config sweeps with on-disk caching.

The Pallas kernels in this package expose a small set of static tile knobs
(``block_q``, ``tail_tile``, ``block_v``, ``block_p``). The right values
depend on shapes, dtype and backend generation, so they are picked by
measurement, not heuristics:

    cfg = tune_ivf_decode(index, h, plan_args...)   # {'block_q':…, 'tail_tile':…}
    ivf_decode(..., **cfg)

Sweeps run the real kernel on the caller's real operands, time a few
repetitions (median of means), and persist the winner to a JSON cache keyed
by ``(kernel, operand shapes, dtypes, backend, device kind)`` — the same
key scheme as Triton/XLA autotuning caches, so a tuned serving binary never
re-sweeps. Configs that fail to compile or run (e.g. a tile too large for
VMEM) are skipped, not fatal.  Cache location: ``$REPRO_AUTOTUNE_CACHE``,
else ``~/.cache/repro/autotune.json``.

On CPU the Pallas kernels execute in interpret mode, where timings reflect
the interpreter rather than the lowered kernel; sweeps still *work* (the
machinery is exercised by tier-1 tests) but the benchmark artifacts record
``backend: cpu`` so the numbers are read accordingly.

Adding a kernel: write a ``tune_<kernel>`` wrapper that (1) builds the
candidate list, (2) closes the kernel over everything but the swept knobs,
and (3) calls ``autotune`` — see ``tune_ivf_decode`` for the template.
DESIGN.md SS9 documents the scheme.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Dict, Iterable, List, Optional

import jax

_DEF_CACHE = os.path.join(os.path.expanduser("~"), ".cache", "repro",
                          "autotune.json")


def cache_path(path: Optional[str] = None) -> str:
    return path or os.environ.get("REPRO_AUTOTUNE_CACHE", _DEF_CACHE)


def _sig(args) -> str:
    parts = []
    for a in args:
        if hasattr(a, "shape") and hasattr(a, "dtype"):
            parts.append(f"{tuple(a.shape)}:{a.dtype}")
        else:
            parts.append(repr(a))
    return ",".join(parts)


def cache_key(kernel: str, args: Iterable[Any], extra: str = "") -> str:
    """Deterministic key: kernel + operand shapes/dtypes + backend/device."""
    backend = jax.default_backend()
    try:
        kind = jax.devices()[0].device_kind
    except Exception:       # pragma: no cover - device enumeration quirks
        kind = backend
    return f"{kernel}|{_sig(args)}|{extra}|{backend}|{kind}"


def _load(path: str) -> Dict[str, Any]:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def _store(path: str, cache: Dict[str, Any]) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(cache, f, indent=1, sort_keys=True)
    os.replace(tmp, path)    # atomic — concurrent tuners last-write-win


def _time(fn: Callable[[], Any], reps: int) -> float:
    jax.block_until_ready(fn())                    # compile + warm
    best = float("inf")
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def autotune(kernel: str, candidates: List[Dict[str, int]],
             build: Callable[[Dict[str, int]], Callable[[], Any]],
             args: Iterable[Any], *, reps: int = 3,
             path: Optional[str] = None) -> Dict[str, int]:
    """Return the fastest candidate config (cached on disk).

    ``build(cfg)`` must return a zero-arg callable running the kernel with
    that config on the caller's operands. A candidate that raises during
    compile/run is skipped; if every candidate fails, the first one is
    returned so callers degrade to their defaults.
    """
    path = cache_path(path)
    key = cache_key(kernel, args)
    cache = _load(path)
    hit = cache.get(key)
    if hit is not None:
        return dict(hit["config"])
    best_cfg, best_t = None, float("inf")
    results = []
    for cfg in candidates:
        try:
            t = _time(build(cfg), reps)
        except Exception as e:                     # invalid tile/VMEM/etc.
            results.append({"config": cfg, "error": f"{type(e).__name__}"})
            continue
        results.append({"config": cfg, "s": t})
        if t < best_t:
            best_cfg, best_t = cfg, t
    if best_cfg is None:
        return dict(candidates[0])
    cache[key] = {"config": best_cfg, "s": best_t, "swept": results}
    _store(path, cache)
    return dict(best_cfg)


# ---------------------------------------------------------------------------
# per-kernel sweeps
# ---------------------------------------------------------------------------

def _pow2s(lo: int, hi: int) -> List[int]:
    out, v = [], lo
    while v <= hi:
        out.append(v)
        v *= 2
    return out


def tune_ivf_decode(w_blocks, h, head_ids, head_live, head_member, row_logw,
                    tail_rows_g, tail_accept, *, k: int = 1,
                    path: Optional[str] = None,
                    reps: int = 3) -> Dict[str, int]:
    """Sweep (block_q, tail_tile) for the fused MIMPS decode kernel."""
    from .ivf_score import ivf_decode
    q = h.shape[0]
    l = tail_rows_g.shape[0]
    cands = [{"block_q": bq, "tail_tile": tt}
             for bq in _pow2s(8, max(8, min(256, q)))
             for tt in _pow2s(8, max(8, min(128, l)))]

    def build(cfg):
        return lambda: ivf_decode(w_blocks, h, head_ids, head_live,
                                  head_member, row_logw, tail_rows_g,
                                  tail_accept, k=k, **cfg)

    return autotune("ivf_decode", cands, build,
                    (w_blocks, h, head_ids, tail_rows_g, k), reps=reps,
                    path=path)


def tune_union_scores(w_blocks, h, head_ids, head_live, *,
                      path: Optional[str] = None,
                      reps: int = 3) -> Dict[str, int]:
    """Sweep block_q for the deduplicated union-scoring kernel (the MINCE /
    FMBE candidate head)."""
    from .ivf_score import union_scores
    q = h.shape[0]
    cands = [{"block_q": bq} for bq in _pow2s(8, max(8, min(256, q)))]

    def build(cfg):
        return lambda: union_scores(w_blocks, h, head_ids, head_live, **cfg)

    return autotune("union_scores", cands, build, (w_blocks, h, head_ids),
                    reps=reps, path=path)


def tune_lsh_probe(lsh_index, w, h, key, *, l: int, cand_cap: int = 0,
                   k: int = 1, path: Optional[str] = None,
                   reps: int = 3) -> Dict[str, int]:
    """Sweep (block_q, cand_tile, tail_tile) for the fused Hamming-probe
    decode kernel, on the trimmed candidate set a real decode would score."""
    from ..core import lsh as _lsh
    from .lsh_probe import lsh_probe
    plan = _lsh.lsh_plan(lsh_index, h, key, l, cand_cap=cand_cap)
    rows = plan.cand_rows
    cap = rows.shape[0]
    w_cand = w[rows].astype(jax.numpy.float32)
    cand_codes = lsh_index.codes[rows]
    cand_ok = lsh_index.slot_of_row[rows] >= 0
    tail_rows = w[plan.tail_ids].astype(jax.numpy.float32)
    q = h.shape[0]
    cands = [{"block_q": bq, "cand_tile": ct, "tail_tile": tt}
             for bq in _pow2s(8, max(8, min(256, q)))
             for ct in _pow2s(64, max(64, min(512, cap)))
             for tt in _pow2s(8, max(8, min(128, l)))]

    def build(cfg):
        return lambda: lsh_probe(w_cand, h, lsh_index.proj, rows,
                                 cand_codes, cand_ok, plan.cand_live,
                                 tail_rows, plan.tail_accept,
                                 plan.tail_bias, k=k, **cfg)

    return autotune("lsh_probe", cands, build,
                    (w_cand, h, lsh_index.proj, tail_rows, k), reps=reps,
                    path=path)


def tune_fmbe_z(omega, degree, coef, lam, x, *, path: Optional[str] = None,
                reps: int = 3) -> Dict[str, int]:
    """Sweep (block_q, block_p) for the fused feature-map estimate."""
    from .fmbe import fmbe_z
    q = x.shape[0]
    p = omega.shape[0]
    cands = [{"block_q": bq, "block_p": bp}
             for bq in _pow2s(8, max(8, min(256, q)))
             for bp in _pow2s(128, max(128, min(1024, p)))]

    def build(cfg):
        return lambda: fmbe_z(omega, degree, coef, lam, x, **cfg)

    return autotune("fmbe_z", cands, build, (omega, lam, x), reps=reps,
                    path=path)


def tune_topk_z(h, w, k: int, *, path: Optional[str] = None,
                reps: int = 3) -> Dict[str, int]:
    """Sweep (block_q, block_v) for the fused exact log-Z/top-k kernel."""
    from .topk_z import topk_z
    q = h.shape[0]
    v = w.shape[0]
    cands = [{"block_q": bq, "block_v": bv}
             for bq in _pow2s(8, max(8, min(256, q)))
             for bv in _pow2s(128, max(128, min(2048, v)))]

    def build(cfg):
        return lambda: topk_z(h, w, k, **cfg)

    return autotune("topk_z", cands, build, (h, w, k), reps=reps, path=path)
