"""Scalar-prefetch block-gather scoring — the TPU-native S_k(q) retrieval.

The sublinear step of MIMPS: per query, only the ``n_probe`` vocab blocks
selected by the coarse (centroid) stage are pulled HBM->VMEM and scored. The
probed block ids are scalar-prefetched into SMEM so the BlockSpec index_map
can address HBM blocks *data-dependently* — the canonical Pallas block-sparse
pattern (MoE dispatch, block-sparse attention) applied to retrieval.

HBM bytes per decode step drop from  V*d  to  n_probe*block_rows*d
(+ n_blocks*d for centroids) — e.g. gemma3-4b (V=262144, block 512, probes 16):
32x fewer output-embedding bytes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ivf_kernel(ids_ref, h_ref, w_ref, out_ref):
    # h_ref: (1, d) query row; w_ref: (1, br, d) gathered block
    h = h_ref[...]
    w = w_ref[0]
    out_ref[0] = jax.lax.dot_general(
        h, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)           # (1, br)


def ivf_score(w_blocks, h, block_ids, *, interpret=None):
    """w_blocks (nb, br, d), h (Q, d), block_ids (Q, p) -> scores (Q, p, br).

    Only the addressed blocks are read from HBM: the grid is (Q, p) and the
    w_blocks index_map consults the scalar-prefetched id table.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    nb, br, d = w_blocks.shape
    q, p = block_ids.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(q, p),
        in_specs=[
            pl.BlockSpec((1, d), lambda qi, pi, ids: (qi, 0)),
            pl.BlockSpec((1, br, d), lambda qi, pi, ids: (ids[qi, pi], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, br), lambda qi, pi, ids: (qi, pi, 0)),
    )
    return pl.pallas_call(
        _ivf_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((q, p, br), jnp.float32),
        interpret=interpret,
    )(block_ids.astype(jnp.int32), h, w_blocks)
