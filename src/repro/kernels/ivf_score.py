"""Scalar-prefetch block-gather scoring + the fused batched MIMPS decode
kernel — the TPU-native S_k(q) retrieval stage (DESIGN.md SS4).

The sublinear step of MIMPS: only the vocab blocks selected by the coarse
(centroid) stage are pulled HBM->VMEM and scored. Probed block ids are
scalar-prefetched into SMEM so the BlockSpec index_map can address HBM blocks
*data-dependently* — the canonical Pallas block-sparse pattern (MoE dispatch,
block-sparse attention) applied to retrieval.

Two kernels:

 * ``ivf_score``  — the original per-query gather-score kernel. Grid (Q, p),
   query tile (1, d): MXU utilization <= 1/128 and the scores round-trip
   through a (Q, p, br) HBM tensor. Kept as the simple reference/bench kernel.

 * ``ivf_decode`` — the fused batched decode pipeline. Grid
   (Q/block_q, U + l/tail_tile): each grid step scores a **(block_q, d)
   query tile** against one scalar-prefetched vocab block (head phase) or a
   dense ``(tail_tile, d)`` slab of pre-gathered tail rows (tail phase) and
   folds the result directly into per-query online-logsumexp accumulators
   (head and tail separately) and a running top-k (the ``_select_topk``
   sweep shared with ``kernels.topk_z``). Head scoring, tail reduction and
   the top-k merge share the single resident query tile — one pass over the
   probe union per tile, no score tensor in HBM. The tail phase used to
   issue one (1, d) row DMA + matvec per sample (l grid steps of ~1/128 MXU
   utilization); rows are now staged dense once (one XLA gather, the same
   l*d floats) and consumed ``tail_tile`` rows per step, which shrinks the
   grid from U+l to U+l/tail_tile steps of real matmuls.

``block_q`` and ``tail_tile`` are autotuned per (shape, dtype, backend) by
``kernels.autotune`` with on-disk caching.

HBM bytes per decode step drop from  V*d  to  U*br*d + l*d
(+ n_blocks*d for centroids) — e.g. gemma3-4b (V=262144, block 512,
16 shared probes, l=256): ~30x fewer output-embedding bytes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .topk_z import NEG, _select_topk


# ---------------------------------------------------------------------------
# per-query gather-score (reference kernel; (Q, p, br) output)
# ---------------------------------------------------------------------------

def _ivf_kernel(ids_ref, h_ref, w_ref, out_ref):
    # h_ref: (1, d) query row; w_ref: (1, br, d) gathered block
    h = h_ref[...]
    w = w_ref[0]
    out_ref[0] = jax.lax.dot_general(
        h, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)           # (1, br)


def ivf_score(w_blocks, h, block_ids, *, interpret=None):
    """w_blocks (nb, br, d), h (Q, d), block_ids (Q, p) -> scores (Q, p, br).

    Only the addressed blocks are read from HBM: the grid is (Q, p) and the
    w_blocks index_map consults the scalar-prefetched id table. The serving
    path uses ``ivf_decode`` instead, which never materializes this tensor.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    nb, br, d = w_blocks.shape
    q, p = block_ids.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(q, p),
        in_specs=[
            pl.BlockSpec((1, d), lambda qi, pi, ids: (qi, 0)),
            pl.BlockSpec((1, br, d), lambda qi, pi, ids: (ids[qi, pi], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, br), lambda qi, pi, ids: (qi, pi, 0)),
    )
    return pl.pallas_call(
        _ivf_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((q, p, br), jnp.float32),
        interpret=interpret,
    )(block_ids.astype(jnp.int32), h, w_blocks)


# ---------------------------------------------------------------------------
# deduplicated union scoring: (Q, U_cap, br) scores, U unique blocks of DMA
# ---------------------------------------------------------------------------

def _union_kernel(hid_ref, live_ref, h_ref, w_ref, out_ref):
    si = pl.program_id(1)

    @pl.when(si < live_ref[0])
    def _score():
        h = h_ref[...]                                      # (bq, d)
        w = w_ref[0]                                        # (br, d)
        out_ref[:, 0, :] = jax.lax.dot_general(
            h, w, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(si >= live_ref[0])
    def _pad():
        out_ref[...] = jnp.zeros_like(out_ref)   # masked by callers


def union_scores(w_blocks, h, head_ids, head_live, *, block_q: int = 128,
                 interpret=None):
    """Score a deduplicated block union for a whole query batch.

    w_blocks (nb, br, d), h (Q, d), head_ids (U_cap,) (sorted unique ids,
    pad slots repeat the last id), head_live () -> scores (Q, U_cap, br) f32.

    Per (block_q, d) query tile the grid sweeps the union table once:
    identical consecutive BlockSpec indices cost no DMA, and slots past
    ``head_live`` skip their matmul entirely, so embedding reads are the U
    *unique* blocks — the MINCE/FMBE head at MIMPS-kernel traffic (the XLA
    gather reference materializes all U_cap slots instead). Pad-slot outputs
    are zeros; callers mask through the plan's membership mask.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    nb, br, d = w_blocks.shape
    q = h.shape[0]
    u_cap = head_ids.shape[0]
    block_q = min(block_q, max(8, q))
    pad_q = (-q) % block_q
    hp = jnp.pad(h, ((0, pad_q), (0, 0)))
    qp = hp.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(qp // block_q, u_cap),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda qi, si, hid, lv: (qi, 0)),
            pl.BlockSpec((1, br, d),
                         lambda qi, si, hid, lv: (hid[si], 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, 1, br),
                               lambda qi, si, hid, lv: (qi, si, 0)),
    )
    out = pl.pallas_call(
        _union_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((qp, u_cap, br), jnp.float32),
        interpret=interpret,
    )(head_ids.astype(jnp.int32),
      jnp.asarray(head_live, jnp.int32).reshape(1), hp, w_blocks)
    return out[:q]


# ---------------------------------------------------------------------------
# fused batched decode: probe table -> (head lse, tail lse, top-k) per query
# ---------------------------------------------------------------------------

def _decode_kernel(hid_ref, live_ref,                       # scalar prefetch
                   h_ref, wh_ref, logw_ref, member_ref, wt_ref, acc_ref,
                   hlse_ref, tlse_ref, topv_ref, topi_ref,
                   mh_scr, sh_scr, mt_scr, st_scr, tv_scr, ti_scr,
                   *, k: int, n_head: int, block_rows: int):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        mh_scr[...] = jnp.full_like(mh_scr, NEG)
        sh_scr[...] = jnp.zeros_like(sh_scr)
        mt_scr[...] = jnp.full_like(mt_scr, NEG)
        st_scr[...] = jnp.zeros_like(st_scr)
        tv_scr[...] = jnp.full_like(tv_scr, NEG)
        ti_scr[...] = jnp.zeros_like(ti_scr)

    h = h_ref[...]                                          # (bq, d)

    # only the live_ref[0] <= n_head slots hold real unique blocks; pad slots
    # repeat the last id (no DMA) and are fully masked, so skip their matmul
    @pl.when(si < live_ref[0])
    def _head_step():
        w = wh_ref[0]                                       # (br, d)
        scores = jax.lax.dot_general(
            h, w, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)             # (bq, br)
        scores = scores + logw_ref[...]                     # pad rows -> NEG
        member = member_ref[...]                            # (bq, 1) 0/1
        eff = jnp.where(member > 0, scores, NEG)
        m_prev = mh_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(eff, axis=1, keepdims=True))
        contrib = jnp.where(eff > NEG * 0.5,
                            jnp.exp(eff - m_new), 0.0)      # NEG-safe
        sh_scr[...] = (sh_scr[...] * jnp.exp(m_prev - m_new) +
                       jnp.sum(contrib, axis=1, keepdims=True))
        mh_scr[...] = m_new
        # running top-k over global slot ids (block*br + row)
        col = (hid_ref[si] * block_rows +
               jax.lax.broadcasted_iota(jnp.int32, eff.shape, 1))
        cand_v = jnp.concatenate([tv_scr[...], eff], axis=1)
        cand_i = jnp.concatenate([ti_scr[...], col], axis=1)
        tv, ti = _select_topk(cand_v, cand_i, k)
        tv_scr[...] = tv
        ti_scr[...] = ti

    @pl.when(si >= n_head)
    def _tail_step():
        rows = wt_ref[...]                                  # (tt, d)
        s = jax.lax.dot_general(
            h, rows, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)             # (bq, tt)
        acc = acc_ref[...]                                  # (bq, tt) 0/1
        eff = jnp.where(acc > 0, s, NEG)
        m_prev = mt_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(eff, axis=1, keepdims=True))
        contrib = jnp.where(eff > NEG * 0.5, jnp.exp(eff - m_new), 0.0)
        st_scr[...] = (st_scr[...] * jnp.exp(m_prev - m_new) +
                       jnp.sum(contrib, axis=1, keepdims=True))
        mt_scr[...] = m_new

    @pl.when(si == pl.num_programs(1) - 1)
    def _fin():
        hlse_ref[...] = mh_scr[...] + jnp.log(sh_scr[...])
        tlse_ref[...] = mt_scr[...] + jnp.log(st_scr[...])
        topv_ref[...] = tv_scr[...]
        topi_ref[...] = ti_scr[...]


def ivf_decode(w_blocks, h, head_ids, head_live, head_member, row_logw,
               tail_rows_g, tail_accept,
               *, k: int = 1, block_q: int = 128, tail_tile: int = 32,
               interpret=None):
    """Fused batched MIMPS decode over a deduplicated probe plan.

    Inputs (see ``core.decode`` for plan construction):
      w_blocks    (nb, br, d)  block-IVF embedding rows
      h           (Q, d)       query batch
      head_ids    (U,) int32   union of probed block ids (pad = repeat last,
                               masked out via head_member; repeated consecutive
                               ids cost no extra DMA)
      head_live   () int32     number of real (non-pad) union slots; head
                               compute is skipped for slots >= head_live, so
                               per-step head work is O(unique blocks), not
                               O(capacity)
      head_member (Q, U) bool  query q probes union slot u
      row_logw    (nb, br) f32 0 for real rows, NEG for cluster-pad rows
      tail_rows_g (l, d)       shared tail sample rows, staged dense by the
                               caller (one XLA gather; l*d floats, consumed
                               ``tail_tile`` rows per grid step)
      tail_accept (Q, l) bool  sample j survives rejection for query q

    ``block_q`` (query tile) and ``tail_tile`` (tail rows per step) are the
    autotuned knobs (kernels.autotune.tune_ivf_decode).

    Returns (head_lse (Q,), tail_lse (Q,), topv (Q, k), topi (Q, k)) with
    topi global *slot* ids (block*br + row); map through row_id outside.
    Queries with zero accepted tail samples get tail_lse == -inf.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    nb, br, d = w_blocks.shape
    q = h.shape[0]
    n_head = head_ids.shape[0]
    l = tail_rows_g.shape[0]
    assert l >= 1, "fused decode needs at least one tail sample"
    block_q = min(block_q, max(8, q))
    tail_tile = max(1, min(tail_tile, l))
    pad_q = (-q) % block_q
    pad_l = (-l) % tail_tile
    hp = jnp.pad(h, ((0, pad_q), (0, 0)))
    member_p = jnp.pad(head_member.astype(jnp.float32), ((0, pad_q), (0, 0)))
    # pad rows contribute via accept == 0 only — value never read; keep the
    # rows' own dtype (mixed-dtype dot with f32 accumulate, like the head
    # phase) so bf16 queries stay bit-comparable with the XLA reference
    wt_p = jnp.pad(tail_rows_g, ((0, pad_l), (0, 0)))
    accept_p = jnp.pad(tail_accept.astype(jnp.float32),
                       ((0, pad_q), (0, pad_l)))
    qp = hp.shape[0]
    n_tiles = (l + pad_l) // tail_tile

    def _ts(si):
        return jnp.clip(si - n_head, 0, n_tiles - 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(qp // block_q, n_head + n_tiles),
        in_specs=[
            pl.BlockSpec((block_q, d),
                         lambda qi, si, hid, lv: (qi, 0)),
            # head: whole probed block; clamped (hence DMA-elided) on tail steps
            pl.BlockSpec((1, br, d),
                         lambda qi, si, hid, lv:
                         (hid[jnp.minimum(si, lv[0] - 1)], 0, 0)),
            pl.BlockSpec((1, br),
                         lambda qi, si, hid, lv:
                         (hid[jnp.minimum(si, lv[0] - 1)], 0)),
            pl.BlockSpec((block_q, 1),
                         lambda qi, si, hid, lv:
                         (qi, jnp.minimum(si, n_head - 1))),
            # tail: dense (tail_tile, d) slab of the staged rows
            pl.BlockSpec((tail_tile, d),
                         lambda qi, si, hid, lv: (_ts(si), 0)),
            pl.BlockSpec((block_q, tail_tile),
                         lambda qi, si, hid, lv: (qi, _ts(si))),
        ],
        out_specs=[
            pl.BlockSpec((block_q, 1), lambda qi, si, *_: (qi, 0)),
            pl.BlockSpec((block_q, 1), lambda qi, si, *_: (qi, 0)),
            pl.BlockSpec((block_q, k), lambda qi, si, *_: (qi, 0)),
            pl.BlockSpec((block_q, k), lambda qi, si, *_: (qi, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, k), jnp.float32),
            pltpu.VMEM((block_q, k), jnp.int32),
        ],
    )
    kernel = functools.partial(_decode_kernel, k=k, n_head=n_head,
                               block_rows=br)
    hlse, tlse, topv, topi = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((qp, 1), jnp.float32),
            jax.ShapeDtypeStruct((qp, 1), jnp.float32),
            jax.ShapeDtypeStruct((qp, k), jnp.float32),
            jax.ShapeDtypeStruct((qp, k), jnp.int32),
        ],
        interpret=interpret,
    )(head_ids.astype(jnp.int32),
      jnp.asarray(head_live, jnp.int32).reshape(1),
      hp, w_blocks, row_logw, member_p, wt_p, accept_p)
    return hlse[:q, 0], tlse[:q, 0], topv[:q], topi[:q]
