"""Jit'd public wrappers for the Pallas kernels, including the custom-VJP
fused CE used by the training loop."""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import fmbe as _fmbe
from . import fused_ce as _fce
from . import ivf_score as _ivf
from . import topk_z as _tkz
from . import ref as _ref


# ---------------------------------------------------------------------------
# fused cross-entropy with custom VJP
# ---------------------------------------------------------------------------

@jax.custom_vjp
def fused_cross_entropy(h: jax.Array, w: jax.Array,
                        labels: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(nll (T,), lse (T,)) = streaming softmax CE. Differentiable in h, w
    (both outputs contribute cotangents — lse is used by the self-norm loss)."""
    return _fce.fused_ce_fwd(h, w, labels)


def _fce_fwd(h, w, labels):
    nll, lse = _fce.fused_ce_fwd(h, w, labels)
    return (nll, lse), (h, w, labels, lse)


def _fce_bwd(res, cts):
    h, w, labels, lse = res
    g_nll, g_lse = cts
    dh, dw = _fce.fused_ce_bwd(h, w, labels, lse, g_nll, g_lse)
    dlab = np.zeros(labels.shape, dtype=jax.dtypes.float0)
    return dh, dw, dlab


fused_cross_entropy.defvjp(_fce_fwd, _fce_bwd)


# ---------------------------------------------------------------------------
# decode kernels
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k",))
def fused_topk_z(h: jax.Array, w: jax.Array, k: int = 8):
    """(lse, topv, topi) in one fused pass over the vocab shard."""
    return _tkz.topk_z(h, w, k)


@jax.jit
def ivf_block_scores(w_blocks: jax.Array, h: jax.Array,
                     block_ids: jax.Array) -> jax.Array:
    """(Q, p, block_rows) scores for the probed blocks only."""
    return _ivf.ivf_score(w_blocks, h, block_ids)


# The fused decode kernel (_ivf.ivf_decode) is consumed through its planning
# layer, core.decode.mimps_decode (itself jitted) — no bare wrapper here.


@jax.jit
def fused_fmbe_phi(omega: jax.Array, degree: jax.Array, coef: jax.Array,
                   x: jax.Array) -> jax.Array:
    """(Q, P) Kar-Karnick features without the (Q, P, max_degree) HBM
    intermediate of core.feature_maps.apply_feature_map."""
    return _fmbe.fmbe_phi(omega, degree, coef, x)


@jax.jit
def fused_fmbe_z(omega: jax.Array, degree: jax.Array, coef: jax.Array,
                 lam: jax.Array, x: jax.Array) -> jax.Array:
    """(Q,) signed FMBE Ẑ; the (Q, P) feature matrix never reaches HBM."""
    return _fmbe.fmbe_z(omega, degree, coef, lam, x)


# re-export oracles for benches/tests
fused_ce_ref = _ref.fused_ce_ref
topk_z_ref = _ref.topk_z_ref
ivf_score_ref = _ref.ivf_score_ref
