"""Streaming fused cross-entropy over the vocabulary (Pallas TPU).

The training-time analogue of the paper's problem: the O(N) softmax
normalization. We cannot make *training* CE sublinear (every class receives
gradient), but we convert it from memory-bound to compute-bound by never
materializing the [tokens, vocab] logits in HBM: scores are produced tile by
tile in VMEM with an online (flash-style) logsumexp, and the backward pass
recomputes each tile's softmax while accumulating dh / dW.

HBM traffic per step drops from  T*V*4 (logits write+read)  to  T*d + V*d
(+ the tiny per-token outputs) — for gemma3-4b's V=262144 at T=8192 that is
~8.6 GB of logits traffic eliminated per microbatch.

VMEM budget per grid step (bf16, defaults block_t=256, block_v=512, d<=8192):
  h tile 256*8192*2 = 4 MiB, w tile 512*8192*2 = 8 MiB, scores f32 0.5 MiB
— fits the ~16 MiB/core budget with double buffering handled by Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _fwd_kernel(h_ref, w_ref, lab_ref, nll_ref, lse_ref,
                m_scr, s_scr, p_scr, *, block_v: int, v_total: int):
    vi = pl.program_id(1)

    @pl.when(vi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        s_scr[...] = jnp.zeros_like(s_scr)
        p_scr[...] = jnp.full_like(p_scr, NEG)

    h = h_ref[...]
    w = w_ref[...]
    scores = jax.lax.dot_general(
        h, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)            # (Tt, Vt)
    col = vi * block_v + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(col < v_total, scores, NEG)

    lab = lab_ref[...]                                  # (Tt, 1)
    hit = col == lab
    p_scr[...] = jnp.maximum(
        p_scr[...], jnp.max(jnp.where(hit, scores, NEG), axis=1,
                            keepdims=True))

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1, keepdims=True))
    s_scr[...] = (s_scr[...] * jnp.exp(m_prev - m_new) +
                  jnp.sum(jnp.exp(scores - m_new), axis=1, keepdims=True))
    m_scr[...] = m_new

    @pl.when(vi == pl.num_programs(1) - 1)
    def _fin():
        lse = m_scr[...] + jnp.log(s_scr[...])
        lse_ref[...] = lse
        nll_ref[...] = lse - p_scr[...]


def _bwd_kernel(h_ref, w_ref, lab_ref, lse_ref, gn_ref, go_ref, dw_in_ref,
                dh_ref, dw_ref, *, block_v: int, v_total: int,
                alias_dw: bool):
    """One fused backward step: the (Tt, Vt) score tile and its softmax are
    computed ONCE and feed both dh and dW (the seed ran two kernels, paying
    the matmul + softmax recompute and the h/w tile traffic twice).

    Grid is (gt, gv) with the vocab axis innermost:
      * dh block (ti): revisited consecutively across the vi sweep, so it
        accumulates in VMEM and writes back once per sweep.
      * dW block (vi): revisited once per sweep (stride gv). Two modes:
          alias_dw=True (compiled TPU): accumulate through HBM via
            input_output_aliases — read the running total from the aliased
            input, add this tile's contribution, write back. The caller pads
            the vocab grid to gv >= 3, putting >= 2 full grid steps between
            the write-back of step s and the (lookahead-1) prefetch of step
            s+gv. NOTE: this path is exercised only on real TPU — interpret
            mode (CI) takes the alias_dw=False branch below.
          alias_dw=False (interpret): the interpreter loads/stores out blocks
            around every step, so plain out-block accumulation is exact
            (the aliased input is never re-read there, which would drop all
            but the last t-sweep's contribution).
    """
    ti = pl.program_id(0)
    vi = pl.program_id(1)

    @pl.when(vi == 0)
    def _init():
        dh_ref[...] = jnp.zeros_like(dh_ref)

    h = h_ref[...]
    w = w_ref[...]
    scores = jax.lax.dot_general(
        h, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    col = vi * block_v + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    p = jnp.where(col < v_total, jnp.exp(scores - lse_ref[...]), 0.0)
    onehot = jnp.where(col == lab_ref[...], 1.0, 0.0)
    coef = gn_ref[...] * p - go_ref[...] * onehot       # (Tt, Vt) f32
    dh_ref[...] += jax.lax.dot_general(
        coef.astype(w.dtype), w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    dw_delta = jax.lax.dot_general(
        coef.astype(h.dtype), h, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    if alias_dw:
        dw_ref[...] = dw_in_ref[...] + dw_delta
    else:
        @pl.when(ti == 0)
        def _init_dw():
            dw_ref[...] = jnp.zeros_like(dw_ref)
        dw_ref[...] += dw_delta


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def fused_ce_fwd(h, w, labels, *, block_t=256, block_v=512, interpret=None):
    """Forward: (nll (T,), lse (T,)). h (T, d), w (V, d), labels (T,)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    t, d = h.shape
    v = w.shape[0]
    block_t = min(block_t, max(8, t))
    block_v = min(block_v, max(128, v))
    hp = _pad_to(h, block_t, 0)
    wp = _pad_to(w, block_v, 0)
    lab = _pad_to(labels.astype(jnp.int32)[:, None], block_t, 0)
    tp, vp = hp.shape[0], wp.shape[0]
    grid = (tp // block_t, vp // block_v)
    kernel = functools.partial(_fwd_kernel, block_v=block_v, v_total=v)
    nll, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, d), lambda ti, vi: (ti, 0)),
            pl.BlockSpec((block_v, d), lambda ti, vi: (vi, 0)),
            pl.BlockSpec((block_t, 1), lambda ti, vi: (ti, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_t, 1), lambda ti, vi: (ti, 0)),
            pl.BlockSpec((block_t, 1), lambda ti, vi: (ti, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((tp, 1), jnp.float32),
            jax.ShapeDtypeStruct((tp, 1), jnp.float32),
        ],
        scratch_shapes=_scratch(block_t),
        interpret=interpret,
    )(hp, wp, lab)
    return nll[:t, 0], lse[:t, 0]


def _scratch(block_t):
    from jax.experimental.pallas import tpu as pltpu
    return [pltpu.VMEM((block_t, 1), jnp.float32) for _ in range(3)]


def fused_ce_bwd(h, w, labels, lse, g_nll, g_lse, *, block_t=256, block_v=512,
                 interpret=None):
    """Backward: (dh, dw) from ONE fused pallas_call.

    gn = g_nll + g_lse (softmax term), go = g_nll. The vocab grid is padded
    to at least three blocks (pad columns contribute exactly zero: p is
    masked by col < v_total and labels never hit pad columns) so the dW
    accumulate-through-HBM revisit stride is >= 3 — see _bwd_kernel.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    t, d = h.shape
    v = w.shape[0]
    block_t = min(block_t, max(8, t))
    block_v = min(block_v, max(128, v))
    hp = _pad_to(h, block_t, 0)
    wp = _pad_to(w, block_v, 0)
    if wp.shape[0] < 3 * block_v:
        wp = _pad_to(wp, 3 * block_v, 0)
    lab = _pad_to(labels.astype(jnp.int32)[:, None], block_t, 0)
    lsep = _pad_to(lse[:, None], block_t, 0)
    gn = _pad_to((g_nll + g_lse).astype(jnp.float32)[:, None], block_t, 0)
    go = _pad_to(g_nll.astype(jnp.float32)[:, None], block_t, 0)
    tp, vp = hp.shape[0], wp.shape[0]
    gt, gv = tp // block_t, vp // block_v

    dw0 = jnp.zeros((vp, d), jnp.float32)
    dh, dw = pl.pallas_call(
        functools.partial(_bwd_kernel, block_v=block_v, v_total=v,
                          alias_dw=not interpret),
        grid=(gt, gv),
        in_specs=[
            pl.BlockSpec((block_t, d), lambda ti, vi: (ti, 0)),
            pl.BlockSpec((block_v, d), lambda ti, vi: (vi, 0)),
            pl.BlockSpec((block_t, 1), lambda ti, vi: (ti, 0)),
            pl.BlockSpec((block_t, 1), lambda ti, vi: (ti, 0)),
            pl.BlockSpec((block_t, 1), lambda ti, vi: (ti, 0)),
            pl.BlockSpec((block_t, 1), lambda ti, vi: (ti, 0)),
            pl.BlockSpec((block_v, d), lambda ti, vi: (vi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_t, d), lambda ti, vi: (ti, 0)),
            pl.BlockSpec((block_v, d), lambda ti, vi: (vi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((tp, d), jnp.float32),
            jax.ShapeDtypeStruct((vp, d), jnp.float32),
        ],
        input_output_aliases={6: 1},
        interpret=interpret,
    )(hp, wp, lab, lsep, gn, go, dw0)

    return dh[:t].astype(h.dtype), dw[:v].astype(w.dtype)
