"""Fused Kar–Karnick feature-map kernels — the FMBE substrate (paper Eq. 9/10)
as a tiled Pallas pipeline.

The XLA reference (``core.feature_maps.apply_feature_map``) materializes the
projection tensor ``proj (..., P, max_degree)`` with one einsum and reduces it
with a masked product — at serving shapes that intermediate is
``Q * P * max_degree`` floats of HBM round-trip per decode step. Here each
``(block_q, block_p)`` tile of the feature matrix is built as ``max_degree``
successive ``(block_q, d) x (d, block_p)`` MXU matmuls whose running degree
product lives in registers/VMEM:

    prod := 1
    for m in 0..max_degree-1:                # static unroll, M is 4-8
        prod *= where(degree > m, x @ omega[:, m, :].T, 1)
    phi_tile = prod * coef

Two entry points share that tile routine:

 * ``fmbe_phi``  — writes the (Q, P) feature matrix (parity / build-time use).
 * ``fmbe_z``    — the decode path: folds each tile straight into
   ``z += (phi_tile * lambda_tile).sum(feature axis)`` in VMEM, so HBM sees
   only the operands and the (Q, 1) estimate — no (Q, P) tensor at all.

HBM floats per decode step: ``P*max_degree*d (omega) + P (lambda) + Q*d`` —
independent of the vocab size V, the FMBE selling point the SS5/SS8 byte
accounting tracks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _phi_tile(x, om_ref, deg_ref, coef_ref, max_degree: int):
    """One (block_q, block_p) tile of phi. x (bq, d) f32; om (bp, M, d);
    deg/coef (1, bp). Factor order matches apply_feature_map exactly."""
    deg = deg_ref[...]                                    # (1, bp) int32
    prod = jnp.ones((x.shape[0], deg.shape[1]), jnp.float32)
    for m in range(max_degree):
        w_m = om_ref[:, m, :]                             # (bp, d)
        proj = jax.lax.dot_general(
            x, w_m, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (bq, bp)
        prod = prod * jnp.where(deg > m, proj, 1.0)
    return prod * coef_ref[...]


def _fmbe_phi_kernel(x_ref, om_ref, deg_ref, coef_ref, out_ref,
                     *, max_degree: int):
    x = x_ref[...].astype(jnp.float32)
    out_ref[...] = _phi_tile(x, om_ref, deg_ref, coef_ref, max_degree)


def _fmbe_z_kernel(x_ref, om_ref, deg_ref, coef_ref, lam_ref, out_ref,
                   z_scr, *, max_degree: int):
    # lam_ref is (1, bp) (one shared lambda) or (bq, bp) (per-query lambda,
    # the block-partitioned tail-sketch path) — broadcasting covers both
    pi = pl.program_id(1)

    @pl.when(pi == 0)
    def _init():
        z_scr[...] = jnp.zeros_like(z_scr)

    x = x_ref[...].astype(jnp.float32)
    phi = _phi_tile(x, om_ref, deg_ref, coef_ref, max_degree)   # (bq, bp)
    lam = lam_ref[...]                                          # (1|bq, bp)
    z_scr[...] += jnp.sum(phi * lam, axis=1, keepdims=True)

    @pl.when(pi == pl.num_programs(1) - 1)
    def _fin():
        out_ref[...] = z_scr[...]


def _pad_features(omega, degree, coef, block_p):
    """Pad the feature axis to a block multiple; pad features get coef == 0
    so they contribute exactly zero to phi and to z."""
    n_feat = omega.shape[0]
    pad_p = (-n_feat) % block_p
    om = jnp.pad(omega.astype(jnp.float32), ((0, pad_p), (0, 0), (0, 0)))
    deg = jnp.pad(degree.astype(jnp.int32), (0, pad_p)).reshape(1, -1)
    cf = jnp.pad(coef.astype(jnp.float32), (0, pad_p)).reshape(1, -1)
    return om, deg, cf


def fmbe_phi(omega, degree, coef, x, *, block_q: int = 128,
             block_p: int = 128, interpret=None):
    """phi(x) without the (Q, P, max_degree) intermediate.

    omega (P, max_degree, d), degree (P,), coef (P,), x (Q, d) -> (Q, P) f32.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n_feat, max_degree, d = omega.shape
    q = x.shape[0]
    block_q = min(block_q, max(8, q))
    block_p = min(block_p, max(128, n_feat))
    pad_q = (-q) % block_q
    xp = jnp.pad(x, ((0, pad_q), (0, 0)))
    om, deg, cf = _pad_features(omega, degree, coef, block_p)
    qp, pp = xp.shape[0], om.shape[0]
    out = pl.pallas_call(
        functools.partial(_fmbe_phi_kernel, max_degree=max_degree),
        grid=(qp // block_q, pp // block_p),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda qi, pi: (qi, 0)),
            pl.BlockSpec((block_p, max_degree, d), lambda qi, pi: (pi, 0, 0)),
            pl.BlockSpec((1, block_p), lambda qi, pi: (0, pi)),
            pl.BlockSpec((1, block_p), lambda qi, pi: (0, pi)),
        ],
        out_specs=pl.BlockSpec((block_q, block_p), lambda qi, pi: (qi, pi)),
        out_shape=jax.ShapeDtypeStruct((qp, pp), jnp.float32),
        interpret=interpret,
    )(xp, om, deg, cf)
    return out[:q, :n_feat]


def fmbe_z(omega, degree, coef, lam, x, *, block_q: int = 128,
           block_p: int = 128, interpret=None):
    """Fused decode estimate: Ẑ(x) = phi(x) . lambda, (Q,) signed f32.

    ``lam`` is (P,) — one shared sketch sum, the global-Z path — or (Q, P) —
    a per-query lambda, the block-partitioned complement path
    (``core.feature_maps.fmbe_tail_z``). The feature axis rides the inner
    grid dimension; per-query z accumulates in VMEM across feature tiles
    and is written once — HBM traffic is the operands plus Q floats.

    ``block_q``/``block_p`` are autotuned (kernels.autotune.tune_fmbe_z).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n_feat, max_degree, d = omega.shape
    q = x.shape[0]
    block_q = min(block_q, max(8, q))
    block_p = min(block_p, max(128, n_feat))
    pad_q = (-q) % block_q
    xp = jnp.pad(x, ((0, pad_q), (0, 0)))
    om, deg, cf = _pad_features(omega, degree, coef, block_p)
    pad_p = om.shape[0] - n_feat
    if lam.ndim == 1:
        lam_p = jnp.pad(lam.astype(jnp.float32), (0, pad_p)).reshape(1, -1)
        lam_spec = pl.BlockSpec((1, block_p), lambda qi, pi: (0, pi))
    else:
        lam_p = jnp.pad(lam.astype(jnp.float32),
                        ((0, pad_q), (0, pad_p)))
        lam_spec = pl.BlockSpec((block_q, block_p), lambda qi, pi: (qi, pi))
    qp, pp = xp.shape[0], om.shape[0]
    out = pl.pallas_call(
        functools.partial(_fmbe_z_kernel, max_degree=max_degree),
        grid=(qp // block_q, pp // block_p),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda qi, pi: (qi, 0)),
            pl.BlockSpec((block_p, max_degree, d), lambda qi, pi: (pi, 0, 0)),
            pl.BlockSpec((1, block_p), lambda qi, pi: (0, pi)),
            pl.BlockSpec((1, block_p), lambda qi, pi: (0, pi)),
            lam_spec,
        ],
        out_specs=pl.BlockSpec((block_q, 1), lambda qi, pi: (qi, 0)),
        out_shape=jax.ShapeDtypeStruct((qp, 1), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xp, om, deg, cf, lam_p)
    return out[:q, 0]
