"""Fused decode-time scoring: chunked q.W^T + online logsumexp + running top-k.

One pass over the (sharded) vocab produces, per query row, exact log Z and the
top-k candidate (score, id) pairs — the inputs the paper's Eq. 2/3 needs —
without materializing [Q, V] logits in HBM. With vocab sharded over ``model``
this kernel runs on the local shard; the O(k) merge lives in
``repro.core.distributed``.

Mosaic has no generic lax.top_k, so the running top-k is maintained by an
unrolled k-step max/mask sweep over [running_topk ++ tile_scores] using only
max/where/iota reductions (k is small and static: 1-32 for decode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30
BIG = 2 ** 30  # python int — becomes an inline literal inside the kernel


def _select_topk(cand_v, cand_i, k):
    """Top-k of each row via k max/mask sweeps (Mosaic-safe)."""
    out_v, out_i = [], []
    iota = jax.lax.broadcasted_iota(jnp.int32, cand_v.shape, 1)
    for _ in range(k):
        m = jnp.max(cand_v, axis=1, keepdims=True)              # (Q,1)
        pos = jnp.min(jnp.where(cand_v == m, iota, BIG), axis=1,
                      keepdims=True)
        sel = iota == pos
        out_v.append(m)
        out_i.append(jnp.sum(jnp.where(sel, cand_i, 0), axis=1,
                             keepdims=True))
        cand_v = jnp.where(sel, NEG, cand_v)
    return jnp.concatenate(out_v, axis=1), jnp.concatenate(out_i, axis=1)


def _topk_z_kernel(h_ref, w_ref, lse_ref, topv_ref, topi_ref,
                   m_scr, s_scr, tv_scr, ti_scr,
                   *, k: int, block_v: int, v_total: int):
    vi = pl.program_id(1)

    @pl.when(vi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        s_scr[...] = jnp.zeros_like(s_scr)
        tv_scr[...] = jnp.full_like(tv_scr, NEG)
        ti_scr[...] = jnp.zeros_like(ti_scr)

    h = h_ref[...]
    w = w_ref[...]
    scores = jax.lax.dot_general(
        h, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    col = vi * block_v + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(col < v_total, scores, NEG)

    # online logsumexp
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1, keepdims=True))
    s_scr[...] = (s_scr[...] * jnp.exp(m_prev - m_new) +
                  jnp.sum(jnp.exp(scores - m_new), axis=1, keepdims=True))
    m_scr[...] = m_new

    # running top-k merge
    cand_v = jnp.concatenate([tv_scr[...], scores], axis=1)
    cand_i = jnp.concatenate([ti_scr[...], col], axis=1)
    tv, ti = _select_topk(cand_v, cand_i, k)
    tv_scr[...] = tv
    ti_scr[...] = ti

    @pl.when(vi == pl.num_programs(1) - 1)
    def _fin():
        lse_ref[...] = m_scr[...] + jnp.log(s_scr[...])
        topv_ref[...] = tv_scr[...]
        topi_ref[...] = ti_scr[...]


def topk_z(h, w, k: int, *, block_q=128, block_v=512, interpret=None):
    """h (Q, d), w (V, d) -> (lse (Q,), topv (Q, k), topi (Q, k))."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    q, d = h.shape
    v = w.shape[0]
    block_q = min(block_q, max(8, q))
    block_v = min(block_v, max(128, v))
    pad_q = (-q) % block_q
    pad_v = (-v) % block_v
    hp = jnp.pad(h, ((0, pad_q), (0, 0)))
    wp = jnp.pad(w, ((0, pad_v), (0, 0)))
    qp, vp = hp.shape[0], wp.shape[0]
    kernel = functools.partial(_topk_z_kernel, k=k, block_v=block_v,
                               v_total=v)
    lse, topv, topi = pl.pallas_call(
        kernel,
        grid=(qp // block_q, vp // block_v),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda qi, vi: (qi, 0)),
            pl.BlockSpec((block_v, d), lambda qi, vi: (vi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, 1), lambda qi, vi: (qi, 0)),
            pl.BlockSpec((block_q, k), lambda qi, vi: (qi, 0)),
            pl.BlockSpec((block_q, k), lambda qi, vi: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qp, 1), jnp.float32),
            jax.ShapeDtypeStruct((qp, k), jnp.float32),
            jax.ShapeDtypeStruct((qp, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, k), jnp.float32),
            pltpu.VMEM((block_q, k), jnp.int32),
        ],
        interpret=interpret,
    )(hp, wp)
    return lse[:q, 0], topv[:q], topi[:q]
