"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

These materialize the full [tokens, vocab] logits — exactly what the kernels
exist to avoid — and are used by tests (assert_allclose vs interpret=True)
and by the roofline benchmarks as the "naive" baseline.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def fused_ce_ref(h: jax.Array, w: jax.Array,
                 labels: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Full-softmax CE. h (T, d), w (V, d), labels (T,) -> (nll (T,), lse (T,))."""
    logits = (h @ w.T).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[:, None], axis=1)[:, 0]
    return lse - picked, lse


def topk_z_ref(h: jax.Array, w: jax.Array,
               k: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused decode scoring. Returns (lse (Q,), topv (Q,k), topi (Q,k))."""
    logits = (h @ w.T).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    topv, topi = jax.lax.top_k(logits, k)
    return lse, topv, topi.astype(jnp.int32)


def ivf_score_ref(w_blocks: jax.Array, h: jax.Array,
                  block_ids: jax.Array) -> jax.Array:
    """Gather-score probed blocks.

    w_blocks (nb, br, d), h (Q, d), block_ids (Q, p) -> scores (Q, p, br).
    """
    gathered = w_blocks[block_ids]                 # (Q, p, br, d)
    return jnp.einsum("qpbd,qd->qpb", gathered,
                      h).astype(jnp.float32)
