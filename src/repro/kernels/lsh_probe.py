"""Fused Hamming-probe decode kernel for the LSH backend (DESIGN.md SS18).

One Pallas pipeline per (block_q, d) query tile:

  1. query codes IN-KERNEL at the first grid step: sign bits of one
     (block_q, d)x(d, L*K) matmul, packed to per-table integer codes by a
     second matmul against a constant power-of-two weight (K <= 24 keeps the
     packed value f32-exact) — no 3D reshapes, both stages run on the MXU;
  2. candidate phase: per (cand_tile,) slab of the dedup'd union, an
     exact-match compare of the query codes against the slab's stored codes
     (a static L-loop of 2D broadcast compares — the packed-word analogue of
     XOR+popcount == 0) yields per-candidate collision COUNTS; membership
     (count > 0, live slots only) gates an online head logsumexp and a
     running top-k over ORIGINAL row ids, scored against the slab's
     embedding rows resident in VMEM;
  3. tail phase: dense (tail_tile, d) slabs of the pre-gathered shared tail
     rows fold into a separate online logsumexp under the plan's rejection
     mask — identical to ``ivf_score.ivf_decode``'s tail.

Head scoring, the Hamming match, the collision counts, and the top-k merge
all share the single resident query tile; no (Q, C) score tensor ever
reaches HBM. Tiles past the measured live candidate count skip compute and
write zero counts, so per-step work tracks the *measured* union, not the
static capacity.

The in-kernel query codes are computed from the raw ``h`` tile; the plan's
donor-adjusted codes differ only on INACTIVE scheduler lanes, whose outputs
the scheduler discards (parity tests pin active=None).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .topk_z import NEG, _select_topk


def _probe_kernel(live_ref,                                 # scalar prefetch
                  h_ref, projt_ref, packw_ref, wc_ref, cct_ref, okt_ref,
                  cid_ref, wt_ref, acc_ref,
                  hlse_ref, tlse_ref, topv_ref, topi_ref, cnt_ref,
                  mh_scr, sh_scr, mt_scr, st_scr, tv_scr, ti_scr, qc_scr,
                  *, k: int, n_ctiles: int, cand_tile: int, n_tables: int):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        mh_scr[...] = jnp.full_like(mh_scr, NEG)
        sh_scr[...] = jnp.zeros_like(sh_scr)
        mt_scr[...] = jnp.full_like(mt_scr, NEG)
        st_scr[...] = jnp.zeros_like(st_scr)
        tv_scr[...] = jnp.full_like(tv_scr, NEG)
        ti_scr[...] = jnp.zeros_like(ti_scr)
        # query codes, once per query tile: sign-bit matmul + packing matmul
        s = jax.lax.dot_general(
            h_ref[...].astype(jnp.float32), projt_ref[...],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)             # (bq, L*K)
        bits = (s > 0).astype(jnp.float32)
        codes = jax.lax.dot_general(
            bits, packw_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)             # (bq, L)
        qc_scr[...] = codes.astype(jnp.int32)

    h = h_ref[...]                                          # (bq, d)
    col_off = si * cand_tile

    @pl.when((si < n_ctiles) & (col_off < live_ref[0]))
    def _cand_step():
        # Hamming match: exact code equality per table, live-routed only
        cnt = jnp.zeros((h.shape[0], cand_tile), jnp.int32)
        for t in range(n_tables):
            qc_t = qc_scr[:, t:t + 1]                       # (bq, 1)
            cc_t = cct_ref[t:t + 1, :]                      # (1, ct)
            ok_t = okt_ref[t:t + 1, :]                      # (1, ct)
            cnt = cnt + ((qc_t == cc_t) & (ok_t > 0)).astype(jnp.int32)
        col_live = (col_off +
                    jax.lax.broadcasted_iota(jnp.int32, cnt.shape, 1)
                    ) < live_ref[0]
        cnt = jnp.where(col_live, cnt, 0)
        cnt_ref[...] = cnt
        member = cnt > 0

        scores = jax.lax.dot_general(
            h, wc_ref[...], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)             # (bq, ct)
        eff = jnp.where(member, scores, NEG)
        m_prev = mh_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(eff, axis=1, keepdims=True))
        contrib = jnp.where(eff > NEG * 0.5,
                            jnp.exp(eff - m_new), 0.0)      # NEG-safe
        sh_scr[...] = (sh_scr[...] * jnp.exp(m_prev - m_new) +
                       jnp.sum(contrib, axis=1, keepdims=True))
        mh_scr[...] = m_new
        ids = jnp.broadcast_to(cid_ref[...], eff.shape)     # original row ids
        cand_v = jnp.concatenate([tv_scr[...], eff], axis=1)
        cand_i = jnp.concatenate([ti_scr[...], ids], axis=1)
        tv, ti = _select_topk(cand_v, cand_i, k)
        tv_scr[...] = tv
        ti_scr[...] = ti

    @pl.when((si < n_ctiles) & (col_off >= live_ref[0]))
    def _dead_cand_step():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    @pl.when(si >= n_ctiles)
    def _tail_step():
        rows = wt_ref[...]                                  # (tt, d)
        s = jax.lax.dot_general(
            h, rows, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)             # (bq, tt)
        acc = acc_ref[...]                                  # (bq, tt) 0/1
        eff = jnp.where(acc > 0, s, NEG)
        m_prev = mt_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(eff, axis=1, keepdims=True))
        contrib = jnp.where(eff > NEG * 0.5, jnp.exp(eff - m_new), 0.0)
        st_scr[...] = (st_scr[...] * jnp.exp(m_prev - m_new) +
                       jnp.sum(contrib, axis=1, keepdims=True))
        mt_scr[...] = m_new

    @pl.when(si == pl.num_programs(1) - 1)
    def _fin():
        hlse_ref[...] = mh_scr[...] + jnp.log(sh_scr[...])
        tlse_ref[...] = mt_scr[...] + jnp.log(st_scr[...])
        topv_ref[...] = tv_scr[...]
        topi_ref[...] = ti_scr[...]


def lsh_probe(w_cand, h, proj, cand_rows, cand_codes, cand_ok, cand_live,
              tail_rows, tail_accept, tail_bias, *, k: int = 1,
              block_q: int = 128, cand_tile: int = 128, tail_tile: int = 32,
              interpret=None):
    """Fused LSH probe-and-decode over a dedup'd candidate union.

    Inputs (see ``core.lsh.lsh_plan`` / ``lsh_decode``):
      w_cand      (C, d)       gathered candidate embedding rows
      h           (Q, d)       query batch
      proj        (L, K, d+1)  the index's hyperplanes (the trailing MIPS
                               column hits the rows' augmented coordinate;
                               queries hash with it identically 0, so the
                               kernel just drops it)
      cand_rows   (C,) int32   original row id per union slot (pad = 0)
      cand_codes  (C, L) int32 stored codes of the candidates (pad rows may
                               hold live rows' codes; masked by cand_live)
      cand_ok     (C, L) bool  slot_of_row >= 0 (row routed in that table)
      cand_live   () int32     measured unique candidate count
      tail_rows   (l, d)       shared tail rows, staged dense by the caller
      tail_accept (Q, l) bool  sample survives rejection for query q
      tail_bias   (l,) f32     per-sample importance bias -log(n p_j),
                               ADDED to the sample's score. Folded in via
                               one staged column: queries get a constant 1
                               coordinate, tail rows carry their bias there
                               (candidates a 0, the hyperplanes a 0 row),
                               so the kernel body needs no extra operand

    Returns (head_lse (Q,), tail_lse (Q,), topv (Q, k), topi (Q, k) ORIGINAL
    row ids, counts (Q, C) int32 per-candidate collision table-counts, zero
    past ``cand_live``). Queries with an empty collision set get
    head_lse == log 0; zero accepted tail samples get tail_lse == -inf.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    c, d = w_cand.shape
    q = h.shape[0]
    ltab, kbits, _ = proj.shape
    l = tail_rows.shape[0]
    assert l >= 1, "fused probe needs at least one tail sample"
    block_q = min(block_q, max(8, q))
    cand_tile = max(8, min(cand_tile, c))
    tail_tile = max(1, min(tail_tile, l))
    pad_q = (-q) % block_q
    pad_c = (-c) % cand_tile
    pad_l = (-l) % tail_tile

    # the staged width ds = d + 1: the extra column folds the tail
    # importance bias into the one shared query tile (see docstring)
    ds = d + 1
    hp = jnp.pad(h, ((0, pad_q), (0, 0)))
    hp = jnp.concatenate([hp, jnp.ones((hp.shape[0], 1), hp.dtype)], 1)
    wc_p = jnp.pad(w_cand.astype(jnp.float32),
                   ((0, pad_c), (0, 1)))                     # bias col = 0
    # pad codes are -1: query codes are >= 0, so pads can never match
    cct = jnp.pad(cand_codes.astype(jnp.int32), ((0, pad_c), (0, 0)),
                  constant_values=-1).T                      # (L, Cp)
    okt = jnp.pad(cand_ok.astype(jnp.float32), ((0, pad_c), (0, 0))).T
    cid = jnp.pad(cand_rows.astype(jnp.int32), (0, pad_c))[None, :]
    wt_p = jnp.concatenate(
        [jnp.pad(tail_rows.astype(jnp.float32), ((0, pad_l), (0, 0))),
         jnp.pad(tail_bias.astype(jnp.float32), (0, pad_l))[:, None]], 1)
    acc_p = jnp.pad(tail_accept.astype(jnp.float32),
                    ((0, pad_q), (0, pad_l)))
    projt = jnp.pad(proj[..., :d].reshape(
        ltab * kbits, d).T.astype(jnp.float32),
        ((0, 1), (0, 0)))                                    # (ds, L*K)
    packw = jnp.zeros((ltab * kbits, ltab), jnp.float32)
    packw = packw.at[jnp.arange(ltab * kbits),
                     jnp.arange(ltab * kbits) // kbits].set(
        (2.0 ** jnp.arange(kbits))[jnp.arange(ltab * kbits) % kbits])

    qp = hp.shape[0]
    cp = c + pad_c
    n_ctiles = cp // cand_tile
    n_ttiles = (l + pad_l) // tail_tile

    def _cs(si):
        return jnp.clip(si, 0, n_ctiles - 1)

    def _ts(si):
        return jnp.clip(si - n_ctiles, 0, n_ttiles - 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(qp // block_q, n_ctiles + n_ttiles),
        in_specs=[
            pl.BlockSpec((block_q, ds), lambda qi, si, lv: (qi, 0)),
            pl.BlockSpec((ds, ltab * kbits), lambda qi, si, lv: (0, 0)),
            pl.BlockSpec((ltab * kbits, ltab), lambda qi, si, lv: (0, 0)),
            # candidate slabs (clamped, hence DMA-elided, on tail steps)
            pl.BlockSpec((cand_tile, ds), lambda qi, si, lv: (_cs(si), 0)),
            pl.BlockSpec((ltab, cand_tile), lambda qi, si, lv: (0, _cs(si))),
            pl.BlockSpec((ltab, cand_tile), lambda qi, si, lv: (0, _cs(si))),
            pl.BlockSpec((1, cand_tile), lambda qi, si, lv: (0, _cs(si))),
            # tail slabs
            pl.BlockSpec((tail_tile, ds), lambda qi, si, lv: (_ts(si), 0)),
            pl.BlockSpec((block_q, tail_tile),
                         lambda qi, si, lv: (qi, _ts(si))),
        ],
        out_specs=[
            pl.BlockSpec((block_q, 1), lambda qi, si, lv: (qi, 0)),
            pl.BlockSpec((block_q, 1), lambda qi, si, lv: (qi, 0)),
            pl.BlockSpec((block_q, k), lambda qi, si, lv: (qi, 0)),
            pl.BlockSpec((block_q, k), lambda qi, si, lv: (qi, 0)),
            pl.BlockSpec((block_q, cand_tile),
                         lambda qi, si, lv: (qi, _cs(si))),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, k), jnp.float32),
            pltpu.VMEM((block_q, k), jnp.int32),
            pltpu.VMEM((block_q, ltab), jnp.int32),
        ],
    )
    kernel = functools.partial(_probe_kernel, k=k, n_ctiles=n_ctiles,
                               cand_tile=cand_tile, n_tables=ltab)
    hlse, tlse, topv, topi, counts = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((qp, 1), jnp.float32),
            jax.ShapeDtypeStruct((qp, 1), jnp.float32),
            jax.ShapeDtypeStruct((qp, k), jnp.float32),
            jax.ShapeDtypeStruct((qp, k), jnp.int32),
            jax.ShapeDtypeStruct((qp, cp), jnp.int32),
        ],
        interpret=interpret,
    )(jnp.asarray(cand_live, jnp.int32).reshape(1),
      hp, projt, packw, wc_p, cct, okt, cid, wt_p, acc_p)
    return (hlse[:q, 0], tlse[:q, 0], topv[:q], topi[:q], counts[:q, :c])


def lsh_probe_ref(w_cand, h, proj, cand_rows, cand_codes, cand_ok,
                  cand_live, tail_rows, tail_accept, tail_bias, *,
                  k: int = 1, block_q: int = 128, cand_tile: int = 128,
                  tail_tile: int = 32, interpret=None):
    """Pure-XLA reference with the fused kernel's exact contract — the
    parity oracle the bf16/f32 tests pin ``lsh_probe`` against."""
    del block_q, cand_tile, tail_tile, interpret
    ltab, kbits, _ = proj.shape
    d = h.shape[-1]
    s = h.astype(jnp.float32) @ proj[..., :d].reshape(ltab * kbits, d).T
    bits = (s > 0).astype(jnp.int32).reshape(-1, ltab, kbits)
    qcodes = (bits * (1 << jnp.arange(kbits, dtype=jnp.int32))).sum(-1)
    hit = ((qcodes[:, None, :] == cand_codes[None, :, :].astype(jnp.int32))
           & cand_ok[None, :, :].astype(bool))
    counts = hit.sum(-1).astype(jnp.int32)                  # (Q, C)
    col_live = jnp.arange(cand_rows.shape[0]) < cand_live
    counts = jnp.where(col_live[None, :], counts, 0)
    member = counts > 0

    scores = jax.lax.dot_general(
        h, w_cand, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                 # (Q, C)
    eff = jnp.where(member, scores, NEG)
    head_lse = jax.nn.logsumexp(eff, axis=-1)
    topv, pos = jax.lax.top_k(eff, k)
    topi = cand_rows[pos].astype(jnp.int32)

    ts = jax.lax.dot_general(
        h, tail_rows, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) \
        + tail_bias.astype(jnp.float32)[None, :]
    tail_eff = jnp.where(tail_accept, ts, NEG)
    tail_lse = jnp.where(jnp.any(tail_accept, axis=-1),
                         jax.nn.logsumexp(tail_eff, axis=-1), -jnp.inf)
    return head_lse, tail_lse, topv, topi, counts
