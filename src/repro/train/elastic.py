"""Elastic scaling + straggler mitigation hooks.

On a real cluster the coordinator detects node loss (missed heartbeats),
rebuilds the mesh from surviving hosts, and everyone restores from the last
logical checkpoint (checkpoint.py stores unsharded arrays, so resharding is
device_put with the new mesh's shardings). This module implements the
device-count-aware mesh rebuild + the step-time watchdog that flags
stragglers; launch/train.py wires them together.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax

# one shared (data, model) factorization: elastic rebuilds and the serving
# mesh (launch.mesh.make_serving_mesh) must agree on axis names/shapes
from ..launch.mesh import best_mesh_shape, make_mesh_2d

__all__ = ["best_mesh_shape", "make_elastic_mesh", "StragglerWatchdog"]


def make_elastic_mesh(model_parallel: int = 16,
                      devices: Optional[List] = None):
    devices = devices if devices is not None else jax.devices()
    shape = best_mesh_shape(len(devices), model_parallel)
    return make_mesh_2d(shape, devices)


@dataclasses.dataclass
class StragglerWatchdog:
    """EMA step-time monitor: flags steps slower than `threshold` x EMA.

    On TPU pods a flagged straggler triggers the control plane (replace the
    host / rebalance); here the hook records events and (optionally) raises
    after `max_consecutive` so the launcher can checkpoint + rebuild."""
    threshold: float = 3.0
    decay: float = 0.9
    max_consecutive: int = 10
    ema: float = 0.0
    consecutive: int = 0
    events: list = dataclasses.field(default_factory=list)
    _t0: float = 0.0

    def start_step(self):
        self._t0 = time.perf_counter()

    def end_step(self, step: int) -> bool:
        dt = time.perf_counter() - self._t0
        if self.ema == 0.0:
            self.ema = dt
            return False
        is_straggler = dt > self.threshold * self.ema
        if is_straggler:
            self.consecutive += 1
            self.events.append((step, dt, self.ema))
        else:
            self.consecutive = 0
            self.ema = self.decay * self.ema + (1 - self.decay) * dt
        if self.consecutive >= self.max_consecutive:
            raise RuntimeError(
                f"persistent straggler: {self.consecutive} consecutive slow "
                f"steps (last {dt:.3f}s vs EMA {self.ema:.3f}s) — "
                "checkpoint and rebuild the mesh")
        return is_straggler
