"""AdamW + cosine schedule + global-norm clipping — pure JAX (no optax).

Optimizer state is kept in f32 regardless of (bf16) param dtype; update math
runs in f32 and casts back — the standard mixed-precision recipe.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import TrainConfig


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros,
                    v=jax.tree.map(jnp.copy, zeros))


def lr_schedule(cfg: TrainConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(jax.tree.map(
        lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree))
    return jnp.sqrt(sum(leaves))


def adamw_update(cfg: TrainConfig, params, grads, state: OptState
                 ) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = lr_schedule(cfg, state.step)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        mh = m2 / bc1
        vh = v2 / bc2
        delta = mh / (jnp.sqrt(vh) + 1e-8) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, OptState(step=step, m=new_m, v=new_v), {
        "grad_norm": gnorm, "lr": lr}
