from .train_loop import (TrainMetricState, TrainState,
                         harvest_train_metrics, init_train_metric_state,
                         init_train_state, make_index_refresh,
                         make_instrumented_step, make_train_step)
from .optimizer import init_opt_state, adamw_update, lr_schedule
from .checkpoint import CheckpointManager
from .elastic import make_elastic_mesh, best_mesh_shape, StragglerWatchdog
from .losses import (get_loss, streaming_ce, estimator_ce, ESTIMATOR_LOSSES,
                     LOSSES)
