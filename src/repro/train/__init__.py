from .train_loop import (TrainState, init_train_state, make_index_refresh,
                         make_train_step)
from .optimizer import init_opt_state, adamw_update, lr_schedule
from .checkpoint import CheckpointManager
from .elastic import make_elastic_mesh, best_mesh_shape, StragglerWatchdog
from .losses import (get_loss, streaming_ce, estimator_ce, ESTIMATOR_LOSSES,
                     LOSSES)
