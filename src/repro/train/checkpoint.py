"""Fault-tolerant checkpointing.

Design (DESIGN.md SS6):
 * arrays are saved *logically* (fully-replicated numpy view) so a restart
   can reshard onto ANY mesh — elastic down/up-scaling reuses the same file;
 * writes are atomic (tmp dir + os.replace) so a node failure mid-write never
   corrupts the latest-good checkpoint;
 * optional async mode runs serialization in a daemon thread (training step
   N+1 overlaps the write of step N);
 * keep-last-K garbage collection;
 * a manifest carries step, config fingerprint, and data-iterator state so
   resume is exact (no replayed/skipped batches).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if tree is None:                                    # absent optional
        return out                                      # state (e.g. the
    if isinstance(tree, dict):                          # non-estimator
        for k, v in tree.items():                       # TrainState.index)
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    elif hasattr(tree, "_fields"):                      # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = tree
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ----------------------------------------------------------------

    def save(self, step: int, state: Any, extra: Dict[str, Any] = None
             ) -> None:
        """Snapshot `state` (pytree) at `step`. Non-blocking if async."""
        flat = _flatten(jax.device_get(state))
        arrays = {}
        for k, v in flat.items():
            a = np.asarray(v)
            if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
                a = a.astype(np.float32)   # npz-safe; restore() casts back
            arrays[k.replace("/", "__")] = a
        manifest = {"step": int(step), "time": time.time(),
                    "keys": sorted(arrays), "extra": extra or {}}
        self.wait()                                    # one writer at a time
        if self.async_write:
            self._thread = threading.Thread(
                target=self._write, args=(step, arrays, manifest),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, arrays, manifest)

    def _write(self, step: int, arrays, manifest) -> None:
        tmp = os.path.join(self.dir, f".tmp_step_{step}")
        final = os.path.join(self.dir, f"step_{step:010d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)                         # atomic publish
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- restore ---------------------------------------------------------------

    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                # a dir without manifest.json is a torn write -> ignore
                if os.path.exists(os.path.join(self.dir, name,
                                               "manifest.json")):
                    out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int], like: Any,
                shardings: Any = None) -> Tuple[Any, Dict]:
        """Restore into the structure of `like`; optionally device_put with
        `shardings` (same pytree structure) — this is where elastic restarts
        reshard onto the current mesh."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        flat_like = _flatten(like)
        vals = {}
        for k, ref in flat_like.items():
            arr = data[k.replace("/", "__")]
            if hasattr(ref, "dtype"):
                vals[k] = arr.astype(ref.dtype)
            elif isinstance(ref, (bool, int, float)):
                # static pytree scalars (e.g. IVFIndex.n / block_rows) come
                # back as their python type so the restored state's treedef
                # — and therefore every jit cache — matches `like` exactly
                vals[k] = type(ref)(arr)
            else:
                vals[k] = arr
        restored = _unflatten_like(like, vals)
        if shardings is not None:
            restored = jax.tree.map(
                lambda x, s: jax.device_put(x, s), restored, shardings)
        return restored, manifest


def _unflatten_like(like, vals, prefix=""):
    if like is None:
        return None
    if isinstance(like, dict):
        return {k: _unflatten_like(v, vals, f"{prefix}{k}/")
                for k, v in like.items()}
    if hasattr(like, "_fields"):
        return type(like)(*[
            _unflatten_like(getattr(like, k), vals, f"{prefix}{k}/")
            for k in like._fields])
    if isinstance(like, (list, tuple)):
        return type(like)(_unflatten_like(v, vals, f"{prefix}{i}/")
                          for i, v in enumerate(like))
    return vals[prefix[:-1]]
