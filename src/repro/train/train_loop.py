"""Train-step factory: grad-accumulation microbatching, loss registry,
metrics; the function lowered by the dry run and driven by launch/train.py.

Estimator-backed losses (``losses.ESTIMATOR_LOSSES``) thread a
device-resident IVF index through the step: ``TrainState.index`` carries
the block-IVF arrays (built by ``init_train_state`` from the initial output
embedding), every loss call routes its probe/tail plan through it, and
``make_index_refresh`` returns ONE jitted function that re-clusters/repacks
the index from the current embedding — shapes are static (``mips.pack_ivf``
capacity), so calling it every K steps never recompiles either it or the
train step.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, TrainConfig
from ..core import lsh as _lsh
from ..core import mips as _mips
from ..models import Model
from .losses import ESTIMATOR_LOSSES, get_loss
from .optimizer import OptState, adamw_update, init_opt_state
from .compression import compress_psum


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    rng: jax.Array
    index: Any = None       # retrieval index for estimator-backed losses
                            # (IVFIndex for mimps_ce/mince_ce, LSHIndex for
                            # lsh_ce), else None
                            # (checkpointed with the rest of the state so
                            # resume is bit-identical — see checkpoint.py)


class TrainMetricState(NamedTuple):
    """Device-resident training counters (obs layer, DESIGN.md SS17).

    Accumulated INSIDE the jitted step so the host only synchronizes on its
    harvest cadence — the step loop never calls ``block_until_ready`` per
    step just to log. Pure data: threading it through the step adds no
    executable variants (same principle as the scheduler's MetricState).
    """
    steps: jax.Array            # i32 scalar
    loss_sum: jax.Array         # f32 — running sum for the window mean
    loss_sq_sum: jax.Array      # f32 — running sum of squares (variance)
    loss_max: jax.Array         # f32
    grad_norm_sum: jax.Array    # f32
    grad_norm_max: jax.Array    # f32
    nonfinite: jax.Array        # i32 — steps whose loss was NaN/Inf


def init_train_metric_state() -> TrainMetricState:
    z32 = jnp.float32(0.0)
    return TrainMetricState(
        steps=jnp.int32(0), loss_sum=z32, loss_sq_sum=z32,
        loss_max=jnp.float32(-jnp.inf), grad_norm_sum=z32,
        grad_norm_max=z32, nonfinite=jnp.int32(0))


def observe_train_step(tm: TrainMetricState,
                       metrics: Dict[str, jax.Array]) -> TrainMetricState:
    """Fold one step's metrics into the counters (pure jnp, jit-safe).
    Non-finite losses are counted but excluded from the running moments so
    a single blown-up step doesn't poison the window mean."""
    loss = metrics["loss_total"].astype(jnp.float32)
    gn = metrics.get("grad_norm", jnp.float32(0.0)).astype(jnp.float32)
    ok = jnp.isfinite(loss)
    safe = jnp.where(ok, loss, 0.0)
    return TrainMetricState(
        steps=tm.steps + 1,
        loss_sum=tm.loss_sum + safe,
        loss_sq_sum=tm.loss_sq_sum + safe * safe,
        loss_max=jnp.maximum(tm.loss_max, jnp.where(ok, loss, -jnp.inf)),
        grad_norm_sum=tm.grad_norm_sum + gn,
        grad_norm_max=jnp.maximum(tm.grad_norm_max, gn),
        nonfinite=tm.nonfinite + (~ok).astype(jnp.int32))


def harvest_train_metrics(tm: TrainMetricState) -> Dict[str, float]:
    """ONE host sync: device_get the counters and derive window stats."""
    t = jax.device_get(tm)
    n = max(int(t.steps), 1)
    mean = float(t.loss_sum) / n
    var = max(float(t.loss_sq_sum) / n - mean * mean, 0.0)
    return {"steps": int(t.steps), "loss_mean": mean,
            "loss_std": var ** 0.5, "loss_max": float(t.loss_max),
            "grad_norm_mean": float(t.grad_norm_sum) / n,
            "grad_norm_max": float(t.grad_norm_max),
            "nonfinite_steps": int(t.nonfinite)}


def make_instrumented_step(step_fn):
    """Wrap a ``train_step`` so it also threads a ``TrainMetricState``:
    ``(state, tm, batch) -> (state, tm, metrics)``. Jit the RESULT — the
    accumulation fuses into the step executable for free."""
    def inst_step(state: TrainState, tm: TrainMetricState,
                  batch: Dict[str, jax.Array]):
        state, metrics = step_fn(state, batch)
        return state, observe_train_step(tm, metrics), metrics
    return inst_step


def _resolve_n_clusters(cfg: ModelConfig) -> int:
    pc = cfg.partition
    if pc.n_clusters > 0:
        return pc.n_clusters
    return max(1, cfg.vocab // (4 * pc.block_rows))


def init_train_state(model: Model, train_cfg: TrainConfig,
                     key: jax.Array) -> TrainState:
    kp, kr = jax.random.split(key)
    params = model.init(kp)
    index = None
    if train_cfg.loss in ESTIMATOR_LOSSES:
        if model.cfg.n_codebooks:
            raise NotImplementedError(
                "estimator-backed losses serve single-stream heads")
        pc = model.cfg.partition
        if train_cfg.loss == "lsh_ce":
            index = _lsh.build_lsh_device(
                jax.random.fold_in(key, 0x1DF), model.head_matrix(params),
                n_bits=pc.lsh_bits, n_tables=pc.lsh_tables,
                bucket_cap=pc.lsh_bucket_cap,
                mips_scale=pc.lsh_mips_scale, tail_beta=pc.lsh_tail_beta)
        else:
            index = _mips.build_ivf_device(
                jax.random.fold_in(key, 0x1DF), model.head_matrix(params),
                block_rows=pc.block_rows,
                n_clusters=_resolve_n_clusters(model.cfg))
    return TrainState(params=params, opt=init_opt_state(params), rng=kr,
                      index=index)


def make_index_refresh(model: Model, train_cfg: TrainConfig):
    """One jitted ``refresh(state) -> (state, metrics)`` — recluster/repack
    the index from the CURRENT embedding (metrics: churn / drift, the
    maintenance observables launch/train.py logs). Static shapes: the
    executable is traced once and reused for every refresh."""
    n_clusters = _resolve_n_clusters(model.cfg)
    iters = train_cfg.index_refresh_kmeans_iters

    # compiled over (index, params) -> (index, metrics) ONLY: returning the
    # whole TrainState would make XLA materialize fresh buffers for every
    # untouched params/opt leaf on each refresh (a full state copy + ~2x
    # transient memory at real model scale); the _replace happens on host
    if train_cfg.loss == "lsh_ce":
        # LSH refresh: keep the hyperplanes, re-hash + repack — one matmul
        # and L scatter packs, no Lloyd steps (same metrics contract)
        @jax.jit
        def _refresh(index, params):
            return _lsh.rehash_lsh(index, model.head_matrix(params))
    else:
        @jax.jit
        def _refresh(index, params):
            w = model.head_matrix(params)
            return _mips.refresh_ivf(index, w, n_clusters=n_clusters,
                                     kmeans_iters=iters)

    def refresh(state: TrainState):
        new_index, metrics = _refresh(state.index, state.params)
        return state._replace(index=new_index), metrics

    return refresh


def make_train_step(model: Model, train_cfg: TrainConfig, *,
                    backend: str = "xla", pod_axis: str = None, mesh=None):
    """Returns train_step(state, batch) -> (state, metrics).

    microbatches > 1 folds the leading batch dim into a lax.scan that
    accumulates gradients (activation memory / microbatch trade).
    pod_axis: if set (multi-pod shard_map usage), gradients are additionally
    psum'd over that axis with optional int8 compression.
    """
    loss_name = train_cfg.loss
    loss_fn = get_loss(loss_name)
    est_loss = loss_name in ESTIMATOR_LOSSES
    kwargs = {}
    if loss_name in ("fused_ce", "selfnorm"):
        kwargs["backend"] = backend
    if loss_name in ("fused_ce", "selfnorm") or est_loss:
        if mesh is not None:
            from .losses import make_token_constraint
            kwargs["constrain_fn"] = make_token_constraint(mesh)

    def compute_loss(params, batch, key, index):
        if est_loss:
            return loss_fn(model, params, batch, key, train_cfg,
                           index=index, **kwargs)
        return loss_fn(model, params, batch, key, train_cfg, **kwargs)

    grad_fn = jax.value_and_grad(compute_loss, has_aux=True)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]
                   ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        key, new_rng = jax.random.split(state.rng)
        index = state.index
        mb = train_cfg.microbatches
        if mb <= 1:
            (loss, metrics), grads = grad_fn(state.params, batch, key, index)
        else:
            def split_mb(x):
                # (B, ...) -> (mb, B/mb, ...) via (B/mb, mb) + swap so the
                # batch ('data'-sharded) dim STAYS sharded and the scanned
                # microbatch dim is replicated. A plain reshape(mb, B/mb)
                # puts the data sharding on the scan dim and GSPMD
                # all-gathers the full batch inside every microbatch
                # (measured: 8.5 TB/step of collectives on rwkv6 train_4k).
                return x.reshape(x.shape[0] // mb, mb,
                                 *x.shape[1:]).swapaxes(0, 1)
            batches = jax.tree.map(split_mb, batch)
            keys = jax.random.split(key, mb)

            def acc(carry, xs):
                g_acc, l_acc = carry
                b_i, k_i = xs
                (l, m), g = grad_fn(state.params, b_i, k_i, index)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), m
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state.params)
            (grads, loss), ms = jax.lax.scan(acc, (g0, 0.0), (batches, keys))
            grads = jax.tree.map(lambda g: g / mb, grads)
            loss = loss / mb
            metrics = jax.tree.map(lambda m: m[-1], ms)
        if pod_axis is not None:
            grads = compress_psum(grads, pod_axis,
                                  mode=train_cfg.grad_compression)
        params, opt, opt_metrics = adamw_update(
            train_cfg, state.params, grads, state.opt)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss_total"] = loss
        return TrainState(params=params, opt=opt, rng=new_rng,
                          index=index), metrics

    return train_step
