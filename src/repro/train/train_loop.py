"""Train-step factory: grad-accumulation microbatching, loss registry,
metrics; the function lowered by the dry run and driven by launch/train.py."""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, TrainConfig
from ..models import Model
from .losses import get_loss
from .optimizer import OptState, adamw_update, init_opt_state
from .compression import compress_psum


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    rng: jax.Array


def init_train_state(model: Model, train_cfg: TrainConfig,
                     key: jax.Array) -> TrainState:
    kp, kr = jax.random.split(key)
    params = model.init(kp)
    return TrainState(params=params, opt=init_opt_state(params), rng=kr)


def make_train_step(model: Model, train_cfg: TrainConfig, *,
                    backend: str = "xla", pod_axis: str = None, mesh=None):
    """Returns train_step(state, batch) -> (state, metrics).

    microbatches > 1 folds the leading batch dim into a lax.scan that
    accumulates gradients (activation memory / microbatch trade).
    pod_axis: if set (multi-pod shard_map usage), gradients are additionally
    psum'd over that axis with optional int8 compression.
    """
    loss_name = train_cfg.loss
    loss_fn = get_loss(loss_name)
    kwargs = {}
    if loss_name in ("fused_ce", "selfnorm"):
        kwargs["backend"] = backend
        if mesh is not None:
            from .losses import make_token_constraint
            kwargs["constrain_fn"] = make_token_constraint(mesh)

    def compute_loss(params, batch, key):
        return loss_fn(model, params, batch, key, train_cfg, **kwargs)

    grad_fn = jax.value_and_grad(compute_loss, has_aux=True)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]
                   ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        key, new_rng = jax.random.split(state.rng)
        mb = train_cfg.microbatches
        if mb <= 1:
            (loss, metrics), grads = grad_fn(state.params, batch, key)
        else:
            def split_mb(x):
                # (B, ...) -> (mb, B/mb, ...) via (B/mb, mb) + swap so the
                # batch ('data'-sharded) dim STAYS sharded and the scanned
                # microbatch dim is replicated. A plain reshape(mb, B/mb)
                # puts the data sharding on the scan dim and GSPMD
                # all-gathers the full batch inside every microbatch
                # (measured: 8.5 TB/step of collectives on rwkv6 train_4k).
                return x.reshape(x.shape[0] // mb, mb,
                                 *x.shape[1:]).swapaxes(0, 1)
            batches = jax.tree.map(split_mb, batch)
            keys = jax.random.split(key, mb)

            def acc(carry, xs):
                g_acc, l_acc = carry
                b_i, k_i = xs
                (l, m), g = grad_fn(state.params, b_i, k_i)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), m
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state.params)
            (grads, loss), ms = jax.lax.scan(acc, (g0, 0.0), (batches, keys))
            grads = jax.tree.map(lambda g: g / mb, grads)
            loss = loss / mb
            metrics = jax.tree.map(lambda m: m[-1], ms)
        if pod_axis is not None:
            grads = compress_psum(grads, pod_axis,
                                  mode=train_cfg.grad_compression)
        params, opt, opt_metrics = adamw_update(
            train_cfg, state.params, grads, state.opt)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss_total"] = loss
        return TrainState(params=params, opt=opt, rng=new_rng), metrics

    return train_step
