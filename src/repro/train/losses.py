"""Training losses, all partition-aware (DESIGN.md SS2).

 * fused_ce : streaming softmax CE. `backend='pallas'` uses the Pallas kernel
   (TPU); `backend='xla'` uses an equivalent custom-VJP lax.scan formulation
   that also never materializes [T, V] logits — this is the path the 512-way
   dry-run lowers, so the roofline HLO reflects the streaming algorithm.
 * ce        : naive full-logits CE (small vocab / tests).
 * nce       : noise-contrastive estimation with Z clamped to 1 — the paper's
   SS5.2 training setup (unigram noise).
 * selfnorm  : full CE + alpha * log(Z)^2 penalty (Devlin et al.).
 * sampled   : importance-sampled softmax (uniform proposal — the paper's
   UNIFORM baseline used as a training objective).
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from ..kernels.ops import fused_cross_entropy

Array = jax.Array


# ---------------------------------------------------------------------------
# XLA-native streaming CE (same contract as the Pallas kernel)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _xla_fused_ce(h: Array, w: Array, labels: Array,
                  chunk: int) -> Tuple[Array, Array]:
    nll, lse, _ = _xla_ce_fwd_impl(h, w, labels, chunk)
    return nll, lse


def _interleaved_chunks(w, chunk):
    """(V, d) -> (n_chunks, chunk, d) where chunk j holds rows
    {b * n_chunks + j : b}. With V contiguously sharded over 'model', every
    chunk then spans ALL vocab shards, so the per-chunk logits dot stays
    local (contiguous chunks live on one shard each — GSPMD materializes
    them via a full-logits all-reduce per chunk: measured 550 GB/step on
    rwkv6 train_4k at (16,16)). Row r of chunk (j, b) is b*n_chunks + j."""
    v, d = w.shape
    pad = (-v) % chunk
    wp = jnp.pad(w, ((0, pad), (0, 0))) if pad else w
    n_chunks = wp.shape[0] // chunk
    return wp.reshape(chunk, n_chunks, d).swapaxes(0, 1), n_chunks


def _xla_ce_fwd_impl(h, w, labels, chunk):
    v, d = w.shape
    wc, n_chunks = _interleaved_chunks(w, chunk)

    def body(carry, xs):
        m, s, p = carry
        wi, ci = xs
        scores = (h @ wi.T).astype(jnp.float32)          # (T, chunk)
        col = jnp.arange(chunk) * n_chunks + ci
        scores = jnp.where(col[None, :] < v, scores, -1e30)
        hit = col[None, :] == labels[:, None]
        p = jnp.maximum(p, jnp.max(jnp.where(hit, scores, -1e30), -1))
        m_new = jnp.maximum(m, jnp.max(scores, -1))
        s = s * jnp.exp(m - m_new) + jnp.sum(
            jnp.exp(scores - m_new[:, None]), -1)
        return (m_new, s, p), None

    t = h.shape[0]
    init = (jnp.full((t,), -1e30, jnp.float32), jnp.zeros((t,), jnp.float32),
            jnp.full((t,), -1e30, jnp.float32))
    (m, s, p), _ = jax.lax.scan(body, init, (wc, jnp.arange(n_chunks)))
    lse = m + jnp.log(s)
    return lse - p, lse, (m, s)


def _xla_ce_fwd(h, w, labels, chunk):
    nll, lse, _ = _xla_ce_fwd_impl(h, w, labels, chunk)
    return (nll, lse), (h, w, labels, lse)


def _xla_ce_bwd(chunk, res, cts):
    h, w, labels, lse = res
    g_nll, g_lse = cts
    gn = (g_nll + g_lse).astype(jnp.float32)
    go = g_nll.astype(jnp.float32)
    v, d = w.shape
    wc, n_chunks = _interleaved_chunks(w, chunk)

    def body(dh, xs):
        wi, ci = xs
        scores = (h @ wi.T).astype(jnp.float32)
        col = jnp.arange(chunk) * n_chunks + ci
        probs = jnp.where(col[None, :] < v,
                          jnp.exp(scores - lse[:, None]), 0.0)
        onehot = (col[None, :] == labels[:, None]).astype(jnp.float32)
        coef = gn[:, None] * probs - go[:, None] * onehot   # (T, chunk)
        dh = dh + (coef @ wi.astype(jnp.float32))
        dwi = coef.T @ h.astype(jnp.float32)                # (chunk, d)
        return dh, dwi

    dh0 = jnp.zeros(h.shape, jnp.float32)
    dh, dwc = jax.lax.scan(body, dh0, (wc, jnp.arange(n_chunks)))
    # ys[j, b] is the grad of row b*n_chunks + j  ->  swap back and flatten
    dw = dwc.swapaxes(0, 1).reshape(-1, d)[:v]
    import numpy as np
    return (dh.astype(h.dtype), dw.astype(w.dtype),
            np.zeros(labels.shape, dtype=jax.dtypes.float0))


_xla_fused_ce.defvjp(_xla_ce_fwd, _xla_ce_bwd)


def streaming_ce(h, w, labels, *, backend: str = "xla",
                 chunk: int = 2048) -> Tuple[Array, Array]:
    """(nll, lse) per token; h (T, d), w (V, d)."""
    if backend == "pallas":
        return fused_cross_entropy(h, w, labels)
    return _xla_fused_ce(h, w, labels, chunk)


# ---------------------------------------------------------------------------
# loss entry points — each maps (model, params, batch, key, cfg) -> scalar
# ---------------------------------------------------------------------------

def make_token_constraint(mesh):
    """Constraint fn re-pinning the token dim to the data axes after the
    remat/reshape boundary (without it the CE inherits a replicated-T
    fixpoint and its logit chunks are materialized at full T — measured
    550 GB/step of all-reduces on rwkv6 train_4k at (16,16))."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    size = 1
    for a in axes:
        size *= mesh.shape[a]

    def constrain(x):
        if not axes or x.shape[0] % size:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(axes, *([None] * (x.ndim - 1)))))
    return constrain


def _flatten_head(model, params, hidden, labels, constrain_fn=None):
    """Returns (h2d (T, d), w (V, d), lab (T,)) handling codebook heads."""
    cfg = model.cfg
    c = constrain_fn or (lambda x: x)
    w = model.head_matrix(params)
    if cfg.n_codebooks:
        t = hidden.shape[0] * hidden.shape[1]
        h2 = jnp.repeat(hidden.reshape(t, -1), cfg.n_codebooks, axis=0)
        wf = w.reshape(cfg.n_codebooks * cfg.vocab, -1)
        lab = (labels.reshape(t, cfg.n_codebooks) +
               jnp.arange(cfg.n_codebooks) * cfg.vocab)
        # treat each codebook as its own vocab segment of a single big head
        return h2, wf, lab.reshape(-1)
    return (c(hidden.reshape(-1, hidden.shape[-1])), w,
            c(labels.reshape(-1)))


def loss_fused_ce(model, params, batch, key, train_cfg, *,
                  backend="xla", constrain_fn=None) -> Tuple[Array, Dict]:
    tokens, labels = batch["tokens"], batch["labels"]
    hidden, aux = model.forward(params, tokens, img=batch.get("img"))
    h2, w, lab = _flatten_head(model, params, hidden, labels, constrain_fn)
    nll, lse = streaming_ce(h2, w, lab, backend=backend)
    loss = nll.mean()
    metrics = {"loss": loss, "ppl_proxy": loss,
               "mean_log_z": lse.mean(),
               **{k: v for k, v in aux.items() if "moe" in k}}
    total = loss + aux.get("moe_balance", 0.0) + aux.get("moe_zloss", 0.0)
    return total, metrics


def loss_ce(model, params, batch, key, train_cfg) -> Tuple[Array, Dict]:
    """Naive full-logits CE — small vocabs/tests."""
    tokens, labels = batch["tokens"], batch["labels"]
    hidden, aux = model.forward(params, tokens, img=batch.get("img"))
    logits = model.logits(params, hidden)
    if model.cfg.n_codebooks:
        lse = jax.nn.logsumexp(logits, -1)
        picked = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
        nll = (lse - picked).mean()
    else:
        lse = jax.nn.logsumexp(logits, -1)
        picked = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
        nll = (lse - picked).mean()
    total = nll + aux.get("moe_balance", 0.0) + aux.get("moe_zloss", 0.0)
    return total, {"loss": nll, "mean_log_z": lse.mean()}


def loss_selfnorm(model, params, batch, key, train_cfg, *,
                  backend="xla", constrain_fn=None) -> Tuple[Array, Dict]:
    """CE + alpha log(Z)^2 (Devlin) — trains Z(q) ~= 1 so that serving can
    use method='selfnorm' (the heuristic the paper beats in Table 4)."""
    tokens, labels = batch["tokens"], batch["labels"]
    hidden, aux = model.forward(params, tokens, img=batch.get("img"))
    h2, w, lab = _flatten_head(model, params, hidden, labels, constrain_fn)
    nll, lse = streaming_ce(h2, w, lab, backend=backend)
    alpha = train_cfg.selfnorm_alpha
    loss = nll.mean() + alpha * jnp.mean(lse ** 2)
    return loss + aux.get("moe_balance", 0.0), {
        "loss": nll.mean(), "mean_log_z": lse.mean(),
        "selfnorm_penalty": jnp.mean(lse ** 2)}


def loss_nce(model, params, batch, key, train_cfg) -> Tuple[Array, Dict]:
    """NCE with Z clamped to 1, uniform-unigram noise (paper SS5.2 setup)."""
    tokens, labels = batch["tokens"], batch["labels"]
    hidden, aux = model.forward(params, tokens, img=batch.get("img"))
    h2, w, lab = _flatten_head(model, params, hidden, labels)
    t = h2.shape[0]
    kn = train_cfg.nce_noise
    v = w.shape[0]
    noise = jax.random.randint(key, (t, kn), 0, v)
    s_t = jnp.sum(h2 * w[lab], axis=-1)
    s_n = jnp.einsum("td,tkd->tk", h2, w[noise])
    log_q = -jnp.log(jnp.float32(v))                 # uniform noise
    log_k = jnp.log(jnp.float32(kn))
    pos = jax.nn.log_sigmoid(s_t - log_k - log_q)
    neg = jax.nn.log_sigmoid(-(s_n - log_k - log_q))
    loss = -(pos.mean() + neg.sum(-1).mean())
    return loss + aux.get("moe_balance", 0.0), {"loss": loss}


def loss_sampled(model, params, batch, key, train_cfg) -> Tuple[Array, Dict]:
    """Importance-sampled softmax with uniform proposal (UNIFORM baseline)."""
    tokens, labels = batch["tokens"], batch["labels"]
    hidden, aux = model.forward(params, tokens, img=batch.get("img"))
    h2, w, lab = _flatten_head(model, params, hidden, labels)
    t = h2.shape[0]
    kn = train_cfg.nce_noise
    v = w.shape[0]
    samp = jax.random.randint(key, (t, kn), 0, v)
    s_t = jnp.sum(h2 * w[lab], axis=-1)
    s_n = jnp.einsum("td,tkd->tk", h2, w[samp])
    # log Z_hat = log( (V/k) sum exp(s_n) )  (uniform IS estimate of Z)
    log_z = (jax.nn.logsumexp(s_n, -1) + jnp.log(jnp.float32(v))
             - jnp.log(jnp.float32(kn)))
    loss = (log_z - s_t).mean()
    return loss + aux.get("moe_balance", 0.0), {"loss": loss,
                                                "mean_log_z": log_z.mean()}


LOSSES: Dict[str, Callable] = {
    "fused_ce": loss_fused_ce,
    "ce": loss_ce,
    "selfnorm": loss_selfnorm,
    "nce": loss_nce,
    "sampled": loss_sampled,
}


def get_loss(name: str) -> Callable:
    return LOSSES[name]
