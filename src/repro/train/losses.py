"""Training losses, all partition-aware (DESIGN.md SS2, SS13).

 * fused_ce : streaming softmax CE. `backend='pallas'` uses the Pallas kernel
   (TPU); `backend='xla'` uses an equivalent custom-VJP lax.scan formulation
   that also never materializes [T, V] logits — this is the path the 512-way
   dry-run lowers, so the roofline HLO reflects the streaming algorithm.
 * ce        : naive full-logits CE (small vocab / tests).
 * nce       : noise-contrastive estimation with Z clamped to 1 — the paper's
   SS5.2 training setup (unigram noise).
 * selfnorm  : full CE + alpha * log(Z)^2 penalty (Devlin et al.).
 * sampled   : importance-sampled softmax (uniform proposal — the paper's
   UNIFORM baseline used as a training objective).
 * mimps_ce  : estimator-backed CE (Spring & Shrivastava 2017 applied to the
   paper's Eq. 5): log Ẑ from the IVF probe-union head (scored EXACTLY
   against the live ``w``) plus the Rao-Blackwellized uniform tail, and a
   custom VJP whose backward scatter-adds embedding gradients ONLY into the
   probed/tail/label rows — both the forward floats and the embedding-grad
   floats are sublinear in V. Requires an ``IVFIndex`` threaded through
   ``TrainState`` (train_loop) and refreshed as ``w`` drifts
   (``mips.refresh_ivf``).
 * mince_ce  : same sparse machinery with the log Ẑ taken as the anchored
   MINCE root — which by the PR-3 collapse identity coincides exactly with
   the Eq. 5 anchor, so the two losses share one implementation (the name
   exists so ``--loss`` mirrors serving's ``--method``; Barber & Botev 2016
   frame both as points on the same trade-off).
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from ..core import lsh as _lsh
from ..core.decode import (_with_trimmed_head, head_row_table, make_plan,
                           tail_row_ids)
from ..core.estimators import NEG_INF, combine_head_tail_lse
from ..kernels.ops import fused_cross_entropy

Array = jax.Array


# ---------------------------------------------------------------------------
# XLA-native streaming CE (same contract as the Pallas kernel)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _xla_fused_ce(h: Array, w: Array, labels: Array,
                  chunk: int) -> Tuple[Array, Array]:
    nll, lse, _ = _xla_ce_fwd_impl(h, w, labels, chunk)
    return nll, lse


def _interleaved_chunks(w, chunk):
    """(V, d) -> (n_chunks, chunk, d) where chunk j holds rows
    {b * n_chunks + j : b}. With V contiguously sharded over 'model', every
    chunk then spans ALL vocab shards, so the per-chunk logits dot stays
    local (contiguous chunks live on one shard each — GSPMD materializes
    them via a full-logits all-reduce per chunk: measured 550 GB/step on
    rwkv6 train_4k at (16,16)). Row r of chunk (j, b) is b*n_chunks + j."""
    v, d = w.shape
    pad = (-v) % chunk
    wp = jnp.pad(w, ((0, pad), (0, 0))) if pad else w
    n_chunks = wp.shape[0] // chunk
    return wp.reshape(chunk, n_chunks, d).swapaxes(0, 1), n_chunks


def _xla_ce_fwd_impl(h, w, labels, chunk):
    v, d = w.shape
    wc, n_chunks = _interleaved_chunks(w, chunk)

    def body(carry, xs):
        m, s, p = carry
        wi, ci = xs
        scores = (h @ wi.T).astype(jnp.float32)          # (T, chunk)
        col = jnp.arange(chunk) * n_chunks + ci
        scores = jnp.where(col[None, :] < v, scores, -1e30)
        hit = col[None, :] == labels[:, None]
        p = jnp.maximum(p, jnp.max(jnp.where(hit, scores, -1e30), -1))
        m_new = jnp.maximum(m, jnp.max(scores, -1))
        s = s * jnp.exp(m - m_new) + jnp.sum(
            jnp.exp(scores - m_new[:, None]), -1)
        return (m_new, s, p), None

    t = h.shape[0]
    init = (jnp.full((t,), -1e30, jnp.float32), jnp.zeros((t,), jnp.float32),
            jnp.full((t,), -1e30, jnp.float32))
    (m, s, p), _ = jax.lax.scan(body, init, (wc, jnp.arange(n_chunks)))
    lse = m + jnp.log(s)
    return lse - p, lse, (m, s)


def _xla_ce_fwd(h, w, labels, chunk):
    nll, lse, _ = _xla_ce_fwd_impl(h, w, labels, chunk)
    return (nll, lse), (h, w, labels, lse)


def _xla_ce_bwd(chunk, res, cts):
    h, w, labels, lse = res
    g_nll, g_lse = cts
    gn = (g_nll + g_lse).astype(jnp.float32)
    go = g_nll.astype(jnp.float32)
    v, d = w.shape
    wc, n_chunks = _interleaved_chunks(w, chunk)

    def body(dh, xs):
        wi, ci = xs
        scores = (h @ wi.T).astype(jnp.float32)
        col = jnp.arange(chunk) * n_chunks + ci
        probs = jnp.where(col[None, :] < v,
                          jnp.exp(scores - lse[:, None]), 0.0)
        onehot = (col[None, :] == labels[:, None]).astype(jnp.float32)
        coef = gn[:, None] * probs - go[:, None] * onehot   # (T, chunk)
        dh = dh + (coef @ wi.astype(jnp.float32))
        dwi = coef.T @ h.astype(jnp.float32)                # (chunk, d)
        return dh, dwi

    dh0 = jnp.zeros(h.shape, jnp.float32)
    dh, dwc = jax.lax.scan(body, dh0, (wc, jnp.arange(n_chunks)))
    # ys[j, b] is the grad of row b*n_chunks + j  ->  swap back and flatten
    dw = dwc.swapaxes(0, 1).reshape(-1, d)[:v]
    import numpy as np
    return (dh.astype(h.dtype), dw.astype(w.dtype),
            np.zeros(labels.shape, dtype=jax.dtypes.float0))


_xla_fused_ce.defvjp(_xla_ce_fwd, _xla_ce_bwd)


def streaming_ce(h, w, labels, *, backend: str = "xla",
                 chunk: int = 2048) -> Tuple[Array, Array]:
    """(nll, lse) per token; h (T, d), w (V, d)."""
    if backend == "pallas":
        return fused_cross_entropy(h, w, labels)
    return _xla_fused_ce(h, w, labels, chunk)


# ---------------------------------------------------------------------------
# Sparse estimator-backed CE (custom VJP; DESIGN.md SS13)
# ---------------------------------------------------------------------------

def _float0(x):
    import numpy as np
    return np.zeros(x.shape, dtype=jax.dtypes.float0)


@jax.custom_vjp
def _sparse_ce(h: Array, w: Array, labels: Array, head_rows: Array,
               head_mask: Array, tail_ids: Array, tail_accept: Array,
               tail_bias: Array, n_tail_total: Array, label_in_head: Array
               ) -> Tuple[Array, Array]:
    """(nll, log Ẑ) per token from a sparse row table.

    Forward: one (T, d) x (d, Hc + l) gather+matmul scores the probe-union
    head rows EXACTLY and the shared tail rows, combined per Eq. 5
    (Rao-Blackwellized (N - k_eff)/n_accept scale). ``tail_bias`` (l,)
    generalizes the combine to importance-sampled tails (Hajek form): each
    sample's score gets -log(n p_j) added and the accept count becomes the
    matching effective mass — all-zero bias is bit-for-bit the uniform
    ratio estimator. When the label's block
    was not probed, its exact score is added to Ẑ explicitly (the
    sampled-softmax "target always in the support" guarantee: p̂ <= 1 and
    the gradient never pushes through a Ẑ that is missing the label's own
    mass); accidental label hits in the tail are pre-masked by the caller
    so that mass is never double-counted.

    Backward: d nll/d s_i = p̂_i over the same sparse support, so ``dw``
    is three scatter-adds — head rows, tail rows, label rows — touching
    (U*br + l + T) rows instead of V. That makes the embedding-GRADIENT
    floats sublinear too, which is the whole point of estimator-backed
    training (forward-only sublinearity leaves the V*d backward untouched).
    """
    nll, log_z, _ = _sparse_ce_impl(h, w, labels, head_rows, head_mask,
                                    tail_ids, tail_accept, tail_bias,
                                    n_tail_total, label_in_head)
    return nll, log_z


def _sparse_ce_impl(h, w, labels, head_rows, head_mask, tail_ids,
                    tail_accept, tail_bias, n_tail_total, label_in_head):
    scores = jax.lax.dot_general(
        h, w[head_rows], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                  # (T, Hc)
    head_lse = jax.nn.logsumexp(jnp.where(head_mask, scores, NEG_INF), -1)
    ts = jax.lax.dot_general(
        h, w[tail_ids], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) \
        + tail_bias.astype(jnp.float32)[None, :]             # (T, l)
    n_acc = jnp.sum(tail_accept
                    * jnp.exp(tail_bias.astype(jnp.float32))[None, :], -1)
    tail_lse = jax.nn.logsumexp(jnp.where(tail_accept, ts, NEG_INF), -1)
    tail_lse = jnp.where(jnp.any(tail_accept, -1), tail_lse, -jnp.inf)
    log_z0 = combine_head_tail_lse(head_lse, tail_lse, n_tail_total, n_acc)
    s_lab = jnp.einsum("td,td->t", h.astype(jnp.float32),
                       w[labels].astype(jnp.float32))
    log_z = jnp.where(label_in_head, log_z0, jnp.logaddexp(log_z0, s_lab))
    return log_z - s_lab, log_z, (scores, ts, s_lab, n_acc)


def _sparse_ce_fwd(h, w, labels, head_rows, head_mask, tail_ids,
                   tail_accept, tail_bias, n_tail_total, label_in_head):
    nll, log_z, (scores, ts, s_lab, n_acc) = _sparse_ce_impl(
        h, w, labels, head_rows, head_mask, tail_ids, tail_accept,
        tail_bias, n_tail_total, label_in_head)
    res = (h, w, labels, head_rows, head_mask, tail_ids, tail_accept,
           n_tail_total, label_in_head, scores, ts, s_lab, n_acc, log_z)
    return (nll, log_z), res


def _sparse_ce_bwd(res, cts):
    # NOTE ``ts`` is saved with tail_bias already folded in and ``n_acc``
    # is the bias-weighted effective count, so the Hajek gradient below is
    # textually the uniform one
    (h, w, labels, head_rows, head_mask, tail_ids, tail_accept,
     n_tail_total, label_in_head, scores, ts, s_lab, n_acc, log_z) = res
    g_nll, g_lz = cts
    g1 = (g_nll + g_lz).astype(jnp.float32)                  # logẐ path
    # p̂ over the sparse support (masked slots exp-underflow to exactly 0)
    p = jnp.where(head_mask, jnp.exp(scores - log_z[:, None]), 0.0) \
        * g1[:, None]                                        # (T, Hc)
    ok = (n_tail_total > 0) & (n_acc > 0)
    sigma = jnp.where(ok, n_tail_total / jnp.maximum(n_acc, 1e-9), 0.0)
    qc = jnp.where(tail_accept, jnp.exp(ts - log_z[:, None]), 0.0) \
        * (sigma * g1)[:, None]                              # (T, l)
    r = jnp.where(label_in_head, 0.0, jnp.exp(s_lab - log_z))
    lab_coef = g1 * r - g_nll.astype(jnp.float32)            # (T,)
    hf = h.astype(jnp.float32)
    dh = (p @ w[head_rows].astype(jnp.float32)
          + qc @ w[tail_ids].astype(jnp.float32)
          + lab_coef[:, None] * w[labels].astype(jnp.float32))
    # the sublinear scatter: (U*br + l + T) rows of w, not V
    dw = jnp.zeros(w.shape, jnp.float32)
    dw = dw.at[head_rows].add(p.T @ hf)
    dw = dw.at[tail_ids].add(qc.T @ hf)
    dw = dw.at[labels].add(lab_coef[:, None] * hf)
    return (dh.astype(h.dtype), dw.astype(w.dtype), _float0(labels),
            _float0(head_rows), _float0(head_mask), _float0(tail_ids),
            _float0(tail_accept), jnp.zeros(tail_ids.shape, jnp.float32),
            jnp.zeros_like(n_tail_total), _float0(label_in_head))


_sparse_ce.defvjp(_sparse_ce_fwd, _sparse_ce_bwd)


def estimator_ce(index, h: Array, w: Array, labels: Array, key: Array, *,
                 n_probe: int, l: int, head_cap: int = 0
                 ) -> Tuple[Array, Array, Dict[str, Array]]:
    """Estimator-backed CE over a token batch: plan once, score sparsely.

    The index supplies ROUTING only (probe centroids, block layout, tail
    map); all scores come from the live ``w`` via ``head_row_table`` /
    ``tail_row_ids``, so the loss is exact at the current parameters even
    when the index is a few refreshes stale — staleness degrades retrieval
    quality (which rows are in the head), never gradient correctness on
    the retrieved support.
    (This is also why the head matmul gathers ``w`` rows instead of running
    the ``ivf_score`` kernel over ``index.v_blocks``: the kernel scores the
    index's embedded COPIES, which are exactly what drifts between
    refreshes. Serving — where w IS the indexed snapshot — keeps the
    kernel path.)

    ``head_cap`` (blocks) statically trims the scored union exactly like
    the serving decodes: when the measured unique count fits, only
    head_cap*br head rows are gathered/scored/scatter-added; a
    ``lax.cond`` falls back to the full min(T*n_probe, nb) capacity so
    overflow costs wall-clock, never correctness. 0 = no trim (training
    batches don't share context, so the serving auto-cap would always
    overflow — callers size T*n_probe*block_rows << V instead).

    Returns (nll (T,), log Ẑ (T,), aux metrics).
    """
    plan = make_plan(index, h, key, n_probe, l)
    br = index.v_blocks.shape[1]
    lab_block = index.slot_of_row[labels] // br
    label_in_head = jnp.any(plan.block_ids == lab_block[:, None], -1)
    tail_ids = tail_row_ids(index, plan)
    # a tail sample that IS the label is dropped: its mass enters Ẑ exactly
    # (head or explicit term), so the tail must estimate the complement
    accept = plan.tail_accept & (tail_ids[None, :] != labels[:, None])
    n_tail_total = (index.n - plan.k_eff).astype(jnp.float32) \
        - (~label_in_head).astype(jnp.float32)

    def run(head_ids, member):
        head_rows, head_mask = head_row_table(index, head_ids, member)
        return _sparse_ce(h, w, labels, head_rows, head_mask, tail_ids,
                          accept, jnp.zeros(tail_ids.shape, jnp.float32),
                          n_tail_total, label_in_head)

    capacity = plan.head_ids.shape[0]
    nll, log_z = _with_trimmed_head(
        plan, head_cap if head_cap > 0 else capacity, run)
    aux = {"head_hit_rate": jnp.mean(label_in_head.astype(jnp.float32)),
           "k_eff": jnp.mean(plan.k_eff.astype(jnp.float32)),
           "head_live": plan.head_live}
    return nll, log_z, aux


def lsh_estimator_ce(lsh_index, h: Array, w: Array, labels: Array,
                     key: Array, *, l: int, cand_cap: int = 0
                     ) -> Tuple[Array, Array, Dict[str, Array]]:
    """Estimator-backed CE routed through the SimHash index (core.lsh):
    the LSH twin of ``estimator_ce``, feeding the SAME ``_sparse_ce``
    custom VJP — the head here is already ROW-granular (the plan's dedup'd
    candidate union), so there is no block expansion; gradients scatter-add
    into exactly the collision-head/tail/label rows.

    Consistency: head membership, tail rejection, and ``label_in_head``
    all evaluate the one collision predicate (``lsh._collide``) —
    code-match in any table where the row is actually routed — so every
    row lands in exactly one of {head, tail population, explicit label
    term} and no mass is double-counted or lost (overflow-dropped rows
    fall through to the tail population).

    ``cand_cap`` statically trims the scored union like ``estimator_ce``'s
    head_cap (0 = no trim: training batches don't share context, so the
    serving auto-cap would always overflow).
    """
    plan = _lsh.lsh_plan(lsh_index, h, key, l,
                         cand_cap=cand_cap if cand_cap > 0 else lsh_index.n)
    lab_codes = lsh_index.codes[labels]                      # (T, L)
    lab_ok = lsh_index.slot_of_row[labels] >= 0              # (T, L)
    label_in_head = jnp.any((plan.qcodes == lab_codes) & lab_ok, axis=-1)
    accept = plan.tail_accept & (plan.tail_ids[None, :] != labels[:, None])
    n_tail_total = (lsh_index.n - plan.k_eff).astype(jnp.float32) \
        - (~label_in_head).astype(jnp.float32)

    def run(rows, member, col_live):
        del col_live       # membership already encodes dead columns
        return _sparse_ce(h, w, labels, rows, member, plan.tail_ids,
                          accept, plan.tail_bias, n_tail_total,
                          label_in_head)

    nll, log_z = _lsh._with_trimmed_cands(plan, run)
    aux = {"head_hit_rate": jnp.mean(label_in_head.astype(jnp.float32)),
           "k_eff": jnp.mean(plan.k_eff.astype(jnp.float32)),
           "head_live": plan.cand_live}
    return nll, log_z, aux


# ---------------------------------------------------------------------------
# loss entry points — each maps (model, params, batch, key, cfg) -> scalar
# ---------------------------------------------------------------------------

def make_token_constraint(mesh):
    """Constraint fn re-pinning the token dim to the data axes after the
    remat/reshape boundary (without it the CE inherits a replicated-T
    fixpoint and its logit chunks are materialized at full T — measured
    550 GB/step of all-reduces on rwkv6 train_4k at (16,16))."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    size = 1
    for a in axes:
        size *= mesh.shape[a]

    def constrain(x):
        if not axes or x.shape[0] % size:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(axes, *([None] * (x.ndim - 1)))))
    return constrain


def _flatten_head(model, params, hidden, labels, constrain_fn=None):
    """Returns (h2d (T, d), w (V, d), lab (T,)) handling codebook heads."""
    cfg = model.cfg
    c = constrain_fn or (lambda x: x)
    w = model.head_matrix(params)
    if cfg.n_codebooks:
        t = hidden.shape[0] * hidden.shape[1]
        h2 = jnp.repeat(hidden.reshape(t, -1), cfg.n_codebooks, axis=0)
        wf = w.reshape(cfg.n_codebooks * cfg.vocab, -1)
        lab = (labels.reshape(t, cfg.n_codebooks) +
               jnp.arange(cfg.n_codebooks) * cfg.vocab)
        # treat each codebook as its own vocab segment of a single big head
        return h2, wf, lab.reshape(-1)
    return (c(hidden.reshape(-1, hidden.shape[-1])), w,
            c(labels.reshape(-1)))


def loss_fused_ce(model, params, batch, key, train_cfg, *,
                  backend="xla", constrain_fn=None) -> Tuple[Array, Dict]:
    tokens, labels = batch["tokens"], batch["labels"]
    hidden, aux = model.forward(params, tokens, img=batch.get("img"))
    h2, w, lab = _flatten_head(model, params, hidden, labels, constrain_fn)
    nll, lse = streaming_ce(h2, w, lab, backend=backend)
    loss = nll.mean()
    metrics = {"loss": loss, "ppl_proxy": loss,
               "mean_log_z": lse.mean(),
               **{k: v for k, v in aux.items() if "moe" in k}}
    total = loss + aux.get("moe_balance", 0.0) + aux.get("moe_zloss", 0.0)
    return total, metrics


def loss_ce(model, params, batch, key, train_cfg) -> Tuple[Array, Dict]:
    """Naive full-logits CE — small vocabs/tests."""
    tokens, labels = batch["tokens"], batch["labels"]
    hidden, aux = model.forward(params, tokens, img=batch.get("img"))
    logits = model.logits(params, hidden)
    if model.cfg.n_codebooks:
        lse = jax.nn.logsumexp(logits, -1)
        picked = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
        nll = (lse - picked).mean()
    else:
        lse = jax.nn.logsumexp(logits, -1)
        picked = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
        nll = (lse - picked).mean()
    total = nll + aux.get("moe_balance", 0.0) + aux.get("moe_zloss", 0.0)
    return total, {"loss": nll, "mean_log_z": lse.mean()}


def loss_selfnorm(model, params, batch, key, train_cfg, *,
                  backend="xla", constrain_fn=None) -> Tuple[Array, Dict]:
    """CE + alpha log(Z)^2 (Devlin) — trains Z(q) ~= 1 so that serving can
    use method='selfnorm' (the heuristic the paper beats in Table 4)."""
    tokens, labels = batch["tokens"], batch["labels"]
    hidden, aux = model.forward(params, tokens, img=batch.get("img"))
    h2, w, lab = _flatten_head(model, params, hidden, labels, constrain_fn)
    nll, lse = streaming_ce(h2, w, lab, backend=backend)
    alpha = train_cfg.selfnorm_alpha
    loss = nll.mean() + alpha * jnp.mean(lse ** 2)
    return loss + aux.get("moe_balance", 0.0), {
        "loss": nll.mean(), "mean_log_z": lse.mean(),
        "selfnorm_penalty": jnp.mean(lse ** 2)}


def loss_nce(model, params, batch, key, train_cfg) -> Tuple[Array, Dict]:
    """NCE with Z clamped to 1, uniform-unigram noise (paper SS5.2 setup)."""
    tokens, labels = batch["tokens"], batch["labels"]
    hidden, aux = model.forward(params, tokens, img=batch.get("img"))
    h2, w, lab = _flatten_head(model, params, hidden, labels)
    t = h2.shape[0]
    kn = train_cfg.nce_noise
    v = w.shape[0]
    noise = jax.random.randint(key, (t, kn), 0, v)
    s_t = jnp.sum(h2 * w[lab], axis=-1)
    s_n = jnp.einsum("td,tkd->tk", h2, w[noise])
    log_q = -jnp.log(jnp.float32(v))                 # uniform noise
    log_k = jnp.log(jnp.float32(kn))
    pos = jax.nn.log_sigmoid(s_t - log_k - log_q)
    neg = jax.nn.log_sigmoid(-(s_n - log_k - log_q))
    loss = -(pos.mean() + neg.sum(-1).mean())
    return loss + aux.get("moe_balance", 0.0), {"loss": loss}


def loss_sampled(model, params, batch, key, train_cfg) -> Tuple[Array, Dict]:
    """Importance-sampled softmax with uniform proposal (UNIFORM baseline)."""
    tokens, labels = batch["tokens"], batch["labels"]
    hidden, aux = model.forward(params, tokens, img=batch.get("img"))
    h2, w, lab = _flatten_head(model, params, hidden, labels)
    t = h2.shape[0]
    kn = train_cfg.nce_noise
    v = w.shape[0]
    samp = jax.random.randint(key, (t, kn), 0, v)
    s_t = jnp.sum(h2 * w[lab], axis=-1)
    s_n = jnp.einsum("td,tkd->tk", h2, w[samp])
    # log Z_hat = log( (V/k) sum exp(s_n) )  (uniform IS estimate of Z)
    log_z = (jax.nn.logsumexp(s_n, -1) + jnp.log(jnp.float32(v))
             - jnp.log(jnp.float32(kn)))
    loss = (log_z - s_t).mean()
    return loss + aux.get("moe_balance", 0.0), {"loss": loss,
                                                "mean_log_z": log_z.mean()}


def _loss_estimator_ce(model, params, batch, key, train_cfg, *, index,
                       constrain_fn=None) -> Tuple[Array, Dict]:
    """Shared body of mimps_ce / mince_ce (see module docstring: by the
    collapse identity the anchored MINCE root IS the Eq. 5 anchor, so the
    two names share one estimate and one sparse VJP)."""
    if index is None:
        raise ValueError(
            "estimator-backed losses need an IVF index threaded through "
            "TrainState (init_train_state builds it; launch/train.py "
            "refreshes it every --index-refresh-every steps)")
    cfg = model.cfg
    if cfg.n_codebooks:
        raise NotImplementedError(
            "estimator-backed CE serves single-stream heads; audio "
            "codebook training uses the per-codebook exact losses")
    pc = cfg.partition
    tokens, labels = batch["tokens"], batch["labels"]
    hidden, aux = model.forward(params, tokens, img=batch.get("img"))
    h2, w, lab = _flatten_head(model, params, hidden, labels, constrain_fn)
    nll, lse, est_aux = estimator_ce(index, h2, w, lab, key,
                                     n_probe=pc.n_probe, l=pc.l,
                                     head_cap=pc.head_cap)
    loss = nll.mean()
    metrics = {"loss": loss, "ppl_proxy": loss, "mean_log_z": lse.mean(),
               **est_aux,
               **{k: v for k, v in aux.items() if "moe" in k}}
    total = loss + aux.get("moe_balance", 0.0) + aux.get("moe_zloss", 0.0)
    return total, metrics


def loss_mimps_ce(model, params, batch, key, train_cfg, *, index,
                  constrain_fn=None) -> Tuple[Array, Dict]:
    """Eq. 5-backed CE: exact probe-union head + Rao-Blackwellized uniform
    tail, sparse embedding gradients (DESIGN.md SS13)."""
    return _loss_estimator_ce(model, params, batch, key, train_cfg,
                              index=index, constrain_fn=constrain_fn)


def loss_lsh_ce(model, params, batch, key, train_cfg, *, index,
                constrain_fn=None) -> Tuple[Array, Dict]:
    """SimHash-backed estimator CE: the ``lsh`` serving backend's training
    twin. Same sparse forward/backward (``_sparse_ce``) with the collision
    head replacing the probe union; ``TrainState.index`` carries an
    ``lsh.LSHIndex`` whose between-refresh maintenance is a cheap
    ``rehash_lsh``/``update_rows`` instead of a k-means rebuild."""
    if index is None:
        raise ValueError(
            "lsh_ce needs an LSH index threaded through TrainState "
            "(init_train_state builds it; launch/train.py refreshes it "
            "every --index-refresh-every steps)")
    cfg = model.cfg
    if cfg.n_codebooks:
        raise NotImplementedError(
            "estimator-backed CE serves single-stream heads; audio "
            "codebook training uses the per-codebook exact losses")
    pc = cfg.partition
    tokens, labels = batch["tokens"], batch["labels"]
    hidden, aux = model.forward(params, tokens, img=batch.get("img"))
    h2, w, lab = _flatten_head(model, params, hidden, labels, constrain_fn)
    nll, lse, est_aux = lsh_estimator_ce(index, h2, w, lab, key, l=pc.l,
                                         cand_cap=pc.head_cap)
    loss = nll.mean()
    metrics = {"loss": loss, "ppl_proxy": loss, "mean_log_z": lse.mean(),
               **est_aux,
               **{k: v for k, v in aux.items() if "moe" in k}}
    total = loss + aux.get("moe_balance", 0.0) + aux.get("moe_zloss", 0.0)
    return total, metrics


def loss_mince_ce(model, params, batch, key, train_cfg, *, index,
                  constrain_fn=None) -> Tuple[Array, Dict]:
    """Anchored-MINCE CE. The anchored estimating equation's root coincides
    with the Eq. 5 anchor (the PR-3 collapse identity, proved in
    ``core.mince.anchored_solve``), so the estimate — and therefore the
    gradient — is identical to ``mimps_ce``; registered separately so
    ``--loss`` names mirror serving's ``--method`` registry."""
    return _loss_estimator_ce(model, params, batch, key, train_cfg,
                              index=index, constrain_fn=constrain_fn)


LOSSES: Dict[str, Callable] = {
    "fused_ce": loss_fused_ce,
    "ce": loss_ce,
    "selfnorm": loss_selfnorm,
    "nce": loss_nce,
    "sampled": loss_sampled,
    "mimps_ce": loss_mimps_ce,
    "mince_ce": loss_mince_ce,
    "lsh_ce": loss_lsh_ce,
}

# losses whose forward/backward go through a device-resident retrieval index
# (train_loop threads TrainState.index into these; mimps_ce/mince_ce carry a
# block-IVF index, lsh_ce a SimHash index)
ESTIMATOR_LOSSES = ("mimps_ce", "mince_ce", "lsh_ce")


def get_loss(name: str) -> Callable:
    return LOSSES[name]
