"""Gradient compression for the cross-pod (DCN) axis.

Int8 block quantization with per-block scales: the pod-level gradient
all-reduce is the only collective that crosses DCN (DESIGN.md SS6), so
compressing it 4x directly cuts the multi-pod collective roofline term.
Error feedback is unnecessary here because quantization happens per step on
the *gradient* (not a persistent model delta) and the optimizer's momentum
absorbs zero-mean quantization noise; EF hooks can be added at the optimizer
level if a future paper needs them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def _quantize_int8(x: jax.Array):
    """Symmetric per-tensor int8 with f32 scale."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_psum(grads, axis_name: str, mode: str = "none"):
    """psum gradients over `axis_name`; mode='int8' quantizes before the
    all-reduce (int8 summed in int32, rescaled after)."""
    if mode == "none":
        return jax.tree.map(lambda g: lax.psum(g, axis_name), grads)
    if mode != "int8":
        raise ValueError(f"unknown grad compression {mode!r}")

    # max-scale convention: all shards quantize with the all-reduced max
    # scale (one extra scalar psum) so the int payloads sum exactly.
    def one_maxscale(g):
        gf = g.astype(jnp.float32)
        amax = lax.pmax(jnp.max(jnp.abs(gf)), axis_name) + 1e-12
        scale = amax / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        s = lax.psum(q.astype(jnp.int32), axis_name)
        return (s.astype(jnp.float32) * scale).astype(g.dtype)

    return jax.tree.map(one_maxscale, grads)
