"""The 10 assigned architectures + the paper's own LBL model, exactly as
specified in the assignment (sources in brackets there). One function per
arch so ``--arch <id>`` resolves through the registry in __init__.py."""
from __future__ import annotations

from .base import ModelConfig, MoEConfig, PartitionConfig, SSMConfig

# Partition-estimation defaults: MIMPS for the big-vocab archs (the paper's
# winner), exact for vocab < 16k where k+l+probes approaches N (DESIGN.md SS5).
_MIMPS = PartitionConfig(method="mimps", k=1000, l=1000, n_probe=16,
                         block_rows=512)
_EXACT = PartitionConfig(method="exact")


def mistral_nemo_12b() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b", family="dense", n_layers=40, d_model=5120,
        n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336, vocab=131072,
        max_seq_len=131072, act="silu", rope_theta=1e6, partition=_MIMPS)


def gemma3_4b() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b", family="dense", n_layers=34, d_model=2560,
        n_heads=8, n_kv_heads=4, head_dim=256, d_ff=10240, vocab=262144,
        max_seq_len=131072, act="gelu", sliding_window=1024,
        local_global_ratio=5, tie_embeddings=True, rope_theta=1e6,
        partition=_MIMPS, subquadratic=True)


def nemotron_4_15b() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b", family="dense", n_layers=32, d_model=6144,
        n_heads=48, n_kv_heads=8, head_dim=128, d_ff=24576, vocab=256000,
        max_seq_len=4096, act="sqrelu", rope_theta=1e4, partition=_MIMPS)


def qwen15_4b() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b", family="dense", n_layers=40, d_model=2560,
        n_heads=20, n_kv_heads=20, head_dim=128, d_ff=6912, vocab=151936,
        max_seq_len=32768, act="silu", qkv_bias=True, rope_theta=1e6,
        partition=_MIMPS)


def llama32_vision_90b() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b", family="vlm", n_layers=100,
        d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128, d_ff=28672,
        vocab=128256, max_seq_len=131072, act="silu", cross_attn_every=5,
        n_image_tokens=1601, rope_theta=5e5, partition=_MIMPS)


def deepseek_moe_16b() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b", family="moe", n_layers=28, d_model=2048,
        n_heads=16, n_kv_heads=16, head_dim=128, d_ff=1408, vocab=102400,
        max_seq_len=4096, act="silu", rope_theta=1e4,
        moe=MoEConfig(n_experts=64, n_shared=2, top_k=6, expert_d_ff=1408),
        partition=_MIMPS)


def moonshot_v1_16b_a3b() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b", family="moe", n_layers=48, d_model=2048,
        n_heads=16, n_kv_heads=16, head_dim=128, d_ff=1408, vocab=163840,
        max_seq_len=8192, act="silu", rope_theta=5e4,
        moe=MoEConfig(n_experts=64, n_shared=2, top_k=6, expert_d_ff=1408),
        partition=_MIMPS)


def rwkv6_7b() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b", family="ssm", n_layers=32, d_model=4096,
        n_heads=64, n_kv_heads=64, d_ff=14336, vocab=65536,
        max_seq_len=1048576, act="sqrelu",
        ssm=SSMConfig(wkv_head_size=64),
        partition=_MIMPS, subquadratic=True)


def zamba2_7b() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584,
        n_heads=32, n_kv_heads=32, head_dim=112, d_ff=14336, vocab=32000,
        max_seq_len=1048576, act="silu", shared_attn_every=6,
        ssm=SSMConfig(state_dim=64, conv_dim=4, expand=2),
        partition=_EXACT, subquadratic=True)


def musicgen_medium() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium", family="audio", n_layers=48, d_model=1536,
        n_heads=24, n_kv_heads=24, head_dim=64, d_ff=6144, vocab=2048,
        max_seq_len=32768, act="gelu", n_codebooks=4, rope_theta=1e4,
        partition=_EXACT)


def lbl_paper() -> ModelConfig:
    """The paper SS5.2 log-bilinear LM (Mnih & Hinton 2008): d=300, ctx=9.
    Modeled as cfg carrying (vocab, d); the LBL itself lives in models/lbl.py."""
    return ModelConfig(
        name="lbl-paper", family="dense", n_layers=1, d_model=300,
        n_heads=1, n_kv_heads=1, head_dim=300, d_ff=300, vocab=10000,
        max_seq_len=9, act="silu",
        partition=PartitionConfig(method="mimps", k=100, l=100, n_probe=8,
                                  block_rows=128))
