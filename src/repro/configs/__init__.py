"""Config registry: ``get_config(arch_id)`` + reduced smoke variants."""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List

from . import archs
from .base import (ModelConfig, MoEConfig, PartitionConfig, SSMConfig,
                   ServingConfig, ShapeConfig, TrainConfig, SHAPES,
                   get_shape)

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {
    "mistral-nemo-12b": archs.mistral_nemo_12b,
    "gemma3-4b": archs.gemma3_4b,
    "nemotron-4-15b": archs.nemotron_4_15b,
    "qwen1.5-4b": archs.qwen15_4b,
    "llama-3.2-vision-90b": archs.llama32_vision_90b,
    "deepseek-moe-16b": archs.deepseek_moe_16b,
    "moonshot-v1-16b-a3b": archs.moonshot_v1_16b_a3b,
    "rwkv6-7b": archs.rwkv6_7b,
    "zamba2-7b": archs.zamba2_7b,
    "musicgen-medium": archs.musicgen_medium,
    "lbl-paper": archs.lbl_paper,
}

ASSIGNED_ARCHS: List[str] = [k for k in _REGISTRY if k != "lbl-paper"]


def get_config(arch: str) -> ModelConfig:
    if arch not in _REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[arch]()


def reduced_config(arch: str) -> ModelConfig:
    """Same family/topology, laptop-scale: used by per-arch smoke tests.

    Keeps every structural feature (grouping pattern, MoE routing, ssm state)
    while shrinking width/depth/vocab."""
    cfg = get_config(arch)
    opts = dict(
        d_model=128, n_heads=4, n_kv_heads=max(1, 4 * cfg.n_kv_heads
                                               // max(cfg.n_heads, 1)),
        head_dim=32, d_ff=256, vocab=512, max_seq_len=256,
        remat="none",
        partition=dataclasses.replace(cfg.partition, k=16, l=16, n_probe=2,
                                      block_rows=32, n_clusters=8),
    )
    if cfg.family == "moe":
        opts["moe"] = MoEConfig(n_experts=8, n_shared=1, top_k=2,
                                expert_d_ff=64)
        opts["n_layers"] = 2
    elif cfg.local_global_ratio:
        opts["n_layers"] = 8        # one (5L+1G) group + 2 tail locals
        opts["sliding_window"] = 32
    elif cfg.family == "vlm":
        opts["n_layers"] = 10       # two (4 self + 1 cross) groups
        opts["n_image_tokens"] = 16
    elif cfg.family == "hybrid":
        opts["n_layers"] = 8        # one group of 6 + 2 tail
        opts["shared_attn_every"] = 6
        opts["ssm"] = SSMConfig(state_dim=16, conv_dim=4, expand=2)
        opts["head_dim"] = 32
    elif cfg.family == "ssm":
        opts["n_layers"] = 2
        opts["ssm"] = SSMConfig(wkv_head_size=32)
        opts["d_model"] = 128
    elif cfg.family == "audio":
        opts["n_layers"] = 2
        opts["vocab"] = 64
    else:
        opts["n_layers"] = 2
    return dataclasses.replace(cfg, **opts)


__all__ = ["get_config", "reduced_config", "ASSIGNED_ARCHS", "ModelConfig",
           "MoEConfig", "PartitionConfig", "ServingConfig", "SSMConfig",
           "ShapeConfig", "TrainConfig", "SHAPES", "get_shape"]
