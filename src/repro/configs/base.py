"""Config dataclasses for the framework.

Every assigned architecture is expressed as a ``ModelConfig``; the paper's
technique is configured via ``PartitionConfig`` and is a first-class field of
the model config (it parameterizes the output layer / serving path).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class PartitionConfig:
    """Configuration of the sublinear partition estimator (the paper's core).

    method:
      exact    - brute force Z (baseline; also the fused-kernel path)
      mimps    - Eq.5: head via MIPS + uniform tail correction (paper's winner)
      nmimps   - Eq.4: head only (shown inadequate in the paper)
      uniform  - k=0 special case (importance sampling baseline)
      mince    - Eq.6/7: NCE-for-Z with Halley's method
      fmbe     - Eq.8/10: Kar-Karnick random feature maps
      selfnorm - assume Z == 1 (Devlin/NCE-clamped heuristic, paper SS5.2)
      topk     - Eq.4 head-only (nmimps at the output layer): cheapest
                 retrieval tier — no tail sampling, log Ẑ from the probed
                 head alone. Biased low (the paper shows Eq.4 inadequate as
                 an *estimator*), kept as the last rung of the serving
                 degradation ladder where finishing requests beats
                 calibrated log Ẑ.
      lsh      - Eq.5 head/tail combine over a SimHash collision head
                 (Spring & Shrivastava 2017): fixed random hyperplanes, O(1)
                 per-row index updates, no centroid maintenance (core.lsh).
    """
    method: str = "exact"
    k: int = 100                  # head size |S_k(q)|
    l: int = 100                  # tail sample size |U_l|
    sample_k: int = 8             # head candidates kept for temperature
                                  # sampling (Gumbel-max over the retrieved
                                  # top-sample_k; greedy decode retrieves 1)
    # IVF (TPU-native MIPS) parameters
    n_clusters: int = 256
    n_probe: int = 8
    block_rows: int = 512         # vocab rows per Pallas block (cluster pad)
    head_cap: int = 0             # static union capacity of the XLA decode
                                  # paths (blocks); 0 = auto (n_probe plus
                                  # overlap headroom, decode._resolve_head_cap).
                                  # Shared-context decode batches dedup to
                                  # U ~ n_probe, so the trimmed gather is the
                                  # common case; overflow falls back to the
                                  # full min(Q*n_probe, n_blocks) trace
                                  # (slower, never wrong).
    # FMBE parameters
    fmbe_features: int = 4096     # P
    fmbe_max_degree: int = 8      # cap on M ~ Geometric(1/p)
    fmbe_p: float = 2.0
    # LSH (SimHash/ALSH-MIPS) parameters — the second retrieval structure
    lsh_bits: int = 8             # K sign bits per table (<= 24: packed
                                  # codes stay f32-exact for the kernel's
                                  # matmul packing)
    lsh_tables: int = 8           # L independent hash tables
    lsh_bucket_cap: int = 0       # rows per bucket (static shape); 0 = auto
                                  # (4x the uniform-hash mean, lsh.lsh_bucket_cap)
    lsh_mips_scale: float = 0.0   # MIPS norm cap M = scale * max|w|: rows
                                  # heavier than M hash by pure angle,
                                  # lighter rows sink toward the tail;
                                  # 0 = angle-only SimHash everywhere
    lsh_tail_beta: float = 8.0    # norm-tempered tail proposal
                                  # p_r ∝ exp(beta * |w_r|/max|w|);
                                  # 0 = uniform tail
    # MINCE solver
    mince_iters: int = 2          # iterations of the general bracketed
                                  # Halley solvers (oracle weighting='paper'
                                  # and the sharded stats solve); the
                                  # single-node anchored serving estimate is
                                  # closed-form — its root IS the Eq.5
                                  # anchor (mince.anchored_solve) — so it
                                  # needs none. The seed's 25 dated from the
                                  # unbracketed cold-start solver
    mince_solver: str = "halley"  # or "newton"

    def validate(self) -> None:
        assert self.method in (
            "exact", "mimps", "nmimps", "uniform", "mince", "fmbe",
            "selfnorm", "topk", "lsh")
        assert self.k >= 0 and self.l >= 0
        assert self.sample_k >= 1
        assert 1 <= self.lsh_bits <= 24 and self.lsh_tables >= 1


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Overload policy for ``serve.Server`` (DESIGN.md SS14).

    Every knob is in **virtual steps** (the server's deterministic clock),
    so the same trace degrades/sheds identically on any machine. Defaults
    keep every mechanism off — a Server without a ServingConfig behaves
    exactly like the PR-4 unbounded-queue loop.
    """
    max_queue: int = 0            # admission-queue bound; arrivals past it
                                  # are shed as errored completions with
                                  # reason 'queue_full' (0 = unbounded)
    default_deadline: int = 0     # deadline (virtual steps from submission)
                                  # stamped on requests that carry none
                                  # (0 = no default; requests may still set
                                  # their own Request.deadline)
    # estimator-tier graceful degradation: under sustained queue pressure
    # the server walks DOWN the ladder (cheaper tiers keep lanes moving),
    # and restores UP with hysteresis once pressure drops. () = the
    # method's default ladder (serve.server.default_ladder).
    degrade_ladder: Tuple[str, ...] = ()
    degrade_high: int = 0         # queue depth that counts as pressure
                                  # (0 = degradation disabled)
    degrade_low: int = 0          # queue depth that counts as calm
    degrade_after: int = 3        # consecutive pressured steps -> step down
    restore_after: int = 8        # consecutive calm steps -> step up
                                  # (> degrade_after: the hysteresis band)
    # estimator health: when True the compiled step routes queries whose
    # estimate is unhealthy (non-finite log Ẑ / empty probe union /
    # non-finite candidate scores) through the exact fused fallback under
    # lax.cond — no NaN ever reaches sampling.
    health_guard: bool = True
    # retrieval-state integrity: every N scheduler steps the engine's
    # current-tier state is checksummed against the digest recorded at
    # build/swap time; a mismatch (bit-rotted or bad-swap index) rebuilds
    # the state from params BEFORE the step consumes it. The digest pass
    # reads the whole index (O(V d)), so this is a chaos-test / low-cadence
    # production knob, not a per-step default (0 = off).
    verify_index_every: int = 0
    # admission lookahead (DESIGN.md SS16a): with the prefix cache on a
    # mesh, the queue head may prefer the data replica that owns its cached
    # blocks while that replica is full — strict FIFO would either stall
    # admission or forfeit the hit. admit_window > 0 lets the server HOLD
    # up to that many such requests per admission pass (first fit within
    # the window admits instead), counting each hold in
    # ``ServerReport.admit_skipped``. A held request is force-admitted
    # anywhere (forfeiting its cache hit) after admit_hold holds or when
    # its deadline is within admit_hold steps — bounded unfairness, no
    # starvation. 0 = strict FIFO (the PR-6 behavior).
    admit_window: int = 0
    admit_hold: int = 8

    def validate(self) -> None:
        assert self.max_queue >= 0 and self.default_deadline >= 0
        assert self.degrade_high >= self.degrade_low >= 0
        assert self.degrade_after >= 1 and self.restore_after >= 1
        assert self.verify_index_every >= 0
        assert self.admit_window >= 0 and self.admit_hold >= 1


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Observability layer (``obs``, DESIGN.md SS17).

    Cadences are in **scheduler steps**. Everything here is host policy:
    the device-resident metric state is threaded through the compiled step
    unconditionally (same executable with observability on or off — that is
    what keeps tokens bit-identical), and this config only decides how often
    the host harvests it and where the results go. Defaults give live
    metrics with shadow sampling at 1/16 steps and no file/network sinks.
    """
    metrics: bool = True          # harvest device metrics into the registry
    harvest_every: int = 16       # steps between device->host metric reads
                                  # (the only readback observability adds;
                                  # the per-step outs readback already
                                  # exists for token streaming)
    shadow_every: int = 16        # steps between shadow-sampled exact log-Z
                                  # passes (0 = off). The pass runs under
                                  # lax.cond inside the SAME executable; the
                                  # cadence flag is traced data
    trace_path: str = ""          # per-request span trace (Chrome-trace
                                  # JSONL); "" = tracing off
    metrics_port: int = 0         # Prometheus text exposition on
                                  # 127.0.0.1:port (0 = no HTTP server)
    snapshot_path: str = ""       # periodic JSON metric snapshots ("" = off)
    snapshot_every: int = 4       # snapshots are written every N harvests

    def validate(self) -> None:
        assert self.harvest_every >= 1
        assert self.shadow_every >= 0
        assert self.snapshot_every >= 1
        assert 0 <= self.metrics_port < 65536


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 64
    n_shared: int = 2
    top_k: int = 6
    expert_d_ff: int = 1408
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    aux_loss: float = 1e-2


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) / RWKV6 parameters."""
    state_dim: int = 64
    conv_dim: int = 4
    n_ssm_heads: int = 0          # 0 -> derived
    expand: int = 2
    wkv_head_size: int = 64       # RWKV6


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"         # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int = 12
    d_model: int = 768
    n_heads: int = 12
    n_kv_heads: int = 12
    head_dim: int = 0             # 0 -> d_model // n_heads
    d_ff: int = 3072
    vocab: int = 32000
    max_seq_len: int = 131072
    act: str = "silu"             # silu | gelu | sqrelu
    qkv_bias: bool = False
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # sliding-window / local:global attention (gemma3)
    sliding_window: int = 0       # 0 -> full attention
    local_global_ratio: int = 0   # e.g. 5 -> every 6th layer is global
    # VLM cross attention
    cross_attn_every: int = 0     # e.g. 5 -> layers 4,9,... are cross-attn
    n_image_tokens: int = 1601
    # audio (musicgen)
    n_codebooks: int = 0          # >0 -> audio token streams w/ delay pattern
    # hybrid (zamba2): shared attention block every `shared_attn_every` layers
    shared_attn_every: int = 0
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    partition: PartitionConfig = dataclasses.field(default_factory=PartitionConfig)
    # remat policy for the scanned blocks: 'none' | 'full' | 'dots'
    remat: str = "full"
    dtype: str = "bfloat16"
    # which attention impl decode uses; long-context capability flag
    subquadratic: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND roofline checks)."""
        d, L, v = self.d_model, self.n_layers, self.vocab
        hd = self.resolved_head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        if self.act == "sqrelu":
            mlp = 2 * d * self.d_ff
        else:
            mlp = 3 * d * self.d_ff
        if self.family in ("moe",) and self.moe is not None:
            m = self.moe
            e_ff = m.expert_d_ff
            mlp = (m.n_experts + m.n_shared) * 3 * d * e_ff + d * m.n_experts
        if self.family == "ssm":   # rwkv6: time-mix + channel-mix
            s = self.ssm or SSMConfig()
            attn = 5 * d * d + 2 * d * (32 * 5) + d * d  # r,k,v,g,o + lora decay
            mlp = 2 * d * self.d_ff + d * d
        per_layer = attn + mlp + 2 * d
        total = emb + L * per_layer
        if self.shared_attn_every:
            total += attn + mlp  # one shared block
        if self.cross_attn_every:
            n_cross = L // self.cross_attn_every
            total += n_cross * (d * hd * (self.n_heads + 2 * self.n_kv_heads)
                                + self.n_heads * hd * d)
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE-aware) for 6*N_active*D FLOPs."""
        if self.family != "moe" or self.moe is None:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        m = self.moe
        dense_like = self.param_count()
        all_experts = m.n_experts * 3 * d * m.expert_d_ff * L
        active_experts = m.top_k * 3 * d * m.expert_d_ff * L
        return int(dense_like - all_experts + active_experts)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """An assigned (input-shape) cell: seq_len x global_batch + step kind."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # 'train' | 'prefill' | 'decode'


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)


def get_shape(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}; have {[s.name for s in SHAPES]}")


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    microbatches: int = 1         # gradient accumulation
    loss: str = "fused_ce"        # any key of train.losses.LOSSES (fused_ce,
                                  # ce, nce, selfnorm, sampled, mimps_ce,
                                  # mince_ce)
    nce_noise: int = 64
    # estimator-backed losses: IVF index maintenance cadence (steps between
    # recluster/repack refreshes, and Lloyd iterations per refresh)
    index_refresh_every: int = 100
    index_refresh_kmeans_iters: int = 1
    selfnorm_alpha: float = 0.1
    seed: int = 0
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    grad_compression: str = "none"  # none | int8  (pod axis)
