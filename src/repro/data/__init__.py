from .synthetic import SyntheticCorpus, DataIterator, DataState, zipf_probs
