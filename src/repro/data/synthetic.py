"""Synthetic Zipfian corpus pipeline (offline container — no PTB/word2vec).

Deterministic, shardable, resumable: batch t of a run is a pure function of
(seed, step, shard), so restarts and elastic re-sharding never replay or skip
data. Token stream is a Zipf(alpha) unigram draw filtered through a cheap
bigram mixer so models have actual structure to learn (repetition + local
agreement), which is enough for the paper's SS5.2-style LM experiment.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def zipf_probs(vocab: int, alpha: float = 1.1) -> np.ndarray:
    r = np.arange(1, vocab + 1, dtype=np.float64)
    p = r ** (-alpha)
    return (p / p.sum()).astype(np.float64)


@dataclasses.dataclass
class SyntheticCorpus:
    vocab: int
    seed: int = 0
    alpha: float = 1.1
    mix: float = 0.3          # bigram-structure strength

    def __post_init__(self):
        self.probs = zipf_probs(self.vocab, self.alpha)
        rng = np.random.RandomState(self.seed)
        # deterministic "successor" map: w -> preferred next word
        self.successor = rng.permutation(self.vocab)

    def batch(self, step: int, batch: int, seq_len: int,
              shard: int = 0, n_shards: int = 1) -> np.ndarray:
        """Tokens (batch, seq_len + 1) for (step, shard) — pure function."""
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + step * 977 + shard) % (2 ** 31))
        base = rng.choice(self.vocab, size=(batch, seq_len + 1),
                          p=self.probs)
        use_succ = rng.rand(batch, seq_len + 1) < self.mix
        out = base.copy()
        for t in range(1, seq_len + 1):
            out[:, t] = np.where(use_succ[:, t],
                                 self.successor[out[:, t - 1]], base[:, t])
        return out.astype(np.int32)


@dataclasses.dataclass
class DataState:
    """Checkpointable iterator state."""
    step: int = 0

    def to_dict(self):
        return {"step": self.step}

    @staticmethod
    def from_dict(d):
        return DataState(step=int(d["step"]))


class DataIterator:
    """Shard-aware iterator over SyntheticCorpus with resumable state."""

    def __init__(self, corpus: SyntheticCorpus, batch: int, seq_len: int,
                 shard: int = 0, n_shards: int = 1, state: DataState = None,
                 n_codebooks: int = 0):
        self.corpus = corpus
        self.batch = batch
        self.seq_len = seq_len
        self.shard = shard
        self.n_shards = n_shards
        self.state = state or DataState()
        self.n_codebooks = n_codebooks

    def __next__(self) -> Tuple[np.ndarray, np.ndarray]:
        toks = self.corpus.batch(self.state.step, self.batch, self.seq_len,
                                 self.shard, self.n_shards)
        self.state = DataState(self.state.step + 1)
        if self.n_codebooks:
            # audio: C parallel codebook streams with the delay pattern
            reps = [np.roll(toks, c, axis=1) for c in range(self.n_codebooks)]
            toks = np.stack(reps, axis=-1) % self.corpus.vocab
            return toks[:, :-1], toks[:, 1:]
        return toks[:, :-1], toks[:, 1:]

    def __iter__(self) -> Iterator:
        return self
