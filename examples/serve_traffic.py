"""Continuous-batching quickstart: serve a live traffic stream.

  PYTHONPATH=src python examples/serve_traffic.py

Builds a reduced model, wraps it in the slot scheduler, and serves a small
Poisson arrival stream of mixed-length, mixed-temperature requests with
streaming callbacks — then shows the two properties the subsystem is built
around: (1) slot-table decoding is bit-identical per request to a solo
``generate()`` run, and (2) everything after the first step/admission runs
with ZERO recompiles.

Part two serves the raw-speed stack (DESIGN.md SS16) on a shared
system-prompt workload — every request opens with the same template, the
agent/RAG deployment shape: the prefix KV cache turns the shared replay
into block copies, and estimator-speculative decoding (a cheap ``topk``
draft verified by the serving tier in one batched pass) lands several
tokens per step. Tokens stay bit-identical the whole way; the demo prints
cache hits and the acceptance rate per serving tier.
"""
import sys
sys.path.insert(0, "src")

import dataclasses

import jax
import numpy as np

from repro.configs import reduced_config
from repro.models import Model
from repro.serve import (Engine, Request, Scheduler, Server, generate,
                         poisson_arrivals)

# -- model + engine (mimps partition estimation at the output layer) --------
cfg = reduced_config("qwen1.5-4b")
cfg = dataclasses.replace(
    cfg, vocab=4096, partition=dataclasses.replace(
        cfg.partition, method="mimps", block_rows=128, n_probe=4, l=128))
model = Model(cfg)
key = jax.random.PRNGKey(0)
params = model.init(key)
engine = Engine(model, params, max_len=32, key=key)

# -- a little traffic: 6 requests, mixed prompt lengths and temperatures ----
rng = np.random.default_rng(0)
requests = [
    Request(prompt=rng.integers(0, cfg.vocab, size=(3 + 2 * (i % 3),)),
            max_new_tokens=6,
            key=jax.random.PRNGKey(100 + i),
            temperature=0.0 if i % 2 == 0 else 0.8,
            on_token=lambda r, tok, t: print(
                f"    req {r.req_id}: +token {tok}"),
            on_complete=lambda r, comp: print(
                f"  done req {r.req_id} (T={r.temperature}): {comp.tokens}"))
    for i in range(6)
]

# -- serve: 4 slots, Poisson arrivals, admission queue, slot recycling ------
scheduler = Scheduler(engine, n_slots=4, key=key)
server = Server(scheduler)
report = server.run(arrivals=poisson_arrivals(requests, rate=1.0, seed=0))
print("\ntraffic report:", report.summary())
print(f"compiles: step={scheduler.step_traces} admit="
      f"{scheduler.admit_traces} (1 each; nothing recompiled under mixed "
      f"replay/decode/admission)")

# -- the invisibility guarantee: batched == solo, bit for bit ---------------
req = requests[1]
solo = generate(engine, jax.numpy.asarray(req.prompt)[None],
                req.max_new_tokens, req.key, temperature=req.temperature)
batched = next(c for c in report.completions
               if c.request.req_id == req.req_id).tokens
assert batched == [int(t) for t in np.asarray(solo)[0]]
print(f"\nreq {req.req_id} served in the busy slot table == solo "
      f"generate(): {batched}")

# -- raw speed: shared system prompt + speculation (DESIGN.md SS16) ---------
# Every agent request opens with the same template; after the first
# completion registers its blocks, later admissions copy the shared KV
# instead of replaying it, and a cheap topk draft proposes 4 tokens per
# step for the serving tier to verify in one batched pass.
print("\n--- shared-system-prompt traffic: prefix cache + speculation ---")
system_prompt = rng.integers(0, cfg.vocab, size=(12,))


def shared_wave(n, tag):
    return [Request(prompt=np.concatenate(
                        [system_prompt,
                         rng.integers(0, cfg.vocab, size=(1 + i % 3,))]),
                    max_new_tokens=6,
                    key=jax.random.PRNGKey(500 + tag * 100 + i),
                    temperature=0.0 if i % 2 == 0 else 0.8)
            for i in range(n)]


fast_sched = Scheduler(engine, n_slots=4, key=key,
                       spec_draft="topk", spec_k=4,
                       prefix_cache_blocks=16, prefix_block_tokens=4)
fast_server = Server(fast_sched)
for wave in range(2):        # wave 2 finds the pool warm
    reqs = shared_wave(6, wave)
    for r in reqs:
        fast_server.submit(r)
    rep = fast_server.run()
    for r in reqs:           # still bit-identical to solo generate()
        got = next(c for c in rep.completions
                   if c.request.req_id == r.req_id).tokens
        solo = generate(engine, jax.numpy.asarray(r.prompt)[None],
                        r.max_new_tokens, r.key,
                        temperature=r.temperature)
        assert got == [int(t) for t in np.asarray(solo)[0]]
    acc_by_tier = ", ".join(f"{t}: {a:.0%}" for t, a in
                            sorted(rep.spec_acceptance_by_tier.items()))
    print(f"wave {wave + 1}: {rep.goodput_tok_s:.0f} tok/s, prefix hits "
          f"{rep.prefix['hits']} (saved {rep.prefix['saved_steps']} replay "
          f"steps), acceptance by tier [{acc_by_tier}]")
print(f"pool: {fast_sched.prefix.stats()}")
print(f"compiles: step={fast_sched.step_traces} (drafted, verified, "
      f"variable per-lane acceptance — still one executable); every wave-2 "
      f"token bit-identical to solo generate() on cached KV")
