"""Sublinear-training quickstart: train with the estimator IN the gradient,
then hot-swap the checkpoint into a running traffic server.

  PYTHONPATH=src python examples/train_sublinear.py

The full train->serve loop this PR closes:

  1. train a reduced model with ``--loss mimps_ce``: every step's log Z
     (and its gradient) comes from the IVF probe-union head + uniform tail,
     so both the forward floats AND the embedding-gradient floats are
     sublinear in the vocabulary; the device-resident index rides in
     TrainState and is refreshed (recluster + repack, zero recompiles) as
     the embedding drifts;
  2. checkpoint, restore, and ``Engine.swap_index()`` the trained params
     into a LIVE slot-table server — the scheduler's compiled mixed step
     takes (params, retrieval state) as arguments, so the swap needs no
     recompilation and the very next step serves the new model.
"""
import sys
sys.path.insert(0, "src")

import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.configs.base import TrainConfig
from repro.data import DataIterator, SyntheticCorpus
from repro.models import Model
from repro.serve import Engine, Request, Scheduler, generate
from repro.train import (CheckpointManager, init_train_state,
                         make_index_refresh, make_train_step)

# -- model: mimps at the output layer for BOTH training and serving --------
cfg = reduced_config("qwen1.5-4b")
cfg = dataclasses.replace(
    cfg, vocab=4096, partition=dataclasses.replace(
        cfg.partition, method="mimps", block_rows=64, n_probe=4, l=128,
        n_clusters=16))
model = Model(cfg)
tc = TrainConfig(lr=1e-3, loss="mimps_ce", total_steps=40,
                 index_refresh_every=10)

# -- 1. train: estimator-backed CE, index refreshed every 10 steps ---------
print("== training with mimps_ce (sublinear forward AND backward) ==")
state = init_train_state(model, tc, jax.random.PRNGKey(0))
print(f"   index: {state.index.n_blocks} blocks x "
      f"{state.index.v_blocks.shape[1]} rows (device-resident, in "
      f"TrainState)")
step = jax.jit(make_train_step(model, tc))
refresh = make_index_refresh(model, tc)
it = DataIterator(SyntheticCorpus(vocab=cfg.vocab, seed=0), 4, 8)
for i in range(tc.total_steps):
    toks, labels = next(it)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
    if i and i % tc.index_refresh_every == 0:
        state, rm = refresh(state)
        print(f"   step {i:3d}: index refresh — churn "
              f"{float(rm['churn']):.3f}, drift {float(rm['drift']):.3f}")
    state, met = step(state, batch)
    if i % 10 == 0 or i == tc.total_steps - 1:
        print(f"   step {i:3d}: loss {float(met['loss_total']):.4f} "
              f"(head hit-rate {float(met['head_hit_rate']):.2f})")

# -- checkpoint round-trip (index arrays ride along) -----------------------
ckpt_dir = tempfile.mkdtemp(prefix="sublinear_ckpt_")
mgr = CheckpointManager(ckpt_dir, async_write=False)
mgr.save(tc.total_steps, state)
restored, _ = mgr.restore(None, like=state)
print(f"== checkpoint saved + restored from {ckpt_dir} ==")

# -- 2. serve: start a server on the INITIAL params, then hot-swap ---------
p_init = model.init(jax.random.PRNGKey(0))
engine = Engine(model, p_init, max_len=32, key=jax.random.PRNGKey(0),
                device_index=True)          # fixed-capacity index: swappable
sched = Scheduler(engine, n_slots=4, key=jax.random.PRNGKey(1))


def serve_round(tag):
    reqs = [Request(prompt=[7 + i, 11, 13], max_new_tokens=5,
                    key=jax.random.PRNGKey(100 + i)) for i in range(3)]
    for r in reqs:
        sched.admit(r)
    done = []
    while len(done) < len(reqs):
        done += sched.step()["completions"]
    for c in done:
        print(f"   [{tag}] req {c.request.req_id}: {c.tokens}")
    return done


print("== serving with INITIAL params ==")
serve_round("init")
traces = sched.step_traces

print("== swap_index(trained checkpoint) into the LIVE server ==")
engine.swap_index(restored.params)
done = serve_round("trained")
assert sched.step_traces == traces, "swap must not recompile the step"
print(f"   zero recompiles across the swap (step traces: "
      f"{sched.step_traces})")

# parity: a fresh engine built from the trained params emits the same tokens
eng2 = Engine(model, restored.params, max_len=32,
              key=jax.random.PRNGKey(0), device_index=True)
solo = generate(eng2, jnp.asarray([[7, 11, 13]]), 5, jax.random.PRNGKey(100))
match = solo[0].tolist() == done[0].tokens if done else False
print(f"   swapped-server tokens == fresh-engine generate(): {match}")
