"""End-to-end serving driver (the paper's use case): batched decode where
per-token probabilities come from the configured partition estimator.

  PYTHONPATH=src python examples/serve_sublinear.py

Trains nothing — initializes a reduced qwen-family model, serves a batch of
requests with exact Z, then with sublinear MIMPS Z, and compares the
normalized probabilities and output-layer cost.
"""
import sys
sys.path.insert(0, "src")

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import reduced_config
from repro.models import Model
from repro.serve import Engine, generate

BATCH, PROMPT, GEN = 8, 12, 12

base = reduced_config("qwen1.5-4b")
base = dataclasses.replace(base, vocab=8192)   # big enough for IVF to engage
model = Model(base)
key = jax.random.PRNGKey(0)
params = model.init(key)
prompt = jax.random.randint(key, (BATCH, PROMPT), 0, base.vocab)

outs = {}
for method in ("exact", "mimps", "mince", "fmbe", "lsh", "selfnorm"):
    over = (dict(lsh_bits=7, lsh_tables=12, lsh_bucket_cap=256,
                 head_cap=1024, lsh_tail_beta=16.0)
            if method == "lsh" else {})
    cfg = dataclasses.replace(
        base, partition=dataclasses.replace(
            base.partition, method=method, block_rows=128, n_probe=8, l=512,
            **over))
    # every method dispatches through the same estimator-backend registry
    eng = Engine(Model(cfg), params, max_len=PROMPT + GEN + 1, key=key)
    h = jax.random.normal(key, (BATCH, cfg.d_model)).astype(cfg.dtype) * 0.3
    t0 = time.perf_counter()
    dist = eng.next_token_distribution(h, key)
    jax.block_until_ready(dist["log_z"])
    dt = (time.perf_counter() - t0) * 1e3
    outs[method] = dist
    if method == "lsh":
        # dedup'd collision-head candidates (head_cap) + the IS tail draws
        n_scored = 1024 + 512
    elif eng.index is None:
        n_scored = cfg.vocab
    elif method == "fmbe":
        # head candidates only; the Ẑ itself is the V-independent P·M·d
        # feature sketch, not row scoring
        n_scored = eng.index.n_blocks + 8 * 128
    else:
        n_scored = eng.index.n_blocks + 8 * 128 + 512
    print(f"{method:9s} log Z = {[round(float(z),3) for z in dist['log_z'][:4]]} "
          f"rows scored/query: {n_scored:6d}  ({dt:.0f} ms incl. index)")

err = jnp.abs(1 - jnp.exp(outs["mimps"]["log_z"] - outs["exact"]["log_z"]))
agree = jnp.mean((outs["mimps"]["token"] == outs["exact"]["token"])
                 .astype(jnp.float32))
print(f"\nMIMPS vs exact: mean |dZ|/Z = {float(err.mean())*100:.2f}%  "
      f"argmax agreement = {float(agree)*100:.0f}%")
print("(untrained weights -> near-flat logits, so argmax among ties is "
      "noise; Z accuracy is the estimator property. Trained-model behavior: "
      "examples/train_selfnorm_vs_mimps.py and tests/test_infra.py)")

# full generation loop under the sublinear estimator — greedy, then
# temperature sampling (Gumbel-max over the retrieved head candidates,
# normalized with the estimated log-Ẑ)
cfg = dataclasses.replace(
    base, partition=dataclasses.replace(base.partition, method="mimps",
                                        block_rows=128, n_probe=8, l=512))
eng = Engine(Model(cfg), params, max_len=PROMPT + GEN + 1, key=key)
toks = generate(eng, prompt, GEN, key)
print(f"\ngenerated {toks.shape} tokens under sublinear Z; stream 0: "
      f"{[int(t) for t in toks[0][:10]]}")
toks_t = generate(eng, prompt, GEN, key, temperature=0.8)
print(f"same prompt at temperature 0.8; stream 0: "
      f"{[int(t) for t in toks_t[0][:10]]}")
