"""Paper SS5.2 end-to-end on the framework's full training stack: train a
small LM with the self-normalization penalty (so Z ~= 1 at test time, the
Devlin/NCE heuristic), then show MIMPS beats the "assume Z=1" shortcut on
held-out contexts — Table 4's conclusion, here on a transformer rather than
the LBL (run benchmarks/table4_lbl.py for the faithful LBL version).

  PYTHONPATH=src python examples/train_selfnorm_vs_mimps.py
"""
import sys
sys.path.insert(0, "src")

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.configs.base import TrainConfig
from repro.core import build_ivf, exact_log_z, mimps_ivf
from repro.data import DataIterator, SyntheticCorpus
from repro.models import Model
from repro.train import init_train_state, make_train_step

STEPS, BATCH, SEQ = 120, 16, 64

cfg = dataclasses.replace(reduced_config("qwen1.5-4b"), vocab=4096)
model = Model(cfg)
tc = TrainConfig(lr=2e-3, total_steps=STEPS, loss="selfnorm",
                 selfnorm_alpha=0.2, warmup_steps=10)
state = init_train_state(model, tc, jax.random.PRNGKey(0))
step = jax.jit(make_train_step(model, tc))
corpus = SyntheticCorpus(vocab=cfg.vocab, seed=0)
it = DataIterator(corpus, BATCH, SEQ)

for i in range(STEPS):
    toks, labels = next(it)
    state, m = step(state, {"tokens": jnp.asarray(toks),
                            "labels": jnp.asarray(labels)})
    if i % 30 == 0 or i == STEPS - 1:
        print(f"step {i:4d} loss {float(m['loss_total']):.3f} "
              f"mean logZ {float(m['mean_log_z']):+.3f}")

# held-out evaluation: |Z_hat - Z| for MIMPS vs the Z:=1 heuristic
params = state.params
toks, _ = next(it)
hidden, _ = model.forward(params, jnp.asarray(toks))
h = hidden[:, -1]                                    # (B, d) query contexts
w = model.head_matrix(params)
lz_true = jax.vmap(lambda q: exact_log_z(w, q))(h)
z_true = np.exp(np.asarray(lz_true, np.float64))

idx = build_ivf(jax.random.PRNGKey(1), w, block_rows=128)
keys = jax.random.split(jax.random.PRNGKey(2), h.shape[0])
lz_mips = jax.vmap(lambda q, k: mimps_ivf(idx, q, 8, 256, k).log_z)(h, keys)
z_mips = np.exp(np.asarray(lz_mips, np.float64))

abse_mips = np.abs(z_mips - z_true)
abse_nce = np.abs(1.0 - z_true)
print(f"\nheld-out contexts ({h.shape[0]}):")
print(f"  sum|Z_hat - Z|  MIMPS-IVF : {abse_mips.sum():9.3f}")
print(f"  sum|1     - Z|  Z=1 heur. : {abse_nce.sum():9.3f}")
print(f"  MIMPS better on {100*np.mean(abse_mips < abse_nce):.1f}% of "
      f"contexts (paper Table 4: 70.5% at k=l=100)")
