"""Quickstart: estimate the softmax partition function Z(q) sublinearly.

  PYTHONPATH=src python examples/quickstart.py

Builds a word2vec-like class-vector set, then runs every estimator from the
paper (exact / MIMPS / NMIMPS / uniform IS / MINCE / FMBE) plus the
TPU-native block-IVF MIMPS, and prints accuracy + FLOP cost per query.
"""
import sys
sys.path.insert(0, "src")
sys.path.insert(0, ".")

import jax
import jax.numpy as jnp

from benchmarks.common import make_embeddings
from repro.core import (build_fmbe, build_ivf, exact_log_z, fmbe_log_z,
                        make_feature_map, mimps_ivf, mimps_log_z,
                        mince_log_z, nmimps_log_z, relative_error,
                        uniform_log_z)

N, D = 20000, 64
key = jax.random.PRNGKey(0)
v = make_embeddings(key, N, D)
q = v[137]  # a mid-frequency "word" as the query context
k_run = jax.random.fold_in(key, 1)

log_z = exact_log_z(v, q)
print(f"vocab N={N}, d={D}")
print(f"exact    log Z = {float(log_z):.4f}   (cost: {N*D:,} MACs)")

rows = [
    ("MIMPS k=1000 l=1000", mimps_log_z(v, q, 1000, 1000, k_run), 2000 * D),
    ("MIMPS k=100  l=100", mimps_log_z(v, q, 100, 100, k_run), 200 * D),
    ("NMIMPS k=100 (head only)", nmimps_log_z(v, q, 100), 100 * D),
    ("Uniform l=1000", uniform_log_z(v, q, 1000, k_run), 1000 * D),
    ("MINCE k=100 l=100 (Halley)", mince_log_z(v, q, 100, 100, k_run),
     200 * D),
]
fm = make_feature_map(jax.random.fold_in(key, 2), D, 16384)
st = build_fmbe(fm, v)
rows.append(("FMBE P=16384", fmbe_log_z(st, q), 16384 * 8))

print(f"\n{'estimator':30s} {'log Z_hat':>10s} {'rel err %':>10s} "
      f"{'MACs/query':>12s}")
for name, lz, cost in rows:
    err = 100 * float(relative_error(lz, log_z))
    print(f"{name:30s} {float(lz):10.4f} {err:10.2f} {cost:12,}")

# The TPU-native deployment path: block-IVF MIMPS (sublinear retrieval, not
# an oracle sort)
from repro.core import exact_top_k

idx = build_ivf(jax.random.fold_in(key, 3), v, block_rows=256)
r = mimps_ivf(idx, q, n_probe=8, l=256, key=k_run)
cost = (idx.n_blocks + 8 * idx.block_rows + 256) * D
err = 100 * float(relative_error(r.log_z, log_z))
print(f"{'IVF-MIMPS probe=8 l=256':30s} {float(r.log_z):10.4f} {err:10.2f} "
      f"{cost:12,}")
_, true_top = exact_top_k(v, q, 1)
print(f"\nIVF-MIMPS scans {cost/(N*D)*100:.1f}% of brute-force MACs; "
      f"retrieved argmax id {int(r.top_id)} "
      f"(exact argmax {int(true_top[0])})")
